//! The design-space sweep as an end-to-end bench: three core sizes
//! (small-core / table2 / big-core) across the Fig. 8 matrix for a
//! bandwidth-bound subset, with JSON/CSV/Markdown artifacts under
//! reports/ and PPA-shape assertions — resources scaled down must never
//! make a benchmark faster, resources scaled up must never make it
//! slower.
//!
//!     cargo bench --bench dse_sweep             # full 3-variant matrix
//!     cargo bench --bench dse_sweep -- --resume # reuse cached jobs

use std::time::Instant;
use sve_repro::coordinator::{run_dse, SweepConfig};
use sve_repro::report::dse;
use sve_repro::uarch::{parse_variants, ppa};

fn main() {
    let vls = [128usize, 256, 512];
    let names = ["stream_triad", "haccmk", "lulesh_hour", "graph500"];
    let mut cfg = SweepConfig::new(&vls, &names);
    cfg.out_dir = Some("reports".into());
    cfg.resume = std::env::args().any(|a| a == "--resume");
    let variants = parse_variants("small-core,table2,big-core").expect("variant spec");
    let t0 = Instant::now();
    let outcome = run_dse(&cfg, &variants).expect("dse sweep failed");
    let dt = t0.elapsed();
    println!("{}", dse::pivot(&outcome.variants, &vls).to_markdown());
    for p in dse::write_artifacts(&outcome.variants, &vls, "reports").expect("write artifacts")
    {
        println!("wrote {}", p.display());
    }
    println!(
        "dse sweep ({} variants x {} benchmarks x (1 NEON + {} SVE VLs), {} simulated + \
         {} cached, every run validated) in {:.1}s",
        variants.len(),
        names.len(),
        vls.len(),
        outcome.simulated,
        outcome.reloaded,
        dt.as_secs_f64()
    );
    // PPA-shape assertions: cycle counts must respond monotonically to
    // resources on the bandwidth-bound kernel
    let cycles = |vi: usize, bench: &str| {
        let row = outcome.variants[vi].rows.iter().find(|r| r.bench == bench).unwrap();
        (row.neon.cycles, row.sve.last().unwrap().cycles)
    };
    for bench in ["stream_triad", "haccmk"] {
        let small = cycles(0, bench);
        let t2 = cycles(1, bench);
        let big = cycles(2, bench);
        assert!(small.0 >= t2.0 && small.1 >= t2.1, "{bench}: small-core beat table2");
        assert!(t2.0 >= big.0 && t2.1 >= big.1, "{bench}: table2 beat big-core");
    }
    // graph500 is a dependent pointer chase: core width cannot help it
    let (g_small, _) = cycles(0, "graph500");
    let (g_big, _) = cycles(2, "graph500");
    let ratio = g_small as f64 / g_big as f64;
    assert!(
        ratio < 1.5,
        "graph500 must stay latency-bound across core sizes: {ratio:.2}"
    );
    println!("shape assertions PASS");
    // PPA-shape assertions: the area proxy must order the cores at
    // every VL, every run's energy proxy must be positive, and the
    // Pareto ranking must cover the full (variant x VL) matrix with a
    // non-empty frontier
    for &vl in &vls {
        let a_small = ppa::area_um2(&outcome.variants[0].uarch, vl).total_um2;
        let a_t2 = ppa::area_um2(&outcome.variants[1].uarch, vl).total_um2;
        let a_big = ppa::area_um2(&outcome.variants[2].uarch, vl).total_um2;
        assert!(
            a_small < a_t2 && a_t2 < a_big,
            "VL {vl}: area proxy must order the cores: {a_small} / {a_t2} / {a_big}"
        );
    }
    for v in &outcome.variants {
        for r in &v.rows {
            for run in std::iter::once(&r.neon).chain(r.sve.iter()) {
                let e = dse::run_energy_pj(run, &v.uarch);
                assert!(
                    e.is_finite() && e > 0.0,
                    "{}/{}: energy proxy must be positive, got {e}",
                    v.name,
                    run.bench
                );
            }
        }
    }
    let pts = dse::pareto(&outcome.variants, &vls);
    assert_eq!(pts.len(), outcome.variants.len() * vls.len());
    assert!(pts.iter().any(|p| p.frontier), "frontier must be non-empty");
    println!("{}", dse::pareto_table(&pts).to_markdown());
    println!("ppa assertions PASS");
}
