//! Fig. 2/3 companion bench: daxpy instruction parity + per-VL cycles +
//! simulator wall-clock throughput on the kernel.
//!
//!     cargo bench --bench fig2_daxpy

use sve_repro::bench_util::{bench_default, report_throughput};
use sve_repro::compiler::{compile, BinOp, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
use sve_repro::exec::Executor;
use sve_repro::mem::Memory;
use sve_repro::uarch::{run_timed, UarchConfig};

fn daxpy_kernel(mem: &mut Memory, n: u64) -> Kernel {
    let xb = mem.alloc(8 * n, 64);
    let yb = mem.alloc(8 * n, 64);
    for i in 0..n {
        mem.write_f64(xb + 8 * i, i as f64).unwrap();
        mem.write_f64(yb + 8 * i, 1.0).unwrap();
    }
    let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
    let x = k.array("x", Ty::F64, xb);
    let y = k.array("y", Ty::F64, yb);
    k.body.push(Stmt::Store {
        arr: y,
        idx: Index::Affine { offset: 0 },
        value: Expr::bin(
            BinOp::Add,
            Expr::bin(BinOp::Mul, Expr::ConstF(3.0), Expr::load(x, Index::Affine { offset: 0 })),
            Expr::load(y, Index::Affine { offset: 0 })),
    });
    k
}

fn main() {
    let n = 4096u64;
    let mut mem = Memory::new();
    let k = daxpy_kernel(&mut mem, n);
    println!("daxpy n={n}: simulated cycles per target/VL");
    for (label, t, vl) in [
        ("scalar", Target::Scalar, 128),
        ("neon", Target::Neon, 128),
        ("sve-128", Target::Sve, 128),
        ("sve-256", Target::Sve, 256),
        ("sve-512", Target::Sve, 512),
        ("sve-1024", Target::Sve, 1024),
        ("sve-2048", Target::Sve, 2048),
    ] {
        let c = compile(&k, t);
        let mut ex = Executor::new(vl, mem.clone());
        let (stats, tm) =
            run_timed(&mut ex, &c.program, UarchConfig::default(), 10_000_000).unwrap();
        println!(
            "  {label:<9} {:>8} cycles  {:>7} insts  ipc {:.2}",
            tm.cycles,
            stats.insts,
            tm.ipc()
        );
    }
    // host-side throughput of the whole simulate pipeline (functional+timing)
    let c = compile(&k, Target::Sve);
    let sample = bench_default(|| {
        let mut ex = Executor::new(512, mem.clone());
        run_timed(&mut ex, &c.program, UarchConfig::default(), 10_000_000).unwrap().1.cycles
    });
    let insts_per_iter = {
        let mut ex = Executor::new(512, mem.clone());
        ex.run(&c.program, 10_000_000).unwrap().insts as f64
    };
    report_throughput("simulate(daxpy sve-512, func+timing)", &sample, insts_per_iter, "inst");
}
