//! Fig. 7 regeneration + encode/decode round-trip throughput.
//!
//!     cargo bench --bench fig7_encoding

use sve_repro::bench_util::{bench_default, report_throughput};
use sve_repro::isa::encoding::{self, sve_region_report};
use sve_repro::isa::Inst;
use sve_repro::arch::Esize;

fn main() {
    let (groups, total) = sve_region_report();
    println!("Fig. 7 — SVE encoding region usage:");
    for g in &groups {
        println!("  {:<10} {:>12} points ({:.3}%)", g.group, g.points, 100.0 * g.share_of_region);
    }
    println!("  total {total} / {} ({:.2}%)\n", encoding::SVE_REGION_POINTS,
        100.0 * total as f64 / encoding::SVE_REGION_POINTS as f64);
    assert!(total < encoding::SVE_REGION_POINTS);

    let insts: Vec<Inst> = (0..1024)
        .map(|i| Inst::SveFmla { zda: (i % 32) as u8, pg: (i % 8) as u8, zn: ((i * 7) % 32) as u8,
            zm: ((i * 13) % 32) as u8, dbl: i % 2 == 0, sub: i % 3 == 0 })
        .chain((0..1024).map(|i| Inst::While { pd: (i % 16) as u8, esize: Esize::D,
            xn: (i % 31) as u8, xm: ((i * 3) % 31) as u8, unsigned: i % 2 == 0 }))
        .collect();
    let s = bench_default(|| {
        let mut acc = 0u64;
        for (i, inst) in insts.iter().enumerate() {
            let w = encoding::encode(inst, i).unwrap();
            acc ^= w as u64;
            let d = encoding::decode(w, i).unwrap();
            debug_assert_eq!(&d, inst);
        }
        acc
    });
    report_throughput("encode+decode roundtrip", &s, insts.len() as f64, "inst");
}
