//! Regenerates Fig. 8: speedup of SVE@{128,256,512} over Advanced SIMD
//! plus the extra-vectorization bars, for all 12 benchmark proxies.
//! Writes reports/fig8.csv. This is also the end-to-end driver: every
//! run is validated against its golden outputs.
//!
//!     cargo bench --bench fig8_sweep

use std::time::Instant;
use sve_repro::coordinator::{fig8_chart, fig8_table, run_fig8};
use sve_repro::workloads::NAMES;

fn main() {
    let vls = [128usize, 256, 512];
    let t0 = Instant::now();
    let rows = run_fig8(&vls, &NAMES).expect("sweep failed");
    let dt = t0.elapsed();
    let table = fig8_table(&rows, &vls);
    println!("{}", table.to_markdown());
    println!("{}", fig8_chart(&rows, &vls));
    table.write_csv("reports/fig8.csv").expect("write");
    println!(
        "full sweep ({} benchmarks x (1 NEON + {} SVE VLs), every run validated) in {:.1}s",
        NAMES.len(),
        vls.len(),
        dt.as_secs_f64()
    );
    // shape assertions from the paper's narrative
    let get = |n: &str| rows.iter().find(|r| r.bench == n).unwrap();
    assert!(get("haccmk").speedup(0) > 1.5, "HACC wins at equal VL");
    assert!(get("haccmk").speedup(2) > get("haccmk").speedup(0), "HACC scales");
    assert!((0.9..1.1).contains(&get("graph500").speedup(2)), "graph500 flat");
    assert!(get("milcmk").speedup(0) < 1.0, "MILC loses to NEON (compiler quirk)");
    println!("shape assertions PASS");
}
