//! Regenerates Fig. 8: speedup of SVE@{128,256,512} over Advanced SIMD
//! plus the extra-vectorization bars, for all 12 benchmark proxies —
//! on the sharded sweep engine, with JSON/CSV/Markdown artifacts under
//! reports/. This is also the end-to-end driver: every run is validated
//! against its golden outputs.
//!
//!     cargo bench --bench fig8_sweep

use std::time::Instant;
use sve_repro::coordinator::{run_sweep, SweepConfig};
use sve_repro::report::fig8;
use sve_repro::workloads::NAMES;

fn main() {
    let vls = [128usize, 256, 512];
    let mut cfg = SweepConfig::new(&vls, &NAMES);
    cfg.out_dir = Some("reports".into());
    cfg.resume = std::env::args().any(|a| a == "--resume");
    let t0 = Instant::now();
    let outcome = run_sweep(&cfg).expect("sweep failed");
    let dt = t0.elapsed();
    let rows = &outcome.rows;
    println!("{}", fig8::table(rows, &vls).to_markdown());
    println!("{}", fig8::chart(rows, &vls));
    for p in fig8::write_artifacts(rows, &vls, "reports").expect("write artifacts") {
        println!("wrote {}", p.display());
    }
    println!(
        "full sweep ({} benchmarks x (1 NEON + {} SVE VLs), {} simulated + {} cached, \
         every run validated) in {:.1}s",
        NAMES.len(),
        vls.len(),
        outcome.simulated,
        outcome.reloaded,
        dt.as_secs_f64()
    );
    // shape assertions from the paper's narrative
    let get = |n: &str| rows.iter().find(|r| r.bench == n).unwrap();
    assert!(get("haccmk").speedup(0) > 1.5, "HACC wins at equal VL");
    assert!(get("haccmk").speedup(2) > get("haccmk").speedup(0), "HACC scales");
    assert!((0.9..1.1).contains(&get("graph500").speedup(2)), "graph500 flat");
    assert!(get("milcmk").speedup(0) < 1.0, "MILC loses to NEON (compiler quirk)");
    println!("shape assertions PASS");
}
