//! §Perf: simulator hot-path throughput — the numbers EXPERIMENTS.md
//! §Perf tracks. Measures (a) functional-only execution and (b) the full
//! functional+timing pipeline, in host Minst/s, across representative
//! kernels, and writes the machine-readable trajectory to
//! `BENCH_hotpath.json` so the perf history is diffable across PRs.
//!
//!     cargo bench --bench perf_hotpath            # full run
//!     cargo bench --bench perf_hotpath -- --smoke # CI smoke subset

use sve_repro::bench_util::{bench_n, report_throughput, Sample};
use sve_repro::compiler::Target;
use sve_repro::exec::Executor;
use sve_repro::uarch::{run_timed_decoded, UarchConfig};
use sve_repro::workloads;

const VL_BITS: usize = 256;
const KERNELS: [&str; 4] = ["stream_triad", "haccmk", "strlen1m", "graph500"];

struct Row {
    name: &'static str,
    insts: f64,
    functional: Sample,
    func_timing: Sample,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (names, samples): (&[&str], usize) = if smoke { (&KERNELS[..2], 2) } else { (&KERNELS, 5) };

    let mut rows: Vec<Row> = Vec::new();
    for &name in names {
        let w = workloads::build(name);
        // decode-once: the measured loops run the pre-decoded µop
        // program, like the sweep coordinator does
        let c = w.compile(Target::Sve);
        let insts = {
            let mut ex = Executor::new(VL_BITS, w.mem.clone());
            ex.run_decoded(&c.decoded, w.max_insts).unwrap().insts as f64
        };
        let f = bench_n(samples, || {
            let mut ex = Executor::new(VL_BITS, w.mem.clone());
            ex.run_decoded(&c.decoded, w.max_insts).unwrap().insts
        });
        report_throughput(&format!("functional {name} ({insts:.0} insts)"), &f, insts, "inst");
        let t = bench_n(samples, || {
            let mut ex = Executor::new(VL_BITS, w.mem.clone());
            run_timed_decoded(&mut ex, &c.decoded, UarchConfig::default(), w.max_insts)
                .unwrap()
                .1
                .cycles
        });
        report_throughput(&format!("func+timing {name}"), &t, insts, "inst");
        rows.push(Row { name, insts, functional: f, func_timing: t });
    }

    // Hand-rolled JSON (the offline image has no serde); schema kept
    // deliberately flat so future PRs can diff the trajectory.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"sve-repro/perf-hotpath/v1\",\n");
    json.push_str(&format!("  \"vl_bits\": {VL_BITS},\n"));
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str("  \"kernels\": {\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!(
            "    \"{}\": {{ \"insts\": {:.0}, \"functional_minst_s\": {:.3}, \
             \"func_timing_minst_s\": {:.3} }}{}\n",
            r.name,
            r.insts,
            r.functional.throughput(r.insts) / 1e6,
            r.func_timing.throughput(r.insts) / 1e6,
            sep,
        ));
    }
    json.push_str("  }\n}\n");
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");
}
