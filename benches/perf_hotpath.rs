//! §Perf: simulator hot-path throughput — the numbers EXPERIMENTS.md
//! §Perf tracks. Measures (a) functional-only execution and (b) the full
//! functional+timing pipeline, in host Minst/s, across representative
//! kernels, on **both** functional engines — the baseline block
//! interpreter and the superblock trace engine — and writes the
//! machine-readable trajectory to `BENCH_hotpath.json` so the perf
//! history is diffable across PRs.
//!
//! The headline `functional_minst_s` / `func_timing_minst_s` keys carry
//! the default engine's numbers (trace, or baseline under `--no-trace`),
//! so `sve report --compare` works unchanged on old and new artifacts;
//! the per-engine `*_baseline_minst_s` / `*_trace_minst_s` keys are
//! extra and ignored by the comparator.
//!
//! Before timing anything, every kernel is run once on each engine and
//! the run statistics and every timing counter are required to be
//! **equal** — a perf number for an engine that diverges from the
//! baseline would be meaningless, so divergence exits nonzero.
//!
//!     cargo bench --bench perf_hotpath                # both engines
//!     cargo bench --bench perf_hotpath -- --smoke     # CI smoke subset
//!     cargo bench --bench perf_hotpath -- --no-trace  # baseline only
//!     cargo bench --bench perf_hotpath -- --out F.json

use sve_repro::bench_util::{bench_n, report_ab, report_throughput, Sample};
use sve_repro::compiler::{Compiled, Target};
use sve_repro::exec::{Engine, Executor, TraceStats};
use sve_repro::uarch::{run_timed_decoded_engine, UarchConfig};
use sve_repro::workloads::{self, Workload};

const VL_BITS: usize = 256;
/// All 18 workloads, smoke subset first. The smoke six cover every IR
/// shape the hot path dispatches on — streaming FMA, gather,
/// reduction-of-products (oneDAL), the complex-multiply lane-parity
/// form (SU(3)), the linked outer×inner column walk (onedal_moments)
/// and the ELL row nest (spmv_ell) — so the CI gate sees trace linking
/// and dense twins, not just single-loop traces.
const KERNELS: [&str; 18] = [
    "stream_triad",
    "haccmk",
    "onedal_cov",
    "su3_mv",
    "onedal_moments",
    "spmv_ell",
    "strlen1m",
    "graph500",
    "comd_lj",
    "nas_ep",
    "smg2000",
    "milcmk",
    "hpgmg",
    "su3_dot",
    "himenobmt",
    "lulesh_hour",
    "memcpy_like",
    "onedal_l2dist",
];
const SMOKE: usize = 6;

/// One engine's pair of measurements for one kernel.
struct EngineCols {
    functional: Sample,
    func_timing: Sample,
}

struct Row {
    name: &'static str,
    insts: f64,
    baseline: EngineCols,
    /// `None` under `--no-trace`.
    trace: Option<EngineCols>,
    /// Trace-cache telemetry from the correctness-gate trace run.
    tstats: TraceStats,
}

fn measure(w: &Workload, c: &Compiled, engine: Engine, n: usize) -> EngineCols {
    let f = bench_n(n, || {
        let mut ex = Executor::new(VL_BITS, w.mem.clone());
        ex.run_decoded_engine_with(&c.decoded, engine, w.max_insts, |_| {}).unwrap().insts
    });
    let t = bench_n(n, || {
        let mut ex = Executor::new(VL_BITS, w.mem.clone());
        run_timed_decoded_engine(&mut ex, &c.decoded, engine, UarchConfig::default(), w.max_insts)
            .unwrap()
            .1
            .cycles
    });
    EngineCols { functional: f, func_timing: t }
}

/// Run `w` once per engine through the full functional+timing pipeline
/// and demand equal statistics and timing counters. Returns the shared
/// instruction count plus the trace run's cache telemetry (which is
/// excluded from `RunStats` equality — it is observability, not
/// architecture).
fn check_engines_agree(name: &str, w: &Workload, c: &Compiled) -> (f64, TraceStats) {
    let mut base = Executor::new(VL_BITS, w.mem.clone());
    let (bs, bt) = run_timed_decoded_engine(
        &mut base,
        &c.decoded,
        Engine::Baseline,
        UarchConfig::default(),
        w.max_insts,
    )
    .unwrap();
    let mut traced = Executor::new(VL_BITS, w.mem.clone());
    let (ts, tt) = run_timed_decoded_engine(
        &mut traced,
        &c.decoded,
        Engine::Trace,
        UarchConfig::default(),
        w.max_insts,
    )
    .unwrap();
    if bs != ts || bt != tt {
        eprintln!("FAILED: {name}: trace engine diverged from baseline");
        eprintln!("  baseline stats {bs:?} timing {bt:?}");
        eprintln!("  trace    stats {ts:?} timing {tt:?}");
        std::process::exit(1);
    }
    (bs.insts as f64, ts.trace)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let no_trace = args.iter().any(|a| a == "--no-trace");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "BENCH_hotpath.json".into());
    let (names, samples): (&[&str], usize) =
        if smoke { (&KERNELS[..SMOKE], 2) } else { (&KERNELS, 5) };
    // the full set must track the workload registry exactly — a kernel
    // added there without A/B coverage here is a silent perf blind spot
    assert_eq!(KERNELS.len(), workloads::NAMES.len(), "bench must cover every workload");
    for n in workloads::NAMES {
        assert!(KERNELS.contains(&n), "workload {n} missing from the hotpath bench");
    }

    let mut rows: Vec<Row> = Vec::new();
    for &name in names {
        let w = workloads::build(name);
        // decode-once: the measured loops run the pre-decoded µop
        // program, like the sweep coordinator does
        let c = w.compile(Target::Sve);
        // correctness gate first — a fast-but-wrong engine must never
        // produce a perf number
        let (insts, tstats) = check_engines_agree(name, &w, &c);
        let baseline = measure(&w, &c, Engine::Baseline, samples);
        report_throughput(
            &format!("functional {name} baseline ({insts:.0} insts)"),
            &baseline.functional,
            insts,
            "inst",
        );
        let trace = if no_trace {
            None
        } else {
            let tr = measure(&w, &c, Engine::Trace, samples);
            let fl = format!("functional {name} trace");
            report_ab(&fl, &baseline.functional, &tr.functional, insts, "inst");
            let tl = format!("func+timing {name} trace");
            report_ab(&tl, &baseline.func_timing, &tr.func_timing, insts, "inst");
            Some(tr)
        };
        rows.push(Row { name, insts, baseline, trace, tstats });
    }

    // Telemetry gate: the trace cache must actually be doing the things
    // the perf claims rest on. Some kernel's steady state must take
    // patched trace→trace links, and at least one PR 7 kernel family
    // (onedal_* / su3_*) must run linked *and* dense. The telemetry
    // comes from the always-on correctness-gate run, so this holds even
    // under --no-trace.
    let linked = rows.iter().any(|r| r.tstats.link_jumps > 0);
    let pr7_dense = rows.iter().any(|r| {
        (r.name.starts_with("onedal_") || r.name.starts_with("su3_"))
            && r.tstats.link_jumps > 0
            && r.tstats.dense_iters > 0
    });
    if !linked || !pr7_dense {
        for r in &rows {
            eprintln!("  {}: {:?}", r.name, r.tstats);
        }
        if !linked {
            eprintln!("FAILED: no kernel took a trace link jump");
        }
        if !pr7_dense {
            eprintln!("FAILED: no onedal_*/su3_* kernel ran linked dense iterations");
        }
        std::process::exit(1);
    }

    // Hand-rolled JSON (the offline image has no serde); schema kept
    // deliberately flat so future PRs can diff the trajectory. The
    // headline keys carry the default engine (trace unless --no-trace);
    // per-engine keys are additive and ignored by `report --compare`.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"sve-repro/perf-hotpath/v1\",\n");
    json.push_str(&format!("  \"vl_bits\": {VL_BITS},\n"));
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str(&format!(
        "  \"engine\": \"{}\",\n",
        if no_trace { Engine::Baseline.label() } else { Engine::Trace.label() }
    ));
    json.push_str("  \"kernels\": {\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        let bf = r.baseline.functional.throughput(r.insts) / 1e6;
        let bt = r.baseline.func_timing.throughput(r.insts) / 1e6;
        let (hf, ht) = match &r.trace {
            Some(tr) => (
                tr.functional.throughput(r.insts) / 1e6,
                tr.func_timing.throughput(r.insts) / 1e6,
            ),
            None => (bf, bt),
        };
        json.push_str(&format!(
            "    \"{}\": {{ \"insts\": {:.0}, \"functional_minst_s\": {hf:.3}, \
             \"func_timing_minst_s\": {ht:.3},\n",
            r.name, r.insts,
        ));
        json.push_str(&format!(
            "             \"functional_baseline_minst_s\": {bf:.3}, \
             \"func_timing_baseline_minst_s\": {bt:.3}",
        ));
        if let Some(tr) = &r.trace {
            json.push_str(&format!(
                ",\n             \"functional_trace_minst_s\": {:.3}, \
                 \"func_timing_trace_minst_s\": {:.3}",
                tr.functional.throughput(r.insts) / 1e6,
                tr.func_timing.throughput(r.insts) / 1e6,
            ));
        }
        // additive trace-cache telemetry (ignored by `report --compare`)
        let t = &r.tstats;
        json.push_str(&format!(
            ",\n             \"trace_built\": {}, \"trace_rejected\": {}, \
             \"trace_rerecorded\": {}, \"trace_link_jumps\": {}, \
             \"trace_dense_iters\": {}, \"trace_general_iters\": {}",
            t.built, t.rejected, t.rerecorded, t.link_jumps, t.dense_iters, t.general_iters,
        ));
        json.push_str(&format!(" }}{sep}\n"));
    }
    json.push_str("  }\n}\n");
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("wrote {out}");
}
