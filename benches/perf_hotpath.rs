//! §Perf: simulator hot-path throughput — the numbers EXPERIMENTS.md
//! §Perf tracks. Measures (a) functional-only execution and (b) the full
//! functional+timing pipeline, in host Minst/s, across representative
//! kernels.
//!
//!     cargo bench --bench perf_hotpath

use sve_repro::bench_util::{bench_n, report_throughput};
use sve_repro::compiler::Target;
use sve_repro::exec::Executor;
use sve_repro::uarch::{run_timed, UarchConfig};
use sve_repro::workloads;

fn main() {
    for name in ["stream_triad", "haccmk", "strlen1m", "graph500"] {
        let w = workloads::build(name);
        let c = w.compile(Target::Sve);
        let insts = {
            let mut ex = Executor::new(256, w.mem.clone());
            ex.run(&c.program, w.max_insts).unwrap().insts as f64
        };
        let f = bench_n(5, || {
            let mut ex = Executor::new(256, w.mem.clone());
            ex.run(&c.program, w.max_insts).unwrap().insts
        });
        report_throughput(&format!("functional {name} ({insts:.0} insts)"), &f, insts, "inst");
        let t = bench_n(5, || {
            let mut ex = Executor::new(256, w.mem.clone());
            run_timed(&mut ex, &c.program, UarchConfig::default(), w.max_insts).unwrap().1.cycles
        });
        report_throughput(&format!("func+timing {name}"), &t, insts, "inst");
    }
}
