//! Fig. 3: cycle-by-cycle daxpy with n=3 at 128- and 256-bit vector
//! lengths — at 128 bits (2 f64 lanes) the loop runs twice; at 256 bits
//! one pass covers all three elements with a whilelt tail predicate.
//!
//!     cargo run --release --example daxpy_trace

use sve_repro::compiler::{compile, BinOp, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
use sve_repro::exec::Executor;
use sve_repro::mem::Memory;
use sve_repro::uarch::{run_traced, trace::render_timeline, UarchConfig};

fn main() {
    let n = 3u64; // exactly the figure's example
    for vl in [128usize, 256] {
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 64);
        let yb = mem.alloc(8 * n, 64);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, 1.0 + i as f64).unwrap();
            mem.write_f64(yb + 8 * i, 10.0 * (i + 1) as f64).unwrap();
        }
        let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        let y = k.array("y", Ty::F64, yb);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Add,
                Expr::bin(
                    BinOp::Mul,
                    Expr::ConstF(2.0),
                    Expr::load(x, Index::Affine { offset: 0 }),
                ),
                Expr::load(y, Index::Affine { offset: 0 })),
        });
        let c = compile(&k, Target::Sve);
        let mut ex = Executor::new(vl, mem);
        let (stats, timing, tr) =
            run_traced(&mut ex, &c.program, UarchConfig::default(), 10_000).unwrap();
        println!(
            "== Fig. 3 (VL = {vl} bits): daxpy n=3, {} insts, {} cycles ==\n",
            stats.insts,
            timing.cycles
        );
        println!("{}", render_timeline(&c.program, &tr));
        for i in 0..n {
            println!("y[{i}] = {}", ex.mem.read_f64(yb + 8 * i).unwrap());
        }
        println!();
    }
    println!("note: one whilelt-governed pass at 256-bit covers what 128-bit needs two\npasses for — the figure's point.");
}
