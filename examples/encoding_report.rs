//! Fig. 7: the SVE encoding footprint — our ISA's allocation inside the
//! single 28-bit A64 region, plus the §4 constructive-forms
//! counterfactual that motivated movprfx.
//!
//!     cargo run --release --example encoding_report

use sve_repro::csvutil::Table;
use sve_repro::isa::encoding::{
    constructive_counterfactual, sve_region_report, FULL_DP_OPCODES, SVE_REGION_POINTS,
};

fn main() {
    println!("== Fig. 7: SVE inside one 28-bit region of the A64 map ==\n");
    let (groups, total) = sve_region_report();
    let mut t = Table::new(vec!["group", "encoding points", "share of region"]);
    for g in &groups {
        t.push_row(vec![
            g.group.clone(),
            g.points.to_string(),
            format!("{:.3}%", 100.0 * g.share_of_region),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "total used: {total} / {SVE_REGION_POINTS} ({:.2}%) — \"some room for future\nexpansion is left in this region\" (Fig. 7b)\n",
        100.0 * total as f64 / SVE_REGION_POINTS as f64
    );
    let (destructive, constructive) = constructive_counterfactual();
    println!("== §4: why destructive forms + movprfx ==\n");
    println!("full predicated data-processing set (~{FULL_DP_OPCODES} opcodes):");
    println!("  destructive (Zdn Pg3 Zm sz, 15 bits)      : {destructive:>12} points");
    println!("  constructive (Zd Zn Zm Pg4 sz, 21 bits)   : {constructive:>12} points");
    println!(
        "  the constructive design needs {:.1}x the ENTIRE 28-bit region —\n  \"would have easily exceeded the projected encoding budget\"",
        constructive as f64 / SVE_REGION_POINTS as f64
    );
}
