//! The Fig. 6 scalarized intra-vector sub-loop: linked-list traversal
//! with an XOR reduction, vectorized via pnext/cpy/ctermeq + gather +
//! eorv, versus the scalar pointer chase.
//!
//!     cargo run --release --example linked_list

use sve_repro::compiler::chase::{compile_chase, ChaseKernel};
use sve_repro::compiler::Target;
use sve_repro::exec::Executor;
use sve_repro::mem::Memory;
use sve_repro::rng::Rng;
use sve_repro::uarch::{run_timed, UarchConfig};

fn main() {
    let n = 20_000usize;
    let mut mem = Memory::new();
    let mut rng = Rng::new(42);
    let nodes = mem.alloc(16 * n as u64, 64);
    let mut order: Vec<u64> = (0..n as u64).collect();
    rng.shuffle(&mut order);
    let mut expected = 0u64;
    for i in 0..n {
        let addr = nodes + 16 * order[i];
        let val = rng.next_u64() >> 1;
        expected ^= val;
        mem.write_u64(addr, val).unwrap();
        let next = if i + 1 < n { nodes + 16 * order[i + 1] } else { 0 };
        mem.write_u64(addr + 8, next).unwrap();
    }
    let result = mem.alloc(8, 8);
    let k = ChaseKernel {
        name: "list".into(),
        head: nodes + 16 * order[0],
        next_off: 8,
        val_off: 0,
        result,
    };

    println!("== Fig. 6: linked-list XOR reduction, {n} shuffled nodes ==\n");
    // the honest compiler decision first
    let auto = compile_chase(&k, Target::Sve, false);
    println!("auto-vectorizer decision: {}\n", auto.why_not.as_deref().unwrap());

    let scalar = compile_chase(&k, Target::Scalar, false);
    let sve = compile_chase(&k, Target::Sve, true); // forced, as the paper demonstrates
    let mut base = 0;
    for (label, c, vl) in [
        ("scalar chase", &scalar, 128),
        ("sve-128 split-loop", &sve, 128),
        ("sve-512 split-loop", &sve, 512),
        ("sve-2048 split-loop", &sve, 2048),
    ] {
        let mut ex = Executor::new(vl, mem.clone());
        let (_, t) = run_timed(&mut ex, &c.program, UarchConfig::default(), 50_000_000).unwrap();
        assert_eq!(ex.mem.read_u64(result).unwrap(), expected, "XOR result");
        if base == 0 { base = t.cycles; }
        println!(
            "{label:<20} {:>9} cycles  vs scalar {:>5.2}x",
            t.cycles,
            base as f64 / t.cycles as f64
        );
    }
    println!("\n(the paper: \"the performance gained may not be sufficient to justify\n vectorization for this loop, but it serves to illustrate the principle\")");
}
