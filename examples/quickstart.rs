//! Quickstart — the paper's Fig. 2 end to end: one daxpy kernel compiled
//! for scalar, Advanced SIMD and SVE, run at several vector lengths, with
//! the instruction-count parity claim checked on the way.
//!
//!     cargo run --release --example quickstart

use sve_repro::compiler::{compile, BinOp, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
use sve_repro::exec::Executor;
use sve_repro::mem::Memory;
use sve_repro::uarch::{run_timed, UarchConfig};

fn main() {
    let n = 10_000u64;
    let mut mem = Memory::new();
    let xb = mem.alloc(8 * n, 64);
    let yb = mem.alloc(8 * n, 64);
    for i in 0..n {
        mem.write_f64(xb + 8 * i, (i as f64).sin()).unwrap();
        mem.write_f64(yb + 8 * i, (i as f64).cos()).unwrap();
    }
    let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
    let x = k.array("x", Ty::F64, xb);
    let y = k.array("y", Ty::F64, yb);
    k.body.push(Stmt::Store {
        arr: y,
        idx: Index::Affine { offset: 0 },
        value: Expr::bin(
            BinOp::Add,
            Expr::bin(BinOp::Mul, Expr::ConstF(3.0), Expr::load(x, Index::Affine { offset: 0 })),
            Expr::load(y, Index::Affine { offset: 0 })),
    });

    println!("== Fig. 2: daxpy compiled three ways ==\n");
    let mut scalar_cycles = 0;
    for (label, target, vl) in [
        ("scalar (Fig. 2b)", Target::Scalar, 128),
        ("Advanced SIMD", Target::Neon, 128),
        ("SVE (Fig. 2c)", Target::Sve, 128),
    ] {
        let c = compile(&k, target);
        let mut ex = Executor::new(vl, mem.clone());
        let (stats, t) = run_timed(&mut ex, &c.program, UarchConfig::default(), 10_000_000)
            .expect("run");
        if target == Target::Scalar {
            scalar_cycles = t.cycles;
        }
        println!(
            "{label:<18} {:>4} static insts | {:>7} dynamic | {:>7} cycles | speedup vs scalar {:>5.2}x",
            c.program.len(),
            stats.insts,
            t.cycles,
            scalar_cycles as f64 / t.cycles as f64
        );
    }

    // §2.3.2: "no overhead in instruction count for the SVE version"
    let sc = compile(&k, Target::Scalar);
    let sv = compile(&k, Target::Sve);
    println!(
        "\nstatic loop bodies: scalar {} vs SVE {} instructions (parity claim, Fig. 2)",
        sc.program.len(),
        sv.program.len()
    );

    println!("\n== §2.2: the SAME SVE binary across vector lengths ==\n");
    let c = compile(&k, Target::Sve);
    let mut base = 0u64;
    for vl in [128usize, 256, 512, 1024, 2048] {
        let mut ex = Executor::new(vl, mem.clone());
        let (_, t) = run_timed(&mut ex, &c.program, UarchConfig::default(), 10_000_000)
            .expect("run");
        if vl == 128 {
            base = t.cycles;
        }
        println!(
            "VL = {vl:>4} bits: {:>7} cycles  (speedup vs VL-128: {:>4.2}x)",
            t.cycles,
            base as f64 / t.cycles as f64
        );
        // verify correctness at every VL
        for i in (0..n).step_by(1999) {
            let want = 3.0 * (i as f64).sin() + (i as f64).cos();
            assert!((ex.mem.read_f64(yb + 8 * i).unwrap() - want).abs() < 1e-12);
        }
    }
    println!("\nresults verified at every vector length — vector-length agnosticism holds.");
}
