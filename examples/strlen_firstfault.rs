//! First-faulting speculation (Figs. 4 and 5): a gather that crosses an
//! unmapped page updates the FFR instead of trapping, and strlen
//! vectorizes with ldff1b + rdffr + brkbs.
//!
//!     cargo run --release --example strlen_firstfault

use sve_repro::arch::Esize;
use sve_repro::asm::Asm;
use sve_repro::compiler::{compile, CmpKind, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
use sve_repro::exec::Executor;
use sve_repro::isa::{GatherAddr, Inst, SveMemOff};
use sve_repro::mem::{Memory, PAGE_SIZE};
use sve_repro::uarch::{run_timed, UarchConfig};

fn main() {
    // ---- Fig. 4: speculative gather over a page hole ----
    println!("== Fig. 4: first-faulting gather ==\n");
    let mut mem = Memory::new();
    let good = 0x20_000u64;
    mem.map(good, 64);
    mem.write_u64(good, 111).unwrap();
    mem.write_u64(good + 8, 222).unwrap();
    let bad = 0x90_000u64; // unmapped
    let addrs = mem.alloc(32, 8);
    mem.write_u64_slice(addrs, &[good, good + 8, bad, bad + 8]);
    let mut a = Asm::new();
    a.push(Inst::MovImm { xd: 1, imm: addrs });
    a.push(Inst::Ptrue { pd: 1, esize: Esize::D, s: false });
    a.push(Inst::SveLd1 {
        zt: 3,
        pg: 1,
        esize: Esize::D,
        base: 1,
        off: SveMemOff::ImmVl(0),
        ff: false,
    });
    a.push(Inst::Setffr);
    a.push(Inst::SveLdGather {
        zt: 0,
        pg: 1,
        esize: Esize::D,
        addr: GatherAddr::VecImm(3, 0),
        ff: true,
    });
    a.push(Inst::Rdffr { pd: 2, pg: Some(1), s: false });
    a.push(Inst::Halt);
    let p = a.finish();
    let mut ex = Executor::new(256, mem);
    ex.run(&p, 100).expect("no trap — faults were suppressed");
    println!("addresses: [A[0]=ok, A[1]=ok, A[2]=UNMAPPED, A[3]=UNMAPPED]");
    print!("FFR after ldff1d: [");
    for i in 0..4 {
        print!("{}", if ex.state.p[2].active(Esize::D, i) { "T" } else { "F" });
        if i < 3 { print!(", "); }
    }
    println!("]  (paper: true, true, false, false)");
    println!(
        "loaded lanes: z0 = [{}, {}, -, -]\n",
        ex.state.z[0].get(Esize::D, 0),
        ex.state.z[0].get(Esize::D, 1)
    );

    // ---- Fig. 5: strlen ----
    println!("== Fig. 5: vectorized strlen over a page-exact string ==\n");
    let mut mem = Memory::new();
    let page = 0x40_000u64;
    let pages = 16u64;
    mem.map(page, pages * PAGE_SIZE as u64); // nothing mapped beyond
    let len = pages * PAGE_SIZE as u64 - 1; // NUL is the very last byte
    for i in 0..len {
        mem.write_byte(page + i, b'a' + (i % 26) as u8).unwrap();
    }
    mem.write_byte(page + len, 0).unwrap();
    let out = 0x100_000u64;
    mem.map(out, 8);
    let mut k = Kernel::new("strlen", Ty::U8, Trip::DataDependent { max: 1 << 24 });
    let s = k.array("s", Ty::U8, page);
    k.count_out = Some(out);
    k.body.push(Stmt::Break {
        cond: Expr::cmp(CmpKind::Eq, Expr::load(s, Index::Affine { offset: 0 }), Expr::ConstI(0)),
    });

    let scalar = compile(&k, Target::Scalar);
    let neon = compile(&k, Target::Neon);
    println!("Advanced SIMD vectorizer says: {}\n", neon.why_not.as_deref().unwrap());
    let sve = compile(&k, Target::Sve);
    assert!(sve.vectorized);

    let mut base = 0;
    for (label, c, vl) in [
        ("scalar", &scalar, 128),
        ("sve-128", &sve, 128),
        ("sve-512", &sve, 512),
        ("sve-2048", &sve, 2048),
    ] {
        let mut ex = Executor::new(vl, mem.clone());
        let (_, t) = run_timed(&mut ex, &c.program, UarchConfig::default(), 50_000_000).unwrap();
        assert_eq!(ex.mem.read_u64(out).unwrap(), len, "length correct");
        if base == 0 { base = t.cycles; }
        println!(
            "{label:<9} {:>9} cycles  speedup {:>5.2}x  (len={} found, speculative loads never trapped)",
            t.cycles,
            base as f64 / t.cycles as f64,
            len
        );
    }
}
