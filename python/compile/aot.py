"""AOT lowering: every L2 golden model -> artifacts/<name>.hlo.txt.

Interchange format is HLO **text**, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Lowered with ``return_tuple=True``; the Rust side unwraps with
``to_tuple1()``.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> str:
    fn, example = model.ENTRIES[name]
    lowered = jax.jit(fn).lower(*example)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of entries")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(model.ENTRIES)
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, example = model.ENTRIES[name]
        sig = ", ".join(f"{s.dtype}{list(s.shape)}" for s in example)
        manifest.append(f"{name}: ({sig})")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
