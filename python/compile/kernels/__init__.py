"""Layer-1 Pallas kernels: the compute hot-spots of the golden models.

Each kernel expresses the SVE execution model in Pallas terms (see
DESIGN.md §Hardware-Adaptation):

* per-lane predication      -> boolean mask tensors + ``jnp.where``
* vector-length agnosticism -> block-size-agnostic kernels driven by a
  grid; the block size plays the role of VL and the tail mask plays the
  role of ``whilelt``
* first-fault partitioning  -> bounds masks derived from the logical
  array length

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from . import daxpy, hacc, reduction, stencil  # noqa: F401
