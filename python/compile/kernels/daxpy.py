"""Predicated daxpy Pallas kernel — the paper's Fig. 2 running example.

``y[i] = a*x[i] + y[i]`` for ``i < n`` where ``n`` need not be a multiple
of the block size. The grid loop models SVE's ``whilelt``-governed loop:
each grid step processes one block (one "vector") and derives a per-lane
predicate from the remaining trip count, exactly as ``whilelt p0.d, x4, x3``
does in Fig. 2c. Lanes whose predicate is false must write back the *old*
value of y (merging predication, ``/m``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size == the modelled vector length in elements. 64 f64 lanes is a
# 4096-bit "vector": deliberately larger than any real SVE implementation
# to show the kernel is genuinely length-agnostic.
DEFAULT_BLOCK = 64


def _daxpy_kernel(n_ref, a_ref, x_ref, y_ref, o_ref, *, block: int):
    """One grid step = one governed vector iteration.

    VMEM footprint per step: 3 f64 blocks (x, y, out) + 2 scalars =
    ``3*8*block`` bytes (1.5 KiB at the default block) — far below any
    VMEM budget; the kernel is memory-streaming, not MXU-bound.
    """
    i = pl.program_id(0)
    n = n_ref[0]
    # whilelt: lane l is active iff  i*block + l < n.
    lane = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    pred = (i * block + lane) < n
    a = a_ref[0]
    # fmla z2.d, p0/m, z1.d, z0.d
    fma = a * x_ref[...] + y_ref[...]
    # merging predication: inactive lanes keep the old y value.
    o_ref[...] = jnp.where(pred, fma, y_ref[...])


def daxpy(a, x, y, n, *, block: int = DEFAULT_BLOCK):
    """Predicated daxpy over the first ``n`` elements; the rest of y is
    returned unchanged. Shapes of x and y must be equal and a multiple of
    ``block`` (the caller pads, as the simulator pads its heap images).
    """
    size = x.shape[0]
    assert size % block == 0, "pad inputs to a block multiple"
    grid = (size // block,)
    dtype = x.dtype
    n_arr = jnp.asarray([n], dtype=jnp.int32)
    a_arr = jnp.asarray([a], dtype=dtype)
    return pl.pallas_call(
        functools.partial(_daxpy_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # n (scalar, replicated)
            pl.BlockSpec((1,), lambda i: (0,)),       # a (scalar, replicated)
            pl.BlockSpec((block,), lambda i: (i,)),   # x block
            pl.BlockSpec((block,), lambda i: (i,)),   # y block
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((size,), dtype),
        interpret=True,
    )(n_arr, a_arr, x, y)
