"""HACCmk-style short-range force kernel with conditional assignments.

The paper (§5): *"in the particular case of HACCmk, the main loop has two
conditional assignments that inhibit vectorization for Advanced SIMD, but
the code is trivially vectorized for SVE"*. This is the golden model for
our ``haccmk`` proxy workload: an O(n) inner force loop over particle
coordinates, with

  1. a cutoff conditional  (``r2 < rmax2 ? poly(r2) : 0``)       and
  2. a softening conditional (``r2 > eps2  ? r2 : eps2``)

both of which if-convert to per-lane predication — ``jnp.where`` here,
``fcmgt``+merging moves in the simulator's SVE code.

The polynomial is the standard HACCmk 5th-order interaction polynomial in
1/r form, kept in f32 (HACCmk is single precision).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128

# HACCmk interaction polynomial coefficients (public mini-app values).
POLY = (0.269327, -0.0750978, 0.0114808, -0.00109313, 5.63434e-05,
        -1.26461e-06)


def poly_force(r2):
    """f(r2) = 1/(r2*sqrt(r2)) - (c0 + r2*(c1 + r2*(c2 + ...)))."""
    p = POLY[5]
    for c in (POLY[4], POLY[3], POLY[2], POLY[1], POLY[0]):
        p = p * r2 + c
    return 1.0 / (r2 * jnp.sqrt(r2)) - p


def _hacc_kernel(n_ref, p_ref, x_ref, y_ref, z_ref, m_ref, fx_ref,
                 *, block: int, rmax2: float, eps2: float):
    """Force of all particles in this block on the pivot particle ``p``.

    VMEM per step: 4 f32 input blocks + 1 f32 output block = 20*block
    bytes (2.5 KiB at default block).
    """
    i = pl.program_id(0)
    n = n_ref[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    pred = (i * block + lane) < n

    px, py, pz = p_ref[0], p_ref[1], p_ref[2]
    dx = x_ref[...] - px
    dy = y_ref[...] - py
    dz = z_ref[...] - pz
    r2 = dx * dx + dy * dy + dz * dz
    # conditional assignment #1: softening (r2 = max(r2, eps2)).
    r2s = jnp.where(r2 > eps2, r2, eps2)
    f = poly_force(r2s)
    # conditional assignment #2: cutoff (f = r2 < rmax2 ? f : 0).
    f = jnp.where(r2 < rmax2, f, 0.0)
    contrib = f * m_ref[...] * dx
    fx_ref[...] = jnp.where(pred, contrib, 0.0)


def hacc_force(pivot, x, y, z, m, n, *, block: int = DEFAULT_BLOCK,
               rmax2: float = 16.0, eps2: float = 1e-3):
    """Per-lane x-force contributions on ``pivot`` from particles [0, n).

    Returns the *unreduced* per-lane contributions (the simulator reduces
    with ``faddv``; the L2 model reduces with an ordered ``fadda`` in
    ``ref.py`` so both reduction orders are validated).
    """
    size = x.shape[0]
    assert size % block == 0
    grid = (size // block,)
    n_arr = jnp.asarray([n], dtype=jnp.int32)
    p_arr = jnp.asarray(pivot, dtype=x.dtype)
    return pl.pallas_call(
        functools.partial(_hacc_kernel, block=block, rmax2=rmax2, eps2=eps2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((size,), x.dtype),
        interpret=True,
    )(n_arr, p_arr, x, y, z, m)
