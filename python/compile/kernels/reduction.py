"""Horizontal-reduction Pallas kernels: ordered fadda vs tree faddv.

§2.4 / §3.3 of the paper: SVE provides both tree-order reductions (faddv,
eorv, ...) and the *strictly-ordered* ``fadda`` so compilers can vectorize
loops where FP addition order is semantically visible. These kernels are
the golden models for the simulator's reduction semantics:

* ``fadda_ordered``  — sequential left-to-right accumulation (bitwise
  identical to the scalar loop; this is the property the instruction
  exists for).
* ``faddv_tree``     — pairwise tree reduction (what a VL-wide hardware
  reduction tree computes; result may differ from ordered in the last
  ulps, and our tests check both *that* difference and the agreement
  within tolerance).

Both respect a governing predicate: inactive lanes contribute the
identity.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fadda_kernel(n_ref, x_ref, o_ref, *, size: int):
    """Strictly-ordered masked sum of x[0:n] via lax.fori_loop.

    The scan order is the architectural element order (least- to
    most-significant), matching SVE's implicit predicate order (§2.3.1).
    """
    n = n_ref[0]
    x = x_ref[...]

    def body(i, acc):
        return jnp.where(i < n, acc + x[i], acc)

    o_ref[0] = jax.lax.fori_loop(0, size, body, jnp.asarray(0.0, x.dtype))


def fadda_ordered(x, n):
    """acc = (((0 + x[0]) + x[1]) + ...) over active lanes i < n."""
    size = x.shape[0]
    n_arr = jnp.asarray([n], dtype=jnp.int32)
    return pl.pallas_call(
        functools.partial(_fadda_kernel, size=size),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(n_arr, x)[0]


def _faddv_kernel(n_ref, x_ref, o_ref, *, size: int):
    """Pairwise tree reduction with inactive lanes zeroed first."""
    n = n_ref[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (size,), 0)
    v = jnp.where(lane < n, x_ref[...], 0.0)
    # log2(size) halving steps — the hardware reduction tree.
    width = size
    while width > 1:
        half = width // 2
        v = v[:half] + v[half:width]
        width = half
    o_ref[0] = v[0]


def faddv_tree(x, n):
    """Tree-order masked sum; ``x`` length must be a power of two."""
    size = x.shape[0]
    assert size & (size - 1) == 0, "power-of-two vector"
    n_arr = jnp.asarray([n], dtype=jnp.int32)
    return pl.pallas_call(
        functools.partial(_faddv_kernel, size=size),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(n_arr, x)[0]


def _eorv_kernel(n_ref, x_ref, o_ref, *, size: int):
    n = n_ref[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (size,), 0)
    v = jnp.where(lane < n, x_ref[...], 0)
    o_ref[0] = jax.lax.reduce(v, jnp.asarray(0, v.dtype),
                              jax.lax.bitwise_xor, (0,))


def eorv(x, n):
    """Masked XOR reduction (integer) — the Fig. 6 linked-list reduction."""
    size = x.shape[0]
    n_arr = jnp.asarray([n], dtype=jnp.int32)
    return pl.pallas_call(
        functools.partial(_eorv_kernel, size=size),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(n_arr, x)[0]
