"""Pure-jnp (and pure-python, for the scan) correctness oracles.

Every Pallas kernel in this package has an oracle here written with no
Pallas, no masking tricks — the most obvious possible formulation. pytest
(``python/tests/``) sweeps shapes/dtypes with hypothesis and
assert_allclose's kernel-vs-ref; the Rust simulator is validated against
the same oracles through the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np

from . import hacc as hacc_mod
from . import stencil as stencil_mod


def daxpy(a, x, y, n):
    """y[i] = a*x[i] + y[i] for i < n; y unchanged beyond."""
    idx = jnp.arange(x.shape[0])
    return jnp.where(idx < n, a * x + y, y)


def hacc_force(pivot, x, y, z, m, n, rmax2=16.0, eps2=1e-3):
    """Unreduced per-lane x-force contributions (see kernels.hacc)."""
    idx = jnp.arange(x.shape[0])
    dx = x - pivot[0]
    dy = y - pivot[1]
    dz = z - pivot[2]
    r2 = dx * dx + dy * dy + dz * dz
    r2s = jnp.where(r2 > eps2, r2, eps2)
    f = hacc_mod.poly_force(r2s)
    f = jnp.where(r2 < rmax2, f, 0.0)
    return jnp.where(idx < n, f * m * dx, 0.0)


def jacobi19(p):
    """One 19-point Jacobi sweep, boundaries pass through (numpy loops)."""
    p = np.asarray(p)
    ni, nj, nk = p.shape
    out = p.copy()
    for i in range(1, ni - 1):
        for j in range(1, nj - 1):
            for k in range(1, nk - 1):
                s = 0.0
                for di, dj, dk in NEIGHBOURS19:
                    s += p[i + di, j + dj, k + dk]
                c = p[i, j, k]
                out[i, j, k] = c + stencil_mod.OMEGA * (s / 18.0 - c)
    return out


# the 18 neighbours of the 19-point stencil (centre excluded from the sum)
NEIGHBOURS19 = [
    (-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1),
    (-1, -1, 0), (-1, 1, 0), (1, -1, 0), (1, 1, 0),
    (-1, 0, -1), (-1, 0, 1), (1, 0, -1), (1, 0, 1),
    (0, -1, -1), (0, -1, 1), (0, 1, -1), (0, 1, 1),
]


def fadda_ordered(x, n):
    """Strictly-ordered scalar-loop sum — the semantic definition."""
    x = np.asarray(x)
    acc = x.dtype.type(0)
    for i in range(min(int(n), x.shape[0])):
        acc = acc + x[i]
    return acc


def faddv_tree(x, n):
    """Pairwise tree sum over masked lanes (power-of-two length)."""
    x = np.asarray(x)
    idx = np.arange(x.shape[0])
    v = np.where(idx < n, x, x.dtype.type(0))
    while v.shape[0] > 1:
        half = v.shape[0] // 2
        v = v[:half] + v[half:]
    return v[0]


def eorv(x, n):
    x = np.asarray(x)
    acc = x.dtype.type(0)
    for i in range(min(int(n), x.shape[0])):
        acc ^= x[i]
    return acc
