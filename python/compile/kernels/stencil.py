"""HimenoBMT-style 19-point Jacobi stencil Pallas kernel.

Golden model for the ``himenobmt`` proxy workload: one Jacobi sweep of the
Himeno pressure-Poisson update on a 3D grid. The paper's compiler
vectorizes the innermost (k) dimension contiguously; here the k dimension
is processed as one masked vector per (i, j) pencil with an interior-lane
predicate — an SVE loop whose governing predicate excludes both boundary
lanes (merging predication keeps the old value there).

For tractability the golden model uses uniform coefficients (the 1/18
Jacobi form), matching ``workloads/himenobmt.rs`` exactly; the
*memory-access structure* (19 loads per output point, contiguous in k) is
what matters for the reproduction, not Himeno's full coefficient arrays.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OMEGA = 0.8


def _stencil_kernel(p_ref, o_ref, *, nk: int):
    """One (i, j) pencil of the 19-point update.

    Stencil windows overlap, which BlockSpec index maps (block-granular)
    cannot express, so the kernel receives the whole grid and carves its
    (3, 3, nk) window with a dynamic slice: 36*nk bytes (~4.6 KiB for the
    AOT shape) live per step — the HBM<->VMEM schedule the paper's L1D
    provides implicitly for the stencil's 19-load working set.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    p = p_ref[pl.dslice(i, 3), pl.dslice(j, 3), :]
    c = p[1, 1, :]
    cm = jnp.roll(p, 1, axis=2)   # k-1 neighbours
    cp = jnp.roll(p, -1, axis=2)  # k+1 neighbours
    s = (p[0, 1, :] + p[2, 1, :] + p[1, 0, :] + p[1, 2, :] +
         cm[1, 1, :] + cp[1, 1, :] +
         p[0, 0, :] + p[0, 2, :] + p[2, 0, :] + p[2, 2, :] +
         cm[0, 1, :] + cp[0, 1, :] + cm[2, 1, :] + cp[2, 1, :] +
         cm[1, 0, :] + cp[1, 0, :] + cm[1, 2, :] + cp[1, 2, :])
    new = c + OMEGA * (s / 18.0 - c)
    # interior predicate along k (whilelt on both ends).
    lane = jax.lax.broadcasted_iota(jnp.int32, (nk,), 0)
    pred = (lane >= 1) & (lane < nk - 1)
    o_ref[0, 0, :] = jnp.where(pred, new, c)


def jacobi19(p):
    """One 19-point Jacobi sweep over ``p`` (shape (ni, nj, nk), f32).

    Interior points get the relaxation update; all boundary points pass
    through unchanged.
    """
    ni, nj, nk = p.shape
    grid = (ni - 2, nj - 2)
    out = pl.pallas_call(
        functools.partial(_stencil_kernel, nk=nk),
        grid=grid,
        in_specs=[pl.BlockSpec((ni, nj, nk), lambda i, j: (0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, nk), lambda i, j: (i + 1, j + 1, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nk), p.dtype),
        interpret=True,
    )(p)
    # faces i=0, i=ni-1, j=0, j=nj-1 pass through.
    out = out.at[0, :, :].set(p[0, :, :])
    out = out.at[ni - 1, :, :].set(p[ni - 1, :, :])
    out = out.at[:, 0, :].set(p[:, 0, :])
    out = out.at[:, nj - 1, :].set(p[:, nj - 1, :])
    return out
