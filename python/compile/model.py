"""Layer-2: golden compute models assembled from the Layer-1 kernels.

Each entry in ``ENTRIES`` is one AOT artifact: a jax function (calling the
Pallas kernels) plus its example arguments. ``aot.py`` lowers every entry
to HLO text once at build time; the Rust runtime
(``rust/src/runtime/golden.rs``) loads them and cross-validates the ISA
simulator's architectural results. Python never runs at simulation time.

All golden models use explicit array arguments (no python scalars) so the
Rust side can feed plain literals:

  daxpy     : (n i32[1], a f64[1], x f64[N], y f64[N])        -> f64[N]
  hacc      : (n i32[1], pivot f32[3], x,y,z,m f32[N])        -> f32[N]
  stencil   : (p f32[NI,NJ,NK])                               -> f32[NI,NJ,NK]
  fadda     : (n i32[1], x f64[R])                            -> f64[1]
  faddv     : (n i32[1], x f64[R])                            -> f64[1]
  eorv      : (n i32[1], x i64[R])                            -> i64[1]
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import daxpy as daxpy_k  # noqa: E402
from .kernels import hacc as hacc_k  # noqa: E402
from .kernels import reduction as red_k  # noqa: E402
from .kernels import stencil as stencil_k  # noqa: E402

# AOT shapes — must match rust/src/runtime/golden.rs.
DAXPY_N = 1024
HACC_N = 1024
STENCIL_SHAPE = (10, 10, 32)
RED_N = 256


def daxpy(n, a, x, y):
    return daxpy_k.daxpy(a[0], x, y, n[0])


def hacc(n, pivot, x, y, z, m):
    return hacc_k.hacc_force(pivot, x, y, z, m, n[0])


def stencil(p):
    return stencil_k.jacobi19(p)


def fadda(n, x):
    return red_k.fadda_ordered(x, n[0]).reshape((1,))


def faddv(n, x):
    return red_k.faddv_tree(x, n[0]).reshape((1,))


def eorv(n, x):
    return red_k.eorv(x, n[0]).reshape((1,))


def _s(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


ENTRIES = {
    "daxpy": (daxpy, (_s((1,), jnp.int32), _s((1,), jnp.float64),
                      _s((DAXPY_N,), jnp.float64), _s((DAXPY_N,), jnp.float64))),
    "hacc": (hacc, (_s((1,), jnp.int32), _s((3,), jnp.float32),
                    _s((HACC_N,), jnp.float32), _s((HACC_N,), jnp.float32),
                    _s((HACC_N,), jnp.float32), _s((HACC_N,), jnp.float32))),
    "stencil": (stencil, (_s(STENCIL_SHAPE, jnp.float32),)),
    "fadda": (fadda, (_s((1,), jnp.int32), _s((RED_N,), jnp.float64))),
    "faddv": (faddv, (_s((1,), jnp.int32), _s((RED_N,), jnp.float64))),
    "eorv": (eorv, (_s((1,), jnp.int32), _s((RED_N,), jnp.int64))),
}
