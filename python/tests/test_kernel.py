"""Kernel-vs-ref allclose — the CORE correctness signal for L1.

hypothesis sweeps shapes, trip counts and dtypes; every Pallas kernel must
match its pure-jnp/numpy oracle in ``compile.kernels.ref``.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import daxpy as daxpy_k
from compile.kernels import hacc as hacc_k
from compile.kernels import reduction as red_k
from compile.kernels import ref
from compile.kernels import stencil as stencil_k

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- daxpy

@settings(**SETTINGS)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    block=st.sampled_from([8, 16, 64]),
    n_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_daxpy_matches_ref(blocks, block, n_frac, seed, dtype):
    size = blocks * block
    n = int(round(n_frac * size))
    r = rng(seed)
    a = dtype(2.5)
    x = r.standard_normal(size).astype(dtype)
    y = r.standard_normal(size).astype(dtype)
    got = daxpy_k.daxpy(a, jnp.asarray(x), jnp.asarray(y), n, block=block)
    want = ref.daxpy(a, x, y, n)
    # atol: XLA may contract a*x+y to an FMA in one of the two lowerings
    tol = 1e-6 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_daxpy_tail_lanes_keep_old_y():
    """Merging predication: lanes >= n must hold y verbatim (bitwise)."""
    size, n = 64, 37
    r = rng(7)
    x = r.standard_normal(size)
    y = r.standard_normal(size)
    got = np.asarray(daxpy_k.daxpy(3.0, jnp.asarray(x), jnp.asarray(y), n,
                                   block=16))
    assert (got[n:] == y[n:]).all()


def test_daxpy_n_zero_is_identity():
    size = 32
    y = rng(1).standard_normal(size)
    got = daxpy_k.daxpy(1.5, jnp.zeros(size), jnp.asarray(y), 0, block=16)
    np.testing.assert_array_equal(np.asarray(got), y)


def test_daxpy_block_size_agnostic():
    """VLA property: the result must not depend on the block size (VL)."""
    size, n = 128, 100
    r = rng(3)
    x, y = r.standard_normal(size), r.standard_normal(size)
    outs = [
        np.asarray(daxpy_k.daxpy(2.0, jnp.asarray(x), jnp.asarray(y), n,
                                 block=b))
        for b in (8, 16, 32, 64, 128)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


# ----------------------------------------------------------------- hacc

@settings(**SETTINGS)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    n_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hacc_matches_ref(blocks, n_frac, seed):
    block = 32
    size = blocks * block
    n = int(round(n_frac * size))
    r = rng(seed)
    pivot = r.uniform(-1, 1, 3).astype(np.float32)
    x = r.uniform(-4, 4, size).astype(np.float32)
    y = r.uniform(-4, 4, size).astype(np.float32)
    z = r.uniform(-4, 4, size).astype(np.float32)
    m = r.uniform(0.5, 2.0, size).astype(np.float32)
    got = hacc_k.hacc_force(jnp.asarray(pivot), jnp.asarray(x),
                            jnp.asarray(y), jnp.asarray(z), jnp.asarray(m),
                            n, block=block)
    want = ref.hacc_force(pivot, x, y, z, m, n)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_hacc_cutoff_conditional():
    """Particles beyond rmax2 contribute exactly zero (conditional #2)."""
    size = 32
    pivot = np.zeros(3, np.float32)
    x = np.full(size, 100.0, np.float32)  # far outside cutoff
    y = np.zeros(size, np.float32)
    z = np.zeros(size, np.float32)
    m = np.ones(size, np.float32)
    got = np.asarray(hacc_k.hacc_force(jnp.asarray(pivot), jnp.asarray(x),
                                       jnp.asarray(y), jnp.asarray(z),
                                       jnp.asarray(m), size, block=32))
    assert (got == 0).all()


def test_hacc_softening_conditional():
    """Coincident particle does not produce inf/nan (conditional #1)."""
    size = 32
    pivot = np.zeros(3, np.float32)
    x = np.zeros(size, np.float32)
    y = np.zeros(size, np.float32)
    z = np.zeros(size, np.float32)
    m = np.ones(size, np.float32)
    got = np.asarray(hacc_k.hacc_force(jnp.asarray(pivot), jnp.asarray(x),
                                       jnp.asarray(y), jnp.asarray(z),
                                       jnp.asarray(m), size, block=32))
    assert np.isfinite(got).all()


# -------------------------------------------------------------- stencil

@settings(max_examples=8, deadline=None)
@given(
    ni=st.integers(min_value=3, max_value=6),
    nj=st.integers(min_value=3, max_value=6),
    nk=st.integers(min_value=4, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_stencil_matches_ref(ni, nj, nk, seed):
    p = rng(seed).standard_normal((ni, nj, nk)).astype(np.float32)
    got = stencil_k.jacobi19(jnp.asarray(p))
    want = ref.jacobi19(p)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_stencil_boundaries_pass_through():
    p = rng(11).standard_normal((5, 5, 8)).astype(np.float32)
    got = np.asarray(stencil_k.jacobi19(jnp.asarray(p)))
    for face in (got[0], got[-1], got[:, 0], got[:, -1],
                 got[:, :, 0], got[:, :, -1]):
        pass  # indexing checked below explicitly
    assert (got[0] == p[0]).all() and (got[-1] == p[-1]).all()
    assert (got[:, 0] == p[:, 0]).all() and (got[:, -1] == p[:, -1]).all()
    assert (got[:, :, 0] == p[:, :, 0]).all()
    assert (got[:, :, -1] == p[:, :, -1]).all()


def test_stencil_constant_field_is_fixed_point():
    p = np.full((4, 4, 8), 3.25, np.float32)
    got = np.asarray(stencil_k.jacobi19(jnp.asarray(p)))
    np.testing.assert_allclose(got, p, rtol=1e-6)


# ----------------------------------------------------------- reductions

@settings(**SETTINGS)
@given(
    logsize=st.integers(min_value=2, max_value=9),
    n_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fadda_is_strictly_ordered(logsize, n_frac, seed):
    size = 1 << logsize
    n = int(round(n_frac * size))
    x = rng(seed).standard_normal(size)
    got = float(red_k.fadda_ordered(jnp.asarray(x), n))
    want = float(ref.fadda_ordered(x, n))
    # strictly ordered => bitwise equal to the scalar loop, not just close
    assert got == want


@settings(**SETTINGS)
@given(
    logsize=st.integers(min_value=2, max_value=9),
    n_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_faddv_matches_tree_ref(logsize, n_frac, seed):
    size = 1 << logsize
    n = int(round(n_frac * size))
    x = rng(seed).standard_normal(size)
    got = float(red_k.faddv_tree(jnp.asarray(x), n))
    want = float(ref.faddv_tree(x, n))
    assert got == want  # identical tree order => bitwise equal


def test_fadda_vs_faddv_close_but_possibly_different():
    """§3.3: the two orders agree within tolerance, not necessarily
    bitwise — the reason fadda exists."""
    x = rng(5).standard_normal(512) * 1e6
    a = float(red_k.fadda_ordered(jnp.asarray(x), 512))
    t = float(red_k.faddv_tree(jnp.asarray(x), 512))
    np.testing.assert_allclose(a, t, rtol=1e-9)


@settings(**SETTINGS)
@given(
    size=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_eorv_matches_ref(size, seed):
    r = rng(seed)
    x = r.integers(0, 2**62, size, dtype=np.int64)
    n = int(r.integers(0, size + 1))
    got = int(red_k.eorv(jnp.asarray(x), n))
    want = int(ref.eorv(x, n))
    assert got == want
