"""L2 model entries: shape/dtype contracts + AOT lowering smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.mark.parametrize("name", list(model.ENTRIES))
def test_entry_evaluates_at_example_shapes(name):
    fn, example = model.ENTRIES[name]
    r = np.random.default_rng(42)
    args = []
    for spec in example:
        if np.issubdtype(spec.dtype, np.integer):
            # trip counts: keep within the array bound
            args.append(jnp.asarray(
                r.integers(1, 64, spec.shape), dtype=spec.dtype))
        else:
            args.append(jnp.asarray(
                r.standard_normal(spec.shape), dtype=spec.dtype))
    out = fn(*args)
    assert np.isfinite(np.asarray(out, dtype=np.float64)).all()


@pytest.mark.parametrize("name", list(model.ENTRIES))
def test_entry_output_shape_is_stable(name):
    """The Rust runtime hard-codes output shapes; lock them here."""
    fn, example = model.ENTRIES[name]
    out = jax.eval_shape(fn, *example)
    expected = {
        "daxpy": ((model.DAXPY_N,), jnp.float64),
        "hacc": ((model.HACC_N,), jnp.float32),
        "stencil": (model.STENCIL_SHAPE, jnp.float32),
        "fadda": ((1,), jnp.float64),
        "faddv": ((1,), jnp.float64),
        "eorv": ((1,), jnp.int64),
    }[name]
    assert out.shape == expected[0]
    assert out.dtype == expected[1]


@pytest.mark.parametrize("name", ["daxpy", "fadda"])
def test_aot_lowering_produces_hlo_text(name):
    text = aot.lower_entry(name)
    assert "HloModule" in text
    # return_tuple=True => the root is a tuple
    assert "tuple" in text


def test_aot_main_writes_all_artifacts(tmp_path, monkeypatch):
    import sys
    monkeypatch.setattr(sys, "argv",
                        ["aot", "--out-dir", str(tmp_path), "--only",
                         "eorv"])
    aot.main()
    assert (tmp_path / "eorv.hlo.txt").exists()
    assert (tmp_path / "manifest.txt").read_text().startswith("eorv:")
