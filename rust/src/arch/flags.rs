//! NZCV condition flags, including the SVE overloading of Table 1:
//!
//! | Flag | SVE   | Condition                          |
//! |------|-------|------------------------------------|
//! | N    | First | set if first element is active     |
//! | Z    | None  | set if no element is active        |
//! | C    | !Last | set if last element is not active  |
//! | V    |       | scalarized loop state, else zero   |

use super::regs::{Esize, PredReg};

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Flags {
    pub n: bool,
    pub z: bool,
    pub c: bool,
    pub v: bool,
}

impl Flags {
    /// Set from a predicate-generating instruction's result (Table 1).
    /// `First`/`Last` are relative to the implicit least- to
    /// most-significant element order (§2.3.1), and — per the ARM ARM —
    /// relative to the *governing* predicate `pg`: "first" is the first
    /// element active in pg, "last" the last element active in pg.
    /// Entirely word-parallel: this runs once per predicate-setting
    /// instruction, i.e. twice per vector-loop iteration.
    pub fn from_pred_result(pg: &PredReg, result: &PredReg, e: Esize, vl_bytes: usize) -> Flags {
        let first = pg
            .first_active(e, vl_bytes)
            .map(|i| result.active(e, i))
            .unwrap_or(false);
        let last = pg
            .last_active(e, vl_bytes)
            .map(|i| result.active(e, i))
            .unwrap_or(false);
        let none = pg.and_none(result, e, vl_bytes);
        Flags { n: first, z: none, c: !last, v: false }
    }

    /// AArch64 integer compare semantics (subtract and set flags) — used
    /// by the scalar `cmp`/`subs` path.
    pub fn from_sub(a: u64, b: u64) -> Flags {
        let (res, borrow) = a.overflowing_sub(b);
        let sa = a as i64;
        let sb = b as i64;
        let (sres, sover) = sa.overflowing_sub(sb);
        debug_assert_eq!(sres as u64, res);
        Flags { n: (res as i64) < 0, z: res == 0, c: !borrow, v: sover }
    }

    /// Scalar FP compare (fcmp): standard AArch64 mapping with
    /// unordered -> C,V set.
    pub fn from_fcmp(a: f64, b: f64) -> Flags {
        if a.is_nan() || b.is_nan() {
            Flags { n: false, z: false, c: true, v: true }
        } else if a == b {
            Flags { n: false, z: true, c: true, v: false }
        } else if a < b {
            Flags { n: true, z: false, c: false, v: false }
        } else {
            Flags { n: false, z: false, c: true, v: false }
        }
    }

    /// Evaluate an AArch64 condition.
    pub fn cond(&self, c: Cond) -> bool {
        match c {
            Cond::Eq => self.z,
            Cond::Ne => !self.z,
            Cond::Hs => self.c,
            Cond::Lo => !self.c,
            Cond::Mi => self.n,
            Cond::Pl => !self.n,
            Cond::Vs => self.v,
            Cond::Vc => !self.v,
            Cond::Hi => self.c && !self.z,
            Cond::Ls => !(self.c && !self.z),
            Cond::Ge => self.n == self.v,
            Cond::Lt => self.n != self.v,
            Cond::Gt => !self.z && self.n == self.v,
            Cond::Le => !(!self.z && self.n == self.v),
        }
    }
}

/// AArch64 condition codes, with the SVE aliases of §2.3 spelled out.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Cond {
    Eq,
    Ne,
    Hs,
    Lo,
    Mi,
    Pl,
    Vs,
    Vc,
    Hi,
    Ls,
    Ge,
    Lt,
    Gt,
    Le,
}

impl Cond {
    /// SVE aliases (ARM ARM "condition aliases for SVE"):
    /// none=EQ, any=NE, nlast=HS, **last=LO**, **first=MI**, nfrst=PL,
    /// pmore=HI, plast=LS, **tcont=GE**, tstop=LT.
    pub const NONE: Cond = Cond::Eq;
    pub const ANY: Cond = Cond::Ne;
    pub const NLAST: Cond = Cond::Hs;
    pub const LAST: Cond = Cond::Lo;
    pub const FIRST: Cond = Cond::Mi;
    pub const NFRST: Cond = Cond::Pl;
    pub const PMORE: Cond = Cond::Hi;
    pub const PLAST: Cond = Cond::Ls;
    pub const TCONT: Cond = Cond::Ge;
    pub const TSTOP: Cond = Cond::Lt;

    pub fn invert(self) -> Cond {
        match self {
            Cond::Eq => Cond::Ne,
            Cond::Ne => Cond::Eq,
            Cond::Hs => Cond::Lo,
            Cond::Lo => Cond::Hs,
            Cond::Mi => Cond::Pl,
            Cond::Pl => Cond::Mi,
            Cond::Vs => Cond::Vc,
            Cond::Vc => Cond::Vs,
            Cond::Hi => Cond::Ls,
            Cond::Ls => Cond::Hi,
            Cond::Ge => Cond::Lt,
            Cond::Lt => Cond::Ge,
            Cond::Gt => Cond::Le,
            Cond::Le => Cond::Gt,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;

    fn pred_from_bits(e: Esize, bits: &[bool]) -> PredReg {
        let mut p = PredReg::default();
        for (i, &b) in bits.iter().enumerate() {
            p.set_active(e, i, b);
        }
        p
    }

    #[test]
    fn table1_first_none_last() {
        let e = Esize::D;
        let vlb = 32; // 4 lanes of .d
        let pg = pred_from_bits(e, &[true, true, true, true]);

        // all active: First=1, None=0, Last=1 => N=1 Z=0 C=0
        let f = Flags::from_pred_result(&pg, &pred_from_bits(e, &[true, true, true, true]), e, vlb);
        assert_eq!(f, Flags { n: true, z: false, c: false, v: false });

        // partial from the front: First=1, Last=0 => N=1 C=1
        let f =
            Flags::from_pred_result(&pg, &pred_from_bits(e, &[true, true, false, false]), e, vlb);
        assert_eq!(f, Flags { n: true, z: false, c: true, v: false });

        // empty: None=1 => Z=1, N=0, C=1
        let f = Flags::from_pred_result(&pg, &pred_from_bits(e, &[false; 4]), e, vlb);
        assert_eq!(f, Flags { n: false, z: true, c: true, v: false });
    }

    #[test]
    fn table1_first_last_follow_governing_pred() {
        // Governing predicate covers lanes 1..3 only: "first" means lane 1.
        let e = Esize::S;
        let vlb = 16; // 4 lanes of .s
        let pg = pred_from_bits(e, &[false, true, true, false]);
        let res = pred_from_bits(e, &[false, true, false, false]);
        let f = Flags::from_pred_result(&pg, &res, e, vlb);
        assert!(f.n, "lane1 is pg's first and is set in result");
        assert!(f.c, "pg's last (lane2) not set in result -> C=!Last=1");
        assert!(!f.z);
    }

    #[test]
    fn sve_condition_aliases() {
        // b.first == b.mi, b.last == b.lo, b.tcont == b.ge (§2.3, Fig. 2/5/6)
        assert_eq!(Cond::FIRST, Cond::Mi);
        assert_eq!(Cond::LAST, Cond::Lo);
        assert_eq!(Cond::NONE, Cond::Eq);
        assert_eq!(Cond::ANY, Cond::Ne);
        assert_eq!(Cond::TCONT, Cond::Ge);
    }

    #[test]
    fn sub_flags_match_reference_cases() {
        let f = Flags::from_sub(5, 5);
        assert!(f.z && f.c && !f.n && !f.v);
        let f = Flags::from_sub(3, 5);
        assert!(!f.z && !f.c && f.n && !f.v);
        let f = Flags::from_sub(5, 3);
        assert!(!f.z && f.c && !f.n && !f.v);
        // signed overflow: i64::MIN - 1
        let f = Flags::from_sub(i64::MIN as u64, 1);
        assert!(f.v);
    }

    #[test]
    fn cond_eval_vs_scalar_semantics() {
        check("cond_eval_vs_scalar_semantics", 500, |g| {
            let a = g.u64();
            let b = g.u64();
            let f = Flags::from_sub(a, b);
            assert_eq!(f.cond(Cond::Eq), a == b);
            assert_eq!(f.cond(Cond::Ne), a != b);
            assert_eq!(f.cond(Cond::Lo), a < b);
            assert_eq!(f.cond(Cond::Hs), a >= b);
            assert_eq!(f.cond(Cond::Hi), a > b);
            assert_eq!(f.cond(Cond::Ls), a <= b);
            assert_eq!(f.cond(Cond::Lt), (a as i64) < (b as i64));
            assert_eq!(f.cond(Cond::Ge), (a as i64) >= (b as i64));
            assert_eq!(f.cond(Cond::Gt), (a as i64) > (b as i64));
            assert_eq!(f.cond(Cond::Le), (a as i64) <= (b as i64));
        });
    }

    #[test]
    fn cond_invert_is_involution_and_negation() {
        let all = [
            Cond::Eq,
            Cond::Ne,
            Cond::Hs,
            Cond::Lo,
            Cond::Mi,
            Cond::Pl,
            Cond::Vs,
            Cond::Vc,
            Cond::Hi,
            Cond::Ls,
            Cond::Ge,
            Cond::Lt,
            Cond::Gt,
            Cond::Le,
        ];
        check("cond_invert_is_involution_and_negation", 200, |g| {
            let c = *g.choose(&all);
            let f = Flags { n: g.bool(), z: g.bool(), c: g.bool(), v: g.bool() };
            assert_eq!(c.invert().invert(), c);
            assert_eq!(f.cond(c), !f.cond(c.invert()));
        });
    }

    #[test]
    fn fcmp_cases() {
        assert!(Flags::from_fcmp(1.0, 1.0).cond(Cond::Eq));
        assert!(Flags::from_fcmp(0.5, 1.0).cond(Cond::Mi));
        assert!(Flags::from_fcmp(2.0, 1.0).cond(Cond::Gt));
        let un = Flags::from_fcmp(f64::NAN, 1.0);
        assert!(un.c && un.v && !un.z);
    }
}
