//! Architectural state introduced by SVE (§2.1, Fig. 1) plus the AArch64
//! base state the paper's examples rely on.

mod flags;
mod regs;
mod state;

pub use flags::{Cond, Flags};
pub use regs::{Esize, PredReg, VectorReg};
pub use state::{CpuState, Zcr, NUM_PREGS, NUM_VREGS, NUM_XREGS};
