//! Scalable vector and predicate registers (§2.1).
//!
//! A [`VectorReg`] holds the architectural maximum of 2048 bits; the
//! *effective* vector length (VL) is carried by the executing
//! [`super::CpuState`] and every operation only touches the first
//! `VL/8` bytes. A [`PredReg`] holds one bit per vector *byte* (§2.3.1:
//! "eight enable bits per 64-bit vector element"); for element size `E`
//! only the least-significant bit of each element's group is the enable.
//!
//! Predicates are stored as four `u64` words, and every operation the
//! simulator's hot loops need — logic under a governing predicate,
//! prefix construction/detection for `whilelt`, break masks, population
//! counts, first/last scans — is word-parallel rather than per-lane.

use crate::VL_MAX_BYTES;

/// Element size of a vector operation (B/H/S/D suffixes in the ISA).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Esize {
    B,
    H,
    S,
    D,
}

impl Esize {
    /// Element width in bytes.
    #[inline]
    pub const fn bytes(self) -> usize {
        match self {
            Esize::B => 1,
            Esize::H => 2,
            Esize::S => 4,
            Esize::D => 8,
        }
    }

    /// Number of elements in a vector of `vl_bytes`.
    #[inline]
    pub const fn lanes(self, vl_bytes: usize) -> usize {
        vl_bytes / self.bytes()
    }

    pub const ALL: [Esize; 4] = [Esize::B, Esize::H, Esize::S, Esize::D];

    pub fn suffix(self) -> &'static str {
        match self {
            Esize::B => "b",
            Esize::H => "h",
            Esize::S => "s",
            Esize::D => "d",
        }
    }
}

/// One scalable vector register (Z0–Z31). The low 128 bits double as the
/// corresponding Advanced SIMD register V0–V31 (§4: the SVE register file
/// *overlays* the SIMD/FP file).
#[derive(Clone, Copy)]
pub struct VectorReg {
    pub bytes: [u8; VL_MAX_BYTES],
}

impl Default for VectorReg {
    fn default() -> Self {
        VectorReg { bytes: [0u8; VL_MAX_BYTES] }
    }
}

impl std::fmt::Debug for VectorReg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // print the low 128 bits only; full dumps come from trace code
        write!(f, "VectorReg(lo128=")?;
        for b in self.bytes[..16].iter().rev() {
            write!(f, "{b:02x}")?;
        }
        write!(f, ", ..)")
    }
}

impl VectorReg {
    /// Read element `i` (zero-extended to u64). Little-endian element
    /// layout, as in AArch64; word-at-a-time for the hot sizes.
    #[inline]
    pub fn get(&self, e: Esize, i: usize) -> u64 {
        match e {
            Esize::B => self.bytes[i] as u64,
            Esize::H => {
                u16::from_le_bytes(self.bytes[i * 2..i * 2 + 2].try_into().unwrap()) as u64
            }
            Esize::S => {
                u32::from_le_bytes(self.bytes[i * 4..i * 4 + 4].try_into().unwrap()) as u64
            }
            Esize::D => u64::from_le_bytes(self.bytes[i * 8..i * 8 + 8].try_into().unwrap()),
        }
    }

    /// Write element `i` (truncating `v` to the element width).
    #[inline]
    pub fn set(&mut self, e: Esize, i: usize, v: u64) {
        match e {
            Esize::B => self.bytes[i] = v as u8,
            Esize::H => self.bytes[i * 2..i * 2 + 2].copy_from_slice(&(v as u16).to_le_bytes()),
            Esize::S => self.bytes[i * 4..i * 4 + 4].copy_from_slice(&(v as u32).to_le_bytes()),
            Esize::D => self.bytes[i * 8..i * 8 + 8].copy_from_slice(&v.to_le_bytes()),
        }
    }

    /// Read element `i` sign-extended to i64.
    #[inline]
    pub fn get_signed(&self, e: Esize, i: usize) -> i64 {
        let v = self.get(e, i);
        let bits = e.bytes() * 8;
        if bits == 64 {
            v as i64
        } else {
            let shift = 64 - bits;
            ((v << shift) as i64) >> shift
        }
    }

    #[inline]
    pub fn get_f64(&self, i: usize) -> f64 {
        f64::from_bits(self.get(Esize::D, i))
    }

    #[inline]
    pub fn set_f64(&mut self, i: usize, v: f64) {
        self.set(Esize::D, i, v.to_bits())
    }

    #[inline]
    pub fn get_f32(&self, i: usize) -> f32 {
        f32::from_bits(self.get(Esize::S, i) as u32)
    }

    #[inline]
    pub fn set_f32(&mut self, i: usize, v: f32) {
        self.set(Esize::S, i, v.to_bits() as u64)
    }

    /// Zero everything from byte `from` upward. Advanced SIMD writes call
    /// this with `from = 16`: §4 — "Advanced SIMD ... instructions are
    /// required to zero the extended bits of any vector register which
    /// they write, avoiding partial updates".
    pub fn zero_from(&mut self, from: usize) {
        for b in &mut self.bytes[from..] {
            *b = 0;
        }
    }

    pub fn zero(&mut self) {
        self.bytes = [0u8; VL_MAX_BYTES];
    }
}

/// One scalable predicate register (P0–P15) or the FFR: one bit per
/// vector byte, stored as a bitset.
#[derive(Clone, Copy, Default, PartialEq, Eq)]
pub struct PredReg {
    words: [u64; VL_MAX_BYTES / 64],
}

impl std::fmt::Debug for PredReg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PredReg(")?;
        for i in (0..32).rev() {
            write!(f, "{}", u8::from(self.get_bit(i)))?;
        }
        write!(f, "… low 32 byte-lanes)")
    }
}

impl PredReg {
    /// Raw per-byte enable bit.
    #[inline]
    pub fn get_bit(&self, byte_lane: usize) -> bool {
        (self.words[byte_lane / 64] >> (byte_lane % 64)) & 1 == 1
    }

    #[inline]
    pub fn set_bit(&mut self, byte_lane: usize, v: bool) {
        let (w, b) = (byte_lane / 64, byte_lane % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Is element `i` (at element size `e`) active? Only the least
    /// significant bit of the element's byte group is the enable
    /// (§2.3.1 "Mixed element size control").
    #[inline]
    pub fn active(&self, e: Esize, i: usize) -> bool {
        self.get_bit(i * e.bytes())
    }

    /// Set element `i`'s enable. The canonical encoding sets the low bit
    /// of the group and clears the rest, which is what all
    /// predicate-producing instructions write.
    #[inline]
    pub fn set_active(&mut self, e: Esize, i: usize, v: bool) {
        let base = i * e.bytes();
        self.set_bit(base, v);
        for k in 1..e.bytes() {
            self.set_bit(base + k, false);
        }
    }

    /// All-false.
    pub fn clear(&mut self) {
        self.words = [0; VL_MAX_BYTES / 64];
    }

    /// Word pattern with one set bit per element of size `e`.
    #[inline]
    const fn elem_pattern(e: Esize) -> u64 {
        match e {
            Esize::B => u64::MAX,
            Esize::H => 0x5555_5555_5555_5555,
            Esize::S => 0x1111_1111_1111_1111,
            Esize::D => 0x0101_0101_0101_0101,
        }
    }

    /// Mask of word `w`'s bits that fall below `vl_bytes`.
    #[inline]
    const fn word_mask(vl_bytes: usize, w: usize) -> u64 {
        let lo = w * 64;
        if vl_bytes >= lo + 64 {
            u64::MAX
        } else if vl_bytes > lo {
            (1u64 << (vl_bytes - lo)) - 1
        } else {
            0
        }
    }

    /// Canonical all-true at element size `e` over `vl_bytes`
    /// (word-parallel: this is on the simulator's hottest path).
    pub fn set_all(&mut self, e: Esize, vl_bytes: usize) {
        let pat = Self::elem_pattern(e);
        for (w, word) in self.words.iter_mut().enumerate() {
            *word = pat & Self::word_mask(vl_bytes, w);
        }
    }

    /// Canonical prefix: exactly the first `k` elements of size `e`
    /// active (the shape `whilelt` produces).
    pub fn set_prefix(&mut self, e: Esize, k: usize, vl_bytes: usize) {
        self.set_all(e, (k * e.bytes()).min(vl_bytes));
    }

    /// `Some(k)` iff exactly the first `k` elements are active (`k` may
    /// be 0). This is the shape every `ptrue`/`whilelt` governing
    /// predicate has, and what lets contiguous loads/stores collapse to
    /// one bulk copy.
    pub fn prefix_len(&self, e: Esize, vl_bytes: usize) -> Option<usize> {
        let pat = Self::elem_pattern(e);
        let mut k = 0usize;
        let mut ended = false;
        for (w, &word) in self.words.iter().enumerate() {
            let full = pat & Self::word_mask(vl_bytes, w);
            let bits = word & full;
            if ended || full == 0 {
                if bits != 0 {
                    return None; // active lane after a gap
                }
                continue;
            }
            if bits == full {
                k += full.count_ones() as usize;
            } else if bits == 0 {
                ended = true;
            } else {
                // partial word: actives must be bottom-contiguous in full
                let top = 63 - bits.leading_zeros() as usize;
                let below = if top == 63 { u64::MAX } else { (1u64 << (top + 1)) - 1 };
                if bits != full & below {
                    return None;
                }
                k += bits.count_ones() as usize;
                ended = true;
            }
        }
        Some(k)
    }

    /// Clear every enable bit at byte lane >= `from_byte` (the FFR
    /// partition update of §2.3.3, and break masks of §2.3.4).
    pub fn clear_from(&mut self, from_byte: usize) {
        for (w, word) in self.words.iter_mut().enumerate() {
            let lo = w * 64;
            if from_byte <= lo {
                *word = 0;
            } else if from_byte < lo + 64 {
                *word &= (1u64 << (from_byte - lo)) - 1;
            }
        }
    }

    /// Word-parallel predicate logic under a governing predicate:
    /// `result = f(n, m) & g`, masked to `vl_bytes` (B-granule — every
    /// bit is an element enable).
    pub fn combine(
        n: &PredReg,
        m: &PredReg,
        g: &PredReg,
        vl_bytes: usize,
        f: impl Fn(u64, u64) -> u64,
    ) -> PredReg {
        let mut r = PredReg::default();
        for w in 0..r.words.len() {
            r.words[w] = f(n.words[w], m.words[w]) & g.words[w] & Self::word_mask(vl_bytes, w);
        }
        r
    }

    /// Number of active elements at size `e` within `vl_bytes`.
    pub fn count_active(&self, e: Esize, vl_bytes: usize) -> usize {
        let pat = Self::elem_pattern(e);
        let mut n = 0;
        for (w, &word) in self.words.iter().enumerate() {
            n += (word & pat & Self::word_mask(vl_bytes, w)).count_ones() as usize;
        }
        n
    }

    /// Index of the first active element, if any (§2.3.1 "Implicit
    /// order": least- to most-significant).
    pub fn first_active(&self, e: Esize, vl_bytes: usize) -> Option<usize> {
        self.first_active_from(e, 0, vl_bytes)
    }

    /// Index of the first active element at lane >= `from`, if any
    /// (the `pnext` scan of §2.3.5).
    pub fn first_active_from(&self, e: Esize, from: usize, vl_bytes: usize) -> Option<usize> {
        let pat = Self::elem_pattern(e);
        let start_bit = from * e.bytes();
        for (w, &word) in self.words.iter().enumerate() {
            let lo = w * 64;
            if lo + 64 <= start_bit {
                continue;
            }
            if lo >= vl_bytes {
                break;
            }
            let mut bits = word & pat & Self::word_mask(vl_bytes, w);
            if start_bit > lo {
                bits &= !((1u64 << (start_bit - lo)) - 1);
            }
            if bits != 0 {
                return Some((lo + bits.trailing_zeros() as usize) / e.bytes());
            }
        }
        None
    }

    /// Index of the last active element, if any.
    pub fn last_active(&self, e: Esize, vl_bytes: usize) -> Option<usize> {
        let pat = Self::elem_pattern(e);
        let words = vl_bytes.div_ceil(64).min(self.words.len());
        for w in (0..words).rev() {
            let bits = self.words[w] & pat & Self::word_mask(vl_bytes, w);
            if bits != 0 {
                return Some((w * 64 + 63 - bits.leading_zeros() as usize) / e.bytes());
            }
        }
        None
    }

    /// No element active?
    pub fn none_active(&self, e: Esize, vl_bytes: usize) -> bool {
        self.first_active(e, vl_bytes).is_none()
    }

    /// Bitwise AND (used for governed predicate reads, e.g. `rdffr pd, pg/z`).
    pub fn and(&self, other: &PredReg) -> PredReg {
        let mut r = PredReg::default();
        for (i, w) in r.words.iter_mut().enumerate() {
            *w = self.words[i] & other.words[i];
        }
        r
    }

    /// Is `self & other` empty at element granularity within `vl_bytes`?
    /// (The Table 1 "None" flag, word-parallel.)
    pub fn and_none(&self, other: &PredReg, e: Esize, vl_bytes: usize) -> bool {
        let pat = Self::elem_pattern(e);
        for w in 0..self.words.len() {
            if self.words[w] & other.words[w] & pat & Self::word_mask(vl_bytes, w) != 0 {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;

    #[test]
    fn element_roundtrip_all_sizes() {
        check("element_roundtrip_all_sizes", 200, |g| {
            let e = *g.choose(&Esize::ALL);
            let mut v = VectorReg::default();
            let lanes = e.lanes(32); // VL = 256-bit
            let i = g.usize_in(0, lanes - 1);
            let raw = g.u64();
            v.set(e, i, raw);
            let mask = if e.bytes() == 8 { u64::MAX } else { (1u64 << (e.bytes() * 8)) - 1 };
            assert_eq!(v.get(e, i), raw & mask);
        });
    }

    #[test]
    fn set_does_not_clobber_neighbours() {
        let mut v = VectorReg::default();
        v.set(Esize::S, 0, 0xAAAA_BBBB);
        v.set(Esize::S, 1, 0xCCCC_DDDD);
        v.set(Esize::S, 2, 0x1111_2222);
        v.set(Esize::S, 1, 0x3333_4444);
        assert_eq!(v.get(Esize::S, 0), 0xAAAA_BBBB);
        assert_eq!(v.get(Esize::S, 1), 0x3333_4444);
        assert_eq!(v.get(Esize::S, 2), 0x1111_2222);
    }

    #[test]
    fn sign_extension() {
        let mut v = VectorReg::default();
        v.set(Esize::B, 3, 0x80);
        assert_eq!(v.get_signed(Esize::B, 3), -128);
        v.set(Esize::S, 1, 0xFFFF_FFFF);
        assert_eq!(v.get_signed(Esize::S, 1), -1);
        v.set(Esize::D, 0, u64::MAX);
        assert_eq!(v.get_signed(Esize::D, 0), -1);
    }

    #[test]
    fn f64_bits_roundtrip() {
        let mut v = VectorReg::default();
        v.set_f64(2, -3.75);
        assert_eq!(v.get_f64(2), -3.75);
        v.set_f32(5, 1.5);
        assert_eq!(v.get_f32(5), 1.5);
    }

    #[test]
    fn neon_write_zeroes_high_bits() {
        let mut v = VectorReg::default();
        for i in 0..32 {
            v.set(Esize::D, i % 4, u64::MAX);
            v.bytes[i] = 0xFF;
        }
        v.zero_from(16);
        assert!(v.bytes[16..].iter().all(|&b| b == 0));
        assert!(v.bytes[..16].iter().all(|&b| b == 0xFF));
    }

    #[test]
    fn predicate_element_granularity() {
        let mut p = PredReg::default();
        p.set_active(Esize::D, 1, true);
        // element 1 at .d = byte lane 8
        assert!(p.get_bit(8));
        assert!(p.active(Esize::D, 1));
        // the same register viewed at .b granularity: only lane 8 set
        assert!(p.active(Esize::B, 8));
        assert!(!p.active(Esize::B, 9));
    }

    #[test]
    fn predicate_first_last_none() {
        let vlb = 32;
        let mut p = PredReg::default();
        assert!(p.none_active(Esize::S, vlb));
        p.set_active(Esize::S, 2, true);
        p.set_active(Esize::S, 5, true);
        assert_eq!(p.first_active(Esize::S, vlb), Some(2));
        assert_eq!(p.last_active(Esize::S, vlb), Some(5));
        assert_eq!(p.count_active(Esize::S, vlb), 2);
        assert_eq!(p.first_active_from(Esize::S, 3, vlb), Some(5));
        assert_eq!(p.first_active_from(Esize::S, 6, vlb), None);
    }

    #[test]
    fn predicate_all_then_and() {
        let vlb = 16;
        let mut a = PredReg::default();
        a.set_all(Esize::D, vlb);
        let mut b = PredReg::default();
        b.set_active(Esize::D, 0, true);
        let c = a.and(&b);
        assert!(c.active(Esize::D, 0));
        assert!(!c.active(Esize::D, 1));
        assert!(!a.and_none(&b, Esize::D, vlb));
        assert!(b.and_none(&PredReg::default(), Esize::D, vlb));
    }

    #[test]
    fn prefix_construction_and_detection_agree() {
        check("prefix_construction_and_detection_agree", 400, |g| {
            let e = *g.choose(&Esize::ALL);
            let vlb = 16 * g.usize_in(1, 16);
            let lanes = e.lanes(vlb);
            let k = g.usize_in(0, lanes);
            let mut p = PredReg::default();
            p.set_prefix(e, k, vlb);
            for i in 0..lanes {
                assert_eq!(p.active(e, i), i < k, "lane {i} of prefix {k}");
            }
            assert_eq!(p.prefix_len(e, vlb), Some(k));
            // poke an interior hole (or a detached lane): shape breaks
            if k >= 3 {
                p.set_active(e, k / 2, false); // k/2 <= k-2: hole, not a shorter prefix
                assert_eq!(p.prefix_len(e, vlb), None);
            } else if k + 2 <= lanes {
                p.set_active(e, k + 1, true);
                assert_eq!(p.prefix_len(e, vlb), None);
            }
        });
    }

    #[test]
    fn clear_from_partitions_the_register() {
        let vlb = 32;
        let mut p = PredReg::default();
        p.set_all(Esize::B, vlb);
        p.clear_from(10);
        for i in 0..vlb {
            assert_eq!(p.active(Esize::B, i), i < 10, "lane {i}");
        }
        // clearing across a word boundary
        let mut q = PredReg::default();
        q.set_all(Esize::B, 256);
        q.clear_from(70);
        assert_eq!(q.count_active(Esize::B, 256), 70);
    }

    #[test]
    fn combine_matches_per_lane_reference() {
        check("combine_matches_per_lane_reference", 300, |g| {
            let vlb = 16 * g.usize_in(1, 16);
            let mut n = PredReg::default();
            let mut m = PredReg::default();
            let mut pg = PredReg::default();
            for i in 0..vlb {
                n.set_bit(i, g.bool());
                m.set_bit(i, g.bool());
                pg.set_bit(i, g.bool());
            }
            let r = PredReg::combine(&n, &m, &pg, vlb, |a, b| a & !b); // bic
            for i in 0..vlb {
                let want = n.get_bit(i) && !m.get_bit(i) && pg.get_bit(i);
                assert_eq!(r.get_bit(i), want, "lane {i}");
            }
            // nothing beyond VL survives
            for i in vlb..VL_MAX_BYTES {
                assert!(!r.get_bit(i));
            }
        });
    }

    #[test]
    fn prop_count_equals_firstlast_consistency() {
        check("prop_count_equals_firstlast_consistency", 300, |g| {
            let e = *g.choose(&Esize::ALL);
            let vlb = 16 * g.usize_in(1, 16);
            let mut p = PredReg::default();
            let lanes = e.lanes(vlb);
            for i in 0..lanes {
                if g.bool() {
                    p.set_active(e, i, true);
                }
            }
            let cnt = p.count_active(e, vlb);
            match (p.first_active(e, vlb), p.last_active(e, vlb)) {
                (None, None) => assert_eq!(cnt, 0),
                (Some(f), Some(l)) => {
                    assert!(f <= l);
                    assert!(cnt >= 1 && cnt <= l - f + 1);
                }
                _ => panic!("first/last disagree"),
            }
        });
    }
}
