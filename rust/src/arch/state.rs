//! The complete per-core architectural state (Fig. 1a), plus the ZCR
//! vector-length virtualization registers.

use super::flags::Flags;
use super::regs::{PredReg, VectorReg};
use crate::{vl_is_legal, VL_MAX_BITS};

pub const NUM_XREGS: usize = 32; // x31 reads as zero (xzr)
pub const NUM_VREGS: usize = 32;
pub const NUM_PREGS: usize = 16;

/// ZCR_ELx: each privilege level can *reduce* the effective vector width
/// (§2.1). `len` holds (VL/128 - 1) like the architectural LEN field.
#[derive(Clone, Copy, Debug)]
pub struct Zcr {
    pub len: [u8; 3], // EL1..EL3
}

impl Default for Zcr {
    fn default() -> Self {
        // all levels allow the architectural max
        Zcr { len: [(VL_MAX_BITS / 128 - 1) as u8; 3] }
    }
}

impl Zcr {
    /// Effective VL in bits for a hardware of `hw_vl_bits`, as seen at
    /// EL0: the minimum of the hardware width and every level's limit.
    pub fn effective_vl_bits(&self, hw_vl_bits: usize) -> usize {
        let mut vl = hw_vl_bits;
        for l in self.len {
            vl = vl.min((l as usize + 1) * 128);
        }
        vl
    }
}

/// Architectural state of one simulated core.
#[derive(Clone)]
pub struct CpuState {
    /// General-purpose registers; index 31 is XZR (reads 0, writes
    /// discarded).
    pub x: [u64; NUM_XREGS],
    /// Scalable vector registers Z0–Z31; low 128 bits are V0–V31.
    pub z: [VectorReg; NUM_VREGS],
    /// Scalable predicate registers P0–P15.
    pub p: [PredReg; NUM_PREGS],
    /// First-fault register (§2.3.3).
    pub ffr: PredReg,
    /// NZCV.
    pub flags: Flags,
    /// Program counter, as an instruction *index* into the program.
    pub pc: usize,
    /// Vector-length control.
    pub zcr: Zcr,
    /// Hardware vector length in bits (an implementation choice, §2.2).
    hw_vl_bits: usize,
}

impl CpuState {
    pub fn new(hw_vl_bits: usize) -> Self {
        assert!(vl_is_legal(hw_vl_bits), "illegal vector length {hw_vl_bits}");
        CpuState {
            x: [0; NUM_XREGS],
            z: [VectorReg::default(); NUM_VREGS],
            p: [PredReg::default(); NUM_PREGS],
            ffr: PredReg::default(),
            flags: Flags::default(),
            pc: 0,
            zcr: Zcr::default(),
            hw_vl_bits,
        }
    }

    /// Effective vector length in bits after ZCR virtualization.
    #[inline]
    pub fn vl_bits(&self) -> usize {
        self.zcr.effective_vl_bits(self.hw_vl_bits)
    }

    /// Effective vector length in bytes.
    #[inline]
    pub fn vl_bytes(&self) -> usize {
        self.vl_bits() / 8
    }

    /// Read Xn with the XZR convention.
    #[inline]
    pub fn get_x(&self, n: u8) -> u64 {
        if n == 31 {
            0
        } else {
            self.x[n as usize]
        }
    }

    /// Write Xn with the XZR convention.
    #[inline]
    pub fn set_x(&mut self, n: u8, v: u64) {
        if n != 31 {
            self.x[n as usize] = v;
        }
    }

    /// Scalar FP view of V-register `n` (low 64 bits).
    #[inline]
    pub fn get_d(&self, n: u8) -> f64 {
        self.z[n as usize].get_f64(0)
    }

    /// Write D-register (scalar fp writes zero the rest of the vector,
    /// like any Advanced SIMD/FP write — §4).
    #[inline]
    pub fn set_d(&mut self, n: u8, v: f64) {
        let r = &mut self.z[n as usize];
        r.zero();
        r.set_f64(0, v);
    }

    #[inline]
    pub fn get_s(&self, n: u8) -> f32 {
        self.z[n as usize].get_f32(0)
    }

    #[inline]
    pub fn set_s(&mut self, n: u8, v: f32) {
        let r = &mut self.z[n as usize];
        r.zero();
        r.set_f32(0, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Esize;

    #[test]
    fn xzr_reads_zero_and_ignores_writes() {
        let mut s = CpuState::new(256);
        s.set_x(31, 0xDEAD);
        assert_eq!(s.get_x(31), 0);
        s.set_x(0, 7);
        assert_eq!(s.get_x(0), 7);
    }

    #[test]
    #[should_panic(expected = "illegal vector length")]
    fn illegal_vl_rejected() {
        CpuState::new(96);
    }

    #[test]
    fn zcr_reduces_effective_vl() {
        let mut s = CpuState::new(2048);
        assert_eq!(s.vl_bits(), 2048);
        s.zcr.len[0] = 1; // EL1 caps at 256
        assert_eq!(s.vl_bits(), 256);
        s.zcr.len[2] = 0; // EL3 caps at 128 — minimum across levels wins
        assert_eq!(s.vl_bits(), 128);
    }

    #[test]
    fn zcr_cannot_exceed_hardware() {
        let s = CpuState::new(256);
        assert_eq!(s.vl_bits(), 256, "default ZCR allows hw max only");
    }

    #[test]
    fn scalar_fp_writes_zero_the_vector() {
        let mut s = CpuState::new(512);
        for i in 0..8 {
            s.z[3].set(Esize::D, i, u64::MAX);
        }
        s.set_d(3, 2.5);
        assert_eq!(s.get_d(3), 2.5);
        for i in 1..8 {
            assert_eq!(s.z[3].get(Esize::D, i), 0, "lane {i} must be zeroed");
        }
    }
}
