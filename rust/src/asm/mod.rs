//! Program container and assembler-style builder with labels.

use crate::isa::Inst;
use std::collections::HashMap;

/// An executable program: a flat instruction sequence with resolved
/// branch targets (instruction indices) plus label names for traces.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub insts: Vec<Inst>,
    /// label -> instruction index (for disassembly/trace output).
    pub labels: Vec<(String, usize)>,
}

impl Program {
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Label at instruction index, if any.
    pub fn label_at(&self, idx: usize) -> Option<&str> {
        self.labels.iter().find(|(_, i)| *i == idx).map(|(n, _)| n.as_str())
    }

    /// Static count of SVE / NEON / other instructions.
    pub fn static_mix(&self) -> (usize, usize, usize) {
        let sve = self.insts.iter().filter(|i| i.is_sve()).count();
        let neon = self.insts.iter().filter(|i| i.is_neon()).count();
        (sve, neon, self.insts.len() - sve - neon)
    }
}

/// Builder: append instructions, define labels, reference labels in
/// branches before they are defined; `finish()` resolves everything.
#[derive(Default)]
pub struct Asm {
    insts: Vec<Inst>,
    labels: HashMap<String, usize>,
    /// (instruction index, label) pairs awaiting resolution.
    fixups: Vec<(usize, String)>,
}

impl Asm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current instruction index (where the next `push` lands).
    pub fn here(&self) -> usize {
        self.insts.len()
    }

    /// Define a label at the current position.
    pub fn label(&mut self, name: &str) -> &mut Self {
        let prev = self.labels.insert(name.to_string(), self.insts.len());
        assert!(prev.is_none(), "duplicate label {name}");
        self
    }

    pub fn push(&mut self, inst: Inst) -> &mut Self {
        self.insts.push(inst);
        self
    }

    /// Push a branch whose `target` field will be patched to `label`.
    pub fn push_branch(&mut self, inst: Inst, label: &str) -> &mut Self {
        debug_assert!(inst.branch_target().is_some(), "not a branch: {inst:?}");
        self.fixups.push((self.insts.len(), label.to_string()));
        self.insts.push(inst);
        self
    }

    /// Resolve fixups and produce the program.
    pub fn finish(mut self) -> Program {
        for (idx, label) in &self.fixups {
            let target = *self
                .labels
                .get(label)
                .unwrap_or_else(|| panic!("undefined label {label}"));
            match &mut self.insts[*idx] {
                Inst::B { target: t }
                | Inst::BCond { target: t, .. }
                | Inst::Cbz { target: t, .. }
                | Inst::Cbnz { target: t, .. } => *t = target,
                other => panic!("fixup on non-branch {other:?}"),
            }
        }
        let mut labels: Vec<(String, usize)> = self.labels.into_iter().collect();
        labels.sort_by_key(|(_, i)| *i);
        Program { insts: self.insts, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Cond;

    #[test]
    fn forward_and_backward_labels_resolve() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 0 });
        a.label("loop");
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 1 });
        a.push(Inst::CmpImm { xn: 0, imm: 10 });
        a.push_branch(Inst::BCond { cond: Cond::Lt, target: 0 }, "loop");
        a.push_branch(Inst::B { target: 0 }, "end");
        a.push(Inst::Nop);
        a.label("end");
        a.push(Inst::Halt);
        let p = a.finish();
        assert_eq!(p.insts[3].branch_target(), Some(1));
        assert_eq!(p.insts[4].branch_target(), Some(6));
        assert_eq!(p.label_at(1), Some("loop"));
        assert_eq!(p.label_at(6), Some("end"));
    }

    #[test]
    #[should_panic(expected = "undefined label")]
    fn undefined_label_panics() {
        let mut a = Asm::new();
        a.push_branch(Inst::B { target: 0 }, "nowhere");
        a.finish();
    }

    #[test]
    #[should_panic(expected = "duplicate label")]
    fn duplicate_label_panics() {
        let mut a = Asm::new();
        a.label("x");
        a.push(Inst::Nop);
        a.label("x");
    }

    #[test]
    fn static_mix_counts() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 0 });
        a.push(Inst::Setffr);
        a.push(Inst::NeonMoviZero { vd: 0 });
        let p = a.finish();
        assert_eq!(p.static_mix(), (1, 1, 1));
    }
}
