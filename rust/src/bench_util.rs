//! In-house micro-benchmark harness (the offline image has no criterion).
//!
//! `cargo bench` targets use `[[bench]] harness = false` and drive this
//! module: warmup, fixed-duration sampling, and median/mean/stddev
//! reporting in a criterion-like one-line format. Wall-clock timing via
//! `std::time::Instant`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub iters: u64,
}

impl Sample {
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// items/second at a given per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Benchmark `f`, returning timing statistics.
///
/// `f` must do one full unit of work per call; its return value is passed
/// through `std::hint::black_box` to keep the optimizer honest.
pub fn bench<T>(warmup: Duration, measure: Duration, mut f: impl FnMut() -> T) -> Sample {
    // Warmup + calibration: figure out how many iterations fit the budget.
    let wstart = Instant::now();
    let mut warm_iters = 0u64;
    while wstart.elapsed() < warmup || warm_iters == 0 {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    let per_iter = wstart.elapsed().as_secs_f64() / warm_iters as f64;
    let target_samples = 30usize;
    let batch = ((measure.as_secs_f64() / target_samples as f64 / per_iter).ceil() as u64).max(1);

    let mut samples_ns: Vec<f64> = Vec::with_capacity(target_samples);
    let mstart = Instant::now();
    let mut total_iters = 0u64;
    while mstart.elapsed() < measure || samples_ns.is_empty() {
        let s = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        samples_ns.push(s.elapsed().as_nanos() as f64 / batch as f64);
        total_iters += batch;
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len() as f64;
    let mean = samples_ns.iter().sum::<f64>() / n;
    let median = samples_ns[samples_ns.len() / 2];
    let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Sample { mean_ns: mean, median_ns: median, stddev_ns: var.sqrt(), iters: total_iters }
}

/// Convenience: default 0.3s warmup / 1.2s measurement.
///
/// ```no_run
/// use sve_repro::bench_util::{bench_default, report};
/// let sample = bench_default(|| (0..1_000u64).sum::<u64>());
/// report("sum-1k", &sample);
/// ```
pub fn bench_default<T>(f: impl FnMut() -> T) -> Sample {
    bench(Duration::from_millis(300), Duration::from_millis(1200), f)
}

/// Quick variant for slow end-to-end benches (one warmup call, N samples).
pub fn bench_n<T>(n: usize, mut f: impl FnMut() -> T) -> Sample {
    std::hint::black_box(f());
    let mut samples_ns = Vec::with_capacity(n);
    for _ in 0..n {
        let s = Instant::now();
        std::hint::black_box(f());
        samples_ns.push(s.elapsed().as_nanos() as f64);
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let nn = samples_ns.len() as f64;
    let mean = samples_ns.iter().sum::<f64>() / nn;
    let median = samples_ns[samples_ns.len() / 2];
    let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / nn;
    Sample { mean_ns: mean, median_ns: median, stddev_ns: var.sqrt(), iters: n as u64 }
}

fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// criterion-like single-line report.
pub fn report(name: &str, s: &Sample) {
    println!(
        "{name:<44} time: [{} ± {}]  median: {}  ({} iters)",
        human_ns(s.mean_ns),
        human_ns(s.stddev_ns),
        human_ns(s.median_ns),
        s.iters
    );
}

/// Report with throughput (elements, instructions, ...).
pub fn report_throughput(name: &str, s: &Sample, items: f64, unit: &str) {
    println!(
        "{name:<44} time: [{} ± {}]  thrpt: {:.3} M{unit}/s",
        human_ns(s.mean_ns),
        human_ns(s.stddev_ns),
        s.throughput(items) / 1e6,
    );
}

/// Mean-time speedup of `contender` over `baseline` (>1 means faster).
pub fn speedup(baseline: &Sample, contender: &Sample) -> f64 {
    baseline.mean_ns / contender.mean_ns
}

/// A/B throughput line: baseline vs contender at the same item count,
/// with the mean-time speedup — the perf_hotpath side-by-side format.
pub fn report_ab(name: &str, base: &Sample, new: &Sample, items: f64, unit: &str) {
    println!(
        "{name:<44} base: {:.3} M{unit}/s  new: {:.3} M{unit}/s  speedup: {:.2}x",
        base.throughput(items) / 1e6,
        new.throughput(items) / 1e6,
        speedup(base, new),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(Duration::from_millis(10), Duration::from_millis(50), || {
            (0..1000u64).sum::<u64>()
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.iters > 0);
        assert!(s.median_ns > 0.0);
    }

    #[test]
    fn bench_n_returns_n_samples() {
        let s = bench_n(5, || 42u64);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn speedup_is_mean_time_ratio() {
        let mk = |mean_ns| Sample { mean_ns, median_ns: mean_ns, stddev_ns: 0.0, iters: 1 };
        assert_eq!(speedup(&mk(200.0), &mk(100.0)), 2.0);
        assert_eq!(speedup(&mk(100.0), &mk(200.0)), 0.5);
    }

    #[test]
    fn human_units() {
        assert!(human_ns(5.0).ends_with("ns"));
        assert!(human_ns(5e3).ends_with("µs"));
        assert!(human_ns(5e6).ends_with("ms"));
        assert!(human_ns(5e9).ends_with(" s"));
    }
}
