//! Pointer-chase kernels: the Fig. 6 "scalarized intra-vector sub-loop"
//! (§2.3.5) and its scalar baseline.
//!
//! Linked-structure traversal has a loop-carried dependency through the
//! `next` pointer. §2.3.5's answer is loop fission: a *serialized*
//! sub-loop gathers up to VL node pointers into a vector using
//! `pnext`/`cpy`/`ctermeq`, then the payload work runs vectorized under
//! the partition of filled lanes, finishing with a horizontal reduction.

use super::codegen::Target;
use super::ir::Compiled;
use crate::arch::{Cond, Esize};
use crate::asm::Asm;
use crate::isa::{GatherAddr, Inst, IntOp, MemOff, PLogicOp, RedOp};

/// A linked-list traversal computing an XOR reduction of node values
/// (exactly Fig. 6a: `res ^= p->val`).
#[derive(Clone, Debug)]
pub struct ChaseKernel {
    pub name: String,
    /// Address of the first node (NULL-terminated list).
    pub head: u64,
    /// Byte offset of the `next` pointer within a node.
    pub next_off: i64,
    /// Byte offset of the 64-bit value within a node.
    pub val_off: i64,
    /// Where to store the final reduction.
    pub result: u64,
}

/// Is the scalarized sub-loop profitable? With a single XOR as payload it
/// is not (the paper itself: "the performance gained may not be
/// sufficient to justify using vectorization for this loop") — which is
/// also why Graph500 sees no benefit (§5). `force` overrides, as in the
/// Fig. 6 demonstration.
pub fn chase_profitable() -> bool {
    false
}

pub fn compile_chase(k: &ChaseKernel, target: Target, force_vectorize: bool) -> Compiled {
    let vectorize = matches!(target, Target::Sve) && (chase_profitable() || force_vectorize);
    if vectorize {
        compile_chase_sve(k)
    } else {
        let mut c = compile_chase_scalar(k);
        if matches!(target, Target::Sve) {
            c.why_not = Some(
                "scalarized sub-loop not profitable: payload is a single XOR \
                 (§2.3.5; the Graph500 situation)"
                    .into(),
            );
        } else if matches!(target, Target::Neon) {
            c.why_not =
                Some("loop-carried dependency through pointer chase".into());
        }
        c
    }
}

/// Fig. 6b's serial part, fused back into one loop (the scalar baseline).
fn compile_chase_scalar(k: &ChaseKernel) -> Compiled {
    let mut a = Asm::new();
    a.push(Inst::MovImm { xd: 1, imm: k.head });
    a.push(Inst::MovImm { xd: 16, imm: 0 }); // acc
    a.label("loop");
    a.push(Inst::Ldr { size: 8, signed: false, xt: 2, base: 1, off: MemOff::Imm(k.val_off) });
    a.push(Inst::LogReg { op: PLogicOp::Eor, xd: 16, xn: 16, xm: 2 });
    a.push(Inst::Ldr { size: 8, signed: false, xt: 1, base: 1, off: MemOff::Imm(k.next_off) });
    a.push_branch(Inst::Cbnz { xn: 1, target: 0 }, "loop");
    a.push(Inst::MovImm { xd: 3, imm: k.result });
    a.push(Inst::Str { size: 8, xt: 16, base: 3, off: MemOff::Imm(0) });
    a.push(Inst::Halt);
    Compiled::new(a.finish(), false, None)
}

/// Fig. 6c, transliterated: serialized pointer chase into Z1, vectorized
/// XOR under the filled partition, horizontal `eorv`.
fn compile_chase_sve(k: &ChaseKernel) -> Compiled {
    let mut a = Asm::new();
    a.push(Inst::MovImm { xd: 1, imm: k.head }); // p = &head
    a.push(Inst::DupImm { zd: 0, esize: Esize::D, imm: 0 }); // res' = 0
    a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false }); // current partition
    a.label("outer");
    a.push(Inst::Pfalse { pd: 1 }); // first i
    a.label("serial");
    // serialized sub-loop under P0
    a.push(Inst::Pnext { pdn: 1, pg: 0, esize: Esize::D }); // next i in P0
    a.push(Inst::CpyX { zd: 1, pg: 1, xn: 1, esize: Esize::D }); // Z1[i] = p
    a.push(Inst::Ldr { size: 8, signed: false, xt: 1, base: 1, off: MemOff::Imm(k.next_off) });
    a.push(Inst::Cterm { xn: 1, xm: 31, ne: false }); // p == NULL?
    a.push_branch(Inst::BCond { cond: Cond::TCONT, target: 0 }, "serial"); // !(term|last)
    // P2[0..i] = T
    a.push(Inst::Brk { pd: 2, pg: 0, pn: 1, before: false, s: false });
    // vectorized main loop under P2
    a.push(Inst::SveLdGather {
        zt: 2,
        pg: 2,
        esize: Esize::D,
        addr: GatherAddr::VecImm(1, k.val_off), // val' = p->val
        ff: false,
    });
    // res' ^= val'
    a.push(Inst::SveIntBin { op: IntOp::Eor, zdn: 0, pg: 2, zm: 2, esize: Esize::D });
    a.push_branch(Inst::Cbnz { xn: 1, target: 0 }, "outer"); // while p != NULL
    // d0 = eor(res')
    a.push(Inst::SveReduce { op: RedOp::EorV, vd: 0, pg: 0, zn: 0, esize: Esize::D });
    a.push(Inst::FmovDtoX { xd: 0, dn: 0 }); // return d0
    a.push(Inst::MovImm { xd: 3, imm: k.result });
    a.push(Inst::Str { size: 8, xt: 0, base: 3, off: MemOff::Imm(0) });
    a.push(Inst::Halt);
    Compiled::new(a.finish(), true, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::mem::Memory;
    use crate::rng::Rng;

    /// Build a shuffled linked list of `n` nodes; returns (kernel, xor).
    pub fn build_list(mem: &mut Memory, n: usize, seed: u64) -> (ChaseKernel, u64) {
        let mut rng = Rng::new(seed);
        let nodes = mem.alloc(16 * n as u64, 16);
        let mut order: Vec<u64> = (0..n as u64).collect();
        rng.shuffle(&mut order);
        let mut expected = 0u64;
        for i in 0..n {
            let addr = nodes + 16 * order[i];
            let val = rng.next_u64() >> 1;
            expected ^= val;
            mem.write_u64(addr, val).unwrap();
            let next = if i + 1 < n { nodes + 16 * order[i + 1] } else { 0 };
            mem.write_u64(addr + 8, next).unwrap();
        }
        let result = mem.alloc(8, 8);
        (
            ChaseKernel {
                name: "list".into(),
                head: nodes + 16 * order[0],
                next_off: 8,
                val_off: 0,
                result,
            },
            expected,
        )
    }

    #[test]
    fn scalar_chase_computes_xor() {
        let mut mem = Memory::new();
        let (k, want) = build_list(&mut mem, 100, 1);
        let c = compile_chase(&k, Target::Scalar, false);
        assert!(!c.vectorized);
        let mut ex = Executor::new(128, mem);
        ex.run(&c.program, 1_000_000).unwrap();
        assert_eq!(ex.mem.read_u64(k.result).unwrap(), want);
    }

    #[test]
    fn sve_chase_fig6_matches_scalar_at_all_vls() {
        for vl in [128, 256, 512, 1024, 2048] {
            for n in [1usize, 2, 3, 7, 64, 129] {
                let mut mem = Memory::new();
                let (k, want) = build_list(&mut mem, n, 42 + n as u64);
                let c = compile_chase(&k, Target::Sve, true);
                assert!(c.vectorized);
                let mut ex = Executor::new(vl, mem);
                ex.run(&c.program, 10_000_000).unwrap();
                assert_eq!(
                    ex.mem.read_u64(k.result).unwrap(),
                    want,
                    "vl={vl} n={n} (Fig. 6 semantics)"
                );
            }
        }
    }

    #[test]
    fn sve_chase_unforced_stays_scalar() {
        let mut mem = Memory::new();
        let (k, want) = build_list(&mut mem, 50, 7);
        let c = compile_chase(&k, Target::Sve, false);
        assert!(!c.vectorized);
        assert!(c.why_not.as_deref().unwrap().contains("not profitable"));
        let mut ex = Executor::new(256, mem);
        ex.run(&c.program, 1_000_000).unwrap();
        assert_eq!(ex.mem.read_u64(k.result).unwrap(), want);
    }
}
