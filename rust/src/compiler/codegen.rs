//! Code generation: one IR [`Kernel`] -> a complete [`Program`] for the
//! scalar, NEON or SVE target.
//!
//! Register conventions (shared by all targets):
//!
//! | regs        | use                                        |
//! |-------------|--------------------------------------------|
//! | x0–x7       | integer expression stack                   |
//! | x8–x15      | array base registers (one per array)       |
//! | x16–x18     | integer reduction accumulators             |
//! | x19         | stride/scale scratch                       |
//! | x20 / x21   | induction variable / trip count            |
//! | x22–x23     | address scratch                            |
//! | x25–x27     | outer-dimension counters                   |
//! | d0–d7/z0–z7 | FP/vector expression stack                 |
//! | z8–z14      | cached constants (splatted for vectors)    |
//! | z15         | gather index scratch                       |
//! | z16–z19     | vector reduction accumulators              |
//! | z20–z23     | lane-index helper vectors (per stride)     |
//! | d24–d27     | scalar FP reduction accumulators           |
//! | z28–z31     | per-iteration locals                       |
//! | p0          | governing predicate (whilelt)              |
//! | p1–p3       | condition predicate stack                  |
//! | p4 / p5     | first-fault partition / break partition    |
//! | p6          | all-true (epilogue reductions)             |

use super::ir::*;
use crate::arch::{Cond, Esize};
use crate::asm::Asm;
use crate::isa::{FpOp, FpUnOp, Inst, MemOff, PLogicOp, RegOrImm};

/// Base register of array `arr`.
#[allow(non_snake_case)]
pub(crate) fn BASE_REG(arr: usize) -> u8 {
    BASE0 + arr as u8
}

/// Integer reduction accumulator register.
#[allow(non_snake_case)]
pub(crate) fn XACC_REG(r: u8) -> u8 {
    XACC + r
}

pub const IV: u8 = 20;
pub const TRIP: u8 = 21;
pub const SCR: u8 = 22;
pub const SCR2: u8 = 23;
pub const SCALE: u8 = 19;
const XSTACK: u8 = 0; // x0..x7
const XACC: u8 = 16; // x16..x18
const BASE0: u8 = 8; // x8..x15
const OUTER0: u8 = 25; // x25..x27
const CONST0: u8 = 8; // d8/z8..z14
const VACC: u8 = 16; // z16..z19
const LANE0: u8 = 20; // z20..z23
const FACC: u8 = 24; // d24..d27
const LOCAL0: u8 = 28; // z28..z31 / d28..d31

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Target {
    Scalar,
    Neon,
    Sve,
}

/// Scalar value: FP register or integer register.
#[derive(Clone, Copy, Debug, PartialEq)]
enum SVal {
    D(u8),
    X(u8),
}

pub struct Cg<'k> {
    pub(super) k: &'k Kernel,
    pub asm: Asm,
    label_n: usize,
    /// cached f64/f32 constants: bit pattern -> register 8..=14
    consts: Vec<u64>,
    /// lane-helper scales -> z20+slot
    scales: Vec<i64>,
    target: Target,
    /// local slot types
    local_ty: Vec<Ty>,
    /// when set, emit_*_iter uses this body instead of `k.body` (the SVE
    /// break path re-emits only the stores under the partition)
    body_override: Option<Vec<Stmt>>,
}

fn esize_of(ty: Ty) -> Esize {
    match ty {
        Ty::F64 | Ty::I64 => Esize::D,
        Ty::F32 | Ty::I32 => Esize::S,
        Ty::U8 => Esize::B,
    }
}

fn log2(b: usize) -> u8 {
    b.trailing_zeros() as u8
}

impl<'k> Cg<'k> {
    pub fn new(k: &'k Kernel, target: Target) -> Self {
        let mut cg = Cg {
            k,
            asm: Asm::new(),
            label_n: 0,
            consts: vec![],
            scales: vec![],
            target,
            local_ty: vec![],
            body_override: None,
        };
        cg.collect_consts_scales();
        cg.local_ty = k.locals.iter().map(|e| cg.ty_of(e)).collect();
        assert!(k.locals.len() <= 4, "max 4 locals");
        assert!(k.arrays.len() <= 8, "max 8 arrays");
        assert!(k.outer.len() <= 3, "max 3 outer dims");
        assert!(k.reductions.len() <= 3, "max 3 reductions");
        cg
    }

    pub(super) fn fresh(&mut self, p: &str) -> String {
        self.label_n += 1;
        format!("{p}_{}", self.label_n)
    }

    // ------------------------------------------------- analysis helpers

    pub(super) fn ty_of(&self, e: &Expr) -> Ty {
        match e {
            Expr::ConstF(_) => {
                if self.k.elem_ty == Ty::F32 {
                    Ty::F32
                } else {
                    Ty::F64
                }
            }
            Expr::ConstI(_) | Expr::Iv => Ty::I64,
            Expr::IvAsF => {
                if self.k.elem_ty == Ty::F32 {
                    Ty::F32
                } else {
                    Ty::F64
                }
            }
            Expr::Load { arr, .. } => self.k.arrays[*arr].ty,
            Expr::Bin { a, .. } | Expr::Un { a, .. } => self.ty_of(a),
            Expr::Cmp { a, .. } => self.ty_of(a),
            Expr::Select { t, .. } => self.ty_of(t),
            Expr::Opaque { .. } => Ty::F64,
            Expr::Fma { a, .. } => self.ty_of(a),
            Expr::ComplexMul { a_arr, .. } => self.k.arrays[*a_arr].ty,
            Expr::Local(i) => self.local_ty.get(*i).copied().unwrap_or(self.k.elem_ty),
        }
    }

    fn collect_consts_scales(&mut self) {
        let dbl = self.k.elem_ty != Ty::F32;
        let mut consts = vec![];
        let mut scales: Vec<i64> = vec![];
        let mut need_lane1 = false;
        for e in self.k.all_exprs() {
            e.visit(&mut |n| match n {
                Expr::ConstF(v) => {
                    let bits = if dbl { v.to_bits() } else { (*v as f32).to_bits() as u64 };
                    if !consts.contains(&bits) && consts.len() < 7 {
                        consts.push(bits);
                    }
                }
                Expr::Load { idx: Index::Strided { scale, .. }, .. } => {
                    if !scales.contains(scale) {
                        scales.push(*scale);
                    }
                }
                Expr::Iv | Expr::IvAsF => need_lane1 = true,
                _ => {}
            });
        }
        for s in &self.k.body {
            if let Stmt::Store { idx: Index::Strided { scale, .. }, .. } = s {
                if !scales.contains(scale) {
                    scales.push(*scale);
                }
            }
        }
        if need_lane1 && !scales.contains(&1) {
            scales.push(1);
        }
        assert!(scales.len() <= 4, "max 4 distinct strides");
        self.consts = consts;
        self.scales = scales;
    }

    pub(super) fn const_reg(&self, bits: u64) -> Option<u8> {
        self.consts.iter().position(|&b| b == bits).map(|i| CONST0 + i as u8)
    }

    pub(super) fn scale_slot(&self, scale: i64) -> u8 {
        LANE0 + self.scales.iter().position(|&s| s == scale).expect("scale collected") as u8
    }

    pub(super) fn dbl(&self) -> bool {
        self.k.elem_ty != Ty::F32
    }

    pub(super) fn elem_esize(&self) -> Esize {
        esize_of(self.k.elem_ty)
    }

    // ------------------------------------------------- common scaffolding

    /// Prologue: array bases, constants, reduction init, lane helpers.
    pub fn prologue(&mut self) {
        let dbl = self.dbl();
        for (i, a) in self.k.arrays.iter().enumerate() {
            self.asm.push(Inst::MovImm { xd: BASE0 + i as u8, imm: a.base });
        }
        for (i, &bits) in self.consts.clone().iter().enumerate() {
            let dd = CONST0 + i as u8;
            self.asm.push(Inst::FmovImm { dbl, dd, bits });
            match self.target {
                Target::Neon => {
                    self.asm.push(Inst::NeonDupLane0 { esize: self.elem_esize(), vd: dd, vn: dd });
                }
                Target::Sve => {
                    self.asm.push(Inst::FdupImm { zd: dd, dbl, bits });
                }
                Target::Scalar => {}
            }
        }
        if self.target == Target::Sve {
            for (i, &scale) in self.scales.clone().iter().enumerate() {
                self.asm.push(Inst::Index {
                    zd: LANE0 + i as u8,
                    esize: self.elem_esize(),
                    base: RegOrImm::Imm(0),
                    step: RegOrImm::Imm(scale),
                });
            }
            self.asm.push(Inst::Ptrue { pd: 6, esize: self.elem_esize(), s: false });
        }
        // reduction accumulators
        for (r, red) in self.k.reductions.iter().enumerate() {
            let r = r as u8;
            match red.kind {
                RedKind::XorI => {
                    self.asm.push(Inst::MovImm { xd: XACC + r, imm: 0 });
                }
                RedKind::SumF | RedKind::OrderedSumF | RedKind::DotF => {
                    self.asm.push(Inst::FmovImm { dbl, dd: FACC + r, bits: 0 });
                }
                RedKind::MaxF => {
                    let bits = if dbl {
                        f64::NEG_INFINITY.to_bits()
                    } else {
                        f32::NEG_INFINITY.to_bits() as u64
                    };
                    self.asm.push(Inst::FmovImm { dbl, dd: FACC + r, bits });
                }
            }
            if self.target != Target::Scalar {
                // vector accumulators
                match red.kind {
                    RedKind::XorI => {
                        self.asm.push(Inst::DupImm {
                            zd: VACC + r,
                            esize: self.elem_esize(),
                            imm: 0,
                        });
                    }
                    RedKind::SumF | RedKind::DotF => {
                        self.asm.push(Inst::FdupImm { zd: VACC + r, dbl, bits: 0 });
                    }
                    RedKind::MaxF => {
                        let bits = if dbl {
                            f64::NEG_INFINITY.to_bits()
                        } else {
                            f32::NEG_INFINITY.to_bits() as u64
                        };
                        self.asm.push(Inst::FdupImm { zd: VACC + r, dbl, bits });
                    }
                    RedKind::OrderedSumF => {} // accumulates in d-reg via fadda
                }
            }
        }
    }

    /// Open outer loops and (re)compute effective base registers.
    pub fn open_outer(&mut self) -> Vec<String> {
        let mut labels = vec![];
        let outer = self.k.outer.clone();
        for (d, _) in outer.iter().enumerate() {
            let l = self.fresh("outer");
            self.asm.push(Inst::MovImm { xd: OUTER0 + d as u8, imm: 0 });
            self.asm.label(&l);
            labels.push(l);
        }
        // effective bases: base + sum_d counter_d * stride * esz
        for (i, a) in self.k.arrays.clone().iter().enumerate() {
            let breg = BASE0 + i as u8;
            let mut needed = false;
            for dim in &outer {
                if dim.strides.iter().any(|(arr, _)| *arr == i) {
                    needed = true;
                }
            }
            if !needed {
                continue;
            }
            self.asm.push(Inst::MovImm { xd: breg, imm: a.base });
            for (d, dim) in outer.iter().enumerate() {
                for &(arr, stride) in &dim.strides {
                    if arr == i {
                        let bytes = stride * a.ty.bytes() as i64;
                        self.asm.push(Inst::MovImm { xd: SCR2, imm: bytes as u64 });
                        self.asm.push(Inst::Madd {
                            xd: breg,
                            xn: OUTER0 + d as u8,
                            xm: SCR2,
                            xa: breg,
                        });
                    }
                }
            }
        }
        labels
    }

    /// Close outer loops (reverse order).
    pub fn close_outer(&mut self, labels: Vec<String>) {
        let outer = self.k.outer.clone();
        for (d, dim) in outer.iter().enumerate().rev() {
            let c = OUTER0 + d as u8;
            self.asm.push(Inst::AddImm { xd: c, xn: c, imm: 1 });
            self.asm.push(Inst::CmpImm { xn: c, imm: dim.trip });
            self.asm
                .push_branch(Inst::BCond { cond: Cond::Lo, target: 0 }, &labels[d]);
        }
    }

    /// Final stores of reduction results / count.
    pub fn epilogue_outputs(&mut self) {
        let dbl = self.dbl();
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            let addr = self.k.red_out[r];
            let r = r as u8;
            self.asm.push(Inst::MovImm { xd: SCR, imm: addr });
            match red.kind {
                RedKind::XorI => {
                    self.asm.push(Inst::Str {
                        size: 8,
                        xt: XACC + r,
                        base: SCR,
                        off: MemOff::Imm(0),
                    })
                }
                _ => self.asm.push(Inst::StrFp {
                    dbl,
                    vt: FACC + r,
                    base: SCR,
                    off: MemOff::Imm(0),
                }),
            };
        }
        if let Some(addr) = self.k.count_out {
            self.asm.push(Inst::MovImm { xd: SCR, imm: addr });
            self.asm.push(Inst::Str { size: 8, xt: IV, base: SCR, off: MemOff::Imm(0) });
        }
        self.asm.push(Inst::Halt);
    }

    /// Effective base register for (array, element offset): emits an add
    /// into SCR2 when offset != 0 and returns the register to use.
    pub(super) fn base_with_offset(&mut self, arr: usize, offset: i64) -> u8 {
        let breg = BASE0 + arr as u8;
        if offset == 0 {
            breg
        } else {
            let bytes = offset * self.k.arrays[arr].ty.bytes() as i64;
            self.asm.push(Inst::AddImm { xd: SCR2, xn: breg, imm: bytes });
            SCR2
        }
    }

    // =================================================================
    // scalar target (also: NEON tail loops)
    // =================================================================

    /// Evaluate `e` for iteration `IV`, returning the value's register.
    /// `ft`/`it` are the next free FP / int stack slots.
    fn ev_scalar(&mut self, e: &Expr, ft: u8, it: u8) -> SVal {
        assert!(ft < 8 && it < 8, "scalar expression stack overflow");
        let dbl = self.dbl();
        match e {
            Expr::ConstF(v) => {
                let bits = if dbl { v.to_bits() } else { (*v as f32).to_bits() as u64 };
                if let Some(r) = self.const_reg(bits) {
                    SVal::D(r)
                } else {
                    self.asm.push(Inst::FmovImm { dbl, dd: ft, bits });
                    SVal::D(ft)
                }
            }
            Expr::ConstI(v) => {
                self.asm.push(Inst::MovImm { xd: XSTACK + it, imm: *v as u64 });
                SVal::X(XSTACK + it)
            }
            Expr::Iv => {
                self.asm.push(Inst::MovReg { xd: XSTACK + it, xn: IV });
                SVal::X(XSTACK + it)
            }
            Expr::IvAsF => {
                self.asm.push(Inst::Scvtf { dbl, dd: ft, xn: IV });
                SVal::D(ft)
            }
            Expr::Local(i) => {
                if self.local_ty[*i].is_fp() {
                    SVal::D(LOCAL0 + *i as u8)
                } else {
                    SVal::X(XACC + 3 + *i as u8) // unreachable in practice
                }
            }
            Expr::Load { arr, idx } => {
                let ty = self.k.arrays[*arr].ty;
                let esz = ty.bytes();
                let (base, off) = self.scalar_addr(*arr, *idx);
                match ty {
                    Ty::F64 => {
                        self.asm.push(Inst::LdrFp { dbl: true, vt: ft, base, off });
                        SVal::D(ft)
                    }
                    Ty::F32 => {
                        self.asm.push(Inst::LdrFp { dbl: false, vt: ft, base, off });
                        SVal::D(ft)
                    }
                    _ => {
                        self.asm.push(Inst::Ldr {
                            size: esz as u8,
                            signed: false,
                            xt: XSTACK + it,
                            base,
                            off,
                        });
                        SVal::X(XSTACK + it)
                    }
                }
            }
            Expr::Bin { op, a, b } => {
                let ra = self.ev_scalar_into(a, ft, it);
                match ra {
                    SVal::D(_) => {
                        let rb = match self.ev_scalar(b, ft + 1, it) {
                            SVal::D(r) => r,
                            SVal::X(_) => panic!("mixed int/fp binop"),
                        };
                        let fpop = match op {
                            BinOp::Add => FpOp::Add,
                            BinOp::Sub => FpOp::Sub,
                            BinOp::Mul => FpOp::Mul,
                            BinOp::Div => FpOp::Div,
                            BinOp::Max => FpOp::Max,
                            BinOp::Min => FpOp::Min,
                            _ => panic!("bitwise op on fp"),
                        };
                        self.asm.push(Inst::FpBin { op: fpop, dbl, dd: ft, dn: ft, dm: rb });
                        SVal::D(ft)
                    }
                    SVal::X(_) => {
                        let rb = match self.ev_scalar(b, ft, it + 1) {
                            SVal::X(r) => r,
                            SVal::D(_) => panic!("mixed int/fp binop"),
                        };
                        let xd = XSTACK + it;
                        match op {
                            BinOp::Add => {
                                self.asm.push(Inst::AddReg { xd, xn: xd, xm: rb, lsl: 0 })
                            }
                            BinOp::Sub => self.asm.push(Inst::SubReg { xd, xn: xd, xm: rb }),
                            BinOp::Mul => self.asm.push(Inst::Madd { xd, xn: xd, xm: rb, xa: 31 }),
                            BinOp::Xor => {
                                self.asm.push(Inst::LogReg {
                                    op: PLogicOp::Eor,
                                    xd,
                                    xn: xd,
                                    xm: rb,
                                })
                            }
                            BinOp::And => {
                                self.asm.push(Inst::LogReg {
                                    op: PLogicOp::And,
                                    xd,
                                    xn: xd,
                                    xm: rb,
                                })
                            }
                            BinOp::Or => {
                                self.asm.push(Inst::LogReg {
                                    op: PLogicOp::Orr,
                                    xd,
                                    xn: xd,
                                    xm: rb,
                                })
                            }
                            _ => panic!("fp op on ints"),
                        };
                        SVal::X(xd)
                    }
                }
            }
            Expr::Un { op, a } => {
                let ra = self.ev_scalar_into(a, ft, it);
                let SVal::D(_) = ra else { panic!("unary on int") };
                let fop = match op {
                    UnOp::Neg => FpUnOp::Neg,
                    UnOp::Abs => FpUnOp::Abs,
                    UnOp::Sqrt => FpUnOp::Sqrt,
                };
                self.asm.push(Inst::FpUn { op: fop, dbl, dd: ft, dn: ft });
                SVal::D(ft)
            }
            Expr::Select { c, t, f } => {
                let rt = self.ev_scalar_into(t, ft, it);
                match rt {
                    SVal::D(_) => {
                        let rf = match self.ev_scalar(f, ft + 1, it) {
                            SVal::D(r) => r,
                            _ => panic!("mixed select"),
                        };
                        let cond = self.ev_scalar_cond(c, ft + 2, it);
                        // keep rt if cond; else copy rf over
                        let skip = self.fresh("sel");
                        self.asm.push_branch(Inst::BCond { cond, target: 0 }, &skip);
                        self.asm.push(Inst::FmovReg { dbl, dd: ft, dn: rf });
                        self.asm.label(&skip);
                        SVal::D(ft)
                    }
                    SVal::X(xt) => {
                        let rf = match self.ev_scalar(f, ft, it + 1) {
                            SVal::X(r) => r,
                            _ => panic!("mixed select"),
                        };
                        let cond = self.ev_scalar_cond(c, ft, it + 2);
                        self.asm.push(Inst::Csel { xd: xt, xn: xt, xm: rf, cond });
                        SVal::X(xt)
                    }
                }
            }
            Expr::Opaque { f, args } => {
                let a0 = match self.ev_scalar_into(&args[0], ft, it) {
                    SVal::D(r) => r,
                    _ => panic!("opaque on int"),
                };
                let a1 = args.get(1).map(|a| match self.ev_scalar(a, ft + 1, it) {
                    SVal::D(r) => r,
                    _ => panic!("opaque on int"),
                });
                self.asm.push(Inst::OpaqueCall { f: *f, dd: ft, dn: a0, dm: a1 });
                SVal::D(ft)
            }
            Expr::Fma { a, b, acc, sub } => {
                // unfused: the product rounds, then the add — the exact
                // semantics of the executor's Fmadd (and of NeonFmla /
                // SveFmla), so all targets agree bit-for-bit.
                let SVal::D(_) = self.ev_scalar_into(a, ft, it) else {
                    panic!("fma on int")
                };
                let SVal::D(rb) = self.ev_scalar(b, ft + 1, it) else {
                    panic!("fma on int")
                };
                let SVal::D(racc) = self.ev_scalar(acc, ft + 2, it) else {
                    panic!("fma on int")
                };
                self.asm.push(Inst::Fmadd { dbl, dd: ft, dn: ft, dm: rb, da: racc, sub: *sub });
                SVal::D(ft)
            }
            Expr::ComplexMul { a_arr, a_off, b_arr, b_off, conj } => {
                // one lane of an interleaved-complex product: pair base
                // p = iv & !1; even iv produces the real part, odd iv the
                // imaginary part, each as one mul + one unfused fmadd —
                // the same rounding sequence every target performs.
                assert!(ft + 3 < 8, "scalar expression stack overflow");
                let (a_arr, b_arr) = (*a_arr, *b_arr);
                let lg = log2(self.k.arrays[a_arr].ty.bytes());
                self.asm.push(Inst::AndImm { xd: SCR, xn: IV, imm: !1 });
                for (slot, (arr, off)) in [
                    (a_arr, *a_off),
                    (a_arr, *a_off + 1),
                    (b_arr, *b_off),
                    (b_arr, *b_off + 1),
                ]
                .into_iter()
                .enumerate()
                {
                    let base = self.base_with_offset(arr, off);
                    self.asm.push(Inst::LdrFp {
                        dbl,
                        vt: ft + slot as u8,
                        base,
                        off: MemOff::RegLsl(SCR, lg),
                    });
                }
                // ft=ar ft+1=ai ft+2=br ft+3=bi
                self.asm.push(Inst::AndImm { xd: XSTACK + it, xn: IV, imm: 1 });
                self.asm.push(Inst::CmpImm { xn: XSTACK + it, imm: 0 });
                let odd = self.fresh("codd");
                let done = self.fresh("cdone");
                self.asm.push_branch(Inst::BCond { cond: Cond::Ne, target: 0 }, &odd);
                // even: re = ar*br -/+ ai*bi
                self.asm.push(Inst::FpBin { op: FpOp::Mul, dbl, dd: ft, dn: ft, dm: ft + 2 });
                self.asm.push(Inst::Fmadd {
                    dbl,
                    dd: ft,
                    dn: ft + 1,
                    dm: ft + 3,
                    da: ft,
                    sub: !*conj,
                });
                self.asm.push_branch(Inst::B { target: 0 }, &done);
                self.asm.label(&odd);
                // odd: im = ar*bi +/- ai*br
                self.asm.push(Inst::FpBin { op: FpOp::Mul, dbl, dd: ft, dn: ft, dm: ft + 3 });
                self.asm.push(Inst::Fmadd {
                    dbl,
                    dd: ft,
                    dn: ft + 1,
                    dm: ft + 2,
                    da: ft,
                    sub: *conj,
                });
                self.asm.label(&done);
                SVal::D(ft)
            }
            Expr::Cmp { .. } => panic!("bare Cmp outside Select/Break"),
        }
    }

    /// Evaluate and force the result into stack slot `ft`/`it` so
    /// destructive ops cannot clobber locals/constants.
    fn ev_scalar_into(&mut self, e: &Expr, ft: u8, it: u8) -> SVal {
        let v = self.ev_scalar(e, ft, it);
        match v {
            SVal::D(r) if r != ft => {
                self.asm.push(Inst::FmovReg { dbl: self.dbl(), dd: ft, dn: r });
                SVal::D(ft)
            }
            SVal::X(r) if r != XSTACK + it => {
                self.asm.push(Inst::MovReg { xd: XSTACK + it, xn: r });
                SVal::X(XSTACK + it)
            }
            v => v,
        }
    }

    /// Evaluate a comparison to the NZCV flags, returning the branch
    /// condition that means "true".
    fn ev_scalar_cond(&mut self, e: &Expr, ft: u8, it: u8) -> Cond {
        let Expr::Cmp { op, a, b } = e else { panic!("condition must be Cmp") };
        let ra = self.ev_scalar(a, ft, it);
        match ra {
            SVal::D(da) => {
                let db = match self.ev_scalar(b, ft + 1, it) {
                    SVal::D(r) => r,
                    _ => panic!("mixed cmp"),
                };
                self.asm.push(Inst::Fcmp { dbl: self.dbl(), dn: da, dm: db });
                match op {
                    CmpKind::Eq => Cond::Eq,
                    CmpKind::Ne => Cond::Ne,
                    CmpKind::Gt => Cond::Gt,
                    CmpKind::Ge => Cond::Ge,
                    CmpKind::Lt => Cond::Mi,
                    CmpKind::Le => Cond::Ls,
                }
            }
            SVal::X(xa) => {
                let xb = match self.ev_scalar(b, ft, it + 1) {
                    SVal::X(r) => r,
                    _ => panic!("mixed cmp"),
                };
                self.asm.push(Inst::CmpReg { xn: xa, xm: xb });
                match op {
                    CmpKind::Eq => Cond::Eq,
                    CmpKind::Ne => Cond::Ne,
                    CmpKind::Gt => Cond::Gt,
                    CmpKind::Ge => Cond::Ge,
                    CmpKind::Lt => Cond::Lt,
                    CmpKind::Le => Cond::Le,
                }
            }
        }
    }

    /// Address operand for a scalar access at iteration IV.
    fn scalar_addr(&mut self, arr: usize, idx: Index) -> (u8, MemOff) {
        let esz = self.k.arrays[arr].ty.bytes();
        match idx {
            Index::Affine { offset } => {
                let base = self.base_with_offset(arr, offset);
                (base, MemOff::RegLsl(IV, log2(esz)))
            }
            Index::Strided { scale, offset } => {
                self.asm.push(Inst::MovImm { xd: SCALE, imm: scale as u64 });
                self.asm.push(Inst::Madd { xd: SCR, xn: IV, xm: SCALE, xa: 31 });
                let base = self.base_with_offset(arr, offset);
                (base, MemOff::RegLsl(SCR, log2(esz)))
            }
            Index::Indirect { idx_arr, offset } => {
                let ity = self.k.arrays[idx_arr].ty;
                self.asm.push(Inst::Ldr {
                    size: ity.bytes() as u8,
                    signed: false,
                    xt: SCR,
                    base: BASE0 + idx_arr as u8,
                    off: MemOff::RegLsl(IV, log2(ity.bytes())),
                });
                let base = self.base_with_offset(arr, offset);
                (base, MemOff::RegLsl(SCR, log2(esz)))
            }
        }
    }

    /// One scalar iteration: locals, body, reductions. `exit` is the
    /// label Break jumps to.
    pub fn emit_scalar_iter(&mut self, exit: &str) {
        let dbl = self.dbl();
        for (i, l) in self.k.locals.clone().iter().enumerate() {
            let v = self.ev_scalar(l, 0, 0);
            match v {
                SVal::D(r) => self.asm.push(Inst::FmovReg { dbl, dd: LOCAL0 + i as u8, dn: r }),
                SVal::X(_) => panic!("int locals unsupported"),
            };
        }
        for s in self.body() {
            match s {
                Stmt::Store { arr, idx, value } => {
                    let v = self.ev_scalar(&value, 0, 0);
                    let ty = self.k.arrays[arr].ty;
                    let (base, off) = self.scalar_addr(arr, idx);
                    match v {
                        SVal::D(r) => {
                            self.asm.push(Inst::StrFp { dbl: ty == Ty::F64, vt: r, base, off })
                        }
                        SVal::X(r) => self.asm.push(Inst::Str {
                            size: ty.bytes() as u8,
                            xt: r,
                            base,
                            off,
                        }),
                    };
                }
                Stmt::Break { cond } => {
                    let c = self.ev_scalar_cond(&cond, 0, 0);
                    self.asm.push_branch(Inst::BCond { cond: c, target: 0 }, exit);
                }
            }
        }
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            let r = r as u8;
            if red.kind == RedKind::DotF {
                // dot-product reduction: one unfused fmadd per element
                // instead of mul + add — numerically identical to SumF
                // over the same product.
                let Expr::Bin { op: BinOp::Mul, a, b } = &red.value else {
                    panic!("DotF value must be a product")
                };
                let SVal::D(_) = self.ev_scalar_into(a, 0, 0) else {
                    panic!("DotF on int")
                };
                let SVal::D(rb) = self.ev_scalar(b, 1, 0) else { panic!("DotF on int") };
                self.asm.push(Inst::Fmadd {
                    dbl,
                    dd: FACC + r,
                    dn: 0,
                    dm: rb,
                    da: FACC + r,
                    sub: false,
                });
                continue;
            }
            let v = self.ev_scalar(&red.value, 0, 0);
            match (red.kind, v) {
                (RedKind::XorI, SVal::X(x)) => self.asm.push(Inst::LogReg {
                    op: PLogicOp::Eor,
                    xd: XACC + r,
                    xn: XACC + r,
                    xm: x,
                }),
                (RedKind::SumF | RedKind::OrderedSumF, SVal::D(d)) => self.asm.push(Inst::FpBin {
                    op: FpOp::Add,
                    dbl,
                    dd: FACC + r,
                    dn: FACC + r,
                    dm: d,
                }),
                (RedKind::MaxF, SVal::D(d)) => self.asm.push(Inst::FpBin {
                    op: FpOp::Max,
                    dbl,
                    dd: FACC + r,
                    dn: FACC + r,
                    dm: d,
                }),
                _ => panic!("reduction type mismatch"),
            };
        }
    }

    /// Install a body override (used by the SVE break-loop path to
    /// re-emit only the stores); `None` restores the kernel body.
    pub(super) fn set_body_override(&mut self, body: Option<Vec<Stmt>>) {
        self.body_override = body;
    }

    /// Effective loop body (override or the kernel's).
    pub(super) fn body(&self) -> Vec<Stmt> {
        self.body_override.clone().unwrap_or_else(|| self.k.body.clone())
    }

    /// Complete scalar loop (used by the Scalar target and NEON tails).
    /// Iterates IV from its current value to TRIP (or until Break).
    pub fn emit_scalar_loop(&mut self) {
        let lloop = self.fresh("sloop");
        let latch = self.fresh("slatch");
        let exit = self.fresh("sexit");
        match self.k.trip {
            Trip::Count(_) => {
                self.asm.push_branch(Inst::B { target: 0 }, &latch);
                self.asm.label(&lloop);
                self.emit_scalar_iter(&exit);
                self.asm.push(Inst::AddImm { xd: IV, xn: IV, imm: 1 });
                self.asm.label(&latch);
                self.asm.push(Inst::CmpReg { xn: IV, xm: TRIP });
                self.asm.push_branch(Inst::BCond { cond: Cond::Lt, target: 0 }, &lloop);
                self.asm.label(&exit);
                self.asm.push(Inst::Nop);
            }
            Trip::DataDependent { .. } => {
                self.asm.label(&lloop);
                self.emit_scalar_iter(&exit);
                self.asm.push(Inst::AddImm { xd: IV, xn: IV, imm: 1 });
                self.asm.push_branch(Inst::B { target: 0 }, &lloop);
                self.asm.label(&exit);
                self.asm.push(Inst::Nop);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Program;
    use crate::exec::Executor;
    use crate::mem::Memory;

    fn compile_scalar(k: &Kernel) -> Program {
        let mut cg = Cg::new(k, Target::Scalar);
        cg.prologue();
        let outer = cg.open_outer();
        cg.asm.push(Inst::MovImm { xd: IV, imm: 0 });
        if let Trip::Count(n) = k.trip {
            cg.asm.push(Inst::MovImm { xd: TRIP, imm: n });
        }
        cg.emit_scalar_loop();
        cg.close_outer(outer);
        cg.epilogue_outputs();
        cg.asm.finish()
    }

    #[test]
    fn scalar_daxpy_from_ir() {
        let n = 37;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let yb = mem.alloc(8 * n, 16);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, i as f64).unwrap();
            mem.write_f64(yb + 8 * i, 2.0 * i as f64).unwrap();
        }
        let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        let y = k.array("y", Ty::F64, yb);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Add,
                Expr::bin(
                    BinOp::Mul,
                    Expr::ConstF(3.0),
                    Expr::load(x, Index::Affine { offset: 0 }),
                ),
                Expr::load(y, Index::Affine { offset: 0 }),
            ),
        });
        let p = compile_scalar(&k);
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        for i in 0..n {
            assert_eq!(
                ex.mem.read_f64(yb + 8 * i).unwrap(),
                3.0 * i as f64 + 2.0 * i as f64,
                "y[{i}]"
            );
        }
    }

    #[test]
    fn scalar_select_and_reduction() {
        // sum of max(x[i], 1.0) over i<n, with a conditional assignment
        let n = 16;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let out = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, i as f64 - 8.0).unwrap();
        }
        let mut k = Kernel::new("condsum", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        k.red_out = vec![out];
        let xi = Expr::load(x, Index::Affine { offset: 0 });
        k.reductions.push(Reduction {
            kind: RedKind::SumF,
            value: Expr::select(
                Expr::cmp(CmpKind::Gt, xi.clone(), Expr::ConstF(1.0)),
                xi,
                Expr::ConstF(1.0),
            ),
        });
        let p = compile_scalar(&k);
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        let want: f64 = (0..n).map(|i| (i as f64 - 8.0).max(1.0)).sum();
        assert_eq!(ex.mem.read_f64(out).unwrap(), want);
    }

    #[test]
    fn scalar_break_strlen() {
        let mut mem = Memory::new();
        let sb = mem.alloc(64, 16);
        let out = mem.alloc(8, 8);
        let msg = b"hello, sve";
        for (i, &b) in msg.iter().enumerate() {
            mem.write_byte(sb + i as u64, b).unwrap();
        }
        mem.write_byte(sb + msg.len() as u64, 0).unwrap();
        let mut k = Kernel::new("strlen", Ty::U8, Trip::DataDependent { max: 1 << 20 });
        let s = k.array("s", Ty::U8, sb);
        k.count_out = Some(out);
        k.body.push(Stmt::Break {
            cond: Expr::cmp(
                CmpKind::Eq,
                Expr::load(s, Index::Affine { offset: 0 }),
                Expr::ConstI(0),
            ),
        });
        let p = compile_scalar(&k);
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        assert_eq!(ex.mem.read_u64(out).unwrap(), msg.len() as u64);
    }

    #[test]
    fn scalar_outer_dims_advance_bases() {
        // out[j] = sum_i a[j*4 + i] over a 3x4 matrix, via outer dim
        let mut mem = Memory::new();
        let ab = mem.alloc(8 * 12, 16);
        let ob = mem.alloc(8 * 3, 16);
        for i in 0..12 {
            mem.write_f64(ab + 8 * i, i as f64).unwrap();
        }
        let mut k = Kernel::new("rowsum", Ty::F64, Trip::Count(4));
        let a = k.array("a", Ty::F64, ab);
        let o = k.array("o", Ty::F64, ob);
        k.outer.push(OuterDim { trip: 3, strides: vec![(a, 4), (o, 1)] });
        // o[0] += not expressible; instead store a[i] + a[i] to o... use
        // store of per-row accumulation via strided store: simpler: store
        // running element o[0_of_row] = a[3] (last element) — use store at
        // Affine offset 0 with iv... we store a[i] into o[0] when i==3 is
        // awkward; instead just store a[i]*2 into o row base + 0 each iter
        // (last write wins = a[3]*2 per row).
        k.body.push(Stmt::Store {
            arr: o,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Mul,
                Expr::load(a, Index::Affine { offset: 0 }),
                Expr::ConstF(2.0),
            ),
        });
        // o is indexed by iv too: o[i] would run off; limit: o stride 1 per
        // row, iv 0..4 writes o[row+i]: rows overlap — we only check row
        // bases below.
        let p = compile_scalar(&k);
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        // row r base = ob + 8r; its last write is a[4r+?]... iv runs 0..4
        // so o[r + i] = 2*a[4r + i]; final value at o[2] written by row 2
        // iv 0 = 2*a[8] = 16
        assert_eq!(ex.mem.read_f64(ob + 16).unwrap(), 16.0);
    }

    #[test]
    fn scalar_strided_and_indirect() {
        let mut mem = Memory::new();
        let ab = mem.alloc(8 * 16, 16);
        let ib = mem.alloc(8 * 4, 16);
        let ob = mem.alloc(8 * 4, 16);
        for i in 0..16 {
            mem.write_f64(ab + 8 * i, 10.0 * i as f64).unwrap();
        }
        mem.write_u64_slice(ib, &[7, 0, 3, 2]);
        let mut k = Kernel::new("gather", Ty::F64, Trip::Count(4));
        let a = k.array("a", Ty::F64, ab);
        let idx = k.array("idx", Ty::I64, ib);
        let o = k.array("o", Ty::F64, ob);
        // o[i] = a[2i] + a[idx[i]]
        k.body.push(Stmt::Store {
            arr: o,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Add,
                Expr::load(a, Index::Strided { scale: 2, offset: 0 }),
                Expr::load(a, Index::Indirect { idx_arr: idx, offset: 0 }),
            ),
        });
        let p = compile_scalar(&k);
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        let want = [0.0 + 70.0, 20.0 + 0.0, 40.0 + 30.0, 60.0 + 20.0];
        for i in 0..4 {
            assert_eq!(ex.mem.read_f64(ob + 8 * i).unwrap(), want[i as usize], "o[{i}]");
        }
    }
}
