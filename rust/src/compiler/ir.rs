//! Loop IR — the post-frontend form our auto-vectorizer consumes (§3).
//!
//! One [`Kernel`] describes an innermost loop (plus rectangular outer
//! dimensions that only adjust array bases), exactly the unit an
//! LLVM-style loop vectorizer operates on. Array bases are bound to
//! simulated-memory addresses at construction, so code generation can
//! fold them into immediates — the moral equivalent of the compiler
//! knowing symbol addresses at link time.

use crate::isa::OpaqueFn;

/// Element type of an array or expression.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ty {
    F64,
    F32,
    I64,
    I32,
    U8,
}

impl Ty {
    pub fn bytes(self) -> usize {
        match self {
            Ty::F64 | Ty::I64 => 8,
            Ty::F32 | Ty::I32 => 4,
            Ty::U8 => 1,
        }
    }

    pub fn is_fp(self) -> bool {
        matches!(self, Ty::F64 | Ty::F32)
    }
}

/// An array bound to simulated memory.
#[derive(Clone, Debug)]
pub struct ArrayDecl {
    pub name: String,
    pub ty: Ty,
    pub base: u64,
}

/// How an array is indexed by the induction variable `i`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Index {
    /// `A[i + offset]` — contiguous (unit stride).
    Affine { offset: i64 },
    /// `A[i*scale + offset]`, scale > 1 — strided (SVE: gather).
    Strided { scale: i64, offset: i64 },
    /// `A[B[i] + offset]` — indirect through index array `idx` (gather).
    Indirect { idx_arr: usize, offset: i64 },
}

/// Binary arithmetic ops (typed by context).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Xor,
    And,
    Or,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Abs,
    Sqrt,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpKind {
    Eq,
    Ne,
    Gt,
    Ge,
    Lt,
    Le,
}

/// Expression tree (pure; loads are side-effect-free).
#[derive(Clone, Debug)]
pub enum Expr {
    ConstF(f64),
    ConstI(i64),
    /// Load `arrays[arr]` at `idx`.
    Load { arr: usize, idx: Index },
    Bin { op: BinOp, a: Box<Expr>, b: Box<Expr> },
    Un { op: UnOp, a: Box<Expr> },
    /// Comparison producing a boolean (predicate / mask / branch).
    Cmp { op: CmpKind, a: Box<Expr>, b: Box<Expr> },
    /// `c ? t : f` — the paper's "conditional assignment" shape.
    Select { c: Box<Expr>, t: Box<Expr>, f: Box<Expr> },
    /// Opaque libm call — never vectorizable (§5, EP).
    Opaque { f: OpaqueFn, args: Vec<Expr> },
    /// Multiply-accumulate shape `acc ± a*b`, lowered to the target's
    /// FMLA/FMLS form (scalar `Fmadd`, `NeonFmla`, `SveFmla`). All three
    /// evaluate it **unfused** — the product rounds, then the add — so
    /// results are bit-identical across targets for a fixed operand
    /// order. The reduction-of-product kernels (oneDAL, SU(3)) build
    /// their accumulator chains from this node.
    Fma { a: Box<Expr>, b: Box<Expr>, acc: Box<Expr>, sub: bool },
    /// One interleaved-complex product lane, FCMLA-style (§SU(3)).
    ///
    /// Arrays `a_arr`/`b_arr` hold complex values as interleaved
    /// `re, im` element pairs; `a_off`/`b_off` are element offsets of
    /// the operand blocks. With `p = (i & !1) + off` the pair base for
    /// iteration `i`, the value is the real part of
    /// `A[p..p+2] * B[p..p+2]` on even `i` and the imaginary part on
    /// odd `i` (`conj` conjugates the `A` operand). Evaluated as a
    /// multiply then an unfused FMLA/FMLS, identically on every target.
    ///
    /// The SVE lowering reads the `off-1`/`off`/`off+1` shifted
    /// contiguous vectors, so both neighbours of every accessed pair
    /// must be **mapped** (one guard element before and after each
    /// operand block) — the values read there never influence selected
    /// lanes.
    ComplexMul { a_arr: usize, a_off: i64, b_arr: usize, b_off: i64, conj: bool },
    /// The induction variable as a value (i64).
    Iv,
    /// Convert i64 -> fp.
    IvAsF,
    /// Reference to a per-iteration local binding (common subexpression,
    /// see [`Kernel::locals`]).
    Local(usize),
}

impl Expr {
    pub fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
        Expr::Bin { op, a: Box::new(a), b: Box::new(b) }
    }

    pub fn cmp(op: CmpKind, a: Expr, b: Expr) -> Expr {
        Expr::Cmp { op, a: Box::new(a), b: Box::new(b) }
    }

    pub fn select(c: Expr, t: Expr, f: Expr) -> Expr {
        Expr::Select { c: Box::new(c), t: Box::new(t), f: Box::new(f) }
    }

    pub fn load(arr: usize, idx: Index) -> Expr {
        Expr::Load { arr, idx }
    }

    pub fn fma(a: Expr, b: Expr, acc: Expr) -> Expr {
        Expr::Fma { a: Box::new(a), b: Box::new(b), acc: Box::new(acc), sub: false }
    }

    /// Walk the tree, calling `f` on every node.
    pub fn visit(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Bin { a, b, .. } => {
                a.visit(f);
                b.visit(f);
            }
            Expr::Un { a, .. } => a.visit(f),
            Expr::Cmp { a, b, .. } => {
                a.visit(f);
                b.visit(f);
            }
            Expr::Select { c, t, f: fe } => {
                c.visit(f);
                t.visit(f);
                fe.visit(f);
            }
            Expr::Opaque { args, .. } => {
                for a in args {
                    a.visit(f);
                }
            }
            Expr::Fma { a, b, acc, .. } => {
                a.visit(f);
                b.visit(f);
                acc.visit(f);
            }
            _ => {}
        }
    }
}

/// Reduction kinds (§2.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RedKind {
    /// FP sum; tree order allowed (fast-math, faddv).
    SumF,
    /// FP sum with source order required (fadda) — §3.3.
    OrderedSumF,
    /// Integer XOR (eorv) — Fig. 6.
    XorI,
    /// FP max (fmaxv).
    MaxF,
    /// FP dot product: the value must be `Bin { op: Mul, .. }` and the
    /// per-iteration update is one unfused FMLA into the accumulator
    /// (`acc += a*b`, product rounded first) — numerically identical to
    /// `SumF` over the same product, but one µop per element instead of
    /// two. Tree order allowed (per-lane partial sums + faddv fold),
    /// like `SumF`.
    DotF,
}

/// A reduction accumulator updated every iteration.
#[derive(Clone, Debug)]
pub struct Reduction {
    pub kind: RedKind,
    /// Value added/xored/maxed each iteration.
    pub value: Expr,
}

/// One statement of the loop body.
#[derive(Clone, Debug)]
pub enum Stmt {
    /// `A[idx] = value`.
    Store { arr: usize, idx: Index, value: Expr },
    /// Data-dependent loop exit *before* this iteration's remaining
    /// side effects: `if (cond) break;` — §2.3.4.
    Break { cond: Expr },
}

/// Loop trip count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trip {
    /// Runtime-constant `n` (known only at entry — compilers must not
    /// assume a multiple of VL).
    Count(u64),
    /// No static bound; termination only via `Stmt::Break` (strlen).
    DataDependent { max: u64 },
}

/// A rectangular outer dimension: `trip` iterations, each advancing the
/// effective base of array `arr` by `stride_elems` elements.
#[derive(Clone, Debug)]
pub struct OuterDim {
    pub trip: u64,
    pub strides: Vec<(usize, i64)>,
}

/// Compiler quirks — *documented* reproductions of the specific compiler
/// defects §5 attributes to individual benchmarks. They model toolchain
/// behaviour, not architecture.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quirk {
    None,
    /// MILCmk: "the compiler decides to vectorize the outermost loop in
    /// a loop nest generating unnecessary overheads (the Advanced SIMD
    /// compiler vectorizes the inner loop)". For the SVE target the
    /// vectorizer treats every contiguous access as strided (gathered),
    /// as outer-loop vectorization of an inner-contiguous nest does.
    MilcOuterLoop,
}

/// The vectorizer's input: one innermost loop.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub name: String,
    pub arrays: Vec<ArrayDecl>,
    pub outer: Vec<OuterDim>,
    pub trip: Trip,
    pub body: Vec<Stmt>,
    pub reductions: Vec<Reduction>,
    /// Per-iteration local bindings (max 4): evaluated in order at the
    /// top of every iteration; `Expr::Local(i)` references binding `i`.
    pub locals: Vec<Expr>,
    /// Addresses to store each reduction's final value to.
    pub red_out: Vec<u64>,
    /// Address to store the final trip count to (strlen-style results).
    pub count_out: Option<u64>,
    /// Element type the loop is "aligned" to (largest data type used).
    pub elem_ty: Ty,
    pub quirk: Quirk,
}

impl Kernel {
    pub fn new(name: &str, elem_ty: Ty, trip: Trip) -> Self {
        Kernel {
            name: name.to_string(),
            arrays: vec![],
            outer: vec![],
            trip,
            body: vec![],
            reductions: vec![],
            locals: vec![],
            red_out: vec![],
            count_out: None,
            elem_ty,
            quirk: Quirk::None,
        }
    }

    pub fn array(&mut self, name: &str, ty: Ty, base: u64) -> usize {
        self.arrays.push(ArrayDecl { name: name.to_string(), ty, base });
        self.arrays.len() - 1
    }

    /// All expressions in the body + reductions (for analysis).
    pub fn all_exprs(&self) -> Vec<&Expr> {
        let mut out: Vec<&Expr> = vec![];
        for s in &self.body {
            match s {
                Stmt::Store { value, .. } => out.push(value),
                Stmt::Break { cond } => out.push(cond),
            }
        }
        for r in &self.reductions {
            out.push(&r.value);
        }
        for l in &self.locals {
            out.push(l);
        }
        out
    }

    pub fn has_break(&self) -> bool {
        self.body.iter().any(|s| matches!(s, Stmt::Break { .. }))
    }

    /// Total outer iterations (product of outer trips, min 1).
    pub fn outer_iters(&self) -> u64 {
        self.outer.iter().map(|d| d.trip).product::<u64>().max(1)
    }
}

/// A compiled kernel: the program plus its one-time lowering through
/// the shared decode layer ([`crate::isa::uop`]). Decoding happens here
/// — once per (kernel, target) — and the
/// [`crate::isa::uop::DecodedProgram`] is shared read-only across every
/// vector length and µarch variant a sweep runs, since µops are
/// VL-agnostic (§2.2).
#[derive(Clone, Debug)]
pub struct Compiled {
    pub program: crate::asm::Program,
    /// The pre-decoded µop form both the executor and the timing
    /// pipeline consume.
    pub decoded: crate::isa::uop::DecodedProgram,
    /// Did the vectorizer fire for this target?
    pub vectorized: bool,
    /// Human-readable reason when it did not.
    pub why_not: Option<String>,
}

impl Compiled {
    /// Wrap a finished program, decoding it once.
    pub fn new(
        program: crate::asm::Program,
        vectorized: bool,
        why_not: Option<String>,
    ) -> Compiled {
        let decoded = crate::isa::uop::DecodedProgram::decode(&program);
        Compiled { program, decoded, vectorized, why_not }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_builder_basics() {
        let mut k = Kernel::new("t", Ty::F64, Trip::Count(10));
        let a = k.array("a", Ty::F64, 0x1000);
        let b = k.array("b", Ty::F64, 0x2000);
        assert_eq!((a, b), (0, 1));
        k.body.push(Stmt::Store {
            arr: b,
            idx: Index::Affine { offset: 0 },
            value: Expr::load(a, Index::Affine { offset: 0 }),
        });
        assert_eq!(k.all_exprs().len(), 1);
        assert!(!k.has_break());
        assert_eq!(k.outer_iters(), 1);
    }

    #[test]
    fn outer_iters_product() {
        let mut k = Kernel::new("t", Ty::F32, Trip::Count(4));
        k.outer.push(OuterDim { trip: 3, strides: vec![] });
        k.outer.push(OuterDim { trip: 5, strides: vec![] });
        assert_eq!(k.outer_iters(), 15);
    }

    #[test]
    fn expr_visit_reaches_all_nodes() {
        let e = Expr::select(
            Expr::cmp(CmpKind::Gt, Expr::load(0, Index::Affine { offset: 0 }), Expr::ConstF(1.0)),
            Expr::bin(BinOp::Mul, Expr::IvAsF, Expr::ConstF(2.0)),
            Expr::ConstF(0.0),
        );
        let mut n = 0;
        e.visit(&mut |_| n += 1);
        // Select + Cmp + Load + ConstF + Bin + IvAsF + ConstF + ConstF
        assert_eq!(n, 8);
    }

    #[test]
    fn expr_visit_recurses_into_fma_operands() {
        let e = Expr::fma(
            Expr::load(0, Index::Affine { offset: 0 }),
            Expr::ConstF(2.0),
            Expr::fma(Expr::IvAsF, Expr::ConstF(3.0), Expr::ConstF(0.0)),
        );
        let mut n = 0;
        let mut loads = 0;
        e.visit(&mut |x| {
            n += 1;
            if matches!(x, Expr::Load { .. }) {
                loads += 1;
            }
        });
        // Fma + Load + ConstF + Fma + IvAsF + ConstF + ConstF
        assert_eq!((n, loads), (7, 1));
    }

    #[test]
    fn complex_mul_is_a_leaf_node() {
        let e = Expr::bin(
            BinOp::Add,
            Expr::ComplexMul { a_arr: 0, a_off: 1, b_arr: 1, b_off: 1, conj: false },
            Expr::ComplexMul { a_arr: 0, a_off: 3, b_arr: 1, b_off: 1, conj: true },
        );
        let mut cmuls = 0;
        e.visit(&mut |x| {
            if matches!(x, Expr::ComplexMul { .. }) {
                cmuls += 1;
            }
        });
        assert_eq!(cmuls, 2);
    }
}
