//! The auto-vectorizing compiler (§3) — our stand-in for the paper's
//! "experimental compiler, able to auto-vectorize code for SVE".
//!
//! * [`ir`] — the loop IR the vectorizer consumes.
//! * [`vectorize`] — legality + profitability for the NEON and SVE
//!   targets.
//! * [`codegen`] / `neon_cg` / `sve_cg` — scalar, NEON and SVE code
//!   generation over the shared register conventions.
//! * [`chase`] — the Fig. 6 scalarized intra-vector sub-loop.

pub mod chase;
pub mod codegen;
pub mod ir;
mod neon_cg;
mod sve_cg;
pub mod vectorize;

pub use codegen::{Cg, Target};
pub use ir::*;

/// Rewrite for [`Quirk::MilcOuterLoop`]: outer-loop vectorization turns
/// inner-contiguous accesses into strided (gathered) ones.
fn rewrite_milc(k: &Kernel) -> Kernel {
    fn fix_idx(i: Index) -> Index {
        match i {
            Index::Affine { offset } => Index::Strided { scale: 1, offset },
            other => other,
        }
    }
    fn fix_expr(e: &mut Expr) {
        match e {
            Expr::Load { idx, .. } => *idx = fix_idx(*idx),
            Expr::Bin { a, b, .. } => {
                fix_expr(a);
                fix_expr(b);
            }
            Expr::Un { a, .. } => fix_expr(a),
            Expr::Cmp { a, b, .. } => {
                fix_expr(a);
                fix_expr(b);
            }
            Expr::Select { c, t, f } => {
                fix_expr(c);
                fix_expr(t);
                fix_expr(f);
            }
            Expr::Opaque { args, .. } => args.iter_mut().for_each(fix_expr),
            Expr::Fma { a, b, acc, .. } => {
                fix_expr(a);
                fix_expr(b);
                fix_expr(acc);
            }
            // ComplexMul carries its own addressing (pair-base), not an
            // Index — nothing to rewrite; only milcmk uses the quirk.
            _ => {}
        }
    }
    let mut k = k.clone();
    for s in &mut k.body {
        match s {
            Stmt::Store { idx, value, .. } => {
                *idx = fix_idx(*idx);
                fix_expr(value);
            }
            Stmt::Break { cond } => fix_expr(cond),
        }
    }
    for r in &mut k.reductions {
        fix_expr(&mut r.value);
    }
    for l in &mut k.locals {
        fix_expr(l);
    }
    k
}

/// Compile `k` for `target`. When the target's vectorizer rejects the
/// loop, the scalar fallback is emitted (so an "SVE binary" of an
/// unvectorizable loop is scalar code, exactly like the paper's left
/// benchmark group).
pub fn compile(k: &Kernel, target: Target) -> Compiled {
    match target {
        Target::Scalar => {
            let mut cg = Cg::new(k, Target::Scalar);
            cg.emit_scalar_program();
            Compiled::new(cg.asm.finish(), false, None)
        }
        Target::Neon => match vectorize::neon_legal(k) {
            Ok(()) => {
                let mut cg = Cg::new(k, Target::Neon);
                cg.emit_neon_program();
                Compiled::new(cg.asm.finish(), true, None)
            }
            Err(why) => {
                let mut cg = Cg::new(k, Target::Neon);
                cg.emit_scalar_program();
                Compiled::new(cg.asm.finish(), false, Some(why))
            }
        },
        Target::Sve => match vectorize::sve_legal(k) {
            Ok(()) => {
                let quirked;
                let k2: &Kernel = if k.quirk == Quirk::MilcOuterLoop {
                    quirked = rewrite_milc(k);
                    &quirked
                } else {
                    k
                };
                let mut cg = Cg::new(k2, Target::Sve);
                cg.emit_sve_program();
                Compiled::new(cg.asm.finish(), true, None)
            }
            Err(why) => {
                let mut cg = Cg::new(k, Target::Sve);
                cg.emit_scalar_program();
                Compiled::new(cg.asm.finish(), false, Some(why))
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_targets_produce_programs() {
        let mut k = Kernel::new("t", Ty::F64, Trip::Count(8));
        let x = k.array("x", Ty::F64, 0x10000);
        let y = k.array("y", Ty::F64, 0x20000);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::load(x, Index::Affine { offset: 0 }),
        });
        for t in [Target::Scalar, Target::Neon, Target::Sve] {
            let c = compile(&k, t);
            assert!(!c.program.is_empty());
        }
    }

    #[test]
    fn sve_program_contains_whilelt_and_predicated_ops() {
        let mut k = Kernel::new("t", Ty::F64, Trip::Count(8));
        let x = k.array("x", Ty::F64, 0x10000);
        let y = k.array("y", Ty::F64, 0x20000);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::load(x, Index::Affine { offset: 0 }),
        });
        let c = compile(&k, Target::Sve);
        use crate::isa::Inst;
        assert!(c.program.insts.iter().any(|i| matches!(i, Inst::While { .. })));
        assert!(c.program.insts.iter().any(|i| matches!(i, Inst::SveLd1 { .. })));
        assert!(c.program.insts.iter().any(|i| matches!(i, Inst::SveSt1 { .. })));
    }
}
