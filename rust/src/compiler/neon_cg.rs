//! NEON (Advanced SIMD) code generation: fixed 128-bit unpredicated
//! vector main loop + scalar tail — the classic pre-SVE vectorization
//! shape ("Unroll and Jam" family, §3.1).

use super::codegen::{Cg, IV, SCR, TRIP};
use super::ir::*;
use crate::arch::Cond;
use crate::isa::{FpOp, FpUnOp, Inst, IntOp, MemOff};

const VACC: u8 = 16;
const FACC: u8 = 24;
const LOCAL0: u8 = 28;
const NMAIN: u8 = 24; // x24 = floor(n / lanes) * lanes
const HSCR: u8 = 15; // d15: horizontal-reduce scratch

impl<'k> Cg<'k> {
    fn neon_lanes(&self) -> u64 {
        (16 / self.elem_esize().bytes()) as u64
    }

    /// Evaluate `e` as a 128-bit vector. `vt` = next free stack slot.
    fn ev_neon(&mut self, e: &Expr, vt: u8) -> u8 {
        assert!(vt < 8, "vector expression stack overflow");
        let dbl = self.dbl();
        let esize = self.elem_esize();
        match e {
            Expr::ConstF(v) => {
                let bits = if dbl { v.to_bits() } else { (*v as f32).to_bits() as u64 };
                if let Some(r) = self.const_reg(bits) {
                    r
                } else {
                    self.asm.push(Inst::FmovImm { dbl, dd: vt, bits });
                    self.asm.push(Inst::NeonDupLane0 { esize, vd: vt, vn: vt });
                    vt
                }
            }
            Expr::ConstI(v) => {
                self.asm.push(Inst::MovImm { xd: SCR, imm: *v as u64 });
                self.asm.push(Inst::NeonDupX { esize, vd: vt, xn: SCR });
                vt
            }
            Expr::Local(i) => LOCAL0 + *i as u8,
            Expr::Load { arr, idx } => {
                let Index::Affine { offset } = idx else {
                    panic!("non-contiguous access reached NEON codegen")
                };
                let base = self.base_with_offset(*arr, *offset);
                self.asm.push(Inst::NeonLd1 {
                    esize,
                    vt,
                    base,
                    off: MemOff::RegLsl(IV, esize.bytes().trailing_zeros() as u8),
                });
                vt
            }
            Expr::Bin { op, a, b } => {
                let ra = self.ev_neon(a, vt);
                let rb = self.ev_neon(b, vt + 1);
                let ty = self.ty_of(a);
                if ty.is_fp() {
                    let fpop = match op {
                        BinOp::Add => FpOp::Add,
                        BinOp::Sub => FpOp::Sub,
                        BinOp::Mul => FpOp::Mul,
                        BinOp::Div => FpOp::Div,
                        BinOp::Max => FpOp::Max,
                        BinOp::Min => FpOp::Min,
                        _ => panic!("bitwise op on fp"),
                    };
                    self.asm.push(Inst::NeonFpBin { op: fpop, dbl, vd: vt, vn: ra, vm: rb });
                } else {
                    let iop = match op {
                        BinOp::Add => IntOp::Add,
                        BinOp::Sub => IntOp::Sub,
                        BinOp::Mul => IntOp::Mul,
                        BinOp::Xor => IntOp::Eor,
                        BinOp::And => IntOp::And,
                        BinOp::Or => IntOp::Orr,
                        _ => panic!("fp op on ints"),
                    };
                    self.asm.push(Inst::NeonIntBin { op: iop, esize, vd: vt, vn: ra, vm: rb });
                }
                vt
            }
            Expr::Un { op, a } => {
                let ra = self.ev_neon(a, vt);
                let fop = match op {
                    UnOp::Neg => FpUnOp::Neg,
                    UnOp::Abs => FpUnOp::Abs,
                    UnOp::Sqrt => FpUnOp::Sqrt,
                };
                self.asm.push(Inst::NeonFpUn { op: fop, dbl, vd: vt, vn: ra });
                vt
            }
            Expr::Fma { a, b, acc, sub } => {
                let racc = self.ev_neon(acc, vt);
                if racc != vt {
                    // full-register copy (the locals idiom): Orr vt, r, r
                    self.asm.push(Inst::NeonIntBin {
                        op: IntOp::Orr,
                        esize: crate::arch::Esize::B,
                        vd: vt,
                        vn: racc,
                        vm: racc,
                    });
                }
                let ra = self.ev_neon(a, vt + 1);
                let rb = self.ev_neon(b, vt + 2);
                self.asm.push(Inst::NeonFmla { dbl, vd: vt, vn: ra, vm: rb, sub: *sub });
                vt
            }
            Expr::ComplexMul { .. } => {
                panic!("complex multiply reached NEON codegen (legality bug)")
            }
            Expr::Select { .. } | Expr::Cmp { .. } => {
                panic!("conditional reached NEON codegen (legality bug)")
            }
            Expr::Opaque { .. } => panic!("opaque call reached NEON codegen"),
            Expr::Iv | Expr::IvAsF => panic!("induction value reached NEON codegen"),
        }
    }

    fn emit_neon_iter(&mut self) {
        let dbl = self.dbl();
        let esize = self.elem_esize();
        for (i, l) in self.k.locals.clone().iter().enumerate() {
            let r = self.ev_neon(l, 0);
            self.asm.push(Inst::NeonIntBin {
                op: IntOp::Orr,
                esize: crate::arch::Esize::B,
                vd: LOCAL0 + i as u8,
                vn: r,
                vm: r,
            });
        }
        for s in self.body() {
            match s {
                Stmt::Store { arr, idx, value } => {
                    let rv = self.ev_neon(&value, 0);
                    let Index::Affine { offset } = idx else {
                        panic!("non-contiguous store reached NEON codegen")
                    };
                    let base = self.base_with_offset(arr, offset);
                    self.asm.push(Inst::NeonSt1 {
                        esize,
                        vt: rv,
                        base,
                        off: MemOff::RegLsl(IV, esize.bytes().trailing_zeros() as u8),
                    });
                }
                Stmt::Break { .. } => panic!("break reached NEON codegen"),
            }
        }
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            if red.kind == RedKind::DotF {
                // dot-product reduction: one FMLA per vector into the
                // per-lane partial sums (folded by faddv like SumF).
                let Expr::Bin { op: BinOp::Mul, a, b } = &red.value else {
                    panic!("DotF value must be a product")
                };
                let ra = self.ev_neon(a, 0);
                let rb = self.ev_neon(b, 1);
                self.asm.push(Inst::NeonFmla {
                    dbl,
                    vd: VACC + r as u8,
                    vn: ra,
                    vm: rb,
                    sub: false,
                });
                continue;
            }
            let rv = self.ev_neon(&red.value, 0);
            match red.kind {
                RedKind::SumF => self.asm.push(Inst::NeonFpBin {
                    op: FpOp::Add,
                    dbl,
                    vd: VACC + r as u8,
                    vn: VACC + r as u8,
                    vm: rv,
                }),
                _ => panic!("unsupported NEON reduction"),
            };
        }
    }

    /// Complete NEON program: vector main loop + scalar tail.
    pub fn emit_neon_program(&mut self) {
        let dbl = self.dbl();
        let lanes = self.neon_lanes();
        self.prologue();
        let outer = self.open_outer();
        self.asm.push(Inst::MovImm { xd: IV, imm: 0 });
        let Trip::Count(n) = self.k.trip else { panic!("NEON needs counted trip") };
        self.asm.push(Inst::MovImm { xd: TRIP, imm: n });
        // (re)zero vector accumulators for this outer iteration
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            if matches!(red.kind, RedKind::SumF | RedKind::DotF) {
                self.asm.push(Inst::FdupImm { zd: VACC + r as u8, dbl, bits: 0 });
            }
        }
        // n_main = n & !(lanes-1)
        self.asm.push(Inst::AndImm { xd: NMAIN, xn: TRIP, imm: !(lanes - 1) });
        let nloop = self.fresh("nloop");
        let nlatch = self.fresh("nlatch");
        self.asm.push_branch(Inst::B { target: 0 }, &nlatch);
        self.asm.label(&nloop);
        self.emit_neon_iter();
        self.asm.push(Inst::AddImm { xd: IV, xn: IV, imm: lanes as i64 });
        self.asm.label(&nlatch);
        self.asm.push(Inst::CmpReg { xn: IV, xm: NMAIN });
        self.asm.push_branch(Inst::BCond { cond: Cond::Lt, target: 0 }, &nloop);
        // fold the vector accumulators into the scalar ones
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            if matches!(red.kind, RedKind::SumF | RedKind::DotF) {
                self.asm.push(Inst::NeonFaddv { dbl, dd: HSCR, vn: VACC + r as u8 });
                self.asm.push(Inst::FpBin {
                    op: FpOp::Add,
                    dbl,
                    dd: FACC + r as u8,
                    dn: FACC + r as u8,
                    dm: HSCR,
                });
            }
        }
        // scalar tail: IV already == n_main
        self.emit_scalar_loop();
        self.close_outer(outer);
        self.epilogue_outputs();
    }

    /// Complete scalar program (the Scalar target, and the fallback when
    /// a vectorizer rejects a loop).
    pub fn emit_scalar_program(&mut self) {
        self.prologue();
        let outer = self.open_outer();
        self.asm.push(Inst::MovImm { xd: IV, imm: 0 });
        if let Trip::Count(n) = self.k.trip {
            self.asm.push(Inst::MovImm { xd: TRIP, imm: n });
        }
        self.emit_scalar_loop();
        self.close_outer(outer);
        self.epilogue_outputs();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, Target};
    use crate::exec::Executor;
    use crate::mem::Memory;

    #[test]
    fn neon_daxpy_matches_reference_with_tail() {
        // n = 43: 40 main-loop elements (f64 x2 lanes) + 3 tail
        let n = 43u64;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let yb = mem.alloc(8 * n, 16);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, i as f64).unwrap();
            mem.write_f64(yb + 8 * i, 0.5 * i as f64).unwrap();
        }
        let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        let y = k.array("y", Ty::F64, yb);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Add,
                Expr::bin(
                    BinOp::Mul,
                    Expr::ConstF(2.0),
                    Expr::load(x, Index::Affine { offset: 0 }),
                ),
                Expr::load(y, Index::Affine { offset: 0 }),
            ),
        });
        let c = compile(&k, Target::Neon);
        assert!(c.vectorized, "{:?}", c.why_not);
        let mut ex = Executor::new(128, mem);
        ex.run(&c.program, 10_000_000).unwrap();
        for i in 0..n {
            assert_eq!(ex.mem.read_f64(yb + 8 * i).unwrap(), 2.0 * i as f64 + 0.5 * i as f64);
        }
    }

    #[test]
    fn neon_sum_reduction_with_tail() {
        let n = 21u64;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let out = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, (i + 1) as f64).unwrap();
        }
        let mut k = Kernel::new("sum", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        k.red_out = vec![out];
        k.reductions.push(Reduction {
            kind: RedKind::SumF,
            value: Expr::load(x, Index::Affine { offset: 0 }),
        });
        let c = compile(&k, Target::Neon);
        assert!(c.vectorized);
        let mut ex = Executor::new(128, mem);
        ex.run(&c.program, 10_000_000).unwrap();
        assert_eq!(ex.mem.read_f64(out).unwrap(), (n * (n + 1) / 2) as f64);
    }

    #[test]
    fn neon_rejection_falls_back_to_scalar_and_stays_correct() {
        // conditional assignment: NEON target must emit scalar code
        let n = 10u64;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let yb = mem.alloc(8 * n, 16);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, i as f64 - 5.0).unwrap();
        }
        let mut k = Kernel::new("relu", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        let y = k.array("y", Ty::F64, yb);
        let xi = Expr::load(x, Index::Affine { offset: 0 });
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::select(
                Expr::cmp(CmpKind::Gt, xi.clone(), Expr::ConstF(0.0)),
                xi,
                Expr::ConstF(0.0),
            ),
        });
        let c = compile(&k, Target::Neon);
        assert!(!c.vectorized);
        assert!(c.why_not.as_deref().unwrap().contains("conditional assignment"));
        let mut ex = Executor::new(128, mem);
        ex.run(&c.program, 10_000_000).unwrap();
        for i in 0..n {
            assert_eq!(ex.mem.read_f64(yb + 8 * i).unwrap(), (i as f64 - 5.0).max(0.0));
        }
    }

    #[test]
    fn neon_f32_uses_four_lanes() {
        let n = 16u64;
        let mut mem = Memory::new();
        let xb = mem.alloc(4 * n, 16);
        let yb = mem.alloc(4 * n, 16);
        for i in 0..n {
            mem.write_f32(xb + 4 * i, i as f32).unwrap();
        }
        let mut k = Kernel::new("scale32", Ty::F32, Trip::Count(n));
        let x = k.array("x", Ty::F32, xb);
        let y = k.array("y", Ty::F32, yb);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Mul,
                Expr::load(x, Index::Affine { offset: 0 }),
                Expr::ConstF(3.0),
            ),
        });
        let c = compile(&k, Target::Neon);
        assert!(c.vectorized);
        let mut ex = Executor::new(128, mem);
        let stats = ex.run(&c.program, 10_000_000).unwrap();
        for i in 0..n {
            assert_eq!(ex.mem.read_f32(yb + 4 * i).unwrap(), 3.0 * i as f32);
        }
        // 4 lanes/iter: 4 main iterations, no tail
        assert!(stats.neon_insts >= 8, "vector code must actually run");
    }
}
