//! SVE code generation: predicated vector loops per §3 — while-based
//! loop control, if-conversion to predication, gather/scatter,
//! first-faulting speculative vectorization, vector+ordered reductions.

use super::codegen::{Cg, IV, SCALE, SCR, TRIP};
use super::ir::*;
use crate::arch::Cond;
use crate::isa::{
    CmpOp, FpOp, FpUnOp, GatherAddr, Inst, IntOp, SveMemOff, ZmOrImm,
};

const GIDX: u8 = 15;
const VACC: u8 = 16;
const FACC: u8 = 24;
const LOCAL0: u8 = 28;
const PALL: u8 = 6;

impl<'k> Cg<'k> {
    /// Evaluate `e` as a vector under governing predicate `pred`.
    /// `zt` = next free z stack slot (0..=7), `pt` = next free predicate
    /// slot (1..=3). Returns the register holding the value.
    fn ev_sve(&mut self, e: &Expr, zt: u8, pred: u8, pt: u8) -> u8 {
        assert!(zt < 8, "vector expression stack overflow");
        let dbl = self.dbl();
        let esize = self.elem_esize();
        match e {
            Expr::ConstF(v) => {
                let bits = if dbl { v.to_bits() } else { (*v as f32).to_bits() as u64 };
                if let Some(r) = self.const_reg(bits) {
                    r
                } else {
                    self.asm.push(Inst::FdupImm { zd: zt, dbl, bits });
                    zt
                }
            }
            Expr::ConstI(v) => {
                self.asm.push(Inst::DupImm { zd: zt, esize, imm: *v });
                zt
            }
            Expr::Iv => {
                // lanes = iv + [0,1,2,...]
                self.asm.push(Inst::DupX { zd: zt, esize, xn: IV });
                let lane = self.scale_slot(1);
                self.asm.push(Inst::SveIntBinU { op: IntOp::Add, zd: zt, zn: zt, zm: lane, esize });
                zt
            }
            Expr::IvAsF => {
                self.asm.push(Inst::DupX { zd: zt, esize, xn: IV });
                let lane = self.scale_slot(1);
                self.asm.push(Inst::SveIntBinU { op: IntOp::Add, zd: zt, zn: zt, zm: lane, esize });
                self.asm.push(Inst::SveScvtf { zd: zt, pg: pred, zn: zt, dbl });
                zt
            }
            Expr::Local(i) => LOCAL0 + *i as u8,
            Expr::Load { arr, idx } => {
                self.sve_load(*arr, *idx, zt, pred);
                zt
            }
            Expr::Bin { op, a, b } => {
                let ra = self.ev_sve_into(a, zt, pred, pt);
                let rb = self.ev_sve(b, zt + 1, pred, pt);
                let ty = self.ty_of(a);
                if ty.is_fp() {
                    let fpop = match op {
                        BinOp::Add => FpOp::Add,
                        BinOp::Sub => FpOp::Sub,
                        BinOp::Mul => FpOp::Mul,
                        BinOp::Div => FpOp::Div,
                        BinOp::Max => FpOp::Max,
                        BinOp::Min => FpOp::Min,
                        _ => panic!("bitwise op on fp"),
                    };
                    self.asm.push(Inst::SveFpBin { op: fpop, zdn: ra, pg: pred, zm: rb, dbl });
                } else {
                    let iop = match op {
                        BinOp::Add => IntOp::Add,
                        BinOp::Sub => IntOp::Sub,
                        BinOp::Mul => IntOp::Mul,
                        BinOp::Xor => IntOp::Eor,
                        BinOp::And => IntOp::And,
                        BinOp::Or => IntOp::Orr,
                        _ => panic!("fp op on ints"),
                    };
                    self.asm.push(Inst::SveIntBin { op: iop, zdn: ra, pg: pred, zm: rb, esize });
                }
                ra
            }
            Expr::Un { op, a } => {
                let ra = self.ev_sve_into(a, zt, pred, pt);
                let fop = match op {
                    UnOp::Neg => FpUnOp::Neg,
                    UnOp::Abs => FpUnOp::Abs,
                    UnOp::Sqrt => FpUnOp::Sqrt,
                };
                self.asm.push(Inst::SveFpUn { op: fop, zd: ra, pg: pred, zn: ra, dbl });
                ra
            }
            Expr::Select { c, t, f } => {
                // if-conversion (§3.2): compute the condition predicate,
                // then a vector select
                let rt = self.ev_sve_into(t, zt, pred, pt);
                let rf = self.ev_sve(f, zt + 1, pred, pt);
                let pd = self.ev_sve_cond(c, zt + 2, pred, pt);
                self.asm.push(Inst::Sel { zd: rt, pg: pd, zn: rt, zm: rf, esize });
                rt
            }
            Expr::Opaque { .. } => panic!("opaque call reached SVE codegen (vectorizer bug)"),
            Expr::Fma { a, b, acc, sub } => {
                // predicated FMLA/FMLS: inactive lanes keep the acc value,
                // active lanes get acc ± a*b with the same unfused rounding
                // as the scalar Fmadd.
                let _ = self.ev_sve_into(acc, zt, pred, pt);
                let ra = self.ev_sve(a, zt + 1, pred, pt);
                let rb = self.ev_sve(b, zt + 2, pred, pt);
                self.asm.push(Inst::SveFmla { zda: zt, pg: pred, zn: ra, zm: rb, dbl, sub: *sub });
                zt
            }
            Expr::ComplexMul { a_arr, a_off, b_arr, b_off, conj } => {
                // FCMLA-style lane-parity form: compute the even-lane (real)
                // arm from the aligned and +1-shifted vectors and the
                // odd-lane (imaginary) arm from the aligned and -1-shifted
                // vectors, then select by lane parity (p7, set up once in
                // the program prologue). The shifted loads read one element
                // before/after the operand blocks — guard elements the
                // kernel must map; their values land only in lanes the Sel
                // discards. Per-arm rounding (mul, then unfused fmla)
                // matches the scalar lowering exactly.
                assert!(zt + 4 < 8, "vector expression stack overflow");
                let (a_arr, b_arr) = (*a_arr, *b_arr);
                let mut ld = |cg: &mut Self, arr: usize, off: i64, zreg: u8| {
                    let base = cg.base_with_offset(arr, off);
                    cg.asm.push(Inst::SveLd1 {
                        zt: zreg,
                        pg: pred,
                        esize,
                        base,
                        off: SveMemOff::RegScaled(IV),
                        ff: false,
                    });
                };
                ld(self, a_arr, *a_off, zt + 2); // A0: even→ar, odd→ai
                ld(self, b_arr, *b_off, zt + 3); // B0: even→br, odd→bi
                // even arm: re = A0*B0 -/+ Ap*Bp
                self.asm.push(Inst::Movprfx { zd: zt, zn: zt + 2, pg: None });
                self.asm.push(Inst::SveFpBin { op: FpOp::Mul, zdn: zt, pg: pred, zm: zt + 3, dbl });
                ld(self, a_arr, *a_off + 1, zt + 1); // Ap: even→ai
                ld(self, b_arr, *b_off + 1, zt + 4); // Bp: even→bi
                self.asm.push(Inst::SveFmla {
                    zda: zt,
                    pg: pred,
                    zn: zt + 1,
                    zm: zt + 4,
                    dbl,
                    sub: !*conj,
                });
                // odd arm: im = Am*B0 +/- A0*Bm
                ld(self, a_arr, *a_off - 1, zt + 1); // Am: odd→ar
                self.asm.push(Inst::SveFpBin {
                    op: FpOp::Mul,
                    zdn: zt + 1,
                    pg: pred,
                    zm: zt + 3,
                    dbl,
                });
                ld(self, b_arr, *b_off - 1, zt + 4); // Bm: odd→br
                self.asm.push(Inst::SveFmla {
                    zda: zt + 1,
                    pg: pred,
                    zn: zt + 2,
                    zm: zt + 4,
                    dbl,
                    sub: *conj,
                });
                self.asm.push(Inst::Sel { zd: zt, pg: 7, zn: zt, zm: zt + 1, esize });
                zt
            }
            Expr::Cmp { .. } => panic!("bare Cmp outside Select/Break"),
        }
    }

    /// Force the result into stack slot `zt` (protects locals/constants
    /// from destructive ops).
    fn ev_sve_into(&mut self, e: &Expr, zt: u8, pred: u8, pt: u8) -> u8 {
        let r = self.ev_sve(e, zt, pred, pt);
        if r != zt {
            // §4: movprfx is the architecture's answer to exactly this
            self.asm.push(Inst::Movprfx { zd: zt, zn: r, pg: None });
        }
        zt
    }

    /// Evaluate a comparison into predicate register `pt`, governed by
    /// `pred`. Returns the predicate register.
    fn ev_sve_cond(&mut self, e: &Expr, zt: u8, pred: u8, pt: u8) -> u8 {
        assert!((1..=3).contains(&pt), "predicate stack overflow");
        let Expr::Cmp { op, a, b } = e else { panic!("condition must be Cmp") };
        let cmpop = match op {
            CmpKind::Eq => CmpOp::Eq,
            CmpKind::Ne => CmpOp::Ne,
            CmpKind::Gt => CmpOp::Gt,
            CmpKind::Ge => CmpOp::Ge,
            CmpKind::Lt => CmpOp::Lt,
            CmpKind::Le => CmpOp::Le,
        };
        let ty = self.ty_of(a);
        if ty.is_fp() {
            let ra = self.ev_sve(a, zt, pred, pt);
            let rhs = match &**b {
                Expr::ConstF(v) if *v == 0.0 => None,
                _ => Some(self.ev_sve(b, zt + 1, pred, pt)),
            };
            self.asm.push(Inst::SveFpCmp {
                op: cmpop,
                pd: pt,
                pg: pred,
                zn: ra,
                rhs,
                dbl: self.dbl(),
            });
        } else {
            let ra = self.ev_sve(a, zt, pred, pt);
            let rhs = match &**b {
                Expr::ConstI(v) if (-16..16).contains(v) => ZmOrImm::Imm(*v),
                _ => ZmOrImm::Z(self.ev_sve(b, zt + 1, pred, pt)),
            };
            self.asm.push(Inst::SveIntCmp {
                op: cmpop,
                unsigned: false,
                pd: pt,
                pg: pred,
                zn: ra,
                rhs,
                esize: self.elem_esize(),
            });
        }
        pt
    }

    /// Predicated vector load of `arr[idx]` into `zt`.
    fn sve_load(&mut self, arr: usize, idx: Index, zt: u8, pred: u8) {
        let ty = self.k.arrays[arr].ty;
        let esize = self.elem_esize();
        debug_assert_eq!(ty.bytes(), esize.bytes(), "uniform lane width");
        match idx {
            Index::Affine { offset } => {
                let base = self.base_with_offset(arr, offset);
                self.asm.push(Inst::SveLd1 {
                    zt,
                    pg: pred,
                    esize,
                    base,
                    off: SveMemOff::RegScaled(IV),
                    ff: false,
                });
            }
            Index::Strided { scale, offset } => {
                self.sve_strided_index(scale);
                let base = self.base_with_offset(arr, offset);
                self.asm.push(Inst::SveLdGather {
                    zt,
                    pg: pred,
                    esize,
                    addr: GatherAddr::BaseVec { xn: base, zm: GIDX, scaled: true },
                    ff: false,
                });
            }
            Index::Indirect { idx_arr, offset } => {
                let ity = self.k.arrays[idx_arr].ty;
                debug_assert_eq!(ity.bytes(), esize.bytes(), "index lane width");
                self.asm.push(Inst::SveLd1 {
                    zt: GIDX,
                    pg: pred,
                    esize,
                    base: super::codegen::BASE_REG(idx_arr),
                    off: SveMemOff::RegScaled(IV),
                    ff: false,
                });
                let base = self.base_with_offset(arr, offset);
                self.asm.push(Inst::SveLdGather {
                    zt,
                    pg: pred,
                    esize,
                    addr: GatherAddr::BaseVec { xn: base, zm: GIDX, scaled: true },
                    ff: false,
                });
            }
        }
    }

    /// Compute the gather index vector for a strided access into GIDX:
    /// lanes = iv*scale + [0, scale, 2*scale, ...].
    fn sve_strided_index(&mut self, scale: i64) {
        let esize = self.elem_esize();
        self.asm.push(Inst::MovImm { xd: SCALE, imm: scale as u64 });
        self.asm.push(Inst::Madd { xd: SCR, xn: IV, xm: SCALE, xa: 31 });
        self.asm.push(Inst::DupX { zd: GIDX, esize, xn: SCR });
        let lane = self.scale_slot(scale);
        self.asm.push(Inst::SveIntBinU { op: IntOp::Add, zd: GIDX, zn: GIDX, zm: lane, esize });
    }

    /// One predicated vector iteration: locals, stores, reductions.
    fn emit_sve_iter(&mut self, pred: u8) {
        let dbl = self.dbl();
        let esize = self.elem_esize();
        for (i, l) in self.k.locals.clone().iter().enumerate() {
            let r = self.ev_sve(l, 0, pred, 1);
            if r != LOCAL0 + i as u8 {
                self.asm.push(Inst::Movprfx { zd: LOCAL0 + i as u8, zn: r, pg: None });
            }
        }
        for s in self.body() {
            match s {
                Stmt::Store { arr, idx, value } => {
                    let zv = self.ev_sve(&value, 0, pred, 1);
                    match idx {
                        Index::Affine { offset } => {
                            let base = self.base_with_offset(arr, offset);
                            self.asm.push(Inst::SveSt1 {
                                zt: zv,
                                pg: pred,
                                esize,
                                base,
                                off: SveMemOff::RegScaled(IV),
                            });
                        }
                        Index::Strided { scale, offset } => {
                            self.sve_strided_index(scale);
                            let base = self.base_with_offset(arr, offset);
                            self.asm.push(Inst::SveStScatter {
                                zt: zv,
                                pg: pred,
                                esize,
                                addr: GatherAddr::BaseVec { xn: base, zm: GIDX, scaled: true },
                            });
                        }
                        Index::Indirect { idx_arr, offset } => {
                            self.asm.push(Inst::SveLd1 {
                                zt: GIDX,
                                pg: pred,
                                esize,
                                base: super::codegen::BASE_REG(idx_arr),
                                off: SveMemOff::RegScaled(IV),
                                ff: false,
                            });
                            let base = self.base_with_offset(arr, offset);
                            self.asm.push(Inst::SveStScatter {
                                zt: zv,
                                pg: pred,
                                esize,
                                addr: GatherAddr::BaseVec { xn: base, zm: GIDX, scaled: true },
                            });
                        }
                    }
                }
                Stmt::Break { .. } => unreachable!("breaks handled by emit_sve_break_loop"),
            }
        }
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            let r = r as u8;
            if red.kind == RedKind::DotF {
                // dot-product reduction: one predicated FMLA per vector
                // into the per-lane partial sums (folded by FAddV in the
                // epilogue, exactly like SumF).
                let Expr::Bin { op: BinOp::Mul, a, b } = &red.value else {
                    panic!("DotF value must be a product")
                };
                let ra = self.ev_sve(a, 0, pred, 1);
                let rb = self.ev_sve(b, 1, pred, 1);
                self.asm.push(Inst::SveFmla {
                    zda: VACC + r,
                    pg: pred,
                    zn: ra,
                    zm: rb,
                    dbl,
                    sub: false,
                });
                continue;
            }
            let zv = self.ev_sve(&red.value, 0, pred, 1);
            match red.kind {
                RedKind::SumF => self.asm.push(Inst::SveFpBin {
                    op: FpOp::Add,
                    zdn: VACC + r,
                    pg: pred,
                    zm: zv,
                    dbl,
                }),
                RedKind::MaxF => self.asm.push(Inst::SveFpBin {
                    op: FpOp::Max,
                    zdn: VACC + r,
                    pg: pred,
                    zm: zv,
                    dbl,
                }),
                RedKind::XorI => self.asm.push(Inst::SveIntBin {
                    op: IntOp::Eor,
                    zdn: VACC + r,
                    pg: pred,
                    zm: zv,
                    esize,
                }),
                // strictly-ordered accumulate, in element order (§3.3)
                RedKind::OrderedSumF => {
                    self.asm.push(Inst::SveFadda { vdn: FACC + r, pg: pred, zm: zv, dbl })
                }
                RedKind::DotF => unreachable!("handled above"),
            };
        }
    }

    /// Horizontal reduction epilogue (after all loops).
    fn emit_sve_red_epilogue(&mut self) {
        let esize = self.elem_esize();
        for (r, red) in self.k.reductions.clone().iter().enumerate() {
            let r = r as u8;
            match red.kind {
                RedKind::SumF | RedKind::DotF => {
                    self.asm.push(Inst::SveReduce {
                        op: crate::isa::RedOp::FAddV,
                        vd: FACC + r,
                        pg: PALL,
                        zn: VACC + r,
                        esize,
                    });
                }
                RedKind::MaxF => {
                    self.asm.push(Inst::SveReduce {
                        op: crate::isa::RedOp::FMaxV,
                        vd: FACC + r,
                        pg: PALL,
                        zn: VACC + r,
                        esize,
                    });
                }
                RedKind::XorI => {
                    self.asm.push(Inst::SveReduce {
                        op: crate::isa::RedOp::EorV,
                        vd: FACC + r,
                        pg: PALL,
                        zn: VACC + r,
                        esize,
                    });
                    // move to the integer accumulator for the final store
                    self.asm.push(Inst::FmovDtoX { xd: super::codegen::XACC_REG(r), dn: FACC + r });
                }
                RedKind::OrderedSumF => {} // already scalar in FACC+r
            }
        }
    }

    /// The whilelt-governed counted loop — the Fig. 2c shape.
    pub fn emit_sve_counted_loop(&mut self) {
        let esize = self.elem_esize();
        let lloop = self.fresh("vloop");
        self.asm.push(Inst::While { pd: 0, esize, xn: IV, xm: TRIP, unsigned: false });
        self.asm.label(&lloop);
        self.emit_sve_iter(0);
        self.asm.push(Inst::IncDec { xdn: IV, esize, dec: false });
        self.asm.push(Inst::While { pd: 0, esize, xn: IV, xm: TRIP, unsigned: false });
        self.asm.push_branch(Inst::BCond { cond: Cond::FIRST, target: 0 }, &lloop);
    }

    /// The speculative (first-faulting) loop for data-dependent exits —
    /// the Fig. 5 shape (§2.3.3/§2.3.4/§3.4).
    pub fn emit_sve_break_loop(&mut self) {
        let esize = self.elem_esize();
        let lloop = self.fresh("ffloop");
        // collect the load streams to probe speculatively
        let mut probes: Vec<(usize, i64)> = vec![];
        for e in self.k.all_exprs() {
            e.visit(&mut |n| {
                if let Expr::Load { arr, idx: Index::Affine { offset } } = n {
                    if !probes.contains(&(*arr, *offset)) {
                        probes.push((*arr, *offset));
                    }
                }
            });
        }
        self.asm.push(Inst::Ptrue { pd: 0, esize, s: false });
        self.asm.label(&lloop);
        self.asm.push(Inst::Setffr);
        for (arr, offset) in probes.clone() {
            let base = self.base_with_offset(arr, offset);
            self.asm.push(Inst::SveLd1 {
                zt: 7,
                pg: 0,
                esize,
                base,
                off: SveMemOff::RegScaled(IV),
                ff: true,
            });
        }
        // p4 = partition of safely-loaded lanes
        self.asm.push(Inst::Rdffr { pd: 4, pg: Some(0), s: false });
        // breaks narrow the partition: p5 = before-break lanes
        let mut cur: u8 = 4;
        for s in self.k.body.clone() {
            match s {
                Stmt::Break { cond } => {
                    let pd = self.ev_sve_cond(&cond, 0, cur, 1);
                    self.asm.push(Inst::Brk { pd: 5, pg: cur, pn: pd, before: true, s: true });
                    cur = 5;
                }
                Stmt::Store { .. } => {}
            }
        }
        // body side effects + reductions under the final partition
        let body: Vec<Stmt> = self
            .k
            .body
            .iter()
            .filter(|s| matches!(s, Stmt::Store { .. }))
            .cloned()
            .collect();
        if !body.is_empty() || !self.k.reductions.is_empty() {
            // temporarily narrow to the stores-only body for emit
            self.set_body_override(Some(body));
            self.emit_sve_iter(cur);
            self.set_body_override(None);
        }
        self.asm.push(Inst::IncpX { xdn: IV, pm: cur, esize });
        // regenerate the continue/exit flags (body compares clobber NZCV)
        self.asm.push(Inst::Ptest { pg: 4, pn: cur });
        self.asm.push_branch(Inst::BCond { cond: Cond::LAST, target: 0 }, &lloop);
    }

    /// The complete SVE program for a vectorizable kernel.
    pub fn emit_sve_program(&mut self) {
        self.prologue();
        // lane-parity predicate for ComplexMul: p7 = even lanes. Lane
        // counts are even at every legal VL (≥ 2 elements per vector),
        // and the IV advances by whole vectors, so lane parity equals
        // element parity for the whole loop — compute it once.
        let has_cmul = {
            let mut found = false;
            for e in self.k.all_exprs() {
                e.visit(&mut |n| {
                    if matches!(n, Expr::ComplexMul { .. }) {
                        found = true;
                    }
                });
            }
            found
        };
        if has_cmul {
            let esize = self.elem_esize();
            self.asm.push(Inst::Index {
                zd: 7,
                esize,
                base: crate::isa::RegOrImm::Imm(0),
                step: crate::isa::RegOrImm::Imm(1),
            });
            self.asm.push(Inst::DupImm { zd: 6, esize, imm: 1 });
            self.asm.push(Inst::SveIntBinU { op: IntOp::And, zd: 7, zn: 7, zm: 6, esize });
            self.asm.push(Inst::SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 7,
                pg: PALL,
                zn: 7,
                rhs: ZmOrImm::Imm(0),
                esize,
            });
        }
        let outer = self.open_outer();
        self.asm.push(Inst::MovImm { xd: IV, imm: 0 });
        match self.k.trip {
            Trip::Count(n) => {
                self.asm.push(Inst::MovImm { xd: TRIP, imm: n });
                self.emit_sve_counted_loop();
            }
            Trip::DataDependent { .. } => self.emit_sve_break_loop(),
        }
        self.close_outer(outer);
        self.emit_sve_red_epilogue();
        self.epilogue_outputs();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::compiler::Target;
    use crate::exec::Executor;
    use crate::mem::Memory;

    fn daxpy_kernel(mem: &mut Memory, n: u64) -> (Kernel, u64, u64) {
        let xb = mem.alloc(8 * n.max(1), 16);
        let yb = mem.alloc(8 * n.max(1), 16);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, i as f64).unwrap();
            mem.write_f64(yb + 8 * i, 100.0 + i as f64).unwrap();
        }
        let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        let y = k.array("y", Ty::F64, yb);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Add,
                Expr::bin(
                    BinOp::Mul,
                    Expr::ConstF(3.0),
                    Expr::load(x, Index::Affine { offset: 0 }),
                ),
                Expr::load(y, Index::Affine { offset: 0 }),
            ),
        });
        (k, xb, yb)
    }

    #[test]
    fn sve_daxpy_matches_scalar_at_all_vls() {
        for vl in [128, 256, 512, 2048] {
            let mut mem = Memory::new();
            let (k, _, yb) = daxpy_kernel(&mut mem, 43);
            let c = compile(&k, Target::Sve);
            assert!(c.vectorized);
            let mut ex = Executor::new(vl, mem);
            ex.run(&c.program, 10_000_000).unwrap();
            for i in 0..43 {
                assert_eq!(
                    ex.mem.read_f64(yb + 8 * i).unwrap(),
                    3.0 * i as f64 + 100.0 + i as f64,
                    "vl={vl} y[{i}]"
                );
            }
        }
    }

    #[test]
    fn sve_conditional_assignment_if_converts() {
        // y[i] = x[i] > 0 ? x[i] : 0  (HACC-style)
        let n = 37u64;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let yb = mem.alloc(8 * n, 16);
        for i in 0..n {
            mem.write_f64(xb + 8 * i, i as f64 - 18.0).unwrap();
        }
        let mut k = Kernel::new("relu", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        let y = k.array("y", Ty::F64, yb);
        let xi = Expr::load(x, Index::Affine { offset: 0 });
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::select(
                Expr::cmp(CmpKind::Gt, xi.clone(), Expr::ConstF(0.0)),
                xi,
                Expr::ConstF(0.0),
            ),
        });
        let c = compile(&k, Target::Sve);
        assert!(c.vectorized, "{:?}", c.why_not);
        let mut ex = Executor::new(256, mem);
        ex.run(&c.program, 10_000_000).unwrap();
        for i in 0..n {
            let want = (i as f64 - 18.0).max(0.0);
            assert_eq!(ex.mem.read_f64(yb + 8 * i).unwrap(), want, "y[{i}]");
        }
    }

    #[test]
    fn sve_strided_gather_loop() {
        // o[i] = a[3i]  (AoS x-coordinate walk)
        let n = 20u64;
        let mut mem = Memory::new();
        let ab = mem.alloc(8 * 3 * n, 16);
        let ob = mem.alloc(8 * n, 16);
        for i in 0..3 * n {
            mem.write_f64(ab + 8 * i, i as f64).unwrap();
        }
        let mut k = Kernel::new("aos", Ty::F64, Trip::Count(n));
        let a = k.array("a", Ty::F64, ab);
        let o = k.array("o", Ty::F64, ob);
        k.body.push(Stmt::Store {
            arr: o,
            idx: Index::Affine { offset: 0 },
            value: Expr::load(a, Index::Strided { scale: 3, offset: 0 }),
        });
        // force SVE codegen even though the cost model would reject it
        let mut cg = Cg::new(&k, Target::Sve);
        cg.emit_sve_program();
        let p = cg.asm.finish();
        let mut ex = Executor::new(512, mem);
        ex.run(&p, 10_000_000).unwrap();
        for i in 0..n {
            assert_eq!(ex.mem.read_f64(ob + 8 * i).unwrap(), (3 * i) as f64, "o[{i}]");
        }
    }

    #[test]
    fn sve_indirect_gather_loop() {
        // red += a[idx[i]]
        let n = 16u64;
        let mut mem = Memory::new();
        let ab = mem.alloc(8 * 64, 16);
        let ib = mem.alloc(8 * n, 16);
        let out = mem.alloc(8, 8);
        for i in 0..64 {
            mem.write_f64(ab + 8 * i, i as f64).unwrap();
        }
        let idxs: Vec<u64> = (0..n).map(|i| (i * 7) % 64).collect();
        mem.write_u64_slice(ib, &idxs);
        let vb = mem.alloc(8 * n, 16);
        for i in 0..n {
            mem.write_f64(vb + 8 * i, (i + 1) as f64).unwrap();
        }
        let mut k = Kernel::new("spmv-ish", Ty::F64, Trip::Count(n));
        let a = k.array("a", Ty::F64, ab);
        let idx = k.array("idx", Ty::I64, ib);
        let vals = k.array("vals", Ty::F64, vb);
        k.red_out = vec![out];
        // red += vals[i] * a[idx[i]] — the SpMV inner product shape
        k.reductions.push(Reduction {
            kind: RedKind::SumF,
            value: Expr::bin(
                BinOp::Mul,
                Expr::load(vals, Index::Affine { offset: 0 }),
                Expr::load(a, Index::Indirect { idx_arr: idx, offset: 0 }),
            ),
        });
        let c = compile(&k, Target::Sve);
        assert!(c.vectorized, "{:?}", c.why_not);
        let mut ex = Executor::new(256, mem);
        ex.run(&c.program, 10_000_000).unwrap();
        let want: f64 = idxs.iter().enumerate().map(|(i, &x)| (i + 1) as f64 * x as f64).sum();
        assert_eq!(ex.mem.read_f64(out).unwrap(), want);
    }

    #[test]
    fn sve_ordered_reduction_bitwise_matches_scalar() {
        let n = 100u64;
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * n, 16);
        let out = mem.alloc(8, 8);
        let mut rng = crate::rng::Rng::new(9);
        let vals: Vec<f64> = (0..n).map(|_| rng.f64_range(-1e9, 1e9)).collect();
        mem.write_f64_slice(xb, &vals);
        let mut k = Kernel::new("osum", Ty::F64, Trip::Count(n));
        let x = k.array("x", Ty::F64, xb);
        k.red_out = vec![out];
        k.reductions.push(Reduction {
            kind: RedKind::OrderedSumF,
            value: Expr::load(x, Index::Affine { offset: 0 }),
        });
        let c = compile(&k, Target::Sve);
        assert!(c.vectorized);
        // at every VL, fadda must equal the exact scalar loop
        let mut want = 0.0f64;
        for v in &vals {
            want += v;
        }
        for vl in [128, 384, 1024] {
            let mut ex = Executor::new(vl, mem.clone());
            ex.run(&c.program, 10_000_000).unwrap();
            assert_eq!(ex.mem.read_f64(out).unwrap(), want, "vl={vl} (§3.3)");
        }
    }

    #[test]
    fn sve_break_strlen_fig5() {
        let mut mem = Memory::new();
        let sb = mem.alloc(4096, 64);
        let out = mem.alloc(8, 8);
        let len = 1000usize;
        for i in 0..len {
            mem.write_byte(sb + i as u64, b'x').unwrap();
        }
        mem.write_byte(sb + len as u64, 0).unwrap();
        let mut k = Kernel::new("strlen", Ty::U8, Trip::DataDependent { max: 1 << 22 });
        let s = k.array("s", Ty::U8, sb);
        k.count_out = Some(out);
        k.body.push(Stmt::Break {
            cond: Expr::cmp(
                CmpKind::Eq,
                Expr::load(s, Index::Affine { offset: 0 }),
                Expr::ConstI(0),
            ),
        });
        let c = compile(&k, Target::Sve);
        assert!(c.vectorized, "{:?}", c.why_not);
        for vl in [128, 256, 2048] {
            let mut ex = Executor::new(vl, mem.clone());
            ex.run(&c.program, 10_000_000).unwrap();
            assert_eq!(ex.mem.read_u64(out).unwrap(), len as u64, "vl={vl}");
        }
    }

    #[test]
    fn sve_break_loop_faults_handled_speculatively() {
        // string ends exactly at the last mapped byte: the speculative
        // loads past it must NOT trap (Fig. 5's whole point)
        let mut mem = Memory::new();
        let page = 0x40_000u64;
        mem.map(page, 4096);
        let out_page = 0x80_000u64;
        mem.map(out_page, 4096);
        let len = 4095;
        for i in 0..len {
            mem.write_byte(page + i, b'a').unwrap();
        }
        mem.write_byte(page + len, 0).unwrap(); // NUL is the final byte
        let mut k = Kernel::new("strlen-edge", Ty::U8, Trip::DataDependent { max: 1 << 22 });
        let s = k.array("s", Ty::U8, page);
        k.count_out = Some(out_page);
        k.body.push(Stmt::Break {
            cond: Expr::cmp(
                CmpKind::Eq,
                Expr::load(s, Index::Affine { offset: 0 }),
                Expr::ConstI(0),
            ),
        });
        let c = compile(&k, Target::Sve);
        let mut ex = Executor::new(2048, mem);
        ex.run(&c.program, 10_000_000).expect("no trap despite page end");
        assert_eq!(ex.mem.read_u64(out_page).unwrap(), len);
    }

    #[test]
    fn milc_quirk_forces_gathers() {
        let mut mem = Memory::new();
        let (mut k, _, yb) = daxpy_kernel(&mut mem, 16);
        k.quirk = Quirk::MilcOuterLoop;
        let c = compile(&k, Target::Sve);
        assert!(c.vectorized);
        // correctness preserved despite the bad decision
        let mut ex = Executor::new(256, mem);
        ex.run(&c.program, 10_000_000).unwrap();
        for i in 0..16 {
            assert_eq!(ex.mem.read_f64(yb + 8 * i).unwrap(), 3.0 * i as f64 + 100.0 + i as f64);
        }
        // and the program indeed contains gathers
        let gathers = c
            .program
            .insts
            .iter()
            .filter(|i| matches!(i, Inst::SveLdGather { .. }))
            .count();
        assert!(gathers > 0, "quirk must produce gathered code");
    }
}
