//! The vectorizer's legality + profitability analysis (§3).
//!
//! Two targets with deliberately different capabilities:
//!
//! * **NEON** models the ca.-2016 Advanced SIMD auto-vectorizer the paper
//!   compares against: no predication (so any conditional assignment or
//!   data-dependent exit blocks it — §5 HACCmk), no gather (so any
//!   non-contiguous access blocks it), no speculative loads (strlen), no
//!   strictly-ordered reductions.
//! * **SVE** implements §3: if-conversion to predication, while-based
//!   loop control, gather/scatter, first-faulting speculative
//!   vectorization, and ordered reductions — gated only by a
//!   profitability estimate (gathers are *cracked*, §4, so gather-dense
//!   loops may still be unprofitable, which is what keeps our CoMD proxy
//!   scalar, §5).

use super::ir::*;

/// Per-element cost weights (in rough µops; documented in DESIGN.md).
pub mod cost {
    pub const MEM: f64 = 1.0;
    pub const ARITH: f64 = 1.0;
    pub const DIV: f64 = 4.0;
    pub const OPAQUE: f64 = 20.0;
    /// scalar conditional assignment: compare + branch
    pub const SELECT_SCALAR: f64 = 2.0;
    /// vector conditional assignment: compare + sel (per vector)
    pub const SELECT_VEC: f64 = 2.0;
    /// cracked gather/scatter element (§4): address gen + port slot
    pub const GATHER_ELEM: f64 = 2.0;
    /// scalar interleaved-complex product lane: 4 loads, a multiply, an
    /// FMLA and the parity branch
    pub const CMUL_SCALAR: f64 = 4.0 * MEM + 2.0 * ARITH + SELECT_SCALAR;
    /// vector interleaved-complex product lane: 6 shifted contiguous
    /// loads, FMUL+FMLA per parity arm, and the lane select
    pub const CMUL_VEC: f64 = 6.0 * MEM + 4.0 * ARITH + SELECT_VEC;
}

/// Why a loop was not vectorized (mirrors real -Rpass-missed output).
pub type WhyNot = String;

#[derive(Clone, Debug, Default)]
struct Counts {
    contig_loads: usize,
    contig_stores: usize,
    gather: usize,
    scatter: usize,
    arith: usize,
    divsqrt: usize,
    selects: usize,
    opaque: usize,
    cmps: usize,
    cmul: usize,
}

fn count_expr(e: &Expr, c: &mut Counts) {
    e.visit(&mut |n| match n {
        Expr::Load { idx, .. } => match idx {
            Index::Affine { .. } => c.contig_loads += 1,
            Index::Strided { .. } => c.gather += 1,
            // indirect = one contiguous index load + one gather
            Index::Indirect { .. } => {
                c.contig_loads += 1;
                c.gather += 1;
            }
        },
        Expr::Bin { op, .. } => {
            if matches!(op, BinOp::Div) {
                c.divsqrt += 1;
            } else {
                c.arith += 1;
            }
        }
        Expr::Un { op, .. } => {
            if matches!(op, UnOp::Sqrt) {
                c.divsqrt += 1;
            } else {
                c.arith += 1;
            }
        }
        Expr::Cmp { .. } => c.cmps += 1,
        Expr::Select { .. } => c.selects += 1,
        Expr::Opaque { .. } => c.opaque += 1,
        // one multiply-accumulate instruction (operands counted by the
        // recursive visit)
        Expr::Fma { .. } => c.arith += 1,
        Expr::ComplexMul { .. } => c.cmul += 1,
        _ => {}
    });
}

fn count_kernel(k: &Kernel) -> Counts {
    let mut c = Counts::default();
    for e in k.all_exprs() {
        count_expr(e, &mut c);
    }
    for s in &k.body {
        if let Stmt::Store { idx, .. } = s {
            match idx {
                Index::Affine { .. } => c.contig_stores += 1,
                Index::Strided { .. } | Index::Indirect { .. } => c.scatter += 1,
            }
        }
    }
    // reductions cost one arith per element
    c.arith += k.reductions.len();
    c
}

/// Scalar per-element cost estimate.
fn scalar_cost(c: &Counts) -> f64 {
    (c.contig_loads + c.contig_stores + c.gather + c.scatter) as f64 * cost::MEM
        + c.arith as f64 * cost::ARITH
        + c.divsqrt as f64 * cost::DIV
        + c.selects as f64 * cost::SELECT_SCALAR
        + c.cmps as f64 * cost::ARITH
        + c.opaque as f64 * cost::OPAQUE
        + c.cmul as f64 * cost::CMUL_SCALAR
}

/// SVE per-element cost at the conservative minimum VL (the compiler
/// cannot assume more than 128 bits — §3.1).
fn sve_cost(c: &Counts, lanes_min: f64) -> f64 {
    ((c.contig_loads + c.contig_stores) as f64 * cost::MEM
        + c.arith as f64 * cost::ARITH
        + c.divsqrt as f64 * cost::DIV
        + (c.selects + c.cmps) as f64 * cost::SELECT_VEC
        + c.cmul as f64 * cost::CMUL_VEC)
        / lanes_min
        + (c.gather + c.scatter) as f64 * cost::GATHER_ELEM
}

/// NEON legality (ca.-2016 model).
pub fn neon_legal(k: &Kernel) -> Result<(), WhyNot> {
    if k.has_break() {
        return Err("loop has data-dependent exit; cannot vectorize without \
                    speculative (first-faulting) loads"
            .into());
    }
    let c = count_kernel(k);
    if c.selects > 0 || c.cmps > 0 {
        return Err("conditional assignment in loop body inhibits Advanced \
                    SIMD vectorization (no per-lane predication)"
            .into());
    }
    if c.gather > 0 || c.scatter > 0 {
        return Err("non-contiguous (strided/indirect) access; Advanced SIMD \
                    has no gather/scatter"
            .into());
    }
    if c.opaque > 0 {
        return Err("call to scalar math library".into());
    }
    if c.cmul > 0 {
        return Err("interleaved complex multiply needs lane-rotating \
                    fused multiply-add (FCMLA); not in ARMv8.0 Advanced SIMD"
            .into());
    }
    if k.reductions.iter().any(|r| matches!(r.kind, RedKind::OrderedSumF)) {
        return Err("reduction requires strictly-ordered FP accumulation".into());
    }
    if k.reductions.iter().any(|r| matches!(r.kind, RedKind::XorI | RedKind::MaxF)) {
        return Err("unsupported horizontal reduction kind".into());
    }
    Ok(())
}

/// SVE legality + profitability.
pub fn sve_legal(k: &Kernel) -> Result<(), WhyNot> {
    // scatter-accumulate through an index array (A[idx[i]] op= v) may
    // carry an intra-vector output dependence when idx has duplicates;
    // SVE1 has no conflict-detection support, so the vectorizer must
    // reject it (the CoMD situation: AoS neighbour-list force update)
    for s in &k.body {
        if let Stmt::Store { arr, idx: Index::Indirect { .. } | Index::Strided { .. }, value } = s {
            let mut reads_target = false;
            value.visit(&mut |n| {
                if let Expr::Load { arr: a, .. } = n {
                    if a == arr {
                        reads_target = true;
                    }
                }
            });
            if reads_target {
                return Err("possible intra-vector output dependence: \
                            indexed store reads its own target array \
                            (no conflict-detection support)"
                    .into());
            }
        }
    }
    let c = count_kernel(k);
    if c.opaque > 0 {
        // §5: "the toolchain ... did not have vectorized versions of some
        // basic math library functions such as pow() and log()"
        return Err("call to scalar math library (no vector libm)".into());
    }
    if c.cmul > 0 && k.has_break() {
        // the speculative (first-faulting) loop form probes contiguous
        // loads only; the complex-multiply lowering's shifted neighbour
        // loads are not represented there
        return Err("complex multiply under a data-dependent exit; \
                    speculative form not supported"
            .into());
    }
    let lanes_min = (128 / (k.elem_ty.bytes() * 8)) as f64;
    let sc = scalar_cost(&c);
    let vc = sve_cost(&c, lanes_min);
    if vc >= sc {
        return Err(format!(
            "not profitable at minimum vector length: vector cost {vc:.2} \
             >= scalar cost {sc:.2} per element (gathers are cracked, §4)"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn daxpy_kernel() -> Kernel {
        let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(100));
        let x = k.array("x", Ty::F64, 0x1000);
        let y = k.array("y", Ty::F64, 0x9000);
        k.body.push(Stmt::Store {
            arr: y,
            idx: Index::Affine { offset: 0 },
            value: Expr::bin(
                BinOp::Add,
                Expr::bin(
                    BinOp::Mul,
                    Expr::ConstF(3.0),
                    Expr::load(x, Index::Affine { offset: 0 }),
                ),
                Expr::load(y, Index::Affine { offset: 0 }),
            ),
        });
        k
    }

    #[test]
    fn daxpy_vectorizes_everywhere() {
        let k = daxpy_kernel();
        assert!(neon_legal(&k).is_ok());
        assert!(sve_legal(&k).is_ok());
    }

    #[test]
    fn conditional_assignment_blocks_neon_not_sve() {
        // the HACCmk situation (§5)
        let mut k = daxpy_kernel();
        if let Stmt::Store { value, .. } = &mut k.body[0] {
            *value = Expr::select(
                Expr::cmp(CmpKind::Lt, value.clone(), Expr::ConstF(10.0)),
                value.clone(),
                Expr::ConstF(0.0),
            );
        }
        assert!(neon_legal(&k).unwrap_err().contains("conditional assignment"));
        assert!(sve_legal(&k).is_ok());
    }

    #[test]
    fn data_dependent_exit_blocks_neon() {
        let mut k = Kernel::new("strlen", Ty::U8, Trip::DataDependent { max: 1 << 20 });
        let s = k.array("s", Ty::U8, 0x1000);
        k.body.push(Stmt::Break {
            cond: Expr::cmp(
                CmpKind::Eq,
                Expr::load(s, Index::Affine { offset: 0 }),
                Expr::ConstI(0),
            ),
        });
        assert!(neon_legal(&k).unwrap_err().contains("data-dependent exit"));
        assert!(sve_legal(&k).is_ok(), "first-faulting loads make this legal");
    }

    #[test]
    fn gather_blocks_neon() {
        let mut k = daxpy_kernel();
        if let Stmt::Store { value, .. } = &mut k.body[0] {
            *value = Expr::load(0, Index::Strided { scale: 2, offset: 0 });
        }
        assert!(neon_legal(&k).unwrap_err().contains("gather"));
    }

    #[test]
    fn gather_dense_loop_unprofitable_for_sve() {
        // the CoMD situation: nearly every access is a (cracked) gather
        let mut k = Kernel::new("comd", Ty::F64, Trip::Count(100));
        let pos = k.array("pos", Ty::F64, 0x1000);
        let mut sum = Expr::ConstF(0.0);
        for c in 0..3 {
            sum = Expr::bin(
                BinOp::Add,
                sum,
                Expr::load(pos, Index::Strided { scale: 3, offset: c }),
            );
        }
        k.reductions.push(Reduction { kind: RedKind::SumF, value: sum });
        let err = sve_legal(&k).unwrap_err();
        assert!(err.contains("not profitable"), "{err}");
    }

    #[test]
    fn opaque_call_blocks_both() {
        let mut k = daxpy_kernel();
        if let Stmt::Store { value, .. } = &mut k.body[0] {
            *value = Expr::Opaque {
                f: crate::isa::OpaqueFn::Log,
                args: vec![Expr::load(0, Index::Affine { offset: 0 })],
            };
        }
        assert!(neon_legal(&k).is_err());
        assert!(sve_legal(&k).unwrap_err().contains("libm"), "EP situation");
    }

    #[test]
    fn dot_product_reduction_vectorizes_everywhere() {
        // the oneDAL covariance shape: acc += x[i]*y[i]
        let mut k = Kernel::new("dot", Ty::F64, Trip::Count(100));
        let x = k.array("x", Ty::F64, 0x1000);
        let y = k.array("y", Ty::F64, 0x9000);
        k.reductions.push(Reduction {
            kind: RedKind::DotF,
            value: Expr::bin(
                BinOp::Mul,
                Expr::load(x, Index::Affine { offset: 0 }),
                Expr::load(y, Index::Affine { offset: 0 }),
            ),
        });
        assert!(neon_legal(&k).is_ok(), "FMLA-based dot reductions are NEON-legal");
        assert!(sve_legal(&k).is_ok());
    }

    #[test]
    fn fma_chain_vectorizes_everywhere() {
        // the oneDAL L2-distance shape: nested multiply-accumulates
        let mut k = daxpy_kernel();
        if let Stmt::Store { value, .. } = &mut k.body[0] {
            let d = Expr::bin(
                BinOp::Sub,
                Expr::load(0, Index::Affine { offset: 0 }),
                Expr::ConstF(0.5),
            );
            *value = Expr::fma(
                d.clone(),
                d.clone(),
                Expr::bin(BinOp::Mul, d.clone(), d),
            );
        }
        assert!(neon_legal(&k).is_ok());
        assert!(sve_legal(&k).is_ok());
    }

    #[test]
    fn complex_multiply_blocks_neon_not_sve() {
        // the SU(3) shape: interleaved re/im product lanes
        let mut k = Kernel::new("su3", Ty::F32, Trip::Count(128));
        let u = k.array("u", Ty::F32, 0x1000);
        let v = k.array("v", Ty::F32, 0x9000);
        let c = k.array("c", Ty::F32, 0xF000);
        k.body.push(Stmt::Store {
            arr: c,
            idx: Index::Affine { offset: 0 },
            value: Expr::ComplexMul { a_arr: u, a_off: 1, b_arr: v, b_off: 1, conj: false },
        });
        assert!(neon_legal(&k).unwrap_err().contains("FCMLA"));
        assert!(sve_legal(&k).is_ok(), "{:?}", sve_legal(&k));
    }

    #[test]
    fn complex_multiply_under_break_blocks_sve() {
        let mut k = Kernel::new("su3brk", Ty::F32, Trip::DataDependent { max: 1 << 20 });
        let u = k.array("u", Ty::F32, 0x1000);
        let v = k.array("v", Ty::F32, 0x9000);
        k.body.push(Stmt::Break {
            cond: Expr::cmp(
                CmpKind::Eq,
                Expr::ComplexMul { a_arr: u, a_off: 1, b_arr: v, b_off: 1, conj: true },
                Expr::ConstF(0.0),
            ),
        });
        assert!(sve_legal(&k).unwrap_err().contains("data-dependent"));
    }

    #[test]
    fn ordered_reduction_blocks_neon_only() {
        let mut k = daxpy_kernel();
        k.body.clear();
        k.reductions.push(Reduction {
            kind: RedKind::OrderedSumF,
            value: Expr::load(0, Index::Affine { offset: 0 }),
        });
        assert!(neon_legal(&k).unwrap_err().contains("ordered"));
        assert!(sve_legal(&k).is_ok(), "fadda makes this legal (§3.3)");
    }
}
