//! Sweep coordinator: the driver behind the paper's headline experiment.
//!
//! The Fig. 8 sweep is a (benchmark × ISA × VL) job matrix; the
//! design-space sweep behind `sve dse` adds a fourth axis, the
//! microarchitecture variant ([`crate::uarch::UarchVariant`]). This
//! module turns that matrix into an explicit list of [`Job`]s, shards
//! it across a self-scheduling thread pool ([`run_dse`]), validates
//! every run's architectural results, and — when an output directory is
//! configured — persists each job's [`RunRecord`] under a content-hash
//! key so later invocations can **resume** instead of re-simulating
//! (see [`crate::report::store`]).
//!
//! Entry points, from low to high level:
//!
//! * [`run_one`] / [`run_compiled`] — one (workload, ISA, VL) job.
//! * [`run_fig8_sequential`] — the plain in-process reference loop; the
//!   sharded engine is pinned bit-identical to it by tests.
//! * [`run_sweep`] — the Fig. 8 production driver: sharded, resumable,
//!   cache-aware, at one microarchitecture. [`run_fig8`] is the
//!   convenience wrapper used by tests and benches.
//! * [`run_dse`] — the full design-space driver: the same engine over
//!   (variant × benchmark × ISA × VL). [`run_sweep`] is exactly
//!   [`run_dse`] with a single variant.
//!
//! Determinism is the load-bearing property: the simulator is fully
//! deterministic, every job is independent, and results are assembled
//! in matrix order — so thread count, scheduling order, and cache hits
//! cannot change a single reported number. Rendering of the collected
//! rows into JSON/CSV/Markdown artifacts lives in [`crate::report`].

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::compiler::{Compiled, Target};
use crate::exec::{Engine, Executor};
use crate::report::store::{job_key, JobStore};
use crate::uarch::{run_timed_decoded_engine, PpaCounters, UarchConfig, UarchVariant};
use crate::workloads::{self, Group, Workload};

/// One simulated configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Neon,
    Sve(usize), // vector length in bits
}

impl Isa {
    pub fn target(self) -> Target {
        match self {
            Isa::Scalar => Target::Scalar,
            Isa::Neon => Target::Neon,
            Isa::Sve(_) => Target::Sve,
        }
    }

    pub fn vl(self) -> usize {
        match self {
            Isa::Sve(v) => v,
            _ => 128,
        }
    }

    pub fn label(self) -> String {
        match self {
            Isa::Scalar => "scalar".into(),
            Isa::Neon => "neon".into(),
            Isa::Sve(v) => format!("sve{v}"),
        }
    }

    /// Inverse of [`Isa::label`]: `"scalar"`, `"neon"`, or `"sve<bits>"`.
    ///
    /// ```
    /// use sve_repro::coordinator::Isa;
    /// assert_eq!(Isa::parse_label("sve512"), Some(Isa::Sve(512)));
    /// assert_eq!(Isa::parse_label("neon"), Some(Isa::Neon));
    /// assert_eq!(Isa::parse_label("avx"), None);
    /// ```
    pub fn parse_label(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "neon" => Some(Isa::Neon),
            _ => {
                let bits = s.strip_prefix("sve")?;
                bits.parse::<usize>().ok().map(Isa::Sve)
            }
        }
    }
}

/// One run's record.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub bench: &'static str,
    pub group: Group,
    pub isa: Isa,
    pub cycles: u64,
    pub insts: u64,
    pub vector_fraction: f64,
    pub vectorized: bool,
    pub l1d_miss_rate: f64,
    pub ipc: f64,
    /// Raw pipeline event counters behind the §PPA energy proxy
    /// ([`crate::uarch::ppa`]); persisted in every job file so cached
    /// runs can be re-ranked without re-simulating.
    pub counters: PpaCounters,
}

/// Run one workload on one configuration, with output validation.
///
/// ```
/// use sve_repro::coordinator::{run_one, Isa};
/// // HACCmk is the paper's flagship: NEON cannot vectorize the
/// // conditional assignments, SVE if-converts them (§5).
/// let neon = run_one("haccmk", Isa::Neon).unwrap();
/// let sve = run_one("haccmk", Isa::Sve(256)).unwrap();
/// assert!(!neon.vectorized && sve.vectorized);
/// assert!(sve.cycles < neon.cycles);
/// ```
pub fn run_one(name: &'static str, isa: Isa) -> Result<RunRecord, String> {
    run_one_engine(name, isa, Engine::default())
}

/// [`run_one`] on an explicit functional engine (the CLI's `--no-trace`
/// escape hatch selects [`Engine::Baseline`] here for A/B runs).
pub fn run_one_engine(name: &'static str, isa: Isa, engine: Engine) -> Result<RunRecord, String> {
    run_one_engine_stats(name, isa, engine).map(|(r, _)| r)
}

/// [`run_one_engine`], also returning the raw [`crate::exec::RunStats`]
/// (trace-cache telemetry included) for `sve run --trace-stats`.
pub fn run_one_engine_stats(
    name: &'static str,
    isa: Isa,
    engine: Engine,
) -> Result<(RunRecord, crate::exec::RunStats), String> {
    let w = workloads::build(name);
    let compiled = w.compile(isa.target());
    run_compiled_engine_stats(&w, &compiled, isa, &UarchConfig::default(), engine)
}

/// [`run_compiled_with`] at the paper's Table 2 configuration.
pub fn run_compiled(w: &Workload, compiled: &Compiled, isa: Isa) -> Result<RunRecord, String> {
    run_compiled_with(w, compiled, isa, &UarchConfig::default())
}

/// [`run_compiled_engine_with`] on the default (trace) engine.
pub fn run_compiled_with(
    w: &Workload,
    compiled: &Compiled,
    isa: Isa,
    cfg: &UarchConfig,
) -> Result<RunRecord, String> {
    run_compiled_engine_with(w, compiled, isa, cfg, Engine::default())
}

/// Run an already-built workload with an already-compiled program.
/// SVE binaries are vector-length agnostic (§2.2), so a sweep compiles
/// **and decodes** each (benchmark, target) once and reuses the µop
/// program ([`Compiled::decoded`]) at every VL and µarch variant — only
/// the executor's hardware VL and the timing configuration change
/// between runs. The functional [`Engine`] never enters a job's cache
/// key: both engines retire the same stream (pinned by `exec/trace.rs`
/// tests), so trace-engine and baseline runs share cache entries.
pub fn run_compiled_engine_with(
    w: &Workload,
    compiled: &Compiled,
    isa: Isa,
    cfg: &UarchConfig,
    engine: Engine,
) -> Result<RunRecord, String> {
    run_compiled_engine_stats(w, compiled, isa, cfg, engine).map(|(r, _)| r)
}

/// [`run_compiled_engine_with`], also returning the raw
/// [`crate::exec::RunStats`] — the carrier of the trace-cache telemetry
/// ([`crate::exec::TraceStats`]) behind `sve run --trace-stats` and the
/// hotpath bench. The telemetry never enters the [`RunRecord`] (job
/// cache files stay engine-agnostic).
pub fn run_compiled_engine_stats(
    w: &Workload,
    compiled: &Compiled,
    isa: Isa,
    cfg: &UarchConfig,
    engine: Engine,
) -> Result<(RunRecord, crate::exec::RunStats), String> {
    let name = w.name;
    let mut ex = Executor::new(isa.vl(), w.mem.clone());
    let (stats, timing) =
        run_timed_decoded_engine(&mut ex, &compiled.decoded, engine, cfg.clone(), w.max_insts)
            .map_err(|e| format!("{name}/{}: trap {e:?}", isa.label()))?;
    w.verify(&ex.mem).map_err(|e| format!("{name}/{}: {e}", isa.label()))?;
    let mem_accesses = timing.l1d_hits + timing.l1d_misses;
    let record = RunRecord {
        bench: name,
        group: w.group,
        isa,
        cycles: timing.cycles,
        insts: stats.insts,
        vector_fraction: stats.vector_fraction(),
        vectorized: compiled.vectorized,
        l1d_miss_rate: if mem_accesses == 0 {
            0.0
        } else {
            timing.l1d_misses as f64 / mem_accesses as f64
        },
        ipc: timing.ipc(),
        counters: PpaCounters {
            l1d_accesses: mem_accesses,
            l2_accesses: timing.l1d_misses,
            mem_accesses: timing.l2_misses,
            mispredicts: timing.mispredicts,
            cracked_elems: timing.cracked_elems,
            pf_issued: timing.pf_issued,
            pf_useful: timing.pf_useful,
            dram_channel_cycles: timing.dram_channel_cycles,
            class_counts: timing.class_counts,
        },
    };
    Ok((record, stats))
}

/// The Fig. 8 data for one benchmark.
#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub bench: &'static str,
    pub group: Group,
    pub neon: RunRecord,
    pub sve: Vec<RunRecord>, // one per VL
    /// extra vectorization: SVE@128 dynamic vector fraction minus NEON's
    pub extra_vectorization: f64,
}

impl Fig8Row {
    pub fn speedup(&self, i: usize) -> f64 {
        self.neon.cycles as f64 / self.sve[i].cycles as f64
    }
}

/// One cell of the sweep's job matrix.
#[derive(Clone, Copy, Debug)]
pub struct Job {
    pub bench: &'static str,
    pub isa: Isa,
    /// Index into the sweep's variant list (always 0 for [`run_sweep`]).
    pub variant: usize,
}

/// Expand a sweep into its deterministic job matrix: variant-major,
/// then benchmark-major, NEON before the SVE points in `vls` order.
/// This is the one expansion shared by the batch drivers ([`run_dse`])
/// and the `sve serve` hub — every consumer agrees on what a request
/// *means* because they agree on this list.
///
/// ```
/// use sve_repro::coordinator::{job_matrix, Isa};
/// let jobs = job_matrix(&["haccmk"], &[128, 256], 2);
/// assert_eq!(jobs.len(), 2 * 1 * (1 + 2)); // variants × benches × (NEON + VLs)
/// assert_eq!(jobs[0].isa, Isa::Neon);
/// assert_eq!(jobs[1].isa, Isa::Sve(128));
/// assert_eq!(jobs[3].variant, 1);
/// ```
pub fn job_matrix(names: &[&'static str], vls: &[usize], n_variants: usize) -> Vec<Job> {
    let stride = 1 + vls.len(); // jobs per benchmark
    let block = names.len() * stride; // jobs per variant
    let mut jobs: Vec<Job> = Vec::with_capacity(n_variants * block);
    for vi in 0..n_variants {
        for &name in names {
            jobs.push(Job { bench: name, isa: Isa::Neon, variant: vi });
            for &vl in vls {
                jobs.push(Job { bench: name, isa: Isa::Sve(vl), variant: vi });
            }
        }
    }
    jobs
}

/// Configuration for [`run_sweep`].
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// SVE vector lengths to sweep (bits). Must be non-empty.
    pub vls: Vec<usize>,
    /// Benchmarks to run (subset of [`workloads::NAMES`]).
    pub names: Vec<&'static str>,
    /// Worker threads; `0` means one per available CPU.
    pub jobs: usize,
    /// Reuse job files already present in `out_dir` instead of
    /// re-simulating. Without an `out_dir` this is a no-op.
    pub resume: bool,
    /// Where to persist per-job records (under `<out_dir>/jobs/`).
    /// `None` disables persistence (pure in-memory sweep).
    pub out_dir: Option<PathBuf>,
    /// Timing-model parameters; part of every job's cache key.
    pub uarch: UarchConfig,
    /// Functional engine running each job. Deliberately **not** part of
    /// the job cache key: engines are bit-identical (architectural state
    /// and every timing counter), so cached records are engine-agnostic.
    pub engine: Engine,
}

impl SweepConfig {
    /// An in-memory, non-resumable sweep at the Table 2 configuration.
    pub fn new(vls: &[usize], names: &[&'static str]) -> SweepConfig {
        SweepConfig {
            vls: vls.to_vec(),
            names: names.to_vec(),
            jobs: 0,
            resume: false,
            out_dir: None,
            uarch: UarchConfig::default(),
            engine: Engine::default(),
        }
    }
}

/// What [`run_sweep`] did, beyond the rows themselves.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub rows: Vec<Fig8Row>,
    /// Jobs actually simulated this invocation.
    pub simulated: usize,
    /// Jobs reloaded from the on-disk cache.
    pub reloaded: usize,
}

/// One microarchitecture variant's complete Fig. 8 row set within a
/// design-space sweep.
#[derive(Clone, Debug)]
pub struct VariantRows {
    /// Display name, e.g. `table2` or `small-core+l2_bytes=524288`.
    pub name: String,
    /// The configuration the rows were timed under.
    pub uarch: UarchConfig,
    pub rows: Vec<Fig8Row>,
}

/// What [`run_dse`] did: per-variant rows, in the variant order given.
#[derive(Clone, Debug)]
pub struct DseOutcome {
    pub variants: Vec<VariantRows>,
    /// Jobs actually simulated this invocation.
    pub simulated: usize,
    /// Jobs reloaded from the on-disk cache.
    pub reloaded: usize,
}

pub(crate) fn worker_count(requested: usize, pending: usize) -> usize {
    let n = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    n.clamp(1, pending.max(1))
}

/// The Fig. 8 production sweep driver: [`run_dse`] at a single
/// microarchitecture point (`cfg.uarch`). Results are deterministic and
/// independent of `jobs`, scheduling order, and cache state (pinned by
/// tests against [`run_fig8_sequential`]).
pub fn run_sweep(cfg: &SweepConfig) -> Result<SweepOutcome, String> {
    // label the single design point honestly in diagnostics: "table2"
    // only when it actually is the paper's configuration
    let name = if cfg.uarch == UarchConfig::default() { "table2" } else { "custom" };
    let variant = UarchVariant { name: name.into(), cfg: cfg.uarch.clone() };
    let mut dse = run_dse(cfg, std::slice::from_ref(&variant))?;
    let rows = dse.variants.pop().expect("single-variant sweep has one row set").rows;
    Ok(SweepOutcome { rows, simulated: dse.simulated, reloaded: dse.reloaded })
}

/// The design-space sweep driver: shard the full
/// (variant × benchmark × ISA × VL) job matrix across one
/// self-scheduling thread pool, reusing cached job records when
/// resuming. `cfg.uarch` is ignored — each job is timed under its
/// variant's configuration, and each job's cache key covers that
/// configuration (`job_key`), so design points never collide in
/// `<out>/jobs/` and a `table2` variant shares cache entries with plain
/// `sve sweep` runs over the same matrix.
///
/// Workloads are built and programs compiled **and decoded once per
/// benchmark**, shared read-only across every variant and VL — the
/// decoded µop stream ([`Compiled::decoded`]) depends only on the
/// target ISA, never on the timing model or VL, and SVE binaries are
/// VL-agnostic (§2.2).
pub fn run_dse(cfg: &SweepConfig, variants: &[UarchVariant]) -> Result<DseOutcome, String> {
    if cfg.vls.is_empty() {
        return Err("sweep needs at least one vector length".into());
    }
    if cfg.names.is_empty() {
        return Err("sweep needs at least one benchmark".into());
    }
    if variants.is_empty() {
        return Err("design-space sweep needs at least one µarch variant".into());
    }
    for &vl in &cfg.vls {
        if !crate::vl_is_legal(vl) {
            return Err(format!("illegal SVE vector length {vl} (§2.2: 128..2048, step 128)"));
        }
    }
    for &name in &cfg.names {
        if !workloads::NAMES.contains(&name) {
            return Err(format!("unknown benchmark '{name}'"));
        }
    }
    // same rules as parse_variants: unique names, unique configs,
    // realizable geometry (an unrealizable one panics every worker) —
    // API callers constructing variants directly get an Err, not a panic
    crate::uarch::check_variants(variants)?;
    let store = match &cfg.out_dir {
        Some(dir) => {
            Some(JobStore::open(dir).map_err(|e| format!("open job store in {dir:?}: {e}"))?)
        }
        None => None,
    };

    // the job matrix, in deterministic (variant-major, then bench-major,
    // NEON first) order — the same expansion `sve serve` streams from
    let stride = 1 + cfg.vls.len(); // jobs per benchmark
    let block = cfg.names.len() * stride; // jobs per variant
    let jobs = job_matrix(&cfg.names, &cfg.vls, variants.len());

    // resume pass: adopt every valid cached record
    let mut records: Vec<Option<RunRecord>> = vec![None; jobs.len()];
    let mut pending: Vec<usize> = Vec::new();
    let mut reloaded = 0usize;
    for (i, job) in jobs.iter().enumerate() {
        if cfg.resume {
            if let Some(st) = &store {
                let key = job_key(job.bench, job.isa, &variants[job.variant].cfg);
                if let Some(r) = st.load(&key, job.bench, job.isa) {
                    records[i] = Some(r);
                    reloaded += 1;
                    continue;
                }
            }
        }
        pending.push(i);
    }

    // build each workload and compile each needed target ONCE per
    // benchmark, shared read-only across all of its jobs — across every
    // variant too, since programs don't depend on the timing model.
    // Benchmarks whose jobs were all reloaded from cache skip this.
    struct Prep {
        w: Workload,
        neon: Compiled,
        sve: Compiled,
    }
    let mut preps: Vec<Option<Prep>> = Vec::with_capacity(cfg.names.len());
    for (bi, &name) in cfg.names.iter().enumerate() {
        if pending.iter().any(|&i| (i % block) / stride == bi) {
            let w = workloads::build(name);
            let neon = w.compile(Target::Neon);
            let sve = w.compile(Target::Sve);
            preps.push(Some(Prep { w, neon, sve }));
        } else {
            preps.push(None);
        }
    }

    // shard the remaining jobs: workers pull the next job index from a
    // shared atomic cursor until the queue is drained (self-scheduling,
    // so a slow benchmark never strands idle threads the way the old
    // one-thread-per-benchmark split did)
    let simulated = pending.len();
    let cursor = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, Result<RunRecord, String>)>> = Mutex::new(Vec::new());
    let nworkers = worker_count(cfg.jobs, pending.len());
    std::thread::scope(|s| {
        for _ in 0..nworkers {
            s.spawn(|| loop {
                let n = cursor.fetch_add(1, Ordering::Relaxed);
                if n >= pending.len() {
                    break;
                }
                let i = pending[n];
                let job = jobs[i];
                // a panicking job must fail the sweep, not abort the
                // process (thread::scope re-raises worker panics)
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> Result<RunRecord, String> {
                        let prep = preps[(i % block) / stride]
                            .as_ref()
                            .ok_or_else(|| format!("{}: missing prep", job.bench))?;
                        let compiled = match job.isa {
                            Isa::Neon => &prep.neon,
                            _ => &prep.sve,
                        };
                        let uarch = &variants[job.variant].cfg;
                        let r = run_compiled_engine_with(
                            &prep.w, compiled, job.isa, uarch, cfg.engine,
                        )?;
                        if let Some(st) = &store {
                            let key = job_key(job.bench, job.isa, uarch);
                            st.save(&key, &r).map_err(|e| {
                                format!("persist {}/{}: {e}", job.bench, job.isa.label())
                            })?;
                        }
                        Ok(r)
                    },
                ))
                .unwrap_or_else(|_| {
                    Err(format!("{}/{}: job panicked", job.bench, job.isa.label()))
                });
                done.lock().unwrap().push((i, res));
            });
        }
    });
    for (i, res) in done.into_inner().map_err(|_| "result mutex poisoned".to_string())? {
        records[i] = Some(res?);
    }

    // assemble rows in matrix order — independent of completion order
    let mut out = Vec::with_capacity(variants.len());
    for (vi, variant) in variants.iter().enumerate() {
        let mut rows = Vec::with_capacity(cfg.names.len());
        for (bi, &name) in cfg.names.iter().enumerate() {
            let base = vi * block + bi * stride;
            let neon =
                records[base].take().ok_or_else(|| format!("{name}: neon job lost"))?;
            let sve: Vec<RunRecord> = (0..cfg.vls.len())
                .map(|i| {
                    records[base + 1 + i]
                        .take()
                        .ok_or_else(|| format!("{name}: sve job {i} lost"))
                })
                .collect::<Result<_, String>>()?;
            let extra = (sve[0].vector_fraction - neon.vector_fraction).max(0.0);
            rows.push(Fig8Row {
                bench: name,
                group: neon.group,
                neon,
                sve,
                extra_vectorization: extra,
            });
        }
        out.push(VariantRows { name: variant.name.clone(), uarch: variant.cfg.clone(), rows });
    }
    Ok(DseOutcome { variants: out, simulated, reloaded })
}

/// Run the full Fig. 8 sweep (all benchmarks × NEON + SVE at `vls`)
/// on the sharded engine, without persistence.
///
/// ```
/// use sve_repro::coordinator::run_fig8;
/// let rows = run_fig8(&[128, 512], &["haccmk"]).unwrap();
/// assert!(rows[0].speedup(0) > 1.5, "SVE wins at equal VL");
/// assert!(rows[0].speedup(1) > rows[0].speedup(0), "and scales with VL");
/// ```
pub fn run_fig8(vls: &[usize], names: &[&'static str]) -> Result<Vec<Fig8Row>, String> {
    run_sweep(&SweepConfig::new(vls, names)).map(|o| o.rows)
}

/// The plain sequential in-process sweep: one loop, no threads, no
/// cache, compile-once per (benchmark, target). This is the semantic
/// reference the sharded driver is pinned against — keep it boring.
pub fn run_fig8_sequential(
    vls: &[usize],
    names: &[&'static str],
) -> Result<Vec<Fig8Row>, String> {
    let mut rows = Vec::with_capacity(names.len());
    for &name in names {
        let w = workloads::build(name);
        let compiled_neon = w.compile(Target::Neon);
        let neon = run_compiled(&w, &compiled_neon, Isa::Neon)?;
        let compiled_sve = w.compile(Target::Sve);
        let mut sve = Vec::with_capacity(vls.len());
        for &vl in vls {
            sve.push(run_compiled(&w, &compiled_sve, Isa::Sve(vl))?);
        }
        let extra = (sve[0].vector_fraction - neon.vector_fraction).max(0.0);
        rows.push(Fig8Row {
            bench: name,
            group: neon.group,
            neon,
            sve,
            extra_vectorization: extra,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_one_validates_and_times() {
        let r = run_one("stream_triad", Isa::Neon).unwrap();
        assert!(r.cycles > 0 && r.insts > 0);
        assert!(r.vectorized);
        let s = run_one("stream_triad", Isa::Scalar).unwrap();
        assert!(!s.vectorized);
        assert!(
            s.cycles > r.cycles,
            "NEON must beat scalar on a streaming kernel: {} vs {}",
            s.cycles,
            r.cycles
        );
    }

    #[test]
    fn compile_once_sweep_is_bit_identical_to_per_run_compile() {
        // reusing one compiled SVE program across VLs (VLA, §2.2) must
        // not change any reported number
        let rows = run_fig8_sequential(&[128, 512], &["stream_triad"]).unwrap();
        let d128 = run_one("stream_triad", Isa::Sve(128)).unwrap();
        let d512 = run_one("stream_triad", Isa::Sve(512)).unwrap();
        assert_eq!(rows[0].sve[0].cycles, d128.cycles);
        assert_eq!(rows[0].sve[1].cycles, d512.cycles);
        assert_eq!(rows[0].sve[0].insts, d128.insts);
        assert_eq!(rows[0].sve[0].vector_fraction, d128.vector_fraction);
    }

    #[test]
    fn sharded_sweep_is_bit_identical_to_sequential() {
        let vls = [128usize, 512];
        let names = ["stream_triad", "graph500"];
        let seq = run_fig8_sequential(&vls, &names).unwrap();
        let mut cfg = SweepConfig::new(&vls, &names);
        cfg.jobs = 3; // deliberately not a divisor of the 6-job matrix
        let out = run_sweep(&cfg).unwrap();
        assert_eq!(out.simulated, 6);
        assert_eq!(out.reloaded, 0);
        for (a, b) in seq.iter().zip(&out.rows) {
            assert_eq!(a.bench, b.bench);
            assert_eq!(a.neon.cycles, b.neon.cycles);
            assert_eq!(a.extra_vectorization.to_bits(), b.extra_vectorization.to_bits());
            for (ra, rb) in a.sve.iter().zip(&b.sve) {
                assert_eq!(ra.cycles, rb.cycles);
                assert_eq!(ra.insts, rb.insts);
                assert_eq!(ra.vector_fraction.to_bits(), rb.vector_fraction.to_bits());
                assert_eq!(ra.ipc.to_bits(), rb.ipc.to_bits());
            }
        }
    }

    #[test]
    fn baseline_engine_sweep_is_bit_identical_to_trace_engine() {
        // the whole reason the engine stays out of job_key: every
        // reported number must be engine-independent
        let vls = [128usize, 512];
        let names = ["stream_triad", "haccmk"];
        let mut cfg = SweepConfig::new(&vls, &names);
        assert_eq!(cfg.engine, Engine::Trace, "trace engine is the default");
        let traced = run_sweep(&cfg).unwrap();
        cfg.engine = Engine::Baseline;
        let base = run_sweep(&cfg).unwrap();
        for (a, b) in traced.rows.iter().zip(&base.rows) {
            assert_eq!(a.neon.cycles, b.neon.cycles);
            assert_eq!(a.neon.counters, b.neon.counters);
            for (ra, rb) in a.sve.iter().zip(&b.sve) {
                assert_eq!(ra.cycles, rb.cycles);
                assert_eq!(ra.insts, rb.insts);
                assert_eq!(ra.vector_fraction.to_bits(), rb.vector_fraction.to_bits());
                assert_eq!(ra.counters, rb.counters);
            }
        }
    }

    #[test]
    fn sweep_rejects_bad_matrix() {
        assert!(run_sweep(&SweepConfig::new(&[], &["haccmk"])).is_err());
        assert!(run_sweep(&SweepConfig::new(&[256], &[])).is_err());
        assert!(run_sweep(&SweepConfig::new(&[192], &["haccmk"])).is_err());
        // unknown names are an Err, not a worker panic/abort
        assert!(run_sweep(&SweepConfig::new(&[256], &["nosuchbench"])).is_err());
        // and the variant axis rejects empty/duplicate variant lists
        let cfg = SweepConfig::new(&[256], &["haccmk"]);
        assert!(run_dse(&cfg, &[]).is_err());
        let v = UarchVariant { name: "table2".into(), cfg: UarchConfig::default() };
        assert!(run_dse(&cfg, &[v.clone(), v]).is_err());
    }

    #[test]
    fn dse_table2_variant_is_bit_identical_to_plain_sweep() {
        let vls = [128usize, 512];
        let names = ["stream_triad", "haccmk"];
        let cfg = SweepConfig::new(&vls, &names);
        let plain = run_sweep(&cfg).unwrap();
        let variants = crate::uarch::parse_variants("table2,small-core").unwrap();
        let dse = run_dse(&cfg, &variants).unwrap();
        assert_eq!(dse.simulated, 2 * names.len() * (1 + vls.len()));
        assert_eq!(dse.variants.len(), 2);
        assert_eq!(dse.variants[0].name, "table2");
        for (a, b) in plain.rows.iter().zip(&dse.variants[0].rows) {
            assert_eq!(a.bench, b.bench);
            assert_eq!(a.neon.cycles, b.neon.cycles);
            for (ra, rb) in a.sve.iter().zip(&b.sve) {
                assert_eq!(ra.cycles, rb.cycles);
                assert_eq!(ra.insts, rb.insts);
            }
        }
        // the variant axis is real: a halved core times differently,
        // while functional results (instruction counts) are untouched
        let t2 = &dse.variants[0].rows[0];
        let small = &dse.variants[1].rows[0];
        assert_eq!(t2.neon.insts, small.neon.insts);
        assert!(
            small.neon.cycles > t2.neon.cycles,
            "small-core must be slower on stream_triad: {} vs {}",
            small.neon.cycles,
            t2.neon.cycles
        );
    }

    #[test]
    fn haccmk_shape_sve_beats_neon_and_scales() {
        // the paper's flagship example: conditional assignments mean NEON
        // runs scalar code while SVE if-converts — "speedups of up to 3x
        // even when the vectors are the same size" (§5)
        let neon = run_one("haccmk", Isa::Neon).unwrap();
        let sve128 = run_one("haccmk", Isa::Sve(128)).unwrap();
        let sve512 = run_one("haccmk", Isa::Sve(512)).unwrap();
        assert!(!neon.vectorized && sve128.vectorized);
        let sp128 = neon.cycles as f64 / sve128.cycles as f64;
        let sp512 = neon.cycles as f64 / sve512.cycles as f64;
        assert!(sp128 > 1.5, "SVE@128 must already win: {sp128:.2}");
        assert!(sp512 > sp128 * 1.3, "and scale with VL: {sp512:.2} vs {sp128:.2}");
    }

    #[test]
    fn graph500_shape_no_speedup() {
        let neon = run_one("graph500", Isa::Neon).unwrap();
        let sve = run_one("graph500", Isa::Sve(512)).unwrap();
        let sp = neon.cycles as f64 / sve.cycles as f64;
        assert!((0.95..1.05).contains(&sp), "pointer chase must not speed up: {sp:.3}");
        assert_eq!(sve.vector_fraction, 0.0);
    }

    #[test]
    fn narrowing_dram_hurts_bandwidth_bound_kernels_most() {
        // PR 9 acceptance: DRAM bandwidth is a shared finite resource,
        // so squeezing it must slow the streaming copy *relatively*
        // more than the compute-bound FMA kernel — while leaving every
        // functional result untouched.
        let run = |name: &'static str, bw: u64| {
            let cfg =
                UarchConfig { dram_bytes_per_cycle: bw, ..UarchConfig::default() };
            let w = workloads::build(name);
            let compiled = w.compile(Isa::Sve(256).target());
            run_compiled_with(&w, &compiled, Isa::Sve(256), &cfg).unwrap()
        };
        let copy_wide = run("memcpy_like", 64);
        let copy_narrow = run("memcpy_like", 4);
        let fma_wide = run("haccmk", 64);
        let fma_narrow = run("haccmk", 4);
        // the bandwidth axis is timing-only
        assert_eq!(copy_wide.insts, copy_narrow.insts);
        assert_eq!(fma_wide.insts, fma_narrow.insts);
        // narrowing never speeds anything up
        assert!(copy_narrow.cycles >= copy_wide.cycles);
        assert!(fma_narrow.cycles >= fma_wide.cycles);
        // relative slowdowns compared exactly via u128 cross-products:
        // copy_narrow/copy_wide > fma_narrow/fma_wide
        assert!(
            u128::from(copy_narrow.cycles) * u128::from(fma_wide.cycles)
                > u128::from(fma_narrow.cycles) * u128::from(copy_wide.cycles),
            "memcpy_like must suffer more than haccmk: copy {} -> {}, fma {} -> {}",
            copy_wide.cycles,
            copy_narrow.cycles,
            fma_wide.cycles,
            fma_narrow.cycles
        );
    }

    #[test]
    fn spmv_shape_vectorized_but_flat() {
        // gathers are cracked: vectorization happens, scaling does not
        let s128 = run_one("spmv_ell", Isa::Sve(128)).unwrap();
        let s1024 = run_one("spmv_ell", Isa::Sve(1024)).unwrap();
        assert!(s128.vectorized);
        let scale = s128.cycles as f64 / s1024.cycles as f64;
        assert!(scale < 2.5, "gather-bound loop must scale sub-linearly: {scale:.2}");
    }
}
