//! Sweep coordinator: runs (benchmark × ISA × VL) jobs across threads,
//! validates every run's architectural results, aggregates statistics and
//! regenerates the paper's figures/tables (Fig. 8 foremost).

use crate::compiler::{Compiled, Target};
use crate::csvutil::{f, Table};
use crate::exec::Executor;
use crate::uarch::{run_timed, UarchConfig};
use crate::workloads::{self, Group, Workload};

/// One simulated configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Neon,
    Sve(usize), // vector length in bits
}

impl Isa {
    pub fn target(self) -> Target {
        match self {
            Isa::Scalar => Target::Scalar,
            Isa::Neon => Target::Neon,
            Isa::Sve(_) => Target::Sve,
        }
    }

    pub fn vl(self) -> usize {
        match self {
            Isa::Sve(v) => v,
            _ => 128,
        }
    }

    pub fn label(self) -> String {
        match self {
            Isa::Scalar => "scalar".into(),
            Isa::Neon => "neon".into(),
            Isa::Sve(v) => format!("sve{v}"),
        }
    }
}

/// One run's record.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub bench: &'static str,
    pub group: Group,
    pub isa: Isa,
    pub cycles: u64,
    pub insts: u64,
    pub vector_fraction: f64,
    pub vectorized: bool,
    pub l1d_miss_rate: f64,
    pub ipc: f64,
}

/// Run one workload on one configuration, with output validation.
pub fn run_one(name: &'static str, isa: Isa) -> Result<RunRecord, String> {
    let w = workloads::build(name);
    let compiled = w.compile(isa.target());
    run_compiled(&w, &compiled, isa)
}

/// Run an already-built workload with an already-compiled program.
/// SVE binaries are vector-length agnostic (§2.2), so a sweep compiles
/// each (benchmark, target) once and reuses the program at every VL —
/// only the executor's hardware VL changes between runs.
pub fn run_compiled(w: &Workload, compiled: &Compiled, isa: Isa) -> Result<RunRecord, String> {
    let name = w.name;
    let mut ex = Executor::new(isa.vl(), w.mem.clone());
    let (stats, timing) =
        run_timed(&mut ex, &compiled.program, UarchConfig::default(), w.max_insts)
            .map_err(|e| format!("{name}/{}: trap {e:?}", isa.label()))?;
    w.verify(&ex.mem).map_err(|e| format!("{name}/{}: {e}", isa.label()))?;
    let mem_accesses = timing.l1d_hits + timing.l1d_misses;
    Ok(RunRecord {
        bench: name,
        group: w.group,
        isa,
        cycles: timing.cycles,
        insts: stats.insts,
        vector_fraction: stats.vector_fraction(),
        vectorized: compiled.vectorized,
        l1d_miss_rate: if mem_accesses == 0 {
            0.0
        } else {
            timing.l1d_misses as f64 / mem_accesses as f64
        },
        ipc: timing.ipc(),
    })
}

/// The Fig. 8 data for one benchmark.
#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub bench: &'static str,
    pub group: Group,
    pub neon: RunRecord,
    pub sve: Vec<RunRecord>, // one per VL
    /// extra vectorization: SVE@128 dynamic vector fraction minus NEON's
    pub extra_vectorization: f64,
}

impl Fig8Row {
    pub fn speedup(&self, i: usize) -> f64 {
        self.neon.cycles as f64 / self.sve[i].cycles as f64
    }
}

/// Run the full Fig. 8 sweep (all benchmarks × NEON + SVE at `vls`),
/// parallelized over benchmarks with std threads. Each benchmark is
/// built and compiled once per target; the same SVE program is swept
/// across every VL (vector-length agnosticism, §2.2).
pub fn run_fig8(vls: &[usize], names: &[&'static str]) -> Result<Vec<Fig8Row>, String> {
    let mut rows: Vec<Option<Fig8Row>> = (0..names.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = vec![];
        for &name in names {
            handles.push(s.spawn(move || -> Result<Fig8Row, String> {
                let w = workloads::build(name);
                let compiled_neon = w.compile(Target::Neon);
                let neon = run_compiled(&w, &compiled_neon, Isa::Neon)?;
                let compiled_sve = w.compile(Target::Sve);
                let mut sve = vec![];
                for &vl in vls {
                    sve.push(run_compiled(&w, &compiled_sve, Isa::Sve(vl))?);
                }
                let extra = (sve[0].vector_fraction - neon.vector_fraction).max(0.0);
                Ok(Fig8Row {
                    bench: name,
                    group: neon.group,
                    neon,
                    sve,
                    extra_vectorization: extra,
                })
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            rows[i] = Some(h.join().map_err(|_| "worker panicked".to_string())??);
        }
        Ok::<(), String>(())
    })?;
    Ok(rows.into_iter().map(|r| r.unwrap()).collect())
}

/// Render the Fig. 8 table (speedups + extra vectorization).
pub fn fig8_table(rows: &[Fig8Row], vls: &[usize]) -> Table {
    let mut header = vec!["bench".to_string(), "group".to_string(), "extra_vec_%".to_string()];
    for vl in vls {
        header.push(format!("speedup_sve{vl}"));
    }
    header.push("neon_cycles".into());
    let mut t = Table::new(header);
    for r in rows {
        let mut row = vec![
            r.bench.to_string(),
            format!("{:?}", r.group),
            f(100.0 * r.extra_vectorization, 1),
        ];
        for i in 0..vls.len() {
            row.push(f(r.speedup(i), 2));
        }
        row.push(r.neon.cycles.to_string());
        t.push_row(row);
    }
    t
}

/// ASCII rendition of Fig. 8: one row per benchmark, speedup bars per VL
/// plus the extra-vectorization percentage.
pub fn fig8_chart(rows: &[Fig8Row], vls: &[usize]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Fig. 8 — speedup over Advanced SIMD (bracket: extra vectorization %)\n"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<13} [{:>5.1}% extra vectorization]  {:?}",
            r.bench,
            100.0 * r.extra_vectorization,
            r.group
        );
        for (i, vl) in vls.iter().enumerate() {
            let sp = r.speedup(i);
            let bar_len = (sp * 8.0).round() as usize;
            let _ = writeln!(out, "  sve-{:<4} {:>5.2}x |{}", vl, sp, "#".repeat(bar_len.min(80)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_one_validates_and_times() {
        let r = run_one("stream_triad", Isa::Neon).unwrap();
        assert!(r.cycles > 0 && r.insts > 0);
        assert!(r.vectorized);
        let s = run_one("stream_triad", Isa::Scalar).unwrap();
        assert!(!s.vectorized);
        assert!(
            s.cycles > r.cycles,
            "NEON must beat scalar on a streaming kernel: {} vs {}",
            s.cycles,
            r.cycles
        );
    }

    #[test]
    fn compile_once_sweep_is_bit_identical_to_per_run_compile() {
        // reusing one compiled SVE program across VLs (VLA, §2.2) must
        // not change any reported number
        let rows = run_fig8(&[128, 512], &["stream_triad"]).unwrap();
        let d128 = run_one("stream_triad", Isa::Sve(128)).unwrap();
        let d512 = run_one("stream_triad", Isa::Sve(512)).unwrap();
        assert_eq!(rows[0].sve[0].cycles, d128.cycles);
        assert_eq!(rows[0].sve[1].cycles, d512.cycles);
        assert_eq!(rows[0].sve[0].insts, d128.insts);
        assert_eq!(rows[0].sve[0].vector_fraction, d128.vector_fraction);
    }

    #[test]
    fn haccmk_shape_sve_beats_neon_and_scales() {
        // the paper's flagship example: conditional assignments mean NEON
        // runs scalar code while SVE if-converts — "speedups of up to 3x
        // even when the vectors are the same size" (§5)
        let neon = run_one("haccmk", Isa::Neon).unwrap();
        let sve128 = run_one("haccmk", Isa::Sve(128)).unwrap();
        let sve512 = run_one("haccmk", Isa::Sve(512)).unwrap();
        assert!(!neon.vectorized && sve128.vectorized);
        let sp128 = neon.cycles as f64 / sve128.cycles as f64;
        let sp512 = neon.cycles as f64 / sve512.cycles as f64;
        assert!(sp128 > 1.5, "SVE@128 must already win: {sp128:.2}");
        assert!(sp512 > sp128 * 1.3, "and scale with VL: {sp512:.2} vs {sp128:.2}");
    }

    #[test]
    fn graph500_shape_no_speedup() {
        let neon = run_one("graph500", Isa::Neon).unwrap();
        let sve = run_one("graph500", Isa::Sve(512)).unwrap();
        let sp = neon.cycles as f64 / sve.cycles as f64;
        assert!((0.95..1.05).contains(&sp), "pointer chase must not speed up: {sp:.3}");
        assert_eq!(sve.vector_fraction, 0.0);
    }

    #[test]
    fn spmv_shape_vectorized_but_flat() {
        // gathers are cracked: vectorization happens, scaling does not
        let s128 = run_one("spmv_ell", Isa::Sve(128)).unwrap();
        let s1024 = run_one("spmv_ell", Isa::Sve(1024)).unwrap();
        assert!(s128.vectorized);
        let scale = s128.cycles as f64 / s1024.cycles as f64;
        assert!(scale < 2.5, "gather-bound loop must scale sub-linearly: {scale:.2}");
    }
}
