//! Tiny CSV/markdown table writers (no serde on the offline image).

use std::fmt::Write as _;
use std::path::Path;

/// A rectangular table with a header row.
///
/// The backing for every CSV/Markdown table the CLI and the report
/// emitters produce:
///
/// ```
/// use sve_repro::csvutil::Table;
/// let mut t = Table::new(vec!["bench", "cycles"]);
/// t.push_row(vec!["daxpy", "1234"]);
/// assert_eq!(t.to_csv(), "bench,cycles\ndaxpy,1234\n");
/// assert!(t.to_markdown().starts_with("| bench | cycles |"));
/// ```
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn push_row<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "ragged row");
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let header: Vec<String> = self.header.iter().map(|s| esc(s)).collect();
        let _ = writeln!(out, "{}", header.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Aligned markdown rendering for terminal / EXPERIMENTS.md output.
    pub fn to_markdown(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(line, " {:w$} |", c, w = widths[i]);
            }
            line
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Fixed-precision float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_simple() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push_row(vec!["1", "2"]);
        t.push_row(vec!["x,y", "q\"u"]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"u\""));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push_row(vec!["only-one"]);
    }

    #[test]
    fn markdown_is_aligned() {
        let mut t = Table::new(vec!["name", "v"]);
        t.push_row(vec!["longer-name", "1.5"]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }
}
