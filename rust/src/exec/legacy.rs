//! The legacy `Inst`-matching interpreter, kept **test-only** as the
//! reference the decoded dispatch path is compared against bit-for-bit
//! (memory, registers, flags, traps and `RunStats`). Production code
//! never matches `Inst` — that happens once, in
//! [`crate::isa::uop::DecodedProgram::decode`].
//!
//! Pure-compute scalar/NEON arms restate the original semantics
//! inline (an independent second implementation); memory operations and
//! all SVE operations call the same parameterized [`Executor`] methods
//! as the µop handlers, fed straight from the `Inst` payloads — so a
//! decoder operand-packing mistake shows up as a divergence here.

use super::{ExecResult, Executor, RunStats, Trap};
use crate::arch::Flags;
use crate::asm::Program;
use crate::exec::neon::{fcmp, icmp_signed, int_bin, NEON_BYTES};
use crate::exec::scalar::{fp_bin, fp_bin32, fp_un, fp_un32};
use crate::isa::{Inst, OpaqueFn, PLogicOp};

impl Executor {
    /// One architectural step of the legacy interpreter (shared
    /// fetch/advance logic lives in [`Executor::run_legacy`]).
    pub(crate) fn exec_inst_legacy(&mut self, inst: &Inst) -> ExecResult {
        use Inst::*;
        match *inst {
            // ---- scalar integer ----
            MovImm { xd, imm } => self.state.set_x(xd, imm),
            MovReg { xd, xn } => {
                let v = self.state.get_x(xn);
                self.state.set_x(xd, v)
            }
            AddImm { xd, xn, imm } => {
                let v = self.state.get_x(xn).wrapping_add(imm as u64);
                self.state.set_x(xd, v)
            }
            AddReg { xd, xn, xm, lsl } => {
                let v = self.state.get_x(xn).wrapping_add(self.state.get_x(xm) << lsl);
                self.state.set_x(xd, v)
            }
            SubReg { xd, xn, xm } => {
                let v = self.state.get_x(xn).wrapping_sub(self.state.get_x(xm));
                self.state.set_x(xd, v)
            }
            Madd { xd, xn, xm, xa } => {
                let v = self
                    .state
                    .get_x(xa)
                    .wrapping_add(self.state.get_x(xn).wrapping_mul(self.state.get_x(xm)));
                self.state.set_x(xd, v)
            }
            Udiv { xd, xn, xm } => {
                let d = self.state.get_x(xm);
                let v = if d == 0 { 0 } else { self.state.get_x(xn) / d };
                self.state.set_x(xd, v)
            }
            AndImm { xd, xn, imm } => {
                let v = self.state.get_x(xn) & imm;
                self.state.set_x(xd, v)
            }
            LogReg { op, xd, xn, xm } => {
                let (a, b) = (self.state.get_x(xn), self.state.get_x(xm));
                let v = match op {
                    PLogicOp::And => a & b,
                    PLogicOp::Orr => a | b,
                    PLogicOp::Eor => a ^ b,
                    PLogicOp::Bic => a & !b,
                };
                self.state.set_x(xd, v)
            }
            LslImm { xd, xn, sh } => {
                let v = self.state.get_x(xn) << sh;
                self.state.set_x(xd, v)
            }
            LsrImm { xd, xn, sh } => {
                let v = self.state.get_x(xn) >> sh;
                self.state.set_x(xd, v)
            }
            AsrImm { xd, xn, sh } => {
                let v = (self.state.get_x(xn) as i64) >> sh;
                self.state.set_x(xd, v as u64)
            }
            Csel { xd, xn, xm, cond } => {
                let v = if self.state.flags.cond(cond) {
                    self.state.get_x(xn)
                } else {
                    self.state.get_x(xm)
                };
                self.state.set_x(xd, v)
            }
            Ldr { size, signed, xt, base, off } => {
                let addr = self.ea(base, off);
                self.ldr_at(addr, size as usize, signed, xt)?;
            }
            Str { size, xt, base, off } => {
                let addr = self.ea(base, off);
                self.str_at(addr, size as usize, xt)?;
            }
            LdrFp { dbl, vt, base, off } => {
                let addr = self.ea(base, off);
                self.ldr_fp_at(addr, dbl, vt)?;
            }
            StrFp { dbl, vt, base, off } => {
                let addr = self.ea(base, off);
                self.str_fp_at(addr, dbl, vt)?;
            }
            CmpImm { xn, imm } => {
                self.state.flags = Flags::from_sub(self.state.get_x(xn), imm);
            }
            CmpReg { xn, xm } => {
                self.state.flags = Flags::from_sub(self.state.get_x(xn), self.state.get_x(xm));
            }
            B { target } => self.next_pc = Some(target),
            BCond { cond, target } => {
                if self.state.flags.cond(cond) {
                    self.next_pc = Some(target);
                }
            }
            Cbz { xn, target } => {
                if self.state.get_x(xn) == 0 {
                    self.next_pc = Some(target);
                }
            }
            Cbnz { xn, target } => {
                if self.state.get_x(xn) != 0 {
                    self.next_pc = Some(target);
                }
            }
            Ret | Halt => self.halted = true,
            Nop => {}
            // ---- scalar FP ----
            FmovImm { dbl, dd, bits } => {
                if dbl {
                    self.state.set_d(dd, f64::from_bits(bits));
                } else {
                    self.state.set_s(dd, f32::from_bits(bits as u32));
                }
            }
            FmovXtoD { dd, xn } => {
                let v = self.state.get_x(xn);
                self.state.set_d(dd, f64::from_bits(v));
            }
            FmovReg { dbl, dd, dn } => {
                if dbl {
                    let v = self.state.get_d(dn);
                    self.state.set_d(dd, v);
                } else {
                    let v = self.state.get_s(dn);
                    self.state.set_s(dd, v);
                }
            }
            FmovDtoX { xd, dn } => {
                let v = self.state.get_d(dn).to_bits();
                self.state.set_x(xd, v);
            }
            FpBin { op, dbl, dd, dn, dm } => {
                if dbl {
                    let (a, b) = (self.state.get_d(dn), self.state.get_d(dm));
                    self.state.set_d(dd, fp_bin(op, a, b));
                } else {
                    let (a, b) = (self.state.get_s(dn), self.state.get_s(dm));
                    self.state.set_s(dd, fp_bin32(op, a, b));
                }
            }
            FpUn { op, dbl, dd, dn } => {
                if dbl {
                    let a = self.state.get_d(dn);
                    self.state.set_d(dd, fp_un(op, a));
                } else {
                    let a = self.state.get_s(dn);
                    self.state.set_s(dd, fp_un32(op, a));
                }
            }
            Fmadd { dbl, dd, dn, dm, da, sub } => {
                if dbl {
                    let (n, m, a) =
                        (self.state.get_d(dn), self.state.get_d(dm), self.state.get_d(da));
                    let prod = if sub { -(n * m) } else { n * m };
                    self.state.set_d(dd, a + prod);
                } else {
                    let (n, m, a) =
                        (self.state.get_s(dn), self.state.get_s(dm), self.state.get_s(da));
                    let prod = if sub { -(n * m) } else { n * m };
                    self.state.set_s(dd, a + prod);
                }
            }
            Fcmp { dbl, dn, dm } => {
                let (a, b) = if dbl {
                    (self.state.get_d(dn), self.state.get_d(dm))
                } else {
                    (self.state.get_s(dn) as f64, self.state.get_s(dm) as f64)
                };
                self.state.flags = Flags::from_fcmp(a, b);
            }
            Scvtf { dbl, dd, xn } => {
                let v = self.state.get_x(xn) as i64;
                if dbl {
                    self.state.set_d(dd, v as f64);
                } else {
                    self.state.set_s(dd, v as f32);
                }
            }
            Fcvtzs { dbl, xd, dn } => {
                let v = if dbl { self.state.get_d(dn) } else { self.state.get_s(dn) as f64 };
                self.state.set_x(xd, v.trunc() as i64 as u64);
            }
            OpaqueCall { f, dd, dn, dm } => {
                let a = self.state.get_d(dn);
                let b = dm.map(|m| self.state.get_d(m));
                let v = match f {
                    OpaqueFn::Exp => a.exp(),
                    OpaqueFn::Log => a.ln(),
                    OpaqueFn::Pow => a.powf(b.expect("pow needs 2 args")),
                    OpaqueFn::Sqrt => a.sqrt(),
                    OpaqueFn::Sin => a.sin(),
                };
                self.state.set_d(dd, v);
            }
            // ---- Advanced SIMD (NEON) ----
            NeonLd1 { esize: _, vt, base, off } => {
                let addr = self.neon_ea(base, off);
                self.neon_ld1_at(addr, vt)?;
            }
            NeonSt1 { esize: _, vt, base, off } => {
                let addr = self.neon_ea(base, off);
                self.neon_st1_at(addr, vt)?;
            }
            NeonDupX { esize, vd, xn } => {
                let v = self.state.get_x(xn);
                let r = &mut self.state.z[vd as usize];
                for i in 0..esize.lanes(NEON_BYTES) {
                    r.set(esize, i, v);
                }
                r.zero_from(NEON_BYTES);
            }
            NeonDupLane0 { esize, vd, vn } => {
                let v = self.state.z[vn as usize].get(esize, 0);
                let r = &mut self.state.z[vd as usize];
                for i in 0..esize.lanes(NEON_BYTES) {
                    r.set(esize, i, v);
                }
                r.zero_from(NEON_BYTES);
            }
            NeonMoviZero { vd } => self.state.z[vd as usize].zero(),
            NeonFpBin { op, dbl, vd, vn, vm } => {
                let (zn, zm) = (self.state.z[vn as usize], self.state.z[vm as usize]);
                let r = &mut self.state.z[vd as usize];
                if dbl {
                    for i in 0..2 {
                        r.set_f64(i, fp_bin(op, zn.get_f64(i), zm.get_f64(i)));
                    }
                } else {
                    for i in 0..4 {
                        r.set_f32(i, fp_bin32(op, zn.get_f32(i), zm.get_f32(i)));
                    }
                }
                r.zero_from(NEON_BYTES);
            }
            NeonFpUn { op, dbl, vd, vn } => {
                let zn = self.state.z[vn as usize];
                let r = &mut self.state.z[vd as usize];
                if dbl {
                    for i in 0..2 {
                        r.set_f64(i, fp_un(op, zn.get_f64(i)));
                    }
                } else {
                    for i in 0..4 {
                        r.set_f32(i, fp_un32(op, zn.get_f32(i)));
                    }
                }
                r.zero_from(NEON_BYTES);
            }
            NeonFmla { dbl, vd, vn, vm, sub } => {
                let (zn, zm) = (self.state.z[vn as usize], self.state.z[vm as usize]);
                let r = &mut self.state.z[vd as usize];
                if dbl {
                    for i in 0..2 {
                        let p = zn.get_f64(i) * zm.get_f64(i);
                        let p = if sub { -p } else { p };
                        r.set_f64(i, r.get_f64(i) + p);
                    }
                } else {
                    for i in 0..4 {
                        let p = zn.get_f32(i) * zm.get_f32(i);
                        let p = if sub { -p } else { p };
                        r.set_f32(i, r.get_f32(i) + p);
                    }
                }
                r.zero_from(NEON_BYTES);
            }
            NeonIntBin { op, esize, vd, vn, vm } => {
                let (zn, zm) = (self.state.z[vn as usize], self.state.z[vm as usize]);
                let r = &mut self.state.z[vd as usize];
                for i in 0..esize.lanes(NEON_BYTES) {
                    let v = int_bin(op, esize, zn.get(esize, i), zm.get(esize, i));
                    r.set(esize, i, v);
                }
                r.zero_from(NEON_BYTES);
            }
            NeonFcm { op, dbl, vd, vn, vm } => {
                let (zn, zm) = (self.state.z[vn as usize], self.state.z[vm as usize]);
                let r = &mut self.state.z[vd as usize];
                if dbl {
                    for i in 0..2 {
                        let t = fcmp(op, zn.get_f64(i), zm.get_f64(i));
                        r.set(crate::arch::Esize::D, i, if t { u64::MAX } else { 0 });
                    }
                } else {
                    for i in 0..4 {
                        let t = fcmp(op, zn.get_f32(i) as f64, zm.get_f32(i) as f64);
                        r.set(crate::arch::Esize::S, i, if t { 0xFFFF_FFFF } else { 0 });
                    }
                }
                r.zero_from(NEON_BYTES);
            }
            NeonCm { op, esize, vd, vn, vm } => {
                let (zn, zm) = (self.state.z[vn as usize], self.state.z[vm as usize]);
                let r = &mut self.state.z[vd as usize];
                let ones = if esize.bytes() == 8 {
                    u64::MAX
                } else {
                    (1u64 << (esize.bytes() * 8)) - 1
                };
                for i in 0..esize.lanes(NEON_BYTES) {
                    let t = icmp_signed(op, zn.get_signed(esize, i), zm.get_signed(esize, i));
                    r.set(esize, i, if t { ones } else { 0 });
                }
                r.zero_from(NEON_BYTES);
            }
            NeonBsl { vd, vn, vm } => {
                let (zn, zm) = (self.state.z[vn as usize], self.state.z[vm as usize]);
                let r = &mut self.state.z[vd as usize];
                for k in 0..NEON_BYTES {
                    r.bytes[k] = (r.bytes[k] & zn.bytes[k]) | (!r.bytes[k] & zm.bytes[k]);
                }
                r.zero_from(NEON_BYTES);
            }
            NeonFaddv { dbl, dd, vn } => {
                let zn = self.state.z[vn as usize];
                if dbl {
                    let v = zn.get_f64(0) + zn.get_f64(1);
                    self.state.set_d(dd, v);
                } else {
                    let (a, b) =
                        (zn.get_f32(0) + zn.get_f32(1), zn.get_f32(2) + zn.get_f32(3));
                    self.state.set_s(dd, a + b);
                }
            }
            NeonAddv { esize, dd, vn } => {
                let zn = self.state.z[vn as usize];
                let mut acc = 0u64;
                for i in 0..esize.lanes(NEON_BYTES) {
                    acc = acc.wrapping_add(zn.get(esize, i));
                }
                let r = &mut self.state.z[dd as usize];
                r.zero();
                r.set(esize, 0, acc);
            }
            NeonUmov { esize, xd, vn, lane } => {
                let v = self.state.z[vn as usize].get(esize, lane as usize);
                self.state.set_x(xd, v);
            }
            NeonInsX { esize, vd, lane, xn } => {
                let v = self.state.get_x(xn);
                let r = &mut self.state.z[vd as usize];
                r.set(esize, lane as usize, v);
                r.zero_from(NEON_BYTES);
            }
            // ---- SVE (shared parameterized bodies) ----
            Ptrue { pd, esize, s } => self.sve_ptrue(pd, esize, s),
            Pfalse { pd } => self.sve_pfalse(pd),
            While { pd, esize, xn, xm, unsigned } => self.sve_while(pd, esize, xn, xm, unsigned),
            Ptest { pg, pn } => self.sve_ptest(pg, pn),
            Pnext { pdn, pg, esize } => self.sve_pnext(pdn, pg, esize),
            Brk { pd, pg, pn, before, s } => self.sve_brk(pd, pg, pn, before, s),
            PredLogic { op, pd, pg, pn, pm, s } => self.sve_pred_logic(op, pd, pg, pn, pm, s),
            Rdffr { pd, pg, s } => self.sve_rdffr(pd, pg, s),
            Setffr => self.sve_setffr(),
            Wrffr { pn } => self.sve_wrffr(pn),
            Cnt { xd, esize } => self.sve_cnt(xd, esize),
            IncDec { xdn, esize, dec } => self.sve_inc_dec(xdn, esize, dec),
            IncpX { xdn, pm, esize } => self.sve_incp(xdn, pm, esize),
            Index { zd, esize, base, step } => self.sve_index(zd, esize, base, step),
            DupImm { zd, esize, imm } => self.sve_dup_imm(zd, esize, imm),
            FdupImm { zd, dbl, bits } => self.sve_fdup(zd, dbl, bits),
            DupX { zd, esize, xn } => self.sve_dup_x(zd, esize, xn),
            CpyX { zd, pg, xn, esize } => self.sve_cpy_x(zd, pg, xn, esize),
            Sel { zd, pg, zn, zm, esize } => self.sve_sel(zd, pg, zn, zm, esize),
            Movprfx { zd, zn, pg } => self.sve_movprfx(zd, zn, pg),
            Last { xd, pg, zn, esize, before } => self.sve_last(xd, pg, zn, esize, before),
            SveLd1 { zt, pg, esize, base, off, ff } => {
                self.sve_ld1(zt, pg, esize, base, off, ff)?;
            }
            SveLd1R { zt, pg, esize, base, imm } => {
                self.sve_ld1r(zt, pg, esize, base, imm)?;
            }
            SveSt1 { zt, pg, esize, base, off } => {
                self.sve_st1(zt, pg, esize, base, off)?;
            }
            SveLdGather { zt, pg, esize, addr, ff } => {
                self.sve_gather(zt, pg, esize, addr, ff)?;
            }
            SveStScatter { zt, pg, esize, addr } => {
                self.sve_scatter(zt, pg, esize, addr)?;
            }
            SveIntBin { op, zdn, pg, zm, esize } => self.sve_int_bin(op, zdn, pg, zm, esize),
            SveIntBinU { op, zd, zn, zm, esize } => self.sve_int_bin_u(op, zd, zn, zm, esize),
            SveAddImm { zdn, esize, imm } => self.sve_add_imm(zdn, esize, imm),
            SveFpBin { op, zdn, pg, zm, dbl } => self.sve_fp_bin(op, zdn, pg, zm, dbl),
            SveFpUn { op, zd, pg, zn, dbl } => self.sve_fp_un(op, zd, pg, zn, dbl),
            SveFmla { zda, pg, zn, zm, dbl, sub } => self.sve_fmla(zda, pg, zn, zm, dbl, sub),
            SveScvtf { zd, pg, zn, dbl } => self.sve_scvtf(zd, pg, zn, dbl),
            SveIntCmp { op, unsigned, pd, pg, zn, rhs, esize } => {
                self.sve_int_cmp(op, unsigned, pd, pg, zn, rhs, esize)
            }
            SveFpCmp { op, pd, pg, zn, rhs, dbl } => self.sve_fp_cmp(op, pd, pg, zn, rhs, dbl),
            SveReduce { op, vd, pg, zn, esize } => self.sve_reduce(op, vd, pg, zn, esize),
            SveFadda { vdn, pg, zm, dbl } => self.sve_fadda(vdn, pg, zm, dbl),
            SveRev { zd, zn, esize } => self.sve_rev(zd, zn, esize),
            SveExt { zdn, zm, imm } => self.sve_ext(zdn, zm, imm),
            SveZip { zd, zn, zm, esize, hi } => self.sve_zip(zd, zn, zm, esize, hi),
            SveUzp { zd, zn, zm, esize, odd } => self.sve_uzp(zd, zn, zm, esize, odd),
            SveTrn { zd, zn, zm, esize, odd } => self.sve_trn(zd, zn, zm, esize, odd),
            SveTbl { zd, zn, zm, esize } => self.sve_tbl(zd, zn, zm, esize),
            SveCompact { zd, pg, zn, esize } => self.sve_compact(zd, pg, zn, esize),
            SveSplice { zdn, pg, zm, esize } => self.sve_splice(zdn, pg, zm, esize),
            Cterm { xn, xm, ne } => self.sve_cterm(xn, xm, ne),
        }
        Ok(())
    }

    /// One legacy step with the same fetch/advance contract as
    /// `Executor::exec_at`.
    pub(crate) fn legacy_step(&mut self, prog: &Program) -> Result<bool, Trap> {
        let pc = self.state.pc;
        let inst = &prog.insts[pc];
        self.accesses.clear();
        self.next_pc = None;
        if let Err(fault) = self.exec_inst_legacy(inst) {
            return Err(Trap::Fault { fault, pc });
        }
        let taken = self.next_pc.is_some();
        self.state.pc = match self.next_pc {
            Some(t) => t,
            None => pc + 1,
        };
        Ok(taken)
    }

    /// Run to Halt/trap on the legacy interpreter, deriving the dynamic
    /// mix from the `Inst` metadata (how `run_with` worked before the
    /// shared decode layer).
    pub(crate) fn run_legacy(&mut self, prog: &Program, max_insts: u64) -> Result<RunStats, Trap> {
        let mut stats = RunStats::default();
        while !self.halted {
            if stats.insts >= max_insts {
                return Err(Trap::Budget);
            }
            let pc = self.state.pc;
            self.legacy_step(prog)?;
            let inst = &prog.insts[pc];
            stats.insts += 1;
            stats.sve_insts += u64::from(inst.is_sve());
            stats.neon_insts += u64::from(inst.is_neon());
            stats.vector_insts += u64::from(inst.class().is_vector());
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod bitident {
    use super::*;
    use crate::arch::Esize;
    use crate::asm::Asm;
    use crate::compiler::{self, Compiled, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
    use crate::isa::uop::DecodedProgram;
    use crate::mem::Memory;
    use crate::proptest_lite::{check, Gen};
    use crate::workloads;

    /// Assert two executors reached bit-identical architectural state.
    fn assert_state_eq(a: &Executor, b: &Executor, what: &str) {
        assert_eq!(a.state.pc, b.state.pc, "{what}: pc");
        assert_eq!(a.halted, b.halted, "{what}: halted");
        assert_eq!(a.state.x, b.state.x, "{what}: x registers");
        assert_eq!(a.state.flags, b.state.flags, "{what}: NZCV");
        for r in 0..a.state.z.len() {
            assert_eq!(a.state.z[r].bytes, b.state.z[r].bytes, "{what}: z{r}");
        }
        assert_eq!(a.state.p, b.state.p, "{what}: predicates");
        assert_eq!(a.state.ffr, b.state.ffr, "{what}: FFR");
        assert_eq!(a.accesses, b.accesses, "{what}: memory-access stream");
    }

    /// Compare a memory range byte-for-byte.
    fn assert_mem_eq(a: &Memory, b: &Memory, lo: u64, len: u64, what: &str) {
        for off in (0..len).step_by(8) {
            let n = (len - off).min(8) as usize;
            assert_eq!(
                a.read(lo + off, n).ok(),
                b.read(lo + off, n).ok(),
                "{what}: memory at {:#x}",
                lo + off
            );
        }
    }

    /// Run `prog` to completion on all three paths — the legacy
    /// interpreter, the decoded block interpreter, and the superblock
    /// trace engine (with a low formation threshold so even short runs
    /// execute stitched traces, tail side exits and traps included) —
    /// and compare everything: state, memory, traps and `RunStats`.
    fn run_both(
        prog: &crate::asm::Program,
        mem: &Memory,
        vl: usize,
        max: u64,
        regions: &[(u64, u64)],
        what: &str,
    ) {
        let mut legacy = Executor::new(vl, mem.clone());
        let ra = legacy.run_legacy(prog, max);
        let dec = DecodedProgram::decode(prog);
        let mut decoded = Executor::new(vl, mem.clone());
        let rb = decoded.run_decoded(&dec, max);
        assert_eq!(ra, rb, "{what}: run results (stats/trap)");
        assert_state_eq(&legacy, &decoded, what);
        for &(lo, len) in regions {
            assert_mem_eq(&legacy.mem, &decoded.mem, lo, len, what);
        }
        let mut traced = Executor::new(vl, mem.clone());
        let mut engine = crate::exec::TraceEngine::with_threshold(&dec, 2);
        let rc = engine.run_with(&mut traced, &dec, max, |_| {});
        let tw = format!("{what} [trace engine]");
        assert_eq!(rb, rc, "{tw}: run results (stats/trap)");
        assert_state_eq(&decoded, &traced, &tw);
        for &(lo, len) in regions {
            assert_mem_eq(&decoded.mem, &traced.mem, lo, len, &tw);
        }
    }

    const SCRATCH: u64 = 0x10_000;
    const SCRATCH_LEN: u64 = 0x10_000;

    /// The mapped, pattern-filled scratch region behind [`seeded`]
    /// (built once per test and cloned per sample).
    fn scratch_mem() -> Memory {
        let mut mem = Memory::new();
        mem.map(SCRATCH, SCRATCH_LEN);
        for i in 0..SCRATCH_LEN {
            mem.write_byte(SCRATCH + i, (i % 253) as u8).unwrap();
        }
        mem
    }

    /// An executor with deterministic non-trivial state: a mapped,
    /// pattern-filled scratch region, x registers pointing into it, lane
    /// patterns in the vector file and a mixed predicate file.
    fn seeded(vl: usize, mem: &Memory) -> Executor {
        let mut ex = Executor::new(vl, mem.clone());
        for r in 0..31u8 {
            ex.state.set_x(r, SCRATCH + r as u64 * 0x3F8);
        }
        for r in 0..32 {
            for i in 0..ex.state.vl_bytes() {
                ex.state.z[r].bytes[i] = (r as u8).wrapping_mul(37).wrapping_add(i as u8);
            }
        }
        for r in 0..16 {
            for lane in 0..ex.state.vl_bytes() {
                ex.state.p[r].set_bit(lane, (lane + r) % (r + 2) == 0);
            }
        }
        ex.state.ffr = ex.state.p[3];
        ex.state.flags = crate::arch::Flags { n: true, z: false, c: true, v: false };
        ex
    }

    /// Every decoded shape, single-stepped from identical seeded state:
    /// the legacy interpreter and the tag dispatch must agree on the
    /// resulting state — or fault identically.
    #[test]
    fn every_uop_shape_steps_identically_to_legacy() {
        let mem = scratch_mem();
        for vl in [128usize, 256, 1024] {
            for (i, inst) in crate::isa::uop::tests::samples().into_iter().enumerate() {
                let mut a = Asm::new();
                a.push(inst.clone());
                let prog = a.finish();
                let dec = DecodedProgram::decode(&prog);
                let mut legacy = seeded(vl, &mem);
                let mut decoded = seeded(vl, &mem);
                let ra = legacy.legacy_step(&prog);
                let rb = decoded.step(&dec);
                let what = format!("sample {i} ({inst:?}) at VL {vl}");
                assert_eq!(ra, rb, "{what}: step outcome");
                assert_state_eq(&legacy, &decoded, &what);
                assert_mem_eq(&legacy.mem, &decoded.mem, SCRATCH, SCRATCH_LEN, &what);
            }
        }
    }

    /// Real compiled workloads, all three targets, several VLs.
    #[test]
    fn compiled_workloads_are_bit_identical_across_paths() {
        for name in ["stream_triad", "haccmk", "graph500", "spmv_ell", "strlen1m"] {
            let w = workloads::build(name);
            for target in [Target::Scalar, Target::Neon, Target::Sve] {
                let c = w.compile(target);
                let vls: &[usize] = match target {
                    Target::Sve => &[128, 384, 1024],
                    _ => &[128],
                };
                for &vl in vls {
                    run_both(
                        &c.program,
                        &w.mem,
                        vl,
                        w.max_insts,
                        &[],
                        &format!("{name}/{target:?}@vl{vl}"),
                    );
                }
            }
        }
    }

    // ---- random IR kernels through the real compiler ----

    struct RandKernel {
        kernel: Kernel,
        mem: Memory,
        regions: Vec<(u64, u64)>,
    }

    fn random_expr(g: &mut Gen, arrays: &[usize], idx_arr: usize, depth: usize) -> Expr {
        use crate::compiler::{BinOp, CmpKind, UnOp};
        let leaf = depth == 0 || g.bool();
        if leaf {
            match g.usize_in(0, 3) {
                0 => Expr::ConstF(g.f64_in(-4.0, 4.0)),
                1 => Expr::IvAsF,
                _ => {
                    let arr = *g.choose(arrays);
                    let idx = match g.usize_in(0, 3) {
                        0 | 1 => Index::Affine { offset: 0 },
                        2 => Index::Strided { scale: 2, offset: 0 },
                        _ => Index::Indirect { idx_arr, offset: 0 },
                    };
                    Expr::load(arr, idx)
                }
            }
        } else {
            match g.usize_in(0, 5) {
                0..=2 => {
                    let op = *g.choose(&[
                        BinOp::Add,
                        BinOp::Sub,
                        BinOp::Mul,
                        BinOp::Max,
                        BinOp::Min,
                    ]);
                    Expr::bin(
                        op,
                        random_expr(g, arrays, idx_arr, depth - 1),
                        random_expr(g, arrays, idx_arr, depth - 1),
                    )
                }
                3 => Expr::Un {
                    op: *g.choose(&[UnOp::Neg, UnOp::Abs]),
                    a: Box::new(random_expr(g, arrays, idx_arr, depth - 1)),
                },
                _ => {
                    let op = *g.choose(&[CmpKind::Gt, CmpKind::Le, CmpKind::Ne]);
                    Expr::select(
                        Expr::cmp(
                            op,
                            random_expr(g, arrays, idx_arr, depth - 1),
                            Expr::ConstF(g.f64_in(-2.0, 2.0)),
                        ),
                        random_expr(g, arrays, idx_arr, depth - 1),
                        random_expr(g, arrays, idx_arr, depth - 1),
                    )
                }
            }
        }
    }

    fn random_kernel(g: &mut Gen) -> RandKernel {
        let n = g.u64_in(0, 64);
        let mut mem = Memory::new();
        let mut k = Kernel::new("prop", Ty::F64, Trip::Count(n));
        let elems = 2 * n + 16; // covers Strided{scale: 2} accesses
        let mut regions = Vec::new();
        let mut inputs = Vec::new();
        for name in ["a", "b"] {
            let base = mem.alloc(8 * elems, 16);
            for e in 0..elems {
                mem.write_f64(base + 8 * e, g.f64_in(-8.0, 8.0)).unwrap();
            }
            regions.push((base, 8 * elems));
            inputs.push(k.array(name, Ty::F64, base));
        }
        let ibase = mem.alloc(8 * elems, 16);
        for e in 0..elems {
            mem.write_u64(ibase + 8 * e, g.u64_in(0, n.max(1) - 1)).unwrap();
        }
        regions.push((ibase, 8 * elems));
        let idx_arr = k.array("idx", Ty::I64, ibase);
        let obase = mem.alloc(8 * elems, 16);
        regions.push((obase, 8 * elems));
        let out = k.array("out", Ty::F64, obase);
        let value = random_expr(g, &inputs, idx_arr, 3);
        k.body.push(Stmt::Store { arr: out, idx: Index::Affine { offset: 0 }, value });
        if g.bool() {
            let kind = *g.choose(&[
                crate::compiler::RedKind::SumF,
                crate::compiler::RedKind::MaxF,
            ]);
            let value = random_expr(g, &inputs, idx_arr, 2);
            k.reductions.push(crate::compiler::Reduction { kind, value });
            let rout = mem.alloc(8, 8);
            mem.write_f64(rout, 0.0).unwrap();
            regions.push((rout, 8));
            k.red_out.push(rout);
        }
        RandKernel { kernel: k, mem, regions }
    }

    /// The tentpole property: random kernels × all three targets ×
    /// several VLs execute bit-identically on the legacy interpreter and
    /// the decoded dispatch path (memory, registers, flags, RunStats).
    #[test]
    fn prop_random_kernels_bit_identical_legacy_vs_decoded() {
        check("prop_random_kernels_bit_identical_legacy_vs_decoded", 24, |g| {
            let rk = random_kernel(g);
            for target in [Target::Scalar, Target::Neon, Target::Sve] {
                let c: Compiled = compiler::compile(&rk.kernel, target);
                let vls: &[usize] = match target {
                    Target::Sve => &[128, 256, 512, 2048],
                    _ => &[128],
                };
                for &vl in vls {
                    run_both(
                        &c.program,
                        &rk.mem,
                        vl,
                        10_000_000,
                        &rk.regions,
                        &format!("random kernel on {target:?}@vl{vl}"),
                    );
                }
            }
        });
    }

    // ---- PR-7 IR shapes: reductions of products and complex products ----

    /// A flat leaf for multiply-accumulate chains: contiguous load,
    /// constant, or the induction variable (no nesting — keeps the
    /// scalar expression stack inside its 8-register budget).
    fn fma_leaf(g: &mut Gen, arrays: &[usize]) -> Expr {
        match g.usize_in(0, 2) {
            0 => Expr::ConstF(g.f64_in(-2.0, 2.0)),
            1 => Expr::IvAsF,
            _ => Expr::load(*g.choose(arrays), Index::Affine { offset: 0 }),
        }
    }

    /// Random f64 kernel over the reduction-of-product shapes: a stored
    /// FMLA/FMLS chain, a `DotF` dot-product reduction, and (sometimes)
    /// a plain sum over a multiply-accumulate.
    fn random_product_kernel(g: &mut Gen) -> RandKernel {
        use crate::compiler::{BinOp, RedKind, Reduction};
        let n = g.u64_in(0, 64);
        let mut mem = Memory::new();
        let mut k = Kernel::new("prodprop", Ty::F64, Trip::Count(n));
        let elems = n + 8;
        let mut regions = Vec::new();
        let mut inputs = Vec::new();
        for name in ["a", "b"] {
            let base = mem.alloc(8 * elems, 16);
            for e in 0..elems {
                mem.write_f64(base + 8 * e, g.f64_in(-4.0, 4.0)).unwrap();
            }
            regions.push((base, 8 * elems));
            inputs.push(k.array(name, Ty::F64, base));
        }
        let obase = mem.alloc(8 * elems, 16);
        regions.push((obase, 8 * elems));
        let out = k.array("out", Ty::F64, obase);
        let mut acc = fma_leaf(g, &inputs);
        for _ in 0..g.usize_in(1, 3) {
            let a = Box::new(fma_leaf(g, &inputs));
            let b = Box::new(fma_leaf(g, &inputs));
            acc = Expr::Fma { a, b, acc: Box::new(acc), sub: g.bool() };
        }
        k.body.push(Stmt::Store { arr: out, idx: Index::Affine { offset: 0 }, value: acc });
        // the DotF contract: the reduced value is a product
        let value = Expr::bin(
            BinOp::Mul,
            Expr::load(*g.choose(&inputs), Index::Affine { offset: 0 }),
            Expr::load(*g.choose(&inputs), Index::Affine { offset: 0 }),
        );
        k.reductions.push(Reduction { kind: RedKind::DotF, value });
        let rout = mem.alloc(8, 8);
        mem.write_f64(rout, 0.0).unwrap();
        regions.push((rout, 8));
        k.red_out.push(rout);
        if g.bool() {
            let value = Expr::fma(
                fma_leaf(g, &inputs),
                fma_leaf(g, &inputs),
                fma_leaf(g, &inputs),
            );
            k.reductions.push(Reduction { kind: RedKind::SumF, value });
            let rout = mem.alloc(8, 8);
            mem.write_f64(rout, 0.0).unwrap();
            regions.push((rout, 8));
            k.red_out.push(rout);
        }
        RandKernel { kernel: k, mem, regions }
    }

    /// Random f32 kernel over the interleaved complex-product shape:
    /// stored `ComplexMul` lanes (sometimes a sum of two products, as in
    /// the SU(3) mat-vec row) and sometimes a sum reduction over one.
    /// Operand blocks start at element 1 or 2 so the lowering's ±1
    /// shifted loads stay inside the mapping (the guard-element
    /// contract).
    fn random_cmul_kernel(g: &mut Gen) -> RandKernel {
        use crate::compiler::{BinOp, RedKind, Reduction};
        let n = g.u64_in(0, 48);
        let mut mem = Memory::new();
        let mut k = Kernel::new("cmulprop", Ty::F32, Trip::Count(n));
        let elems = n + 6; // data + guards + offset slack
        let mut regions = Vec::new();
        let mut arrs = Vec::new();
        let mut offs = Vec::new();
        for name in ["a", "b"] {
            let base = mem.alloc(4 * elems, 16);
            for e in 0..elems {
                mem.write_f32(base + 4 * e, g.f64_in(-2.0, 2.0) as f32).unwrap();
            }
            regions.push((base, 4 * elems));
            arrs.push(k.array(name, Ty::F32, base));
            offs.push(g.i64_in(1, 2));
        }
        let obase = mem.alloc(4 * elems, 16);
        regions.push((obase, 4 * elems));
        let out = k.array("out", Ty::F32, obase);
        let cmul = |g: &mut Gen| Expr::ComplexMul {
            a_arr: arrs[0],
            a_off: offs[0],
            b_arr: arrs[1],
            b_off: offs[1],
            conj: g.bool(),
        };
        let c0 = cmul(g);
        let value = if g.bool() { Expr::bin(BinOp::Add, c0, cmul(g)) } else { c0 };
        k.body.push(Stmt::Store { arr: out, idx: Index::Affine { offset: 0 }, value });
        if g.bool() {
            k.reductions.push(Reduction { kind: RedKind::SumF, value: cmul(g) });
            let rout = mem.alloc(8, 8);
            mem.write_f64(rout, 0.0).unwrap();
            regions.push((rout, 8));
            k.red_out.push(rout);
        }
        RandKernel { kernel: k, mem, regions }
    }

    /// Satellite property: random reduction-of-product kernels execute
    /// bit-identically on the legacy interpreter, the decoded dispatch
    /// path and the trace engine, on every target, across VLs.
    #[test]
    fn prop_reduction_of_product_kernels_three_way() {
        check("prop_reduction_of_product_kernels_three_way", 24, |g| {
            let rk = random_product_kernel(g);
            for target in [Target::Scalar, Target::Neon, Target::Sve] {
                let c: Compiled = compiler::compile(&rk.kernel, target);
                let vls: &[usize] = match target {
                    Target::Sve => &[128, 256, 512, 2048],
                    _ => &[128],
                };
                for &vl in vls {
                    run_both(
                        &c.program,
                        &rk.mem,
                        vl,
                        10_000_000,
                        &rk.regions,
                        &format!("product kernel on {target:?}@vl{vl}"),
                    );
                }
            }
        });
    }

    /// Satellite property: random complex-multiply kernels execute
    /// bit-identically on all three paths (NEON compiles to the scalar
    /// fallback — no FCMLA — which is itself a path worth pinning).
    #[test]
    fn prop_complex_mul_kernels_three_way() {
        check("prop_complex_mul_kernels_three_way", 24, |g| {
            let rk = random_cmul_kernel(g);
            for target in [Target::Scalar, Target::Neon, Target::Sve] {
                let c: Compiled = compiler::compile(&rk.kernel, target);
                let vls: &[usize] = match target {
                    Target::Sve => &[128, 256, 512, 2048],
                    _ => &[128],
                };
                for &vl in vls {
                    run_both(
                        &c.program,
                        &rk.mem,
                        vl,
                        10_000_000,
                        &rk.regions,
                        &format!("cmul kernel on {target:?}@vl{vl}"),
                    );
                }
            }
        });
    }

    // ---- PR-9 memory-model wall: timing knobs must be timing-only ----

    /// The most aggressive memory configuration the CLI can express:
    /// a hot prefetcher in front of a 1 B/cycle DRAM channel.
    fn extreme_memory_cfg() -> crate::uarch::UarchConfig {
        crate::uarch::UarchConfig {
            pf_entries: 64,
            pf_degree: 4,
            dram_bytes_per_cycle: 1,
            ..crate::uarch::UarchConfig::default()
        }
    }

    /// Run `dec` under `cfg` with the timing pipeline attached,
    /// recording the retire stream as (pc, µop class) pairs.
    fn run_timed_recording(
        dec: &DecodedProgram,
        mem: &Memory,
        vl: usize,
        max: u64,
        cfg: crate::uarch::UarchConfig,
    ) -> (
        Executor,
        Vec<(usize, crate::isa::UopClass)>,
        Result<(RunStats, crate::uarch::TimingResult), Trap>,
    ) {
        let mut ex = Executor::new(vl, mem.clone());
        let mut pipe = crate::uarch::Pipeline::new(cfg, vl);
        let mut stream = Vec::new();
        let r = ex
            .run_decoded_with(dec, max, |info| {
                stream.push((info.pc, info.uop.class));
                pipe.on_retire(&info);
            })
            .map(|stats| (stats, pipe.result));
        (ex, stream, r)
    }

    /// The PR-9 differential: default vs extreme memory configuration
    /// must retire the identical µop stream and reach bit-identical
    /// architectural state and memory — the prefetcher and the DRAM
    /// channel are observers, never actors. Also audits the channel
    /// books under the extreme config: every L2 miss occupies the
    /// channel for at least `line_bytes / bandwidth` cycles.
    fn assert_memory_model_invariant(
        prog: &Program,
        mem: &Memory,
        vl: usize,
        max: u64,
        regions: &[(u64, u64)],
        what: &str,
    ) -> (Executor, Executor) {
        let dec = DecodedProgram::decode(prog);
        let base = crate::uarch::UarchConfig::default();
        let extreme = extreme_memory_cfg();
        let occ = base.line_bytes as u64; // div_ceil(64, 1)
        let (ea, sa, ra) = run_timed_recording(&dec, mem, vl, max, base);
        let (eb, sb, rb) = run_timed_recording(&dec, mem, vl, max, extreme);
        assert_eq!(sa, sb, "{what}: retire streams");
        match (&ra, &rb) {
            (Ok((stats_a, _)), Ok((stats_b, _))) => {
                assert_eq!(stats_a, stats_b, "{what}: RunStats")
            }
            (Err(ta), Err(tb)) => assert_eq!(ta, tb, "{what}: traps"),
            _ => panic!("{what}: only one path trapped: {ra:?} vs {rb:?}"),
        }
        assert_state_eq(&ea, &eb, what);
        for &(lo, len) in regions {
            assert_mem_eq(&ea.mem, &eb.mem, lo, len, what);
        }
        if let Ok((_, t)) = &rb {
            assert!(
                t.dram_channel_cycles >= t.l2_misses * occ,
                "{what}: channel books must cover every demand fill: {} < {} x {occ}",
                t.dram_channel_cycles,
                t.l2_misses
            );
        }
        (ea, eb)
    }

    /// Real compiled workloads under the extreme memory configuration:
    /// identical retire streams, identical state, and both runs still
    /// pass the workload's own golden-output checks.
    #[test]
    fn extreme_memory_configs_are_bit_identical_on_workloads() {
        for name in ["stream_triad", "memcpy_like", "spmv_ell", "graph500"] {
            let w = workloads::build(name);
            for (target, vl) in [(Target::Neon, 128usize), (Target::Sve, 256)] {
                let c = w.compile(target);
                let what = format!("{name}/{target:?}@vl{vl}");
                let (ea, eb) = assert_memory_model_invariant(
                    &c.program,
                    &w.mem,
                    vl,
                    w.max_insts,
                    &[],
                    &what,
                );
                w.verify(&ea.mem).unwrap_or_else(|e| panic!("{what} default: {e}"));
                w.verify(&eb.mem).unwrap_or_else(|e| panic!("{what} extreme: {e}"));
            }
        }
    }

    /// PR-9 satellite property: random compiled kernels are functionally
    /// invisible to the memory model — retire stream, registers and
    /// every written region are bit-identical between the default and
    /// extreme configurations, and DRAM conservation holds throughout.
    #[test]
    fn prop_memory_model_is_functionally_invisible() {
        check("prop_memory_model_is_functionally_invisible", 16, |g| {
            let rk = random_kernel(g);
            for target in [Target::Neon, Target::Sve] {
                let c: Compiled = compiler::compile(&rk.kernel, target);
                let vls: &[usize] = match target {
                    Target::Sve => &[128, 512],
                    _ => &[128],
                };
                for &vl in vls {
                    assert_memory_model_invariant(
                        &c.program,
                        &rk.mem,
                        vl,
                        10_000_000,
                        &rk.regions,
                        &format!("memory-model kernel on {target:?}@vl{vl}"),
                    );
                }
            }
        });
    }

    // ---- PR-10 wall: trace linking and the full dense-twin surface ----

    /// A two-level daxpy nest: the shape whose steady state chains
    /// outer-close → outer-head → inner-loop traces through patched
    /// links on the trace engine.
    fn nested_daxpy_prog(x: u64, y: u64, a_addr: u64, n: u64, reps: u64) -> Program {
        let mut a = Asm::new();
        use crate::isa::{Cond, Inst, SveMemOff};
        a.push(Inst::MovImm { xd: 0, imm: x });
        a.push(Inst::MovImm { xd: 1, imm: y });
        a.push(Inst::MovImm { xd: 2, imm: a_addr });
        a.push(Inst::MovImm { xd: 3, imm: n });
        a.push(Inst::MovImm { xd: 5, imm: reps });
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.push(Inst::SveLd1R { zt: 0, pg: 0, esize: Esize::D, base: 2, imm: 0 });
        a.label("outer");
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.label("loop");
        let off = SveMemOff::RegScaled(4);
        a.push(Inst::SveLd1 { zt: 1, pg: 0, esize: Esize::D, base: 0, off, ff: false });
        a.push(Inst::SveLd1 { zt: 2, pg: 0, esize: Esize::D, base: 1, off, ff: false });
        a.push(Inst::SveFmla { zda: 2, pg: 0, zn: 1, zm: 0, dbl: true, sub: false });
        a.push(Inst::SveSt1 { zt: 2, pg: 0, esize: Esize::D, base: 1, off });
        a.push(Inst::IncDec { xdn: 4, esize: Esize::D, dec: false });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.push_branch(Inst::BCond { cond: Cond::FIRST, target: 0 }, "loop");
        a.push(Inst::AddImm { xd: 5, xn: 5, imm: -1 });
        a.push_branch(Inst::Cbnz { xn: 5, target: 0 }, "outer");
        a.push(Inst::Halt);
        a.finish()
    }

    /// Linked loop nests are bit-identical three ways, across VLs and
    /// awkward trip counts — and the trace engine really does take
    /// patched link jumps on the steady state.
    #[test]
    fn linked_loop_nests_are_bit_identical_three_way() {
        for vl in [128usize, 256, 1024] {
            for (n, reps) in [(0u64, 8u64), (1, 8), (7, 12), (16, 8), (33, 6)] {
                let mut mem = Memory::new();
                let x = mem.alloc(8 * n.max(1), 16);
                let y = mem.alloc(8 * n.max(1), 16);
                let a_addr = mem.alloc(8, 8);
                for i in 0..n {
                    mem.write_f64(x + 8 * i, 0.25 * i as f64).unwrap();
                    mem.write_f64(y + 8 * i, 10.0 + i as f64).unwrap();
                }
                mem.write_f64(a_addr, 1.5).unwrap();
                let prog = nested_daxpy_prog(x, y, a_addr, n, reps);
                let what = format!("nest n={n} reps={reps}@vl{vl}");
                run_both(&prog, &mem, vl, 1_000_000, &[(y, 8 * n)], &what);
            }
        }
        // the steady state of a big-enough nest takes patched links
        let mut mem = Memory::new();
        let x = mem.alloc(8 * 16, 16);
        let y = mem.alloc(8 * 16, 16);
        let a_addr = mem.alloc(8, 8);
        for i in 0..16u64 {
            mem.write_f64(x + 8 * i, 0.25 * i as f64).unwrap();
            mem.write_f64(y + 8 * i, 10.0 + i as f64).unwrap();
        }
        mem.write_f64(a_addr, 1.5).unwrap();
        let prog = nested_daxpy_prog(x, y, a_addr, 16, 8);
        let dec = DecodedProgram::decode(&prog);
        let mut ex = Executor::new(256, mem);
        let mut eng = crate::exec::TraceEngine::with_threshold(&dec, 2);
        let stats = eng.run_with(&mut ex, &dec, 1_000_000, |_| {}).unwrap();
        assert!(stats.trace.link_jumps > 0, "the nest steady state must run linked");
    }

    /// A `whilelt` hot loop through every newly dense-twinned tag —
    /// broadcast (`SveLd1R`), register copy (`CpyX`), select (`Sel`),
    /// gather and scatter (`BaseVec`), tree (`SveReduce`) and ordered
    /// (`SveFadda`) reductions — bit-identical three ways, and the trace
    /// engine really runs its dense slots on full-prefix iterations.
    #[test]
    fn dense_twin_gauntlet_is_bit_identical_three_way() {
        use crate::isa::{Cond, GatherAddr, Inst, RedOp, SveMemOff};
        let build = |n: u64| -> (Memory, u64, Program) {
            let mut mem = Memory::new();
            let x = mem.alloc(8 * n.max(1), 16);
            let y = mem.alloc(8 * n.max(1), 16);
            let idx = mem.alloc(8 * n.max(1), 16);
            let out = mem.alloc(8 * n.max(1), 16);
            let a_addr = mem.alloc(8, 8);
            for i in 0..n {
                mem.write_f64(x + 8 * i, 0.5 * i as f64 - 3.0).unwrap();
                mem.write_f64(y + 8 * i, 20.0 - i as f64).unwrap();
                // a permutation keeps scatter lanes disjoint
                mem.write_u64(idx + 8 * i, n - 1 - i).unwrap();
            }
            mem.write_f64(a_addr, 1.25).unwrap();
            let mut a = Asm::new();
            a.push(Inst::MovImm { xd: 0, imm: x });
            a.push(Inst::MovImm { xd: 1, imm: y });
            a.push(Inst::MovImm { xd: 2, imm: a_addr });
            a.push(Inst::MovImm { xd: 3, imm: n });
            a.push(Inst::MovImm { xd: 6, imm: idx });
            a.push(Inst::MovImm { xd: 8, imm: out });
            a.push(Inst::MovImm { xd: 7, imm: 0x4008_0000_0000_0000 }); // f64 3.0 bits
            a.push(Inst::MovImm { xd: 4, imm: 0 });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
            a.label("loop");
            let off = SveMemOff::RegScaled(4);
            a.push(Inst::SveLd1R { zt: 0, pg: 0, esize: Esize::D, base: 2, imm: 0 });
            a.push(Inst::SveLd1 { zt: 1, pg: 0, esize: Esize::D, base: 0, off, ff: false });
            a.push(Inst::SveLd1 { zt: 5, pg: 0, esize: Esize::D, base: 6, off, ff: false });
            let bv = GatherAddr::BaseVec { xn: 1, zm: 5, scaled: true };
            a.push(Inst::SveLdGather { zt: 2, pg: 0, esize: Esize::D, addr: bv, ff: false });
            a.push(Inst::CpyX { zd: 3, pg: 0, xn: 7, esize: Esize::D });
            a.push(Inst::Sel { zd: 4, pg: 0, zn: 2, zm: 3, esize: Esize::D });
            a.push(Inst::SveFmla { zda: 2, pg: 0, zn: 1, zm: 0, dbl: true, sub: false });
            a.push(Inst::SveStScatter { zt: 2, pg: 0, esize: Esize::D, addr: bv });
            a.push(Inst::SveSt1 { zt: 4, pg: 0, esize: Esize::D, base: 8, off });
            a.push(Inst::SveReduce { op: RedOp::FAddV, vd: 10, pg: 0, zn: 4, esize: Esize::D });
            a.push(Inst::SveFadda { vdn: 11, pg: 0, zm: 1, dbl: true });
            a.push(Inst::IncDec { xdn: 4, esize: Esize::D, dec: false });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
            a.push_branch(Inst::BCond { cond: Cond::FIRST, target: 0 }, "loop");
            a.push(Inst::Halt);
            (mem, y, a.finish())
        };
        for vl in [128usize, 256, 512, 1024] {
            for n in [0u64, 1, 5, 16, 33, 64] {
                let (mem, y, prog) = build(n);
                let what = format!("twin gauntlet n={n}@vl{vl}");
                run_both(&prog, &mem, vl, 1_000_000, &[(y, 8 * n)], &what);
            }
        }
        // the full-prefix iterations of a hot run take the dense slots
        let (mem, _y, prog) = build(64);
        let dec = DecodedProgram::decode(&prog);
        let mut ex = Executor::new(256, mem);
        let mut eng = crate::exec::TraceEngine::with_threshold(&dec, 2);
        let stats = eng.run_with(&mut ex, &dec, 1_000_000, |_| {}).unwrap();
        assert!(eng.has_dense_trace(), "the gauntlet loop must dense-specialize");
        assert!(stats.trace.dense_iters > 0, "and run dense iterations");
    }

    /// Budget exhaustion and faults trap identically on both paths.
    #[test]
    fn traps_agree_across_paths() {
        // budget
        let mut a = Asm::new();
        a.label("spin");
        a.push_branch(crate::isa::Inst::B { target: 0 }, "spin");
        let prog = a.finish();
        run_both(&prog, &Memory::new(), 128, 100, &[], "budget trap");
        // fault with a precise address
        let mut a = Asm::new();
        a.push(crate::isa::Inst::MovImm { xd: 0, imm: 0xBAD_000 });
        a.push(crate::isa::Inst::SveLd1 {
            zt: 0,
            pg: 0,
            esize: Esize::D,
            base: 0,
            off: crate::isa::SveMemOff::ImmVl(0),
            ff: false,
        });
        a.push(crate::isa::Inst::Halt);
        let prog = a.finish();
        let mut mem = Memory::new();
        mem.map(0x1000, 0x1000);
        run_both(&prog, &mem, 256, 100, &[], "fault trap");
    }
}
