//! Functional executor: architectural semantics for the scalar, NEON and
//! SVE subsets. Timing is *not* modelled here — the executor streams
//! retired-instruction information to a callback, which the
//! [`crate::uarch`] model consumes (classic trace-driven split).
//!
//! # Hot-path design
//!
//! The retire loop is the simulator's innermost loop (hundreds of
//! millions of iterations per Fig. 8 sweep), so:
//!
//! * a direct-mapped **software TLB** ([`Tlb`]) caches page→slot
//!   translations into [`Memory`]'s page table, validated against
//!   [`Memory::epoch`] so any `map`/`unmap_page` (or wholesale memory
//!   replacement) invalidates every entry — contiguous vector accesses
//!   translate once per *page* instead of once per lane, while
//!   first-fault loads still observe per-element faults (see
//!   `exec/sve.rs`);
//! * per-instruction static metadata (µop class, SVE/NEON/vector bits)
//!   is precomputed once per [`Executor::run_with`] call instead of
//!   re-deriving it from the `Inst` enum on every retire.

mod neon;
mod scalar;
mod sve;

use crate::arch::CpuState;
use crate::asm::Program;
use crate::isa::{Inst, UopClass};
use crate::mem::{MemFault, Memory, PAGE_SHIFT, PAGE_SIZE};

/// One architectural memory access, as seen by the LSU/cache model.
/// Contiguous vector accesses are reported as a single span (the LSU
/// splits them at the 512-bit port width); gathers/scatters report one
/// access per active element (the "cracked" implementation of §4/§5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemAccess {
    pub addr: u64,
    pub len: u32,
    pub is_store: bool,
}

/// Execution stopped abnormally.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trap {
    /// Unhandled memory fault (translation failure) at instruction `pc`.
    Fault { fault: MemFault, pc: usize },
    /// Instruction budget exhausted (runaway guard).
    Budget,
}

/// Per-retired-instruction view handed to the timing callback.
pub struct StepInfo<'a> {
    pub pc: usize,
    pub inst: &'a Inst,
    /// µop class, precomputed per pc (identical to `inst.class()`).
    pub class: UopClass,
    /// For branches: was it taken?
    pub taken: bool,
    pub mem: &'a [MemAccess],
}

/// Aggregate run statistics (the paper's Fig. 8 bar metric needs the
/// dynamic instruction mix).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunStats {
    pub insts: u64,
    pub sve_insts: u64,
    pub neon_insts: u64,
    /// Dynamic µops that are vector-class (SVE or NEON).
    pub vector_insts: u64,
}

impl RunStats {
    /// "Percentage of dynamically executed vector instructions" (§5).
    pub fn vector_fraction(&self) -> f64 {
        if self.insts == 0 {
            0.0
        } else {
            self.vector_insts as f64 / self.insts as f64
        }
    }
}

const TLB_SLOTS: usize = 32;
const TLB_INVALID_PAGE: u64 = u64::MAX;

/// Direct-mapped software TLB: page number → [`Memory`] slot handle.
///
/// Entries are valid only for the [`Memory::epoch`] they were filled at;
/// the epoch changes on every `map`/`unmap_page`/page-table growth and
/// on every new `Memory` value, so a mismatch flushes the whole TLB.
/// All-safe-Rust: a (hypothetically) stale handle panics in
/// `Memory::slot_frame` rather than reading the wrong page.
pub(crate) struct Tlb {
    epoch: u64,
    pages: [u64; TLB_SLOTS],
    slots: [u32; TLB_SLOTS],
}

impl Tlb {
    fn new() -> Self {
        // memory epochs are >= 1, so epoch 0 can never validate
        Tlb { epoch: 0, pages: [TLB_INVALID_PAGE; TLB_SLOTS], slots: [0; TLB_SLOTS] }
    }

    /// Translate `addr`'s page to a slot handle, filling on miss.
    /// `None` means the page is unmapped (the caller faults).
    #[inline]
    fn lookup(&mut self, mem: &Memory, addr: u64) -> Option<u32> {
        if self.epoch != mem.epoch() {
            self.pages = [TLB_INVALID_PAGE; TLB_SLOTS];
            self.epoch = mem.epoch();
        }
        let page = addr >> PAGE_SHIFT;
        let i = (page as usize) & (TLB_SLOTS - 1);
        if self.pages[i] == page {
            return Some(self.slots[i]);
        }
        let slot = mem.slot_handle(addr)?;
        self.pages[i] = page;
        self.slots[i] = slot;
        Some(slot)
    }
}

/// Per-pc static metadata, precomputed once per run.
#[derive(Clone, Copy)]
struct InstMeta {
    class: UopClass,
    flags: u8,
}

const META_SVE: u8 = 1;
const META_NEON: u8 = 2;
const META_VECTOR: u8 = 4;

impl InstMeta {
    fn of(inst: &Inst) -> InstMeta {
        let class = inst.class();
        let mut flags = 0u8;
        if inst.is_sve() {
            flags |= META_SVE;
        }
        if inst.is_neon() {
            flags |= META_NEON;
        }
        if class.is_vector() {
            flags |= META_VECTOR;
        }
        InstMeta { class, flags }
    }
}

/// The functional core: architectural state + memory.
pub struct Executor {
    pub state: CpuState,
    pub mem: Memory,
    /// Software TLB over `mem`'s page table.
    pub(crate) tlb: Tlb,
    /// Scratch buffer of the current instruction's memory accesses.
    pub(crate) accesses: Vec<MemAccess>,
    /// PC override set by a taken branch during `exec_inst`.
    pub(crate) next_pc: Option<usize>,
    /// Scratch lane buffer for vector loads (avoids per-inst allocation).
    pub(crate) lane_scratch: Vec<u64>,
    /// Set by Halt/Ret.
    pub(crate) halted: bool,
}

impl Executor {
    pub fn new(vl_bits: usize, mem: Memory) -> Self {
        Executor {
            state: CpuState::new(vl_bits),
            mem,
            tlb: Tlb::new(),
            accesses: Vec::with_capacity(64),
            next_pc: None,
            lane_scratch: vec![0; 256],
            halted: false,
        }
    }

    /// Execute one instruction at `state.pc`. On success advances the PC
    /// and returns whether a branch was taken.
    pub fn step(&mut self, prog: &Program) -> Result<bool, Trap> {
        self.exec_at(prog, self.state.pc)
    }

    /// Execute the instruction at `pc` and advance the PC — the single
    /// shared body behind [`Executor::step`] and the `run_with` loop.
    #[inline(always)]
    fn exec_at(&mut self, prog: &Program, pc: usize) -> Result<bool, Trap> {
        let inst = &prog.insts[pc];
        self.accesses.clear();
        self.next_pc = None;
        if let Err(fault) = self.exec_inst(inst) {
            return Err(Trap::Fault { fault, pc });
        }
        let taken = self.next_pc.is_some();
        self.state.pc = match self.next_pc {
            Some(t) => t,
            None => pc + 1,
        };
        Ok(taken)
    }

    /// Run until Halt/Ret (Ok) or a trap (Err), streaming retire info.
    pub fn run_with(
        &mut self,
        prog: &Program,
        max_insts: u64,
        mut on_retire: impl FnMut(StepInfo<'_>),
    ) -> Result<RunStats, Trap> {
        // One pass over the static program instead of three enum matches
        // per retired instruction.
        let meta: Vec<InstMeta> = prog.insts.iter().map(InstMeta::of).collect();
        let mut stats = RunStats::default();
        while !self.halted {
            if stats.insts >= max_insts {
                return Err(Trap::Budget);
            }
            let pc = self.state.pc;
            let taken = self.exec_at(prog, pc)?;
            let inst = &prog.insts[pc];
            let m = meta[pc];
            stats.insts += 1;
            stats.sve_insts += u64::from(m.flags & META_SVE != 0);
            stats.neon_insts += u64::from(m.flags & META_NEON != 0);
            stats.vector_insts += u64::from(m.flags & META_VECTOR != 0);
            on_retire(StepInfo { pc, inst, class: m.class, taken, mem: &self.accesses });
        }
        Ok(stats)
    }

    /// Run without a timing consumer.
    pub fn run(&mut self, prog: &Program, max_insts: u64) -> Result<RunStats, Trap> {
        self.run_with(prog, max_insts, |_| {})
    }

    /// Dispatch. Implementations live in `scalar.rs`, `neon.rs`, `sve.rs`.
    fn exec_inst(&mut self, inst: &Inst) -> Result<(), MemFault> {
        use Inst::*;
        match inst {
            // scalar (incl. scalar fp)
            MovImm { .. } | MovReg { .. } | AddImm { .. } | AddReg { .. } | SubReg { .. }
            | Madd { .. } | Udiv { .. } | AndImm { .. } | LogReg { .. } | LslImm { .. }
            | LsrImm { .. } | AsrImm { .. } | Csel { .. } | Ldr { .. } | Str { .. }
            | LdrFp { .. } | StrFp { .. } | CmpImm { .. } | CmpReg { .. } | B { .. }
            | BCond { .. } | Cbz { .. } | Cbnz { .. } | Ret | Halt | Nop | FmovImm { .. }
            | FmovXtoD { .. } | FmovDtoX { .. } | FmovReg { .. } | FpBin { .. } | FpUn { .. } | Fmadd { .. }
            | Fcmp { .. } | Scvtf { .. } | Fcvtzs { .. } | OpaqueCall { .. } => {
                self.exec_scalar(inst)
            }
            // NEON
            NeonLd1 { .. } | NeonSt1 { .. } | NeonDupX { .. } | NeonDupLane0 { .. }
            | NeonMoviZero { .. } | NeonFpBin { .. } | NeonFpUn { .. } | NeonFmla { .. }
            | NeonIntBin { .. } | NeonFcm { .. } | NeonCm { .. } | NeonBsl { .. }
            | NeonFaddv { .. } | NeonAddv { .. } | NeonUmov { .. } | NeonInsX { .. } => {
                self.exec_neon(inst)
            }
            // SVE
            _ => self.exec_sve(inst),
        }
    }

    // ---- shared helpers ----

    #[inline]
    pub(crate) fn record_load(&mut self, addr: u64, len: u32) {
        self.accesses.push(MemAccess { addr, len, is_store: false });
    }

    #[inline]
    pub(crate) fn record_store(&mut self, addr: u64, len: u32) {
        self.accesses.push(MemAccess { addr, len, is_store: true });
    }

    /// Contiguous read through the TLB: one translation per page
    /// touched, `copy_from_slice` within each page. Copies until the
    /// first unmapped byte; returns bytes copied plus the fault, if any
    /// (the fault address is the exact first unmapped byte, matching the
    /// per-byte path's reporting).
    pub(crate) fn read_contig_partial(
        &mut self,
        addr: u64,
        out: &mut [u8],
    ) -> (usize, Option<MemFault>) {
        let mut done = 0usize;
        while done < out.len() {
            let a = addr + done as u64;
            let off = (a as usize) & (PAGE_SIZE - 1);
            let chunk = (PAGE_SIZE - off).min(out.len() - done);
            match self.tlb.lookup(&self.mem, a) {
                Some(slot) => {
                    out[done..done + chunk]
                        .copy_from_slice(&self.mem.slot_frame(slot)[off..off + chunk]);
                    done += chunk;
                }
                None => return (done, Some(MemFault { addr: a, is_store: false })),
            }
        }
        (done, None)
    }

    /// All-or-fault contiguous read through the TLB.
    pub(crate) fn read_contig(&mut self, addr: u64, out: &mut [u8]) -> Result<(), MemFault> {
        match self.read_contig_partial(addr, out) {
            (_, Some(fault)) => Err(fault),
            _ => Ok(()),
        }
    }

    /// Contiguous write through the TLB (one translation per page).
    /// Pages before the first unmapped byte stay written on fault, the
    /// same observable behaviour as the per-element path (a fault aborts
    /// the whole run).
    pub(crate) fn write_contig(&mut self, addr: u64, src: &[u8]) -> Result<(), MemFault> {
        let mut done = 0usize;
        while done < src.len() {
            let a = addr + done as u64;
            let off = (a as usize) & (PAGE_SIZE - 1);
            let chunk = (PAGE_SIZE - off).min(src.len() - done);
            let slot = self
                .tlb
                .lookup(&self.mem, a)
                .ok_or(MemFault { addr: a, is_store: true })?;
            self.mem.slot_frame_mut(slot)[off..off + chunk]
                .copy_from_slice(&src[done..done + chunk]);
            done += chunk;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;

    #[test]
    fn run_halts_and_counts() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 3 });
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 4 });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(256, Memory::new());
        let stats = ex.run(&p, 100).unwrap();
        assert_eq!(stats.insts, 3);
        assert_eq!(ex.state.get_x(0), 7);
    }

    #[test]
    fn budget_guard_trips_on_infinite_loop() {
        let mut a = Asm::new();
        a.label("x");
        a.push_branch(Inst::B { target: 0 }, "x");
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        assert_eq!(ex.run(&p, 50), Err(Trap::Budget));
    }

    #[test]
    fn vector_fraction_metric() {
        let s = RunStats { insts: 10, sve_insts: 4, neon_insts: 0, vector_insts: 5 };
        assert!((s.vector_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(RunStats::default().vector_fraction(), 0.0);
    }

    #[test]
    fn step_info_class_matches_inst_class() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 1 });
        a.push(Inst::Setffr);
        a.push(Inst::NeonMoviZero { vd: 0 });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        ex.run_with(&p, 100, |info| {
            assert_eq!(info.class, info.inst.class(), "pc {}", info.pc);
        })
        .unwrap();
    }

    #[test]
    fn contig_helpers_roundtrip_and_fault() {
        let mut mem = Memory::new();
        mem.map(0x1000, 2 * PAGE_SIZE as u64); // third page unmapped
        let mut ex = Executor::new(128, mem);
        let base = 0x1000 + PAGE_SIZE as u64 - 8; // straddles a boundary
        let src: Vec<u8> = (0..64u8).collect();
        ex.write_contig(base, &src).unwrap();
        let mut out = [0u8; 64];
        ex.read_contig(base, &mut out).unwrap();
        assert_eq!(&out[..], &src[..]);
        // partial read up to the hole after page 2
        let tail = 0x1000 + 2 * PAGE_SIZE as u64 - 4;
        let mut buf = [0u8; 16];
        let (copied, fault) = ex.read_contig_partial(tail, &mut buf);
        assert_eq!(copied, 4);
        assert_eq!(fault, Some(MemFault { addr: 0x3000, is_store: false }));
    }
}
