//! Functional executor: architectural semantics for the scalar, NEON and
//! SVE subsets. Timing is *not* modelled here — the executor streams
//! retired-instruction information to a callback, which the
//! [`crate::uarch`] model consumes (classic trace-driven split).

mod neon;
mod scalar;
mod sve;

use crate::arch::CpuState;
use crate::asm::Program;
use crate::isa::Inst;
use crate::mem::{MemFault, Memory};

/// One architectural memory access, as seen by the LSU/cache model.
/// Contiguous vector accesses are reported as a single span (the LSU
/// splits them at the 512-bit port width); gathers/scatters report one
/// access per active element (the "cracked" implementation of §4/§5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemAccess {
    pub addr: u64,
    pub len: u32,
    pub is_store: bool,
}

/// Execution stopped abnormally.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trap {
    /// Unhandled memory fault (translation failure) at instruction `pc`.
    Fault { fault: MemFault, pc: usize },
    /// Instruction budget exhausted (runaway guard).
    Budget,
}

/// Per-retired-instruction view handed to the timing callback.
pub struct StepInfo<'a> {
    pub pc: usize,
    pub inst: &'a Inst,
    /// For branches: was it taken?
    pub taken: bool,
    pub mem: &'a [MemAccess],
}

/// Aggregate run statistics (the paper's Fig. 8 bar metric needs the
/// dynamic instruction mix).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunStats {
    pub insts: u64,
    pub sve_insts: u64,
    pub neon_insts: u64,
    /// Dynamic µops that are vector-class (SVE or NEON).
    pub vector_insts: u64,
}

impl RunStats {
    /// "Percentage of dynamically executed vector instructions" (§5).
    pub fn vector_fraction(&self) -> f64 {
        if self.insts == 0 {
            0.0
        } else {
            self.vector_insts as f64 / self.insts as f64
        }
    }
}

/// The functional core: architectural state + memory.
pub struct Executor {
    pub state: CpuState,
    pub mem: Memory,
    /// Scratch buffer of the current instruction's memory accesses.
    pub(crate) accesses: Vec<MemAccess>,
    /// PC override set by a taken branch during `exec_inst`.
    pub(crate) next_pc: Option<usize>,
    /// Scratch lane buffer for vector loads (avoids per-inst allocation).
    pub(crate) lane_scratch: Vec<u64>,
    /// Set by Halt/Ret.
    pub(crate) halted: bool,
}

impl Executor {
    pub fn new(vl_bits: usize, mem: Memory) -> Self {
        Executor {
            state: CpuState::new(vl_bits),
            mem,
            accesses: Vec::with_capacity(64),
            next_pc: None,
            lane_scratch: vec![0; 256],
            halted: false,
        }
    }

    /// Execute one instruction at `state.pc`. On success advances the PC
    /// and returns whether a branch was taken.
    pub fn step(&mut self, prog: &Program) -> Result<bool, Trap> {
        let pc = self.state.pc;
        let inst = &prog.insts[pc];
        self.accesses.clear();
        self.next_pc = None;
        match self.exec_inst(inst) {
            Ok(()) => {
                let taken = self.next_pc.is_some();
                self.state.pc = self.next_pc.unwrap_or(pc + 1);
                Ok(taken)
            }
            Err(fault) => Err(Trap::Fault { fault, pc }),
        }
    }

    /// Run until Halt/Ret (Ok) or a trap (Err), streaming retire info.
    pub fn run_with(
        &mut self,
        prog: &Program,
        max_insts: u64,
        mut on_retire: impl FnMut(StepInfo<'_>),
    ) -> Result<RunStats, Trap> {
        let mut stats = RunStats::default();
        while !self.halted {
            if stats.insts >= max_insts {
                return Err(Trap::Budget);
            }
            let pc = self.state.pc;
            let taken = self.step(prog)?;
            let inst = &prog.insts[pc];
            stats.insts += 1;
            if inst.is_sve() {
                stats.sve_insts += 1;
            }
            if inst.is_neon() {
                stats.neon_insts += 1;
            }
            if inst.class().is_vector() {
                stats.vector_insts += 1;
            }
            on_retire(StepInfo { pc, inst, taken, mem: &self.accesses });
        }
        Ok(stats)
    }

    /// Run without a timing consumer.
    pub fn run(&mut self, prog: &Program, max_insts: u64) -> Result<RunStats, Trap> {
        self.run_with(prog, max_insts, |_| {})
    }

    /// Dispatch. Implementations live in `scalar.rs`, `neon.rs`, `sve.rs`.
    fn exec_inst(&mut self, inst: &Inst) -> Result<(), MemFault> {
        use Inst::*;
        match inst {
            // scalar (incl. scalar fp)
            MovImm { .. } | MovReg { .. } | AddImm { .. } | AddReg { .. } | SubReg { .. }
            | Madd { .. } | Udiv { .. } | AndImm { .. } | LogReg { .. } | LslImm { .. }
            | LsrImm { .. } | AsrImm { .. } | Csel { .. } | Ldr { .. } | Str { .. }
            | LdrFp { .. } | StrFp { .. } | CmpImm { .. } | CmpReg { .. } | B { .. }
            | BCond { .. } | Cbz { .. } | Cbnz { .. } | Ret | Halt | Nop | FmovImm { .. }
            | FmovXtoD { .. } | FmovDtoX { .. } | FmovReg { .. } | FpBin { .. } | FpUn { .. } | Fmadd { .. }
            | Fcmp { .. } | Scvtf { .. } | Fcvtzs { .. } | OpaqueCall { .. } => {
                self.exec_scalar(inst)
            }
            // NEON
            NeonLd1 { .. } | NeonSt1 { .. } | NeonDupX { .. } | NeonDupLane0 { .. }
            | NeonMoviZero { .. } | NeonFpBin { .. } | NeonFpUn { .. } | NeonFmla { .. }
            | NeonIntBin { .. } | NeonFcm { .. } | NeonCm { .. } | NeonBsl { .. }
            | NeonFaddv { .. } | NeonAddv { .. } | NeonUmov { .. } | NeonInsX { .. } => {
                self.exec_neon(inst)
            }
            // SVE
            _ => self.exec_sve(inst),
        }
    }

    // ---- shared helpers ----

    #[inline]
    pub(crate) fn record_load(&mut self, addr: u64, len: u32) {
        self.accesses.push(MemAccess { addr, len, is_store: false });
    }

    #[inline]
    pub(crate) fn record_store(&mut self, addr: u64, len: u32) {
        self.accesses.push(MemAccess { addr, len, is_store: true });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;

    #[test]
    fn run_halts_and_counts() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 3 });
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 4 });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(256, Memory::new());
        let stats = ex.run(&p, 100).unwrap();
        assert_eq!(stats.insts, 3);
        assert_eq!(ex.state.get_x(0), 7);
    }

    #[test]
    fn budget_guard_trips_on_infinite_loop() {
        let mut a = Asm::new();
        a.label("x");
        a.push_branch(Inst::B { target: 0 }, "x");
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        assert_eq!(ex.run(&p, 50), Err(Trap::Budget));
    }

    #[test]
    fn vector_fraction_metric() {
        let s = RunStats { insts: 10, sve_insts: 4, neon_insts: 0, vector_insts: 5 };
        assert!((s.vector_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(RunStats::default().vector_fraction(), 0.0);
    }
}
