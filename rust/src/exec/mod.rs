//! Functional executor: architectural semantics for the scalar, NEON and
//! SVE subsets. Timing is *not* modelled here — the executor streams
//! retired-instruction information to a callback, which the
//! [`crate::uarch`] model consumes (classic trace-driven split).
//!
//! # Hot-path design
//!
//! The retire loop is the simulator's innermost loop (hundreds of
//! millions of iterations per Fig. 8 sweep), so:
//!
//! * programs are **decoded once** into µops
//!   ([`crate::isa::uop::DecodedProgram`]) — operand fields, µop class,
//!   cracking rule and register-dependence slots are pre-resolved, and
//!   the execute loop dispatches through the tag-indexed [`DISPATCH`]
//!   table instead of re-matching the `Inst` enum per retire (the
//!   decoder is the only place `Inst` is matched);
//! * a direct-mapped **software TLB** ([`Tlb`]) caches page→slot
//!   translations into [`Memory`]'s page table, validated against
//!   [`Memory::epoch`] so any `map`/`unmap_page` (or wholesale memory
//!   replacement) invalidates every entry — contiguous vector accesses
//!   translate once per *page* instead of once per lane, while
//!   first-fault loads still observe per-element faults (see
//!   `exec/sve.rs`).

mod neon;
mod scalar;
mod sve;
pub mod trace;

#[cfg(test)]
mod legacy;

pub use trace::{TraceEngine, TraceStats};

use crate::arch::CpuState;
use crate::asm::Program;
use crate::isa::uop::{DecodedProgram, Uop, UopTag};
use crate::isa::Inst;
use crate::mem::{MemFault, Memory, PAGE_SHIFT, PAGE_SIZE};

/// One architectural memory access, as seen by the LSU/cache model.
/// Contiguous vector accesses are reported as a single span (the LSU
/// splits them at the 512-bit port width); gathers/scatters report one
/// access per active element (the "cracked" implementation of §4/§5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemAccess {
    pub addr: u64,
    pub len: u32,
    pub is_store: bool,
}

/// Execution stopped abnormally.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trap {
    /// Unhandled memory fault (translation failure) at instruction `pc`.
    Fault { fault: MemFault, pc: usize },
    /// Instruction budget exhausted (runaway guard).
    Budget,
}

/// Per-retired-instruction view handed to the timing callback. All
/// static metadata comes from the shared decode layer: the timing model
/// never re-derives classes or dependence sets from the `Inst`.
pub struct StepInfo<'a> {
    pub pc: usize,
    /// The decoded µop: class, cracking rule, operand metadata.
    pub uop: &'a Uop,
    /// The source instruction — for disassembly/trace rendering only.
    pub inst: &'a Inst,
    /// Scoreboard slots read, pre-mapped by the decoder
    /// ([`crate::isa::uop::reg_slot`]).
    pub reads: &'a [u8],
    /// Scoreboard slots written.
    pub writes: &'a [u8],
    /// For branches: was it taken?
    pub taken: bool,
    pub mem: &'a [MemAccess],
}

/// Aggregate run statistics (the paper's Fig. 8 bar metric needs the
/// dynamic instruction mix).
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    pub insts: u64,
    pub sve_insts: u64,
    pub neon_insts: u64,
    /// Dynamic µops that are vector-class (SVE or NEON).
    pub vector_insts: u64,
    /// Trace-cache telemetry (always zero on [`Engine::Baseline`]).
    pub trace: TraceStats,
}

/// Equality compares the **architectural contract** only: the retire
/// counters every engine must reproduce bit-identically. The `trace`
/// field is engine-local observability (the baseline interpreter and
/// the legacy harness have no trace cache to count), so the three-way
/// bit-identity walls and the coordinator's engine-equivalence checks
/// deliberately ignore it.
impl PartialEq for RunStats {
    fn eq(&self, other: &RunStats) -> bool {
        self.insts == other.insts
            && self.sve_insts == other.sve_insts
            && self.neon_insts == other.neon_insts
            && self.vector_insts == other.vector_insts
    }
}

impl RunStats {
    /// "Percentage of dynamically executed vector instructions" (§5).
    pub fn vector_fraction(&self) -> f64 {
        if self.insts == 0 {
            0.0
        } else {
            self.vector_insts as f64 / self.insts as f64
        }
    }
}

/// Which functional-execution engine to run a decoded program on. Both
/// are bit-identical in architectural state, retire stream and
/// statistics (pinned by the `exec/legacy.rs` harness); they differ
/// only in wall-clock speed. [`Engine::Trace`] is the default
/// everywhere; `--no-trace` on the CLI selects [`Engine::Baseline`]
/// for A/B runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// The block interpreter ([`Executor::run_decoded_with`]).
    Baseline,
    /// The superblock trace cache ([`TraceEngine`]).
    #[default]
    Trace,
}

impl Engine {
    /// Stable label for reports and JSON artifacts.
    pub fn label(self) -> &'static str {
        match self {
            Engine::Baseline => "baseline",
            Engine::Trace => "trace",
        }
    }
}

const TLB_SLOTS: usize = 32;
const TLB_INVALID_PAGE: u64 = u64::MAX;

/// Direct-mapped software TLB: page number → [`Memory`] slot handle.
///
/// Entries are valid only for the [`Memory::epoch`] they were filled at;
/// the epoch changes on every `map`/`unmap_page`/page-table growth and
/// on every new `Memory` value, so a mismatch flushes the whole TLB.
/// All-safe-Rust: a (hypothetically) stale handle panics in
/// `Memory::slot_frame` rather than reading the wrong page.
pub(crate) struct Tlb {
    epoch: u64,
    pages: [u64; TLB_SLOTS],
    slots: [u32; TLB_SLOTS],
}

impl Tlb {
    fn new() -> Self {
        // memory epochs are >= 1, so epoch 0 can never validate
        Tlb { epoch: 0, pages: [TLB_INVALID_PAGE; TLB_SLOTS], slots: [0; TLB_SLOTS] }
    }

    /// Translate `addr`'s page to a slot handle, filling on miss.
    /// `None` means the page is unmapped (the caller faults).
    #[inline]
    fn lookup(&mut self, mem: &Memory, addr: u64) -> Option<u32> {
        if self.epoch != mem.epoch() {
            self.pages = [TLB_INVALID_PAGE; TLB_SLOTS];
            self.epoch = mem.epoch();
        }
        let page = addr >> PAGE_SHIFT;
        let i = (page as usize) & (TLB_SLOTS - 1);
        if self.pages[i] == page {
            return Some(self.slots[i]);
        }
        let slot = mem.slot_handle(addr)?;
        self.pages[i] = page;
        self.slots[i] = slot;
        Some(slot)
    }
}

/// Result type of every µop handler.
pub(crate) type ExecResult = Result<(), MemFault>;

/// A µop handler: executes one decoded µop against the architectural
/// state.
pub(crate) type Handler = fn(&mut Executor, &Uop) -> ExecResult;

fn h_invalid(_ex: &mut Executor, u: &Uop) -> ExecResult {
    unreachable!("no handler wired for µop tag {:?}", u.tag)
}

/// The tag-indexed dispatch table: one handler per [`UopTag`]. Built at
/// compile time; [`h_invalid`] only remains for tags the decoder can
/// never produce (there are none — pinned by the decode-coverage test).
pub(crate) static DISPATCH: [Handler; UopTag::COUNT] = dispatch_table();

const fn dispatch_table() -> [Handler; UopTag::COUNT] {
    use UopTag as T;
    let mut t: [Handler; UopTag::COUNT] = [h_invalid as Handler; UopTag::COUNT];
    t[T::MovImm as usize] = scalar::h_mov_imm;
    t[T::MovReg as usize] = scalar::h_mov_reg;
    t[T::AddImm as usize] = scalar::h_add_imm;
    t[T::AddReg as usize] = scalar::h_add_reg;
    t[T::SubReg as usize] = scalar::h_sub_reg;
    t[T::Madd as usize] = scalar::h_madd;
    t[T::Udiv as usize] = scalar::h_udiv;
    t[T::AndImm as usize] = scalar::h_and_imm;
    t[T::LogReg as usize] = scalar::h_log_reg;
    t[T::LslImm as usize] = scalar::h_lsl_imm;
    t[T::LsrImm as usize] = scalar::h_lsr_imm;
    t[T::AsrImm as usize] = scalar::h_asr_imm;
    t[T::Csel as usize] = scalar::h_csel;
    t[T::LdrImm as usize] = scalar::h_ldr_imm;
    t[T::LdrReg as usize] = scalar::h_ldr_reg;
    t[T::StrImm as usize] = scalar::h_str_imm;
    t[T::StrReg as usize] = scalar::h_str_reg;
    t[T::LdrFpImm as usize] = scalar::h_ldr_fp_imm;
    t[T::LdrFpReg as usize] = scalar::h_ldr_fp_reg;
    t[T::StrFpImm as usize] = scalar::h_str_fp_imm;
    t[T::StrFpReg as usize] = scalar::h_str_fp_reg;
    t[T::CmpImm as usize] = scalar::h_cmp_imm;
    t[T::CmpReg as usize] = scalar::h_cmp_reg;
    t[T::B as usize] = scalar::h_b;
    t[T::BCond as usize] = scalar::h_b_cond;
    t[T::Cbz as usize] = scalar::h_cbz;
    t[T::Cbnz as usize] = scalar::h_cbnz;
    t[T::Halt as usize] = scalar::h_halt;
    t[T::Nop as usize] = scalar::h_nop;
    t[T::FmovImm as usize] = scalar::h_fmov_imm;
    t[T::FmovXtoD as usize] = scalar::h_fmov_x_to_d;
    t[T::FmovReg as usize] = scalar::h_fmov_reg;
    t[T::FmovDtoX as usize] = scalar::h_fmov_d_to_x;
    t[T::FpBin as usize] = scalar::h_fp_bin;
    t[T::FpUn as usize] = scalar::h_fp_un;
    t[T::Fmadd as usize] = scalar::h_fmadd;
    t[T::Fcmp as usize] = scalar::h_fcmp;
    t[T::Scvtf as usize] = scalar::h_scvtf;
    t[T::Fcvtzs as usize] = scalar::h_fcvtzs;
    t[T::OpaqueCall as usize] = scalar::h_opaque_call;
    t[T::NeonLd1Imm as usize] = neon::h_neon_ld1_imm;
    t[T::NeonLd1Reg as usize] = neon::h_neon_ld1_reg;
    t[T::NeonSt1Imm as usize] = neon::h_neon_st1_imm;
    t[T::NeonSt1Reg as usize] = neon::h_neon_st1_reg;
    t[T::NeonDupX as usize] = neon::h_neon_dup_x;
    t[T::NeonDupLane0 as usize] = neon::h_neon_dup_lane0;
    t[T::NeonMoviZero as usize] = neon::h_neon_movi_zero;
    t[T::NeonFpBin as usize] = neon::h_neon_fp_bin;
    t[T::NeonFpUn as usize] = neon::h_neon_fp_un;
    t[T::NeonFmla as usize] = neon::h_neon_fmla;
    t[T::NeonIntBin as usize] = neon::h_neon_int_bin;
    t[T::NeonFcm as usize] = neon::h_neon_fcm;
    t[T::NeonCm as usize] = neon::h_neon_cm;
    t[T::NeonBsl as usize] = neon::h_neon_bsl;
    t[T::NeonFaddv as usize] = neon::h_neon_faddv;
    t[T::NeonAddv as usize] = neon::h_neon_addv;
    t[T::NeonUmov as usize] = neon::h_neon_umov;
    t[T::NeonInsX as usize] = neon::h_neon_ins_x;
    t[T::Ptrue as usize] = sve::h_ptrue;
    t[T::Pfalse as usize] = sve::h_pfalse;
    t[T::While as usize] = sve::h_while;
    t[T::Ptest as usize] = sve::h_ptest;
    t[T::Pnext as usize] = sve::h_pnext;
    t[T::Brk as usize] = sve::h_brk;
    t[T::PredLogic as usize] = sve::h_pred_logic;
    t[T::Rdffr as usize] = sve::h_rdffr;
    t[T::Setffr as usize] = sve::h_setffr;
    t[T::Wrffr as usize] = sve::h_wrffr;
    t[T::Cnt as usize] = sve::h_cnt;
    t[T::IncDec as usize] = sve::h_inc_dec;
    t[T::IncpX as usize] = sve::h_incp_x;
    t[T::Index as usize] = sve::h_index;
    t[T::DupImm as usize] = sve::h_dup_imm;
    t[T::FdupImm as usize] = sve::h_fdup_imm;
    t[T::DupX as usize] = sve::h_dup_x;
    t[T::CpyX as usize] = sve::h_cpy_x;
    t[T::Sel as usize] = sve::h_sel;
    t[T::Movprfx as usize] = sve::h_movprfx;
    t[T::Last as usize] = sve::h_last;
    t[T::SveLd1ImmVl as usize] = sve::h_sve_ld1_imm_vl;
    t[T::SveLd1Reg as usize] = sve::h_sve_ld1_reg;
    t[T::SveLd1R as usize] = sve::h_sve_ld1r;
    t[T::SveSt1ImmVl as usize] = sve::h_sve_st1_imm_vl;
    t[T::SveSt1Reg as usize] = sve::h_sve_st1_reg;
    t[T::SveGatherVecImm as usize] = sve::h_sve_gather_vec_imm;
    t[T::SveGatherBaseVec as usize] = sve::h_sve_gather_base_vec;
    t[T::SveScatterVecImm as usize] = sve::h_sve_scatter_vec_imm;
    t[T::SveScatterBaseVec as usize] = sve::h_sve_scatter_base_vec;
    t[T::SveIntBin as usize] = sve::h_sve_int_bin;
    t[T::SveIntBinU as usize] = sve::h_sve_int_bin_u;
    t[T::SveAddImm as usize] = sve::h_sve_add_imm;
    t[T::SveFpBin as usize] = sve::h_sve_fp_bin;
    t[T::SveFpUn as usize] = sve::h_sve_fp_un;
    t[T::SveFmla as usize] = sve::h_sve_fmla;
    t[T::SveScvtf as usize] = sve::h_sve_scvtf;
    t[T::SveIntCmpZ as usize] = sve::h_sve_int_cmp_z;
    t[T::SveIntCmpImm as usize] = sve::h_sve_int_cmp_imm;
    t[T::SveFpCmpV as usize] = sve::h_sve_fp_cmp_v;
    t[T::SveFpCmp0 as usize] = sve::h_sve_fp_cmp_0;
    t[T::SveReduce as usize] = sve::h_sve_reduce;
    t[T::SveFadda as usize] = sve::h_sve_fadda;
    t[T::SveRev as usize] = sve::h_sve_rev;
    t[T::SveExt as usize] = sve::h_sve_ext;
    t[T::SveZip as usize] = sve::h_sve_zip;
    t[T::SveUzp as usize] = sve::h_sve_uzp;
    t[T::SveTrn as usize] = sve::h_sve_trn;
    t[T::SveTbl as usize] = sve::h_sve_tbl;
    t[T::SveCompact as usize] = sve::h_sve_compact;
    t[T::SveSplice as usize] = sve::h_sve_splice;
    t[T::Cterm as usize] = sve::h_cterm;
    t
}

/// The functional core: architectural state + memory.
pub struct Executor {
    pub state: CpuState,
    pub mem: Memory,
    /// Software TLB over `mem`'s page table.
    pub(crate) tlb: Tlb,
    /// Scratch buffer of the current instruction's memory accesses.
    pub(crate) accesses: Vec<MemAccess>,
    /// PC override set by a taken branch during µop execution.
    pub(crate) next_pc: Option<usize>,
    /// Scratch lane buffer for vector loads (avoids per-inst allocation).
    pub(crate) lane_scratch: Vec<u64>,
    /// Set by Halt/Ret.
    pub(crate) halted: bool,
}

impl Executor {
    pub fn new(vl_bits: usize, mem: Memory) -> Self {
        Executor {
            state: CpuState::new(vl_bits),
            mem,
            tlb: Tlb::new(),
            accesses: Vec::with_capacity(64),
            next_pc: None,
            lane_scratch: vec![0; 256],
            halted: false,
        }
    }

    /// Execute one µop at `state.pc`. On success advances the PC and
    /// returns whether a branch was taken.
    pub fn step(&mut self, dec: &DecodedProgram) -> Result<bool, Trap> {
        self.exec_at(dec, self.state.pc)
    }

    /// Execute the µop at `pc` and advance the PC — the single shared
    /// body behind [`Executor::step`] and the `run_decoded_with` loop.
    #[inline(always)]
    fn exec_at(&mut self, dec: &DecodedProgram, pc: usize) -> Result<bool, Trap> {
        let u = &dec.uops()[pc];
        self.accesses.clear();
        self.next_pc = None;
        if let Err(fault) = DISPATCH[u.tag as usize](self, u) {
            return Err(Trap::Fault { fault, pc });
        }
        let taken = self.next_pc.is_some();
        self.state.pc = match self.next_pc {
            Some(t) => t,
            None => pc + 1,
        };
        Ok(taken)
    }

    /// Run a pre-decoded program until Halt/Ret (Ok) or a trap (Err),
    /// streaming retire info. This is the hot path: the sweep
    /// coordinator decodes each program once per (benchmark, target)
    /// and shares it across every VL and µarch variant.
    pub fn run_decoded_with(
        &mut self,
        dec: &DecodedProgram,
        max_insts: u64,
        mut on_retire: impl FnMut(StepInfo<'_>),
    ) -> Result<RunStats, Trap> {
        let uops = dec.uops();
        let insts = dec.insts();
        let straight = dec.straight_lens();
        let mut stats = RunStats::default();
        while !self.halted {
            let remaining = max_insts - stats.insts;
            if remaining == 0 {
                return Err(Trap::Budget);
            }
            // One straight-line run: only its final µop can redirect
            // the pc or halt, so the budget is metered here, once per
            // run, instead of once per retire (the min keeps trip
            // counts exact — a clamped run re-enters the check above).
            let n = match straight.get(self.state.pc) {
                Some(&l) => u64::from(l).min(remaining),
                None => 1, // out-of-range pc: panics below, like any bad index
            };
            for _ in 0..n {
                let pc = self.state.pc;
                let taken = self.exec_at(dec, pc)?;
                let u = &uops[pc];
                stats.insts += 1;
                stats.sve_insts += u64::from(u.is_sve());
                stats.neon_insts += u64::from(u.is_neon());
                stats.vector_insts += u64::from(u.is_vector());
                on_retire(StepInfo {
                    pc,
                    uop: u,
                    inst: &insts[pc],
                    reads: dec.reads(u),
                    writes: dec.writes(u),
                    taken,
                    mem: &self.accesses,
                });
            }
        }
        Ok(stats)
    }

    /// Run a pre-decoded program on the selected [`Engine`]. For
    /// repeated runs of the same program on [`Engine::Trace`], build a
    /// [`TraceEngine`] once and reuse it so formed traces persist.
    pub fn run_decoded_engine_with(
        &mut self,
        dec: &DecodedProgram,
        engine: Engine,
        max_insts: u64,
        on_retire: impl FnMut(StepInfo<'_>),
    ) -> Result<RunStats, Trap> {
        match engine {
            Engine::Baseline => self.run_decoded_with(dec, max_insts, on_retire),
            Engine::Trace => TraceEngine::new(dec).run_with(self, dec, max_insts, on_retire),
        }
    }

    /// Run a pre-decoded program without a timing consumer.
    pub fn run_decoded(&mut self, dec: &DecodedProgram, max_insts: u64) -> Result<RunStats, Trap> {
        self.run_decoded_with(dec, max_insts, |_| {})
    }

    /// Decode `prog` and run it (convenience wrapper; callers on the
    /// hot path pre-decode with [`DecodedProgram::decode`] and use
    /// [`Executor::run_decoded_with`] to share the decode).
    pub fn run_with(
        &mut self,
        prog: &Program,
        max_insts: u64,
        on_retire: impl FnMut(StepInfo<'_>),
    ) -> Result<RunStats, Trap> {
        let dec = DecodedProgram::decode(prog);
        self.run_decoded_with(&dec, max_insts, on_retire)
    }

    /// Decode and run without a timing consumer.
    pub fn run(&mut self, prog: &Program, max_insts: u64) -> Result<RunStats, Trap> {
        self.run_with(prog, max_insts, |_| {})
    }

    // ---- shared helpers ----

    #[inline]
    pub(crate) fn record_load(&mut self, addr: u64, len: u32) {
        self.accesses.push(MemAccess { addr, len, is_store: false });
    }

    #[inline]
    pub(crate) fn record_store(&mut self, addr: u64, len: u32) {
        self.accesses.push(MemAccess { addr, len, is_store: true });
    }

    /// Contiguous read through the TLB: one translation per page
    /// touched, `copy_from_slice` within each page. Copies until the
    /// first unmapped byte; returns bytes copied plus the fault, if any
    /// (the fault address is the exact first unmapped byte, matching the
    /// per-byte path's reporting).
    pub(crate) fn read_contig_partial(
        &mut self,
        addr: u64,
        out: &mut [u8],
    ) -> (usize, Option<MemFault>) {
        let mut done = 0usize;
        while done < out.len() {
            let a = addr + done as u64;
            let off = (a as usize) & (PAGE_SIZE - 1);
            let chunk = (PAGE_SIZE - off).min(out.len() - done);
            match self.tlb.lookup(&self.mem, a) {
                Some(slot) => {
                    out[done..done + chunk]
                        .copy_from_slice(&self.mem.slot_frame(slot)[off..off + chunk]);
                    done += chunk;
                }
                None => return (done, Some(MemFault { addr: a, is_store: false })),
            }
        }
        (done, None)
    }

    /// All-or-fault contiguous read through the TLB.
    pub(crate) fn read_contig(&mut self, addr: u64, out: &mut [u8]) -> Result<(), MemFault> {
        match self.read_contig_partial(addr, out) {
            (_, Some(fault)) => Err(fault),
            _ => Ok(()),
        }
    }

    /// Contiguous write through the TLB (one translation per page).
    /// Pages before the first unmapped byte stay written on fault, the
    /// same observable behaviour as the per-element path (a fault aborts
    /// the whole run).
    pub(crate) fn write_contig(&mut self, addr: u64, src: &[u8]) -> Result<(), MemFault> {
        let mut done = 0usize;
        while done < src.len() {
            let a = addr + done as u64;
            let off = (a as usize) & (PAGE_SIZE - 1);
            let chunk = (PAGE_SIZE - off).min(src.len() - done);
            let slot = self
                .tlb
                .lookup(&self.mem, a)
                .ok_or(MemFault { addr: a, is_store: true })?;
            self.mem.slot_frame_mut(slot)[off..off + chunk]
                .copy_from_slice(&src[done..done + chunk]);
            done += chunk;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;

    #[test]
    fn run_halts_and_counts() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 3 });
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 4 });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(256, Memory::new());
        let stats = ex.run(&p, 100).unwrap();
        assert_eq!(stats.insts, 3);
        assert_eq!(ex.state.get_x(0), 7);
    }

    #[test]
    fn budget_guard_trips_on_infinite_loop() {
        let mut a = Asm::new();
        a.label("x");
        a.push_branch(Inst::B { target: 0 }, "x");
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        assert_eq!(ex.run(&p, 50), Err(Trap::Budget));
    }

    #[test]
    fn budget_guard_is_exact_mid_block() {
        // the budget is metered per straight-line run, but trip counts
        // must stay exact at every cutoff inside a block
        let mut a = Asm::new();
        a.label("top");
        a.push(Inst::MovImm { xd: 0, imm: 1 });
        a.push(Inst::AddImm { xd: 1, xn: 1, imm: 1 });
        a.push(Inst::Nop);
        a.push_branch(Inst::B { target: 0 }, "top");
        let p = a.finish();
        let dec = DecodedProgram::decode(&p);
        for budget in 0..10u64 {
            let mut ex = Executor::new(128, Memory::new());
            let mut retired = 0u64;
            let r = ex.run_decoded_with(&dec, budget, |_| retired += 1);
            assert_eq!(r, Err(Trap::Budget), "budget {budget}");
            assert_eq!(retired, budget, "budget {budget}");
            assert_eq!(ex.state.get_x(1), (budget + 2) / 4, "adds completed at budget {budget}");
        }
    }

    #[test]
    fn vector_fraction_metric() {
        let s = RunStats {
            insts: 10,
            sve_insts: 4,
            neon_insts: 0,
            vector_insts: 5,
            ..Default::default()
        };
        assert!((s.vector_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(RunStats::default().vector_fraction(), 0.0);
    }

    #[test]
    fn step_info_carries_decoded_metadata() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 1 });
        a.push(Inst::Setffr);
        a.push(Inst::NeonMoviZero { vd: 0 });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        ex.run_with(&p, 100, |info| {
            assert_eq!(info.uop.class, info.inst.class(), "pc {}", info.pc);
            assert_eq!(info.uop.is_sve(), info.inst.is_sve(), "pc {}", info.pc);
        })
        .unwrap();
    }

    #[test]
    fn step_executes_one_uop_at_a_time() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 5 });
        a.push_branch(Inst::B { target: 0 }, "end");
        a.push(Inst::Nop);
        a.label("end");
        a.push(Inst::Halt);
        let p = a.finish();
        let dec = DecodedProgram::decode(&p);
        let mut ex = Executor::new(128, Memory::new());
        assert!(!ex.step(&dec).unwrap(), "mov is not a taken branch");
        assert_eq!(ex.state.get_x(0), 5);
        assert!(ex.step(&dec).unwrap(), "unconditional branch is taken");
        assert_eq!(ex.state.pc, 3);
    }

    #[test]
    fn contig_helpers_roundtrip_and_fault() {
        let mut mem = Memory::new();
        mem.map(0x1000, 2 * PAGE_SIZE as u64); // third page unmapped
        let mut ex = Executor::new(128, mem);
        let base = 0x1000 + PAGE_SIZE as u64 - 8; // straddles a boundary
        let src: Vec<u8> = (0..64u8).collect();
        ex.write_contig(base, &src).unwrap();
        let mut out = [0u8; 64];
        ex.read_contig(base, &mut out).unwrap();
        assert_eq!(&out[..], &src[..]);
        // partial read up to the hole after page 2
        let tail = 0x1000 + 2 * PAGE_SIZE as u64 - 4;
        let mut buf = [0u8; 16];
        let (copied, fault) = ex.read_contig_partial(tail, &mut buf);
        assert_eq!(copied, 4);
        assert_eq!(fault, Some(MemFault { addr: 0x3000, is_store: false }));
    }
}
