//! Advanced SIMD (NEON) semantics: fixed 128-bit operations on the low
//! 16 bytes of the vector file, as µop handlers over the decoded form.
//! Every NEON write zeroes the extended bits (§4 — "avoiding partial
//! updates"). The memory bodies are shared with the `cfg(test)` legacy
//! interpreter.

use super::{ExecResult, Executor};
use crate::arch::Esize;
use crate::exec::scalar::{fp_bin, fp_bin32, fp_un, fp_un32};
use crate::isa::uop::{Uop, F_DBL, F_SUB};
use crate::isa::{CmpOp, IntOp, MemOff};

pub(crate) const NEON_BYTES: usize = 16;

impl Executor {
    #[inline]
    pub(crate) fn neon_ea(&self, base: u8, off: MemOff) -> u64 {
        let b = self.state.get_x(base);
        match off {
            MemOff::Imm(i) => b.wrapping_add(i as u64),
            MemOff::RegLsl(xm, sh) => b.wrapping_add(self.state.get_x(xm) << sh),
        }
    }

    /// 128-bit contiguous load at `addr` into `vt` (high bits zeroed).
    pub(crate) fn neon_ld1_at(&mut self, addr: u64, vt: u8) -> ExecResult {
        // bulk path: one TLB translation per page touched
        let mut bytes = [0u8; NEON_BYTES];
        self.read_contig(addr, &mut bytes)?;
        self.record_load(addr, NEON_BYTES as u32);
        let r = &mut self.state.z[vt as usize];
        r.bytes[..NEON_BYTES].copy_from_slice(&bytes);
        r.zero_from(NEON_BYTES);
        Ok(())
    }

    /// 128-bit contiguous store of `vt` at `addr`.
    pub(crate) fn neon_st1_at(&mut self, addr: u64, vt: u8) -> ExecResult {
        let bytes: [u8; NEON_BYTES] =
            self.state.z[vt as usize].bytes[..NEON_BYTES].try_into().unwrap();
        self.write_contig(addr, &bytes)?;
        self.record_store(addr, NEON_BYTES as u32);
        Ok(())
    }
}

// ---- µop handlers (tag-indexed; see exec::DISPATCH) ----

pub(crate) fn h_neon_ld1_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.neon_ea(u.b, MemOff::Imm(u.imm));
    ex.neon_ld1_at(addr, u.a)
}

pub(crate) fn h_neon_ld1_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.neon_ea(u.b, MemOff::RegLsl(u.c, u.imm2 as u8));
    ex.neon_ld1_at(addr, u.a)
}

pub(crate) fn h_neon_st1_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.neon_ea(u.b, MemOff::Imm(u.imm));
    ex.neon_st1_at(addr, u.a)
}

pub(crate) fn h_neon_st1_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.neon_ea(u.b, MemOff::RegLsl(u.c, u.imm2 as u8));
    ex.neon_st1_at(addr, u.a)
}

pub(crate) fn h_neon_dup_x(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b);
    let r = &mut ex.state.z[u.a as usize];
    for i in 0..u.esize.lanes(NEON_BYTES) {
        r.set(u.esize, i, v);
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_dup_lane0(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.z[u.b as usize].get(u.esize, 0);
    let r = &mut ex.state.z[u.a as usize];
    for i in 0..u.esize.lanes(NEON_BYTES) {
        r.set(u.esize, i, v);
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_movi_zero(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.state.z[u.a as usize].zero();
    Ok(())
}

pub(crate) fn h_neon_fp_bin(ex: &mut Executor, u: &Uop) -> ExecResult {
    let op = u.sub.fp();
    let (zn, zm) = (ex.state.z[u.b as usize], ex.state.z[u.c as usize]);
    let r = &mut ex.state.z[u.a as usize];
    if u.has(F_DBL) {
        for i in 0..2 {
            r.set_f64(i, fp_bin(op, zn.get_f64(i), zm.get_f64(i)));
        }
    } else {
        for i in 0..4 {
            r.set_f32(i, fp_bin32(op, zn.get_f32(i), zm.get_f32(i)));
        }
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_fp_un(ex: &mut Executor, u: &Uop) -> ExecResult {
    let op = u.sub.fp_un();
    let zn = ex.state.z[u.b as usize];
    let r = &mut ex.state.z[u.a as usize];
    if u.has(F_DBL) {
        for i in 0..2 {
            r.set_f64(i, fp_un(op, zn.get_f64(i)));
        }
    } else {
        for i in 0..4 {
            r.set_f32(i, fp_un32(op, zn.get_f32(i)));
        }
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_fmla(ex: &mut Executor, u: &Uop) -> ExecResult {
    let sub = u.has(F_SUB);
    let (zn, zm) = (ex.state.z[u.b as usize], ex.state.z[u.c as usize]);
    let r = &mut ex.state.z[u.a as usize];
    if u.has(F_DBL) {
        for i in 0..2 {
            let p = zn.get_f64(i) * zm.get_f64(i);
            let p = if sub { -p } else { p };
            r.set_f64(i, r.get_f64(i) + p);
        }
    } else {
        for i in 0..4 {
            let p = zn.get_f32(i) * zm.get_f32(i);
            let p = if sub { -p } else { p };
            r.set_f32(i, r.get_f32(i) + p);
        }
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_int_bin(ex: &mut Executor, u: &Uop) -> ExecResult {
    let op = u.sub.int();
    let (zn, zm) = (ex.state.z[u.b as usize], ex.state.z[u.c as usize]);
    let r = &mut ex.state.z[u.a as usize];
    for i in 0..u.esize.lanes(NEON_BYTES) {
        let v = int_bin(op, u.esize, zn.get(u.esize, i), zm.get(u.esize, i));
        r.set(u.esize, i, v);
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_fcm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let op = u.sub.cmp();
    let (zn, zm) = (ex.state.z[u.b as usize], ex.state.z[u.c as usize]);
    let r = &mut ex.state.z[u.a as usize];
    if u.has(F_DBL) {
        for i in 0..2 {
            let t = fcmp(op, zn.get_f64(i), zm.get_f64(i));
            r.set(Esize::D, i, if t { u64::MAX } else { 0 });
        }
    } else {
        for i in 0..4 {
            let t = fcmp(op, zn.get_f32(i) as f64, zm.get_f32(i) as f64);
            r.set(Esize::S, i, if t { 0xFFFF_FFFF } else { 0 });
        }
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_cm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let op = u.sub.cmp();
    let esize = u.esize;
    let (zn, zm) = (ex.state.z[u.b as usize], ex.state.z[u.c as usize]);
    let r = &mut ex.state.z[u.a as usize];
    let ones = if esize.bytes() == 8 { u64::MAX } else { (1u64 << (esize.bytes() * 8)) - 1 };
    for i in 0..esize.lanes(NEON_BYTES) {
        let t = icmp_signed(op, zn.get_signed(esize, i), zm.get_signed(esize, i));
        r.set(esize, i, if t { ones } else { 0 });
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_bsl(ex: &mut Executor, u: &Uop) -> ExecResult {
    let (zn, zm) = (ex.state.z[u.b as usize], ex.state.z[u.c as usize]);
    let r = &mut ex.state.z[u.a as usize];
    for k in 0..NEON_BYTES {
        r.bytes[k] = (r.bytes[k] & zn.bytes[k]) | (!r.bytes[k] & zm.bytes[k]);
    }
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn h_neon_faddv(ex: &mut Executor, u: &Uop) -> ExecResult {
    let zn = ex.state.z[u.b as usize];
    if u.has(F_DBL) {
        // 2 lanes: single pairwise add
        let v = zn.get_f64(0) + zn.get_f64(1);
        ex.state.set_d(u.a, v);
    } else {
        // 4 lanes: faddp tree
        let (a, b) = (zn.get_f32(0) + zn.get_f32(1), zn.get_f32(2) + zn.get_f32(3));
        ex.state.set_s(u.a, a + b);
    }
    Ok(())
}

pub(crate) fn h_neon_addv(ex: &mut Executor, u: &Uop) -> ExecResult {
    let zn = ex.state.z[u.b as usize];
    let mut acc = 0u64;
    for i in 0..u.esize.lanes(NEON_BYTES) {
        acc = acc.wrapping_add(zn.get(u.esize, i));
    }
    let r = &mut ex.state.z[u.a as usize];
    r.zero();
    r.set(u.esize, 0, acc);
    Ok(())
}

pub(crate) fn h_neon_umov(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.z[u.b as usize].get(u.esize, u.imm as usize);
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_neon_ins_x(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b);
    let r = &mut ex.state.z[u.a as usize];
    r.set(u.esize, u.imm as usize, v);
    r.zero_from(NEON_BYTES);
    Ok(())
}

pub(crate) fn int_bin(op: IntOp, esize: Esize, a: u64, b: u64) -> u64 {
    let bits = esize.bytes() * 8;
    let sign = |v: u64| -> i64 {
        if bits == 64 {
            v as i64
        } else {
            ((v << (64 - bits)) as i64) >> (64 - bits)
        }
    };
    match op {
        IntOp::Add => a.wrapping_add(b),
        IntOp::Sub => a.wrapping_sub(b),
        IntOp::Mul => a.wrapping_mul(b),
        IntOp::SMax => sign(a).max(sign(b)) as u64,
        IntOp::SMin => sign(a).min(sign(b)) as u64,
        IntOp::UMax => a.max(b),
        IntOp::UMin => a.min(b),
        IntOp::And => a & b,
        IntOp::Orr => a | b,
        IntOp::Eor => a ^ b,
        IntOp::Lsl => {
            if b >= bits as u64 {
                0
            } else {
                a << b
            }
        }
        IntOp::Lsr => {
            let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
            if b >= bits as u64 {
                0
            } else {
                (a & mask) >> b
            }
        }
        IntOp::Asr => {
            let sh = b.min(bits as u64 - 1);
            (sign(a) >> sh) as u64
        }
    }
}

pub(crate) fn fcmp(op: CmpOp, a: f64, b: f64) -> bool {
    match op {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
    }
}

pub(crate) fn icmp_signed(op: CmpOp, a: i64, b: i64) -> bool {
    match op {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
    }
}

pub(crate) fn icmp_unsigned(op: CmpOp, a: u64, b: u64) -> bool {
    match op {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::Inst;
    use crate::mem::Memory;

    fn run(mem: Memory, build: impl FnOnce(&mut Asm)) -> Executor {
        let mut a = Asm::new();
        build(&mut a);
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(512, mem); // wide SVE reg to check zeroing
        ex.run(&p, 100_000).unwrap();
        ex
    }

    #[test]
    fn ld1_fmla_st1_roundtrip() {
        let mut mem = Memory::new();
        let xb = mem.alloc(32, 16);
        let yb = mem.alloc(32, 16);
        mem.write_f64_slice(xb, &[1.0, 2.0]);
        mem.write_f64_slice(yb, &[10.0, 20.0]);
        let ex = run(mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: xb });
            a.push(Inst::MovImm { xd: 1, imm: yb });
            a.push(Inst::MovImm { xd: 2, imm: 3f64.to_bits() });
            a.push(Inst::FmovXtoD { dd: 0, xn: 2 });
            a.push(Inst::NeonDupLane0 { esize: Esize::D, vd: 0, vn: 0 });
            a.push(Inst::NeonLd1 { esize: Esize::D, vt: 1, base: 0, off: MemOff::Imm(0) });
            a.push(Inst::NeonLd1 { esize: Esize::D, vt: 2, base: 1, off: MemOff::Imm(0) });
            a.push(Inst::NeonFmla { dbl: true, vd: 2, vn: 1, vm: 0, sub: false });
            a.push(Inst::NeonSt1 { esize: Esize::D, vt: 2, base: 1, off: MemOff::Imm(0) });
        });
        assert_eq!(ex.mem.read_f64(yb).unwrap(), 13.0);
        assert_eq!(ex.mem.read_f64(yb + 8).unwrap(), 26.0);
    }

    #[test]
    fn neon_writes_zero_high_sve_bits() {
        let mut mem = Memory::new();
        let b = mem.alloc(16, 16);
        let ex = run(mem, |a| {
            // dirty the full z1 via SVE dup, then overwrite low 128 via NEON
            a.push(Inst::DupImm { zd: 1, esize: Esize::D, imm: -1 });
            a.push(Inst::MovImm { xd: 0, imm: b });
            a.push(Inst::NeonLd1 { esize: Esize::D, vt: 1, base: 0, off: MemOff::Imm(0) });
        });
        assert!(ex.state.z[1].bytes[16..].iter().all(|&x| x == 0), "§4 zeroing");
    }

    #[test]
    fn bsl_selects_bitwise() {
        let ex = run(Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 1, imm: 0xFF00_FF00_FF00_FF00 });
            a.push(Inst::NeonDupX { esize: Esize::D, vd: 0, xn: 1 }); // mask
            a.push(Inst::MovImm { xd: 2, imm: 0x1111_1111_1111_1111 });
            a.push(Inst::NeonDupX { esize: Esize::D, vd: 1, xn: 2 });
            a.push(Inst::MovImm { xd: 3, imm: 0x2222_2222_2222_2222 });
            a.push(Inst::NeonDupX { esize: Esize::D, vd: 2, xn: 3 });
            a.push(Inst::NeonBsl { vd: 0, vn: 1, vm: 2 });
        });
        assert_eq!(ex.state.z[0].get(Esize::D, 0), 0x1122_1122_1122_1122);
    }

    #[test]
    fn fcm_produces_lane_masks() {
        let ex = run(Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 1, imm: 4f64.to_bits() });
            a.push(Inst::FmovXtoD { dd: 0, xn: 1 });
            a.push(Inst::NeonDupLane0 { esize: Esize::D, vd: 1, vn: 0 }); // [4,4]
            a.push(Inst::MovImm { xd: 2, imm: 2f64.to_bits() });
            a.push(Inst::FmovXtoD { dd: 2, xn: 2 });
            a.push(Inst::NeonDupLane0 { esize: Esize::D, vd: 2, vn: 2 }); // [2,2]
            a.push(Inst::NeonFcm { op: CmpOp::Gt, dbl: true, vd: 3, vn: 1, vm: 2 });
        });
        assert_eq!(ex.state.z[3].get(Esize::D, 0), u64::MAX);
        assert_eq!(ex.state.z[3].get(Esize::D, 1), u64::MAX);
    }

    #[test]
    fn faddv_trees() {
        let mut mem = Memory::new();
        let b = mem.alloc(16, 16);
        mem.write_f32_slice(b, &[1.0, 2.0, 3.0, 4.0]);
        let ex = run(mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: b });
            a.push(Inst::NeonLd1 { esize: Esize::S, vt: 0, base: 0, off: MemOff::Imm(0) });
            a.push(Inst::NeonFaddv { dbl: false, dd: 1, vn: 0 });
        });
        assert_eq!(ex.state.get_s(1), 10.0);
    }

    #[test]
    fn int_bin_shift_saturation() {
        assert_eq!(int_bin(IntOp::Lsl, Esize::S, 1, 40), 0, "shift >= width -> 0");
        assert_eq!(int_bin(IntOp::Asr, Esize::B, 0x80, 10), 0xFFFF_FFFF_FFFF_FFFF);
        assert_eq!(int_bin(IntOp::SMax, Esize::B, 0x80, 1), 1, "-128 vs 1");
    }
}
