//! AArch64 scalar (integer + FP) semantics.

use super::Executor;
use crate::arch::Flags;
use crate::isa::{FpOp, FpUnOp, Inst, MemOff, OpaqueFn, PLogicOp};
use crate::mem::MemFault;

impl Executor {
    pub(crate) fn exec_scalar(&mut self, inst: &Inst) -> Result<(), MemFault> {
        use Inst::*;
        let s = &mut self.state;
        match *inst {
            MovImm { xd, imm } => s.set_x(xd, imm),
            MovReg { xd, xn } => {
                let v = s.get_x(xn);
                s.set_x(xd, v)
            }
            AddImm { xd, xn, imm } => {
                let v = s.get_x(xn).wrapping_add(imm as u64);
                s.set_x(xd, v)
            }
            AddReg { xd, xn, xm, lsl } => {
                let v = s.get_x(xn).wrapping_add(s.get_x(xm) << lsl);
                s.set_x(xd, v)
            }
            SubReg { xd, xn, xm } => {
                let v = s.get_x(xn).wrapping_sub(s.get_x(xm));
                s.set_x(xd, v)
            }
            Madd { xd, xn, xm, xa } => {
                let v = s.get_x(xa).wrapping_add(s.get_x(xn).wrapping_mul(s.get_x(xm)));
                s.set_x(xd, v)
            }
            Udiv { xd, xn, xm } => {
                let d = s.get_x(xm);
                let v = if d == 0 { 0 } else { s.get_x(xn) / d }; // A64: div by 0 = 0
                s.set_x(xd, v)
            }
            AndImm { xd, xn, imm } => {
                let v = s.get_x(xn) & imm;
                s.set_x(xd, v)
            }
            LogReg { op, xd, xn, xm } => {
                let (a, b) = (s.get_x(xn), s.get_x(xm));
                let v = match op {
                    PLogicOp::And => a & b,
                    PLogicOp::Orr => a | b,
                    PLogicOp::Eor => a ^ b,
                    PLogicOp::Bic => a & !b,
                };
                s.set_x(xd, v)
            }
            LslImm { xd, xn, sh } => {
                let v = s.get_x(xn) << sh;
                s.set_x(xd, v)
            }
            LsrImm { xd, xn, sh } => {
                let v = s.get_x(xn) >> sh;
                s.set_x(xd, v)
            }
            AsrImm { xd, xn, sh } => {
                let v = (s.get_x(xn) as i64) >> sh;
                s.set_x(xd, v as u64)
            }
            Csel { xd, xn, xm, cond } => {
                let v = if s.flags.cond(cond) { s.get_x(xn) } else { s.get_x(xm) };
                s.set_x(xd, v)
            }
            Ldr { size, signed, xt, base, off } => {
                let addr = self.ea(base, off);
                let raw = self.mem.read(addr, size as usize)?;
                self.record_load(addr, size as u32);
                let v = if signed {
                    let bits = size as u32 * 8;
                    if bits == 64 {
                        raw
                    } else {
                        (((raw << (64 - bits)) as i64) >> (64 - bits)) as u64
                    }
                } else {
                    raw
                };
                self.state.set_x(xt, v);
            }
            Str { size, xt, base, off } => {
                let addr = self.ea(base, off);
                let v = self.state.get_x(xt);
                self.mem.write(addr, size as usize, v)?;
                self.record_store(addr, size as u32);
            }
            LdrFp { dbl, vt, base, off } => {
                let addr = self.ea(base, off);
                let size = if dbl { 8 } else { 4 };
                let raw = self.mem.read(addr, size)?;
                self.record_load(addr, size as u32);
                if dbl {
                    self.state.set_d(vt, f64::from_bits(raw));
                } else {
                    self.state.set_s(vt, f32::from_bits(raw as u32));
                }
            }
            StrFp { dbl, vt, base, off } => {
                let addr = self.ea(base, off);
                if dbl {
                    self.mem.write(addr, 8, self.state.get_d(vt).to_bits())?;
                    self.record_store(addr, 8);
                } else {
                    self.mem.write(addr, 4, self.state.get_s(vt).to_bits() as u64)?;
                    self.record_store(addr, 4);
                }
            }
            CmpImm { xn, imm } => s.flags = Flags::from_sub(s.get_x(xn), imm),
            CmpReg { xn, xm } => s.flags = Flags::from_sub(s.get_x(xn), s.get_x(xm)),
            B { target } => self.next_pc = Some(target),
            BCond { cond, target } => {
                if s.flags.cond(cond) {
                    self.next_pc = Some(target);
                }
            }
            Cbz { xn, target } => {
                if s.get_x(xn) == 0 {
                    self.next_pc = Some(target);
                }
            }
            Cbnz { xn, target } => {
                if s.get_x(xn) != 0 {
                    self.next_pc = Some(target);
                }
            }
            Ret | Halt => self.halted = true,
            Nop => {}
            FmovImm { dbl, dd, bits } => {
                if dbl {
                    s.set_d(dd, f64::from_bits(bits));
                } else {
                    s.set_s(dd, f32::from_bits(bits as u32));
                }
            }
            FmovXtoD { dd, xn } => {
                let v = s.get_x(xn);
                s.set_d(dd, f64::from_bits(v));
            }
            FmovReg { dbl, dd, dn } => {
                if dbl {
                    let v = s.get_d(dn);
                    s.set_d(dd, v);
                } else {
                    let v = s.get_s(dn);
                    s.set_s(dd, v);
                }
            }
            FmovDtoX { xd, dn } => {
                let v = s.get_d(dn).to_bits();
                s.set_x(xd, v);
            }
            FpBin { op, dbl, dd, dn, dm } => {
                if dbl {
                    let (a, b) = (s.get_d(dn), s.get_d(dm));
                    s.set_d(dd, fp_bin(op, a, b));
                } else {
                    let (a, b) = (s.get_s(dn), s.get_s(dm));
                    s.set_s(dd, fp_bin32(op, a, b));
                }
            }
            FpUn { op, dbl, dd, dn } => {
                if dbl {
                    let a = s.get_d(dn);
                    s.set_d(dd, fp_un(op, a));
                } else {
                    let a = s.get_s(dn);
                    s.set_s(dd, fp_un32(op, a));
                }
            }
            Fmadd { dbl, dd, dn, dm, da, sub } => {
                if dbl {
                    let (n, m, a) = (s.get_d(dn), s.get_d(dm), s.get_d(da));
                    let prod = if sub { -(n * m) } else { n * m };
                    s.set_d(dd, a + prod);
                } else {
                    let (n, m, a) = (s.get_s(dn), s.get_s(dm), s.get_s(da));
                    let prod = if sub { -(n * m) } else { n * m };
                    s.set_s(dd, a + prod);
                }
            }
            Fcmp { dbl, dn, dm } => {
                let (a, b) = if dbl {
                    (s.get_d(dn), s.get_d(dm))
                } else {
                    (s.get_s(dn) as f64, s.get_s(dm) as f64)
                };
                s.flags = Flags::from_fcmp(a, b);
            }
            Scvtf { dbl, dd, xn } => {
                let v = s.get_x(xn) as i64;
                if dbl {
                    s.set_d(dd, v as f64);
                } else {
                    s.set_s(dd, v as f32);
                }
            }
            Fcvtzs { dbl, xd, dn } => {
                let v = if dbl { s.get_d(dn) } else { s.get_s(dn) as f64 };
                s.set_x(xd, v.trunc() as i64 as u64);
            }
            OpaqueCall { f, dd, dn, dm } => {
                let a = s.get_d(dn);
                let b = dm.map(|m| s.get_d(m));
                let v = match f {
                    OpaqueFn::Exp => a.exp(),
                    OpaqueFn::Log => a.ln(),
                    OpaqueFn::Pow => a.powf(b.expect("pow needs 2 args")),
                    OpaqueFn::Sqrt => a.sqrt(),
                    OpaqueFn::Sin => a.sin(),
                };
                s.set_d(dd, v);
            }
            _ => unreachable!("non-scalar inst routed to exec_scalar: {inst:?}"),
        }
        Ok(())
    }

    /// Effective address of a scalar memory operand.
    #[inline]
    fn ea(&self, base: u8, off: MemOff) -> u64 {
        let b = self.state.get_x(base);
        match off {
            MemOff::Imm(i) => b.wrapping_add(i as u64),
            MemOff::RegLsl(xm, sh) => b.wrapping_add(self.state.get_x(xm) << sh),
        }
    }
}

pub(crate) fn fp_bin(op: FpOp, a: f64, b: f64) -> f64 {
    match op {
        FpOp::Add => a + b,
        FpOp::Sub => a - b,
        FpOp::Mul => a * b,
        FpOp::Div => a / b,
        FpOp::Max => a.max(b),
        FpOp::Min => a.min(b),
    }
}

pub(crate) fn fp_bin32(op: FpOp, a: f32, b: f32) -> f32 {
    match op {
        FpOp::Add => a + b,
        FpOp::Sub => a - b,
        FpOp::Mul => a * b,
        FpOp::Div => a / b,
        FpOp::Max => a.max(b),
        FpOp::Min => a.min(b),
    }
}

pub(crate) fn fp_un(op: FpUnOp, a: f64) -> f64 {
    match op {
        FpUnOp::Sqrt => a.sqrt(),
        FpUnOp::Neg => -a,
        FpUnOp::Abs => a.abs(),
        FpUnOp::Recpe => 1.0 / a,
    }
}

pub(crate) fn fp_un32(op: FpUnOp, a: f32) -> f32 {
    match op {
        FpUnOp::Sqrt => a.sqrt(),
        FpUnOp::Neg => -a,
        FpUnOp::Abs => a.abs(),
        FpUnOp::Recpe => 1.0 / a,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Cond;
    use crate::asm::Asm;
    use crate::exec::Trap;
    use crate::mem::Memory;

    fn run_prog(build: impl FnOnce(&mut Asm)) -> Executor {
        let mut a = Asm::new();
        build(&mut a);
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(256, Memory::new());
        ex.run(&p, 1_000_000).unwrap();
        ex
    }

    #[test]
    fn fig2b_scalar_daxpy() {
        // the paper's scalar daxpy (Fig. 2b), transliterated
        let n = 17usize;
        let mut mem = Memory::new();
        let x = mem.alloc(8 * n as u64, 8);
        let y = mem.alloc(8 * n as u64, 8);
        let a_addr = mem.alloc(8, 8);
        let n_addr = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(x + 8 * i as u64, i as f64).unwrap();
            mem.write_f64(y + 8 * i as u64, 100.0 + i as f64).unwrap();
        }
        mem.write_f64(a_addr, 3.0).unwrap();
        mem.write_u32(n_addr, n as u32).unwrap();

        let mut asm = Asm::new();
        let a = &mut asm;
        // x0=&x, x1=&y, x2=&a, x3=&n
        a.push(Inst::MovImm { xd: 0, imm: x });
        a.push(Inst::MovImm { xd: 1, imm: y });
        a.push(Inst::MovImm { xd: 2, imm: a_addr });
        a.push(Inst::MovImm { xd: 3, imm: n_addr });
        a.push(Inst::Ldr { size: 4, signed: true, xt: 3, base: 3, off: MemOff::Imm(0) });
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::LdrFp { dbl: true, vt: 0, base: 2, off: MemOff::Imm(0) });
        a.push_branch(Inst::B { target: 0 }, "latch");
        a.label("loop");
        a.push(Inst::LdrFp { dbl: true, vt: 1, base: 0, off: MemOff::RegLsl(4, 3) });
        a.push(Inst::LdrFp { dbl: true, vt: 2, base: 1, off: MemOff::RegLsl(4, 3) });
        a.push(Inst::Fmadd { dbl: true, dd: 2, dn: 1, dm: 0, da: 2, sub: false });
        a.push(Inst::StrFp { dbl: true, vt: 2, base: 1, off: MemOff::RegLsl(4, 3) });
        a.push(Inst::AddImm { xd: 4, xn: 4, imm: 1 });
        a.label("latch");
        a.push(Inst::CmpReg { xn: 4, xm: 3 });
        a.push_branch(Inst::BCond { cond: Cond::Lt, target: 0 }, "loop");
        a.push(Inst::Halt);
        let p = asm.finish();

        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        for i in 0..n {
            let want = 3.0 * i as f64 + (100.0 + i as f64);
            assert_eq!(ex.mem.read_f64(y + 8 * i as u64).unwrap(), want, "y[{i}]");
        }
    }

    #[test]
    fn arithmetic_and_logic() {
        let ex = run_prog(|a| {
            a.push(Inst::MovImm { xd: 1, imm: 12 });
            a.push(Inst::MovImm { xd: 2, imm: 5 });
            a.push(Inst::Madd { xd: 3, xn: 1, xm: 2, xa: 31 }); // 60
            a.push(Inst::SubReg { xd: 4, xn: 3, xm: 2 }); // 55
            a.push(Inst::Udiv { xd: 5, xn: 3, xm: 2 }); // 12
            a.push(Inst::LogReg { op: PLogicOp::Eor, xd: 6, xn: 1, xm: 2 }); // 9
            a.push(Inst::LslImm { xd: 7, xn: 2, sh: 3 }); // 40
            a.push(Inst::AsrImm { xd: 8, xn: 7, sh: 2 }); // 10
        });
        assert_eq!(ex.state.get_x(3), 60);
        assert_eq!(ex.state.get_x(4), 55);
        assert_eq!(ex.state.get_x(5), 12);
        assert_eq!(ex.state.get_x(6), 9);
        assert_eq!(ex.state.get_x(7), 40);
        assert_eq!(ex.state.get_x(8), 10);
    }

    #[test]
    fn udiv_by_zero_gives_zero() {
        let ex = run_prog(|a| {
            a.push(Inst::MovImm { xd: 1, imm: 42 });
            a.push(Inst::MovImm { xd: 2, imm: 0 });
            a.push(Inst::Udiv { xd: 3, xn: 1, xm: 2 });
        });
        assert_eq!(ex.state.get_x(3), 0);
    }

    #[test]
    fn signed_byte_load() {
        let mut mem = Memory::new();
        let buf = mem.alloc(16, 8);
        mem.write_byte(buf, 0x80).unwrap();
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: buf });
        a.push(Inst::Ldr { size: 1, signed: true, xt: 1, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::Ldr { size: 1, signed: false, xt: 2, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 100).unwrap();
        assert_eq!(ex.state.get_x(1) as i64, -128);
        assert_eq!(ex.state.get_x(2), 0x80);
    }

    #[test]
    fn scalar_fault_traps_with_pc() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 0xdead_000 });
        a.push(Inst::Ldr { size: 8, signed: false, xt: 1, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        match ex.run(&p, 100) {
            Err(Trap::Fault { pc, fault }) => {
                assert_eq!(pc, 1);
                assert_eq!(fault.addr, 0xdead_000);
            }
            other => panic!("expected fault, got {other:?}"),
        }
    }

    #[test]
    fn csel_and_flags() {
        let ex = run_prog(|a| {
            a.push(Inst::MovImm { xd: 1, imm: 3 });
            a.push(Inst::MovImm { xd: 2, imm: 9 });
            a.push(Inst::CmpReg { xn: 1, xm: 2 });
            a.push(Inst::Csel { xd: 3, xn: 1, xm: 2, cond: Cond::Lt }); // 3 < 9 -> x1
            a.push(Inst::Csel { xd: 4, xn: 1, xm: 2, cond: Cond::Ge }); // -> x2
        });
        assert_eq!(ex.state.get_x(3), 3);
        assert_eq!(ex.state.get_x(4), 9);
    }

    #[test]
    fn opaque_calls_compute_libm() {
        let ex = run_prog(|a| {
            a.push(Inst::FmovImm { dbl: true, dd: 0, bits: 2.0f64.to_bits() });
            a.push(Inst::FmovImm { dbl: true, dd: 1, bits: 10.0f64.to_bits() });
            a.push(Inst::OpaqueCall { f: OpaqueFn::Pow, dd: 2, dn: 0, dm: Some(1) });
            a.push(Inst::OpaqueCall { f: OpaqueFn::Log, dd: 3, dn: 1, dm: None });
        });
        assert_eq!(ex.state.get_d(2), 1024.0);
        assert!((ex.state.get_d(3) - 10.0f64.ln()).abs() < 1e-15);
    }

    #[test]
    fn fp32_path() {
        let ex = run_prog(|a| {
            a.push(Inst::FmovImm { dbl: false, dd: 0, bits: 1.5f32.to_bits() as u64 });
            a.push(Inst::FmovImm { dbl: false, dd: 1, bits: 2.0f32.to_bits() as u64 });
            a.push(Inst::FpBin { op: FpOp::Mul, dbl: false, dd: 2, dn: 0, dm: 1 });
            a.push(Inst::FpUn { op: FpUnOp::Sqrt, dbl: false, dd: 3, dn: 1 });
        });
        assert_eq!(ex.state.get_s(2), 3.0);
        assert_eq!(ex.state.get_s(3), 2.0f32.sqrt());
    }
}
