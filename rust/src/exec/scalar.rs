//! AArch64 scalar (integer + FP) semantics, as µop handlers over the
//! decoded form ([`crate::isa::uop`]). Operand fields arrive
//! pre-resolved in the [`Uop`]; the shared memory bodies
//! ([`Executor::ldr_at`] and friends) are also used by the `cfg(test)`
//! legacy interpreter so the two paths can be compared bit-for-bit.

use super::{ExecResult, Executor};
use crate::arch::Flags;
use crate::isa::uop::{Uop, F_DBL, F_OPT, F_SIGNED, F_SUB};
use crate::isa::{FpOp, FpUnOp, MemOff, OpaqueFn, PLogicOp};

impl Executor {
    /// Effective address of a scalar memory operand.
    #[inline]
    pub(crate) fn ea(&self, base: u8, off: MemOff) -> u64 {
        let b = self.state.get_x(base);
        match off {
            MemOff::Imm(i) => b.wrapping_add(i as u64),
            MemOff::RegLsl(xm, sh) => b.wrapping_add(self.state.get_x(xm) << sh),
        }
    }

    /// Scalar integer load at `addr` (`size` bytes, optionally
    /// sign-extending) into `xt`.
    pub(crate) fn ldr_at(&mut self, addr: u64, size: usize, signed: bool, xt: u8) -> ExecResult {
        let raw = self.mem.read(addr, size)?;
        self.record_load(addr, size as u32);
        let v = if signed {
            let bits = size as u32 * 8;
            if bits == 64 {
                raw
            } else {
                (((raw << (64 - bits)) as i64) >> (64 - bits)) as u64
            }
        } else {
            raw
        };
        self.state.set_x(xt, v);
        Ok(())
    }

    /// Scalar integer store of `xt` at `addr` (`size` bytes).
    pub(crate) fn str_at(&mut self, addr: u64, size: usize, xt: u8) -> ExecResult {
        let v = self.state.get_x(xt);
        self.mem.write(addr, size, v)?;
        self.record_store(addr, size as u32);
        Ok(())
    }

    /// Scalar FP load at `addr` into `vt` (d- or s-view).
    pub(crate) fn ldr_fp_at(&mut self, addr: u64, dbl: bool, vt: u8) -> ExecResult {
        let size = if dbl { 8 } else { 4 };
        let raw = self.mem.read(addr, size)?;
        self.record_load(addr, size as u32);
        if dbl {
            self.state.set_d(vt, f64::from_bits(raw));
        } else {
            self.state.set_s(vt, f32::from_bits(raw as u32));
        }
        Ok(())
    }

    /// Scalar FP store of `vt` at `addr`.
    pub(crate) fn str_fp_at(&mut self, addr: u64, dbl: bool, vt: u8) -> ExecResult {
        if dbl {
            self.mem.write(addr, 8, self.state.get_d(vt).to_bits())?;
            self.record_store(addr, 8);
        } else {
            self.mem.write(addr, 4, self.state.get_s(vt).to_bits() as u64)?;
            self.record_store(addr, 4);
        }
        Ok(())
    }
}

// ---- µop handlers (tag-indexed; see exec::DISPATCH) ----

pub(crate) fn h_mov_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.state.set_x(u.a, u.imm as u64);
    Ok(())
}

pub(crate) fn h_mov_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b);
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_add_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b).wrapping_add(u.imm as u64);
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_add_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b).wrapping_add(ex.state.get_x(u.c) << u.imm2);
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_sub_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b).wrapping_sub(ex.state.get_x(u.c));
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_madd(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let v = s.get_x(u.d).wrapping_add(s.get_x(u.b).wrapping_mul(s.get_x(u.c)));
    s.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_udiv(ex: &mut Executor, u: &Uop) -> ExecResult {
    let d = ex.state.get_x(u.c);
    let v = if d == 0 { 0 } else { ex.state.get_x(u.b) / d }; // A64: div by 0 = 0
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_and_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b) & u.imm as u64;
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_log_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let (a, b) = (ex.state.get_x(u.b), ex.state.get_x(u.c));
    let v = match u.sub.plogic() {
        PLogicOp::And => a & b,
        PLogicOp::Orr => a | b,
        PLogicOp::Eor => a ^ b,
        PLogicOp::Bic => a & !b,
    };
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_lsl_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b) << u.imm;
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_lsr_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b) >> u.imm;
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_asr_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = (ex.state.get_x(u.b) as i64) >> u.imm;
    ex.state.set_x(u.a, v as u64);
    Ok(())
}

pub(crate) fn h_csel(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let v = if s.flags.cond(u.sub.cond()) { s.get_x(u.b) } else { s.get_x(u.c) };
    s.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_ldr_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::Imm(u.imm));
    ex.ldr_at(addr, u.esize.bytes(), u.has(F_SIGNED), u.a)
}

pub(crate) fn h_ldr_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::RegLsl(u.c, u.imm2 as u8));
    ex.ldr_at(addr, u.esize.bytes(), u.has(F_SIGNED), u.a)
}

pub(crate) fn h_str_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::Imm(u.imm));
    ex.str_at(addr, u.esize.bytes(), u.a)
}

pub(crate) fn h_str_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::RegLsl(u.c, u.imm2 as u8));
    ex.str_at(addr, u.esize.bytes(), u.a)
}

pub(crate) fn h_ldr_fp_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::Imm(u.imm));
    ex.ldr_fp_at(addr, u.dbl(), u.a)
}

pub(crate) fn h_ldr_fp_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::RegLsl(u.c, u.imm2 as u8));
    ex.ldr_fp_at(addr, u.dbl(), u.a)
}

pub(crate) fn h_str_fp_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::Imm(u.imm));
    ex.str_fp_at(addr, u.dbl(), u.a)
}

pub(crate) fn h_str_fp_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = ex.ea(u.b, MemOff::RegLsl(u.c, u.imm2 as u8));
    ex.str_fp_at(addr, u.dbl(), u.a)
}

pub(crate) fn h_cmp_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.state.flags = Flags::from_sub(ex.state.get_x(u.b), u.imm as u64);
    Ok(())
}

pub(crate) fn h_cmp_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.state.flags = Flags::from_sub(ex.state.get_x(u.b), ex.state.get_x(u.c));
    Ok(())
}

pub(crate) fn h_b(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.next_pc = Some(u.imm as usize);
    Ok(())
}

pub(crate) fn h_b_cond(ex: &mut Executor, u: &Uop) -> ExecResult {
    if ex.state.flags.cond(u.sub.cond()) {
        ex.next_pc = Some(u.imm as usize);
    }
    Ok(())
}

pub(crate) fn h_cbz(ex: &mut Executor, u: &Uop) -> ExecResult {
    if ex.state.get_x(u.b) == 0 {
        ex.next_pc = Some(u.imm as usize);
    }
    Ok(())
}

pub(crate) fn h_cbnz(ex: &mut Executor, u: &Uop) -> ExecResult {
    if ex.state.get_x(u.b) != 0 {
        ex.next_pc = Some(u.imm as usize);
    }
    Ok(())
}

pub(crate) fn h_halt(ex: &mut Executor, _u: &Uop) -> ExecResult {
    ex.halted = true;
    Ok(())
}

pub(crate) fn h_nop(_ex: &mut Executor, _u: &Uop) -> ExecResult {
    Ok(())
}

pub(crate) fn h_fmov_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    if u.has(F_DBL) {
        ex.state.set_d(u.a, f64::from_bits(u.imm as u64));
    } else {
        ex.state.set_s(u.a, f32::from_bits(u.imm as u32));
    }
    Ok(())
}

pub(crate) fn h_fmov_x_to_d(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b);
    ex.state.set_d(u.a, f64::from_bits(v));
    Ok(())
}

pub(crate) fn h_fmov_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    if u.has(F_DBL) {
        let v = ex.state.get_d(u.b);
        ex.state.set_d(u.a, v);
    } else {
        let v = ex.state.get_s(u.b);
        ex.state.set_s(u.a, v);
    }
    Ok(())
}

pub(crate) fn h_fmov_d_to_x(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_d(u.b).to_bits();
    ex.state.set_x(u.a, v);
    Ok(())
}

pub(crate) fn h_fp_bin(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let op = u.sub.fp();
    if u.has(F_DBL) {
        let (a, b) = (s.get_d(u.b), s.get_d(u.c));
        s.set_d(u.a, fp_bin(op, a, b));
    } else {
        let (a, b) = (s.get_s(u.b), s.get_s(u.c));
        s.set_s(u.a, fp_bin32(op, a, b));
    }
    Ok(())
}

pub(crate) fn h_fp_un(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let op = u.sub.fp_un();
    if u.has(F_DBL) {
        let a = s.get_d(u.b);
        s.set_d(u.a, fp_un(op, a));
    } else {
        let a = s.get_s(u.b);
        s.set_s(u.a, fp_un32(op, a));
    }
    Ok(())
}

pub(crate) fn h_fmadd(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let sub = u.has(F_SUB);
    if u.has(F_DBL) {
        let (n, m, a) = (s.get_d(u.b), s.get_d(u.c), s.get_d(u.d));
        let prod = if sub { -(n * m) } else { n * m };
        s.set_d(u.a, a + prod);
    } else {
        let (n, m, a) = (s.get_s(u.b), s.get_s(u.c), s.get_s(u.d));
        let prod = if sub { -(n * m) } else { n * m };
        s.set_s(u.a, a + prod);
    }
    Ok(())
}

pub(crate) fn h_fcmp(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let (a, b) = if u.has(F_DBL) {
        (s.get_d(u.b), s.get_d(u.c))
    } else {
        (s.get_s(u.b) as f64, s.get_s(u.c) as f64)
    };
    s.flags = Flags::from_fcmp(a, b);
    Ok(())
}

pub(crate) fn h_scvtf(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = ex.state.get_x(u.b) as i64;
    if u.has(F_DBL) {
        ex.state.set_d(u.a, v as f64);
    } else {
        ex.state.set_s(u.a, v as f32);
    }
    Ok(())
}

pub(crate) fn h_fcvtzs(ex: &mut Executor, u: &Uop) -> ExecResult {
    let v = if u.has(F_DBL) { ex.state.get_d(u.b) } else { ex.state.get_s(u.b) as f64 };
    ex.state.set_x(u.a, v.trunc() as i64 as u64);
    Ok(())
}

pub(crate) fn h_opaque_call(ex: &mut Executor, u: &Uop) -> ExecResult {
    let s = &mut ex.state;
    let a = s.get_d(u.b);
    let b = if u.has(F_OPT) { Some(s.get_d(u.c)) } else { None };
    let v = match u.sub.opaque() {
        OpaqueFn::Exp => a.exp(),
        OpaqueFn::Log => a.ln(),
        OpaqueFn::Pow => a.powf(b.expect("pow needs 2 args")),
        OpaqueFn::Sqrt => a.sqrt(),
        OpaqueFn::Sin => a.sin(),
    };
    s.set_d(u.a, v);
    Ok(())
}

pub(crate) fn fp_bin(op: FpOp, a: f64, b: f64) -> f64 {
    match op {
        FpOp::Add => a + b,
        FpOp::Sub => a - b,
        FpOp::Mul => a * b,
        FpOp::Div => a / b,
        FpOp::Max => a.max(b),
        FpOp::Min => a.min(b),
    }
}

pub(crate) fn fp_bin32(op: FpOp, a: f32, b: f32) -> f32 {
    match op {
        FpOp::Add => a + b,
        FpOp::Sub => a - b,
        FpOp::Mul => a * b,
        FpOp::Div => a / b,
        FpOp::Max => a.max(b),
        FpOp::Min => a.min(b),
    }
}

pub(crate) fn fp_un(op: FpUnOp, a: f64) -> f64 {
    match op {
        FpUnOp::Sqrt => a.sqrt(),
        FpUnOp::Neg => -a,
        FpUnOp::Abs => a.abs(),
        FpUnOp::Recpe => 1.0 / a,
    }
}

pub(crate) fn fp_un32(op: FpUnOp, a: f32) -> f32 {
    match op {
        FpUnOp::Sqrt => a.sqrt(),
        FpUnOp::Neg => -a,
        FpUnOp::Abs => a.abs(),
        FpUnOp::Recpe => 1.0 / a,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Cond;
    use crate::asm::Asm;
    use crate::exec::Trap;
    use crate::isa::Inst;
    use crate::mem::Memory;

    fn run_prog(build: impl FnOnce(&mut Asm)) -> Executor {
        let mut a = Asm::new();
        build(&mut a);
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(256, Memory::new());
        ex.run(&p, 1_000_000).unwrap();
        ex
    }

    #[test]
    fn fig2b_scalar_daxpy() {
        // the paper's scalar daxpy (Fig. 2b), transliterated
        let n = 17usize;
        let mut mem = Memory::new();
        let x = mem.alloc(8 * n as u64, 8);
        let y = mem.alloc(8 * n as u64, 8);
        let a_addr = mem.alloc(8, 8);
        let n_addr = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(x + 8 * i as u64, i as f64).unwrap();
            mem.write_f64(y + 8 * i as u64, 100.0 + i as f64).unwrap();
        }
        mem.write_f64(a_addr, 3.0).unwrap();
        mem.write_u32(n_addr, n as u32).unwrap();

        let mut asm = Asm::new();
        let a = &mut asm;
        // x0=&x, x1=&y, x2=&a, x3=&n
        a.push(Inst::MovImm { xd: 0, imm: x });
        a.push(Inst::MovImm { xd: 1, imm: y });
        a.push(Inst::MovImm { xd: 2, imm: a_addr });
        a.push(Inst::MovImm { xd: 3, imm: n_addr });
        a.push(Inst::Ldr { size: 4, signed: true, xt: 3, base: 3, off: MemOff::Imm(0) });
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::LdrFp { dbl: true, vt: 0, base: 2, off: MemOff::Imm(0) });
        a.push_branch(Inst::B { target: 0 }, "latch");
        a.label("loop");
        a.push(Inst::LdrFp { dbl: true, vt: 1, base: 0, off: MemOff::RegLsl(4, 3) });
        a.push(Inst::LdrFp { dbl: true, vt: 2, base: 1, off: MemOff::RegLsl(4, 3) });
        a.push(Inst::Fmadd { dbl: true, dd: 2, dn: 1, dm: 0, da: 2, sub: false });
        a.push(Inst::StrFp { dbl: true, vt: 2, base: 1, off: MemOff::RegLsl(4, 3) });
        a.push(Inst::AddImm { xd: 4, xn: 4, imm: 1 });
        a.label("latch");
        a.push(Inst::CmpReg { xn: 4, xm: 3 });
        a.push_branch(Inst::BCond { cond: Cond::Lt, target: 0 }, "loop");
        a.push(Inst::Halt);
        let p = asm.finish();

        let mut ex = Executor::new(128, mem);
        ex.run(&p, 1_000_000).unwrap();
        for i in 0..n {
            let want = 3.0 * i as f64 + (100.0 + i as f64);
            assert_eq!(ex.mem.read_f64(y + 8 * i as u64).unwrap(), want, "y[{i}]");
        }
    }

    #[test]
    fn arithmetic_and_logic() {
        let ex = run_prog(|a| {
            a.push(Inst::MovImm { xd: 1, imm: 12 });
            a.push(Inst::MovImm { xd: 2, imm: 5 });
            a.push(Inst::Madd { xd: 3, xn: 1, xm: 2, xa: 31 }); // 60
            a.push(Inst::SubReg { xd: 4, xn: 3, xm: 2 }); // 55
            a.push(Inst::Udiv { xd: 5, xn: 3, xm: 2 }); // 12
            a.push(Inst::LogReg { op: PLogicOp::Eor, xd: 6, xn: 1, xm: 2 }); // 9
            a.push(Inst::LslImm { xd: 7, xn: 2, sh: 3 }); // 40
            a.push(Inst::AsrImm { xd: 8, xn: 7, sh: 2 }); // 10
        });
        assert_eq!(ex.state.get_x(3), 60);
        assert_eq!(ex.state.get_x(4), 55);
        assert_eq!(ex.state.get_x(5), 12);
        assert_eq!(ex.state.get_x(6), 9);
        assert_eq!(ex.state.get_x(7), 40);
        assert_eq!(ex.state.get_x(8), 10);
    }

    #[test]
    fn udiv_by_zero_gives_zero() {
        let ex = run_prog(|a| {
            a.push(Inst::MovImm { xd: 1, imm: 42 });
            a.push(Inst::MovImm { xd: 2, imm: 0 });
            a.push(Inst::Udiv { xd: 3, xn: 1, xm: 2 });
        });
        assert_eq!(ex.state.get_x(3), 0);
    }

    #[test]
    fn signed_byte_load() {
        let mut mem = Memory::new();
        let buf = mem.alloc(16, 8);
        mem.write_byte(buf, 0x80).unwrap();
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: buf });
        a.push(Inst::Ldr { size: 1, signed: true, xt: 1, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::Ldr { size: 1, signed: false, xt: 2, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 100).unwrap();
        assert_eq!(ex.state.get_x(1) as i64, -128);
        assert_eq!(ex.state.get_x(2), 0x80);
    }

    #[test]
    fn scalar_fault_traps_with_pc() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 0xdead_000 });
        a.push(Inst::Ldr { size: 8, signed: false, xt: 1, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        match ex.run(&p, 100) {
            Err(Trap::Fault { pc, fault }) => {
                assert_eq!(pc, 1);
                assert_eq!(fault.addr, 0xdead_000);
            }
            other => panic!("expected fault, got {other:?}"),
        }
    }

    #[test]
    fn csel_and_flags() {
        let ex = run_prog(|a| {
            a.push(Inst::MovImm { xd: 1, imm: 3 });
            a.push(Inst::MovImm { xd: 2, imm: 9 });
            a.push(Inst::CmpReg { xn: 1, xm: 2 });
            a.push(Inst::Csel { xd: 3, xn: 1, xm: 2, cond: Cond::Lt }); // 3 < 9 -> x1
            a.push(Inst::Csel { xd: 4, xn: 1, xm: 2, cond: Cond::Ge }); // -> x2
        });
        assert_eq!(ex.state.get_x(3), 3);
        assert_eq!(ex.state.get_x(4), 9);
    }

    #[test]
    fn opaque_calls_compute_libm() {
        let ex = run_prog(|a| {
            a.push(Inst::FmovImm { dbl: true, dd: 0, bits: 2.0f64.to_bits() });
            a.push(Inst::FmovImm { dbl: true, dd: 1, bits: 10.0f64.to_bits() });
            a.push(Inst::OpaqueCall { f: OpaqueFn::Pow, dd: 2, dn: 0, dm: Some(1) });
            a.push(Inst::OpaqueCall { f: OpaqueFn::Log, dd: 3, dn: 1, dm: None });
        });
        assert_eq!(ex.state.get_d(2), 1024.0);
        assert!((ex.state.get_d(3) - 10.0f64.ln()).abs() < 1e-15);
    }

    #[test]
    fn fp32_path() {
        let ex = run_prog(|a| {
            a.push(Inst::FmovImm { dbl: false, dd: 0, bits: 1.5f32.to_bits() as u64 });
            a.push(Inst::FmovImm { dbl: false, dd: 1, bits: 2.0f32.to_bits() as u64 });
            a.push(Inst::FpBin { op: FpOp::Mul, dbl: false, dd: 2, dn: 0, dm: 1 });
            a.push(Inst::FpUn { op: FpUnOp::Sqrt, dbl: false, dd: 3, dn: 1 });
        });
        assert_eq!(ex.state.get_s(2), 3.0);
        assert_eq!(ex.state.get_s(3), 2.0f32.sqrt());
    }
}
