//! SVE semantics: predication, while-loops, first-faulting loads, vector
//! partitioning, gather/scatter, horizontal reductions, permutes — every
//! mechanism of §2.
//!
//! Each operation is one parameterized [`Executor`] method; the `h_*`
//! functions below are the tag-indexed µop handlers that feed those
//! methods from the decoded operand fields ([`crate::isa::uop`]). The
//! `cfg(test)` legacy interpreter drives the same methods straight from
//! the `Inst` payloads, which is what the bit-identity property tests
//! compare against.

use super::{ExecResult, Executor};
use crate::arch::{Esize, Flags, PredReg};
use crate::exec::neon::{fcmp, icmp_signed, icmp_unsigned, int_bin};
use crate::exec::scalar::{fp_bin, fp_bin32, fp_un, fp_un32};
use crate::isa::uop::{
    Uop, F_BEFORE, F_FF, F_HI, F_NE, F_OPT, F_SCALED, F_SETFLAGS, F_SUB, F_UNSIGNED, F_ZEROING,
};
use crate::isa::{
    CmpOp, FpOp, FpUnOp, GatherAddr, IntOp, PLogicOp, RedOp, RegOrImm, SveMemOff, ZmOrImm,
};
use crate::mem::MemFault;
use crate::VL_MAX_BYTES;

impl Executor {
    // ====================== predicates ======================

    pub(crate) fn sve_ptrue(&mut self, pd: u8, esize: Esize, s: bool) {
        let vlb = self.state.vl_bytes();
        let mut p = PredReg::default();
        p.set_all(esize, vlb);
        self.state.p[pd as usize] = p;
        if s {
            // governing predicate of ptrue is itself
            self.state.flags = Flags::from_pred_result(&p, &p, esize, vlb);
        }
    }

    pub(crate) fn sve_pfalse(&mut self, pd: u8) {
        self.state.p[pd as usize].clear();
    }

    /// §2.3.2 — the governing predicate a sequential loop would compute,
    /// with wrap-around handled like the original sequential code.
    /// whilelt/whilelo produce a *prefix* predicate by construction, so
    /// the lane loop collapses to a count plus one word-parallel fill.
    pub(crate) fn sve_while(&mut self, pd: u8, esize: Esize, xn: u8, xm: u8, unsigned: bool) {
        let vlb = self.state.vl_bytes();
        let lanes = esize.lanes(vlb);
        let (a, b) = (self.state.get_x(xn), self.state.get_x(xm));
        let count = if unsigned {
            if a >= b {
                0
            } else {
                // lanes stay active until the counter reaches b;
                // a wrapped counter compares below a and stops.
                ((b - a) as u128).min(lanes as u128) as usize
            }
        } else {
            let (a, b) = (a as i64, b as i64);
            if a >= b {
                0
            } else {
                let remaining = (i64::MAX as i128) - (a as i128) + 1; // until wrap
                ((b as i128) - (a as i128)).min(remaining).min(lanes as i128) as usize
            }
        };
        let mut p = PredReg::default();
        p.set_prefix(esize, count, vlb);
        self.state.p[pd as usize] = p;
        let mut all = PredReg::default();
        all.set_all(esize, vlb);
        self.state.flags = Flags::from_pred_result(&all, &p, esize, vlb);
    }

    pub(crate) fn sve_ptest(&mut self, pg: u8, pn: u8) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.p[pn as usize];
        // PTEST interprets at .b granularity
        self.state.flags = Flags::from_pred_result(&g, &n.and(&g), Esize::B, vlb);
    }

    /// §2.3.5 — next active element of pg after pdn's last.
    pub(crate) fn sve_pnext(&mut self, pdn: u8, pg: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let cur = self.state.p[pdn as usize];
        let start = match cur.last_active(esize, vlb) {
            Some(i) => i + 1,
            None => 0,
        };
        let mut r = PredReg::default();
        if let Some(i) = g.first_active_from(esize, start, vlb) {
            r.set_active(esize, i, true);
        }
        self.state.p[pdn as usize] = r;
        self.state.flags = Flags::from_pred_result(&g, &r, esize, vlb);
    }

    /// §2.3.4 — vector partitioning: the before-break (brkb) or
    /// up-to-and-including-break (brka) partition, B-granule, zeroing
    /// form: keep pg's lanes strictly before (brkb) / up to and
    /// including (brka) the first active break lane — one scan plus one
    /// mask.
    pub(crate) fn sve_brk(&mut self, pd: u8, pg: u8, pn: u8, before: bool, s: bool) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.p[pn as usize];
        let keep = match g.and(&n).first_active(Esize::B, vlb) {
            None => vlb,
            Some(k) => {
                if before {
                    k
                } else {
                    k + 1
                }
            }
        };
        let mut r = g;
        r.clear_from(keep.min(vlb));
        self.state.p[pd as usize] = r;
        if s {
            self.state.flags = Flags::from_pred_result(&g, &r, Esize::B, vlb);
        }
    }

    /// Word-parallel: at .b granularity every bit is an element enable,
    /// so the lane loop is four u64 ops.
    pub(crate) fn sve_pred_logic(&mut self, op: PLogicOp, pd: u8, pg: u8, pn: u8, pm: u8, s: bool) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.p[pn as usize];
        let m = self.state.p[pm as usize];
        let r = match op {
            PLogicOp::And => PredReg::combine(&n, &m, &g, vlb, |a, b| a & b),
            PLogicOp::Orr => PredReg::combine(&n, &m, &g, vlb, |a, b| a | b),
            PLogicOp::Eor => PredReg::combine(&n, &m, &g, vlb, |a, b| a ^ b),
            PLogicOp::Bic => PredReg::combine(&n, &m, &g, vlb, |a, b| a & !b),
        };
        self.state.p[pd as usize] = r;
        if s {
            self.state.flags = Flags::from_pred_result(&g, &r, Esize::B, vlb);
        }
    }

    pub(crate) fn sve_rdffr(&mut self, pd: u8, pg: Option<u8>, s: bool) {
        let vlb = self.state.vl_bytes();
        let f = self.state.ffr;
        let r = match pg {
            Some(g) => f.and(&self.state.p[g as usize]),
            None => f,
        };
        self.state.p[pd as usize] = r;
        if s {
            let g = match pg {
                Some(g) => self.state.p[g as usize],
                None => {
                    let mut all = PredReg::default();
                    all.set_all(Esize::B, vlb);
                    all
                }
            };
            self.state.flags = Flags::from_pred_result(&g, &r, Esize::B, vlb);
        }
    }

    pub(crate) fn sve_setffr(&mut self) {
        let vlb = self.state.vl_bytes();
        let mut f = PredReg::default();
        f.set_all(Esize::B, vlb);
        self.state.ffr = f;
    }

    pub(crate) fn sve_wrffr(&mut self, pn: u8) {
        self.state.ffr = self.state.p[pn as usize];
    }

    // ====================== counting ======================

    pub(crate) fn sve_cnt(&mut self, xd: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        self.state.set_x(xd, esize.lanes(vlb) as u64);
    }

    pub(crate) fn sve_inc_dec(&mut self, xdn: u8, esize: Esize, dec: bool) {
        let vlb = self.state.vl_bytes();
        let d = esize.lanes(vlb) as u64;
        let v = self.state.get_x(xdn);
        self.state.set_x(xdn, if dec { v.wrapping_sub(d) } else { v.wrapping_add(d) });
    }

    pub(crate) fn sve_incp(&mut self, xdn: u8, pm: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let c = self.state.p[pm as usize].count_active(esize, vlb) as u64;
        let v = self.state.get_x(xdn).wrapping_add(c);
        self.state.set_x(xdn, v);
    }

    pub(crate) fn sve_index(&mut self, zd: u8, esize: Esize, base: RegOrImm, step: RegOrImm) {
        let vlb = self.state.vl_bytes();
        let b = self.ri(base);
        let st = self.ri(step);
        let z = &mut self.state.z[zd as usize];
        for i in 0..esize.lanes(vlb) {
            z.set(esize, i, (b.wrapping_add(st.wrapping_mul(i as i64))) as u64);
        }
    }

    // ====================== data movement ======================

    pub(crate) fn sve_dup_imm(&mut self, zd: u8, esize: Esize, imm: i64) {
        let vlb = self.state.vl_bytes();
        let z = &mut self.state.z[zd as usize];
        z.zero();
        for i in 0..esize.lanes(vlb) {
            z.set(esize, i, imm as u64);
        }
    }

    pub(crate) fn sve_fdup(&mut self, zd: u8, dbl: bool, bits: u64) {
        let vlb = self.state.vl_bytes();
        let z = &mut self.state.z[zd as usize];
        z.zero();
        let e = if dbl { Esize::D } else { Esize::S };
        for i in 0..e.lanes(vlb) {
            z.set(e, i, bits);
        }
    }

    pub(crate) fn sve_dup_x(&mut self, zd: u8, esize: Esize, xn: u8) {
        let vlb = self.state.vl_bytes();
        let v = self.state.get_x(xn);
        let z = &mut self.state.z[zd as usize];
        z.zero();
        for i in 0..esize.lanes(vlb) {
            z.set(esize, i, v);
        }
    }

    pub(crate) fn sve_cpy_x(&mut self, zd: u8, pg: u8, xn: u8, esize: Esize) {
        self.sve_cpy_x_impl::<false>(zd, pg, xn, esize);
    }

    pub(crate) fn sve_cpy_x_impl<const DENSE: bool>(
        &mut self,
        zd: u8,
        pg: u8,
        xn: u8,
        esize: Esize,
    ) {
        let vlb = self.state.vl_bytes();
        let v = self.state.get_x(xn);
        let g = self.state.p[pg as usize];
        let z = &mut self.state.z[zd as usize];
        for i in 0..esize.lanes(vlb) {
            if DENSE || g.active(esize, i) {
                z.set(esize, i, v);
            }
        }
    }

    pub(crate) fn sve_sel(&mut self, zd: u8, pg: u8, zn: u8, zm: u8, esize: Esize) {
        self.sve_sel_impl::<false>(zd, pg, zn, zm, esize);
    }

    pub(crate) fn sve_sel_impl<const DENSE: bool>(
        &mut self,
        zd: u8,
        pg: u8,
        zn: u8,
        zm: u8,
        esize: Esize,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let z = &mut self.state.z[zd as usize];
        for i in 0..esize.lanes(vlb) {
            let v = if DENSE || g.active(esize, i) { n.get(esize, i) } else { m.get(esize, i) };
            z.set(esize, i, v);
        }
    }

    pub(crate) fn sve_movprfx(&mut self, zd: u8, zn: u8, pg: Option<(u8, bool)>) {
        let vlb = self.state.vl_bytes();
        let n = self.state.z[zn as usize];
        match pg {
            None => self.state.z[zd as usize] = n,
            Some((g, zeroing)) => {
                let gp = self.state.p[g as usize];
                let z = &mut self.state.z[zd as usize];
                // byte-granule merging/zeroing copy
                for i in 0..vlb {
                    if gp.active(Esize::B, i) {
                        z.bytes[i] = n.bytes[i];
                    } else if zeroing {
                        z.bytes[i] = 0;
                    }
                }
            }
        }
    }

    pub(crate) fn sve_last(&mut self, xd: u8, pg: u8, zn: u8, esize: Esize, before: bool) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let z = self.state.z[zn as usize];
        let lanes = esize.lanes(vlb);
        let idx = match (g.last_active(esize, vlb), before) {
            (Some(l), true) => l,                // lastb
            (Some(l), false) => (l + 1) % lanes, // lasta
            (None, true) => lanes - 1,
            (None, false) => 0,
        };
        self.state.set_x(xd, z.get(esize, idx));
    }

    // ====================== memory ======================

    /// ld1r<esize> — load-and-broadcast (§4): one element load.
    pub(crate) fn sve_ld1r(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        base: u8,
        imm: i64,
    ) -> ExecResult {
        self.sve_ld1r_impl::<false>(zt, pg, esize, base, imm)
    }

    pub(crate) fn sve_ld1r_impl<const DENSE: bool>(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        base: u8,
        imm: i64,
    ) -> ExecResult {
        let vlb = self.state.vl_bytes();
        let addr = self.state.get_x(base).wrapping_add(imm as u64);
        let g = self.state.p[pg as usize];
        let v = self.mem.read(addr, esize.bytes())?;
        self.record_load(addr, esize.bytes() as u32);
        let z = &mut self.state.z[zt as usize];
        z.zero();
        for i in 0..esize.lanes(vlb) {
            if DENSE || g.active(esize, i) {
                z.set(esize, i, v);
            }
        }
        Ok(())
    }

    /// Contiguous predicated store.
    pub(crate) fn sve_st1(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        base: u8,
        off: SveMemOff,
    ) -> ExecResult {
        let vlb = self.state.vl_bytes();
        let ebytes = esize.bytes();
        let baddr = self.sve_contig_base(base, off, ebytes, vlb);
        let g = self.state.p[pg as usize];
        if let Some(k) = g.prefix_len(esize, vlb) {
            return self.sve_st1_bulk(zt, baddr, k * ebytes);
        }
        // sparse predicate: element-at-a-time semantics
        let z = self.state.z[zt as usize];
        let mut span: Option<(u64, u64)> = None;
        for i in 0..esize.lanes(vlb) {
            if g.active(esize, i) {
                let addr = baddr + (i * ebytes) as u64;
                self.mem.write(addr, ebytes, z.get(esize, i))?;
                span = Some(match span {
                    None => (addr, addr + ebytes as u64),
                    Some((lo, hi)) => (lo.min(addr), hi.max(addr + ebytes as u64)),
                });
            }
        }
        if let Some((lo, hi)) = span {
            self.record_store(lo, (hi - lo) as u32);
        }
        Ok(())
    }

    /// Bulk contiguous store of the leading `total` bytes of `zt`: the
    /// dense-prefix arm of [`Executor::sve_st1`] (ptrue/whilelt
    /// predicates — the little-endian register image *is* the memory
    /// image, so the store is one bulk copy per page), also entered
    /// directly by the trace engine's dense slots with `total` = the
    /// whole register.
    pub(crate) fn sve_st1_bulk(&mut self, zt: u8, baddr: u64, total: usize) -> ExecResult {
        if total > 0 {
            let zbytes = self.state.z[zt as usize].bytes;
            self.write_contig(baddr, &zbytes[..total])?;
            self.record_store(baddr, total as u32);
        }
        Ok(())
    }

    /// Scatter store: one element access per active lane (cracked, §4).
    pub(crate) fn sve_scatter(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        addr: GatherAddr,
    ) -> ExecResult {
        self.sve_scatter_impl::<false>(zt, pg, esize, addr)
    }

    pub(crate) fn sve_scatter_impl<const DENSE: bool>(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        addr: GatherAddr,
    ) -> ExecResult {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let z = self.state.z[zt as usize];
        let ebytes = esize.bytes();
        for i in 0..esize.lanes(vlb) {
            if DENSE || g.active(esize, i) {
                let a = self.gather_ea(addr, esize, i);
                self.mem.write(a, ebytes, z.get(esize, i))?;
                self.record_store(a, ebytes as u32);
            }
        }
        Ok(())
    }

    // ====================== arithmetic ======================

    pub(crate) fn sve_int_bin(&mut self, op: IntOp, zdn: u8, pg: u8, zm: u8, esize: Esize) {
        self.sve_int_bin_impl::<false>(op, zdn, pg, zm, esize);
    }

    /// [`Executor::sve_int_bin`] monomorphized over predicate density:
    /// `DENSE` callers (the trace engine's specialized slots) have
    /// proven every lane active behind the trace's per-iteration
    /// guard, so the per-lane predicate test folds away.
    pub(crate) fn sve_int_bin_impl<const DENSE: bool>(
        &mut self,
        op: IntOp,
        zdn: u8,
        pg: u8,
        zm: u8,
        esize: Esize,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let m = self.state.z[zm as usize];
        let z = &mut self.state.z[zdn as usize];
        for i in 0..esize.lanes(vlb) {
            if DENSE || g.active(esize, i) {
                let v = int_bin(op, esize, z.get(esize, i), m.get(esize, i));
                z.set(esize, i, v);
            }
        }
    }

    pub(crate) fn sve_int_bin_u(&mut self, op: IntOp, zd: u8, zn: u8, zm: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let z = &mut self.state.z[zd as usize];
        for i in 0..esize.lanes(vlb) {
            z.set(esize, i, int_bin(op, esize, n.get(esize, i), m.get(esize, i)));
        }
    }

    pub(crate) fn sve_add_imm(&mut self, zdn: u8, esize: Esize, imm: u64) {
        let vlb = self.state.vl_bytes();
        let z = &mut self.state.z[zdn as usize];
        for i in 0..esize.lanes(vlb) {
            z.set(esize, i, z.get(esize, i).wrapping_add(imm));
        }
    }

    pub(crate) fn sve_fp_bin(&mut self, op: FpOp, zdn: u8, pg: u8, zm: u8, dbl: bool) {
        self.sve_fp_bin_impl::<false>(op, zdn, pg, zm, dbl);
    }

    /// [`Executor::sve_fp_bin`] monomorphized over predicate density
    /// (see [`Executor::sve_int_bin_impl`]).
    pub(crate) fn sve_fp_bin_impl<const DENSE: bool>(
        &mut self,
        op: FpOp,
        zdn: u8,
        pg: u8,
        zm: u8,
        dbl: bool,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let m = self.state.z[zm as usize];
        let z = &mut self.state.z[zdn as usize];
        if dbl {
            for i in 0..Esize::D.lanes(vlb) {
                if DENSE || g.active(Esize::D, i) {
                    z.set_f64(i, fp_bin(op, z.get_f64(i), m.get_f64(i)));
                }
            }
        } else {
            for i in 0..Esize::S.lanes(vlb) {
                if DENSE || g.active(Esize::S, i) {
                    z.set_f32(i, fp_bin32(op, z.get_f32(i), m.get_f32(i)));
                }
            }
        }
    }

    pub(crate) fn sve_fp_un(&mut self, op: FpUnOp, zd: u8, pg: u8, zn: u8, dbl: bool) {
        self.sve_fp_un_impl::<false>(op, zd, pg, zn, dbl);
    }

    /// [`Executor::sve_fp_un`] monomorphized over predicate density
    /// (see [`Executor::sve_int_bin_impl`]).
    pub(crate) fn sve_fp_un_impl<const DENSE: bool>(
        &mut self,
        op: FpUnOp,
        zd: u8,
        pg: u8,
        zn: u8,
        dbl: bool,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.z[zn as usize];
        let z = &mut self.state.z[zd as usize];
        if dbl {
            for i in 0..Esize::D.lanes(vlb) {
                if DENSE || g.active(Esize::D, i) {
                    z.set_f64(i, fp_un(op, n.get_f64(i)));
                }
            }
        } else {
            for i in 0..Esize::S.lanes(vlb) {
                if DENSE || g.active(Esize::S, i) {
                    z.set_f32(i, fp_un32(op, n.get_f32(i)));
                }
            }
        }
    }

    pub(crate) fn sve_fmla(&mut self, zda: u8, pg: u8, zn: u8, zm: u8, dbl: bool, sub: bool) {
        self.sve_fmla_impl::<false>(zda, pg, zn, zm, dbl, sub);
    }

    /// [`Executor::sve_fmla`] monomorphized over predicate density
    /// (see [`Executor::sve_int_bin_impl`]).
    pub(crate) fn sve_fmla_impl<const DENSE: bool>(
        &mut self,
        zda: u8,
        pg: u8,
        zn: u8,
        zm: u8,
        dbl: bool,
        sub: bool,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let z = &mut self.state.z[zda as usize];
        if dbl {
            for i in 0..Esize::D.lanes(vlb) {
                if DENSE || g.active(Esize::D, i) {
                    let p = n.get_f64(i) * m.get_f64(i);
                    let p = if sub { -p } else { p };
                    z.set_f64(i, z.get_f64(i) + p);
                }
            }
        } else {
            for i in 0..Esize::S.lanes(vlb) {
                if DENSE || g.active(Esize::S, i) {
                    let p = n.get_f32(i) * m.get_f32(i);
                    let p = if sub { -p } else { p };
                    z.set_f32(i, z.get_f32(i) + p);
                }
            }
        }
    }

    pub(crate) fn sve_scvtf(&mut self, zd: u8, pg: u8, zn: u8, dbl: bool) {
        self.sve_scvtf_impl::<false>(zd, pg, zn, dbl);
    }

    /// [`Executor::sve_scvtf`] monomorphized over predicate density
    /// (see [`Executor::sve_int_bin_impl`]).
    pub(crate) fn sve_scvtf_impl<const DENSE: bool>(&mut self, zd: u8, pg: u8, zn: u8, dbl: bool) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.z[zn as usize];
        let z = &mut self.state.z[zd as usize];
        if dbl {
            for i in 0..Esize::D.lanes(vlb) {
                if DENSE || g.active(Esize::D, i) {
                    z.set_f64(i, n.get_signed(Esize::D, i) as f64);
                }
            }
        } else {
            for i in 0..Esize::S.lanes(vlb) {
                if DENSE || g.active(Esize::S, i) {
                    z.set_f32(i, n.get_signed(Esize::S, i) as f32);
                }
            }
        }
    }

    // ====================== compares ======================

    #[allow(clippy::too_many_arguments)] // one operand bundle per compare shape
    pub(crate) fn sve_int_cmp(
        &mut self,
        op: CmpOp,
        unsigned: bool,
        pd: u8,
        pg: u8,
        zn: u8,
        rhs: ZmOrImm,
        esize: Esize,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.z[zn as usize];
        let mut r = PredReg::default();
        for i in 0..esize.lanes(vlb) {
            if g.active(esize, i) {
                let t = match rhs {
                    ZmOrImm::Z(zm) => {
                        let m = self.state.z[zm as usize];
                        if unsigned {
                            icmp_unsigned(op, n.get(esize, i), m.get(esize, i))
                        } else {
                            icmp_signed(op, n.get_signed(esize, i), m.get_signed(esize, i))
                        }
                    }
                    ZmOrImm::Imm(imm) => {
                        if unsigned {
                            icmp_unsigned(op, n.get(esize, i), imm as u64)
                        } else {
                            icmp_signed(op, n.get_signed(esize, i), imm)
                        }
                    }
                };
                r.set_active(esize, i, t);
            }
        }
        self.state.p[pd as usize] = r;
        self.state.flags = Flags::from_pred_result(&g, &r, esize, vlb);
    }

    /// FP compare against vector or #0.0 (rhs None).
    pub(crate) fn sve_fp_cmp(
        &mut self,
        op: CmpOp,
        pd: u8,
        pg: u8,
        zn: u8,
        rhs: Option<u8>,
        dbl: bool,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.z[zn as usize];
        let e = if dbl { Esize::D } else { Esize::S };
        let mut r = PredReg::default();
        for i in 0..e.lanes(vlb) {
            if g.active(e, i) {
                let a = if dbl { n.get_f64(i) } else { n.get_f32(i) as f64 };
                let b = match rhs {
                    Some(zm) => {
                        let m = self.state.z[zm as usize];
                        if dbl {
                            m.get_f64(i)
                        } else {
                            m.get_f32(i) as f64
                        }
                    }
                    None => 0.0,
                };
                r.set_active(e, i, fcmp(op, a, b));
            }
        }
        self.state.p[pd as usize] = r;
        self.state.flags = Flags::from_pred_result(&g, &r, e, vlb);
    }

    // ====================== horizontal (§2.4) ======================

    pub(crate) fn sve_reduce(&mut self, op: RedOp, vd: u8, pg: u8, zn: u8, esize: Esize) {
        self.sve_reduce_impl::<false>(op, vd, pg, zn, esize);
    }

    pub(crate) fn sve_reduce_impl<const DENSE: bool>(
        &mut self,
        op: RedOp,
        vd: u8,
        pg: u8,
        zn: u8,
        esize: Esize,
    ) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.z[zn as usize];
        let lanes = esize.lanes(vlb);
        match op {
            RedOp::FAddV | RedOp::FMaxV | RedOp::FMinV => {
                // recursive pairwise tree over the full vector with
                // identity at inactive lanes
                let dbl = esize == Esize::D;
                let ident = match op {
                    RedOp::FAddV => 0.0f64,
                    RedOp::FMaxV => f64::NEG_INFINITY,
                    RedOp::FMinV => f64::INFINITY,
                    _ => unreachable!(),
                };
                let mut buf: Vec<f64> = (0..lanes)
                    .map(|i| {
                        if DENSE || g.active(esize, i) {
                            if dbl {
                                n.get_f64(i)
                            } else {
                                n.get_f32(i) as f64
                            }
                        } else {
                            ident
                        }
                    })
                    .collect();
                let mut width = lanes;
                while width > 1 {
                    let half = width / 2;
                    for i in 0..half {
                        buf[i] = match op {
                            RedOp::FAddV => buf[i] + buf[i + half],
                            RedOp::FMaxV => buf[i].max(buf[i + half]),
                            RedOp::FMinV => buf[i].min(buf[i + half]),
                            _ => unreachable!(),
                        };
                    }
                    width = half;
                }
                if dbl {
                    self.state.set_d(vd, buf[0]);
                } else {
                    self.state.set_s(vd, buf[0] as f32);
                }
            }
            RedOp::EorV | RedOp::OrV | RedOp::AndV | RedOp::UAddV | RedOp::SMaxV => {
                let mut acc: u64 = match op {
                    RedOp::EorV | RedOp::OrV | RedOp::UAddV => 0,
                    RedOp::AndV => u64::MAX,
                    RedOp::SMaxV => i64::MIN as u64,
                    _ => unreachable!(),
                };
                for i in 0..lanes {
                    if DENSE || g.active(esize, i) {
                        let v = n.get(esize, i);
                        acc = match op {
                            RedOp::EorV => acc ^ v,
                            RedOp::OrV => acc | v,
                            RedOp::AndV => acc & v,
                            RedOp::UAddV => acc.wrapping_add(v),
                            RedOp::SMaxV => (acc as i64).max(n.get_signed(esize, i)) as u64,
                            _ => unreachable!(),
                        };
                    }
                }
                let z = &mut self.state.z[vd as usize];
                z.zero();
                z.set(Esize::D, 0, acc);
            }
        }
    }

    /// Strictly-ordered accumulation (§3.3): scalar dest, element order
    /// = implicit predicate order.
    pub(crate) fn sve_fadda(&mut self, vdn: u8, pg: u8, zm: u8, dbl: bool) {
        self.sve_fadda_impl::<false>(vdn, pg, zm, dbl);
    }

    pub(crate) fn sve_fadda_impl<const DENSE: bool>(&mut self, vdn: u8, pg: u8, zm: u8, dbl: bool) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let m = self.state.z[zm as usize];
        if dbl {
            let mut acc = self.state.get_d(vdn);
            for i in 0..Esize::D.lanes(vlb) {
                if DENSE || g.active(Esize::D, i) {
                    acc += m.get_f64(i);
                }
            }
            self.state.set_d(vdn, acc);
        } else {
            let mut acc = self.state.get_s(vdn);
            for i in 0..Esize::S.lanes(vlb) {
                if DENSE || g.active(Esize::S, i) {
                    acc += m.get_f32(i);
                }
            }
            self.state.set_s(vdn, acc);
        }
    }

    // ====================== permutes ======================

    pub(crate) fn sve_rev(&mut self, zd: u8, zn: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let n = self.state.z[zn as usize];
        let lanes = esize.lanes(vlb);
        let z = &mut self.state.z[zd as usize];
        for i in 0..lanes {
            z.set(esize, i, n.get(esize, lanes - 1 - i));
        }
    }

    pub(crate) fn sve_ext(&mut self, zdn: u8, zm: u8, imm: u8) {
        let vlb = self.state.vl_bytes();
        let a = self.state.z[zdn as usize];
        let b = self.state.z[zm as usize];
        let z = &mut self.state.z[zdn as usize];
        for i in 0..vlb {
            let src = i + imm as usize;
            z.bytes[i] = if src < vlb { a.bytes[src] } else { b.bytes[src - vlb] };
        }
    }

    pub(crate) fn sve_zip(&mut self, zd: u8, zn: u8, zm: u8, esize: Esize, hi: bool) {
        let vlb = self.state.vl_bytes();
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let lanes = esize.lanes(vlb);
        let half = lanes / 2;
        let base = if hi { half } else { 0 };
        let z = &mut self.state.z[zd as usize];
        for i in 0..half {
            z.set(esize, 2 * i, n.get(esize, base + i));
            z.set(esize, 2 * i + 1, m.get(esize, base + i));
        }
    }

    pub(crate) fn sve_uzp(&mut self, zd: u8, zn: u8, zm: u8, esize: Esize, odd: bool) {
        let vlb = self.state.vl_bytes();
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let lanes = esize.lanes(vlb);
        let half = lanes / 2;
        let off = odd as usize;
        let z = &mut self.state.z[zd as usize];
        for i in 0..half {
            z.set(esize, i, n.get(esize, 2 * i + off));
            z.set(esize, half + i, m.get(esize, 2 * i + off));
        }
    }

    pub(crate) fn sve_trn(&mut self, zd: u8, zn: u8, zm: u8, esize: Esize, odd: bool) {
        let vlb = self.state.vl_bytes();
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let lanes = esize.lanes(vlb);
        let off = odd as usize;
        let z = &mut self.state.z[zd as usize];
        for i in 0..lanes / 2 {
            z.set(esize, 2 * i, n.get(esize, 2 * i + off));
            z.set(esize, 2 * i + 1, m.get(esize, 2 * i + off));
        }
    }

    pub(crate) fn sve_tbl(&mut self, zd: u8, zn: u8, zm: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let (n, m) = (self.state.z[zn as usize], self.state.z[zm as usize]);
        let lanes = esize.lanes(vlb);
        let z = &mut self.state.z[zd as usize];
        for i in 0..lanes {
            let idx = m.get(esize, i) as usize;
            z.set(esize, i, if idx < lanes { n.get(esize, idx) } else { 0 });
        }
    }

    pub(crate) fn sve_compact(&mut self, zd: u8, pg: u8, zn: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let n = self.state.z[zn as usize];
        let lanes = esize.lanes(vlb);
        let z = &mut self.state.z[zd as usize];
        let mut k = 0;
        let vals: Vec<u64> = (0..lanes)
            .filter(|&i| g.active(esize, i))
            .map(|i| n.get(esize, i))
            .collect();
        for i in 0..lanes {
            z.set(esize, i, 0);
        }
        for v in vals {
            z.set(esize, k, v);
            k += 1;
        }
    }

    pub(crate) fn sve_splice(&mut self, zdn: u8, pg: u8, zm: u8, esize: Esize) {
        let vlb = self.state.vl_bytes();
        let g = self.state.p[pg as usize];
        let a = self.state.z[zdn as usize];
        let b = self.state.z[zm as usize];
        let lanes = esize.lanes(vlb);
        let z = &mut self.state.z[zdn as usize];
        let mut out: Vec<u64> = vec![];
        if let (Some(f), Some(l)) = (g.first_active(esize, vlb), g.last_active(esize, vlb)) {
            for i in f..=l {
                out.push(a.get(esize, i));
            }
        }
        let mut bi = 0;
        while out.len() < lanes {
            out.push(b.get(esize, bi));
            bi += 1;
        }
        for (i, v) in out.into_iter().enumerate() {
            z.set(esize, i, v);
        }
    }

    // ====================== termination ======================

    /// CTERMEQ/CTERMNE (§2.3.5): if the termination condition holds,
    /// N=1 V=0 (b.tcont fails); otherwise N=0 and V = !C, so b.tcont
    /// (GE) continues iff C was set (the preceding pnext's "not last"
    /// state).
    pub(crate) fn sve_cterm(&mut self, xn: u8, xm: u8, ne: bool) {
        let term = if ne {
            self.state.get_x(xn) != self.state.get_x(xm)
        } else {
            self.state.get_x(xn) == self.state.get_x(xm)
        };
        let c = self.state.flags.c;
        self.state.flags = if term {
            Flags { n: true, z: false, c, v: false }
        } else {
            Flags { n: false, z: false, c, v: !c }
        };
    }

    // ---- shared address/memory helpers ----

    fn ri(&self, v: RegOrImm) -> i64 {
        match v {
            RegOrImm::Reg(r) => self.state.get_x(r) as i64,
            RegOrImm::Imm(i) => i,
        }
    }

    /// Base address of a contiguous SVE access.
    pub(crate) fn sve_contig_base(
        &self,
        base: u8,
        off: SveMemOff,
        ebytes: usize,
        vlb: usize,
    ) -> u64 {
        let b = self.state.get_x(base);
        match off {
            SveMemOff::ImmVl(imm) => b.wrapping_add((imm * vlb as i64) as u64),
            SveMemOff::RegScaled(xm) => {
                b.wrapping_add(self.state.get_x(xm).wrapping_mul(ebytes as u64))
            }
        }
    }

    /// Contiguous (optionally first-faulting) predicated load.
    ///
    /// Dense-prefix predicates (what `ptrue`/`whilelt` produce — the
    /// only shape the compiler emits for contiguous loops) take a bulk
    /// path: one TLB translation per page and one `copy_from_slice`
    /// straight into the little-endian register image. First-fault
    /// semantics are preserved exactly — the bulk copy stops at the
    /// first unmapped byte, which identifies the same faulting element
    /// the per-lane walk would find (elements before it sit entirely in
    /// mapped pages), and the FFR partition update is one bitwise mask.
    pub(crate) fn sve_ld1(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        base: u8,
        off: SveMemOff,
        ff: bool,
    ) -> Result<(), MemFault> {
        let vlb = self.state.vl_bytes();
        let ebytes = esize.bytes();
        let baddr = self.sve_contig_base(base, off, ebytes, vlb);
        let g = self.state.p[pg as usize];
        let lanes = esize.lanes(vlb);
        if let Some(k) = g.prefix_len(esize, vlb) {
            return self.sve_ld1_bulk(zt, esize, baddr, k, ff);
        }
        // sparse predicate: element-at-a-time (zeroing predication, and
        // inactive lanes never touch memory — a hole under an inactive
        // lane is not a fault)
        let mut vals = std::mem::take(&mut self.lane_scratch);
        vals[..lanes].fill(0);
        let mut span: Option<(u64, u64)> = None;
        let mut fault_lane: Option<usize> = None;
        let first_active = g.first_active(esize, vlb);
        for i in 0..lanes {
            if !g.active(esize, i) {
                continue;
            }
            let addr = baddr + (i * ebytes) as u64;
            match self.mem.read(addr, ebytes) {
                Ok(v) => {
                    vals[i] = v;
                    span = Some(match span {
                        None => (addr, addr + ebytes as u64),
                        Some((lo, hi)) => (lo.min(addr), hi.max(addr + ebytes as u64)),
                    });
                }
                Err(fault) => {
                    if !ff || Some(i) == first_active {
                        self.lane_scratch = vals;
                        return Err(fault);
                    }
                    fault_lane = Some(i);
                    break;
                }
            }
        }
        if let Some(fl) = fault_lane {
            // clear FFR from the faulting element onward
            self.state.ffr.clear_from(fl * ebytes);
        }
        if let Some((lo, hi)) = span {
            self.record_load(lo, (hi - lo) as u32);
        }
        let z = &mut self.state.z[zt as usize];
        z.zero();
        for (i, &v) in vals[..lanes].iter().enumerate() {
            z.set(esize, i, v);
        }
        self.lane_scratch = vals;
        Ok(())
    }

    /// Bulk contiguous load of the leading `k` elements into `zt` (the
    /// rest zeroed): the dense-prefix arm of [`Executor::sve_ld1`],
    /// also entered directly by the trace engine's dense slots with
    /// `k` = all lanes (the predicate check already happened, once, at
    /// the trace's per-iteration guard). First-fault semantics are
    /// preserved exactly — the bulk copy stops at the first unmapped
    /// byte, which identifies the same faulting element the per-lane
    /// walk would find.
    pub(crate) fn sve_ld1_bulk(
        &mut self,
        zt: u8,
        esize: Esize,
        baddr: u64,
        k: usize,
        ff: bool,
    ) -> Result<(), MemFault> {
        let ebytes = esize.bytes();
        let total = k * ebytes;
        let mut buf = [0u8; VL_MAX_BYTES];
        let (copied, fault) = self.read_contig_partial(baddr, &mut buf[..total]);
        let loaded = match fault {
            Some(f) => {
                // element containing the first unmapped byte
                let fl = copied / ebytes;
                if !ff || fl == 0 {
                    // non-ff loads, or a fault on the FIRST active
                    // element, trap for real (§2.3.3)
                    return Err(f);
                }
                // clear FFR from the faulting element onward
                self.state.ffr.clear_from(fl * ebytes);
                fl
            }
            None => k,
        };
        if loaded > 0 {
            self.record_load(baddr, (loaded * ebytes) as u32);
        }
        let z = &mut self.state.z[zt as usize];
        z.zero();
        z.bytes[..loaded * ebytes].copy_from_slice(&buf[..loaded * ebytes]);
        Ok(())
    }

    /// Element address of a gather/scatter lane.
    pub(crate) fn gather_ea(&self, addr: GatherAddr, esize: Esize, lane: usize) -> u64 {
        match addr {
            GatherAddr::VecImm(zn, imm) => {
                self.state.z[zn as usize].get(Esize::D, lane).wrapping_add(imm as u64)
            }
            GatherAddr::BaseVec { xn, zm, scaled } => {
                let idx = self.state.z[zm as usize].get(esize, lane);
                let idx = if scaled { idx.wrapping_mul(esize.bytes() as u64) } else { idx };
                self.state.get_x(xn).wrapping_add(idx)
            }
        }
    }

    /// Gather load (optionally first-faulting).
    pub(crate) fn sve_gather(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        addr: GatherAddr,
        ff: bool,
    ) -> Result<(), MemFault> {
        self.sve_gather_impl::<false>(zt, pg, esize, addr, ff)
    }

    pub(crate) fn sve_gather_impl<const DENSE: bool>(
        &mut self,
        zt: u8,
        pg: u8,
        esize: Esize,
        addr: GatherAddr,
        ff: bool,
    ) -> Result<(), MemFault> {
        let vlb = self.state.vl_bytes();
        let ebytes = esize.bytes();
        let g = self.state.p[pg as usize];
        let lanes = esize.lanes(vlb);
        // every lane is active when DENSE, so the first active is lane 0
        let first_active = if DENSE { Some(0) } else { g.first_active(esize, vlb) };
        let mut vals = std::mem::take(&mut self.lane_scratch);
        vals[..lanes].fill(0);
        let mut fault_lane: Option<usize> = None;
        for i in 0..lanes {
            if !DENSE && !g.active(esize, i) {
                continue;
            }
            let a = self.gather_ea(addr, esize, i);
            match self.mem.read(a, ebytes) {
                Ok(v) => {
                    vals[i] = v;
                    self.record_load(a, ebytes as u32);
                }
                Err(fault) => {
                    if !ff || Some(i) == first_active {
                        self.lane_scratch = vals;
                        return Err(fault);
                    }
                    fault_lane = Some(i);
                    break;
                }
            }
        }
        if let Some(fl) = fault_lane {
            // clear FFR from the faulting element onward (bitwise mask)
            self.state.ffr.clear_from(fl * esize.bytes());
        }
        let z = &mut self.state.z[zt as usize];
        z.zero();
        for (i, &v) in vals[..lanes].iter().enumerate() {
            z.set(esize, i, v);
        }
        self.lane_scratch = vals;
        Ok(())
    }
}

// ---- µop handlers (tag-indexed; see exec::DISPATCH) ----

pub(crate) fn h_ptrue(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ptrue(u.a, u.esize, u.has(F_SETFLAGS));
    Ok(())
}

pub(crate) fn h_pfalse(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_pfalse(u.a);
    Ok(())
}

pub(crate) fn h_while(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_while(u.a, u.esize, u.b, u.c, u.has(F_UNSIGNED));
    Ok(())
}

pub(crate) fn h_ptest(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ptest(u.b, u.c);
    Ok(())
}

pub(crate) fn h_pnext(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_pnext(u.a, u.b, u.esize);
    Ok(())
}

pub(crate) fn h_brk(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_brk(u.a, u.b, u.c, u.has(F_BEFORE), u.has(F_SETFLAGS));
    Ok(())
}

pub(crate) fn h_pred_logic(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_pred_logic(u.sub.plogic(), u.a, u.b, u.c, u.d, u.has(F_SETFLAGS));
    Ok(())
}

pub(crate) fn h_rdffr(ex: &mut Executor, u: &Uop) -> ExecResult {
    let pg = if u.has(F_OPT) { Some(u.c) } else { None };
    ex.sve_rdffr(u.a, pg, u.has(F_SETFLAGS));
    Ok(())
}

pub(crate) fn h_setffr(ex: &mut Executor, _u: &Uop) -> ExecResult {
    ex.sve_setffr();
    Ok(())
}

pub(crate) fn h_wrffr(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_wrffr(u.b);
    Ok(())
}

pub(crate) fn h_cnt(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_cnt(u.a, u.esize);
    Ok(())
}

pub(crate) fn h_inc_dec(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_inc_dec(u.a, u.esize, u.has(crate::isa::uop::F_DEC));
    Ok(())
}

pub(crate) fn h_incp_x(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_incp(u.a, u.b, u.esize);
    Ok(())
}

pub(crate) fn h_index(ex: &mut Executor, u: &Uop) -> ExecResult {
    let base = if u.has(crate::isa::uop::F_BASE_REG) {
        RegOrImm::Reg(u.b)
    } else {
        RegOrImm::Imm(u.imm)
    };
    let step = if u.has(crate::isa::uop::F_STEP_REG) {
        RegOrImm::Reg(u.c)
    } else {
        RegOrImm::Imm(u.imm2)
    };
    ex.sve_index(u.a, u.esize, base, step);
    Ok(())
}

pub(crate) fn h_dup_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_dup_imm(u.a, u.esize, u.imm);
    Ok(())
}

pub(crate) fn h_fdup_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fdup(u.a, u.dbl(), u.imm as u64);
    Ok(())
}

pub(crate) fn h_dup_x(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_dup_x(u.a, u.esize, u.b);
    Ok(())
}

pub(crate) fn h_cpy_x(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_cpy_x(u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sel(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_sel(u.a, u.b, u.c, u.d, u.esize);
    Ok(())
}

pub(crate) fn h_movprfx(ex: &mut Executor, u: &Uop) -> ExecResult {
    let pg = if u.has(F_OPT) { Some((u.c, u.has(F_ZEROING))) } else { None };
    ex.sve_movprfx(u.a, u.b, pg);
    Ok(())
}

pub(crate) fn h_last(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_last(u.a, u.b, u.c, u.esize, u.has(F_BEFORE));
    Ok(())
}

pub(crate) fn h_sve_ld1_imm_vl(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ld1(u.a, u.b, u.esize, u.c, SveMemOff::ImmVl(u.imm), u.has(F_FF))
}

pub(crate) fn h_sve_ld1_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ld1(u.a, u.b, u.esize, u.c, SveMemOff::RegScaled(u.d), u.has(F_FF))
}

pub(crate) fn h_sve_ld1r(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ld1r(u.a, u.b, u.esize, u.c, u.imm)
}

pub(crate) fn h_sve_st1_imm_vl(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_st1(u.a, u.b, u.esize, u.c, SveMemOff::ImmVl(u.imm))
}

pub(crate) fn h_sve_st1_reg(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_st1(u.a, u.b, u.esize, u.c, SveMemOff::RegScaled(u.d))
}

pub(crate) fn h_sve_gather_vec_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_gather(u.a, u.b, u.esize, GatherAddr::VecImm(u.c, u.imm), u.has(F_FF))
}

pub(crate) fn h_sve_gather_base_vec(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = GatherAddr::BaseVec { xn: u.c, zm: u.d, scaled: u.has(F_SCALED) };
    ex.sve_gather(u.a, u.b, u.esize, addr, u.has(F_FF))
}

pub(crate) fn h_sve_scatter_vec_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_scatter(u.a, u.b, u.esize, GatherAddr::VecImm(u.c, u.imm))
}

pub(crate) fn h_sve_scatter_base_vec(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = GatherAddr::BaseVec { xn: u.c, zm: u.d, scaled: u.has(F_SCALED) };
    ex.sve_scatter(u.a, u.b, u.esize, addr)
}

pub(crate) fn h_sve_int_bin(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_int_bin(u.sub.int(), u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_int_bin_u(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_int_bin_u(u.sub.int(), u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_add_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_add_imm(u.a, u.esize, u.imm as u64);
    Ok(())
}

pub(crate) fn h_sve_fp_bin(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fp_bin(u.sub.fp(), u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_fp_un(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fp_un(u.sub.fp_un(), u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_fmla(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fmla(u.a, u.b, u.c, u.d, u.dbl(), u.has(F_SUB));
    Ok(())
}

pub(crate) fn h_sve_scvtf(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_scvtf(u.a, u.b, u.c, u.dbl());
    Ok(())
}

// ---- dense fast-path twins (trace-engine specialized slots) ----
//
// Entered only behind a trace's per-iteration dense guard: the
// governing predicate (`u.b`) is all-true at the granule the guard
// checked, so predication folds away — bulk memory ops skip the prefix
// scan, arithmetic skips the per-lane `active` test. Semantics are
// otherwise identical to the general handlers above (pinned by the
// dense-vs-general tests in `exec/trace.rs` and the `exec/legacy.rs`
// three-way harness).

pub(crate) fn h_sve_ld1_imm_vl_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    let vlb = ex.state.vl_bytes();
    let baddr = ex.sve_contig_base(u.c, SveMemOff::ImmVl(u.imm), u.esize.bytes(), vlb);
    ex.sve_ld1_bulk(u.a, u.esize, baddr, u.esize.lanes(vlb), u.has(F_FF))
}

pub(crate) fn h_sve_ld1_reg_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    let vlb = ex.state.vl_bytes();
    let baddr = ex.sve_contig_base(u.c, SveMemOff::RegScaled(u.d), u.esize.bytes(), vlb);
    ex.sve_ld1_bulk(u.a, u.esize, baddr, u.esize.lanes(vlb), u.has(F_FF))
}

pub(crate) fn h_sve_st1_imm_vl_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    let vlb = ex.state.vl_bytes();
    let baddr = ex.sve_contig_base(u.c, SveMemOff::ImmVl(u.imm), u.esize.bytes(), vlb);
    ex.sve_st1_bulk(u.a, baddr, vlb) // all lanes active: the whole register
}

pub(crate) fn h_sve_st1_reg_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    let vlb = ex.state.vl_bytes();
    let baddr = ex.sve_contig_base(u.c, SveMemOff::RegScaled(u.d), u.esize.bytes(), vlb);
    ex.sve_st1_bulk(u.a, baddr, vlb)
}

pub(crate) fn h_sve_int_bin_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_int_bin_impl::<true>(u.sub.int(), u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_fp_bin_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fp_bin_impl::<true>(u.sub.fp(), u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_fp_un_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fp_un_impl::<true>(u.sub.fp_un(), u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_fmla_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fmla_impl::<true>(u.a, u.b, u.c, u.d, u.dbl(), u.has(F_SUB));
    Ok(())
}

pub(crate) fn h_sve_scvtf_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_scvtf_impl::<true>(u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_gather_vec_imm_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_gather_impl::<true>(u.a, u.b, u.esize, GatherAddr::VecImm(u.c, u.imm), u.has(F_FF))
}

pub(crate) fn h_sve_gather_base_vec_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = GatherAddr::BaseVec { xn: u.c, zm: u.d, scaled: u.has(F_SCALED) };
    ex.sve_gather_impl::<true>(u.a, u.b, u.esize, addr, u.has(F_FF))
}

pub(crate) fn h_sve_scatter_vec_imm_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_scatter_impl::<true>(u.a, u.b, u.esize, GatherAddr::VecImm(u.c, u.imm))
}

pub(crate) fn h_sve_scatter_base_vec_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    let addr = GatherAddr::BaseVec { xn: u.c, zm: u.d, scaled: u.has(F_SCALED) };
    ex.sve_scatter_impl::<true>(u.a, u.b, u.esize, addr)
}

pub(crate) fn h_sve_ld1r_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ld1r_impl::<true>(u.a, u.b, u.esize, u.c, u.imm)
}

pub(crate) fn h_cpy_x_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_cpy_x_impl::<true>(u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sel_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_sel_impl::<true>(u.a, u.b, u.c, u.d, u.esize);
    Ok(())
}

pub(crate) fn h_sve_reduce_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_reduce_impl::<true>(u.sub.red(), u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_fadda_dense(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fadda_impl::<true>(u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_int_cmp_z(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_int_cmp(u.sub.cmp(), u.has(F_UNSIGNED), u.a, u.b, u.c, ZmOrImm::Z(u.d), u.esize);
    Ok(())
}

pub(crate) fn h_sve_int_cmp_imm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_int_cmp(u.sub.cmp(), u.has(F_UNSIGNED), u.a, u.b, u.c, ZmOrImm::Imm(u.imm), u.esize);
    Ok(())
}

pub(crate) fn h_sve_fp_cmp_v(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fp_cmp(u.sub.cmp(), u.a, u.b, u.c, Some(u.d), u.dbl());
    Ok(())
}

pub(crate) fn h_sve_fp_cmp_0(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fp_cmp(u.sub.cmp(), u.a, u.b, u.c, None, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_reduce(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_reduce(u.sub.red(), u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_fadda(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_fadda(u.a, u.b, u.c, u.dbl());
    Ok(())
}

pub(crate) fn h_sve_rev(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_rev(u.a, u.b, u.esize);
    Ok(())
}

pub(crate) fn h_sve_ext(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_ext(u.a, u.c, u.imm as u8);
    Ok(())
}

pub(crate) fn h_sve_zip(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_zip(u.a, u.b, u.c, u.esize, u.has(F_HI));
    Ok(())
}

pub(crate) fn h_sve_uzp(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_uzp(u.a, u.b, u.c, u.esize, u.has(F_HI));
    Ok(())
}

pub(crate) fn h_sve_trn(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_trn(u.a, u.b, u.c, u.esize, u.has(F_HI));
    Ok(())
}

pub(crate) fn h_sve_tbl(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_tbl(u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_compact(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_compact(u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_sve_splice(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_splice(u.a, u.b, u.c, u.esize);
    Ok(())
}

pub(crate) fn h_cterm(ex: &mut Executor, u: &Uop) -> ExecResult {
    ex.sve_cterm(u.b, u.c, u.has(F_NE));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Cond;
    use crate::asm::Asm;
    use crate::exec::Trap;
    use crate::isa::{CmpOp, Inst};
    use crate::mem::{Memory, PAGE_SIZE};

    fn exec_with(vl: usize, mem: Memory, build: impl FnOnce(&mut Asm)) -> Executor {
        let mut a = Asm::new();
        build(&mut a);
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(vl, mem);
        ex.run(&p, 10_000_000).unwrap();
        ex
    }

    // ============ Fig. 2c: the paper's SVE daxpy, verbatim ============
    fn sve_daxpy_prog(x: u64, y: u64, a_addr: u64, n_addr: u64) -> crate::asm::Program {
        let mut asm = Asm::new();
        let a = &mut asm;
        a.push(Inst::MovImm { xd: 0, imm: x });
        a.push(Inst::MovImm { xd: 1, imm: y });
        a.push(Inst::MovImm { xd: 2, imm: a_addr });
        a.push(Inst::MovImm { xd: 3, imm: n_addr });
        // ldrsw x3, [x3]
        let off = crate::isa::MemOff::Imm(0);
        a.push(Inst::Ldr { size: 4, signed: true, xt: 3, base: 3, off });
        // mov x4, #0
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        // whilelt p0.d, x4, x3
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        // ld1rd z0.d, p0/z, [x2]
        a.push(Inst::SveLd1R { zt: 0, pg: 0, esize: Esize::D, base: 2, imm: 0 });
        a.label("loop");
        // ld1d z1.d, p0/z, [x0, x4, lsl #3]
        a.push(Inst::SveLd1 {
            zt: 1,
            pg: 0,
            esize: Esize::D,
            base: 0,
            off: SveMemOff::RegScaled(4),
            ff: false,
        });
        a.push(Inst::SveLd1 {
            zt: 2,
            pg: 0,
            esize: Esize::D,
            base: 1,
            off: SveMemOff::RegScaled(4),
            ff: false,
        });
        // fmla z2.d, p0/m, z1.d, z0.d
        a.push(Inst::SveFmla { zda: 2, pg: 0, zn: 1, zm: 0, dbl: true, sub: false });
        // st1d z2.d, p0, [x1, x4, lsl #3]
        a.push(Inst::SveSt1 {
            zt: 2,
            pg: 0,
            esize: Esize::D,
            base: 1,
            off: SveMemOff::RegScaled(4),
        });
        // incd x4
        a.push(Inst::IncDec { xdn: 4, esize: Esize::D, dec: false });
        // whilelt p0.d, x4, x3
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        // b.first loop
        a.push_branch(Inst::BCond { cond: Cond::FIRST, target: 0 }, "loop");
        a.push(Inst::Halt);
        asm.finish()
    }

    fn daxpy_at_vl(vl: usize, n: usize) {
        let mut mem = Memory::new();
        let x = mem.alloc(8 * n.max(1) as u64, 16);
        let y = mem.alloc(8 * n.max(1) as u64, 16);
        let a_addr = mem.alloc(8, 8);
        let n_addr = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(x + 8 * i as u64, 0.5 * i as f64).unwrap();
            mem.write_f64(y + 8 * i as u64, 100.0 - i as f64).unwrap();
        }
        mem.write_f64(a_addr, 2.5).unwrap();
        mem.write_u32(n_addr, n as u32).unwrap();
        let p = sve_daxpy_prog(x, y, a_addr, n_addr);
        let mut ex = Executor::new(vl, mem);
        ex.run(&p, 10_000_000).unwrap();
        for i in 0..n {
            let want = 2.5 * (0.5 * i as f64) + (100.0 - i as f64);
            assert_eq!(ex.mem.read_f64(y + 8 * i as u64).unwrap(), want, "vl={vl} y[{i}]");
        }
    }

    #[test]
    fn fig2c_daxpy_all_vector_lengths_vla() {
        // §2.2 — the same binary must run correctly at every legal VL
        for vl in [128, 256, 384, 512, 1024, 2048] {
            daxpy_at_vl(vl, 100);
        }
    }

    #[test]
    fn fig2c_daxpy_awkward_trip_counts() {
        for n in [0, 1, 3, 31, 32, 33] {
            daxpy_at_vl(256, n);
        }
    }

    #[test]
    fn whilelt_prefix_and_flags() {
        // VL=256 -> 4 .d lanes
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 0 });
            a.push(Inst::MovImm { xd: 1, imm: 3 });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 0, xm: 1, unsigned: false });
        });
        let p = ex.state.p[0];
        assert!(p.active(Esize::D, 0) && p.active(Esize::D, 1) && p.active(Esize::D, 2));
        assert!(!p.active(Esize::D, 3));
        // partial: First=1 (N), any active (Z=0), last inactive (C=1)
        assert!(ex.state.flags.n && !ex.state.flags.z && ex.state.flags.c);
    }

    #[test]
    fn whilelt_empty_sets_none() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 8 });
            a.push(Inst::MovImm { xd: 1, imm: 3 });
            a.push(Inst::While { pd: 1, esize: Esize::D, xn: 0, xm: 1, unsigned: false });
        });
        assert!(ex.state.p[1].none_active(Esize::D, 32));
        assert!(ex.state.flags.z, "Z=None per Table 1");
        assert!(!ex.state.flags.cond(Cond::FIRST), "b.first must fall through");
    }

    #[test]
    fn whilelt_handles_wraparound_near_int_max() {
        // §2.3.2: "if the loop counter is close to the maximum integer
        // value, then while will handle potential wrap-around"
        let ex = exec_with(512, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: (i64::MAX - 2) as u64 });
            a.push(Inst::MovImm { xd: 1, imm: i64::MAX as u64 });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 0, xm: 1, unsigned: false });
        });
        let p = ex.state.p[0];
        // exactly 2 iterations remain; lanes 2.. must NOT wrap to active
        assert!(p.active(Esize::D, 0) && p.active(Esize::D, 1));
        for i in 2..8 {
            assert!(!p.active(Esize::D, i), "lane {i} wrapped");
        }
    }

    #[test]
    fn first_fault_load_partitions_ffr() {
        // Fig. 4 behaviour with a contiguous ldff1b across a page hole
        let mut mem = Memory::new();
        let page = 0x10_000u64;
        mem.map(page, PAGE_SIZE as u64); // next page unmapped
        let start = page + PAGE_SIZE as u64 - 8; // 8 valid bytes, then hole
        for k in 0..8 {
            mem.write_byte(start + k, b'A' + k as u8).unwrap();
        }
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: start });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::B, s: false });
            a.push(Inst::Setffr);
            a.push(Inst::SveLd1 {
                zt: 0,
                pg: 0,
                esize: Esize::B,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: true,
            });
            a.push(Inst::Rdffr { pd: 1, pg: Some(0), s: false });
        });
        // 32 byte lanes; lanes 0..8 loaded, 8.. cleared in FFR
        for i in 0..8 {
            assert!(ex.state.p[1].active(Esize::B, i), "lane {i} safe");
            assert_eq!(ex.state.z[0].get(Esize::B, i), (b'A' + i as u8) as u64);
        }
        for i in 8..32 {
            assert!(!ex.state.p[1].active(Esize::B, i), "lane {i} must be cleared");
        }
    }

    #[test]
    fn first_fault_on_first_active_element_traps() {
        // §2.3.3: "since it is now the first active element, traps"
        let mem = Memory::new(); // nothing mapped
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 0x5000 });
        a.push(Inst::Ptrue { pd: 0, esize: Esize::B, s: false });
        a.push(Inst::Setffr);
        a.push(Inst::SveLd1 {
            zt: 0,
            pg: 0,
            esize: Esize::B,
            base: 0,
            off: SveMemOff::ImmVl(0),
            ff: true,
        });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, Memory::new());
        ex.mem = mem;
        match ex.run(&p, 100) {
            Err(Trap::Fault { fault, .. }) => assert_eq!(fault.addr, 0x5000),
            other => panic!("expected trap, got {other:?}"),
        }
    }

    #[test]
    fn gather_first_fault_fig4() {
        // Fig. 4: gather from addresses [ok, ok, bad, bad]
        let mut mem = Memory::new();
        let good = 0x20_000u64;
        mem.map(good, 64);
        mem.write_u64(good, 111).unwrap();
        mem.write_u64(good + 8, 222).unwrap();
        let bad = 0x90_000u64;
        let addrs = mem.alloc(32, 8);
        mem.write_u64_slice(addrs, &[good, good + 8, bad, bad + 8]);
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 1, imm: addrs });
            a.push(Inst::Ptrue { pd: 1, esize: Esize::D, s: false });
            a.push(Inst::SveLd1 {
                zt: 3,
                pg: 1,
                esize: Esize::D,
                base: 1,
                off: SveMemOff::ImmVl(0),
                ff: false,
            });
            a.push(Inst::Setffr);
            a.push(Inst::SveLdGather {
                zt: 0,
                pg: 1,
                esize: Esize::D,
                addr: GatherAddr::VecImm(3, 0),
                ff: true,
            });
            a.push(Inst::Rdffr { pd: 2, pg: Some(1), s: false });
        });
        assert_eq!(ex.state.z[0].get(Esize::D, 0), 111);
        assert_eq!(ex.state.z[0].get(Esize::D, 1), 222);
        let ffr = ex.state.p[2];
        assert!(ffr.active(Esize::D, 0) && ffr.active(Esize::D, 1));
        assert!(!ffr.active(Esize::D, 2) && !ffr.active(Esize::D, 3), "Fig. 4 FFR");
    }

    #[test]
    fn brkb_builds_before_break_partition() {
        // p2 = lanes strictly before the first zero-char (Fig. 5)
        let ex = exec_with(128, Memory::new(), |a| {
            a.push(Inst::Ptrue { pd: 0, esize: Esize::B, s: false });
            // z0 = [5,5,5,0,5,...] via index+cmp trick: build with dup + insert
            a.push(Inst::DupImm { zd: 0, esize: Esize::B, imm: 5 });
            a.push(Inst::Index {
                zd: 1,
                esize: Esize::B,
                base: RegOrImm::Imm(0),
                step: RegOrImm::Imm(1),
            });
            // p1 = (z1 == 3)  -> lane 3
            a.push(Inst::SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 1,
                pg: 0,
                zn: 1,
                rhs: ZmOrImm::Imm(3),
                esize: Esize::B,
            });
            // brkbs p2.b, p0/z, p1.b
            a.push(Inst::Brk { pd: 2, pg: 0, pn: 1, before: true, s: true });
        });
        for i in 0..3 {
            assert!(ex.state.p[2].active(Esize::B, i), "lane {i}");
        }
        for i in 3..16 {
            assert!(!ex.state.p[2].active(Esize::B, i), "lane {i}");
        }
        // break found -> last lane of pg inactive in result -> C=1 -> b.last
        // (LAST==LO==!C) falls through, exactly Fig. 5's loop exit
        assert!(!ex.state.flags.cond(Cond::LAST));
    }

    #[test]
    fn brkb_no_break_keeps_all_and_continues_loop() {
        let ex = exec_with(128, Memory::new(), |a| {
            a.push(Inst::Ptrue { pd: 0, esize: Esize::B, s: false });
            a.push(Inst::Pfalse { pd: 1 });
            a.push(Inst::Brk { pd: 2, pg: 0, pn: 1, before: true, s: true });
        });
        assert_eq!(ex.state.p[2].count_active(Esize::B, 16), 16);
        assert!(ex.state.flags.cond(Cond::LAST), "no break -> b.last loops");
    }

    #[test]
    fn brka_includes_break_element() {
        let ex = exec_with(128, Memory::new(), |a| {
            a.push(Inst::Ptrue { pd: 0, esize: Esize::B, s: false });
            a.push(Inst::Index {
                zd: 1,
                esize: Esize::B,
                base: RegOrImm::Imm(0),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 1,
                pg: 0,
                zn: 1,
                rhs: ZmOrImm::Imm(5),
                esize: Esize::B,
            });
            a.push(Inst::Brk { pd: 2, pg: 0, pn: 1, before: false, s: false });
        });
        assert_eq!(ex.state.p[2].count_active(Esize::B, 16), 6, "lanes 0..=5");
    }

    #[test]
    fn pnext_walks_active_elements_in_order() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 1 });
            a.push(Inst::MovImm { xd: 1, imm: 4 });
            // pg = lanes 1..4 of .d
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 31, xm: 1, unsigned: false });
            a.push(Inst::While { pd: 2, esize: Esize::D, xn: 31, xm: 0, unsigned: false });
            // pg := p0 AND NOT p2 = lanes 1,2,3
            a.push(Inst::PredLogic { op: PLogicOp::Bic, pd: 0, pg: 0, pn: 0, pm: 2, s: false });
            a.push(Inst::Pfalse { pd: 1 });
            a.push(Inst::Pnext { pdn: 1, pg: 0, esize: Esize::D });
        });
        assert!(ex.state.p[1].active(Esize::D, 1), "first active of pg");
        assert_eq!(ex.state.p[1].count_active(Esize::D, 32), 1);
    }

    #[test]
    fn pnext_exhaustion_sets_none() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Pfalse { pd: 0 }); // empty pg
            a.push(Inst::Pfalse { pd: 1 });
            a.push(Inst::Pnext { pdn: 1, pg: 0, esize: Esize::D });
        });
        assert!(ex.state.p[1].none_active(Esize::D, 32));
        assert!(ex.state.flags.z);
    }

    #[test]
    fn cterm_drives_tcont() {
        // continue: not-equal and C set
        let ex = exec_with(128, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 7 });
            a.push(Inst::MovImm { xd: 1, imm: 9 });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: true }); // sets C=0 (all active)
            // force C=1 via whilelt partial
            a.push(Inst::MovImm { xd: 2, imm: 1 });
            a.push(Inst::While { pd: 1, esize: Esize::D, xn: 31, xm: 2, unsigned: false });
            a.push(Inst::Cterm { xn: 0, xm: 1, ne: false });
        });
        assert!(ex.state.flags.cond(Cond::TCONT), "!term && C -> continue");

        // stop on termination (equal)
        let ex = exec_with(128, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 7 });
            a.push(Inst::MovImm { xd: 1, imm: 7 });
            a.push(Inst::Cterm { xn: 0, xm: 1, ne: false });
        });
        assert!(!ex.state.flags.cond(Cond::TCONT));
    }

    #[test]
    fn incp_counts_active_lanes() {
        let ex = exec_with(512, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 100 });
            a.push(Inst::MovImm { xd: 1, imm: 5 });
            a.push(Inst::While { pd: 3, esize: Esize::D, xn: 31, xm: 1, unsigned: false });
            a.push(Inst::IncpX { xdn: 0, pm: 3, esize: Esize::D });
        });
        assert_eq!(ex.state.get_x(0), 105);
    }

    #[test]
    fn index_and_vl_scaled_counting() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Index {
                zd: 0,
                esize: Esize::S,
                base: RegOrImm::Imm(3),
                step: RegOrImm::Imm(2),
            });
            a.push(Inst::Cnt { xd: 1, esize: Esize::D });
            a.push(Inst::MovImm { xd: 2, imm: 0 });
            a.push(Inst::IncDec { xdn: 2, esize: Esize::S, dec: false });
        });
        for i in 0..8 {
            assert_eq!(ex.state.z[0].get(Esize::S, i), 3 + 2 * i as u64);
        }
        assert_eq!(ex.state.get_x(1), 4, "cntd at VL=256");
        assert_eq!(ex.state.get_x(2), 8, "incw at VL=256");
    }

    #[test]
    fn fadda_is_strictly_ordered_faddv_is_tree() {
        // values chosen so that tree and ordered sums differ in f64
        let mut mem = Memory::new();
        let buf = mem.alloc(8 * 4, 16);
        let xs = [1e308, -1e308, 1.0, 1.0];
        mem.write_f64_slice(buf, &xs);
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: buf });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::SveLd1 {
                zt: 0,
                pg: 0,
                esize: Esize::D,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: false,
            });
            a.push(Inst::FmovImm { dbl: true, dd: 1, bits: 0f64.to_bits() });
            a.push(Inst::SveFadda { vdn: 1, pg: 0, zm: 0, dbl: true });
            a.push(Inst::SveReduce { op: RedOp::FAddV, vd: 2, pg: 0, zn: 0, esize: Esize::D });
        });
        let ordered = (((0.0 + xs[0]) + xs[1]) + xs[2]) + xs[3];
        let tree = (xs[0] + xs[2]) + (xs[1] + xs[3]); // pairwise halves
        assert_eq!(ex.state.get_d(1), ordered, "fadda == scalar loop order");
        assert_eq!(ex.state.get_d(2), tree, "faddv == pairwise tree");
        assert_ne!(ordered, tree, "orders must differ for this input (§3.3)");
    }

    #[test]
    fn eorv_reduction() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Index {
                zd: 0,
                esize: Esize::D,
                base: RegOrImm::Imm(1),
                step: RegOrImm::Imm(2),
            });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::SveReduce { op: RedOp::EorV, vd: 1, pg: 0, zn: 0, esize: Esize::D });
        });
        assert_eq!(ex.state.z[1].get(Esize::D, 0), 1u64 ^ 3 ^ 5 ^ 7);
    }

    #[test]
    fn predicated_fmla_merges_inactive_lanes() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::MovImm { xd: 0, imm: 2 });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 31, xm: 0, unsigned: false });
            a.push(Inst::DupImm { zd: 0, esize: Esize::D, imm: 0 });
            a.push(Inst::SveScvtf { zd: 0, pg: 0, zn: 0, dbl: true }); // zeros
            a.push(Inst::FdupImm { zd: 1, dbl: true, bits: 2.0f64.to_bits() });
            a.push(Inst::FdupImm { zd: 2, dbl: true, bits: 3.0f64.to_bits() });
            a.push(Inst::FdupImm { zd: 3, dbl: true, bits: 10.0f64.to_bits() });
            a.push(Inst::SveFmla { zda: 3, pg: 0, zn: 1, zm: 2, dbl: true, sub: false });
        });
        assert_eq!(ex.state.z[3].get_f64(0), 16.0);
        assert_eq!(ex.state.z[3].get_f64(1), 16.0);
        assert_eq!(ex.state.z[3].get_f64(2), 10.0, "inactive lane merges");
        assert_eq!(ex.state.z[3].get_f64(3), 10.0);
    }

    #[test]
    fn sel_and_fcm_ifconversion_pattern() {
        // the HACC conditional-assignment pattern: p = (a > b); sel
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::Index {
                zd: 0,
                esize: Esize::D,
                base: RegOrImm::Imm(0),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::SveScvtf { zd: 0, pg: 0, zn: 0, dbl: true }); // [0,1,2,3]
            a.push(Inst::FdupImm { zd: 1, dbl: true, bits: 1.5f64.to_bits() });
            a.push(Inst::SveFpCmp {
                op: CmpOp::Gt,
                pd: 1,
                pg: 0,
                zn: 0,
                rhs: Some(1),
                dbl: true,
            });
            a.push(Inst::Sel { zd: 2, pg: 1, zn: 0, zm: 1, esize: Esize::D });
        });
        assert_eq!(ex.state.z[2].get_f64(0), 1.5);
        assert_eq!(ex.state.z[2].get_f64(1), 1.5);
        assert_eq!(ex.state.z[2].get_f64(2), 2.0);
        assert_eq!(ex.state.z[2].get_f64(3), 3.0);
    }

    #[test]
    fn permutes_rev_zip_compact() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Index {
                zd: 0,
                esize: Esize::D,
                base: RegOrImm::Imm(0),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::SveRev { zd: 1, zn: 0, esize: Esize::D });
            a.push(Inst::Index {
                zd: 2,
                esize: Esize::D,
                base: RegOrImm::Imm(10),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::SveZip { zd: 3, zn: 0, zm: 2, esize: Esize::D, hi: false });
            // compact even lanes
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 1,
                pg: 0,
                zn: 0,
                rhs: ZmOrImm::Imm(0),
                esize: Esize::D,
            });
            // p1 = lane0 only; orr with lane2-compare for [0,2]
            a.push(Inst::SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 2,
                pg: 0,
                zn: 0,
                rhs: ZmOrImm::Imm(2),
                esize: Esize::D,
            });
            a.push(Inst::PredLogic { op: PLogicOp::Orr, pd: 1, pg: 0, pn: 1, pm: 2, s: false });
            a.push(Inst::SveCompact { zd: 4, pg: 1, zn: 0, esize: Esize::D });
        });
        assert_eq!(ex.state.z[1].get(Esize::D, 0), 3);
        assert_eq!(ex.state.z[1].get(Esize::D, 3), 0);
        assert_eq!(ex.state.z[3].get(Esize::D, 0), 0);
        assert_eq!(ex.state.z[3].get(Esize::D, 1), 10);
        assert_eq!(ex.state.z[3].get(Esize::D, 2), 1);
        assert_eq!(ex.state.z[4].get(Esize::D, 0), 0);
        assert_eq!(ex.state.z[4].get(Esize::D, 1), 2);
        assert_eq!(ex.state.z[4].get(Esize::D, 2), 0, "compact zero-fills");
    }

    #[test]
    fn scatter_store_writes_elementwise() {
        let mut mem = Memory::new();
        let tgt = mem.alloc(256, 8);
        let idx = mem.alloc(32, 8);
        mem.write_u64_slice(idx, &[3, 0, 2, 1]);
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: idx });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::SveLd1 {
                zt: 1,
                pg: 0,
                esize: Esize::D,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: false,
            });
            a.push(Inst::Index {
                zd: 2,
                esize: Esize::D,
                base: RegOrImm::Imm(100),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::MovImm { xd: 1, imm: tgt });
            a.push(Inst::SveStScatter {
                zt: 2,
                pg: 0,
                esize: Esize::D,
                addr: GatherAddr::BaseVec { xn: 1, zm: 1, scaled: true },
            });
        });
        assert_eq!(ex.mem.read_u64(tgt + 24).unwrap(), 100);
        assert_eq!(ex.mem.read_u64(tgt).unwrap(), 101);
        assert_eq!(ex.mem.read_u64(tgt + 16).unwrap(), 102);
        assert_eq!(ex.mem.read_u64(tgt + 8).unwrap(), 103);
    }

    #[test]
    fn ld1_zeroing_predication() {
        let mut mem = Memory::new();
        let b = mem.alloc(32, 16);
        mem.write_u64_slice(b, &[11, 22, 33, 44]);
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: b });
            a.push(Inst::MovImm { xd: 1, imm: 2 });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 31, xm: 1, unsigned: false });
            a.push(Inst::DupImm { zd: 0, esize: Esize::D, imm: -1 }); // dirty
            a.push(Inst::SveLd1 {
                zt: 0,
                pg: 0,
                esize: Esize::D,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: false,
            });
        });
        assert_eq!(ex.state.z[0].get(Esize::D, 0), 11);
        assert_eq!(ex.state.z[0].get(Esize::D, 1), 22);
        assert_eq!(ex.state.z[0].get(Esize::D, 2), 0, "/z zeroes inactive");
        assert_eq!(ex.state.z[0].get(Esize::D, 3), 0);
    }

    #[test]
    fn movprfx_constructive_pair() {
        // §4: movprfx + destructive op == constructive op
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::FdupImm { zd: 1, dbl: true, bits: 5.0f64.to_bits() });
            a.push(Inst::FdupImm { zd: 2, dbl: true, bits: 7.0f64.to_bits() });
            a.push(Inst::Movprfx { zd: 3, zn: 1, pg: None });
            a.push(Inst::SveFpBin { op: FpOp::Add, zdn: 3, pg: 0, zm: 2, dbl: true });
        });
        assert_eq!(ex.state.z[3].get_f64(0), 12.0);
        assert_eq!(ex.state.z[1].get_f64(0), 5.0, "source unchanged (constructive)");
    }

    // ============ software-TLB / bulk-path regression tests ============

    #[test]
    fn tlb_invalidated_after_unmap_page() {
        let mut mem = Memory::new();
        let page = 0x40_000u64;
        mem.map(page, PAGE_SIZE as u64);
        mem.write_u64(page, 77).unwrap();
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: page });
        a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
        a.push(Inst::SveLd1 {
            zt: 0,
            pg: 0,
            esize: Esize::D,
            base: 0,
            off: SveMemOff::ImmVl(0),
            ff: false,
        });
        a.push(Inst::Halt);
        let p = a.finish();
        let mut ex = Executor::new(128, mem);
        ex.run(&p, 100).unwrap(); // warms the TLB entry for `page`
        assert_eq!(ex.state.z[0].get(Esize::D, 0), 77);
        // unmap must invalidate the cached translation
        ex.mem.unmap_page(page);
        ex.halted = false;
        ex.state.pc = 0;
        match ex.run(&p, 100) {
            Err(Trap::Fault { fault, .. }) => assert_eq!(fault.addr, page),
            other => panic!("expected fault after unmap, got {other:?}"),
        }
        // and a remap must resolve to the fresh (zeroed) page
        ex.mem.map(page, PAGE_SIZE as u64);
        ex.halted = false;
        ex.state.pc = 0;
        ex.run(&p, 100).unwrap();
        assert_eq!(ex.state.z[0].get(Esize::D, 0), 0, "remapped page is zeroed");
    }

    #[test]
    fn tlb_cross_page_contiguous_load_and_store() {
        let mut mem = Memory::new();
        let base = 0x10_000u64;
        mem.map(base, 2 * PAGE_SIZE as u64);
        let start = base + PAGE_SIZE as u64 - 16; // spans both pages
        for k in 0..32u64 {
            mem.write_byte(start + k, k as u8 + 1).unwrap();
        }
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: start });
            a.push(Inst::MovImm { xd: 1, imm: start + 32 });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::B, s: false });
            a.push(Inst::SveLd1 {
                zt: 0,
                pg: 0,
                esize: Esize::B,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: false,
            });
            a.push(Inst::SveSt1 {
                zt: 0,
                pg: 0,
                esize: Esize::B,
                base: 1,
                off: SveMemOff::ImmVl(0),
            });
        });
        for k in 0..32u64 {
            assert_eq!(ex.state.z[0].get(Esize::B, k as usize), k + 1, "lane {k}");
            assert_eq!(ex.mem.read_byte(start + 32 + k).unwrap(), k as u8 + 1, "stored {k}");
        }
    }

    #[test]
    fn sparse_predicate_load_skips_unmapped_inactive_lanes() {
        // non-prefix predicate -> element-at-a-time path: inactive lanes
        // never touch memory even if their addresses are unmapped
        let mut mem = Memory::new();
        let page = 0x60_000u64;
        mem.map(page, PAGE_SIZE as u64);
        let start = page + PAGE_SIZE as u64 - 16; // lanes 0..2 mapped, 2.. not
        mem.write_u64(start, 10).unwrap();
        mem.write_u64(start + 8, 20).unwrap();
        let ex = exec_with(256, mem, |a| {
            a.push(Inst::MovImm { xd: 0, imm: start });
            a.push(Inst::Ptrue { pd: 0, esize: Esize::D, s: false });
            a.push(Inst::Index {
                zd: 1,
                esize: Esize::D,
                base: RegOrImm::Imm(0),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 1,
                pg: 0,
                zn: 1,
                rhs: ZmOrImm::Imm(1),
                esize: Esize::D,
            });
            a.push(Inst::SveLd1 {
                zt: 0,
                pg: 1,
                esize: Esize::D,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: false,
            });
        });
        assert_eq!(ex.state.z[0].get(Esize::D, 0), 0, "inactive lane zeroed");
        assert_eq!(ex.state.z[0].get(Esize::D, 1), 20);
        assert_eq!(ex.state.z[0].get(Esize::D, 2), 0, "unmapped inactive lane skipped");
    }

    #[test]
    fn prop_first_fault_ffr_matches_per_lane_reference() {
        use crate::proptest_lite::check;
        check("prop_first_fault_ffr_matches_per_lane_reference", 60, |g| {
            let vl = *g.choose(&[128usize, 256, 512, 2048]);
            let esize = *g.choose(&Esize::ALL);
            let vlb = vl / 8;
            let lanes = esize.lanes(vlb);
            // one mapped page followed by a hole
            let page = 0x80_000u64;
            let mut mem = Memory::new();
            mem.map(page, PAGE_SIZE as u64);
            for i in 0..PAGE_SIZE as u64 {
                mem.write_byte(page + i, (i % 251) as u8).unwrap();
            }
            // random start near (possibly at/after) the end of the page
            let back = g.usize_in(0, 2 * vlb) as u64;
            let start = page + PAGE_SIZE as u64 - back;
            // prefix predicate of random length via whilelt
            let k = g.usize_in(0, lanes);
            let mut a = Asm::new();
            a.push(Inst::MovImm { xd: 0, imm: start });
            a.push(Inst::MovImm { xd: 1, imm: 0 });
            a.push(Inst::MovImm { xd: 2, imm: k as u64 });
            a.push(Inst::While { pd: 0, esize, xn: 1, xm: 2, unsigned: false });
            a.push(Inst::Setffr);
            a.push(Inst::SveLd1 {
                zt: 0,
                pg: 0,
                esize,
                base: 0,
                off: SveMemOff::ImmVl(0),
                ff: true,
            });
            a.push(Inst::Halt);
            let p = a.finish();
            let mut ex = Executor::new(vl, mem.clone());
            let result = ex.run(&p, 100);
            // reference: the per-lane walk §2.3.3 describes
            let mapped_until = page + PAGE_SIZE as u64;
            let elem_ok = |i: usize| start + ((i + 1) * esize.bytes()) as u64 <= mapped_until;
            let expect_trap = k > 0 && !elem_ok(0); // first active element faults
            match result {
                Err(Trap::Fault { .. }) => {
                    assert!(expect_trap, "unexpected trap (vl={vl} k={k} back={back})");
                }
                Ok(_) => {
                    assert!(!expect_trap, "missed trap (vl={vl} k={k} back={back})");
                    let fl = (0..k).find(|&i| !elem_ok(i));
                    let safe = fl.unwrap_or(k);
                    for i in 0..safe {
                        let addr = start + (i * esize.bytes()) as u64;
                        let want = mem.read(addr, esize.bytes()).unwrap();
                        assert_eq!(ex.state.z[0].get(esize, i), want, "lane {i}");
                        assert!(ex.state.ffr.active(esize, i), "ffr keeps lane {i}");
                    }
                    for i in safe..lanes {
                        if fl.is_some() {
                            assert!(!ex.state.ffr.active(esize, i), "ffr cleared at lane {i}");
                        }
                        assert_eq!(ex.state.z[0].get(esize, i), 0, "zeroing at lane {i}");
                    }
                }
                Err(other) => panic!("unexpected {other:?}"),
            }
        });
    }

    #[test]
    fn lastb_extracts_last_active() {
        let ex = exec_with(256, Memory::new(), |a| {
            a.push(Inst::Index {
                zd: 0,
                esize: Esize::D,
                base: RegOrImm::Imm(40),
                step: RegOrImm::Imm(1),
            });
            a.push(Inst::MovImm { xd: 1, imm: 3 });
            a.push(Inst::While { pd: 0, esize: Esize::D, xn: 31, xm: 1, unsigned: false });
            a.push(Inst::Last { xd: 2, pg: 0, zn: 0, esize: Esize::D, before: true });
            a.push(Inst::Last { xd: 3, pg: 0, zn: 0, esize: Esize::D, before: false });
        });
        assert_eq!(ex.state.get_x(2), 42, "lastb: lane 2");
        assert_eq!(ex.state.get_x(3), 43, "lasta: lane 3");
    }
}
