//! Superblock trace cache: profile-guided straight-line execution over
//! pre-decoded µop programs.
//!
//! The paper's VL-agnostic loops (Fig. 2/8) spend their lives in a tiny
//! `whilelt`-governed steady state executed millions of times, yet the
//! baseline interpreter pays tag-indexed dispatch, branch resolution and
//! predicate re-derivation on every dynamic µop. [`TraceEngine`] removes
//! that overhead in three layers:
//!
//! 1. **Threaded dispatch** — every µop slot carries its handler
//!    pointer, pre-fetched from [`super::DISPATCH`] when the engine is
//!    built, so the interpreter loop is `(slot.h)(ex, &slot.u)` with no
//!    per-retire bounds check or tag load.
//! 2. **Superblock traces** — block entry pcs are profiled; once an
//!    entry crosses [`HOT_THRESHOLD`], the next execution records the
//!    dominant path (following taken/not-taken history through
//!    conditional branches, ending where the path returns to the entry,
//!    takes a backward branch elsewhere, or halts) and stitches it into
//!    a straight-line trace. Control µops keep **side-exit guards**: if
//!    a branch resolves off the recorded path, the engine writes back
//!    the true pc and falls back to the block interpreter — which is
//!    bit-identical by construction, since both run the same handlers
//!    in the same order.
//! 3. **Dense `whilelt` specialization** — when a trace is governed by
//!    a `whilelt` predicate that is provably all-true for the iteration
//!    (dense prefix covering every lane), the µops it governs run
//!    **unpredicated fast-path twins** (contiguous ld1/st1,
//!    gather/scatter, broadcast/select setup ops, reductions and
//!    arithmetic; see `exec/sve.rs`'s `DENSE` monomorphizations) behind
//!    a single per-iteration predicate check. Tail iterations fail the
//!    check and take the general (predicated) slots of the same trace.
//! 4. **Trace linking** — when a completed non-looping trace falls
//!    through to a pc that is itself a built trace entry, a patched
//!    trace→trace link jumps straight to it, so steady-state loop nests
//!    (outer-close → outer-head → inner-loop chains) never return to
//!    the block interpreter. Links cache the engine epoch they were
//!    resolved at; any cache mutation ([`TraceEngine::invalidate`] or a
//!    new install) advances the epoch and forces re-resolution, and the
//!    per-trace budget gate is identical to the front door's, so side
//!    exits and exact trip counts are preserved across link jumps.
//!
//! Formation failures (halting or over-long paths) are **deferred**,
//! not permanently rejected: the entry's heat decays to zero and it may
//! re-earn a recording against an exponentially backed-off threshold,
//! up to [`MAX_RECORD_ATTEMPTS`] — a loop whose early iterations looked
//! megamorphic can still earn a trace, while a genuinely irreducible
//! body hard-stops after the cap. Per-run telemetry (traces built /
//! rejected / re-recorded, link jumps, dense vs general iterations) is
//! exported through [`RunStats::trace`] and `sve run --trace-stats`.
//!
//! Architectural state, the retire stream ([`StepInfo`]) and every
//! counter the job store consumes are bit-identical to
//! [`Executor::run_decoded_with`] — pinned by the three-way harness in
//! `exec/legacy.rs` and the trap/side-exit tests below — so job cache
//! keys, fig8/dse goldens and the timing pipeline are untouched.

use super::{Executor, Handler, RunStats, StepInfo, Trap, DISPATCH};
use crate::arch::Esize;
use crate::isa::uop::{DecodedProgram, Uop, UopTag};
use std::cell::Cell;

/// Block-entry executions before a trace is recorded.
pub const HOT_THRESHOLD: u32 = 32;

/// Longest recordable path, in µops. A recording that exceeds this is
/// abandoned and the entry is deferred (see [`MAX_RECORD_ATTEMPTS`]).
pub const MAX_TRACE_LEN: usize = 256;

/// Recording attempts per entry before it is rejected for good. Each
/// failure decays the entry's heat to zero and doubles the threshold it
/// must re-earn, so megamorphic-looking warmup gets bounded retries
/// while irreducible bodies stay on the block interpreter.
pub const MAX_RECORD_ATTEMPTS: u8 = 3;

/// Per-run trace-cache telemetry, carried on [`RunStats::trace`].
///
/// This is engine-local observability — **not** architectural state or
/// a retire-stream counter. The baseline interpreter and the legacy
/// harness always report it as zero, so it is deliberately excluded
/// from [`RunStats`] equality (see the manual `PartialEq` there): the
/// bit-identity walls compare what the paper's contract pins, and perf
/// claims read these fields instead of being inferred.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Traces stitched and cached.
    pub built: u64,
    /// Recordings abandoned (halting or over-long path).
    pub rejected: u64,
    /// Recordings re-attempted for an entry that had failed before.
    pub rerecorded: u64,
    /// Direct trace→trace transfers that skipped the front door.
    pub link_jumps: u64,
    /// Trace iterations run on the dense (unpredicated-twin) slots.
    pub dense_iters: u64,
    /// Trace iterations run on the general (predicated) slots.
    pub general_iters: u64,
}

/// One threaded µop slot: the handler pointer lives next to the operand
/// fields it consumes, so cold execution pays no dispatch-table load.
#[derive(Clone, Copy)]
struct CodeSlot {
    h: Handler,
    u: Uop,
}

/// One stitched trace slot: threaded handler (possibly a dense twin),
/// the µop, its pc, and — for control µops — the recorded successor the
/// side-exit guard compares against.
#[derive(Clone, Copy)]
struct TSlot {
    h: Handler,
    u: Uop,
    pc: u32,
    /// Recorded next pc (control µops only; fall-through otherwise).
    next: u32,
    /// Needs a side-exit guard (B/BCond/Cbz/Cbnz).
    ctrl: bool,
}

/// A stitched superblock.
struct Trace {
    /// The general (predicated) path.
    slots: Box<[TSlot]>,
    /// Dense-specialized twin of `slots` (same µops, unpredicated
    /// fast-path handlers), present when a `whilelt` governs the body.
    dense: Option<Box<[TSlot]>>,
    /// Predicate register and granule the dense guard checks.
    guard_pd: u8,
    guard_esize: Esize,
    entry: u32,
    /// Where a completed non-looping trace resumes.
    exit_pc: u32,
    /// Loop trace: the last slot branches back to `entry`.
    looping: bool,
    /// µops per full iteration — the budget granule.
    len: u64,
    /// Patched trace→trace link: the engine epoch at which `exit_pc`
    /// was last observed to hold a built trace. Stale (≠ current epoch)
    /// links re-resolve before jumping, so invalidation is safe.
    link: Cell<Option<u64>>,
}

enum TraceCell {
    /// Still profiling.
    Cold,
    /// Formation failed; heat decayed to zero. The entry may re-earn a
    /// recording against a backed-off threshold until
    /// [`MAX_RECORD_ATTEMPTS`] failures.
    Deferred { attempts: u8 },
    /// [`MAX_RECORD_ATTEMPTS`] failures — never retried.
    Rejected,
    Built(Box<Trace>),
}

struct Recording {
    entry: u32,
    path: Vec<u32>,
}

/// The superblock execution engine for one [`DecodedProgram`]. Build it
/// once per program ([`TraceEngine::new`]) and run it as many times as
/// needed; formed traces persist across runs of the same engine.
pub struct TraceEngine {
    slots: Box<[CodeSlot]>,
    heat: Box<[u32]>,
    cells: Vec<TraceCell>,
    recording: Option<Recording>,
    hot_threshold: u32,
    /// Advanced on every cache mutation (install / invalidate); patched
    /// links carry the epoch they were resolved at and go stale when it
    /// moves.
    epoch: u64,
}

impl TraceEngine {
    /// Thread `dec` through the dispatch table (handler pointers
    /// pre-fetched per slot) and start with an empty trace cache.
    pub fn new(dec: &DecodedProgram) -> TraceEngine {
        TraceEngine::with_threshold(dec, HOT_THRESHOLD)
    }

    /// [`TraceEngine::new`] with a custom formation threshold (tests use
    /// low thresholds so short runs still form traces).
    pub fn with_threshold(dec: &DecodedProgram, hot_threshold: u32) -> TraceEngine {
        let slots: Box<[CodeSlot]> = dec
            .uops()
            .iter()
            .map(|&u| CodeSlot { h: DISPATCH[u.tag as usize], u })
            .collect();
        let n = slots.len();
        TraceEngine {
            slots,
            heat: vec![0; n].into_boxed_slice(),
            cells: (0..n).map(|_| TraceCell::Cold).collect(),
            recording: None,
            hot_threshold: hot_threshold.max(1),
            epoch: 0,
        }
    }

    /// Number of stitched traces currently cached.
    pub fn trace_count(&self) -> usize {
        self.cells.iter().filter(|c| matches!(c, TraceCell::Built(_))).count()
    }

    /// Drop the cached trace (if any) at `pc` and reset its profile to
    /// cold. The epoch advance makes every patched trace→trace link
    /// stale, so links into `pc` re-resolve before their next jump
    /// instead of transferring into a dropped trace.
    pub fn invalidate(&mut self, pc: usize) {
        if pc < self.cells.len() {
            self.cells[pc] = TraceCell::Cold;
            self.heat[pc] = 0;
            self.epoch += 1;
        }
    }

    /// Whether any cached trace carries a dense-specialized twin.
    pub fn has_dense_trace(&self) -> bool {
        self.cells.iter().any(|c| matches!(c, TraceCell::Built(t) if t.dense.is_some()))
    }

    /// Run `dec` until Halt/Ret (Ok) or a trap (Err), streaming retire
    /// info — the trace-cache counterpart of
    /// [`Executor::run_decoded_with`], bit-identical to it in
    /// architectural state, retire stream and statistics.
    pub fn run_with(
        &mut self,
        ex: &mut Executor,
        dec: &DecodedProgram,
        max_insts: u64,
        mut on_retire: impl FnMut(StepInfo<'_>),
    ) -> Result<RunStats, Trap> {
        assert_eq!(self.slots.len(), dec.len(), "engine built for a different program");
        // A recording cannot span runs: a trap (budget, fault) can end a
        // run mid-recording, and the pc discontinuity at the next run's
        // start would stitch a false edge into the path. Abandon it
        // without consuming a re-record attempt.
        self.recording = None;
        let straight = dec.straight_lens();
        let mut stats = RunStats::default();
        while !ex.halted {
            let remaining = max_insts - stats.insts;
            if remaining == 0 {
                return Err(Trap::Budget);
            }
            let pc = ex.state.pc;
            if pc < self.cells.len() && self.recording.is_none() {
                match &self.cells[pc] {
                    TraceCell::Built(tr) if remaining >= tr.len => {
                        let mut cur: &Trace = tr;
                        loop {
                            match run_trace(cur, ex, dec, &mut stats, max_insts, &mut on_retire)? {
                                TraceExit::Completed => {}
                                // side exits and budget tails bail out
                                // to the block interpreter exactly as
                                // without linking
                                TraceExit::SideExit | TraceExit::Budget => break,
                            }
                            // Trace linking: the completed trace fell
                            // through to `exit_pc`; when that is itself
                            // a built trace entry, jump straight to it.
                            // The patched link caches the epoch it was
                            // resolved at — a stale epoch (install or
                            // invalidate since) forces re-resolution.
                            let target = cur.exit_pc as usize;
                            if cur.link.get() != Some(self.epoch) {
                                match self.cells.get(target) {
                                    Some(TraceCell::Built(_)) => cur.link.set(Some(self.epoch)),
                                    _ => break,
                                }
                            }
                            let Some(TraceCell::Built(next)) = self.cells.get(target) else {
                                break;
                            };
                            // same per-trace budget gate as the front
                            // door: a too-small remainder hands the
                            // tail to the exactly-metered interpreter
                            if max_insts - stats.insts < next.len {
                                break;
                            }
                            stats.trace.link_jumps += 1;
                            cur = next;
                        }
                        continue;
                    }
                    TraceCell::Cold => {
                        let h = self.heat[pc].saturating_add(1);
                        self.heat[pc] = h;
                        if h >= self.hot_threshold {
                            self.recording = Some(Recording {
                                entry: pc as u32,
                                path: Vec::with_capacity(MAX_TRACE_LEN),
                            });
                        }
                    }
                    &TraceCell::Deferred { attempts } => {
                        // re-profiled entry: heat decayed to zero on
                        // failure, the threshold re-earned doubles per
                        // failed attempt
                        let h = self.heat[pc].saturating_add(1);
                        self.heat[pc] = h;
                        if h >= self.hot_threshold.saturating_mul(1 << attempts.min(20)) {
                            stats.trace.rerecorded += 1;
                            self.recording = Some(Recording {
                                entry: pc as u32,
                                path: Vec::with_capacity(MAX_TRACE_LEN),
                            });
                        }
                    }
                    _ => {}
                }
            }
            // One straight-line block through the threaded slots. The
            // budget is metered at the block boundary (the min below),
            // so the inner loop carries no per-µop budget or halt
            // check — trip counts are preserved exactly.
            let n = match straight.get(pc) {
                Some(&l) => u64::from(l).min(remaining),
                None => 1, // out-of-range pc: fault like the baseline's indexing
            };
            for _ in 0..n {
                let pc = ex.state.pc;
                let slot = &self.slots[pc];
                ex.accesses.clear();
                ex.next_pc = None;
                if let Err(fault) = (slot.h)(ex, &slot.u) {
                    return Err(Trap::Fault { fault, pc });
                }
                let taken = ex.next_pc.is_some();
                let next = ex.next_pc.unwrap_or(pc + 1);
                ex.state.pc = next;
                stats.insts += 1;
                stats.sve_insts += u64::from(slot.u.is_sve());
                stats.neon_insts += u64::from(slot.u.is_neon());
                stats.vector_insts += u64::from(slot.u.is_vector());
                on_retire(StepInfo {
                    pc,
                    uop: &self.slots[pc].u,
                    inst: &dec.insts()[pc],
                    reads: dec.reads(&self.slots[pc].u),
                    writes: dec.writes(&self.slots[pc].u),
                    taken,
                    mem: &ex.accesses,
                });
                if self.recording.is_some() {
                    self.record_step(dec, pc, taken, next, ex.halted, &mut stats.trace);
                }
            }
        }
        Ok(stats)
    }

    /// Run without a timing consumer.
    pub fn run(
        &mut self,
        ex: &mut Executor,
        dec: &DecodedProgram,
        max_insts: u64,
    ) -> Result<RunStats, Trap> {
        self.run_with(ex, dec, max_insts, |_| {})
    }

    /// Record one executed µop of the forming trace and close or reject
    /// the recording when a terminator is reached.
    fn record_step(
        &mut self,
        dec: &DecodedProgram,
        pc: usize,
        taken: bool,
        next: usize,
        halted: bool,
        t: &mut TraceStats,
    ) {
        let rec = self.recording.as_mut().expect("record_step without a recording");
        rec.path.push(pc as u32);
        let entry = rec.entry;
        let over = rec.path.len() >= MAX_TRACE_LEN;
        if halted {
            // a halting path runs at most once more — not worth a trace
            self.reject(entry, t);
            return;
        }
        if next == entry as usize {
            self.install(dec, true, entry, t);
            return;
        }
        if taken && next <= pc {
            // backward branch to a different head ends the superblock
            self.install(dec, false, next as u32, t);
            return;
        }
        if over {
            self.reject(entry, t);
        }
    }

    /// Abandon the active recording: decay the entry's heat to zero and
    /// defer it for a bounded number of re-record attempts; the
    /// [`MAX_RECORD_ATTEMPTS`] cap is the hard stop.
    fn reject(&mut self, entry: u32, t: &mut TraceStats) {
        self.recording = None;
        t.rejected += 1;
        let e = entry as usize;
        self.heat[e] = 0;
        let attempts = match &self.cells[e] {
            TraceCell::Deferred { attempts } => attempts.saturating_add(1),
            _ => 1,
        };
        self.cells[e] = if attempts >= MAX_RECORD_ATTEMPTS {
            TraceCell::Rejected
        } else {
            TraceCell::Deferred { attempts }
        };
    }

    /// Stitch the recorded path into a trace and cache it at its entry.
    fn install(&mut self, dec: &DecodedProgram, looping: bool, exit_pc: u32, t: &mut TraceStats) {
        let rec = self.recording.take().expect("install without a recording");
        let entry = rec.entry;
        let slots: Box<[TSlot]> = rec
            .path
            .iter()
            .enumerate()
            .map(|(i, &pc)| {
                let u = self.slots[pc as usize].u;
                let next = match rec.path.get(i + 1) {
                    Some(&n) => n,
                    None if looping => entry,
                    None => exit_pc,
                };
                TSlot { h: self.slots[pc as usize].h, u, pc, next, ctrl: u.is_control_flow() }
            })
            .collect();
        let (dense, guard_pd, guard_esize) = match specialize_dense(dec, &slots) {
            Some((d, pd, e)) => (Some(d), pd, e),
            None => (None, 0, Esize::B),
        };
        let len = slots.len() as u64;
        let link = Cell::new(None);
        let tr = Trace { slots, dense, guard_pd, guard_esize, entry, exit_pc, looping, len, link };
        self.cells[entry as usize] = TraceCell::Built(Box::new(tr));
        // cache mutation: existing patched links re-resolve (they may
        // now have a new target to link to)
        self.epoch += 1;
        t.built += 1;
    }
}

/// Why [`run_trace`] handed control back (a trap is the `Err` arm).
enum TraceExit {
    /// A non-looping trace ran to completion; `pc` = its `exit_pc` —
    /// the case trace linking may short-circuit.
    Completed,
    /// A control µop resolved off the recorded path; `pc` = true
    /// target. Always falls back to the block interpreter.
    SideExit,
    /// Not enough budget for one more full iteration; `pc` = the trace
    /// entry and the tail runs on the exactly-metered interpreter.
    Budget,
}

/// Execute iterations of `tr` until a side exit, completion of a
/// non-looping trace, a trap, or insufficient budget for one more full
/// iteration (the tail is handed back to the exactly-metered block
/// interpreter). The per-µop body mirrors the baseline step exactly:
/// same handlers, same `accesses`/`next_pc` discipline, same retire
/// stream — only the pc bookkeeping between µops is elided.
fn run_trace(
    tr: &Trace,
    ex: &mut Executor,
    dec: &DecodedProgram,
    stats: &mut RunStats,
    max_insts: u64,
    on_retire: &mut impl FnMut(StepInfo<'_>),
) -> Result<TraceExit, Trap> {
    let insts = dec.insts();
    loop {
        if max_insts - stats.insts < tr.len {
            ex.state.pc = tr.entry as usize;
            return Ok(TraceExit::Budget);
        }
        // the single per-iteration predicate check the specialization
        // is guarded by: dense slots only when every lane is active
        let slots: &[TSlot] = match &tr.dense {
            Some(d) if dense_guard_ok(ex, tr) => {
                stats.trace.dense_iters += 1;
                d
            }
            _ => {
                stats.trace.general_iters += 1;
                &tr.slots
            }
        };
        for slot in slots.iter() {
            let pc = slot.pc as usize;
            ex.accesses.clear();
            if slot.ctrl {
                ex.next_pc = None;
            }
            if let Err(fault) = (slot.h)(ex, &slot.u) {
                // the baseline faults with the pc un-advanced
                ex.state.pc = pc;
                return Err(Trap::Fault { fault, pc });
            }
            let (taken, next) = if slot.ctrl {
                match ex.next_pc {
                    Some(t) => (true, t),
                    None => (false, pc + 1),
                }
            } else {
                (false, pc + 1)
            };
            stats.insts += 1;
            stats.sve_insts += u64::from(slot.u.is_sve());
            stats.neon_insts += u64::from(slot.u.is_neon());
            stats.vector_insts += u64::from(slot.u.is_vector());
            on_retire(StepInfo {
                pc,
                uop: &slot.u,
                inst: &insts[pc],
                reads: dec.reads(&slot.u),
                writes: dec.writes(&slot.u),
                taken,
                mem: &ex.accesses,
            });
            if slot.ctrl && next != slot.next as usize {
                // side exit: write back the true pc and fall back to
                // the block interpreter
                ex.state.pc = next;
                return Ok(TraceExit::SideExit);
            }
        }
        if !tr.looping {
            ex.state.pc = tr.exit_pc as usize;
            return Ok(TraceExit::Completed);
        }
    }
}

/// The dense guard: the governing predicate is an all-lanes-active
/// prefix at the `whilelt` granule, so every twin handler's predication
/// is provably a no-op this iteration.
#[inline]
fn dense_guard_ok(ex: &Executor, tr: &Trace) -> bool {
    let vlb = ex.state.vl_bytes();
    let e = tr.guard_esize;
    ex.state.p[tr.guard_pd as usize].prefix_len(e, vlb) == Some(e.lanes(vlb))
}

/// Build the dense twin of a trace, if a `whilelt` governs it: µops
/// strictly before the first write to the governing predicate — whose
/// own governing predicate *is* that register, at the same granule —
/// swap their handlers for unpredicated fast-path twins.
fn specialize_dense(dec: &DecodedProgram, slots: &[TSlot]) -> Option<(Box<[TSlot]>, u8, Esize)> {
    let w = slots.iter().find(|s| s.u.tag == UopTag::While)?;
    let pd = w.u.a;
    let we = w.u.esize;
    let pd_slot = 63 + pd; // reg_slot(RegId::P(pd))
    let first_write = slots
        .iter()
        .position(|s| dec.writes(&s.u).contains(&pd_slot))
        .unwrap_or(slots.len());
    let mut dense: Vec<TSlot> = slots.to_vec();
    let mut any = false;
    for s in dense.iter_mut().take(first_write) {
        if let Some(h) = dense_twin(&s.u, pd, we) {
            s.h = h;
            any = true;
        }
    }
    if any {
        Some((dense.into_boxed_slice(), pd, we))
    } else {
        None
    }
}

/// Effective predication granule of an FP µop (D if double else S).
fn fp_esize(u: &Uop) -> Esize {
    if u.dbl() {
        Esize::D
    } else {
        Esize::S
    }
}

/// The unpredicated fast-path twin of `u`, if it is governed by `pd` at
/// granule `we` and a `DENSE` monomorphization exists for its tag.
///
/// Covers every predicated tag the compiled kernel families emit in
/// their steady state: contiguous and gather/scatter memory, the
/// `SveLd1R`/`CpyX`/`Sel` setup-and-select class, arithmetic including
/// the FMLA/FMLS pairs `RedKind::DotF` and `Expr::ComplexMul` lower to,
/// and the horizontal reductions (`SveReduce`, ordered `SveFadda`).
/// Deliberately absent: `Movprfx` merges at **byte** granule, which an
/// element-granule dense guard cannot prove away; predicate-writing
/// µops (`While`, compares, `Brk`…) define the guard rather than ride
/// it; µops governed by a different register (e.g. ComplexMul's
/// lane-parity `Sel`) fail the `u.b == pd` check by construction.
fn dense_twin(u: &Uop, pd: u8, we: Esize) -> Option<Handler> {
    use UopTag as T;
    if u.b != pd {
        return None;
    }
    let (h, e): (Handler, Esize) = match u.tag {
        T::SveLd1ImmVl => (super::sve::h_sve_ld1_imm_vl_dense, u.esize),
        T::SveLd1Reg => (super::sve::h_sve_ld1_reg_dense, u.esize),
        T::SveSt1ImmVl => (super::sve::h_sve_st1_imm_vl_dense, u.esize),
        T::SveSt1Reg => (super::sve::h_sve_st1_reg_dense, u.esize),
        T::SveLd1R => (super::sve::h_sve_ld1r_dense, u.esize),
        T::SveGatherVecImm => (super::sve::h_sve_gather_vec_imm_dense, u.esize),
        T::SveGatherBaseVec => (super::sve::h_sve_gather_base_vec_dense, u.esize),
        T::SveScatterVecImm => (super::sve::h_sve_scatter_vec_imm_dense, u.esize),
        T::SveScatterBaseVec => (super::sve::h_sve_scatter_base_vec_dense, u.esize),
        T::CpyX => (super::sve::h_cpy_x_dense, u.esize),
        T::Sel => (super::sve::h_sel_dense, u.esize),
        T::SveIntBin => (super::sve::h_sve_int_bin_dense, u.esize),
        T::SveFpBin => (super::sve::h_sve_fp_bin_dense, fp_esize(u)),
        T::SveFpUn => (super::sve::h_sve_fp_un_dense, fp_esize(u)),
        T::SveFmla => (super::sve::h_sve_fmla_dense, fp_esize(u)),
        T::SveScvtf => (super::sve::h_sve_scvtf_dense, fp_esize(u)),
        T::SveReduce => (super::sve::h_sve_reduce_dense, u.esize),
        T::SveFadda => (super::sve::h_sve_fadda_dense, fp_esize(u)),
        _ => return None,
    };
    if e == we {
        Some(h)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::{Asm, Program};
    use crate::exec::Engine;
    use crate::isa::{Cond, Inst, MemOff, SveMemOff};
    use crate::mem::{Memory, PAGE_SIZE};
    use crate::uarch::{run_timed_decoded, run_timed_decoded_engine, UarchConfig};
    use crate::workloads;

    /// The paper's Fig. 2c daxpy — the canonical `whilelt` steady-state
    /// loop the dense specialization targets.
    fn daxpy_prog(x: u64, y: u64, a_addr: u64, n_addr: u64) -> Program {
        let mut asm = Asm::new();
        let a = &mut asm;
        a.push(Inst::MovImm { xd: 0, imm: x });
        a.push(Inst::MovImm { xd: 1, imm: y });
        a.push(Inst::MovImm { xd: 2, imm: a_addr });
        a.push(Inst::MovImm { xd: 3, imm: n_addr });
        a.push(Inst::Ldr { size: 4, signed: true, xt: 3, base: 3, off: MemOff::Imm(0) });
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.push(Inst::SveLd1R { zt: 0, pg: 0, esize: Esize::D, base: 2, imm: 0 });
        a.label("loop");
        let off = SveMemOff::RegScaled(4);
        a.push(Inst::SveLd1 { zt: 1, pg: 0, esize: Esize::D, base: 0, off, ff: false });
        a.push(Inst::SveLd1 { zt: 2, pg: 0, esize: Esize::D, base: 1, off, ff: false });
        a.push(Inst::SveFmla { zda: 2, pg: 0, zn: 1, zm: 0, dbl: true, sub: false });
        a.push(Inst::SveSt1 { zt: 2, pg: 0, esize: Esize::D, base: 1, off });
        a.push(Inst::IncDec { xdn: 4, esize: Esize::D, dec: false });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.push_branch(Inst::BCond { cond: Cond::FIRST, target: 0 }, "loop");
        a.push(Inst::Halt);
        asm.finish()
    }

    /// Build daxpy memory + program for `n` elements. Returns
    /// (mem, y_base, program).
    fn daxpy_setup(n: usize) -> (Memory, u64, Program) {
        let mut mem = Memory::new();
        let x = mem.alloc(8 * n.max(1) as u64, 16);
        let y = mem.alloc(8 * n.max(1) as u64, 16);
        let a_addr = mem.alloc(8, 8);
        let n_addr = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(x + 8 * i as u64, 0.5 * i as f64).unwrap();
            mem.write_f64(y + 8 * i as u64, 100.0 - i as f64).unwrap();
        }
        mem.write_f64(a_addr, 2.5).unwrap();
        mem.write_u32(n_addr, n as u32).unwrap();
        (mem, y, daxpy_prog(x, y, a_addr, n_addr))
    }

    /// Two-level daxpy nest: `reps` passes over the same vectors — the
    /// loop shape whose steady state exercises trace linking. The inner
    /// vloop becomes a looping trace; the outer-close (AddImm/Cbnz) and
    /// outer-head (MovImm/While + first inner iteration) blocks become
    /// non-looping traces chained close → head → inner by patched links.
    fn nested_prog(x: u64, y: u64, a_addr: u64, n: u64, reps: u64) -> Program {
        let mut asm = Asm::new();
        let a = &mut asm;
        a.push(Inst::MovImm { xd: 0, imm: x });
        a.push(Inst::MovImm { xd: 1, imm: y });
        a.push(Inst::MovImm { xd: 2, imm: a_addr });
        a.push(Inst::MovImm { xd: 3, imm: n });
        a.push(Inst::MovImm { xd: 5, imm: reps });
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.push(Inst::SveLd1R { zt: 0, pg: 0, esize: Esize::D, base: 2, imm: 0 });
        a.label("outer");
        a.push(Inst::MovImm { xd: 4, imm: 0 });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.label("loop");
        let off = SveMemOff::RegScaled(4);
        a.push(Inst::SveLd1 { zt: 1, pg: 0, esize: Esize::D, base: 0, off, ff: false });
        a.push(Inst::SveLd1 { zt: 2, pg: 0, esize: Esize::D, base: 1, off, ff: false });
        a.push(Inst::SveFmla { zda: 2, pg: 0, zn: 1, zm: 0, dbl: true, sub: false });
        a.push(Inst::SveSt1 { zt: 2, pg: 0, esize: Esize::D, base: 1, off });
        a.push(Inst::IncDec { xdn: 4, esize: Esize::D, dec: false });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false });
        a.push_branch(Inst::BCond { cond: Cond::FIRST, target: 0 }, "loop");
        a.push(Inst::AddImm { xd: 5, xn: 5, imm: -1 });
        a.push_branch(Inst::Cbnz { xn: 5, target: 0 }, "outer");
        a.push(Inst::Halt);
        asm.finish()
    }

    /// Build nest memory + program. Returns (mem, y_base, program).
    fn nested_setup(n: usize, reps: u64) -> (Memory, u64, Program) {
        let mut mem = Memory::new();
        let x = mem.alloc(8 * n.max(1) as u64, 16);
        let y = mem.alloc(8 * n.max(1) as u64, 16);
        let a_addr = mem.alloc(8, 8);
        for i in 0..n {
            mem.write_f64(x + 8 * i as u64, 0.25 * i as f64).unwrap();
            mem.write_f64(y + 8 * i as u64, 10.0 + i as f64).unwrap();
        }
        mem.write_f64(a_addr, 1.5).unwrap();
        (mem, y, nested_prog(x, y, a_addr, n as u64, reps))
    }

    /// Expected y[i] after `reps` passes of `y += 1.5 * x` over
    /// [`nested_setup`] data.
    fn nested_want(i: usize, reps: u64) -> f64 {
        let mut v = 10.0 + i as f64;
        for _ in 0..reps {
            v += 1.5 * (0.25 * i as f64);
        }
        v
    }

    /// Assert the two executors reached identical architectural state.
    fn assert_same_state(a: &Executor, b: &Executor, what: &str) {
        assert_eq!(a.state.pc, b.state.pc, "{what}: pc");
        assert_eq!(a.halted, b.halted, "{what}: halted");
        assert_eq!(a.state.x, b.state.x, "{what}: x registers");
        assert_eq!(a.state.flags, b.state.flags, "{what}: NZCV");
        for r in 0..a.state.z.len() {
            assert_eq!(a.state.z[r].bytes, b.state.z[r].bytes, "{what}: z{r}");
        }
        assert_eq!(a.state.p, b.state.p, "{what}: predicates");
        assert_eq!(a.state.ffr, b.state.ffr, "{what}: ffr");
    }

    #[test]
    fn daxpy_forms_a_dense_loop_trace_and_stays_bit_identical() {
        let (mem, y, p) = daxpy_setup(100);
        let dec = DecodedProgram::decode(&p);
        let mut base = Executor::new(256, mem.clone());
        let rb = base.run_decoded(&dec, 1_000_000);
        let mut traced = Executor::new(256, mem.clone());
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        let rt = eng.run(&mut traced, &dec, 1_000_000);
        assert_eq!(rb, rt, "run statistics");
        assert!(eng.trace_count() >= 1, "the hot loop must form a trace");
        assert!(eng.has_dense_trace(), "the whilelt steady state must dense-specialize");
        assert_same_state(&base, &traced, "daxpy n=100");
        for i in 0..100 {
            let want = 2.5 * (0.5 * i as f64) + (100.0 - i as f64);
            assert_eq!(traced.mem.read_f64(y + 8 * i as u64).unwrap(), want, "y[{i}]");
        }
        // formed traces persist across runs of the same engine
        let count = eng.trace_count();
        let mut again = Executor::new(256, mem.clone());
        assert_eq!(eng.run(&mut again, &dec, 1_000_000), rb);
        assert_eq!(eng.trace_count(), count, "no re-formation on reuse");
        assert_same_state(&traced, &again, "daxpy rerun");
    }

    #[test]
    fn tail_iterations_and_sparse_predicates_side_exit_correctly() {
        // awkward trip counts: empty loop, sub-vector tails, exact
        // multiples — the dense guard must fail over to the general
        // (predicated) slots without changing a single bit
        for vl in [128usize, 256, 1024] {
            for n in [0usize, 1, 3, 31, 32, 33] {
                let (mem, y, p) = daxpy_setup(n);
                let dec = DecodedProgram::decode(&p);
                let mut base = Executor::new(vl, mem.clone());
                let rb = base.run_decoded(&dec, 1_000_000);
                let mut traced = Executor::new(vl, mem.clone());
                let mut eng = TraceEngine::with_threshold(&dec, 2);
                let rt = eng.run(&mut traced, &dec, 1_000_000);
                assert_eq!(rb, rt, "vl={vl} n={n}");
                assert_same_state(&base, &traced, &format!("vl={vl} n={n}"));
                for i in 0..n {
                    let want = 2.5 * (0.5 * i as f64) + (100.0 - i as f64);
                    assert_eq!(traced.mem.read_f64(y + 8 * i as u64).unwrap(), want);
                }
            }
        }
    }

    #[test]
    fn retire_streams_are_identical_including_side_exits() {
        // n=33 at VL=256: 8 dense iterations, one tail, one empty exit
        let (mem, _y, p) = daxpy_setup(33);
        let dec = DecodedProgram::decode(&p);
        let collect = |use_trace: bool| {
            let mut steps: Vec<(usize, bool, usize)> = Vec::new();
            let mut ex = Executor::new(256, mem.clone());
            let on = |info: StepInfo<'_>| steps.push((info.pc, info.taken, info.mem.len()));
            let r = if use_trace {
                TraceEngine::with_threshold(&dec, 2).run_with(&mut ex, &dec, 1_000_000, on)
            } else {
                ex.run_decoded_with(&dec, 1_000_000, on)
            };
            r.unwrap();
            steps
        };
        assert_eq!(collect(false), collect(true));
    }

    #[test]
    fn faults_mid_trace_match_the_baseline() {
        // a pointer walk that strides off the end of its one mapped page
        // after the loop has long been stitched into a trace
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 0x1000 });
        a.push(Inst::MovImm { xd: 1, imm: 1000 });
        a.label("loop");
        a.push(Inst::Ldr { size: 8, signed: false, xt: 2, base: 0, off: MemOff::Imm(0) });
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 8 });
        a.push(Inst::AddImm { xd: 1, xn: 1, imm: -1 });
        a.push_branch(Inst::Cbnz { xn: 1, target: 0 }, "loop");
        a.push(Inst::Halt);
        let p = a.finish();
        let dec = DecodedProgram::decode(&p);
        let mut mem = Memory::new();
        mem.map(0x1000, PAGE_SIZE as u64);
        let mut base = Executor::new(128, mem.clone());
        let rb = base.run_decoded(&dec, 1_000_000);
        assert!(matches!(rb, Err(Trap::Fault { .. })), "the walk must fault: {rb:?}");
        let mut traced = Executor::new(128, mem.clone());
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        let rt = eng.run(&mut traced, &dec, 1_000_000);
        assert!(eng.trace_count() >= 1, "the loop must be traced before the fault");
        assert_eq!(rb, rt, "identical Trap::Fault, same fault address, same pc");
        assert_same_state(&base, &traced, "fault state");
    }

    #[test]
    fn budget_is_exact_through_traces() {
        let (mem, _y, p) = daxpy_setup(40);
        let dec = DecodedProgram::decode(&p);
        let full = {
            let mut ex = Executor::new(256, mem.clone());
            ex.run_decoded(&dec, 1_000_000).unwrap().insts
        };
        // pre-form the traces, then sweep every budget through them
        let mut eng = TraceEngine::with_threshold(&dec, 1);
        let mut warm = Executor::new(256, mem.clone());
        eng.run(&mut warm, &dec, 1_000_000).unwrap();
        assert!(eng.trace_count() >= 1);
        for budget in 0..=full {
            let mut base = Executor::new(256, mem.clone());
            let mut nb = 0u64;
            let rb = base.run_decoded_with(&dec, budget, |_| nb += 1);
            let mut traced = Executor::new(256, mem.clone());
            let mut nt = 0u64;
            let rt = eng.run_with(&mut traced, &dec, budget, |_| nt += 1);
            assert_eq!(rb, rt, "budget {budget}");
            assert_eq!(nb, nt, "retire count at budget {budget}");
            if budget < full {
                assert_eq!(rb, Err(Trap::Budget), "budget {budget}");
                assert_eq!(nb, budget, "exact metering at budget {budget}");
            }
            assert_same_state(&base, &traced, &format!("budget {budget}"));
        }
    }

    #[test]
    fn halting_paths_are_deferred_then_hard_rejected() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 7 });
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 1 });
        a.push(Inst::Halt);
        let p = a.finish();
        let dec = DecodedProgram::decode(&p);
        let mut eng = TraceEngine::with_threshold(&dec, 1);
        let (mut rejected, mut rerecorded) = (0u64, 0u64);
        for _ in 0..10 {
            let mut ex = Executor::new(128, Memory::new());
            let stats = eng.run(&mut ex, &dec, 100).unwrap();
            assert_eq!(stats.insts, 3);
            assert_eq!(ex.state.get_x(0), 8);
            rejected += stats.trace.rejected;
            rerecorded += stats.trace.rerecorded;
        }
        assert_eq!(eng.trace_count(), 0, "a halting path is never worth a trace");
        // threshold 1 → records on runs 1, 3 (backed-off ×2), 7 (×4),
        // each failing, then the attempt cap turns the entry to stone
        assert_eq!(rejected, u64::from(MAX_RECORD_ATTEMPTS), "bounded re-record attempts");
        assert!(rerecorded >= 1, "deferred entries re-earn recordings before the cap");
        assert!(
            matches!(eng.cells[0], TraceCell::Rejected),
            "the attempt cap is a hard stop"
        );
    }

    #[test]
    fn nested_loops_link_traces_bit_identically() {
        let (mem, y, p) = nested_setup(16, 8);
        let dec = DecodedProgram::decode(&p);
        let mut base = Executor::new(256, mem.clone());
        let rb = base.run_decoded(&dec, 1_000_000).unwrap();
        let mut traced = Executor::new(256, mem.clone());
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        let rt = eng.run(&mut traced, &dec, 1_000_000).unwrap();
        assert_eq!(rb, rt, "run statistics");
        assert!(eng.trace_count() >= 3, "inner loop, outer head and outer close must all trace");
        assert!(rt.trace.link_jumps > 0, "the steady-state nest must take patched links");
        assert_same_state(&base, &traced, "nest n=16 reps=8");
        for i in 0..16 {
            assert_eq!(traced.mem.read_f64(y + 8 * i as u64).unwrap(), nested_want(i, 8), "y[{i}]");
        }
        // the retire streams agree µop for µop across link jumps
        let collect = |use_trace: bool| {
            let mut steps: Vec<(usize, bool, usize)> = Vec::new();
            let mut ex = Executor::new(256, mem.clone());
            let on = |info: StepInfo<'_>| steps.push((info.pc, info.taken, info.mem.len()));
            if use_trace {
                let mut eng = TraceEngine::with_threshold(&dec, 2);
                eng.run_with(&mut ex, &dec, 1_000_000, on).unwrap();
            } else {
                ex.run_decoded_with(&dec, 1_000_000, on).unwrap();
            }
            steps
        };
        assert_eq!(collect(false), collect(true));
    }

    #[test]
    fn linked_pair_with_one_dense_twin_splits_iteration_kinds() {
        // in the nest, only the inner vloop trace dense-specializes (the
        // outer head's While writes the guard, the outer close has no
        // whilelt at all) — so a linked chain mixes dense and general
        // iterations and must still be bit-identical
        let (mem, y, p) = nested_setup(16, 8);
        let dec = DecodedProgram::decode(&p);
        let mut base = Executor::new(256, mem.clone());
        let rb = base.run_decoded(&dec, 1_000_000).unwrap();
        let mut traced = Executor::new(256, mem.clone());
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        let rt = eng.run(&mut traced, &dec, 1_000_000).unwrap();
        assert_eq!(rb, rt);
        assert_same_state(&base, &traced, "mixed dense/general nest");
        let dense_built = eng
            .cells
            .iter()
            .filter(|c| matches!(c, TraceCell::Built(t) if t.dense.is_some()))
            .count();
        let plain_built = eng
            .cells
            .iter()
            .filter(|c| matches!(c, TraceCell::Built(t) if t.dense.is_none()))
            .count();
        assert!(dense_built >= 1, "the inner vloop must dense-specialize");
        assert!(plain_built >= 2, "outer head and close must build without twins");
        assert!(rt.trace.link_jumps > 0, "the pair must be linked");
        assert!(rt.trace.dense_iters > 0, "full-prefix inner iterations run dense");
        assert!(rt.trace.general_iters > 0, "twin-less traces run their general slots");
        for i in 0..16 {
            assert_eq!(traced.mem.read_f64(y + 8 * i as u64).unwrap(), nested_want(i, 8), "y[{i}]");
        }
    }

    #[test]
    fn invalidated_link_targets_re_resolve_safely() {
        let (mem, y, p) = nested_setup(16, 8);
        let dec = DecodedProgram::decode(&p);
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        let mut warm = Executor::new(256, mem.clone());
        let s1 = eng.run(&mut warm, &dec, 1_000_000).unwrap();
        assert!(s1.trace.link_jumps > 0, "warmup must patch links");
        // drop the inner-loop trace — the target of the outer-head link;
        // the epoch advance must stale every patched link into it
        let v = eng
            .cells
            .iter()
            .position(|c| matches!(c, TraceCell::Built(t) if t.looping))
            .expect("the inner vloop must have a looping trace");
        let count = eng.trace_count();
        eng.invalidate(v);
        assert_eq!(eng.trace_count(), count - 1);
        let mut base = Executor::new(256, mem.clone());
        let rb = base.run_decoded(&dec, 1_000_000).unwrap();
        let mut traced = Executor::new(256, mem.clone());
        let rt = eng.run(&mut traced, &dec, 1_000_000).unwrap();
        assert_eq!(rb, rt, "stale links must re-resolve, not jump into the dropped trace");
        assert_same_state(&base, &traced, "post-invalidate rerun");
        assert!(rt.trace.built >= 1, "the dropped entry re-profiles and re-forms");
        assert!(
            eng.cells.iter().any(|c| matches!(c, TraceCell::Built(t) if t.looping)),
            "the inner vloop trace is back"
        );
        for i in 0..16 {
            assert_eq!(traced.mem.read_f64(y + 8 * i as u64).unwrap(), nested_want(i, 8), "y[{i}]");
        }
    }

    #[test]
    fn budget_is_exact_across_link_jumps() {
        let (mem, _y, p) = nested_setup(16, 8);
        let dec = DecodedProgram::decode(&p);
        let full = {
            let mut ex = Executor::new(256, mem.clone());
            ex.run_decoded(&dec, 1_000_000).unwrap().insts
        };
        // two warm runs: the first builds the three traces, the second
        // patches the links and leaves no entry still profiling
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        for _ in 0..2 {
            let mut warmex = Executor::new(256, mem.clone());
            eng.run(&mut warmex, &dec, 1_000_000).unwrap();
        }
        {
            let mut ex = Executor::new(256, mem.clone());
            let s = eng.run(&mut ex, &dec, 1_000_000).unwrap();
            assert!(s.trace.link_jumps > 0, "warmed nest must run linked");
        }
        // every budget value walks the expiry point across the whole
        // run, including budgets landing exactly on a link jump
        for budget in 0..=full {
            let mut base = Executor::new(256, mem.clone());
            let mut nb = 0u64;
            let rb = base.run_decoded_with(&dec, budget, |_| nb += 1);
            let mut traced = Executor::new(256, mem.clone());
            let mut nt = 0u64;
            let rt = eng.run_with(&mut traced, &dec, budget, |_| nt += 1);
            assert_eq!(rb, rt, "budget {budget}");
            assert_eq!(nb, nt, "retire count at budget {budget}");
            if budget < full {
                assert_eq!(rb, Err(Trap::Budget), "budget {budget}");
                assert_eq!(nb, budget, "exact metering at budget {budget}");
            }
            assert_same_state(&base, &traced, &format!("budget {budget}"));
        }
    }

    #[test]
    fn deferred_entries_re_record_and_succeed() {
        // a loop whose first profile halts mid-recording (tiny runtime
        // trip count) is deferred, then earns its trace on a later run
        // against the backed-off threshold — bit-identical throughout
        let mut mem = Memory::new();
        let x = mem.alloc(800, 16);
        let y = mem.alloc(800, 16);
        let a_addr = mem.alloc(8, 8);
        let n_addr = mem.alloc(8, 8);
        for i in 0..100 {
            mem.write_f64(x + 8 * i as u64, 0.5 * i as f64).unwrap();
            mem.write_f64(y + 8 * i as u64, 100.0 - i as f64).unwrap();
        }
        mem.write_f64(a_addr, 2.5).unwrap();
        let p = daxpy_prog(x, y, a_addr, n_addr);
        let dec = DecodedProgram::decode(&p);
        let mut eng = TraceEngine::with_threshold(&dec, 2);
        // run 1: n=12 → 3 iterations at VL=256; the recording triggered
        // on the final iteration runs into Halt and is deferred
        let mut m1 = mem.clone();
        m1.write_u32(n_addr, 12).unwrap();
        let mut b1 = Executor::new(256, m1.clone());
        let rb1 = b1.run_decoded(&dec, 1_000_000).unwrap();
        let mut t1 = Executor::new(256, m1);
        let s1 = eng.run(&mut t1, &dec, 1_000_000).unwrap();
        assert_eq!(rb1, s1);
        assert_same_state(&b1, &t1, "run 1 (deferred)");
        assert!(s1.trace.rejected >= 1, "the halting recording must be deferred");
        assert_eq!(eng.trace_count(), 0, "no trace from the halting profile");
        // run 2: n=100 on the same engine — the deferred loop re-earns
        // a recording against the doubled threshold and installs
        let mut m2 = mem.clone();
        m2.write_u32(n_addr, 100).unwrap();
        let mut b2 = Executor::new(256, m2.clone());
        let rb2 = b2.run_decoded(&dec, 1_000_000).unwrap();
        let mut t2 = Executor::new(256, m2.clone());
        let s2 = eng.run(&mut t2, &dec, 1_000_000).unwrap();
        assert_eq!(rb2, s2);
        assert_same_state(&b2, &t2, "run 2 (re-recorded)");
        assert!(s2.trace.rerecorded >= 1, "the deferred entry re-records");
        assert!(eng.trace_count() >= 1, "and succeeds on its second recording");
        assert!(eng.has_dense_trace(), "the re-recorded loop dense-specializes");
        for i in 0..100 {
            let want = 2.5 * (0.5 * i as f64) + (100.0 - i as f64);
            assert_eq!(t2.mem.read_f64(y + 8 * i as u64).unwrap(), want, "y[{i}]");
        }
        // run 3: the warmed prologue trace now links into the loop trace
        let mut t3 = Executor::new(256, m2);
        let s3 = eng.run(&mut t3, &dec, 1_000_000).unwrap();
        assert_eq!(rb2, s3);
        assert!(s3.trace.link_jumps >= 1, "prologue trace links into the loop trace");
    }

    #[test]
    fn timed_counters_are_engine_independent_on_compiled_workloads() {
        use crate::compiler::Target;
        let cfg = UarchConfig::default();
        for name in ["stream_triad", "haccmk", "strlen1m", "graph500"] {
            let w = workloads::build(name);
            let plans: [(Target, &[usize]); 3] = [
                (Target::Scalar, &[128]),
                (Target::Neon, &[128]),
                (Target::Sve, &[128, 384, 1024]),
            ];
            for (target, vls) in plans {
                let c = w.compile(target);
                for &vl in vls {
                    let mut a = Executor::new(vl, w.mem.clone());
                    let (sa, ta) =
                        run_timed_decoded(&mut a, &c.decoded, cfg.clone(), w.max_insts).unwrap();
                    let mut b = Executor::new(vl, w.mem.clone());
                    let (sb, tb) = run_timed_decoded_engine(
                        &mut b,
                        &c.decoded,
                        Engine::Trace,
                        cfg.clone(),
                        w.max_insts,
                    )
                    .unwrap();
                    let what = format!("{name}/{target:?}@{vl}");
                    assert_eq!(sa, sb, "{what}: run stats");
                    assert_eq!(ta, tb, "{what}: timing counters");
                    assert_same_state(&a, &b, &what);
                }
            }
        }
    }
}
