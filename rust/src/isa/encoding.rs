//! Encoding-budget model — Fig. 7 and the §4 tradeoffs.
//!
//! The paper's claim: the whole of SVE fits in a *single 28-bit region*
//! of the A64 top-level opcode map (one of the 16 values of the 4-bit
//! `op0` field), and it only fits because of three design decisions:
//!
//! 1. destructive predicated forms + `movprfx` instead of fully
//!    constructive predicated forms ("three vector and one predicate
//!    register specifier would require nineteen bits alone"),
//! 2. predicated data-processing restricted to P0–P7 (3-bit Pg field),
//! 3. constructive unpredicated forms for only the most common opcodes.
//!
//! We model each instruction *format* as (fixed opcode bits, operand
//! bits): a format consumes `2^operand_bits` encoding points of the
//! `2^28` available. [`sve_region_report`] accounts for our implemented
//! ISA; [`constructive_counterfactual`] recomputes the budget with the
//! paper's rejected alternative (fully constructive + 4-bit predicates)
//! and demonstrates it blows the region, reproducing the §4 argument
//! quantitatively. [`encode`]/[`decode`] implement a concrete bit-level
//! packing for the program-visible subset, verified by round-trip
//! property tests.

use super::inst::*;
use crate::arch::{Cond, Esize};

/// Total encoding points in the SVE region: a single 28-bit region
/// (Fig. 7a: 32-bit words, 4-bit top-level `op0`).
pub const SVE_REGION_BITS: u32 = 28;
pub const SVE_REGION_POINTS: u128 = 1 << SVE_REGION_BITS;

/// One instruction format's encoding cost.
#[derive(Clone, Debug)]
pub struct Format {
    pub group: &'static str,
    pub name: &'static str,
    /// Bits of operand payload; the format occupies 2^bits points.
    pub operand_bits: u32,
    /// Number of distinct opcodes sharing this exact format shape.
    pub opcodes: u32,
}

impl Format {
    pub fn points(&self) -> u128 {
        (self.opcodes as u128) << self.operand_bits
    }
}

/// The implemented SVE ISA's formats. Field sizes follow the real
/// architecture: Zx = 5 bits, Px = 4 bits, governing Pg (predicated
/// data-processing) = 3 bits (§4 restriction), size = 2 bits.
pub fn sve_formats() -> Vec<Format> {
    let f = |group, name, operand_bits, opcodes| Format { group, name, operand_bits, opcodes };
    vec![
        // -------- predicated destructive data processing: Zdn(5) Pg(3) Zm(5) size(2) = 15
        f("int-dp", "int binary pred-destructive", 15, 13), // IntOp variants
        f("fp-dp", "fp binary pred-destructive", 14, 6),    // size is 1 bit (S/D) + 13
        f("fp-dp", "fp fused mla/mls: Zda Pg Zn Zm", 19, 2), // 5+3+5+5+1
        f("fp-dp", "fp unary pred-merging", 14, 4),
        f("fp-dp", "scvtf", 14, 1),
        // -------- unpredicated constructive (common opcodes only, §4): Zd Zn Zm size = 17
        f("int-dp", "int binary unpred-constructive", 17, 3), // add/sub/mul... we expose 3
        f("int-dp", "add imm: Zdn size imm8", 15, 1),
        // -------- movprfx: Zd Zn = 10; predicated: Zd Pg(3) M/Z Zn = 14
        f("movprfx", "movprfx unpredicated", 10, 1),
        f("movprfx", "movprfx predicated", 14, 1),
        // -------- predicate generation (full P0-P15 targets: 4-bit fields)
        f("pred-gen", "ptrue/ptrues: Pd size pattern(5)", 11, 2),
        f("pred-gen", "pfalse: Pd", 4, 1),
        f("pred-gen", "while{lt,lo}: Pd size Xn Xm", 16, 2),
        f("pred-gen", "int cmp vec: Pd Pg(3) Zn Zm size op", 19, 12),
        f("pred-gen", "int cmp imm: Pd Pg(3) Zn imm7 size op", 21, 12),
        f("pred-gen", "fp cmp vec/zero: Pd Pg(3) Zn Zm sz op", 18, 12),
        // -------- predicate manipulation
        f("pred-ops", "logic: Pd Pg Pn Pm (16 targets)", 16, 8), // and/orr/eor/bic + s-forms
        f("pred-ops", "brka/brkb(s): Pd Pg Pn", 12, 4),
        f("pred-ops", "pnext: Pdn Pg size", 10, 1),
        f("pred-ops", "ptest: Pg Pn", 8, 1),
        f("pred-ops", "rdffr(s): Pd [Pg]", 8, 3),
        f("pred-ops", "setffr/wrffr", 4, 2),
        // -------- counting / induction
        f("count", "cnt{b,h,w,d}: Xd pattern", 10, 4),
        f("count", "inc/dec{b,h,w,d}: Xdn pattern", 10, 8),
        f("count", "incp: Xdn Pm size", 11, 1),
        f("count", "index: Zd size {imm5|Xn} x2", 17, 4),
        // -------- data movement
        f("move", "dup imm: Zd size imm8", 15, 1),
        f("move", "fdup imm: Zd sz imm8", 14, 1),
        f("move", "dup/cpy scalar: Zd [Pg] Xn size", 15, 2),
        f("move", "sel: Zd Pg(4) Zn Zm size", 21, 1),
        f("move", "lasta/lastb: Xd Pg Zn size", 15, 2),
        // -------- contiguous memory: Zt Pg(3) Rn(5) + {imm4 | Rm(5)} + size
        f("mem", "ld1/ldff1/ldnt contiguous", 19, 12),
        f("mem", "st1 contiguous", 19, 4),
        f("mem", "ld1r broadcast: Zt Pg Rn imm6", 21, 4),
        // -------- gather/scatter: Zt Pg(3) {Zn imm5 | Rn Zm mode}
        f("mem", "gather ld/ldff", 20, 12),
        f("mem", "scatter st", 20, 6),
        // -------- horizontal ops (§2.4)
        f("horiz", "tree reductions: Vd Pg Zn size", 15, 8),
        f("horiz", "fadda: Vdn Pg Zm sz", 14, 1),
        // -------- permutes
        f("permute", "rev/compact/splice etc.", 15, 6),
        f("permute", "zip/uzp/trn/tbl: Zd Zn Zm size", 17, 7),
        f("permute", "ext: Zdn Zm imm8", 18, 1),
        // -------- termination
        f("term", "ctermeq/ne: Xn Xm", 10, 2),
    ]
}

/// Per-group usage summary.
#[derive(Clone, Debug)]
pub struct GroupUsage {
    pub group: String,
    pub points: u128,
    pub share_of_region: f64,
}

pub fn sve_region_report() -> (Vec<GroupUsage>, u128) {
    let mut groups: Vec<(String, u128)> = vec![];
    for fmt in sve_formats() {
        match groups.iter_mut().find(|(g, _)| g == fmt.group) {
            Some((_, p)) => *p += fmt.points(),
            None => groups.push((fmt.group.to_string(), fmt.points())),
        }
    }
    let total: u128 = groups.iter().map(|(_, p)| p).sum();
    let usages = groups
        .into_iter()
        .map(|(group, points)| GroupUsage {
            group,
            points,
            share_of_region: points as f64 / SVE_REGION_POINTS as f64,
        })
        .collect();
    (usages, total)
}

/// Approximate count of predicated data-processing opcodes in the *full*
/// SVE v1 architecture (integer, FP, fused, unary, widening, saturating,
/// shifts, converts — counted from the A64 SVE index). Our simulator
/// implements a subset, but the §4 encoding argument is about the full
/// set ("the entire set of data-processing operations"), so the
/// counterfactual extrapolates with this count.
pub const FULL_DP_OPCODES: u32 = 320;

/// The §4 tradeoff, quantified for the full data-processing set.
///
/// Destructive predicated form: Zdn(5) Pg(3) Zm(5) size(2) = 15 operand
/// bits. Fully-constructive predicated form: Zd(5) Zn(5) Zm(5) Pg(4) =
/// 19 bits ("nineteen bits alone") + size(2) = 21 bits, "without
/// accounting for other control fields". Returns
/// `(destructive_points, constructive_points)`.
pub fn constructive_counterfactual() -> (u128, u128) {
    let destructive = (FULL_DP_OPCODES as u128) << 15;
    let constructive = (FULL_DP_OPCODES as u128) << 21;
    (destructive, constructive)
}

// =====================================================================
// Concrete bit-level packing for the program-visible subset
// =====================================================================

/// Encode failure: instruction not in the packed subset.
#[derive(Debug, PartialEq, Eq)]
pub struct NotPackable;

const fn tag(t: u32) -> u32 {
    // op0 = 0b0100 in the top nibble (Fig. 7a), format tag in bits 22..28
    (0b0100 << 28) | (t << 22)
}

fn esize2(e: Esize) -> u32 {
    match e {
        Esize::B => 0,
        Esize::H => 1,
        Esize::S => 2,
        Esize::D => 3,
    }
}

fn esize_back(v: u32) -> Esize {
    match v & 3 {
        0 => Esize::B,
        1 => Esize::H,
        2 => Esize::S,
        _ => Esize::D,
    }
}

fn cond4(c: Cond) -> u32 {
    match c {
        Cond::Eq => 0,
        Cond::Ne => 1,
        Cond::Hs => 2,
        Cond::Lo => 3,
        Cond::Mi => 4,
        Cond::Pl => 5,
        Cond::Vs => 6,
        Cond::Vc => 7,
        Cond::Hi => 8,
        Cond::Ls => 9,
        Cond::Ge => 10,
        Cond::Lt => 11,
        Cond::Gt => 12,
        Cond::Le => 13,
    }
}

fn cond_back(v: u32) -> Cond {
    [
        Cond::Eq,
        Cond::Ne,
        Cond::Hs,
        Cond::Lo,
        Cond::Mi,
        Cond::Pl,
        Cond::Vs,
        Cond::Vc,
        Cond::Hi,
        Cond::Ls,
        Cond::Ge,
        Cond::Lt,
        Cond::Gt,
        Cond::Le,
    ][(v & 15) as usize]
}

/// Pack the subset of SVE instructions used by the paper's own listings
/// (Figs. 2, 5, 6) into 32-bit words. Branch targets are encoded as
/// 14-bit signed offsets from the instruction index, like real A64
/// PC-relative branches (scaled differently, but faithfully invertible).
pub fn encode(inst: &Inst, at_index: usize) -> Result<u32, NotPackable> {
    use Inst::*;
    Ok(match *inst {
        While { pd, esize, xn, xm, unsigned } => {
            tag(1)
                | (pd as u32)
                | (esize2(esize) << 4)
                | ((xn as u32) << 6)
                | ((xm as u32) << 11)
                | ((unsigned as u32) << 16)
        }
        Ptrue { pd, esize, s } => tag(2) | (pd as u32) | (esize2(esize) << 4) | ((s as u32) << 6),
        Pfalse { pd } => tag(3) | pd as u32,
        Setffr => tag(4),
        Wrffr { pn } => tag(5) | pn as u32,
        Rdffr { pd, pg, s } => {
            tag(6)
                | (pd as u32)
                | ((s as u32) << 4)
                | match pg {
                    Some(g) => 0x20 | ((g as u32) << 6),
                    None => 0,
                }
        }
        Pnext { pdn, pg, esize } => {
            tag(7) | (pdn as u32) | ((pg as u32) << 4) | (esize2(esize) << 8)
        }
        Brk { pd, pg, pn, before, s } => {
            tag(8)
                | (pd as u32)
                | ((pg as u32) << 4)
                | ((pn as u32) << 8)
                | ((before as u32) << 12)
                | ((s as u32) << 13)
        }
        IncDec { xdn, esize, dec } => {
            tag(9) | (xdn as u32) | (esize2(esize) << 5) | ((dec as u32) << 7)
        }
        IncpX { xdn, pm, esize } => {
            tag(10) | (xdn as u32) | ((pm as u32) << 5) | (esize2(esize) << 9)
        }
        SveFmla { zda, pg, zn, zm, dbl, sub } => {
            tag(11)
                | (zda as u32)
                | ((pg as u32) << 5)
                | ((zn as u32) << 8)
                | ((zm as u32) << 13)
                | ((dbl as u32) << 18)
                | ((sub as u32) << 19)
        }
        SveIntCmp { op, unsigned, pd, pg, zn, rhs: ZmOrImm::Imm(imm), esize }
            if (-16..16).contains(&imm) =>
        {
            tag(12)
                | (pd as u32)
                | ((pg as u32) << 4)
                | ((zn as u32) << 7)
                | (((imm & 0x1f) as u32) << 12)
                | (esize2(esize) << 17)
                | ((op as u32 & 7) << 19)
                | ((unsigned as u32) << 21)
        }
        CpyX { zd, pg, xn, esize } => {
            tag(13) | (zd as u32) | ((pg as u32) << 5) | ((xn as u32) << 9) | (esize2(esize) << 14)
        }
        Cterm { xn, xm, ne } => tag(14) | (xn as u32) | ((xm as u32) << 5) | ((ne as u32) << 10),
        SveReduce { op, vd, pg, zn, esize } => {
            tag(15)
                | (vd as u32)
                | ((pg as u32) << 5)
                | ((zn as u32) << 8)
                | (esize2(esize) << 13)
                | ((op as u32 & 7) << 15)
        }
        SveFadda { vdn, pg, zm, dbl } => {
            tag(16) | (vdn as u32) | ((pg as u32) << 5) | ((zm as u32) << 8) | ((dbl as u32) << 13)
        }
        BCond { cond, target } => {
            let off = target as i64 - at_index as i64;
            assert!((-(1 << 13)..(1 << 13)).contains(&off), "branch offset");
            tag(17) | cond4(cond) | (((off & 0x3fff) as u32) << 4)
        }
        DupImm { zd, esize, imm } if (-128..128).contains(&imm) => {
            tag(18) | (zd as u32) | (esize2(esize) << 5) | (((imm & 0xff) as u32) << 7)
        }
        Movprfx { zd, zn, pg: None } => tag(19) | (zd as u32) | ((zn as u32) << 5),
        _ => return Err(NotPackable),
    })
}

/// Inverse of [`encode`] for the packed subset.
pub fn decode(word: u32, at_index: usize) -> Result<Inst, NotPackable> {
    if word >> 28 != 0b0100 {
        return Err(NotPackable);
    }
    let t = (word >> 22) & 0x3f;
    let w = word & ((1 << 22) - 1);
    Ok(match t {
        1 => Inst::While {
            pd: (w & 15) as u8,
            esize: esize_back(w >> 4),
            xn: ((w >> 6) & 31) as u8,
            xm: ((w >> 11) & 31) as u8,
            unsigned: (w >> 16) & 1 == 1,
        },
        2 => Inst::Ptrue { pd: (w & 15) as u8, esize: esize_back(w >> 4), s: (w >> 6) & 1 == 1 },
        3 => Inst::Pfalse { pd: (w & 15) as u8 },
        4 => Inst::Setffr,
        5 => Inst::Wrffr { pn: (w & 15) as u8 },
        6 => Inst::Rdffr {
            pd: (w & 15) as u8,
            s: (w >> 4) & 1 == 1,
            pg: if (w >> 5) & 1 == 1 { Some(((w >> 6) & 15) as u8) } else { None },
        },
        7 => Inst::Pnext {
            pdn: (w & 15) as u8,
            pg: ((w >> 4) & 15) as u8,
            esize: esize_back(w >> 8),
        },
        8 => Inst::Brk {
            pd: (w & 15) as u8,
            pg: ((w >> 4) & 15) as u8,
            pn: ((w >> 8) & 15) as u8,
            before: (w >> 12) & 1 == 1,
            s: (w >> 13) & 1 == 1,
        },
        9 => Inst::IncDec {
            xdn: (w & 31) as u8,
            esize: esize_back(w >> 5),
            dec: (w >> 7) & 1 == 1,
        },
        10 => Inst::IncpX {
            xdn: (w & 31) as u8,
            pm: ((w >> 5) & 15) as u8,
            esize: esize_back(w >> 9),
        },
        11 => Inst::SveFmla {
            zda: (w & 31) as u8,
            pg: ((w >> 5) & 7) as u8,
            zn: ((w >> 8) & 31) as u8,
            zm: ((w >> 13) & 31) as u8,
            dbl: (w >> 18) & 1 == 1,
            sub: (w >> 19) & 1 == 1,
        },
        12 => {
            let imm = {
                let v = ((w >> 12) & 0x1f) as i64;
                if v >= 16 {
                    v - 32
                } else {
                    v
                }
            };
            let ops = [CmpOp::Eq, CmpOp::Ne, CmpOp::Gt, CmpOp::Ge, CmpOp::Lt, CmpOp::Le];
            Inst::SveIntCmp {
                pd: (w & 15) as u8,
                pg: ((w >> 4) & 7) as u8,
                zn: ((w >> 7) & 31) as u8,
                rhs: ZmOrImm::Imm(imm),
                esize: esize_back(w >> 17),
                op: ops[((w >> 19) & 7) as usize % 6],
                unsigned: (w >> 21) & 1 == 1,
            }
        }
        13 => Inst::CpyX {
            zd: (w & 31) as u8,
            pg: ((w >> 5) & 15) as u8,
            xn: ((w >> 9) & 31) as u8,
            esize: esize_back(w >> 14),
        },
        14 => Inst::Cterm {
            xn: (w & 31) as u8,
            xm: ((w >> 5) & 31) as u8,
            ne: (w >> 10) & 1 == 1,
        },
        15 => {
            let ops = [
                RedOp::FAddV,
                RedOp::FMaxV,
                RedOp::FMinV,
                RedOp::EorV,
                RedOp::OrV,
                RedOp::AndV,
                RedOp::UAddV,
                RedOp::SMaxV,
            ];
            Inst::SveReduce {
                vd: (w & 31) as u8,
                pg: ((w >> 5) & 7) as u8,
                zn: ((w >> 8) & 31) as u8,
                esize: esize_back(w >> 13),
                op: ops[((w >> 15) & 7) as usize],
            }
        }
        16 => Inst::SveFadda {
            vdn: (w & 31) as u8,
            pg: ((w >> 5) & 7) as u8,
            zm: ((w >> 8) & 31) as u8,
            dbl: (w >> 13) & 1 == 1,
        },
        17 => {
            let raw = ((w >> 4) & 0x3fff) as i64;
            let off = if raw >= 1 << 13 { raw - (1 << 14) } else { raw };
            Inst::BCond { cond: cond_back(w), target: (at_index as i64 + off) as usize }
        }
        18 => {
            let raw = ((w >> 7) & 0xff) as i64;
            let imm = if raw >= 128 { raw - 256 } else { raw };
            Inst::DupImm { zd: (w & 31) as u8, esize: esize_back(w >> 5), imm }
        }
        19 => Inst::Movprfx { zd: (w & 31) as u8, zn: ((w >> 5) & 31) as u8, pg: None },
        _ => return Err(NotPackable),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;

    #[test]
    fn fig7_sve_fits_one_28bit_region() {
        let (_, total) = sve_region_report();
        assert!(
            total < SVE_REGION_POINTS,
            "SVE must fit the 28-bit region: used {total} of {SVE_REGION_POINTS}"
        );
        // ... while leaving "some room for future expansion" (Fig. 7b)
        assert!(
            total < SVE_REGION_POINTS * 9 / 10,
            "expansion headroom expected, used {total}"
        );
    }

    #[test]
    fn section4_constructive_counterfactual_blows_budget() {
        let (destructive, constructive) = constructive_counterfactual();
        // the rejected design exceeds the whole 28-bit region on the
        // data-processing set ALONE ("would have easily exceeded the
        // projected encoding budget")
        assert!(
            constructive > SVE_REGION_POINTS * 2,
            "fully-constructive predicated forms must exceed the region \
             ({constructive} vs {SVE_REGION_POINTS})"
        );
        // the adopted design spends a small fraction of the region on it
        assert!(destructive < SVE_REGION_POINTS / 20);
        assert_eq!(constructive / destructive, 64, "the tradeoff is 2^6 per opcode");
    }

    #[test]
    fn groups_cover_every_paper_mechanism() {
        let (groups, _) = sve_region_report();
        let names: Vec<&str> = groups.iter().map(|g| g.group.as_str()).collect();
        for g in ["int-dp", "fp-dp", "pred-gen", "pred-ops", "mem", "horiz", "permute", "count"] {
            assert!(names.contains(&g), "missing group {g}");
        }
    }

    #[test]
    fn encode_decode_roundtrip_fig2_loop() {
        // the actual instructions of Fig. 2c
        let insts = vec![
            Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false },
            Inst::SveFmla { zda: 2, pg: 0, zn: 1, zm: 0, dbl: true, sub: false },
            Inst::IncDec { xdn: 4, esize: Esize::D, dec: false },
            Inst::BCond { cond: Cond::FIRST, target: 2 },
        ];
        for (i, inst) in insts.iter().enumerate() {
            let word = encode(inst, i).expect("packable");
            assert_eq!(&decode(word, i).unwrap(), inst, "at {i}");
            assert_eq!(word >> 28, 0b0100, "SVE region tag");
        }
    }

    #[test]
    fn prop_roundtrip_random_instructions() {
        check("prop_roundtrip_random_instructions", 500, |g| {
            let esizes = Esize::ALL;
            let inst = match g.usize_in(0, 9) {
                0 => Inst::While {
                    pd: g.usize_in(0, 15) as u8,
                    esize: *g.choose(&esizes),
                    xn: g.usize_in(0, 31) as u8,
                    xm: g.usize_in(0, 31) as u8,
                    unsigned: g.bool(),
                },
                1 => Inst::Brk {
                    pd: g.usize_in(0, 15) as u8,
                    pg: g.usize_in(0, 15) as u8,
                    pn: g.usize_in(0, 15) as u8,
                    before: g.bool(),
                    s: g.bool(),
                },
                2 => Inst::SveFmla {
                    zda: g.usize_in(0, 31) as u8,
                    pg: g.usize_in(0, 7) as u8,
                    zn: g.usize_in(0, 31) as u8,
                    zm: g.usize_in(0, 31) as u8,
                    dbl: g.bool(),
                    sub: g.bool(),
                },
                3 => Inst::Pnext {
                    pdn: g.usize_in(0, 15) as u8,
                    pg: g.usize_in(0, 15) as u8,
                    esize: *g.choose(&esizes),
                },
                4 => Inst::IncpX {
                    xdn: g.usize_in(0, 31) as u8,
                    pm: g.usize_in(0, 15) as u8,
                    esize: *g.choose(&esizes),
                },
                5 => Inst::CpyX {
                    zd: g.usize_in(0, 31) as u8,
                    pg: g.usize_in(0, 15) as u8,
                    xn: g.usize_in(0, 31) as u8,
                    esize: *g.choose(&esizes),
                },
                6 => Inst::Cterm {
                    xn: g.usize_in(0, 31) as u8,
                    xm: g.usize_in(0, 31) as u8,
                    ne: g.bool(),
                },
                7 => Inst::SveFadda {
                    vdn: g.usize_in(0, 31) as u8,
                    pg: g.usize_in(0, 7) as u8,
                    zm: g.usize_in(0, 31) as u8,
                    dbl: g.bool(),
                },
                8 => Inst::DupImm {
                    zd: g.usize_in(0, 31) as u8,
                    esize: *g.choose(&esizes),
                    imm: g.i64_in(-128, 127),
                },
                _ => Inst::Rdffr {
                    pd: g.usize_in(0, 15) as u8,
                    pg: if g.bool() { Some(g.usize_in(0, 15) as u8) } else { None },
                    s: g.bool(),
                },
            };
            let at = g.usize_in(0, 1000);
            let word = encode(&inst, at).expect("packable subset");
            assert_eq!(decode(word, at).unwrap(), inst);
        });
    }

    #[test]
    fn branch_offsets_are_pc_relative() {
        check("branch_offsets_are_pc_relative", 200, |g| {
            let at = g.usize_in(100, 5000);
            let target = (at as i64 + g.i64_in(-100, 100)) as usize;
            let inst = Inst::BCond { cond: Cond::LAST, target };
            let w = encode(&inst, at).unwrap();
            assert_eq!(decode(w, at).unwrap(), inst);
            // decoding at a different index must shift the target equally
            let shifted = decode(w, at + 10).unwrap();
            match shifted {
                Inst::BCond { target: t2, .. } => assert_eq!(t2, target + 10),
                _ => panic!(),
            }
        });
    }

    #[test]
    fn unencodable_instructions_are_rejected() {
        assert_eq!(encode(&Inst::Halt, 0), Err(NotPackable));
        assert!(decode(0xF000_0000, 0).is_err(), "wrong region");
    }
}
