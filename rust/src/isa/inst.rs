//! The instruction enum and its static metadata (µop class, register
//! dependencies, disassembly).
//!
//! Register-field conventions follow the A64 assembly the paper uses:
//! `x*` general registers (31 = xzr), `d*/s*` scalar FP views of the
//! vector file, `v*` NEON views (low 128 bits), `z*` SVE vectors, `p*`
//! predicates. The enum is interpreted directly by [`crate::exec`]; the
//! separate [`super::encoding`] module maps it into the Fig. 7 encoding
//! budget.

use crate::arch::{Cond, Esize};

/// Scalar memory operand offset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemOff {
    /// `[xn, #imm]`
    Imm(i64),
    /// `[xn, xm, lsl #s]`
    RegLsl(u8, u8),
}

/// SVE contiguous memory offset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SveMemOff {
    /// `[xn, #imm, mul vl]` — imm is in whole-vector units.
    ImmVl(i64),
    /// `[xn, xm, lsl #log2(esize)]` — element-scaled index register.
    RegScaled(u8),
}

/// Gather/scatter addressing (§4: "rich addressing modes").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GatherAddr {
    /// `[zn.d, #imm]` — vector of base addresses plus immediate.
    VecImm(u8, i64),
    /// `[xn, zm.d]` (`scaled`: index shifted by log2 esize; `sxtw`
    /// variants are folded into the executor's sign handling).
    BaseVec { xn: u8, zm: u8, scaled: bool },
}

/// Second operand of SVE integer compares.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ZmOrImm {
    Z(u8),
    Imm(i64),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RegOrImm {
    Reg(u8),
    Imm(i64),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IntOp {
    Add,
    Sub,
    Mul,
    SMax,
    SMin,
    UMax,
    UMin,
    And,
    Orr,
    Eor,
    Lsl,
    Lsr,
    Asr,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FpOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FpUnOp {
    Sqrt,
    Neg,
    Abs,
    Recpe,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Eq,
    Ne,
    Gt,
    Ge,
    Lt,
    Le,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RedOp {
    FAddV,
    FMaxV,
    FMinV,
    EorV,
    OrV,
    AndV,
    UAddV,
    SMaxV,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PLogicOp {
    And,
    Orr,
    Eor,
    Bic,
}

/// Opaque scalar math functions — stand-ins for libm calls the paper's
/// toolchain could not vectorize (§5: "did not have vectorized versions
/// of some basic math library functions such as pow() and log()").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpaqueFn {
    Exp,
    Log,
    Pow,
    Sqrt,
    Sin,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Inst {
    // ===================== AArch64 scalar =====================
    MovImm { xd: u8, imm: u64 },
    MovReg { xd: u8, xn: u8 },
    AddImm { xd: u8, xn: u8, imm: i64 },
    AddReg { xd: u8, xn: u8, xm: u8, lsl: u8 },
    SubReg { xd: u8, xn: u8, xm: u8 },
    /// xd = xa + xn*xm (`mul` = `madd xd, xn, xm, xzr`)
    Madd { xd: u8, xn: u8, xm: u8, xa: u8 },
    Udiv { xd: u8, xn: u8, xm: u8 },
    AndImm { xd: u8, xn: u8, imm: u64 },
    LogReg { op: PLogicOp, xd: u8, xn: u8, xm: u8 },
    LslImm { xd: u8, xn: u8, sh: u8 },
    LsrImm { xd: u8, xn: u8, sh: u8 },
    AsrImm { xd: u8, xn: u8, sh: u8 },
    Csel { xd: u8, xn: u8, xm: u8, cond: Cond },
    /// Scalar integer load; `size` in bytes, `signed` sign-extends.
    Ldr { size: u8, signed: bool, xt: u8, base: u8, off: MemOff },
    Str { size: u8, xt: u8, base: u8, off: MemOff },
    /// Scalar FP load/store (`dbl`: d-register vs s-register).
    LdrFp { dbl: bool, vt: u8, base: u8, off: MemOff },
    StrFp { dbl: bool, vt: u8, base: u8, off: MemOff },
    CmpImm { xn: u8, imm: u64 },
    CmpReg { xn: u8, xm: u8 },
    B { target: usize },
    BCond { cond: Cond, target: usize },
    Cbz { xn: u8, target: usize },
    Cbnz { xn: u8, target: usize },
    Ret,
    /// Stop simulation (top-level return).
    Halt,
    Nop,

    // ===================== scalar FP =====================
    FmovImm { dbl: bool, dd: u8, bits: u64 },
    FmovXtoD { dd: u8, xn: u8 },
    /// Scalar FP register move (fmov dd, dn).
    FmovReg { dbl: bool, dd: u8, dn: u8 },
    FmovDtoX { xd: u8, dn: u8 },
    FpBin { op: FpOp, dbl: bool, dd: u8, dn: u8, dm: u8 },
    FpUn { op: FpUnOp, dbl: bool, dd: u8, dn: u8 },
    /// dd = da + dn*dm (fmsub when `sub`)
    Fmadd { dbl: bool, dd: u8, dn: u8, dm: u8, da: u8, sub: bool },
    Fcmp { dbl: bool, dn: u8, dm: u8 },
    /// signed int -> fp
    Scvtf { dbl: bool, dd: u8, xn: u8 },
    /// fp -> signed int (round toward zero)
    Fcvtzs { dbl: bool, xd: u8, dn: u8 },
    /// Opaque scalar libm call (1 or 2 args).
    OpaqueCall { f: OpaqueFn, dd: u8, dn: u8, dm: Option<u8> },

    // ===================== Advanced SIMD (NEON) =====================
    NeonLd1 { esize: Esize, vt: u8, base: u8, off: MemOff },
    NeonSt1 { esize: Esize, vt: u8, base: u8, off: MemOff },
    NeonDupX { esize: Esize, vd: u8, xn: u8 },
    /// Broadcast lane 0 of `vn` (dup vd.2d, vn.d[0]).
    NeonDupLane0 { esize: Esize, vd: u8, vn: u8 },
    NeonMoviZero { vd: u8 },
    NeonFpBin { op: FpOp, dbl: bool, vd: u8, vn: u8, vm: u8 },
    NeonFpUn { op: FpUnOp, dbl: bool, vd: u8, vn: u8 },
    NeonFmla { dbl: bool, vd: u8, vn: u8, vm: u8, sub: bool },
    NeonIntBin { op: IntOp, esize: Esize, vd: u8, vn: u8, vm: u8 },
    NeonFcm { op: CmpOp, dbl: bool, vd: u8, vn: u8, vm: u8 },
    NeonCm { op: CmpOp, esize: Esize, vd: u8, vn: u8, vm: u8 },
    /// Bitwise select: vd = (vd & vn) | (!vd & vm).
    NeonBsl { vd: u8, vn: u8, vm: u8 },
    /// Horizontal reduce to scalar fp register (models the faddp chain).
    NeonFaddv { dbl: bool, dd: u8, vn: u8 },
    NeonAddv { esize: Esize, dd: u8, vn: u8 },
    NeonUmov { esize: Esize, xd: u8, vn: u8, lane: u8 },
    NeonInsX { esize: Esize, vd: u8, lane: u8, xn: u8 },

    // ===================== SVE predicates =====================
    Ptrue { pd: u8, esize: Esize, s: bool },
    Pfalse { pd: u8 },
    /// `whilelt` (signed) / `whilelo` (unsigned) — §2.3.2.
    While { pd: u8, esize: Esize, xn: u8, xm: u8, unsigned: bool },
    Ptest { pg: u8, pn: u8 },
    /// §2.3.5 — advance to the next active element.
    Pnext { pdn: u8, pg: u8, esize: Esize },
    /// brka/brkb (zeroing form) — §2.3.4 vector partitioning.
    Brk { pd: u8, pg: u8, pn: u8, before: bool, s: bool },
    PredLogic { op: PLogicOp, pd: u8, pg: u8, pn: u8, pm: u8, s: bool },
    /// rdffr pd.b[, pg/z] — §2.3.3.
    Rdffr { pd: u8, pg: Option<u8>, s: bool },
    Setffr,
    Wrffr { pn: u8 },

    // ===================== SVE counting / induction =====================
    /// cntb/cnth/cntw/cntd xd (pattern ALL).
    Cnt { xd: u8, esize: Esize },
    /// incb/inch/incw/incd (or dec*) xdn.
    IncDec { xdn: u8, esize: Esize, dec: bool },
    /// incp xdn, pm.<e> — add active-lane count (Fig. 5 `incp`).
    IncpX { xdn: u8, pm: u8, esize: Esize },
    /// index zd.<e>, base, step — §3.1 induction-variable support.
    Index { zd: u8, esize: Esize, base: RegOrImm, step: RegOrImm },

    // ===================== SVE data movement =====================
    DupImm { zd: u8, esize: Esize, imm: i64 },
    FdupImm { zd: u8, dbl: bool, bits: u64 },
    DupX { zd: u8, esize: Esize, xn: u8 },
    /// cpy zd.<e>, pg/m, xn — Fig. 6's scalar insert.
    CpyX { zd: u8, pg: u8, xn: u8, esize: Esize },
    Sel { zd: u8, pg: u8, zn: u8, zm: u8, esize: Esize },
    /// §4 — constructive prefix; pg None = unpredicated form.
    Movprfx { zd: u8, zn: u8, pg: Option<(u8, bool)> },
    /// lasta/lastb xd, pg, zn.<e>.
    Last { xd: u8, pg: u8, zn: u8, esize: Esize, before: bool },

    // ===================== SVE memory =====================
    /// Contiguous (first-faulting when `ff`) load, elements of `esize`.
    SveLd1 { zt: u8, pg: u8, esize: Esize, base: u8, off: SveMemOff, ff: bool },
    /// ld1r<esize> — load-and-broadcast (§4).
    SveLd1R { zt: u8, pg: u8, esize: Esize, base: u8, imm: i64 },
    SveSt1 { zt: u8, pg: u8, esize: Esize, base: u8, off: SveMemOff },
    /// Gather load (first-faulting when `ff`), 32/64-bit elements.
    SveLdGather { zt: u8, pg: u8, esize: Esize, addr: GatherAddr, ff: bool },
    SveStScatter { zt: u8, pg: u8, esize: Esize, addr: GatherAddr },

    // ===================== SVE arithmetic =====================
    /// Predicated destructive integer ops (§4 encoding tradeoff).
    SveIntBin { op: IntOp, zdn: u8, pg: u8, zm: u8, esize: Esize },
    /// Unpredicated constructive forms of the most common opcodes (§4).
    SveIntBinU { op: IntOp, zd: u8, zn: u8, zm: u8, esize: Esize },
    SveAddImm { zdn: u8, esize: Esize, imm: u64 },
    /// Predicated destructive FP ops.
    SveFpBin { op: FpOp, zdn: u8, pg: u8, zm: u8, dbl: bool },
    /// Predicated merging FP unary (fsqrt zd, pg/m, zn).
    SveFpUn { op: FpUnOp, zd: u8, pg: u8, zn: u8, dbl: bool },
    /// fmla/fmls zda, pg/m, zn, zm.
    SveFmla { zda: u8, pg: u8, zn: u8, zm: u8, dbl: bool, sub: bool },
    /// scvtf zd.<fp>, pg/m, zn.<int> (same-width int->fp).
    SveScvtf { zd: u8, pg: u8, zn: u8, dbl: bool },

    // ===================== SVE compares =====================
    SveIntCmp { op: CmpOp, unsigned: bool, pd: u8, pg: u8, zn: u8, rhs: ZmOrImm, esize: Esize },
    /// FP compare against vector or #0.0 (rhs None).
    SveFpCmp { op: CmpOp, pd: u8, pg: u8, zn: u8, rhs: Option<u8>, dbl: bool },

    // ===================== SVE horizontal (§2.4) =====================
    /// Tree reductions into a scalar FP/int register.
    SveReduce { op: RedOp, vd: u8, pg: u8, zn: u8, esize: Esize },
    /// Strictly-ordered FP accumulate: vdn = vdn + sum-in-order(zm).
    SveFadda { vdn: u8, pg: u8, zm: u8, dbl: bool },

    // ===================== SVE permutes =====================
    SveRev { zd: u8, zn: u8, esize: Esize },
    SveExt { zdn: u8, zm: u8, imm: u8 },
    SveZip { zd: u8, zn: u8, zm: u8, esize: Esize, hi: bool },
    SveUzp { zd: u8, zn: u8, zm: u8, esize: Esize, odd: bool },
    SveTrn { zd: u8, zn: u8, zm: u8, esize: Esize, odd: bool },
    SveTbl { zd: u8, zn: u8, zm: u8, esize: Esize },
    SveCompact { zd: u8, pg: u8, zn: u8, esize: Esize },
    SveSplice { zdn: u8, pg: u8, zm: u8, esize: Esize },

    // ===================== SVE termination (§2.3.5) =====================
    /// ctermeq/ctermne xn, xm.
    Cterm { xn: u8, xm: u8, ne: bool },
}

/// µop class for the timing model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UopClass {
    IntAlu,
    IntMul,
    IntDiv,
    Branch,
    FpAdd,
    FpMul,
    FpFma,
    FpDiv,
    FpSqrt,
    FpCmp,
    FpMov,
    OpaqueCall,
    VecIntAlu,
    VecFpAdd,
    VecFpMul,
    VecFpFma,
    VecFpDiv,
    VecFpSqrt,
    VecCmp,
    PredOp,
    /// Cross-lane tree reduction — VL-proportional penalty (§5).
    VecReduceTree,
    /// Strictly-ordered reduction — latency ∝ active lanes.
    VecReduceOrdered,
    /// Cross-lane permute — VL-proportional penalty (§5).
    VecPermute,
    ScalarLoad,
    ScalarStore,
    VecLoad,
    VecStore,
    VecLoadBcast,
    /// Cracked into per-element accesses by the LSU (§4, §5).
    VecGather,
    VecScatter,
    Nop,
}

/// Number of [`UopClass`] variants — the length of [`UopClass::ALL`]
/// and of every per-class counter array in the timing/energy models.
pub const NUM_UOP_CLASSES: usize = 31;

impl UopClass {
    /// Every class, in declaration order — the canonical indexing for
    /// per-class counter arrays (`class as usize` == position here).
    pub const ALL: [UopClass; NUM_UOP_CLASSES] = [
        UopClass::IntAlu,
        UopClass::IntMul,
        UopClass::IntDiv,
        UopClass::Branch,
        UopClass::FpAdd,
        UopClass::FpMul,
        UopClass::FpFma,
        UopClass::FpDiv,
        UopClass::FpSqrt,
        UopClass::FpCmp,
        UopClass::FpMov,
        UopClass::OpaqueCall,
        UopClass::VecIntAlu,
        UopClass::VecFpAdd,
        UopClass::VecFpMul,
        UopClass::VecFpFma,
        UopClass::VecFpDiv,
        UopClass::VecFpSqrt,
        UopClass::VecCmp,
        UopClass::PredOp,
        UopClass::VecReduceTree,
        UopClass::VecReduceOrdered,
        UopClass::VecPermute,
        UopClass::ScalarLoad,
        UopClass::ScalarStore,
        UopClass::VecLoad,
        UopClass::VecStore,
        UopClass::VecLoadBcast,
        UopClass::VecGather,
        UopClass::VecScatter,
        UopClass::Nop,
    ];

    /// Position in [`UopClass::ALL`] (the discriminant).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable lower-snake name, used in job files and reports.
    pub fn name(self) -> &'static str {
        match self {
            UopClass::IntAlu => "int_alu",
            UopClass::IntMul => "int_mul",
            UopClass::IntDiv => "int_div",
            UopClass::Branch => "branch",
            UopClass::FpAdd => "fp_add",
            UopClass::FpMul => "fp_mul",
            UopClass::FpFma => "fp_fma",
            UopClass::FpDiv => "fp_div",
            UopClass::FpSqrt => "fp_sqrt",
            UopClass::FpCmp => "fp_cmp",
            UopClass::FpMov => "fp_mov",
            UopClass::OpaqueCall => "opaque_call",
            UopClass::VecIntAlu => "vec_int_alu",
            UopClass::VecFpAdd => "vec_fp_add",
            UopClass::VecFpMul => "vec_fp_mul",
            UopClass::VecFpFma => "vec_fp_fma",
            UopClass::VecFpDiv => "vec_fp_div",
            UopClass::VecFpSqrt => "vec_fp_sqrt",
            UopClass::VecCmp => "vec_cmp",
            UopClass::PredOp => "pred_op",
            UopClass::VecReduceTree => "vec_reduce_tree",
            UopClass::VecReduceOrdered => "vec_reduce_ordered",
            UopClass::VecPermute => "vec_permute",
            UopClass::ScalarLoad => "scalar_load",
            UopClass::ScalarStore => "scalar_store",
            UopClass::VecLoad => "vec_load",
            UopClass::VecStore => "vec_store",
            UopClass::VecLoadBcast => "vec_load_bcast",
            UopClass::VecGather => "vec_gather",
            UopClass::VecScatter => "vec_scatter",
            UopClass::Nop => "nop",
        }
    }

    /// Vector (SVE or NEON) instruction class?
    pub fn is_vector(self) -> bool {
        matches!(
            self,
            UopClass::VecIntAlu
                | UopClass::VecFpAdd
                | UopClass::VecFpMul
                | UopClass::VecFpFma
                | UopClass::VecFpDiv
                | UopClass::VecFpSqrt
                | UopClass::VecCmp
                | UopClass::PredOp
                | UopClass::VecReduceTree
                | UopClass::VecReduceOrdered
                | UopClass::VecPermute
                | UopClass::VecLoad
                | UopClass::VecStore
                | UopClass::VecLoadBcast
                | UopClass::VecGather
                | UopClass::VecScatter
        )
    }

    pub fn is_mem(self) -> bool {
        matches!(
            self,
            UopClass::ScalarLoad
                | UopClass::ScalarStore
                | UopClass::VecLoad
                | UopClass::VecStore
                | UopClass::VecLoadBcast
                | UopClass::VecGather
                | UopClass::VecScatter
        )
    }

    pub fn is_cross_lane(self) -> bool {
        matches!(
            self,
            UopClass::VecReduceTree | UopClass::VecReduceOrdered | UopClass::VecPermute
        )
    }
}

/// Architectural register identity, for dependence tracking.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RegId {
    X(u8),
    /// Whole vector register (Z view; V and D/S views alias it).
    Z(u8),
    P(u8),
    Ffr,
    Nzcv,
}

impl Inst {
    /// µop class (timing).
    pub fn class(&self) -> UopClass {
        use Inst::*;
        use UopClass as C;
        match self {
            MovImm { .. } | MovReg { .. } | AddImm { .. } | AddReg { .. } | SubReg { .. }
            | AndImm { .. } | LogReg { .. } | LslImm { .. } | LsrImm { .. } | AsrImm { .. }
            | Csel { .. } | CmpImm { .. } | CmpReg { .. } => C::IntAlu,
            Madd { .. } => C::IntMul,
            Udiv { .. } => C::IntDiv,
            Ldr { .. } | LdrFp { .. } => C::ScalarLoad,
            Str { .. } | StrFp { .. } => C::ScalarStore,
            B { .. } | BCond { .. } | Cbz { .. } | Cbnz { .. } | Ret | Halt => C::Branch,
            Nop => C::Nop,
            FmovImm { .. } | FmovXtoD { .. } | FmovDtoX { .. } | FmovReg { .. } => C::FpMov,
            FpBin { op, .. } => match op {
                FpOp::Add | FpOp::Sub | FpOp::Max | FpOp::Min => C::FpAdd,
                FpOp::Mul => C::FpMul,
                FpOp::Div => C::FpDiv,
            },
            FpUn { op, .. } => match op {
                FpUnOp::Sqrt => C::FpSqrt,
                _ => C::FpAdd,
            },
            Fmadd { .. } => C::FpFma,
            Fcmp { .. } => C::FpCmp,
            Scvtf { .. } | Fcvtzs { .. } => C::FpMov,
            OpaqueCall { .. } => C::OpaqueCall,
            NeonLd1 { .. } => C::VecLoad,
            NeonSt1 { .. } => C::VecStore,
            NeonDupX { .. } | NeonDupLane0 { .. } | NeonMoviZero { .. } | NeonInsX { .. } => {
                C::VecIntAlu
            }
            NeonFpBin { op, .. } => match op {
                FpOp::Add | FpOp::Sub | FpOp::Max | FpOp::Min => C::VecFpAdd,
                FpOp::Mul => C::VecFpMul,
                FpOp::Div => C::VecFpDiv,
            },
            NeonFpUn { op, .. } => match op {
                FpUnOp::Sqrt => C::VecFpSqrt,
                _ => C::VecFpAdd,
            },
            NeonFmla { .. } => C::VecFpFma,
            NeonIntBin { .. } => C::VecIntAlu,
            NeonFcm { .. } | NeonCm { .. } => C::VecCmp,
            NeonBsl { .. } => C::VecIntAlu,
            NeonFaddv { .. } | NeonAddv { .. } => C::VecReduceTree,
            NeonUmov { .. } => C::VecPermute,
            Ptrue { .. } | Pfalse { .. } | While { .. } | Ptest { .. } | Pnext { .. }
            | Brk { .. } | PredLogic { .. } | Rdffr { .. } | Setffr | Wrffr { .. } => C::PredOp,
            Cnt { .. } | IncDec { .. } | IncpX { .. } => C::IntAlu,
            Index { .. } => C::VecIntAlu,
            DupImm { .. } | FdupImm { .. } | DupX { .. } | CpyX { .. } | Sel { .. }
            | Movprfx { .. } => C::VecIntAlu,
            Last { .. } => C::VecPermute,
            SveLd1 { .. } => C::VecLoad,
            SveLd1R { .. } => C::VecLoadBcast,
            SveSt1 { .. } => C::VecStore,
            SveLdGather { .. } => C::VecGather,
            SveStScatter { .. } => C::VecScatter,
            SveIntBin { .. } | SveIntBinU { .. } | SveAddImm { .. } => C::VecIntAlu,
            SveFpBin { op, .. } => match op {
                FpOp::Add | FpOp::Sub | FpOp::Max | FpOp::Min => C::VecFpAdd,
                FpOp::Mul => C::VecFpMul,
                FpOp::Div => C::VecFpDiv,
            },
            SveFpUn { op, .. } => match op {
                FpUnOp::Sqrt => C::VecFpSqrt,
                _ => C::VecFpAdd,
            },
            SveFmla { .. } => C::VecFpFma,
            SveScvtf { .. } => C::VecFpAdd,
            SveIntCmp { .. } | SveFpCmp { .. } => C::VecCmp,
            SveReduce { .. } => C::VecReduceTree,
            SveFadda { .. } => C::VecReduceOrdered,
            SveRev { .. } | SveExt { .. } | SveZip { .. } | SveUzp { .. } | SveTrn { .. }
            | SveTbl { .. } | SveCompact { .. } | SveSplice { .. } => C::VecPermute,
            Cterm { .. } => C::IntAlu,
        }
    }

    /// Is this an SVE instruction (for the paper's "extra vectorization"
    /// metric, which counts SVE/NEON vector instructions)?
    pub fn is_sve(&self) -> bool {
        use Inst::*;
        matches!(
            self,
            Ptrue { .. } | Pfalse { .. } | While { .. } | Ptest { .. } | Pnext { .. }
                | Brk { .. } | PredLogic { .. } | Rdffr { .. } | Setffr | Wrffr { .. }
                | Cnt { .. } | IncDec { .. } | IncpX { .. } | Index { .. } | DupImm { .. }
                | FdupImm { .. } | DupX { .. } | CpyX { .. } | Sel { .. } | Movprfx { .. }
                | Last { .. } | SveLd1 { .. } | SveLd1R { .. } | SveSt1 { .. }
                | SveLdGather { .. } | SveStScatter { .. } | SveIntBin { .. }
                | SveIntBinU { .. } | SveAddImm { .. } | SveFpBin { .. } | SveFpUn { .. }
                | SveFmla { .. } | SveScvtf { .. } | SveIntCmp { .. } | SveFpCmp { .. }
                | SveReduce { .. } | SveFadda { .. } | SveRev { .. } | SveExt { .. }
                | SveZip { .. } | SveUzp { .. } | SveTrn { .. } | SveTbl { .. }
                | SveCompact { .. } | SveSplice { .. } | Cterm { .. }
        )
    }

    pub fn is_neon(&self) -> bool {
        use Inst::*;
        matches!(
            self,
            NeonLd1 { .. } | NeonSt1 { .. } | NeonDupX { .. } | NeonDupLane0 { .. }
                | NeonMoviZero { .. } | NeonFpBin { .. } | NeonFpUn { .. } | NeonFmla { .. }
                | NeonIntBin { .. } | NeonFcm { .. } | NeonCm { .. } | NeonBsl { .. }
                | NeonFaddv { .. } | NeonAddv { .. } | NeonUmov { .. } | NeonInsX { .. }
        )
    }

    /// Register reads/writes for dependence tracking. Appends into the
    /// caller-owned buffers (cleared here) to avoid per-inst allocation
    /// on the timed path.
    pub fn deps(&self, reads: &mut Vec<RegId>, writes: &mut Vec<RegId>) {
        use Inst::*;
        use RegId::*;
        reads.clear();
        writes.clear();
        let rx = |r: &mut Vec<RegId>, n: u8| {
            if n != 31 {
                r.push(X(n));
            }
        };
        match *self {
            MovImm { xd, .. } => rx(writes, xd),
            MovReg { xd, xn } => {
                rx(reads, xn);
                rx(writes, xd);
            }
            AddImm { xd, xn, .. } | LslImm { xd, xn, .. } | LsrImm { xd, xn, .. }
            | AsrImm { xd, xn, .. } | AndImm { xd, xn, .. } => {
                rx(reads, xn);
                rx(writes, xd);
            }
            AddReg { xd, xn, xm, .. } | SubReg { xd, xn, xm } | Udiv { xd, xn, xm }
            | LogReg { xd, xn, xm, .. } => {
                rx(reads, xn);
                rx(reads, xm);
                rx(writes, xd);
            }
            Madd { xd, xn, xm, xa } => {
                rx(reads, xn);
                rx(reads, xm);
                rx(reads, xa);
                rx(writes, xd);
            }
            Csel { xd, xn, xm, .. } => {
                rx(reads, xn);
                rx(reads, xm);
                reads.push(Nzcv);
                rx(writes, xd);
            }
            Ldr { xt, base, off, .. } => {
                rx(reads, base);
                if let MemOff::RegLsl(xm, _) = off {
                    rx(reads, xm);
                }
                rx(writes, xt);
            }
            Str { xt, base, off, .. } => {
                rx(reads, xt);
                rx(reads, base);
                if let MemOff::RegLsl(xm, _) = off {
                    rx(reads, xm);
                }
            }
            LdrFp { vt, base, off, .. } => {
                rx(reads, base);
                if let MemOff::RegLsl(xm, _) = off {
                    rx(reads, xm);
                }
                writes.push(Z(vt));
            }
            StrFp { vt, base, off, .. } => {
                reads.push(Z(vt));
                rx(reads, base);
                if let MemOff::RegLsl(xm, _) = off {
                    rx(reads, xm);
                }
            }
            CmpImm { xn, .. } => {
                rx(reads, xn);
                writes.push(Nzcv);
            }
            CmpReg { xn, xm } => {
                rx(reads, xn);
                rx(reads, xm);
                writes.push(Nzcv);
            }
            B { .. } | Ret | Halt | Nop => {}
            BCond { .. } => reads.push(Nzcv),
            Cbz { xn, .. } | Cbnz { xn, .. } => rx(reads, xn),
            FmovImm { dd, .. } => writes.push(Z(dd)),
            FmovXtoD { dd, xn } => {
                rx(reads, xn);
                writes.push(Z(dd));
            }
            FmovReg { dd, dn, .. } => {
                reads.push(Z(dn));
                writes.push(Z(dd));
            }
            FmovDtoX { xd, dn } => {
                reads.push(Z(dn));
                rx(writes, xd);
            }
            FpBin { dd, dn, dm, .. } => {
                reads.push(Z(dn));
                reads.push(Z(dm));
                writes.push(Z(dd));
            }
            FpUn { dd, dn, .. } => {
                reads.push(Z(dn));
                writes.push(Z(dd));
            }
            Fmadd { dd, dn, dm, da, .. } => {
                reads.push(Z(dn));
                reads.push(Z(dm));
                reads.push(Z(da));
                writes.push(Z(dd));
            }
            Fcmp { dn, dm, .. } => {
                reads.push(Z(dn));
                reads.push(Z(dm));
                writes.push(Nzcv);
            }
            Scvtf { dd, xn, .. } => {
                rx(reads, xn);
                writes.push(Z(dd));
            }
            Fcvtzs { xd, dn, .. } => {
                reads.push(Z(dn));
                rx(writes, xd);
            }
            OpaqueCall { dd, dn, dm, .. } => {
                reads.push(Z(dn));
                if let Some(m) = dm {
                    reads.push(Z(m));
                }
                writes.push(Z(dd));
            }
            NeonLd1 { vt, base, off, .. } => {
                rx(reads, base);
                if let MemOff::RegLsl(xm, _) = off {
                    rx(reads, xm);
                }
                writes.push(Z(vt));
            }
            NeonSt1 { vt, base, off, .. } => {
                reads.push(Z(vt));
                rx(reads, base);
                if let MemOff::RegLsl(xm, _) = off {
                    rx(reads, xm);
                }
            }
            NeonDupX { vd, xn, .. } => {
                rx(reads, xn);
                writes.push(Z(vd));
            }
            NeonDupLane0 { vd, vn, .. } => {
                reads.push(Z(vn));
                writes.push(Z(vd));
            }
            NeonMoviZero { vd } => writes.push(Z(vd)),
            NeonFpBin { vd, vn, vm, .. }
            | NeonIntBin { vd, vn, vm, .. }
            | NeonFcm { vd, vn, vm, .. }
            | NeonCm { vd, vn, vm, .. } => {
                reads.push(Z(vn));
                reads.push(Z(vm));
                writes.push(Z(vd));
            }
            NeonFpUn { vd, vn, .. } => {
                reads.push(Z(vn));
                writes.push(Z(vd));
            }
            NeonFmla { vd, vn, vm, .. } => {
                reads.push(Z(vd));
                reads.push(Z(vn));
                reads.push(Z(vm));
                writes.push(Z(vd));
            }
            NeonBsl { vd, vn, vm } => {
                reads.push(Z(vd));
                reads.push(Z(vn));
                reads.push(Z(vm));
                writes.push(Z(vd));
            }
            NeonFaddv { dd, vn, .. } | NeonAddv { dd, vn, .. } => {
                reads.push(Z(vn));
                writes.push(Z(dd));
            }
            NeonUmov { xd, vn, .. } => {
                reads.push(Z(vn));
                rx(writes, xd);
            }
            NeonInsX { vd, xn, .. } => {
                reads.push(Z(vd));
                rx(reads, xn);
                writes.push(Z(vd));
            }
            Ptrue { pd, s, .. } => {
                writes.push(P(pd));
                if s {
                    writes.push(Nzcv);
                }
            }
            Pfalse { pd } => writes.push(P(pd)),
            While { pd, xn, xm, .. } => {
                rx(reads, xn);
                rx(reads, xm);
                writes.push(P(pd));
                writes.push(Nzcv);
            }
            Ptest { pg, pn } => {
                reads.push(P(pg));
                reads.push(P(pn));
                writes.push(Nzcv);
            }
            Pnext { pdn, pg, .. } => {
                reads.push(P(pdn));
                reads.push(P(pg));
                writes.push(P(pdn));
                writes.push(Nzcv);
            }
            Brk { pd, pg, pn, s, .. } => {
                reads.push(P(pg));
                reads.push(P(pn));
                writes.push(P(pd));
                if s {
                    writes.push(Nzcv);
                }
            }
            PredLogic { pd, pg, pn, pm, s, .. } => {
                reads.push(P(pg));
                reads.push(P(pn));
                reads.push(P(pm));
                writes.push(P(pd));
                if s {
                    writes.push(Nzcv);
                }
            }
            Rdffr { pd, pg, s } => {
                reads.push(Ffr);
                if let Some(g) = pg {
                    reads.push(P(g));
                }
                writes.push(P(pd));
                if s {
                    writes.push(Nzcv);
                }
            }
            Setffr => writes.push(Ffr),
            Wrffr { pn } => {
                reads.push(P(pn));
                writes.push(Ffr);
            }
            Cnt { xd, .. } => rx(writes, xd),
            IncDec { xdn, .. } => {
                rx(reads, xdn);
                rx(writes, xdn);
            }
            IncpX { xdn, pm, .. } => {
                rx(reads, xdn);
                reads.push(P(pm));
                rx(writes, xdn);
            }
            Index { zd, base, step, .. } => {
                if let RegOrImm::Reg(r) = base {
                    rx(reads, r);
                }
                if let RegOrImm::Reg(r) = step {
                    rx(reads, r);
                }
                writes.push(Z(zd));
            }
            DupImm { zd, .. } | FdupImm { zd, .. } => writes.push(Z(zd)),
            DupX { zd, xn, .. } => {
                rx(reads, xn);
                writes.push(Z(zd));
            }
            CpyX { zd, pg, xn, .. } => {
                reads.push(Z(zd));
                reads.push(P(pg));
                rx(reads, xn);
                writes.push(Z(zd));
            }
            Sel { zd, pg, zn, zm, .. } => {
                reads.push(P(pg));
                reads.push(Z(zn));
                reads.push(Z(zm));
                writes.push(Z(zd));
            }
            Movprfx { zd, zn, pg } => {
                reads.push(Z(zn));
                if let Some((g, _)) = pg {
                    reads.push(P(g));
                }
                writes.push(Z(zd));
            }
            Last { xd, pg, zn, .. } => {
                reads.push(P(pg));
                reads.push(Z(zn));
                rx(writes, xd);
            }
            SveLd1 { zt, pg, base, off, ff, .. } => {
                reads.push(P(pg));
                rx(reads, base);
                if let SveMemOff::RegScaled(xm) = off {
                    rx(reads, xm);
                }
                if ff {
                    reads.push(Ffr);
                    writes.push(Ffr);
                }
                writes.push(Z(zt));
            }
            SveLd1R { zt, pg, base, .. } => {
                reads.push(P(pg));
                rx(reads, base);
                writes.push(Z(zt));
            }
            SveSt1 { zt, pg, base, off, .. } => {
                reads.push(Z(zt));
                reads.push(P(pg));
                rx(reads, base);
                if let SveMemOff::RegScaled(xm) = off {
                    rx(reads, xm);
                }
            }
            SveLdGather { zt, pg, addr, ff, .. } => {
                reads.push(P(pg));
                match addr {
                    GatherAddr::VecImm(zn, _) => reads.push(Z(zn)),
                    GatherAddr::BaseVec { xn, zm, .. } => {
                        rx(reads, xn);
                        reads.push(Z(zm));
                    }
                }
                if ff {
                    reads.push(Ffr);
                    writes.push(Ffr);
                }
                writes.push(Z(zt));
            }
            SveStScatter { zt, pg, addr, .. } => {
                reads.push(Z(zt));
                reads.push(P(pg));
                match addr {
                    GatherAddr::VecImm(zn, _) => reads.push(Z(zn)),
                    GatherAddr::BaseVec { xn, zm, .. } => {
                        rx(reads, xn);
                        reads.push(Z(zm));
                    }
                }
            }
            SveIntBin { zdn, pg, zm, .. } => {
                reads.push(Z(zdn));
                reads.push(P(pg));
                reads.push(Z(zm));
                writes.push(Z(zdn));
            }
            SveIntBinU { zd, zn, zm, .. } => {
                reads.push(Z(zn));
                reads.push(Z(zm));
                writes.push(Z(zd));
            }
            SveAddImm { zdn, .. } => {
                reads.push(Z(zdn));
                writes.push(Z(zdn));
            }
            SveFpBin { zdn, pg, zm, .. } => {
                reads.push(Z(zdn));
                reads.push(P(pg));
                reads.push(Z(zm));
                writes.push(Z(zdn));
            }
            SveFpUn { zd, pg, zn, .. } => {
                reads.push(Z(zd));
                reads.push(P(pg));
                reads.push(Z(zn));
                writes.push(Z(zd));
            }
            SveFmla { zda, pg, zn, zm, .. } => {
                reads.push(Z(zda));
                reads.push(P(pg));
                reads.push(Z(zn));
                reads.push(Z(zm));
                writes.push(Z(zda));
            }
            SveScvtf { zd, pg, zn, .. } => {
                reads.push(Z(zd));
                reads.push(P(pg));
                reads.push(Z(zn));
                writes.push(Z(zd));
            }
            SveIntCmp { pd, pg, zn, rhs, .. } => {
                reads.push(P(pg));
                reads.push(Z(zn));
                if let ZmOrImm::Z(m) = rhs {
                    reads.push(Z(m));
                }
                writes.push(P(pd));
                writes.push(Nzcv);
            }
            SveFpCmp { pd, pg, zn, rhs, .. } => {
                reads.push(P(pg));
                reads.push(Z(zn));
                if let Some(m) = rhs {
                    reads.push(Z(m));
                }
                writes.push(P(pd));
                writes.push(Nzcv);
            }
            SveReduce { vd, pg, zn, .. } => {
                reads.push(P(pg));
                reads.push(Z(zn));
                writes.push(Z(vd));
            }
            SveFadda { vdn, pg, zm, .. } => {
                reads.push(Z(vdn));
                reads.push(P(pg));
                reads.push(Z(zm));
                writes.push(Z(vdn));
            }
            SveRev { zd, zn, .. } => {
                reads.push(Z(zn));
                writes.push(Z(zd));
            }
            SveExt { zdn, zm, .. } => {
                reads.push(Z(zdn));
                reads.push(Z(zm));
                writes.push(Z(zdn));
            }
            SveZip { zd, zn, zm, .. } | SveUzp { zd, zn, zm, .. } | SveTrn { zd, zn, zm, .. }
            | SveTbl { zd, zn, zm, .. } => {
                reads.push(Z(zn));
                reads.push(Z(zm));
                writes.push(Z(zd));
            }
            SveCompact { zd, pg, zn, .. } => {
                reads.push(P(pg));
                reads.push(Z(zn));
                writes.push(Z(zd));
            }
            SveSplice { zdn, pg, zm, .. } => {
                reads.push(Z(zdn));
                reads.push(P(pg));
                reads.push(Z(zm));
                writes.push(Z(zdn));
            }
            Cterm { xn, xm, .. } => {
                rx(reads, xn);
                rx(reads, xm);
                reads.push(Nzcv);
                writes.push(Nzcv);
            }
        }
    }

    /// Branch target, if this is a direct branch.
    pub fn branch_target(&self) -> Option<usize> {
        match *self {
            Inst::B { target }
            | Inst::BCond { target, .. }
            | Inst::Cbz { target, .. }
            | Inst::Cbnz { target, .. } => Some(target),
            _ => None,
        }
    }

    pub fn is_cond_branch(&self) -> bool {
        matches!(self, Inst::BCond { .. } | Inst::Cbz { .. } | Inst::Cbnz { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_consistent() {
        let i = Inst::SveFmla { zda: 0, pg: 0, zn: 1, zm: 2, dbl: true, sub: false };
        assert_eq!(i.class(), UopClass::VecFpFma);
        assert!(i.class().is_vector());
        assert!(i.is_sve());
        assert!(!i.is_neon());

        let g = Inst::SveLdGather {
            zt: 0,
            pg: 0,
            esize: Esize::D,
            addr: GatherAddr::VecImm(1, 0),
            ff: false,
        };
        assert_eq!(g.class(), UopClass::VecGather);
        assert!(g.class().is_mem());

        let r = Inst::SveFadda { vdn: 0, pg: 0, zm: 1, dbl: true };
        assert!(r.class().is_cross_lane());
    }

    /// `UopClass::ALL` is the canonical per-class counter indexing: it
    /// must walk every discriminant in order, with unique stable names.
    #[test]
    fn uop_class_all_matches_discriminants() {
        for (i, c) in UopClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i, "{c:?} out of order in UopClass::ALL");
        }
        let mut names: Vec<&str> = UopClass::ALL.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), NUM_UOP_CLASSES, "duplicate UopClass::name");
    }

    #[test]
    fn deps_track_reads_and_writes() {
        let mut r = vec![];
        let mut w = vec![];
        Inst::SveFmla { zda: 3, pg: 1, zn: 4, zm: 5, dbl: true, sub: false }.deps(&mut r, &mut w);
        assert!(r.contains(&RegId::Z(3)), "fmla reads its accumulator");
        assert!(r.contains(&RegId::P(1)));
        assert!(r.contains(&RegId::Z(4)) && r.contains(&RegId::Z(5)));
        assert_eq!(w, vec![RegId::Z(3)]);

        Inst::While { pd: 0, esize: Esize::D, xn: 4, xm: 3, unsigned: false }.deps(&mut r, &mut w);
        assert!(w.contains(&RegId::P(0)) && w.contains(&RegId::Nzcv));
    }

    #[test]
    fn xzr_never_appears_in_deps() {
        let mut r = vec![];
        let mut w = vec![];
        Inst::Madd { xd: 0, xn: 31, xm: 2, xa: 31 }.deps(&mut r, &mut w);
        assert!(!r.contains(&RegId::X(31)));
        Inst::MovImm { xd: 31, imm: 5 }.deps(&mut r, &mut w);
        assert!(w.is_empty());
    }

    #[test]
    fn first_fault_loads_read_and_write_ffr() {
        let mut r = vec![];
        let mut w = vec![];
        Inst::SveLd1 {
            zt: 0,
            pg: 0,
            esize: Esize::B,
            base: 1,
            off: SveMemOff::ImmVl(0),
            ff: true,
        }
        .deps(&mut r, &mut w);
        assert!(r.contains(&RegId::Ffr));
        assert!(w.contains(&RegId::Ffr));
    }

    #[test]
    fn branch_helpers() {
        assert_eq!(Inst::B { target: 7 }.branch_target(), Some(7));
        assert!(Inst::BCond { cond: Cond::FIRST, target: 0 }.is_cond_branch());
        assert!(!Inst::B { target: 0 }.is_cond_branch());
        assert_eq!(Inst::Ret.branch_target(), None);
    }
}
