//! Instruction set: AArch64 scalar subset, Advanced SIMD (NEON) 128-bit
//! baseline subset, and the SVE subset covering every mechanism the paper
//! describes (§2), plus the encoding-budget model of Fig. 7 and the
//! shared decode layer ([`uop`]) that lowers instructions into the µop
//! form both the executor and the timing pipeline consume.

pub mod encoding;
mod inst;
pub mod uop;

pub use inst::*;
