//! Pre-decoded µop programs: the single decode layer shared by the
//! functional executor ([`crate::exec`]) and the timing pipeline
//! ([`crate::uarch`]).
//!
//! The paper's implementation model assumes wide SVE instructions are
//! cracked **once at decode** into µops that both execution and timing
//! reason about (§5). This module is that decoder: [`DecodedProgram`]
//! lowers every [`Inst`] of a [`Program`] into a flat array of [`Uop`]s
//! with
//!
//! * a dense dispatch tag ([`UopTag`]) the executor indexes a handler
//!   table with — addressing modes and optional operands are resolved
//!   into distinct tags here, so the hot loop never re-matches enum
//!   payloads;
//! * pre-resolved operand register indices and immediates in uniform
//!   fields (`a`/`b`/`c`/`d`, `imm`/`imm2`, packed `F_*` flags,
//!   [`SubOp`]);
//! * the µop class and a **cracking rule** ([`Crack`]): the decoded
//!   stream is shared across vector lengths and µarch variants (SVE
//!   binaries are VL-agnostic, §2.2), so VL-dependent expansion is
//!   recorded as a rule the dispatch stage resolves against the run's
//!   VL — `Per128b` ops charge one slice per 128 bits of VL,
//!   `PerElem` ops crack into one port slot per active element, which
//!   is exactly what the §PPA energy proxy bills as `cracked_elems`;
//! * the per-pc read/write register dependence sets, pre-mapped onto
//!   the dense scoreboard slots ([`reg_slot`]) the pipeline's renamer
//!   indexes.
//!
//! `Inst` is matched in exactly one place — [`DecodedProgram::decode`]
//! (together with the static-metadata helpers on [`Inst`] itself that
//! it calls). Everything downstream dispatches on [`UopTag`].

use crate::arch::{Cond, Esize};
use crate::asm::Program;
use crate::isa::{
    CmpOp, FpOp, FpUnOp, GatherAddr, Inst, IntOp, MemOff, OpaqueFn, PLogicOp, RedOp, RegId,
    RegOrImm, SveMemOff, UopClass, ZmOrImm,
};

/// Scoreboard size: X0-30 (31) + Z0-31 (32) + P0-15 (16) + FFR + NZCV.
pub const REG_SLOTS: usize = 31 + 32 + 16 + 2;

/// Dense index of an architectural register for the renamer/scoreboard.
/// X31 (xzr) never appears in dependence sets, so slots 0..31 cover the
/// writable X registers.
#[inline]
pub fn reg_slot(r: RegId) -> u8 {
    match r {
        RegId::X(n) => n,
        RegId::Z(n) => 31 + n,
        RegId::P(n) => 63 + n,
        RegId::Ffr => 79,
        RegId::Nzcv => 80,
    }
}

/// Dense dispatch tag of a decoded µop. One tag per *resolved* operation
/// shape: addressing modes ([`MemOff`], [`SveMemOff`], [`GatherAddr`])
/// and optional operands ([`ZmOrImm`], FP-compare-with-zero) become
/// distinct tags at decode so execute-time dispatch is a single indexed
/// call. `Ret` and `Halt` share one tag (identical semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum UopTag {
    // scalar integer
    MovImm,
    MovReg,
    AddImm,
    AddReg,
    SubReg,
    Madd,
    Udiv,
    AndImm,
    LogReg,
    LslImm,
    LsrImm,
    AsrImm,
    Csel,
    LdrImm,
    LdrReg,
    StrImm,
    StrReg,
    LdrFpImm,
    LdrFpReg,
    StrFpImm,
    StrFpReg,
    CmpImm,
    CmpReg,
    B,
    BCond,
    Cbz,
    Cbnz,
    Halt,
    Nop,
    // scalar FP
    FmovImm,
    FmovXtoD,
    FmovReg,
    FmovDtoX,
    FpBin,
    FpUn,
    Fmadd,
    Fcmp,
    Scvtf,
    Fcvtzs,
    OpaqueCall,
    // Advanced SIMD (NEON)
    NeonLd1Imm,
    NeonLd1Reg,
    NeonSt1Imm,
    NeonSt1Reg,
    NeonDupX,
    NeonDupLane0,
    NeonMoviZero,
    NeonFpBin,
    NeonFpUn,
    NeonFmla,
    NeonIntBin,
    NeonFcm,
    NeonCm,
    NeonBsl,
    NeonFaddv,
    NeonAddv,
    NeonUmov,
    NeonInsX,
    // SVE predicates
    Ptrue,
    Pfalse,
    While,
    Ptest,
    Pnext,
    Brk,
    PredLogic,
    Rdffr,
    Setffr,
    Wrffr,
    // SVE counting / induction
    Cnt,
    IncDec,
    IncpX,
    Index,
    // SVE data movement
    DupImm,
    FdupImm,
    DupX,
    CpyX,
    Sel,
    Movprfx,
    Last,
    // SVE memory
    SveLd1ImmVl,
    SveLd1Reg,
    SveLd1R,
    SveSt1ImmVl,
    SveSt1Reg,
    SveGatherVecImm,
    SveGatherBaseVec,
    SveScatterVecImm,
    SveScatterBaseVec,
    // SVE arithmetic
    SveIntBin,
    SveIntBinU,
    SveAddImm,
    SveFpBin,
    SveFpUn,
    SveFmla,
    SveScvtf,
    // SVE compares
    SveIntCmpZ,
    SveIntCmpImm,
    SveFpCmpV,
    SveFpCmp0,
    // SVE horizontal
    SveReduce,
    SveFadda,
    // SVE permutes
    SveRev,
    SveExt,
    SveZip,
    SveUzp,
    SveTrn,
    SveTbl,
    SveCompact,
    SveSplice,
    // SVE termination
    Cterm,
}

impl UopTag {
    /// Number of distinct tags — the executor's dispatch-table size.
    pub const COUNT: usize = UopTag::Cterm as usize + 1;
}

/// Sub-operation selector of a µop (the "function select" lines of the
/// datapath). Accessors panic on a selector/tag mismatch, which can only
/// be a decoder bug.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubOp {
    None,
    Int(IntOp),
    Fp(FpOp),
    FpUn(FpUnOp),
    Cmp(CmpOp),
    Red(RedOp),
    PLogic(PLogicOp),
    Opaque(OpaqueFn),
    Cond(Cond),
}

impl SubOp {
    #[inline]
    pub fn int(self) -> IntOp {
        match self {
            SubOp::Int(op) => op,
            other => unreachable!("decoder bug: wanted IntOp, found {other:?}"),
        }
    }

    #[inline]
    pub fn fp(self) -> FpOp {
        match self {
            SubOp::Fp(op) => op,
            other => unreachable!("decoder bug: wanted FpOp, found {other:?}"),
        }
    }

    #[inline]
    pub fn fp_un(self) -> FpUnOp {
        match self {
            SubOp::FpUn(op) => op,
            other => unreachable!("decoder bug: wanted FpUnOp, found {other:?}"),
        }
    }

    #[inline]
    pub fn cmp(self) -> CmpOp {
        match self {
            SubOp::Cmp(op) => op,
            other => unreachable!("decoder bug: wanted CmpOp, found {other:?}"),
        }
    }

    #[inline]
    pub fn red(self) -> RedOp {
        match self {
            SubOp::Red(op) => op,
            other => unreachable!("decoder bug: wanted RedOp, found {other:?}"),
        }
    }

    #[inline]
    pub fn plogic(self) -> PLogicOp {
        match self {
            SubOp::PLogic(op) => op,
            other => unreachable!("decoder bug: wanted PLogicOp, found {other:?}"),
        }
    }

    #[inline]
    pub fn opaque(self) -> OpaqueFn {
        match self {
            SubOp::Opaque(f) => f,
            other => unreachable!("decoder bug: wanted OpaqueFn, found {other:?}"),
        }
    }

    #[inline]
    pub fn cond(self) -> Cond {
        match self {
            SubOp::Cond(c) => c,
            other => unreachable!("decoder bug: wanted Cond, found {other:?}"),
        }
    }
}

/// How a µop expands beyond one issue slot. The rule is VL-independent
/// (so one decoded program serves every vector length and µarch
/// variant); the dispatch stage resolves it against the executing VL.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Crack {
    /// One µop regardless of VL.
    Unit,
    /// Cross-lane op: one extra slice per 128 bits of VL beyond the
    /// first (`VL/128 - 1` extra cycles × `cross_lane_per_128b`).
    Per128b,
    /// Gather/scatter: cracked by the LSU into one port slot per active
    /// element (§4/§5) — what the §PPA proxy bills as `cracked_elems`.
    PerElem,
}

impl Crack {
    fn of(class: UopClass) -> Crack {
        if class.is_cross_lane() {
            Crack::Per128b
        } else if matches!(class, UopClass::VecGather | UopClass::VecScatter) {
            Crack::PerElem
        } else {
            Crack::Unit
        }
    }

    /// Worst-case µop expansion at `vl_bits` (every lane active) — the
    /// cracking math EXPERIMENTS.md §Decode and §PPA share.
    pub fn max_uops(self, vl_bits: usize, esize: Esize) -> u64 {
        match self {
            Crack::Unit => 1,
            Crack::Per128b => (vl_bits / 128) as u64,
            Crack::PerElem => esize.lanes(vl_bits / 8) as u64,
        }
    }
}

// ---- operand flags (packed into Uop::flags) ----

/// Double-precision (vs single) FP operand width.
pub const F_DBL: u32 = 1 << 0;
/// Sign-extending scalar load.
pub const F_SIGNED: u32 = 1 << 1;
/// Fused-subtract form (fmsub / fmls).
pub const F_SUB: u32 = 1 << 2;
/// First-faulting memory access (§2.3.3).
pub const F_FF: u32 = 1 << 3;
/// Flag-setting form (the Table 1 NZCV overload).
pub const F_SETFLAGS: u32 = 1 << 4;
/// Unsigned compare/while (whilelo, cmphi...).
pub const F_UNSIGNED: u32 = 1 << 5;
/// Before-form (brkb / lastb).
pub const F_BEFORE: u32 = 1 << 6;
/// Alternate-half selector (zip2 / uzp2 / trn2).
pub const F_HI: u32 = 1 << 7;
/// Element-scaled gather index.
pub const F_SCALED: u32 = 1 << 8;
/// Zeroing (vs merging) predication.
pub const F_ZEROING: u32 = 1 << 9;
/// Optional operand present (`c` holds it): OpaqueCall's second
/// argument, Rdffr's / Movprfx's governing predicate.
pub const F_OPT: u32 = 1 << 10;
/// Decrement form of IncDec.
pub const F_DEC: u32 = 1 << 11;
/// ctermne (vs ctermeq).
pub const F_NE: u32 = 1 << 12;
/// Index base is a register (`b`) rather than `imm`.
pub const F_BASE_REG: u32 = 1 << 13;
/// Index step is a register (`c`) rather than `imm2`.
pub const F_STEP_REG: u32 = 1 << 14;

// ---- static metadata flags ----

/// SVE instruction (the paper's dynamic-mix metric).
pub const F_SVE: u32 = 1 << 16;
/// Advanced SIMD instruction.
pub const F_NEON: u32 = 1 << 17;
/// Vector-class µop (`UopClass::is_vector`).
pub const F_VECTOR: u32 = 1 << 18;
/// Conditional branch (feeds the predictor).
pub const F_COND_BRANCH: u32 = 1 << 19;

/// One decoded µop: dense dispatch tag plus pre-resolved operands and
/// static metadata. Field meaning is per-tag (documented alongside the
/// decoder); by convention `a` is the destination (or the data operand
/// of stores) and `b`/`c`/`d` are sources.
#[derive(Clone, Copy, Debug)]
pub struct Uop {
    /// Dispatch tag — index into the executor's handler table.
    pub tag: UopTag,
    /// µop class for the timing model (identical to [`Inst::class`]).
    pub class: UopClass,
    /// VL-independent cracking rule, resolved at dispatch.
    pub crack: Crack,
    /// Destination register (or store-data register).
    pub a: u8,
    /// First source register (governing predicate for predicated ops).
    pub b: u8,
    /// Second source register.
    pub c: u8,
    /// Third source register.
    pub d: u8,
    /// Element size (scalar loads/stores carry their access size here).
    pub esize: Esize,
    /// Packed `F_*` operand + metadata flags.
    pub flags: u32,
    /// Sub-operation selector.
    pub sub: SubOp,
    /// Primary immediate: value, offset, shift amount, branch target,
    /// FP bit pattern, or lane index, per tag.
    pub imm: i64,
    /// Secondary immediate: index-register shift or Index step.
    pub imm2: i64,
    reads_off: u32,
    writes_off: u32,
    reads_len: u8,
    writes_len: u8,
}

impl Uop {
    #[inline]
    pub fn has(&self, flag: u32) -> bool {
        self.flags & flag != 0
    }

    #[inline]
    pub fn dbl(&self) -> bool {
        self.has(F_DBL)
    }

    #[inline]
    pub fn is_sve(&self) -> bool {
        self.has(F_SVE)
    }

    #[inline]
    pub fn is_neon(&self) -> bool {
        self.has(F_NEON)
    }

    #[inline]
    pub fn is_vector(&self) -> bool {
        self.has(F_VECTOR)
    }

    #[inline]
    pub fn is_cond_branch(&self) -> bool {
        self.has(F_COND_BRANCH)
    }

    /// Can this µop redirect the pc or stop the run? Exactly the tags
    /// whose handlers touch `next_pc`/`halted`: every other handler
    /// falls through to pc+1 unconditionally, which is what lets the
    /// executor run straight-line spans ([`DecodedProgram::
    /// straight_lens`]) and the trace engine elide per-µop branch
    /// resolution.
    #[inline]
    pub fn is_control_flow(&self) -> bool {
        matches!(self.tag, UopTag::B | UopTag::BCond | UopTag::Cbz | UopTag::Cbnz | UopTag::Halt)
    }
}

/// A [`Program`] lowered once into µops: the flat decoded array, the
/// original instructions (kept for disassembly/traces), and the arena
/// of pre-mapped register-dependence slots.
///
/// Decoding is a pure function of the program — no VL, no µarch
/// parameter enters it — so one `DecodedProgram` is shared across every
/// vector length and design-space variant of a sweep, and the job-cache
/// keys of [`crate::report::store`] are unaffected by the decode layer.
///
/// ```
/// use sve_repro::asm::Asm;
/// use sve_repro::isa::uop::DecodedProgram;
/// use sve_repro::isa::{Inst, UopClass};
///
/// let mut a = Asm::new();
/// a.push(Inst::MovImm { xd: 3, imm: 7 });
/// a.push(Inst::AddImm { xd: 4, xn: 3, imm: 35 });
/// a.push(Inst::Halt);
/// let dec = DecodedProgram::decode(&a.finish());
///
/// assert_eq!(dec.len(), 3);
/// assert_eq!(dec.uops()[0].class, UopClass::IntAlu);
/// assert_eq!(dec.uops()[1].a, 4); // destination pre-resolved
/// // the add reads x3 (scoreboard slot 3) and writes x4 (slot 4)
/// assert_eq!(dec.reads(&dec.uops()[1]), &[3]);
/// assert_eq!(dec.writes(&dec.uops()[1]), &[4]);
/// ```
#[derive(Clone, Debug, Default)]
pub struct DecodedProgram {
    insts: Vec<Inst>,
    uops: Vec<Uop>,
    dep_pool: Vec<u8>,
    straight: Vec<u32>,
}

impl DecodedProgram {
    /// Lower `prog` into µops — the one `Inst` match in the simulator.
    pub fn decode(prog: &Program) -> DecodedProgram {
        let mut uops = Vec::with_capacity(prog.insts.len());
        let mut dep_pool = Vec::new();
        let mut reads = Vec::new();
        let mut writes = Vec::new();
        for inst in &prog.insts {
            let mut u = lower(inst);
            inst.deps(&mut reads, &mut writes);
            u.reads_off = dep_pool.len() as u32;
            u.reads_len = reads.len() as u8;
            dep_pool.extend(reads.iter().map(|&r| reg_slot(r)));
            u.writes_off = dep_pool.len() as u32;
            u.writes_len = writes.len() as u8;
            dep_pool.extend(writes.iter().map(|&w| reg_slot(w)));
            uops.push(u);
        }
        // straight-line run lengths: how many µops starting at each pc
        // execute before the next possible pc redirect (inclusive of
        // the control µop itself) — the granule the executor meters
        // its instruction budget at
        let mut straight = vec![0u32; uops.len()];
        let mut run = 0u32;
        for (pc, u) in uops.iter().enumerate().rev() {
            run = if u.is_control_flow() { 1 } else { run.saturating_add(1) };
            straight[pc] = run;
        }
        DecodedProgram { insts: prog.insts.clone(), uops, dep_pool, straight }
    }

    /// Number of architectural instructions (== decoded µop slots).
    pub fn len(&self) -> usize {
        self.uops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.uops.is_empty()
    }

    /// The flat decoded µop array, indexed by pc.
    #[inline]
    pub fn uops(&self) -> &[Uop] {
        &self.uops
    }

    /// The source instructions (disassembly/traces only — execution and
    /// timing never re-match these).
    #[inline]
    pub fn insts(&self) -> &[Inst] {
        &self.insts
    }

    /// Scoreboard slots `u` reads, pre-mapped via [`reg_slot`].
    #[inline]
    pub fn reads(&self, u: &Uop) -> &[u8] {
        let off = u.reads_off as usize;
        &self.dep_pool[off..off + u.reads_len as usize]
    }

    /// Scoreboard slots `u` writes, pre-mapped via [`reg_slot`].
    #[inline]
    pub fn writes(&self, u: &Uop) -> &[u8] {
        let off = u.writes_off as usize;
        &self.dep_pool[off..off + u.writes_len as usize]
    }

    /// Straight-line run length at each pc: the number of µops from
    /// that pc up to and including the next control-flow µop
    /// ([`Uop::is_control_flow`]). Within a run only the final µop can
    /// redirect the pc or halt, so the executor checks its instruction
    /// budget once per run instead of once per retire.
    #[inline]
    pub fn straight_lens(&self) -> &[u32] {
        &self.straight
    }
}

/// Access size of a scalar load/store, carried as an [`Esize`].
fn esize_for_bytes(size: u8) -> Esize {
    match size {
        1 => Esize::B,
        2 => Esize::H,
        4 => Esize::S,
        _ => Esize::D,
    }
}

/// Lower one instruction to its µop (deps are filled in by the caller).
fn lower(inst: &Inst) -> Uop {
    use Inst as I;
    use UopTag as T;
    let class = inst.class();
    let mut flags = 0u32;
    if inst.is_sve() {
        flags |= F_SVE;
    }
    if inst.is_neon() {
        flags |= F_NEON;
    }
    if class.is_vector() {
        flags |= F_VECTOR;
    }
    if inst.is_cond_branch() {
        flags |= F_COND_BRANCH;
    }
    let mut u = Uop {
        tag: T::Nop,
        class,
        crack: Crack::of(class),
        a: 0,
        b: 0,
        c: 0,
        d: 0,
        esize: Esize::B,
        flags,
        sub: SubOp::None,
        imm: 0,
        imm2: 0,
        reads_off: 0,
        writes_off: 0,
        reads_len: 0,
        writes_len: 0,
    };
    let set = |u: &mut Uop, f: u32, on: bool| {
        if on {
            u.flags |= f;
        }
    };
    match *inst {
        // ---- scalar integer ----
        I::MovImm { xd, imm } => {
            u.tag = T::MovImm;
            u.a = xd;
            u.imm = imm as i64;
        }
        I::MovReg { xd, xn } => {
            u.tag = T::MovReg;
            u.a = xd;
            u.b = xn;
        }
        I::AddImm { xd, xn, imm } => {
            u.tag = T::AddImm;
            u.a = xd;
            u.b = xn;
            u.imm = imm;
        }
        I::AddReg { xd, xn, xm, lsl } => {
            u.tag = T::AddReg;
            u.a = xd;
            u.b = xn;
            u.c = xm;
            u.imm2 = lsl as i64;
        }
        I::SubReg { xd, xn, xm } => {
            u.tag = T::SubReg;
            u.a = xd;
            u.b = xn;
            u.c = xm;
        }
        I::Madd { xd, xn, xm, xa } => {
            u.tag = T::Madd;
            u.a = xd;
            u.b = xn;
            u.c = xm;
            u.d = xa;
        }
        I::Udiv { xd, xn, xm } => {
            u.tag = T::Udiv;
            u.a = xd;
            u.b = xn;
            u.c = xm;
        }
        I::AndImm { xd, xn, imm } => {
            u.tag = T::AndImm;
            u.a = xd;
            u.b = xn;
            u.imm = imm as i64;
        }
        I::LogReg { op, xd, xn, xm } => {
            u.tag = T::LogReg;
            u.sub = SubOp::PLogic(op);
            u.a = xd;
            u.b = xn;
            u.c = xm;
        }
        I::LslImm { xd, xn, sh } => {
            u.tag = T::LslImm;
            u.a = xd;
            u.b = xn;
            u.imm = sh as i64;
        }
        I::LsrImm { xd, xn, sh } => {
            u.tag = T::LsrImm;
            u.a = xd;
            u.b = xn;
            u.imm = sh as i64;
        }
        I::AsrImm { xd, xn, sh } => {
            u.tag = T::AsrImm;
            u.a = xd;
            u.b = xn;
            u.imm = sh as i64;
        }
        I::Csel { xd, xn, xm, cond } => {
            u.tag = T::Csel;
            u.sub = SubOp::Cond(cond);
            u.a = xd;
            u.b = xn;
            u.c = xm;
        }
        I::Ldr { size, signed, xt, base, off } => {
            u.a = xt;
            u.b = base;
            u.esize = esize_for_bytes(size);
            set(&mut u, F_SIGNED, signed);
            match off {
                MemOff::Imm(i) => {
                    u.tag = T::LdrImm;
                    u.imm = i;
                }
                MemOff::RegLsl(xm, sh) => {
                    u.tag = T::LdrReg;
                    u.c = xm;
                    u.imm2 = sh as i64;
                }
            }
        }
        I::Str { size, xt, base, off } => {
            u.a = xt;
            u.b = base;
            u.esize = esize_for_bytes(size);
            match off {
                MemOff::Imm(i) => {
                    u.tag = T::StrImm;
                    u.imm = i;
                }
                MemOff::RegLsl(xm, sh) => {
                    u.tag = T::StrReg;
                    u.c = xm;
                    u.imm2 = sh as i64;
                }
            }
        }
        I::LdrFp { dbl, vt, base, off } => {
            u.a = vt;
            u.b = base;
            set(&mut u, F_DBL, dbl);
            match off {
                MemOff::Imm(i) => {
                    u.tag = T::LdrFpImm;
                    u.imm = i;
                }
                MemOff::RegLsl(xm, sh) => {
                    u.tag = T::LdrFpReg;
                    u.c = xm;
                    u.imm2 = sh as i64;
                }
            }
        }
        I::StrFp { dbl, vt, base, off } => {
            u.a = vt;
            u.b = base;
            set(&mut u, F_DBL, dbl);
            match off {
                MemOff::Imm(i) => {
                    u.tag = T::StrFpImm;
                    u.imm = i;
                }
                MemOff::RegLsl(xm, sh) => {
                    u.tag = T::StrFpReg;
                    u.c = xm;
                    u.imm2 = sh as i64;
                }
            }
        }
        I::CmpImm { xn, imm } => {
            u.tag = T::CmpImm;
            u.b = xn;
            u.imm = imm as i64;
        }
        I::CmpReg { xn, xm } => {
            u.tag = T::CmpReg;
            u.b = xn;
            u.c = xm;
        }
        I::B { target } => {
            u.tag = T::B;
            u.imm = target as i64;
        }
        I::BCond { cond, target } => {
            u.tag = T::BCond;
            u.sub = SubOp::Cond(cond);
            u.imm = target as i64;
        }
        I::Cbz { xn, target } => {
            u.tag = T::Cbz;
            u.b = xn;
            u.imm = target as i64;
        }
        I::Cbnz { xn, target } => {
            u.tag = T::Cbnz;
            u.b = xn;
            u.imm = target as i64;
        }
        I::Ret | I::Halt => u.tag = T::Halt,
        I::Nop => u.tag = T::Nop,
        // ---- scalar FP ----
        I::FmovImm { dbl, dd, bits } => {
            u.tag = T::FmovImm;
            u.a = dd;
            u.imm = bits as i64;
            set(&mut u, F_DBL, dbl);
        }
        I::FmovXtoD { dd, xn } => {
            u.tag = T::FmovXtoD;
            u.a = dd;
            u.b = xn;
        }
        I::FmovReg { dbl, dd, dn } => {
            u.tag = T::FmovReg;
            u.a = dd;
            u.b = dn;
            set(&mut u, F_DBL, dbl);
        }
        I::FmovDtoX { xd, dn } => {
            u.tag = T::FmovDtoX;
            u.a = xd;
            u.b = dn;
        }
        I::FpBin { op, dbl, dd, dn, dm } => {
            u.tag = T::FpBin;
            u.sub = SubOp::Fp(op);
            u.a = dd;
            u.b = dn;
            u.c = dm;
            set(&mut u, F_DBL, dbl);
        }
        I::FpUn { op, dbl, dd, dn } => {
            u.tag = T::FpUn;
            u.sub = SubOp::FpUn(op);
            u.a = dd;
            u.b = dn;
            set(&mut u, F_DBL, dbl);
        }
        I::Fmadd { dbl, dd, dn, dm, da, sub } => {
            u.tag = T::Fmadd;
            u.a = dd;
            u.b = dn;
            u.c = dm;
            u.d = da;
            set(&mut u, F_DBL, dbl);
            set(&mut u, F_SUB, sub);
        }
        I::Fcmp { dbl, dn, dm } => {
            u.tag = T::Fcmp;
            u.b = dn;
            u.c = dm;
            set(&mut u, F_DBL, dbl);
        }
        I::Scvtf { dbl, dd, xn } => {
            u.tag = T::Scvtf;
            u.a = dd;
            u.b = xn;
            set(&mut u, F_DBL, dbl);
        }
        I::Fcvtzs { dbl, xd, dn } => {
            u.tag = T::Fcvtzs;
            u.a = xd;
            u.b = dn;
            set(&mut u, F_DBL, dbl);
        }
        I::OpaqueCall { f, dd, dn, dm } => {
            u.tag = T::OpaqueCall;
            u.sub = SubOp::Opaque(f);
            u.a = dd;
            u.b = dn;
            if let Some(m) = dm {
                u.c = m;
                u.flags |= F_OPT;
            }
        }
        // ---- Advanced SIMD (NEON) ----
        I::NeonLd1 { esize, vt, base, off } => {
            u.a = vt;
            u.b = base;
            u.esize = esize;
            match off {
                MemOff::Imm(i) => {
                    u.tag = T::NeonLd1Imm;
                    u.imm = i;
                }
                MemOff::RegLsl(xm, sh) => {
                    u.tag = T::NeonLd1Reg;
                    u.c = xm;
                    u.imm2 = sh as i64;
                }
            }
        }
        I::NeonSt1 { esize, vt, base, off } => {
            u.a = vt;
            u.b = base;
            u.esize = esize;
            match off {
                MemOff::Imm(i) => {
                    u.tag = T::NeonSt1Imm;
                    u.imm = i;
                }
                MemOff::RegLsl(xm, sh) => {
                    u.tag = T::NeonSt1Reg;
                    u.c = xm;
                    u.imm2 = sh as i64;
                }
            }
        }
        I::NeonDupX { esize, vd, xn } => {
            u.tag = T::NeonDupX;
            u.a = vd;
            u.b = xn;
            u.esize = esize;
        }
        I::NeonDupLane0 { esize, vd, vn } => {
            u.tag = T::NeonDupLane0;
            u.a = vd;
            u.b = vn;
            u.esize = esize;
        }
        I::NeonMoviZero { vd } => {
            u.tag = T::NeonMoviZero;
            u.a = vd;
        }
        I::NeonFpBin { op, dbl, vd, vn, vm } => {
            u.tag = T::NeonFpBin;
            u.sub = SubOp::Fp(op);
            u.a = vd;
            u.b = vn;
            u.c = vm;
            set(&mut u, F_DBL, dbl);
        }
        I::NeonFpUn { op, dbl, vd, vn } => {
            u.tag = T::NeonFpUn;
            u.sub = SubOp::FpUn(op);
            u.a = vd;
            u.b = vn;
            set(&mut u, F_DBL, dbl);
        }
        I::NeonFmla { dbl, vd, vn, vm, sub } => {
            u.tag = T::NeonFmla;
            u.a = vd;
            u.b = vn;
            u.c = vm;
            set(&mut u, F_DBL, dbl);
            set(&mut u, F_SUB, sub);
        }
        I::NeonIntBin { op, esize, vd, vn, vm } => {
            u.tag = T::NeonIntBin;
            u.sub = SubOp::Int(op);
            u.a = vd;
            u.b = vn;
            u.c = vm;
            u.esize = esize;
        }
        I::NeonFcm { op, dbl, vd, vn, vm } => {
            u.tag = T::NeonFcm;
            u.sub = SubOp::Cmp(op);
            u.a = vd;
            u.b = vn;
            u.c = vm;
            set(&mut u, F_DBL, dbl);
        }
        I::NeonCm { op, esize, vd, vn, vm } => {
            u.tag = T::NeonCm;
            u.sub = SubOp::Cmp(op);
            u.a = vd;
            u.b = vn;
            u.c = vm;
            u.esize = esize;
        }
        I::NeonBsl { vd, vn, vm } => {
            u.tag = T::NeonBsl;
            u.a = vd;
            u.b = vn;
            u.c = vm;
        }
        I::NeonFaddv { dbl, dd, vn } => {
            u.tag = T::NeonFaddv;
            u.a = dd;
            u.b = vn;
            set(&mut u, F_DBL, dbl);
        }
        I::NeonAddv { esize, dd, vn } => {
            u.tag = T::NeonAddv;
            u.a = dd;
            u.b = vn;
            u.esize = esize;
        }
        I::NeonUmov { esize, xd, vn, lane } => {
            u.tag = T::NeonUmov;
            u.a = xd;
            u.b = vn;
            u.esize = esize;
            u.imm = lane as i64;
        }
        I::NeonInsX { esize, vd, lane, xn } => {
            u.tag = T::NeonInsX;
            u.a = vd;
            u.b = xn;
            u.esize = esize;
            u.imm = lane as i64;
        }
        // ---- SVE predicates ----
        I::Ptrue { pd, esize, s } => {
            u.tag = T::Ptrue;
            u.a = pd;
            u.esize = esize;
            set(&mut u, F_SETFLAGS, s);
        }
        I::Pfalse { pd } => {
            u.tag = T::Pfalse;
            u.a = pd;
        }
        I::While { pd, esize, xn, xm, unsigned } => {
            u.tag = T::While;
            u.a = pd;
            u.b = xn;
            u.c = xm;
            u.esize = esize;
            set(&mut u, F_UNSIGNED, unsigned);
        }
        I::Ptest { pg, pn } => {
            u.tag = T::Ptest;
            u.b = pg;
            u.c = pn;
        }
        I::Pnext { pdn, pg, esize } => {
            u.tag = T::Pnext;
            u.a = pdn;
            u.b = pg;
            u.esize = esize;
        }
        I::Brk { pd, pg, pn, before, s } => {
            u.tag = T::Brk;
            u.a = pd;
            u.b = pg;
            u.c = pn;
            set(&mut u, F_BEFORE, before);
            set(&mut u, F_SETFLAGS, s);
        }
        I::PredLogic { op, pd, pg, pn, pm, s } => {
            u.tag = T::PredLogic;
            u.sub = SubOp::PLogic(op);
            u.a = pd;
            u.b = pg;
            u.c = pn;
            u.d = pm;
            set(&mut u, F_SETFLAGS, s);
        }
        I::Rdffr { pd, pg, s } => {
            u.tag = T::Rdffr;
            u.a = pd;
            if let Some(g) = pg {
                u.c = g;
                u.flags |= F_OPT;
            }
            set(&mut u, F_SETFLAGS, s);
        }
        I::Setffr => u.tag = T::Setffr,
        I::Wrffr { pn } => {
            u.tag = T::Wrffr;
            u.b = pn;
        }
        // ---- SVE counting / induction ----
        I::Cnt { xd, esize } => {
            u.tag = T::Cnt;
            u.a = xd;
            u.esize = esize;
        }
        I::IncDec { xdn, esize, dec } => {
            u.tag = T::IncDec;
            u.a = xdn;
            u.esize = esize;
            set(&mut u, F_DEC, dec);
        }
        I::IncpX { xdn, pm, esize } => {
            u.tag = T::IncpX;
            u.a = xdn;
            u.b = pm;
            u.esize = esize;
        }
        I::Index { zd, esize, base, step } => {
            u.tag = T::Index;
            u.a = zd;
            u.esize = esize;
            match base {
                RegOrImm::Reg(r) => {
                    u.b = r;
                    u.flags |= F_BASE_REG;
                }
                RegOrImm::Imm(i) => u.imm = i,
            }
            match step {
                RegOrImm::Reg(r) => {
                    u.c = r;
                    u.flags |= F_STEP_REG;
                }
                RegOrImm::Imm(i) => u.imm2 = i,
            }
        }
        // ---- SVE data movement ----
        I::DupImm { zd, esize, imm } => {
            u.tag = T::DupImm;
            u.a = zd;
            u.esize = esize;
            u.imm = imm;
        }
        I::FdupImm { zd, dbl, bits } => {
            u.tag = T::FdupImm;
            u.a = zd;
            u.imm = bits as i64;
            set(&mut u, F_DBL, dbl);
        }
        I::DupX { zd, esize, xn } => {
            u.tag = T::DupX;
            u.a = zd;
            u.b = xn;
            u.esize = esize;
        }
        I::CpyX { zd, pg, xn, esize } => {
            u.tag = T::CpyX;
            u.a = zd;
            u.b = pg;
            u.c = xn;
            u.esize = esize;
        }
        I::Sel { zd, pg, zn, zm, esize } => {
            u.tag = T::Sel;
            u.a = zd;
            u.b = pg;
            u.c = zn;
            u.d = zm;
            u.esize = esize;
        }
        I::Movprfx { zd, zn, pg } => {
            u.tag = T::Movprfx;
            u.a = zd;
            u.b = zn;
            if let Some((g, zeroing)) = pg {
                u.c = g;
                u.flags |= F_OPT;
                set(&mut u, F_ZEROING, zeroing);
            }
        }
        I::Last { xd, pg, zn, esize, before } => {
            u.tag = T::Last;
            u.a = xd;
            u.b = pg;
            u.c = zn;
            u.esize = esize;
            set(&mut u, F_BEFORE, before);
        }
        // ---- SVE memory ----
        I::SveLd1 { zt, pg, esize, base, off, ff } => {
            u.a = zt;
            u.b = pg;
            u.c = base;
            u.esize = esize;
            set(&mut u, F_FF, ff);
            match off {
                SveMemOff::ImmVl(i) => {
                    u.tag = T::SveLd1ImmVl;
                    u.imm = i;
                }
                SveMemOff::RegScaled(xm) => {
                    u.tag = T::SveLd1Reg;
                    u.d = xm;
                }
            }
        }
        I::SveLd1R { zt, pg, esize, base, imm } => {
            u.tag = T::SveLd1R;
            u.a = zt;
            u.b = pg;
            u.c = base;
            u.esize = esize;
            u.imm = imm;
        }
        I::SveSt1 { zt, pg, esize, base, off } => {
            u.a = zt;
            u.b = pg;
            u.c = base;
            u.esize = esize;
            match off {
                SveMemOff::ImmVl(i) => {
                    u.tag = T::SveSt1ImmVl;
                    u.imm = i;
                }
                SveMemOff::RegScaled(xm) => {
                    u.tag = T::SveSt1Reg;
                    u.d = xm;
                }
            }
        }
        I::SveLdGather { zt, pg, esize, addr, ff } => {
            u.a = zt;
            u.b = pg;
            u.esize = esize;
            set(&mut u, F_FF, ff);
            match addr {
                GatherAddr::VecImm(zn, i) => {
                    u.tag = T::SveGatherVecImm;
                    u.c = zn;
                    u.imm = i;
                }
                GatherAddr::BaseVec { xn, zm, scaled } => {
                    u.tag = T::SveGatherBaseVec;
                    u.c = xn;
                    u.d = zm;
                    set(&mut u, F_SCALED, scaled);
                }
            }
        }
        I::SveStScatter { zt, pg, esize, addr } => {
            u.a = zt;
            u.b = pg;
            u.esize = esize;
            match addr {
                GatherAddr::VecImm(zn, i) => {
                    u.tag = T::SveScatterVecImm;
                    u.c = zn;
                    u.imm = i;
                }
                GatherAddr::BaseVec { xn, zm, scaled } => {
                    u.tag = T::SveScatterBaseVec;
                    u.c = xn;
                    u.d = zm;
                    set(&mut u, F_SCALED, scaled);
                }
            }
        }
        // ---- SVE arithmetic ----
        I::SveIntBin { op, zdn, pg, zm, esize } => {
            u.tag = T::SveIntBin;
            u.sub = SubOp::Int(op);
            u.a = zdn;
            u.b = pg;
            u.c = zm;
            u.esize = esize;
        }
        I::SveIntBinU { op, zd, zn, zm, esize } => {
            u.tag = T::SveIntBinU;
            u.sub = SubOp::Int(op);
            u.a = zd;
            u.b = zn;
            u.c = zm;
            u.esize = esize;
        }
        I::SveAddImm { zdn, esize, imm } => {
            u.tag = T::SveAddImm;
            u.a = zdn;
            u.esize = esize;
            u.imm = imm as i64;
        }
        I::SveFpBin { op, zdn, pg, zm, dbl } => {
            u.tag = T::SveFpBin;
            u.sub = SubOp::Fp(op);
            u.a = zdn;
            u.b = pg;
            u.c = zm;
            set(&mut u, F_DBL, dbl);
        }
        I::SveFpUn { op, zd, pg, zn, dbl } => {
            u.tag = T::SveFpUn;
            u.sub = SubOp::FpUn(op);
            u.a = zd;
            u.b = pg;
            u.c = zn;
            set(&mut u, F_DBL, dbl);
        }
        I::SveFmla { zda, pg, zn, zm, dbl, sub } => {
            u.tag = T::SveFmla;
            u.a = zda;
            u.b = pg;
            u.c = zn;
            u.d = zm;
            set(&mut u, F_DBL, dbl);
            set(&mut u, F_SUB, sub);
        }
        I::SveScvtf { zd, pg, zn, dbl } => {
            u.tag = T::SveScvtf;
            u.a = zd;
            u.b = pg;
            u.c = zn;
            set(&mut u, F_DBL, dbl);
        }
        // ---- SVE compares ----
        I::SveIntCmp { op, unsigned, pd, pg, zn, rhs, esize } => {
            u.sub = SubOp::Cmp(op);
            u.a = pd;
            u.b = pg;
            u.c = zn;
            u.esize = esize;
            set(&mut u, F_UNSIGNED, unsigned);
            match rhs {
                ZmOrImm::Z(zm) => {
                    u.tag = T::SveIntCmpZ;
                    u.d = zm;
                }
                ZmOrImm::Imm(i) => {
                    u.tag = T::SveIntCmpImm;
                    u.imm = i;
                }
            }
        }
        I::SveFpCmp { op, pd, pg, zn, rhs, dbl } => {
            u.sub = SubOp::Cmp(op);
            u.a = pd;
            u.b = pg;
            u.c = zn;
            set(&mut u, F_DBL, dbl);
            match rhs {
                Some(zm) => {
                    u.tag = T::SveFpCmpV;
                    u.d = zm;
                }
                None => u.tag = T::SveFpCmp0,
            }
        }
        // ---- SVE horizontal ----
        I::SveReduce { op, vd, pg, zn, esize } => {
            u.tag = T::SveReduce;
            u.sub = SubOp::Red(op);
            u.a = vd;
            u.b = pg;
            u.c = zn;
            u.esize = esize;
        }
        I::SveFadda { vdn, pg, zm, dbl } => {
            u.tag = T::SveFadda;
            u.a = vdn;
            u.b = pg;
            u.c = zm;
            set(&mut u, F_DBL, dbl);
        }
        // ---- SVE permutes ----
        I::SveRev { zd, zn, esize } => {
            u.tag = T::SveRev;
            u.a = zd;
            u.b = zn;
            u.esize = esize;
        }
        I::SveExt { zdn, zm, imm } => {
            u.tag = T::SveExt;
            u.a = zdn;
            u.c = zm;
            u.imm = imm as i64;
        }
        I::SveZip { zd, zn, zm, esize, hi } => {
            u.tag = T::SveZip;
            u.a = zd;
            u.b = zn;
            u.c = zm;
            u.esize = esize;
            set(&mut u, F_HI, hi);
        }
        I::SveUzp { zd, zn, zm, esize, odd } => {
            u.tag = T::SveUzp;
            u.a = zd;
            u.b = zn;
            u.c = zm;
            u.esize = esize;
            set(&mut u, F_HI, odd);
        }
        I::SveTrn { zd, zn, zm, esize, odd } => {
            u.tag = T::SveTrn;
            u.a = zd;
            u.b = zn;
            u.c = zm;
            u.esize = esize;
            set(&mut u, F_HI, odd);
        }
        I::SveTbl { zd, zn, zm, esize } => {
            u.tag = T::SveTbl;
            u.a = zd;
            u.b = zn;
            u.c = zm;
            u.esize = esize;
        }
        I::SveCompact { zd, pg, zn, esize } => {
            u.tag = T::SveCompact;
            u.a = zd;
            u.b = pg;
            u.c = zn;
            u.esize = esize;
        }
        I::SveSplice { zdn, pg, zm, esize } => {
            u.tag = T::SveSplice;
            u.a = zdn;
            u.b = pg;
            u.c = zm;
            u.esize = esize;
        }
        // ---- SVE termination ----
        I::Cterm { xn, xm, ne } => {
            u.tag = T::Cterm;
            u.b = xn;
            u.c = xm;
            set(&mut u, F_NE, ne);
        }
    }
    u
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::asm::Asm;

    /// One sample per decoded shape: every `Inst` variant, with both
    /// alternatives of every addressing-mode / optional-operand split.
    pub(crate) fn samples() -> Vec<Inst> {
        use Inst::*;
        vec![
            MovImm { xd: 1, imm: 42 },
            MovReg { xd: 1, xn: 2 },
            AddImm { xd: 1, xn: 2, imm: -3 },
            AddReg { xd: 1, xn: 2, xm: 3, lsl: 1 },
            SubReg { xd: 1, xn: 2, xm: 3 },
            Madd { xd: 1, xn: 2, xm: 3, xa: 4 },
            Udiv { xd: 1, xn: 2, xm: 3 },
            AndImm { xd: 1, xn: 2, imm: 0xff },
            LogReg { op: PLogicOp::Eor, xd: 1, xn: 2, xm: 3 },
            LslImm { xd: 1, xn: 2, sh: 3 },
            LsrImm { xd: 1, xn: 2, sh: 3 },
            AsrImm { xd: 1, xn: 2, sh: 3 },
            Csel { xd: 1, xn: 2, xm: 3, cond: Cond::Lt },
            Ldr { size: 4, signed: true, xt: 1, base: 2, off: MemOff::Imm(8) },
            Ldr { size: 8, signed: false, xt: 1, base: 2, off: MemOff::RegLsl(3, 3) },
            Str { size: 4, xt: 1, base: 2, off: MemOff::Imm(8) },
            Str { size: 8, xt: 1, base: 2, off: MemOff::RegLsl(3, 3) },
            LdrFp { dbl: true, vt: 1, base: 2, off: MemOff::Imm(0) },
            LdrFp { dbl: false, vt: 1, base: 2, off: MemOff::RegLsl(3, 2) },
            StrFp { dbl: true, vt: 1, base: 2, off: MemOff::Imm(0) },
            StrFp { dbl: false, vt: 1, base: 2, off: MemOff::RegLsl(3, 2) },
            CmpImm { xn: 1, imm: 5 },
            CmpReg { xn: 1, xm: 2 },
            B { target: 0 },
            BCond { cond: Cond::Ge, target: 0 },
            Cbz { xn: 1, target: 0 },
            Cbnz { xn: 1, target: 0 },
            Ret,
            Halt,
            Nop,
            FmovImm { dbl: true, dd: 1, bits: 0x3ff0_0000_0000_0000 },
            FmovXtoD { dd: 1, xn: 2 },
            FmovReg { dbl: false, dd: 1, dn: 2 },
            FmovDtoX { xd: 1, dn: 2 },
            FpBin { op: FpOp::Mul, dbl: true, dd: 1, dn: 2, dm: 3 },
            FpUn { op: FpUnOp::Sqrt, dbl: false, dd: 1, dn: 2 },
            Fmadd { dbl: true, dd: 1, dn: 2, dm: 3, da: 4, sub: true },
            Fcmp { dbl: true, dn: 1, dm: 2 },
            Scvtf { dbl: true, dd: 1, xn: 2 },
            Fcvtzs { dbl: false, xd: 1, dn: 2 },
            OpaqueCall { f: OpaqueFn::Pow, dd: 1, dn: 2, dm: Some(3) },
            OpaqueCall { f: OpaqueFn::Log, dd: 1, dn: 2, dm: None },
            NeonLd1 { esize: Esize::D, vt: 1, base: 2, off: MemOff::Imm(0) },
            NeonLd1 { esize: Esize::S, vt: 1, base: 2, off: MemOff::RegLsl(3, 2) },
            NeonSt1 { esize: Esize::D, vt: 1, base: 2, off: MemOff::Imm(0) },
            NeonSt1 { esize: Esize::S, vt: 1, base: 2, off: MemOff::RegLsl(3, 2) },
            NeonDupX { esize: Esize::D, vd: 1, xn: 2 },
            NeonDupLane0 { esize: Esize::D, vd: 1, vn: 2 },
            NeonMoviZero { vd: 1 },
            NeonFpBin { op: FpOp::Add, dbl: true, vd: 1, vn: 2, vm: 3 },
            NeonFpUn { op: FpUnOp::Neg, dbl: false, vd: 1, vn: 2 },
            NeonFmla { dbl: true, vd: 1, vn: 2, vm: 3, sub: false },
            NeonIntBin { op: IntOp::Add, esize: Esize::S, vd: 1, vn: 2, vm: 3 },
            NeonFcm { op: CmpOp::Gt, dbl: true, vd: 1, vn: 2, vm: 3 },
            NeonCm { op: CmpOp::Eq, esize: Esize::S, vd: 1, vn: 2, vm: 3 },
            NeonBsl { vd: 1, vn: 2, vm: 3 },
            NeonFaddv { dbl: false, dd: 1, vn: 2 },
            NeonAddv { esize: Esize::S, dd: 1, vn: 2 },
            NeonUmov { esize: Esize::D, xd: 1, vn: 2, lane: 1 },
            NeonInsX { esize: Esize::D, vd: 1, lane: 1, xn: 2 },
            Ptrue { pd: 1, esize: Esize::D, s: true },
            Pfalse { pd: 1 },
            While { pd: 1, esize: Esize::D, xn: 2, xm: 3, unsigned: true },
            Ptest { pg: 1, pn: 2 },
            Pnext { pdn: 1, pg: 2, esize: Esize::D },
            Brk { pd: 1, pg: 2, pn: 3, before: true, s: true },
            PredLogic { op: PLogicOp::Bic, pd: 1, pg: 2, pn: 3, pm: 4, s: true },
            Rdffr { pd: 1, pg: Some(2), s: true },
            Rdffr { pd: 1, pg: None, s: false },
            Setffr,
            Wrffr { pn: 1 },
            Cnt { xd: 1, esize: Esize::D },
            IncDec { xdn: 1, esize: Esize::D, dec: true },
            IncpX { xdn: 1, pm: 2, esize: Esize::D },
            Index { zd: 1, esize: Esize::S, base: RegOrImm::Reg(2), step: RegOrImm::Imm(3) },
            Index { zd: 1, esize: Esize::S, base: RegOrImm::Imm(0), step: RegOrImm::Reg(3) },
            DupImm { zd: 1, esize: Esize::B, imm: -1 },
            FdupImm { zd: 1, dbl: true, bits: 0x4000_0000_0000_0000 },
            DupX { zd: 1, esize: Esize::D, xn: 2 },
            CpyX { zd: 1, pg: 2, xn: 3, esize: Esize::D },
            Sel { zd: 1, pg: 2, zn: 3, zm: 4, esize: Esize::D },
            Movprfx { zd: 1, zn: 2, pg: Some((3, true)) },
            Movprfx { zd: 1, zn: 2, pg: None },
            Last { xd: 1, pg: 2, zn: 3, esize: Esize::D, before: true },
            SveLd1 { zt: 1, pg: 2, esize: Esize::D, base: 3, off: SveMemOff::ImmVl(1), ff: true },
            SveLd1 {
                zt: 1,
                pg: 2,
                esize: Esize::D,
                base: 3,
                off: SveMemOff::RegScaled(4),
                ff: false,
            },
            SveLd1R { zt: 1, pg: 2, esize: Esize::D, base: 3, imm: 8 },
            SveSt1 { zt: 1, pg: 2, esize: Esize::D, base: 3, off: SveMemOff::ImmVl(1) },
            SveSt1 { zt: 1, pg: 2, esize: Esize::D, base: 3, off: SveMemOff::RegScaled(4) },
            SveLdGather {
                zt: 1,
                pg: 2,
                esize: Esize::D,
                addr: GatherAddr::VecImm(3, 8),
                ff: true,
            },
            SveLdGather {
                zt: 1,
                pg: 2,
                esize: Esize::D,
                addr: GatherAddr::BaseVec { xn: 3, zm: 4, scaled: true },
                ff: false,
            },
            SveStScatter { zt: 1, pg: 2, esize: Esize::D, addr: GatherAddr::VecImm(3, 8) },
            SveStScatter {
                zt: 1,
                pg: 2,
                esize: Esize::D,
                addr: GatherAddr::BaseVec { xn: 3, zm: 4, scaled: false },
            },
            SveIntBin { op: IntOp::Add, zdn: 1, pg: 2, zm: 3, esize: Esize::D },
            SveIntBinU { op: IntOp::Mul, zd: 1, zn: 2, zm: 3, esize: Esize::D },
            SveAddImm { zdn: 1, esize: Esize::D, imm: 7 },
            SveFpBin { op: FpOp::Add, zdn: 1, pg: 2, zm: 3, dbl: true },
            SveFpUn { op: FpUnOp::Sqrt, zd: 1, pg: 2, zn: 3, dbl: false },
            SveFmla { zda: 1, pg: 2, zn: 3, zm: 4, dbl: true, sub: true },
            SveScvtf { zd: 1, pg: 2, zn: 3, dbl: true },
            SveIntCmp {
                op: CmpOp::Lt,
                unsigned: true,
                pd: 1,
                pg: 2,
                zn: 3,
                rhs: ZmOrImm::Z(4),
                esize: Esize::D,
            },
            SveIntCmp {
                op: CmpOp::Eq,
                unsigned: false,
                pd: 1,
                pg: 2,
                zn: 3,
                rhs: ZmOrImm::Imm(0),
                esize: Esize::B,
            },
            SveFpCmp { op: CmpOp::Gt, pd: 1, pg: 2, zn: 3, rhs: Some(4), dbl: true },
            SveFpCmp { op: CmpOp::Lt, pd: 1, pg: 2, zn: 3, rhs: None, dbl: false },
            SveReduce { op: RedOp::FAddV, vd: 1, pg: 2, zn: 3, esize: Esize::D },
            SveFadda { vdn: 1, pg: 2, zm: 3, dbl: true },
            SveRev { zd: 1, zn: 2, esize: Esize::D },
            SveExt { zdn: 1, zm: 2, imm: 8 },
            SveZip { zd: 1, zn: 2, zm: 3, esize: Esize::D, hi: true },
            SveUzp { zd: 1, zn: 2, zm: 3, esize: Esize::D, odd: true },
            SveTrn { zd: 1, zn: 2, zm: 3, esize: Esize::D, odd: false },
            SveTbl { zd: 1, zn: 2, zm: 3, esize: Esize::D },
            SveCompact { zd: 1, pg: 2, zn: 3, esize: Esize::D },
            SveSplice { zdn: 1, pg: 2, zm: 3, esize: Esize::D },
            Cterm { xn: 1, xm: 2, ne: true },
        ]
    }

    #[test]
    fn every_tag_is_reachable_from_decode() {
        let mut a = Asm::new();
        for i in samples() {
            a.push(i);
        }
        let dec = DecodedProgram::decode(&a.finish());
        let mut seen = [false; UopTag::COUNT];
        for u in dec.uops() {
            seen[u.tag as usize] = true;
        }
        let missing: Vec<usize> = (0..UopTag::COUNT).filter(|&t| !seen[t]).collect();
        assert!(missing.is_empty(), "tags with no decode sample: {missing:?}");
    }

    #[test]
    fn deps_match_the_inst_metadata() {
        let mut a = Asm::new();
        for i in samples() {
            a.push(i);
        }
        let prog = a.finish();
        let dec = DecodedProgram::decode(&prog);
        let mut reads = Vec::new();
        let mut writes = Vec::new();
        for (pc, inst) in prog.insts.iter().enumerate() {
            inst.deps(&mut reads, &mut writes);
            let want_r: Vec<u8> = reads.iter().map(|&r| reg_slot(r)).collect();
            let want_w: Vec<u8> = writes.iter().map(|&w| reg_slot(w)).collect();
            let u = &dec.uops()[pc];
            assert_eq!(dec.reads(u), &want_r[..], "pc {pc} reads of {inst:?}");
            assert_eq!(dec.writes(u), &want_w[..], "pc {pc} writes of {inst:?}");
            assert_eq!(u.class, inst.class(), "pc {pc} class of {inst:?}");
            assert_eq!(u.is_sve(), inst.is_sve(), "pc {pc}");
            assert_eq!(u.is_neon(), inst.is_neon(), "pc {pc}");
            assert_eq!(u.is_cond_branch(), inst.is_cond_branch(), "pc {pc}");
        }
    }

    #[test]
    fn crack_rules_follow_the_class() {
        let gather = lower(&Inst::SveLdGather {
            zt: 0,
            pg: 0,
            esize: Esize::D,
            addr: GatherAddr::VecImm(1, 0),
            ff: false,
        });
        assert_eq!(gather.crack, Crack::PerElem);
        assert_eq!(gather.crack.max_uops(512, Esize::D), 8);
        let fadda = lower(&Inst::SveFadda { vdn: 0, pg: 0, zm: 1, dbl: true });
        assert_eq!(fadda.crack, Crack::Per128b);
        assert_eq!(fadda.crack.max_uops(512, Esize::D), 4);
        let fmla = lower(&Inst::SveFmla { zda: 0, pg: 0, zn: 1, zm: 2, dbl: true, sub: false });
        assert_eq!(fmla.crack, Crack::Unit);
        assert_eq!(fmla.crack.max_uops(2048, Esize::D), 1);
    }

    #[test]
    fn reg_slots_are_dense_and_distinct() {
        let mut seen = [false; REG_SLOTS];
        for n in 0..31 {
            seen[reg_slot(RegId::X(n)) as usize] = true;
        }
        for n in 0..32 {
            seen[reg_slot(RegId::Z(n)) as usize] = true;
        }
        for n in 0..16 {
            seen[reg_slot(RegId::P(n)) as usize] = true;
        }
        seen[reg_slot(RegId::Ffr) as usize] = true;
        seen[reg_slot(RegId::Nzcv) as usize] = true;
        assert!(seen.iter().all(|&s| s), "every scoreboard slot is reachable");
    }

    #[test]
    fn straight_lens_count_to_next_control_uop() {
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: 1 });
        a.push(Inst::AddImm { xd: 0, xn: 0, imm: 1 });
        a.push_branch(Inst::Cbnz { xn: 0, target: 0 }, "end");
        a.push(Inst::Nop);
        a.label("end");
        a.push(Inst::Halt);
        let dec = DecodedProgram::decode(&a.finish());
        assert_eq!(dec.straight_lens(), &[3, 2, 1, 2, 1]);
        assert!(dec.uops()[2].is_control_flow());
        assert!(!dec.uops()[3].is_control_flow());
    }

    #[test]
    fn ret_and_halt_share_a_tag() {
        assert_eq!(lower(&Inst::Ret).tag, UopTag::Halt);
        assert_eq!(lower(&Inst::Halt).tag, UopTag::Halt);
        assert_eq!(lower(&Inst::Ret).class, UopClass::Branch);
    }
}
