//! # sve-repro — a reproduction of "The ARM Scalable Vector Extension"
//! (Stephens et al., IEEE Micro 2017, DOI 10.1109/MM.2017.35)
//!
//! A three-layer Rust + JAX/Pallas system (see `DESIGN.md`):
//!
//! * [`arch`] — scalable architectural state: Z0–Z31 (128–2048 bit),
//!   P0–P15, FFR, NZCV with the SVE overloading of Table 1, ZCR vector
//!   length virtualization.
//! * [`mem`] — paged memory with translation faults (the substrate for
//!   first-faulting loads, §2.3.3).
//! * [`isa`] — the instruction set: an AArch64 scalar subset, an Advanced
//!   SIMD (NEON) 128-bit baseline subset, and the SVE subset covering
//!   every mechanism in the paper; plus the encoding-budget model of
//!   Fig. 7.
//! * [`exec`] — the functional executor (architectural semantics).
//! * [`asm`] — program builder with labels.
//! * [`compiler`] — the stand-in for the paper's experimental
//!   auto-vectorizing compiler (§3): a loop IR with scalar, NEON and SVE
//!   code generators.
//! * [`uarch`] — the trace-driven out-of-order timing model configured
//!   per Table 2.
//! * [`workloads`] — the HPC proxy benchmark suite behind Fig. 8.
//! * [`coordinator`] — the sharded, resumable (benchmark × ISA × VL)
//!   sweep engine.
//! * [`request`] — the typed request layer: `sve`'s CLI flags and the
//!   serve socket API as two spellings of one schema.
//! * [`serve`] — the long-running sweep service (`sve serve`) and its
//!   client (`sve submit`): line-JSON over TCP, cross-client job
//!   dedupe, incremental result streaming, cache GC.
//! * [`report`] — JSON/CSV/Markdown artifact emitters for Figs. 2, 7
//!   and 8, plus the content-addressed job cache behind `--resume`.
//! * [`runtime`] — PJRT golden-model loader (`artifacts/*.hlo.txt`,
//!   produced once at build time by `python/compile/aot.py`).
//!
//! The stable entry points are re-exported at the crate root: build a
//! [`SweepRequest`] (from CLI args or JSON), lower it with
//! [`SweepRequest::to_config`], run it with [`run_sweep`] — or hand it
//! to a [`Server`] over a socket and stream the same records back.

pub mod arch;
pub mod asm;
pub mod bench_util;
pub mod compiler;
pub mod coordinator;
pub mod csvutil;
pub mod exec;
pub mod isa;
pub mod mem;
pub mod proptest_lite;
pub mod report;
pub mod request;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod uarch;
pub mod workloads;

pub use coordinator::{run_dse, run_sweep, SweepConfig};
pub use report::store::JOB_SCHEMA;
pub use request::{DseRequest, ReportRequest, SweepRequest};
pub use serve::proto::{REQ_SCHEMA, RESP_SCHEMA};
pub use serve::{Client, Server, ServerConfig};

/// Minimum legal SVE vector length in bits (§2.2).
pub const VL_MIN_BITS: usize = 128;
/// Maximum architectural SVE vector length in bits (§2.2).
pub const VL_MAX_BITS: usize = 2048;
/// Vector length granule (§2.2: "any multiple of 128 bits").
pub const VL_STEP_BITS: usize = 128;
/// Maximum vector length in bytes.
pub const VL_MAX_BYTES: usize = VL_MAX_BITS / 8;

/// Validate a vector length choice per §2.2.
///
/// ```
/// assert!(sve_repro::vl_is_legal(256));
/// assert!(!sve_repro::vl_is_legal(192)); // multiple of 64, not of 128
/// assert!(!sve_repro::vl_is_legal(4096)); // beyond the architectural max
/// ```
pub fn vl_is_legal(vl_bits: usize) -> bool {
    (VL_MIN_BITS..=VL_MAX_BITS).contains(&vl_bits) && vl_bits % VL_STEP_BITS == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legal_vector_lengths() {
        for vl in (VL_MIN_BITS..=VL_MAX_BITS).step_by(VL_STEP_BITS) {
            assert!(vl_is_legal(vl), "VL {vl} must be legal");
        }
        assert!(!vl_is_legal(0));
        assert!(!vl_is_legal(64));
        assert!(!vl_is_legal(192)); // multiple of 64 but not 128
        assert!(!vl_is_legal(2176)); // beyond the architectural max
    }
}
