//! `sve` — CLI for the SVE-paper reproduction.
//!
//! ```text
//! sve list                                              benchmarks
//! sve run <bench> [--isa scalar|neon|sve] [--vl BITS]   one benchmark
//! sve sweep [--vls 128,256,512] [--benches a,b] [--out reports]
//!           [--jobs N] [--resume]                       the Fig. 8 sweep
//! sve dse [--uarch table2,small-core,...] [--vls ...]   design-space sweep
//!         [--benches a,b] [--out reports] [--jobs N] [--resume]
//! sve report [--out reports] [--vls ...] [--jobs N]     all figure artifacts
//! sve report --compare A.json B.json [--fail-on-regress PCT]
//!                                                       diff two artifacts
//! sve trace <bench> [--vl BITS] [--limit N]             Fig. 3-style trace
//! sve encoding                                          Fig. 7 terminal report
//! sve validate [--artifacts DIR]                        PJRT cross-check
//! ```
//!
//! Exit codes: `0` success, `1` runtime failure (a simulation trapped,
//! validation failed, an artifact is unreadable, or `--compare` found a
//! regression beyond `--fail-on-regress`), `2` usage error (unknown
//! subcommand/benchmark/variant, malformed or illegal
//! `--vl`/`--isa`/`--jobs`/`--uarch` values).

use std::path::PathBuf;

use sve_repro::coordinator::{self, Isa, SweepConfig};
use sve_repro::csvutil::Table;
use sve_repro::exec::{Engine, Executor};
use sve_repro::isa::encoding;
use sve_repro::report;
use sve_repro::report::compare::{self, MetricPoint};
use sve_repro::report::json::Json;
use sve_repro::uarch::{parse_variants, UarchConfig, VARIANT_NAMES};
use sve_repro::workloads;

const USAGE: &str = "sve — ARM SVE paper reproduction

usage: sve <command> [options]

commands:
  list                       list the Fig. 8 benchmark proxies
  run <bench>                run one benchmark
      --isa scalar|neon|sve  target ISA (default sve)
      --vl BITS              SVE vector length, 128..2048 step 128 (default 256)
      --no-trace             run on the baseline interpreter instead of the
                             superblock trace engine (A/B escape hatch;
                             results are bit-identical, only speed differs)
  sweep                      the Fig. 8 sweep, sharded + resumable
      --vls A,B,C            SVE vector lengths (default 128,256,512)
      --benches a,b          benchmark subset (default: all)
      --out DIR              artifact/cache directory (default reports)
      --jobs N               worker threads (default: one per CPU)
      --resume               reuse completed jobs cached under DIR/jobs/
      --no-trace             as for run (also accepted by dse and report)
  dse                        design-space sweep across uarch variants,
                             with PPA proxies + Pareto ranking
      --uarch a,b[,k=v,...]  variants: table2, small-core, big-core,
                             narrow-mem, deep-rob (default: all five);
                             key=value overrides modify the variant named
                             before them (l2_bytes=512K, loads_per_cycle=1);
                             key=a,b,c sweeps a cartesian grid over the
                             listed values (rob=64,128,256; max 64 points)
      --pareto-only          filter the report and artifacts to frontier
                             design points (dominated variants dropped)
      --vls/--benches/--out/--jobs/--resume   as for sweep
  report                     emit Fig. 2 + Fig. 7 + Fig. 8 artifacts
      --out DIR  --vls A,B,C  --benches a,b  --jobs N   (as for sweep;
                             the Fig. 8 part always resumes from DIR/jobs/)
      --compare A.json B.json  diff two artifacts instead of emitting
                             figures: fig8/dse docs compare by speedup
                             (and dse/v2 perf/W + perf/mm2); two
                             BENCH_hotpath.json docs compare by
                             simulator Minst/s throughput
      --fail-on-regress PCT  with --compare: exit 1 if any value drops
                             more than PCT percent, or a point disappears
  trace <bench>              Fig. 3-style cycle-by-cycle timeline
      --vl BITS  --limit N
  encoding                   Fig. 7 encoding-budget report (terminal)
  validate [--artifacts DIR] PJRT golden cross-check

exit codes: 0 ok, 1 runtime failure, 2 usage error";

/// Value of `name`, or `None` when the flag is absent. A flag present
/// with no trailing value is a usage error, never a silent default —
/// `--fail-on-regress $PCT` with `PCT` unset in a CI shell must not
/// quietly disable the regression wall.
fn flag(args: &[String], name: &str) -> Option<String> {
    let i = args.iter().position(|a| a == name)?;
    match args.get(i + 1) {
        Some(v) => Some(v.clone()),
        None => die_usage(&format!("{name} needs a value")),
    }
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Usage error: message + usage to stderr, exit 2.
fn die_usage(msg: &str) -> ! {
    eprintln!("error: {msg}\n\n{USAGE}");
    std::process::exit(2)
}

/// Runtime failure: message to stderr, exit 1.
fn die_run(msg: &str) -> ! {
    eprintln!("FAILED: {msg}");
    std::process::exit(1)
}

fn parse_bench(args: &[String], cmd: &str) -> &'static str {
    let Some(bench) = args.get(1) else {
        die_usage(&format!("usage: sve {cmd} <bench>"));
    };
    match workloads::NAMES.iter().find(|n| *n == bench) {
        Some(&n) => n,
        None => die_usage(&format!(
            "unknown benchmark '{bench}' (try: {})",
            workloads::NAMES.join(", ")
        )),
    }
}

fn parse_vl(args: &[String], default: usize) -> usize {
    let Some(text) = flag(args, "--vl") else { return default };
    let Ok(vl) = text.parse::<usize>() else {
        die_usage(&format!("--vl '{text}' is not a number"));
    };
    if !sve_repro::vl_is_legal(vl) {
        die_usage(&format!("--vl {vl} is illegal (§2.2: 128..2048 in steps of 128)"));
    }
    vl
}

fn parse_vls(args: &[String]) -> Vec<usize> {
    let text = flag(args, "--vls").unwrap_or_else(|| "128,256,512".into());
    let mut vls = Vec::new();
    for part in text.split(',') {
        let Ok(vl) = part.trim().parse::<usize>() else {
            die_usage(&format!("--vls component '{part}' is not a number"));
        };
        if !sve_repro::vl_is_legal(vl) {
            die_usage(&format!("--vls {vl} is illegal (§2.2: 128..2048 in steps of 128)"));
        }
        vls.push(vl);
    }
    vls
}

fn parse_jobs(args: &[String]) -> usize {
    let Some(text) = flag(args, "--jobs") else { return 0 };
    match text.parse::<usize>() {
        Ok(n) => n,
        Err(_) => die_usage(&format!("--jobs '{text}' is not a number")),
    }
}

fn parse_benches(args: &[String]) -> Vec<&'static str> {
    let Some(text) = flag(args, "--benches") else {
        return workloads::NAMES.to_vec();
    };
    let mut names = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        match workloads::NAMES.iter().find(|n| **n == part) {
            Some(n) => names.push(*n),
            None => die_usage(&format!(
                "unknown benchmark '{part}' in --benches (try: {})",
                workloads::NAMES.join(", ")
            )),
        }
    }
    names
}

/// `--no-trace` drops back to the baseline block interpreter; the
/// default is the superblock trace engine. Reported numbers are
/// bit-identical either way (pinned by `exec/trace.rs` tests) — the
/// flag exists for A/B simulator-throughput runs and for bisecting.
fn parse_engine(args: &[String]) -> Engine {
    if has_flag(args, "--no-trace") {
        Engine::Baseline
    } else {
        Engine::Trace
    }
}

fn sweep_config(args: &[String]) -> (SweepConfig, PathBuf) {
    let out: PathBuf = flag(args, "--out").unwrap_or_else(|| "reports".into()).into();
    let mut cfg = SweepConfig::new(&parse_vls(args), &parse_benches(args));
    cfg.jobs = parse_jobs(args);
    cfg.resume = has_flag(args, "--resume");
    cfg.out_dir = Some(out.clone());
    cfg.engine = parse_engine(args);
    (cfg, out)
}

/// Print the written artifact paths and the cache summary line shared
/// by `sweep`, `report` and `dse` (CI greps the exact
/// "N simulated, M reloaded" wording — keep it in one place).
fn emit_paths_and_counts(
    paths: std::io::Result<Vec<PathBuf>>,
    what: &str,
    simulated: usize,
    reloaded: usize,
    out: &PathBuf,
) {
    match paths {
        Ok(paths) => {
            for p in paths {
                println!("wrote {}", p.display());
            }
        }
        Err(e) => die_run(&format!("write {what} artifacts: {e}")),
    }
    println!(
        "{} jobs: {} simulated, {} reloaded from {}/jobs/",
        simulated + reloaded,
        simulated,
        reloaded,
        out.display()
    );
}

fn run_sweep_and_emit(cfg: &SweepConfig, out: &PathBuf) {
    let outcome = match coordinator::run_sweep(cfg) {
        Ok(o) => o,
        Err(e) => die_run(&e),
    };
    let t = report::fig8::table(&outcome.rows, &cfg.vls);
    println!("{}", t.to_markdown());
    println!("{}", report::fig8::chart(&outcome.rows, &cfg.vls));
    emit_paths_and_counts(
        report::fig8::write_artifacts(&outcome.rows, &cfg.vls, out),
        "fig8",
        outcome.simulated,
        outcome.reloaded,
        out,
    );
}

/// Load an artifact and extract its speedup points, dying with exit 1
/// (runtime failure) on unreadable/unparseable/unsupported files.
fn load_points(path: &str) -> Vec<MetricPoint> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| die_run(&format!("read {path}: {e}")));
    let doc =
        Json::parse(&text).unwrap_or_else(|e| die_run(&format!("parse {path}: {e}")));
    compare::extract_points(&doc).unwrap_or_else(|e| die_run(&format!("{path}: {e}")))
}

/// `sve report --compare A B [--fail-on-regress PCT]`.
fn run_compare(args: &[String]) -> ! {
    let i = args.iter().position(|a| a == "--compare").expect("checked by caller");
    let (a, b) = match (args.get(i + 1), args.get(i + 2)) {
        (Some(a), Some(b)) if !a.starts_with("--") && !b.starts_with("--") => (a, b),
        _ => die_usage("--compare needs two artifact paths (A.json B.json)"),
    };
    let fail_below_pct = flag(args, "--fail-on-regress").map(|t| match t.parse::<f64>() {
        Ok(pct) if pct.is_finite() && pct >= 0.0 => pct,
        _ => die_usage(&format!(
            "--fail-on-regress '{t}' is not a non-negative number"
        )),
    });
    let cmp = compare::compare(&load_points(a), &load_points(b), fail_below_pct);
    print!("{}", compare::render(&cmp));
    if cmp.failed() {
        die_run(&format!(
            "comparison failed the regression threshold: {} regression(s), \
             {} point(s) missing from B (see report above)",
            cmp.regressions.len(),
            cmp.only_in_a.len()
        ));
    }
    std::process::exit(0)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
        }
        "list" => {
            for n in workloads::NAMES {
                let w = workloads::build(n);
                println!("{n:<14} {}", w.group.label());
            }
        }
        "run" => {
            let name = parse_bench(&args, "run");
            // validate --vl whatever the ISA: a typo'd value must never
            // be silently ignored (scalar/neon fix the width at 128)
            let vl = parse_vl(&args, 256);
            let isa = match flag(&args, "--isa").as_deref() {
                Some("scalar") => Isa::Scalar,
                Some("neon") => Isa::Neon,
                Some("sve") | None => Isa::Sve(vl),
                Some(other) => {
                    die_usage(&format!("unknown --isa '{other}' (scalar, neon or sve)"))
                }
            };
            match coordinator::run_one_engine(name, isa, parse_engine(&args)) {
                Ok(r) => {
                    println!(
                        "{} on {}: {} insts, {} cycles, ipc {:.2}, vectorized={}, \
                         vector-fraction {:.1}%, L1D miss {:.2}%",
                        r.bench,
                        r.isa.label(),
                        r.insts,
                        r.cycles,
                        r.ipc,
                        r.vectorized,
                        100.0 * r.vector_fraction,
                        100.0 * r.l1d_miss_rate
                    );
                }
                Err(e) => die_run(&e),
            }
        }
        "sweep" => {
            let (cfg, out) = sweep_config(&args);
            run_sweep_and_emit(&cfg, &out);
        }
        "dse" => {
            let (cfg, out) = sweep_config(&args);
            let spec =
                flag(&args, "--uarch").unwrap_or_else(|| VARIANT_NAMES.join(","));
            let variants = match parse_variants(&spec) {
                Ok(v) => v,
                Err(e) => die_usage(&e),
            };
            let outcome = match coordinator::run_dse(&cfg, &variants) {
                Ok(o) => o,
                Err(e) => die_run(&e),
            };
            // --pareto-only: restrict reporting and artifacts to the
            // frontier design points (ROADMAP open item)
            let pareto_only = has_flag(&args, "--pareto-only");
            let (shown, pts) = if pareto_only {
                report::dse::frontier_only(&outcome.variants, &cfg.vls)
            } else {
                let pts = report::dse::pareto(&outcome.variants, &cfg.vls);
                (outcome.variants.clone(), pts)
            };
            for v in &shown {
                println!("## {}\n", v.name);
                println!("{}", report::fig8::table(&v.rows, &cfg.vls).to_markdown());
            }
            println!("## Cross-variant pivot — speedup, perf/W, perf/mm2 over NEON\n");
            println!("{}", report::dse::pivot(&shown, &cfg.vls).to_markdown());
            if pareto_only {
                println!("## Pareto frontier (frontier-only view)\n");
            } else {
                println!("## Pareto frontier — performance vs energy vs area\n");
            }
            println!("{}", report::dse::pareto_table(&pts).to_markdown());
            let paths = if pareto_only {
                report::dse::write_artifacts_pareto_only(&outcome.variants, &cfg.vls, &out)
            } else {
                report::dse::write_artifacts(&outcome.variants, &cfg.vls, &out)
            };
            emit_paths_and_counts(paths, "dse", outcome.simulated, outcome.reloaded, &out);
        }
        "report" if has_flag(&args, "--compare") => run_compare(&args),
        "report" => {
            let (mut cfg, out) = sweep_config(&args);
            // `report` is idempotent by design: always reuse cached jobs
            cfg.resume = true;
            let fig2 = report::fig2::build(report::fig2::DAXPY_N);
            match report::fig2::write_artifacts(&fig2, &out) {
                Ok(paths) => paths.iter().for_each(|p| println!("wrote {}", p.display())),
                Err(e) => die_run(&format!("write fig2 artifacts: {e}")),
            }
            match report::fig7::write_artifacts(&out) {
                Ok(paths) => paths.iter().for_each(|p| println!("wrote {}", p.display())),
                Err(e) => die_run(&format!("write fig7 artifacts: {e}")),
            }
            run_sweep_and_emit(&cfg, &out);
        }
        "trace" => {
            let name = parse_bench(&args, "trace");
            let vl = parse_vl(&args, 256);
            let limit: u64 = match flag(&args, "--limit") {
                Some(t) => match t.parse() {
                    Ok(n) => n,
                    Err(_) => die_usage(&format!("--limit '{t}' is not a number")),
                },
                None => 64,
            };
            let w = workloads::build(name);
            let c = w.compile(sve_repro::compiler::Target::Sve);
            let mut ex = Executor::new(vl, w.mem.clone());
            let mut pipe = sve_repro::uarch::Pipeline::new(UarchConfig::default(), vl);
            pipe.enable_trace();
            // budget exhaustion is expected: we trace only a prefix
            let _ = ex.run_with(&c.program, limit, |i| pipe.on_retire(&i));
            let tr = pipe.trace.take().unwrap_or_default();
            println!("{}", sve_repro::uarch::trace::render_timeline(&c.program, &tr));
            println!("(traced prefix: {} cycles)", pipe.result.cycles);
        }
        "encoding" => {
            let (groups, total) = encoding::sve_region_report();
            let mut t = Table::new(vec!["group", "points", "share of 2^28"]);
            for g in &groups {
                t.push_row(vec![
                    g.group.clone(),
                    g.points.to_string(),
                    format!("{:.3}%", 100.0 * g.share_of_region),
                ]);
            }
            println!("{}", t.to_markdown());
            println!(
                "total: {total} of {} encoding points ({:.2}%) — Fig. 7: SVE fits one \
                 28-bit region",
                encoding::SVE_REGION_POINTS,
                100.0 * total as f64 / encoding::SVE_REGION_POINTS as f64
            );
            let (d, c) = encoding::constructive_counterfactual();
            println!(
                "§4 counterfactual (full {}-opcode dp set): destructive+movprfx = {d} \
                 points; fully-constructive = {c} points ({:.1}x the whole region)",
                encoding::FULL_DP_OPCODES,
                c as f64 / encoding::SVE_REGION_POINTS as f64
            );
        }
        "validate" => {
            let dir = flag(&args, "--artifacts").unwrap_or_else(|| "artifacts".into());
            match sve_repro::runtime::validate_all(&dir) {
                Ok(vs) => {
                    for v in &vs {
                        println!(
                            "{:<8} {} (max |err| = {:.3e})",
                            v.name,
                            if v.ok { "OK" } else { "MISMATCH" },
                            v.max_abs_err
                        );
                    }
                    if vs.iter().any(|v| !v.ok) {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("validation failed: {e:#} (run `make artifacts` first)");
                    std::process::exit(1);
                }
            }
        }
        other => {
            die_usage(&format!("unknown command '{other}'"));
        }
    }
}
