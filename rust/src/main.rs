//! `sve` — CLI for the SVE-paper reproduction.
//!
//! ```text
//! sve list                                              benchmarks
//! sve run <bench> [--isa scalar|neon|sve] [--vl BITS]   one benchmark
//! sve sweep [--vls 128,256,512] [--benches a,b] [--out reports]
//!           [--jobs N] [--resume]                       the Fig. 8 sweep
//! sve dse [--uarch table2,small-core,...] [--vls ...]   design-space sweep
//!         [--benches a,b] [--out reports] [--jobs N] [--resume]
//! sve report [--out reports] [--vls ...] [--jobs N]     all figure artifacts
//! sve report --compare A.json B.json [--fail-on-regress PCT]
//!                                                       diff two artifacts
//! sve serve [--listen HOST:PORT] [--out reports]        long-running sweep
//!           [--cache-bytes N] [--max-request-jobs N]    service
//! sve submit [--addr HOST:PORT] [--vls ...|--dse|--ping|--stats|--shutdown]
//!                                                       serve client
//! sve trace <bench> [--vl BITS] [--limit N]             Fig. 3-style trace
//! sve encoding                                          Fig. 7 terminal report
//! sve validate [--artifacts DIR]                        PJRT cross-check
//! ```
//!
//! Flag parsing lives in [`sve_repro::request`]: every subcommand that
//! drives the sweep engine parses into a typed request
//! (`SweepRequest`/`DseRequest`/...) whose JSON spelling is also the
//! `sve serve` wire format — one schema, two transports.
//!
//! Exit codes: `0` success, `1` runtime failure (a simulation trapped,
//! validation failed, an artifact is unreadable, `--compare` found a
//! regression beyond `--fail-on-regress`, or a submit could not reach
//! the server), `2` usage error (unknown subcommand/benchmark/variant,
//! malformed or illegal `--vl`/`--isa`/`--jobs`/`--uarch` values).

use std::path::PathBuf;

use sve_repro::coordinator::{self, Isa, SweepConfig};
use sve_repro::csvutil::Table;
use sve_repro::exec::Executor;
use sve_repro::isa::encoding;
use sve_repro::report;
use sve_repro::report::compare::{self, MetricPoint};
use sve_repro::report::json::Json;
use sve_repro::request::{
    self, DseRequest, ReportRequest, ServeOpts, SubmitAction, SubmitOpts, SweepRequest,
};
use sve_repro::serve::proto::JobLine;
use sve_repro::serve::{Client, Server, ServerConfig};
use sve_repro::uarch::UarchConfig;
use sve_repro::workloads;

const USAGE: &str = "sve — ARM SVE paper reproduction

usage: sve <command> [options]

commands:
  list                       list the Fig. 8 benchmark proxies
  run <bench>                run one benchmark
      --isa scalar|neon|sve  target ISA (default sve)
      --vl BITS              SVE vector length, 128..2048 step 128 (default 256)
      --no-trace             run on the baseline interpreter instead of the
                             superblock trace engine (A/B escape hatch;
                             results are bit-identical, only speed differs)
      --trace-stats          print trace-cache telemetry after the run:
                             traces built/rejected/re-recorded, link jumps
                             taken, dense vs general trace iterations
                             (all zero under --no-trace)
  sweep                      the Fig. 8 sweep, sharded + resumable
      --vls A,B,C            SVE vector lengths (default 128,256,512)
      --benches a,b          benchmark subset (default: all)
      --out DIR              artifact/cache directory (default reports)
      --jobs N               worker threads (default: one per CPU)
      --resume               reuse completed jobs cached under DIR/jobs/
      --no-trace             as for run (also accepted by dse and report)
  dse                        design-space sweep across uarch variants,
                             with PPA proxies + Pareto ranking
      --uarch a,b[,k=v,...]  variants: table2, small-core, big-core,
                             narrow-mem, deep-rob (default: all five);
                             key=value overrides modify the variant named
                             before them (l2_bytes=512K, loads_per_cycle=1);
                             key=a,b,c sweeps a cartesian grid over the
                             listed values (rob=64,128,256; max 64 points)
      --pareto-only          filter the report and artifacts to frontier
                             design points (dominated variants dropped)
      --vls/--benches/--out/--jobs/--resume   as for sweep
  report                     emit Fig. 2 + Fig. 7 + Fig. 8 artifacts
      --out DIR  --vls A,B,C  --benches a,b  --jobs N   (as for sweep;
                             the Fig. 8 part always resumes from DIR/jobs/)
      --compare A.json B.json  diff two artifacts instead of emitting
                             figures: fig8/dse docs compare by speedup
                             (and dse/v2 perf/W + perf/mm2); two
                             BENCH_hotpath.json docs compare by
                             simulator Minst/s throughput
      --fail-on-regress PCT  with --compare: exit 1 if any value drops
                             more than PCT percent, or a point disappears
  serve                      long-running sweep service: line-delimited
                             JSON requests over TCP, cross-client job
                             dedupe, incremental result streaming
      --listen HOST:PORT     bind address (default 127.0.0.1:7878; port 0
                             picks a free port, printed at startup)
      --out DIR              shared job store (default reports)
      --jobs N               worker threads per request
      --cache-bytes N        evict least-recently-used job files once the
                             store exceeds N bytes (default: no eviction)
      --max-request-jobs N   refuse requests expanding past N jobs (4096)
      --no-trace             as for run
  submit                     client for a running `sve serve`
      --addr HOST:PORT       server address (default 127.0.0.1:7878)
      --vls/--benches        sweep request, as for sweep (default action)
      --dse [--uarch ...]    design-space request across variants
      --ping                 liveness probe
      --stats                cumulative server dedupe/GC counters
      --shutdown             drain in-flight work and stop the server
  trace <bench>              Fig. 3-style cycle-by-cycle timeline
      --vl BITS  --limit N
  encoding                   Fig. 7 encoding-budget report (terminal)
  validate [--artifacts DIR] PJRT golden cross-check

exit codes: 0 ok, 1 runtime failure, 2 usage error";

/// Usage error: message + usage to stderr, exit 2.
fn die_usage(msg: &str) -> ! {
    eprintln!("error: {msg}\n\n{USAGE}");
    std::process::exit(2)
}

/// Runtime failure: message to stderr, exit 1.
fn die_run(msg: &str) -> ! {
    eprintln!("FAILED: {msg}");
    std::process::exit(1)
}

/// Unwrap a request-layer parse, mapping `Err` to the exit-2 contract.
fn usage<T>(parsed: Result<T, String>) -> T {
    parsed.unwrap_or_else(|e| die_usage(&e))
}

/// Print the written artifact paths and the cache summary line shared
/// by `sweep`, `report` and `dse` (CI greps the exact
/// "N simulated, M reloaded" wording — keep it in one place).
fn emit_paths_and_counts(
    paths: std::io::Result<Vec<PathBuf>>,
    what: &str,
    simulated: usize,
    reloaded: usize,
    out: &PathBuf,
) {
    match paths {
        Ok(paths) => {
            for p in paths {
                println!("wrote {}", p.display());
            }
        }
        Err(e) => die_run(&format!("write {what} artifacts: {e}")),
    }
    println!(
        "{} jobs: {} simulated, {} reloaded from {}/jobs/",
        simulated + reloaded,
        simulated,
        reloaded,
        out.display()
    );
}

fn run_sweep_and_emit(cfg: &SweepConfig, out: &PathBuf) {
    let outcome = match coordinator::run_sweep(cfg) {
        Ok(o) => o,
        Err(e) => die_run(&e),
    };
    let t = report::fig8::table(&outcome.rows, &cfg.vls);
    println!("{}", t.to_markdown());
    println!("{}", report::fig8::chart(&outcome.rows, &cfg.vls));
    emit_paths_and_counts(
        report::fig8::write_artifacts(&outcome.rows, &cfg.vls, out),
        "fig8",
        outcome.simulated,
        outcome.reloaded,
        out,
    );
}

/// Load an artifact and extract its speedup points, dying with exit 1
/// (runtime failure) on unreadable/unparseable/unsupported files.
fn load_points(path: &str) -> Vec<MetricPoint> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| die_run(&format!("read {path}: {e}")));
    let doc =
        Json::parse(&text).unwrap_or_else(|e| die_run(&format!("parse {path}: {e}")));
    compare::extract_points(&doc).unwrap_or_else(|e| die_run(&format!("{path}: {e}")))
}

/// `sve report --compare A B [--fail-on-regress PCT]`.
fn run_compare(args: &[String]) -> ! {
    let i = args.iter().position(|a| a == "--compare").expect("checked by caller");
    let (a, b) = match (args.get(i + 1), args.get(i + 2)) {
        (Some(a), Some(b)) if !a.starts_with("--") && !b.starts_with("--") => (a, b),
        _ => die_usage("--compare needs two artifact paths (A.json B.json)"),
    };
    let fail_below_pct =
        usage(request::flag(args, "--fail-on-regress")).map(|t| match t.parse::<f64>() {
            Ok(pct) if pct.is_finite() && pct >= 0.0 => pct,
            _ => die_usage(&format!(
                "--fail-on-regress '{t}' is not a non-negative number"
            )),
        });
    let cmp = compare::compare(&load_points(a), &load_points(b), fail_below_pct);
    print!("{}", compare::render(&cmp));
    if cmp.failed() {
        die_run(&format!(
            "comparison failed the regression threshold: {} regression(s), \
             {} point(s) missing from B (see report above)",
            cmp.regressions.len(),
            cmp.only_in_a.len()
        ));
    }
    std::process::exit(0)
}

/// `sve serve`: bind, announce, run until a shutdown request drains.
fn run_serve(args: &[String]) -> ! {
    let opts = usage(ServeOpts::from_cli(args));
    let server = match Server::bind(&opts.listen, ServerConfig::from_opts(&opts)) {
        Ok(s) => s,
        Err(e) => die_run(&e),
    };
    match server.local_addr() {
        Ok(addr) => println!("serve: listening on {addr}, store {}/jobs/", opts.out.display()),
        Err(e) => die_run(&format!("local addr: {e}")),
    }
    if let Err(e) = server.run() {
        die_run(&e);
    }
    let stats = server.stats();
    println!(
        "serve: drained; lifetime {} simulated, {} deduped, {} reloaded, {} evicted",
        stats.simulated, stats.deduped, stats.reloaded, stats.evicted
    );
    std::process::exit(0)
}

/// One streamed job result on the terminal.
fn print_job(job: &JobLine) {
    println!(
        "{:<14} {:<8} {:<10} {:<9} {} cycles",
        job.record.bench,
        job.record.isa.label(),
        job.variant,
        job.source.as_str(),
        job.record.cycles
    );
}

/// `sve submit`: one request against a running server. Connection or
/// request failures are runtime errors (exit 1) — the server being
/// down is not a usage mistake.
fn run_submit(args: &[String]) -> ! {
    let opts = usage(SubmitOpts::from_cli(args));
    let mut client = match Client::connect(&opts.addr) {
        Ok(c) => c,
        Err(e) => die_run(&e),
    };
    match &opts.action {
        SubmitAction::Ping => match client.ping() {
            Ok(()) => println!("pong from {}", opts.addr),
            Err(e) => die_run(&e),
        },
        SubmitAction::Stats => match client.stats() {
            Ok(s) => println!(
                "server at {}: {} simulated, {} deduped, {} reloaded, {} evicted",
                opts.addr, s.simulated, s.deduped, s.reloaded, s.evicted
            ),
            Err(e) => die_run(&e),
        },
        SubmitAction::Shutdown => match client.shutdown_server() {
            Ok(()) => println!("server at {} is shutting down", opts.addr),
            Err(e) => die_run(&e),
        },
        SubmitAction::Sweep(req) => match client.submit_sweep(req, &mut print_job) {
            // CI greps this exact accounting line — keep the wording
            Ok(c) => println!(
                "{} jobs: {} simulated, {} deduped, {} reloaded",
                c.jobs, c.simulated, c.deduped, c.reloaded
            ),
            Err(e) => die_run(&e),
        },
        SubmitAction::Dse(req) => match client.submit_dse(req, &mut print_job) {
            Ok(c) => println!(
                "{} jobs: {} simulated, {} deduped, {} reloaded",
                c.jobs, c.simulated, c.deduped, c.reloaded
            ),
            Err(e) => die_run(&e),
        },
    }
    std::process::exit(0)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
        }
        "list" => {
            for n in workloads::NAMES {
                let w = workloads::build(n);
                println!("{n:<14} {}", w.group.label());
            }
        }
        "run" => {
            let name = usage(request::parse_bench_arg(&args, "run"));
            // validate --vl whatever the ISA: a typo'd value must never
            // be silently ignored (scalar/neon fix the width at 128)
            let vl = usage(request::parse_vl(&args, 256));
            let isa = match usage(request::flag(&args, "--isa")).as_deref() {
                Some("scalar") => Isa::Scalar,
                Some("neon") => Isa::Neon,
                Some("sve") | None => Isa::Sve(vl),
                Some(other) => {
                    die_usage(&format!("unknown --isa '{other}' (scalar, neon or sve)"))
                }
            };
            match coordinator::run_one_engine_stats(name, isa, request::parse_engine(&args)) {
                Ok((r, stats)) => {
                    println!(
                        "{} on {}: {} insts, {} cycles, ipc {:.2}, vectorized={}, \
                         vector-fraction {:.1}%, L1D miss {:.2}%",
                        r.bench,
                        r.isa.label(),
                        r.insts,
                        r.cycles,
                        r.ipc,
                        r.vectorized,
                        100.0 * r.vector_fraction,
                        100.0 * r.l1d_miss_rate
                    );
                    if request::has_flag(&args, "--trace-stats") {
                        let t = stats.trace;
                        println!(
                            "trace: built={} rejected={} rerecorded={} link_jumps={} \
                             dense_iters={} general_iters={}",
                            t.built,
                            t.rejected,
                            t.rerecorded,
                            t.link_jumps,
                            t.dense_iters,
                            t.general_iters
                        );
                    }
                }
                Err(e) => die_run(&e),
            }
        }
        "sweep" => {
            let req = usage(SweepRequest::from_cli(&args));
            let (cfg, out) = req.to_config();
            run_sweep_and_emit(&cfg, &out);
        }
        "dse" => {
            let req = usage(DseRequest::from_cli(&args));
            let (cfg, out) = req.sweep.to_config();
            let variants = usage(req.variants());
            let outcome = match coordinator::run_dse(&cfg, &variants) {
                Ok(o) => o,
                Err(e) => die_run(&e),
            };
            // --pareto-only: restrict reporting and artifacts to the
            // frontier design points
            let (shown, pts) = if req.pareto_only {
                report::dse::frontier_only(&outcome.variants, &cfg.vls)
            } else {
                let pts = report::dse::pareto(&outcome.variants, &cfg.vls);
                (outcome.variants.clone(), pts)
            };
            for v in &shown {
                println!("## {}\n", v.name);
                println!("{}", report::fig8::table(&v.rows, &cfg.vls).to_markdown());
            }
            println!("## Cross-variant pivot — speedup, perf/W, perf/mm2 over NEON\n");
            println!("{}", report::dse::pivot(&shown, &cfg.vls).to_markdown());
            if req.pareto_only {
                println!("## Pareto frontier (frontier-only view)\n");
            } else {
                println!("## Pareto frontier — performance vs energy vs area\n");
            }
            println!("{}", report::dse::pareto_table(&pts).to_markdown());
            let paths = if req.pareto_only {
                report::dse::write_artifacts_pareto_only(&outcome.variants, &cfg.vls, &out)
            } else {
                report::dse::write_artifacts(&outcome.variants, &cfg.vls, &out)
            };
            emit_paths_and_counts(paths, "dse", outcome.simulated, outcome.reloaded, &out);
        }
        "report" if args.iter().any(|a| a == "--compare") => run_compare(&args),
        "report" => {
            let req = usage(ReportRequest::from_cli(&args));
            let (cfg, out) = req.sweep.to_config();
            let fig2 = report::fig2::build(report::fig2::DAXPY_N);
            match report::fig2::write_artifacts(&fig2, &out) {
                Ok(paths) => paths.iter().for_each(|p| println!("wrote {}", p.display())),
                Err(e) => die_run(&format!("write fig2 artifacts: {e}")),
            }
            match report::fig7::write_artifacts(&out) {
                Ok(paths) => paths.iter().for_each(|p| println!("wrote {}", p.display())),
                Err(e) => die_run(&format!("write fig7 artifacts: {e}")),
            }
            run_sweep_and_emit(&cfg, &out);
        }
        "serve" => run_serve(&args),
        "submit" => run_submit(&args),
        "trace" => {
            let name = usage(request::parse_bench_arg(&args, "trace"));
            let vl = usage(request::parse_vl(&args, 256));
            let limit: u64 = match usage(request::flag(&args, "--limit")) {
                Some(t) => match t.parse() {
                    Ok(n) => n,
                    Err(_) => die_usage(&format!("--limit '{t}' is not a number")),
                },
                None => 64,
            };
            let w = workloads::build(name);
            let c = w.compile(sve_repro::compiler::Target::Sve);
            let mut ex = Executor::new(vl, w.mem.clone());
            let mut pipe = sve_repro::uarch::Pipeline::new(UarchConfig::default(), vl);
            pipe.enable_trace();
            // budget exhaustion is expected: we trace only a prefix
            let _ = ex.run_with(&c.program, limit, |i| pipe.on_retire(&i));
            let tr = pipe.trace.take().unwrap_or_default();
            println!("{}", sve_repro::uarch::trace::render_timeline(&c.program, &tr));
            println!("(traced prefix: {} cycles)", pipe.result.cycles);
        }
        "encoding" => {
            let (groups, total) = encoding::sve_region_report();
            let mut t = Table::new(vec!["group", "points", "share of 2^28"]);
            for g in &groups {
                t.push_row(vec![
                    g.group.clone(),
                    g.points.to_string(),
                    format!("{:.3}%", 100.0 * g.share_of_region),
                ]);
            }
            println!("{}", t.to_markdown());
            println!(
                "total: {total} of {} encoding points ({:.2}%) — Fig. 7: SVE fits one \
                 28-bit region",
                encoding::SVE_REGION_POINTS,
                100.0 * total as f64 / encoding::SVE_REGION_POINTS as f64
            );
            let (d, c) = encoding::constructive_counterfactual();
            println!(
                "§4 counterfactual (full {}-opcode dp set): destructive+movprfx = {d} \
                 points; fully-constructive = {c} points ({:.1}x the whole region)",
                encoding::FULL_DP_OPCODES,
                c as f64 / encoding::SVE_REGION_POINTS as f64
            );
        }
        "validate" => {
            let dir = usage(request::flag(&args, "--artifacts"))
                .unwrap_or_else(|| "artifacts".into());
            match sve_repro::runtime::validate_all(&dir) {
                Ok(vs) => {
                    for v in &vs {
                        println!(
                            "{:<8} {} (max |err| = {:.3e})",
                            v.name,
                            if v.ok { "OK" } else { "MISMATCH" },
                            v.max_abs_err
                        );
                    }
                    if vs.iter().any(|v| !v.ok) {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("validation failed: {e:#} (run `make artifacts` first)");
                    std::process::exit(1);
                }
            }
        }
        other => {
            die_usage(&format!("unknown command '{other}'"));
        }
    }
}
