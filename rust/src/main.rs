//! `sve` — CLI for the SVE-paper reproduction.
//!
//! Subcommands:
//!   run <bench> [--isa scalar|neon|sve] [--vl BITS]   one benchmark
//!   sweep [--vls 128,256,512] [--out reports/]        the Fig. 8 sweep
//!   trace <bench> [--vl BITS] [--limit N]             Fig. 3-style trace
//!   encoding                                          Fig. 7 report
//!   validate [--artifacts DIR]                        PJRT cross-check
//!   list                                              benchmarks

use sve_repro::coordinator::{self, Isa};
use sve_repro::csvutil::Table;
use sve_repro::exec::Executor;
use sve_repro::isa::encoding;
use sve_repro::uarch::UarchConfig;
use sve_repro::workloads;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "list" => {
            for n in workloads::NAMES {
                let w = workloads::build(n);
                println!("{n:<14} {}", w.group.label());
            }
        }
        "run" => {
            let bench = args.get(1).expect("usage: sve run <bench>");
            let name = workloads::NAMES
                .iter()
                .find(|n| *n == bench)
                .unwrap_or_else(|| panic!("unknown benchmark {bench}"));
            let isa = match flag(&args, "--isa").as_deref() {
                Some("scalar") => Isa::Scalar,
                Some("neon") => Isa::Neon,
                _ => {
                    let vl = flag(&args, "--vl").and_then(|v| v.parse().ok()).unwrap_or(256);
                    Isa::Sve(vl)
                }
            };
            match coordinator::run_one(name, isa) {
                Ok(r) => {
                    println!(
                        "{} on {}: {} insts, {} cycles, ipc {:.2}, vectorized={}, \
                         vector-fraction {:.1}%, L1D miss {:.2}%",
                        r.bench,
                        r.isa.label(),
                        r.insts,
                        r.cycles,
                        r.ipc,
                        r.vectorized,
                        100.0 * r.vector_fraction,
                        100.0 * r.l1d_miss_rate
                    );
                }
                Err(e) => {
                    eprintln!("FAILED: {e}");
                    std::process::exit(1);
                }
            }
        }
        "sweep" => {
            let vls: Vec<usize> = flag(&args, "--vls")
                .unwrap_or_else(|| "128,256,512".into())
                .split(',')
                .map(|v| v.parse().expect("vl"))
                .collect();
            let out = flag(&args, "--out").unwrap_or_else(|| "reports".into());
            let rows = coordinator::run_fig8(&vls, &workloads::NAMES).expect("sweep");
            let t = coordinator::fig8_table(&rows, &vls);
            println!("{}", t.to_markdown());
            println!("{}", coordinator::fig8_chart(&rows, &vls));
            t.write_csv(format!("{out}/fig8.csv")).expect("write csv");
            println!("wrote {out}/fig8.csv");
        }
        "trace" => {
            let bench = args.get(1).expect("usage: sve trace <bench>");
            let vl = flag(&args, "--vl").and_then(|v| v.parse().ok()).unwrap_or(256);
            let limit: u64 = flag(&args, "--limit").and_then(|v| v.parse().ok()).unwrap_or(64);
            let w = workloads::build(bench);
            let c = w.compile(sve_repro::compiler::Target::Sve);
            let mut ex = Executor::new(vl, w.mem.clone());
            let mut pipe = sve_repro::uarch::Pipeline::new(UarchConfig::default(), vl);
            pipe.enable_trace();
            // budget exhaustion is expected: we trace only a prefix
            let _ = ex.run_with(&c.program, limit, |i| pipe.on_retire(&i));
            let tr = pipe.trace.take().unwrap_or_default();
            println!("{}", sve_repro::uarch::trace::render_timeline(&c.program, &tr));
            println!("(traced prefix: {} cycles)", pipe.result.cycles);
        }
        "encoding" => {
            let (groups, total) = encoding::sve_region_report();
            let mut t = Table::new(vec!["group", "points", "share of 2^28"]);
            for g in &groups {
                t.push_row(vec![
                    g.group.clone(),
                    g.points.to_string(),
                    format!("{:.3}%", 100.0 * g.share_of_region),
                ]);
            }
            println!("{}", t.to_markdown());
            println!(
                "total: {total} of {} encoding points ({:.2}%) — Fig. 7: SVE fits one \
                 28-bit region",
                encoding::SVE_REGION_POINTS,
                100.0 * total as f64 / encoding::SVE_REGION_POINTS as f64
            );
            let (d, c) = encoding::constructive_counterfactual();
            println!(
                "§4 counterfactual (full {}-opcode dp set): destructive+movprfx = {d} \
                 points; fully-constructive = {c} points ({}x the whole region)",
                encoding::FULL_DP_OPCODES,
                c / encoding::SVE_REGION_POINTS
            );
        }
        "validate" => {
            let dir = flag(&args, "--artifacts").unwrap_or_else(|| "artifacts".into());
            match sve_repro::runtime::validate_all(&dir) {
                Ok(vs) => {
                    for v in &vs {
                        println!(
                            "{:<8} {} (max |err| = {:.3e})",
                            v.name,
                            if v.ok { "OK" } else { "MISMATCH" },
                            v.max_abs_err
                        );
                    }
                    if vs.iter().any(|v| !v.ok) {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("validation failed: {e:#} (run `make artifacts` first)");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            println!(
                "sve — ARM SVE paper reproduction\n\
                 usage: sve <list|run|sweep|trace|encoding|validate> [options]\n\
                 see `cargo doc` and README.md"
            );
        }
    }
}
