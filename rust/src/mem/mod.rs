//! Paged memory with translation faults — the substrate first-faulting
//! loads (§2.3.3) are defined against.
//!
//! Memory is sparse: 4 KiB pages allocated on [`Memory::map`]. Accessing
//! an unmapped page returns [`MemFault`] instead of panicking, which the
//! executor turns either into a trap (scalar access, or the first active
//! element of a first-fault load) or into an FFR update (any other
//! element of a first-fault load).

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Trivial multiply-mix hasher for page numbers (SipHash is the hot spot
/// otherwise — pages are already well-distributed keys).
#[derive(Default)]
pub struct PageHasher(u64);

impl Hasher for PageHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }
    fn write_u64(&mut self, v: u64) {
        self.0 = v.wrapping_mul(0x9E3779B97F4A7C15);
        self.0 ^= self.0 >> 29;
    }
}

pub const PAGE_SIZE: usize = 4096;
pub const PAGE_SHIFT: u32 = 12;

/// A failed translation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemFault {
    pub addr: u64,
    pub is_store: bool,
}

/// Sparse paged memory.
#[derive(Default, Clone)]
pub struct Memory {
    pages: HashMap<u64, Box<[u8; PAGE_SIZE]>, BuildHasherDefault<PageHasher>>,
    /// Monotone bump pointer for [`Memory::alloc`].
    brk: u64,
}

impl Memory {
    pub fn new() -> Self {
        Memory { pages: HashMap::default(), brk: 0x0001_0000 }
    }

    #[inline]
    fn page_of(addr: u64) -> u64 {
        addr >> PAGE_SHIFT
    }

    /// Map all pages covering `[base, base+len)` (idempotent).
    pub fn map(&mut self, base: u64, len: u64) {
        if len == 0 {
            return;
        }
        let first = Self::page_of(base);
        let last = Self::page_of(base + len - 1);
        for p in first..=last {
            self.pages.entry(p).or_insert_with(|| Box::new([0u8; PAGE_SIZE]));
        }
    }

    /// Remove the mapping of the page containing `addr` (for fault tests).
    pub fn unmap_page(&mut self, addr: u64) {
        self.pages.remove(&Self::page_of(addr));
    }

    pub fn is_mapped(&self, addr: u64) -> bool {
        self.pages.contains_key(&Self::page_of(addr))
    }

    /// Bump-allocate `len` bytes with `align` alignment; maps the range.
    /// Guarantees one full unmapped guard page between allocations, so
    /// runaway kernels fault quickly (and first-fault loads running off
    /// the end of a buffer genuinely fault, as in Fig. 4/5).
    pub fn alloc(&mut self, len: u64, align: u64) -> u64 {
        assert!(align.is_power_of_two());
        let base = (self.brk + align - 1) & !(align - 1);
        self.map(base, len);
        self.brk = ((base + len + PAGE_SIZE as u64) & !(PAGE_SIZE as u64 - 1)) + PAGE_SIZE as u64;
        base
    }

    /// Read up to 8 bytes (little-endian) as a u64. The access may cross
    /// a page boundary; it faults if *any* byte is unmapped.
    #[inline]
    pub fn read(&self, addr: u64, size: usize) -> Result<u64, MemFault> {
        debug_assert!(size <= 8);
        // fast path: fully inside one page
        let off = (addr & (PAGE_SIZE as u64 - 1)) as usize;
        if off + size <= PAGE_SIZE {
            let page = self
                .pages
                .get(&Self::page_of(addr))
                .ok_or(MemFault { addr, is_store: false })?;
            let mut v = 0u64;
            for k in 0..size {
                v |= (page[off + k] as u64) << (8 * k);
            }
            Ok(v)
        } else {
            let mut v = 0u64;
            for k in 0..size {
                v |= (self.read_byte(addr + k as u64)? as u64) << (8 * k);
            }
            Ok(v)
        }
    }

    #[inline]
    pub fn read_byte(&self, addr: u64) -> Result<u8, MemFault> {
        let page = self
            .pages
            .get(&Self::page_of(addr))
            .ok_or(MemFault { addr, is_store: false })?;
        Ok(page[(addr & (PAGE_SIZE as u64 - 1)) as usize])
    }

    /// Write up to 8 bytes (little-endian).
    #[inline]
    pub fn write(&mut self, addr: u64, size: usize, v: u64) -> Result<(), MemFault> {
        debug_assert!(size <= 8);
        let off = (addr & (PAGE_SIZE as u64 - 1)) as usize;
        if off + size <= PAGE_SIZE {
            let page = self
                .pages
                .get_mut(&Self::page_of(addr))
                .ok_or(MemFault { addr, is_store: true })?;
            for k in 0..size {
                page[off + k] = (v >> (8 * k)) as u8;
            }
            Ok(())
        } else {
            for k in 0..size {
                self.write_byte(addr + k as u64, (v >> (8 * k)) as u8)?;
            }
            Ok(())
        }
    }

    #[inline]
    pub fn write_byte(&mut self, addr: u64, v: u8) -> Result<(), MemFault> {
        let page = self
            .pages
            .get_mut(&Self::page_of(addr))
            .ok_or(MemFault { addr, is_store: true })?;
        page[(addr & (PAGE_SIZE as u64 - 1)) as usize] = v;
        Ok(())
    }

    // ---- typed convenience accessors (workload setup / golden checks) ----

    pub fn read_u64(&self, addr: u64) -> Result<u64, MemFault> {
        self.read(addr, 8)
    }

    pub fn write_u64(&mut self, addr: u64, v: u64) -> Result<(), MemFault> {
        self.write(addr, 8, v)
    }

    pub fn read_f64(&self, addr: u64) -> Result<f64, MemFault> {
        Ok(f64::from_bits(self.read(addr, 8)?))
    }

    pub fn write_f64(&mut self, addr: u64, v: f64) -> Result<(), MemFault> {
        self.write(addr, 8, v.to_bits())
    }

    pub fn read_f32(&self, addr: u64) -> Result<f32, MemFault> {
        Ok(f32::from_bits(self.read(addr, 4)? as u32))
    }

    pub fn write_f32(&mut self, addr: u64, v: f32) -> Result<(), MemFault> {
        self.write(addr, 4, v.to_bits() as u64)
    }

    pub fn read_u32(&self, addr: u64) -> Result<u32, MemFault> {
        Ok(self.read(addr, 4)? as u32)
    }

    pub fn write_u32(&mut self, addr: u64, v: u32) -> Result<(), MemFault> {
        self.write(addr, 4, v as u64)
    }

    /// Bulk fill of f64 slice.
    pub fn write_f64_slice(&mut self, base: u64, xs: &[f64]) {
        for (i, &v) in xs.iter().enumerate() {
            self.write_f64(base + 8 * i as u64, v).expect("mapped");
        }
    }

    pub fn read_f64_slice(&self, base: u64, n: usize) -> Vec<f64> {
        (0..n).map(|i| self.read_f64(base + 8 * i as u64).expect("mapped")).collect()
    }

    pub fn write_f32_slice(&mut self, base: u64, xs: &[f32]) {
        for (i, &v) in xs.iter().enumerate() {
            self.write_f32(base + 4 * i as u64, v).expect("mapped");
        }
    }

    pub fn read_f32_slice(&self, base: u64, n: usize) -> Vec<f32> {
        (0..n).map(|i| self.read_f32(base + 4 * i as u64).expect("mapped")).collect()
    }

    pub fn write_u64_slice(&mut self, base: u64, xs: &[u64]) {
        for (i, &v) in xs.iter().enumerate() {
            self.write_u64(base + 8 * i as u64, v).expect("mapped");
        }
    }

    pub fn read_u64_slice(&self, base: u64, n: usize) -> Vec<u64> {
        (0..n).map(|i| self.read_u64(base + 8 * i as u64).expect("mapped")).collect()
    }

    pub fn write_u32_slice(&mut self, base: u64, xs: &[u32]) {
        for (i, &v) in xs.iter().enumerate() {
            self.write_u32(base + 4 * i as u64, v).expect("mapped");
        }
    }

    /// Number of mapped pages (footprint metric).
    pub fn mapped_pages(&self) -> usize {
        self.pages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;

    #[test]
    fn unmapped_access_faults() {
        let m = Memory::new();
        assert_eq!(m.read(0x5000, 8), Err(MemFault { addr: 0x5000, is_store: false }));
    }

    #[test]
    fn map_then_rw_roundtrip() {
        let mut m = Memory::new();
        m.map(0x1000, 64);
        m.write(0x1008, 8, 0x1122_3344_5566_7788).unwrap();
        assert_eq!(m.read(0x1008, 8).unwrap(), 0x1122_3344_5566_7788);
        assert_eq!(m.read(0x1008, 4).unwrap(), 0x5566_7788);
        assert_eq!(m.read(0x100C, 4).unwrap(), 0x1122_3344);
    }

    #[test]
    fn cross_page_access_works_when_both_mapped() {
        let mut m = Memory::new();
        m.map(0x1000, 2 * PAGE_SIZE as u64);
        let addr = 0x1000 + PAGE_SIZE as u64 - 4;
        m.write(addr, 8, 0xAABB_CCDD_EEFF_0011).unwrap();
        assert_eq!(m.read(addr, 8).unwrap(), 0xAABB_CCDD_EEFF_0011);
    }

    #[test]
    fn cross_page_access_faults_on_second_page() {
        let mut m = Memory::new();
        m.map(0x1000, PAGE_SIZE as u64); // only the first page
        let addr = 0x1000 + PAGE_SIZE as u64 - 4;
        let r = m.read(addr, 8);
        assert!(r.is_err());
        let f = r.unwrap_err();
        assert_eq!(Memory::page_of(f.addr), Memory::page_of(0x2000));
    }

    #[test]
    fn unmap_reintroduces_faults() {
        let mut m = Memory::new();
        m.map(0x3000, 8);
        m.write_u64(0x3000, 5).unwrap();
        m.unmap_page(0x3000);
        assert!(m.read_u64(0x3000).is_err());
    }

    #[test]
    fn alloc_alignment_and_guard_pages() {
        let mut m = Memory::new();
        let a = m.alloc(100, 64);
        assert_eq!(a % 64, 0);
        let b = m.alloc(100, 4096);
        assert_eq!(b % 4096, 0);
        // guard page between allocations: the page right after a's last
        // byte (rounded up) must be unmapped
        let guard = (a + 100).div_ceil(PAGE_SIZE as u64) * PAGE_SIZE as u64;
        assert!(!m.is_mapped(guard), "guard page must stay unmapped");
        assert!(b > a + 100);
    }

    #[test]
    fn f64_and_f32_roundtrip() {
        let mut m = Memory::new();
        m.map(0x8000, 64);
        m.write_f64(0x8000, -2.25).unwrap();
        assert_eq!(m.read_f64(0x8000).unwrap(), -2.25);
        m.write_f32(0x8010, 9.5).unwrap();
        assert_eq!(m.read_f32(0x8010).unwrap(), 9.5);
    }

    #[test]
    fn prop_rw_roundtrip_any_size() {
        check("prop_rw_roundtrip_any_size", 300, |g| {
            let mut m = Memory::new();
            let base = 0x1000 + g.u64_in(0, 4000);
            m.map(0x1000, 3 * PAGE_SIZE as u64);
            let size = g.usize_in(1, 8);
            let v = g.u64();
            let mask = if size == 8 { u64::MAX } else { (1u64 << (8 * size)) - 1 };
            m.write(base, size, v).unwrap();
            assert_eq!(m.read(base, size).unwrap(), v & mask);
        });
    }

    #[test]
    fn slice_helpers_roundtrip() {
        let mut m = Memory::new();
        let base = m.alloc(8 * 16, 8);
        let xs: Vec<f64> = (0..16).map(|i| i as f64 * 1.5).collect();
        m.write_f64_slice(base, &xs);
        assert_eq!(m.read_f64_slice(base, 16), xs);
    }
}
