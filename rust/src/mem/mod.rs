//! Paged memory with translation faults — the substrate first-faulting
//! loads (§2.3.3) are defined against.
//!
//! Memory is sparse: 4 KiB pages allocated on [`Memory::map`]. Accessing
//! an unmapped page returns [`MemFault`] instead of panicking, which the
//! executor turns either into a trap (scalar access, or the first active
//! element of a first-fault load) or into an FFR update (any other
//! element of a first-fault load).
//!
//! # Hot-path design
//!
//! Pages live in an in-house open-addressing table (linear probing,
//! multiplicative hashing, tombstoned deletes) rather than a `HashMap`:
//! a translation is one multiply plus, typically, one tag compare, and a
//! slot index is a plain integer the executor's software TLB can cache
//! across instructions (see [`crate::exec`]).
//!
//! Two mechanisms keep cached translations safe without `unsafe`:
//!
//! * every structural change (page insert, unmap, table growth, clone)
//!   stamps the memory with a fresh globally-unique [`Memory::epoch`],
//!   so a TLB that remembers the epoch it filled at can discard stale
//!   slot handles wholesale;
//! * bulk accessors ([`Memory::read_into`] / [`Memory::write_from`])
//!   translate once per *page* and move whole in-page slices with
//!   `copy_from_slice`, instead of translating (and shifting bytes) once
//!   per lane.

use std::sync::atomic::{AtomicU64, Ordering};

pub const PAGE_SIZE: usize = 4096;
pub const PAGE_SHIFT: u32 = 12;
const PAGE_MASK: u64 = PAGE_SIZE as u64 - 1;

/// Tag of an empty page-table slot (never a valid page number: pages are
/// addresses shifted right by 12, so they fit in 52 bits).
const EMPTY: u64 = u64::MAX;
/// Tag of a tombstoned (unmapped) slot — probes continue across it.
const TOMB: u64 = u64::MAX - 1;

/// Monotone source of epoch stamps. Global (not per-Memory) so that two
/// distinct `Memory` values can never carry the same epoch: replacing an
/// executor's memory wholesale invalidates its TLB just like an unmap.
static EPOCH_SOURCE: AtomicU64 = AtomicU64::new(0);

#[inline]
fn fresh_epoch() -> u64 {
    EPOCH_SOURCE.fetch_add(1, Ordering::Relaxed) + 1
}

/// A failed translation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemFault {
    pub addr: u64,
    pub is_store: bool,
}

/// Sparse paged memory.
pub struct Memory {
    /// Page number per slot, or `EMPTY` / `TOMB`.
    tags: Vec<u64>,
    /// Page frames, parallel to `tags` (`Some` iff the tag is a page).
    frames: Vec<Option<Box<[u8; PAGE_SIZE]>>>,
    mask: usize,
    /// Mapped pages.
    live: usize,
    /// Mapped pages + tombstones (drives table growth).
    used: usize,
    /// Monotone bump pointer for [`Memory::alloc`].
    brk: u64,
    epoch: u64,
}

impl Default for Memory {
    fn default() -> Self {
        Memory::new()
    }
}

impl Clone for Memory {
    fn clone(&self) -> Self {
        Memory {
            tags: self.tags.clone(),
            frames: self.frames.clone(),
            mask: self.mask,
            live: self.live,
            used: self.used,
            brk: self.brk,
            // a clone has its own frames: stale TLB handles into the
            // original must not validate against it
            epoch: fresh_epoch(),
        }
    }
}

impl Memory {
    pub fn new() -> Self {
        let cap = 256;
        let mut frames = Vec::with_capacity(cap);
        frames.resize_with(cap, || None);
        Memory {
            tags: vec![EMPTY; cap],
            frames,
            mask: cap - 1,
            live: 0,
            used: 0,
            brk: 0x0001_0000,
            epoch: fresh_epoch(),
        }
    }

    #[inline]
    fn page_of(addr: u64) -> u64 {
        addr >> PAGE_SHIFT
    }

    /// Multiplicative hash of a page number (pages are well-distributed
    /// keys, so a single mix step suffices).
    #[inline]
    fn hash(page: u64) -> usize {
        let h = page.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h ^ (h >> 29)) as usize
    }

    /// Slot of an existing page, if mapped.
    #[inline]
    fn slot_of(&self, page: u64) -> Option<usize> {
        let mut i = Self::hash(page) & self.mask;
        loop {
            let t = self.tags[i];
            if t == page {
                return Some(i);
            }
            if t == EMPTY {
                return None;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Insert (or find) `page`, returning its slot.
    fn ensure_page(&mut self, page: u64) -> usize {
        debug_assert!(page < TOMB);
        if (self.used + 1) * 10 > self.tags.len() * 7 {
            self.grow();
        }
        let mut i = Self::hash(page) & self.mask;
        let mut tomb: Option<usize> = None;
        loop {
            let t = self.tags[i];
            if t == page {
                return i;
            }
            if t == EMPTY {
                let j = match tomb {
                    Some(j) => j,
                    None => {
                        self.used += 1;
                        i
                    }
                };
                self.tags[j] = page;
                self.frames[j] = Some(Box::new([0u8; PAGE_SIZE]));
                self.live += 1;
                self.epoch = fresh_epoch();
                return j;
            }
            if t == TOMB && tomb.is_none() {
                tomb = Some(i);
            }
            i = (i + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        let cap = self.tags.len() * 2;
        let mask = cap - 1;
        let mut tags = vec![EMPTY; cap];
        let mut frames: Vec<Option<Box<[u8; PAGE_SIZE]>>> = Vec::with_capacity(cap);
        frames.resize_with(cap, || None);
        for k in 0..self.tags.len() {
            let t = self.tags[k];
            if t < TOMB {
                let mut i = Self::hash(t) & mask;
                while tags[i] != EMPTY {
                    i = (i + 1) & mask;
                }
                tags[i] = t;
                frames[i] = self.frames[k].take();
            }
        }
        self.tags = tags;
        self.frames = frames;
        self.mask = mask;
        self.used = self.live;
        self.epoch = fresh_epoch();
    }

    #[inline]
    fn frame_of(&self, addr: u64) -> Option<&[u8; PAGE_SIZE]> {
        let i = self.slot_of(Self::page_of(addr))?;
        self.frames[i].as_deref()
    }

    #[inline]
    fn frame_mut_of(&mut self, addr: u64) -> Option<&mut [u8; PAGE_SIZE]> {
        let i = self.slot_of(Self::page_of(addr))?;
        self.frames[i].as_deref_mut()
    }

    /// Map all pages covering `[base, base+len)` (idempotent).
    pub fn map(&mut self, base: u64, len: u64) {
        if len == 0 {
            return;
        }
        let first = Self::page_of(base);
        let last = Self::page_of(base + len - 1);
        for p in first..=last {
            self.ensure_page(p);
        }
    }

    /// Remove the mapping of the page containing `addr` (for fault tests).
    pub fn unmap_page(&mut self, addr: u64) {
        if let Some(i) = self.slot_of(Self::page_of(addr)) {
            self.tags[i] = TOMB;
            self.frames[i] = None;
            self.live -= 1;
            self.epoch = fresh_epoch();
        }
    }

    pub fn is_mapped(&self, addr: u64) -> bool {
        self.slot_of(Self::page_of(addr)).is_some()
    }

    /// Bump-allocate `len` bytes with `align` alignment; maps the range.
    /// Guarantees one full unmapped guard page between allocations, so
    /// runaway kernels fault quickly (and first-fault loads running off
    /// the end of a buffer genuinely fault, as in Fig. 4/5).
    pub fn alloc(&mut self, len: u64, align: u64) -> u64 {
        assert!(align.is_power_of_two());
        let base = (self.brk + align - 1) & !(align - 1);
        self.map(base, len);
        self.brk = ((base + len + PAGE_SIZE as u64) & !(PAGE_SIZE as u64 - 1)) + PAGE_SIZE as u64;
        base
    }

    // ---- translation-cache (TLB) interface ----

    /// Epoch stamp: changes on every page insert/unmap/table growth and
    /// on every new `Memory` value (including clones). A cached slot
    /// handle is valid exactly as long as the epoch it was obtained at.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Slot handle of `addr`'s page, stable until [`Memory::epoch`]
    /// changes. This is what the executor's software TLB caches.
    #[inline]
    pub fn slot_handle(&self, addr: u64) -> Option<u32> {
        self.slot_of(Self::page_of(addr)).map(|i| i as u32)
    }

    /// Page frame behind a slot handle obtained at the current epoch.
    /// Panics on a stale handle (a TLB bug), never yields wrong bytes.
    #[inline]
    pub fn slot_frame(&self, slot: u32) -> &[u8; PAGE_SIZE] {
        self.frames[slot as usize].as_deref().expect("stale TLB slot handle")
    }

    /// Mutable page frame behind a slot handle (current epoch only).
    #[inline]
    pub fn slot_frame_mut(&mut self, slot: u32) -> &mut [u8; PAGE_SIZE] {
        self.frames[slot as usize].as_deref_mut().expect("stale TLB slot handle")
    }

    // ---- scalar accessors ----

    /// Read up to 8 bytes (little-endian) as a u64. The access may cross
    /// a page boundary; it faults if *any* byte is unmapped.
    #[inline]
    pub fn read(&self, addr: u64, size: usize) -> Result<u64, MemFault> {
        debug_assert!(size <= 8);
        let off = (addr & PAGE_MASK) as usize;
        if off + size <= PAGE_SIZE {
            let frame = self.frame_of(addr).ok_or(MemFault { addr, is_store: false })?;
            let mut w = [0u8; 8];
            w[..size].copy_from_slice(&frame[off..off + size]);
            Ok(u64::from_le_bytes(w))
        } else {
            let mut v = 0u64;
            for k in 0..size {
                v |= (self.read_byte(addr + k as u64)? as u64) << (8 * k);
            }
            Ok(v)
        }
    }

    #[inline]
    pub fn read_byte(&self, addr: u64) -> Result<u8, MemFault> {
        let frame = self.frame_of(addr).ok_or(MemFault { addr, is_store: false })?;
        Ok(frame[(addr & PAGE_MASK) as usize])
    }

    /// Write up to 8 bytes (little-endian).
    #[inline]
    pub fn write(&mut self, addr: u64, size: usize, v: u64) -> Result<(), MemFault> {
        debug_assert!(size <= 8);
        let off = (addr & PAGE_MASK) as usize;
        if off + size <= PAGE_SIZE {
            let frame = self.frame_mut_of(addr).ok_or(MemFault { addr, is_store: true })?;
            frame[off..off + size].copy_from_slice(&v.to_le_bytes()[..size]);
            Ok(())
        } else {
            for k in 0..size {
                self.write_byte(addr + k as u64, (v >> (8 * k)) as u8)?;
            }
            Ok(())
        }
    }

    #[inline]
    pub fn write_byte(&mut self, addr: u64, v: u8) -> Result<(), MemFault> {
        let frame = self.frame_mut_of(addr).ok_or(MemFault { addr, is_store: true })?;
        frame[(addr & PAGE_MASK) as usize] = v;
        Ok(())
    }

    // ---- bulk accessors (one translation per page touched) ----

    /// Copy `out.len()` contiguous bytes starting at `addr` into `out`.
    /// Faults at the exact address of the first unmapped byte; bytes in
    /// earlier (mapped) pages are already copied at that point.
    pub fn read_into(&self, addr: u64, out: &mut [u8]) -> Result<(), MemFault> {
        let mut done = 0usize;
        while done < out.len() {
            let a = addr + done as u64;
            let off = (a & PAGE_MASK) as usize;
            let chunk = (PAGE_SIZE - off).min(out.len() - done);
            let frame = self.frame_of(a).ok_or(MemFault { addr: a, is_store: false })?;
            out[done..done + chunk].copy_from_slice(&frame[off..off + chunk]);
            done += chunk;
        }
        Ok(())
    }

    /// Copy `src` to `[addr, addr+src.len())`. Faults at the exact
    /// address of the first unmapped byte; earlier pages stay written.
    pub fn write_from(&mut self, addr: u64, src: &[u8]) -> Result<(), MemFault> {
        let mut done = 0usize;
        while done < src.len() {
            let a = addr + done as u64;
            let off = (a & PAGE_MASK) as usize;
            let chunk = (PAGE_SIZE - off).min(src.len() - done);
            let frame = self.frame_mut_of(a).ok_or(MemFault { addr: a, is_store: true })?;
            frame[off..off + chunk].copy_from_slice(&src[done..done + chunk]);
            done += chunk;
        }
        Ok(())
    }

    // ---- typed convenience accessors (workload setup / golden checks) ----

    pub fn read_u64(&self, addr: u64) -> Result<u64, MemFault> {
        self.read(addr, 8)
    }

    pub fn write_u64(&mut self, addr: u64, v: u64) -> Result<(), MemFault> {
        self.write(addr, 8, v)
    }

    pub fn read_f64(&self, addr: u64) -> Result<f64, MemFault> {
        Ok(f64::from_bits(self.read(addr, 8)?))
    }

    pub fn write_f64(&mut self, addr: u64, v: f64) -> Result<(), MemFault> {
        self.write(addr, 8, v.to_bits())
    }

    pub fn read_f32(&self, addr: u64) -> Result<f32, MemFault> {
        Ok(f32::from_bits(self.read(addr, 4)? as u32))
    }

    pub fn write_f32(&mut self, addr: u64, v: f32) -> Result<(), MemFault> {
        self.write(addr, 4, v.to_bits() as u64)
    }

    pub fn read_u32(&self, addr: u64) -> Result<u32, MemFault> {
        Ok(self.read(addr, 4)? as u32)
    }

    pub fn write_u32(&mut self, addr: u64, v: u32) -> Result<(), MemFault> {
        self.write(addr, 4, v as u64)
    }

    /// Bulk fill of f64 slice (one page-granular copy via
    /// [`Memory::write_from`] — workload images are megabytes).
    pub fn write_f64_slice(&mut self, base: u64, xs: &[f64]) {
        let mut bytes = Vec::with_capacity(xs.len() * 8);
        for &v in xs {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self.write_from(base, &bytes).expect("mapped");
    }

    pub fn read_f64_slice(&self, base: u64, n: usize) -> Vec<f64> {
        let mut bytes = vec![0u8; n * 8];
        self.read_into(base, &mut bytes).expect("mapped");
        bytes
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect()
    }

    pub fn write_f32_slice(&mut self, base: u64, xs: &[f32]) {
        let mut bytes = Vec::with_capacity(xs.len() * 4);
        for &v in xs {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self.write_from(base, &bytes).expect("mapped");
    }

    pub fn read_f32_slice(&self, base: u64, n: usize) -> Vec<f32> {
        let mut bytes = vec![0u8; n * 4];
        self.read_into(base, &mut bytes).expect("mapped");
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect()
    }

    pub fn write_u64_slice(&mut self, base: u64, xs: &[u64]) {
        let mut bytes = Vec::with_capacity(xs.len() * 8);
        for &v in xs {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.write_from(base, &bytes).expect("mapped");
    }

    pub fn read_u64_slice(&self, base: u64, n: usize) -> Vec<u64> {
        let mut bytes = vec![0u8; n * 8];
        self.read_into(base, &mut bytes).expect("mapped");
        bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
    }

    pub fn write_u32_slice(&mut self, base: u64, xs: &[u32]) {
        let mut bytes = Vec::with_capacity(xs.len() * 4);
        for &v in xs {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.write_from(base, &bytes).expect("mapped");
    }

    /// Number of mapped pages (footprint metric).
    pub fn mapped_pages(&self) -> usize {
        self.live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;

    #[test]
    fn unmapped_access_faults() {
        let m = Memory::new();
        assert_eq!(m.read(0x5000, 8), Err(MemFault { addr: 0x5000, is_store: false }));
    }

    #[test]
    fn map_then_rw_roundtrip() {
        let mut m = Memory::new();
        m.map(0x1000, 64);
        m.write(0x1008, 8, 0x1122_3344_5566_7788).unwrap();
        assert_eq!(m.read(0x1008, 8).unwrap(), 0x1122_3344_5566_7788);
        assert_eq!(m.read(0x1008, 4).unwrap(), 0x5566_7788);
        assert_eq!(m.read(0x100C, 4).unwrap(), 0x1122_3344);
    }

    #[test]
    fn cross_page_access_works_when_both_mapped() {
        let mut m = Memory::new();
        m.map(0x1000, 2 * PAGE_SIZE as u64);
        let addr = 0x1000 + PAGE_SIZE as u64 - 4;
        m.write(addr, 8, 0xAABB_CCDD_EEFF_0011).unwrap();
        assert_eq!(m.read(addr, 8).unwrap(), 0xAABB_CCDD_EEFF_0011);
    }

    #[test]
    fn cross_page_access_faults_on_second_page() {
        let mut m = Memory::new();
        m.map(0x1000, PAGE_SIZE as u64); // only the first page
        let addr = 0x1000 + PAGE_SIZE as u64 - 4;
        let r = m.read(addr, 8);
        assert!(r.is_err());
        let f = r.unwrap_err();
        assert_eq!(Memory::page_of(f.addr), Memory::page_of(0x2000));
    }

    #[test]
    fn unmap_reintroduces_faults() {
        let mut m = Memory::new();
        m.map(0x3000, 8);
        m.write_u64(0x3000, 5).unwrap();
        m.unmap_page(0x3000);
        assert!(m.read_u64(0x3000).is_err());
    }

    #[test]
    fn remap_after_unmap_yields_fresh_zero_page() {
        let mut m = Memory::new();
        m.map(0x3000, 8);
        m.write_u64(0x3000, 0xDEAD_BEEF).unwrap();
        m.unmap_page(0x3000);
        m.map(0x3000, 8); // reuses the tombstoned slot
        assert_eq!(m.read_u64(0x3000).unwrap(), 0, "remapped page must be zeroed");
        assert_eq!(m.mapped_pages(), 1);
    }

    #[test]
    fn table_growth_preserves_all_pages() {
        let mut m = Memory::new();
        let base = 0x10_0000u64;
        let n = 1000u64; // forces several doublings past the 256-slot start
        for i in 0..n {
            let a = base + i * PAGE_SIZE as u64;
            m.map(a, 8);
            m.write_u64(a, i).unwrap();
        }
        assert_eq!(m.mapped_pages(), n as usize);
        for i in 0..n {
            assert_eq!(m.read_u64(base + i * PAGE_SIZE as u64).unwrap(), i, "page {i}");
        }
        // and unmapped holes still fault
        assert!(!m.is_mapped(base + n * PAGE_SIZE as u64));
    }

    #[test]
    fn alloc_alignment_and_guard_pages() {
        let mut m = Memory::new();
        let a = m.alloc(100, 64);
        assert_eq!(a % 64, 0);
        let b = m.alloc(100, 4096);
        assert_eq!(b % 4096, 0);
        // guard page between allocations: the page right after a's last
        // byte (rounded up) must be unmapped
        let guard = (a + 100).div_ceil(PAGE_SIZE as u64) * PAGE_SIZE as u64;
        assert!(!m.is_mapped(guard), "guard page must stay unmapped");
        assert!(b > a + 100);
    }

    #[test]
    fn f64_and_f32_roundtrip() {
        let mut m = Memory::new();
        m.map(0x8000, 64);
        m.write_f64(0x8000, -2.25).unwrap();
        assert_eq!(m.read_f64(0x8000).unwrap(), -2.25);
        m.write_f32(0x8010, 9.5).unwrap();
        assert_eq!(m.read_f32(0x8010).unwrap(), 9.5);
    }

    #[test]
    fn prop_rw_roundtrip_any_size() {
        check("prop_rw_roundtrip_any_size", 300, |g| {
            let mut m = Memory::new();
            let base = 0x1000 + g.u64_in(0, 4000);
            m.map(0x1000, 3 * PAGE_SIZE as u64);
            let size = g.usize_in(1, 8);
            let v = g.u64();
            let mask = if size == 8 { u64::MAX } else { (1u64 << (8 * size)) - 1 };
            m.write(base, size, v).unwrap();
            assert_eq!(m.read(base, size).unwrap(), v & mask);
        });
    }

    #[test]
    fn slice_helpers_roundtrip() {
        let mut m = Memory::new();
        let base = m.alloc(8 * 16, 8);
        let xs: Vec<f64> = (0..16).map(|i| i as f64 * 1.5).collect();
        m.write_f64_slice(base, &xs);
        assert_eq!(m.read_f64_slice(base, 16), xs);
    }

    #[test]
    fn bulk_read_write_roundtrip_across_pages() {
        let mut m = Memory::new();
        m.map(0x1000, 3 * PAGE_SIZE as u64);
        let src: Vec<u8> = (0..(PAGE_SIZE + 100)).map(|i| (i * 7) as u8).collect();
        let base = 0x1000 + PAGE_SIZE as u64 - 50; // straddles two boundaries
        m.write_from(base, &src).unwrap();
        let mut out = vec![0u8; src.len()];
        m.read_into(base, &mut out).unwrap();
        assert_eq!(out, src);
        // spot-check against the scalar path
        assert_eq!(m.read_byte(base).unwrap(), src[0]);
        assert_eq!(m.read_byte(base + 100).unwrap(), src[100]);
    }

    #[test]
    fn bulk_read_faults_at_first_unmapped_byte() {
        let mut m = Memory::new();
        m.map(0x1000, PAGE_SIZE as u64); // second page unmapped
        let mut out = [0u8; 64];
        let base = 0x1000 + PAGE_SIZE as u64 - 16;
        let f = m.read_into(base, &mut out).unwrap_err();
        assert_eq!(f.addr, 0x2000, "fault at the first unmapped byte");
        assert!(!f.is_store);
        let f = m.write_from(base, &[0u8; 64]).unwrap_err();
        assert_eq!(f.addr, 0x2000);
        assert!(f.is_store);
    }

    #[test]
    fn epoch_tracks_structural_changes() {
        let mut m = Memory::new();
        let e0 = m.epoch();
        m.map(0x1000, 8);
        let e1 = m.epoch();
        assert_ne!(e0, e1, "mapping a new page must bump the epoch");
        m.map(0x1000, 8); // idempotent remap of an existing page
        assert_eq!(m.epoch(), e1, "no structural change, no bump");
        m.write_u64(0x1000, 3).unwrap();
        assert_eq!(m.epoch(), e1, "plain data writes do not bump");
        m.unmap_page(0x1000);
        assert_ne!(m.epoch(), e1, "unmap must bump");
        let c = m.clone();
        assert_ne!(c.epoch(), m.epoch(), "clones never share an epoch");
    }

    #[test]
    fn slot_handles_resolve_current_frames() {
        let mut m = Memory::new();
        m.map(0x7000, 8);
        m.write_u64(0x7000, 0x0102_0304_0506_0708).unwrap();
        let slot = m.slot_handle(0x7000).unwrap();
        assert_eq!(m.slot_frame(slot)[0], 0x08);
        m.slot_frame_mut(slot)[1] = 0xFF;
        assert_eq!(m.read(0x7001, 1).unwrap(), 0xFF);
        assert!(m.slot_handle(0x9000).is_none());
    }
}
