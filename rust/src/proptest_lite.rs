//! Minimal property-testing harness (the offline image has no proptest).
//!
//! `check(name, cases, |g| ...)` runs a property closure against `cases`
//! randomly generated inputs drawn through the [`Gen`] handle. On failure
//! it reports the failing case's seed so the case can be replayed exactly
//! (`SVE_PROP_SEED=<seed> cargo test <name>`), which substitutes for
//! proptest's shrinking: every case is independently reconstructible from
//! its seed.

use crate::rng::Rng;

/// Per-case generator handle.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Inclusive range.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add(self.rng.below((hi - lo + 1) as u64) as i64)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.usize_below(xs.len())]
    }

    /// Vector of `len` values built by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `prop` against `cases` generated cases. Panics (with the replay
/// seed) on the first failure. The base seed can be overridden with
/// `SVE_PROP_SEED` to replay a reported failure as case 0.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base = std::env::var("SVE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let seeds: Vec<u64> = match base {
        Some(s) => vec![s],
        None => {
            // derive per-case seeds from the property name, so adding
            // properties does not perturb existing ones
            let h = name.bytes().fold(0xcbf29ce484222325u64, |a, b| {
                (a ^ b as u64).wrapping_mul(0x100000001b3)
            });
            (0..cases as u64)
                .map(|i| h.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15)))
                .collect()
        }
    };
    for (i, &seed) in seeds.iter().enumerate() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {i}; replay with \
                 SVE_PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_inclusive() {
        check("ranges_are_inclusive", 200, |g| {
            let lo = g.i64_in(-50, 50);
            let hi = lo + g.i64_in(0, 100);
            let x = g.i64_in(lo, hi);
            assert!(x >= lo && x <= hi);
        });
    }

    #[test]
    fn vec_has_requested_length() {
        check("vec_has_requested_length", 50, |g| {
            let n = g.usize_in(0, 64);
            let v = g.vec(n, |g| g.u64());
            assert_eq!(v.len(), n);
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("failures_propagate", 10, |g| {
            assert!(g.u64_in(0, 10) > 10, "impossible");
        });
    }
}
