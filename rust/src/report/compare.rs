//! Cross-commit artifact diffing: `sve report --compare A.json B.json`.
//!
//! Parses two `fig8.json`, `dse.json` or `BENCH_hotpath.json` artifacts
//! (any mix — a fig8 document is treated as the `table2` variant, a
//! perf-hotpath document as the `hotpath` one), matches their
//! (variant, benchmark, VL, metric) points, and renders a delta table.
//! Metrics are `speedup` for figure artifacts (plus, for
//! `sve-repro/dse/v2` documents, the §PPA `perf_per_watt` /
//! `perf_per_mm2` values) and the simulator-throughput Minst/s values
//! for perf-hotpath artifacts — all "higher is better", so one
//! regression rule covers them. With a
//! `--fail-on-regress PCT` threshold the comparison **fails** when any
//! value in A drops by more than PCT percent in B, or when a point of A
//! is missing from B entirely — the primitive CI uses as a regression
//! wall. The rendering is a pure function of the two documents
//! (golden-tested in `tests/dse_compare_golden.rs`), and the exit-code
//! policy lives in `main.rs`: 0 clean, 1 failed comparison, 2 usage
//! error.

use crate::csvutil::{f, Table};
use crate::report::json::Json;
use crate::report::{dse, fig8};

/// One (variant, benchmark, VL, metric) value extracted from an
/// artifact. Every metric is oriented so that **higher is better**.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricPoint {
    /// `table2` for fig8 artifacts; the variant name for dse artifacts.
    pub variant: String,
    pub bench: String,
    pub vl_bits: u64,
    /// `speedup`, `perf_per_watt` or `perf_per_mm2`.
    pub metric: String,
    /// The value as recorded in the artifact.
    pub value: f64,
}

impl MetricPoint {
    fn key(&self) -> (&str, &str, u64, &str) {
        (&self.variant, &self.bench, self.vl_bits, &self.metric)
    }

    fn label(&self) -> String {
        let base = format!("{}/{}@vl{}", self.variant, self.bench, self.vl_bits);
        if self.metric == "speedup" {
            base
        } else {
            format!("{base}:{}", self.metric)
        }
    }
}

fn points_from_benchmarks(
    variant: &str,
    benches: Option<&Json>,
    out: &mut Vec<MetricPoint>,
) -> Result<(), String> {
    let arr = benches
        .and_then(Json::as_arr)
        .ok_or_else(|| "artifact has no \"benchmarks\" array".to_string())?;
    for b in arr {
        let bench = b
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| "benchmark entry has no \"bench\" name".to_string())?;
        let sve = b
            .get("sve")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("benchmark '{bench}' has no \"sve\" array"))?;
        for run in sve {
            let vl = run
                .get("vl_bits")
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("benchmark '{bench}': sve run has no \"vl_bits\""))?;
            let speedup = run
                .get("speedup")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("benchmark '{bench}': sve run has no \"speedup\""))?;
            out.push(MetricPoint {
                variant: variant.to_string(),
                bench: bench.to_string(),
                vl_bits: vl,
                metric: "speedup".to_string(),
                value: speedup,
            });
        }
    }
    Ok(())
}

/// Extract the §PPA points of one v2 dse variant: `perf_per_watt` and
/// `perf_per_mm2` per (benchmark, VL), from the `energy_pj` section.
fn ppa_points_from_variant(
    variant: &str,
    energy: Option<&Json>,
    out: &mut Vec<MetricPoint>,
) -> Result<(), String> {
    let arr = energy
        .and_then(Json::as_arr)
        .ok_or_else(|| "dse variant has no \"energy_pj\" array".to_string())?;
    for b in arr {
        let bench = b
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| "energy_pj entry has no \"bench\" name".to_string())?;
        let sve = b
            .get("sve")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("energy_pj '{bench}' has no \"sve\" array"))?;
        for run in sve {
            let vl = run
                .get("vl_bits")
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("energy_pj '{bench}': run has no \"vl_bits\""))?;
            for metric in ["perf_per_watt", "perf_per_mm2"] {
                let value = run.get(metric).and_then(Json::as_f64).ok_or_else(|| {
                    format!("energy_pj '{bench}': run has no \"{metric}\"")
                })?;
                out.push(MetricPoint {
                    variant: variant.to_string(),
                    bench: bench.to_string(),
                    vl_bits: vl,
                    metric: metric.to_string(),
                    value,
                });
            }
        }
    }
    Ok(())
}

/// Schema tag of `BENCH_hotpath.json` (written by
/// `cargo bench --bench perf_hotpath`).
pub const HOTPATH_SCHEMA: &str = "sve-repro/perf-hotpath/v1";

/// Extract the simulator-throughput points of a perf-hotpath document:
/// per kernel, the functional and func+timing Minst/s values under the
/// pseudo-variant `hotpath` (higher is better, like every figure
/// metric, so the same `--fail-on-regress` contract applies).
fn points_from_hotpath(doc: &Json, out: &mut Vec<MetricPoint>) -> Result<(), String> {
    let vl = doc
        .get("vl_bits")
        .and_then(Json::as_u64)
        .ok_or_else(|| "perf-hotpath artifact has no \"vl_bits\"".to_string())?;
    let kernels = doc
        .get("kernels")
        .ok_or_else(|| "perf-hotpath artifact has no \"kernels\" object".to_string())?;
    let Json::Obj(entries) = kernels else {
        return Err("perf-hotpath \"kernels\" is not an object".to_string());
    };
    for (name, k) in entries {
        for metric in ["functional_minst_s", "func_timing_minst_s"] {
            let value = k
                .get(metric)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("perf-hotpath kernel '{name}' has no \"{metric}\""))?;
            out.push(MetricPoint {
                variant: "hotpath".to_string(),
                bench: name.clone(),
                vl_bits: vl,
                metric: metric.to_string(),
                value,
            });
        }
    }
    Ok(())
}

/// Extract every comparable point from a parsed `fig8.json`, `dse.json`
/// or `BENCH_hotpath.json` document, in document order: per variant,
/// the speedup points first, then (dse/v2 only) the §PPA points; for
/// perf-hotpath documents, the per-kernel throughput points.
pub fn extract_points(doc: &Json) -> Result<Vec<MetricPoint>, String> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| "artifact has no \"schema\" field".to_string())?;
    let mut points = Vec::new();
    match schema {
        fig8::FIG8_SCHEMA => {
            points_from_benchmarks("table2", doc.get("benchmarks"), &mut points)?;
        }
        dse::DSE_SCHEMA | dse::DSE_SCHEMA_V1 => {
            let variants = doc
                .get("variants")
                .and_then(Json::as_arr)
                .ok_or_else(|| "dse artifact has no \"variants\" array".to_string())?;
            for v in variants {
                let name = v
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "dse variant has no \"name\"".to_string())?;
                points_from_benchmarks(name, v.get("benchmarks"), &mut points)?;
                if schema == dse::DSE_SCHEMA {
                    ppa_points_from_variant(name, v.get("energy_pj"), &mut points)?;
                }
            }
        }
        HOTPATH_SCHEMA => points_from_hotpath(doc, &mut points)?,
        other => {
            return Err(format!(
                "unsupported artifact schema '{other}' (expected {}, {}, {} or {})",
                fig8::FIG8_SCHEMA,
                dse::DSE_SCHEMA,
                dse::DSE_SCHEMA_V1,
                HOTPATH_SCHEMA
            ))
        }
    }
    Ok(points)
}

/// The outcome of diffing two artifacts' points.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// Per-matched-point delta rows, in A's order.
    pub table: Table,
    /// Points present in both artifacts.
    pub compared: usize,
    /// Formatted descriptions of every value drop beyond the threshold.
    pub regressions: Vec<String>,
    /// Labels of points only in A — a silently dropped configuration,
    /// counted as a failure when a threshold is set.
    pub only_in_a: Vec<String>,
    /// Labels of points only in B (new configurations; never a failure).
    pub only_in_b: Vec<String>,
    /// The `--fail-on-regress` threshold the comparison ran under.
    pub fail_below_pct: Option<f64>,
}

impl Comparison {
    /// Does this comparison fail the regression wall? Only a set
    /// threshold can fail; without one the comparison is informational.
    pub fn failed(&self) -> bool {
        self.fail_below_pct.is_some()
            && (!self.regressions.is_empty() || !self.only_in_a.is_empty())
    }
}

/// Match A's points against B's and compute per-point deltas. A point
/// regresses when its B value drops below `a * (1 - pct/100)` — the
/// same contract for speedups and the §PPA metrics, since every metric
/// is higher-is-better.
pub fn compare(a: &[MetricPoint], b: &[MetricPoint], fail_below_pct: Option<f64>) -> Comparison {
    let with_variant = a.iter().chain(b.iter()).any(|p| p.variant != "table2");
    let with_metric = a.iter().chain(b.iter()).any(|p| p.metric != "speedup");
    let mut header = Vec::new();
    if with_variant {
        header.push("variant".to_string());
    }
    header.extend(["bench", "vl_bits"].map(String::from));
    if with_metric {
        header.push("metric".to_string());
    }
    header.extend(["value_a", "value_b", "delta_%", "status"].map(String::from));
    let mut table = Table::new(header);
    let mut compared = 0usize;
    let mut regressions = Vec::new();
    let mut only_in_a = Vec::new();
    for pa in a {
        let Some(pb) = b.iter().find(|p| p.key() == pa.key()) else {
            only_in_a.push(pa.label());
            continue;
        };
        compared += 1;
        let delta_pct = (pb.value / pa.value - 1.0) * 100.0;
        let regressed =
            fail_below_pct.is_some_and(|pct| pb.value < pa.value * (1.0 - pct / 100.0));
        if regressed {
            regressions.push(format!(
                "{}: {} -> {} ({:+.2}%)",
                pa.label(),
                f(pa.value, 3),
                f(pb.value, 3),
                delta_pct
            ));
        }
        let mut cells = Vec::new();
        if with_variant {
            cells.push(pa.variant.clone());
        }
        cells.extend([pa.bench.clone(), pa.vl_bits.to_string()]);
        if with_metric {
            cells.push(pa.metric.clone());
        }
        cells.extend([
            f(pa.value, 3),
            f(pb.value, 3),
            format!("{delta_pct:+.2}"),
            if regressed { "REGRESS".to_string() } else { "ok".to_string() },
        ]);
        table.push_row(cells);
    }
    let only_in_b = b
        .iter()
        .filter(|pb| !a.iter().any(|pa| pa.key() == pb.key()))
        .map(MetricPoint::label)
        .collect();
    Comparison { table, compared, regressions, only_in_a, only_in_b, fail_below_pct }
}

/// Render the full comparison report: delta table, regression lines,
/// mismatched-point notes, one-line summary.
pub fn render(c: &Comparison) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    out.push_str(&c.table.to_markdown());
    for r in &c.regressions {
        let _ = writeln!(out, "regression: {r}");
    }
    for l in &c.only_in_a {
        let _ = writeln!(out, "only in A (missing from B): {l}");
    }
    for l in &c.only_in_b {
        let _ = writeln!(out, "only in B (new): {l}");
    }
    match c.fail_below_pct {
        Some(pct) => {
            let failures = c.regressions.len() + c.only_in_a.len();
            let _ = writeln!(
                out,
                "compared {} point(s) against a {pct}% regression threshold: \
                 {failures} failure(s)",
                c.compared
            );
        }
        None => {
            let _ = writeln!(
                out,
                "compared {} point(s); no regression threshold set",
                c.compared
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Fig8Row, Isa, RunRecord};
    use crate::uarch::PpaCounters;
    use crate::workloads::Group;

    fn point(variant: &str, bench: &str, vl: u64, value: f64) -> MetricPoint {
        MetricPoint {
            variant: variant.into(),
            bench: bench.into(),
            vl_bits: vl,
            metric: "speedup".into(),
            value,
        }
    }

    fn fig8_doc() -> Json {
        let neon = RunRecord {
            bench: "stream_triad",
            group: Group::Right,
            isa: Isa::Neon,
            cycles: 1000,
            insts: 10000,
            vector_fraction: 0.5,
            vectorized: true,
            l1d_miss_rate: 0.125,
            ipc: 1.5,
            counters: PpaCounters::default(),
        };
        let sve = vec![
            RunRecord { isa: Isa::Sve(128), cycles: 800, ..neon.clone() },
            RunRecord { isa: Isa::Sve(256), cycles: 400, ..neon.clone() },
        ];
        let rows = vec![Fig8Row {
            bench: "stream_triad",
            group: Group::Right,
            neon,
            sve,
            extra_vectorization: 0.25,
        }];
        fig8::to_json(&rows, &[128, 256])
    }

    #[test]
    fn extracts_fig8_points_as_table2() {
        let pts = extract_points(&fig8_doc()).unwrap();
        assert_eq!(
            pts,
            vec![
                point("table2", "stream_triad", 128, 1.25),
                point("table2", "stream_triad", 256, 2.5),
            ]
        );
    }

    #[test]
    fn extracts_ppa_points_from_v2_dse_docs() {
        use crate::coordinator::VariantRows;
        use crate::uarch::base_variant;
        let neon = RunRecord {
            bench: "stream_triad",
            group: Group::Right,
            isa: Isa::Neon,
            cycles: 1000,
            insts: 10000,
            vector_fraction: 0.5,
            vectorized: true,
            l1d_miss_rate: 0.125,
            ipc: 1.5,
            counters: PpaCounters {
                l1d_accesses: 2000,
                l2_accesses: 250,
                mem_accesses: 60,
                mispredicts: 10,
                ..Default::default()
            },
        };
        let sve = vec![RunRecord { isa: Isa::Sve(128), cycles: 800, ..neon.clone() }];
        let variants = vec![VariantRows {
            name: "table2".into(),
            uarch: base_variant("table2").unwrap(),
            rows: vec![Fig8Row {
                bench: "stream_triad",
                group: Group::Right,
                neon,
                sve,
                extra_vectorization: 0.25,
            }],
        }];
        let doc = dse::to_json(&variants, &[128]);
        let pts = extract_points(&doc).unwrap();
        // 1 speedup + perf_per_watt + perf_per_mm2
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].metric, "speedup");
        assert_eq!(pts[1].metric, "perf_per_watt");
        assert_eq!(pts[2].metric, "perf_per_mm2");
        assert!(pts[1].value > 0.0 && pts[2].value > 0.0);
        assert_eq!(pts[1].label(), "table2/stream_triad@vl128:perf_per_watt");
        // a PPA regression fails under the same contract as a speedup one
        let mut b = pts.clone();
        b[1].value *= 0.5;
        let c = compare(&pts, &b, Some(2.0));
        assert!(c.failed());
        assert!(render(&c).contains("perf_per_watt"));
        // the metric column appears because non-speedup points exist
        assert!(c.table.header.contains(&"metric".to_string()));
    }

    #[test]
    fn extracts_hotpath_points_and_applies_the_regression_contract() {
        let doc = |triad: f64, hacc: f64| {
            Json::parse(&format!(
                r#"{{
  "schema": "sve-repro/perf-hotpath/v1",
  "vl_bits": 256,
  "smoke": true,
  "kernels": {{
    "stream_triad": {{ "insts": 120000, "functional_minst_s": {triad},
                       "func_timing_minst_s": 21.5 }},
    "haccmk": {{ "insts": 90000, "functional_minst_s": {hacc},
                 "func_timing_minst_s": 14.25 }}
  }}
}}"#
            ))
            .unwrap()
        };
        let a = extract_points(&doc(80.0, 60.0)).unwrap();
        assert_eq!(a.len(), 4, "2 kernels x 2 throughput metrics");
        assert_eq!(a[0].variant, "hotpath");
        assert_eq!(a[0].bench, "stream_triad");
        assert_eq!(a[0].metric, "functional_minst_s");
        assert_eq!(a[0].value, 80.0);
        assert_eq!(a[0].label(), "hotpath/stream_triad@vl256:functional_minst_s");
        // identical docs pass; a big functional-throughput drop fails
        assert!(!compare(&a, &a, Some(5.0)).failed());
        let b = extract_points(&doc(40.0, 60.0)).unwrap();
        let c = compare(&a, &b, Some(5.0));
        assert!(c.failed());
        assert_eq!(c.regressions.len(), 1);
        assert!(render(&c).contains("functional_minst_s"));
        // a malformed kernel entry is an error, not a silent skip
        let bad = Json::parse(
            r#"{ "schema": "sve-repro/perf-hotpath/v1", "vl_bits": 256,
                 "kernels": { "x": { "insts": 1 } } }"#,
        )
        .unwrap();
        assert!(extract_points(&bad).unwrap_err().contains("functional_minst_s"));
    }

    #[test]
    fn rejects_unknown_schema_and_malformed_docs() {
        let bad = Json::Obj(vec![("schema".into(), Json::str("sve-repro/fig2/v1"))]);
        assert!(extract_points(&bad).unwrap_err().contains("unsupported artifact schema"));
        assert!(extract_points(&Json::Obj(vec![])).is_err());
        let no_benches =
            Json::Obj(vec![("schema".into(), Json::str(fig8::FIG8_SCHEMA))]);
        assert!(extract_points(&no_benches).is_err());
    }

    #[test]
    fn v1_dse_docs_compare_by_speedup_only() {
        // a hand-built v1 document (no energy_pj section) still parses
        let doc = Json::parse(
            r#"{
  "schema": "sve-repro/dse/v1",
  "variants": [
    {
      "name": "table2",
      "benchmarks": [
        { "bench": "haccmk", "sve": [ { "vl_bits": 256, "speedup": 2.0 } ] }
      ]
    }
  ]
}"#,
        )
        .unwrap();
        let pts = extract_points(&doc).unwrap();
        assert_eq!(pts, vec![point("table2", "haccmk", 256, 2.0)]);
    }

    #[test]
    fn identical_points_never_fail() {
        let a = vec![point("table2", "haccmk", 256, 2.0)];
        let c = compare(&a, &a, Some(0.0));
        assert_eq!(c.compared, 1);
        assert!(!c.failed());
        assert!(render(&c).contains("1 point(s) against a 0% regression threshold"));
    }

    #[test]
    fn regression_beyond_threshold_fails_and_within_does_not() {
        let a = vec![point("table2", "haccmk", 256, 2.0)];
        let slight = vec![point("table2", "haccmk", 256, 1.98)]; // -1%
        let bad = vec![point("table2", "haccmk", 256, 1.5)]; // -25%
        assert!(!compare(&a, &slight, Some(2.0)).failed());
        let c = compare(&a, &bad, Some(2.0));
        assert!(c.failed());
        assert_eq!(c.regressions.len(), 1);
        assert!(render(&c).contains("REGRESS"));
        assert!(render(&c).contains("-25.00"));
        // without a threshold the same delta is informational
        assert!(!compare(&a, &bad, None).failed());
    }

    #[test]
    fn missing_points_fail_only_under_a_threshold() {
        let a = vec![point("table2", "haccmk", 256, 2.0), point("table2", "haccmk", 512, 3.0)];
        let b = vec![point("table2", "haccmk", 256, 2.0), point("big-core", "haccmk", 256, 4.0)];
        let c = compare(&a, &b, Some(2.0));
        assert_eq!(c.compared, 1);
        assert_eq!(c.only_in_a, vec!["table2/haccmk@vl512"]);
        assert_eq!(c.only_in_b, vec!["big-core/haccmk@vl256"]);
        assert!(c.failed());
        assert!(!compare(&a, &b, None).failed());
        // the variant column appears because a non-table2 point exists
        assert_eq!(c.table.header[0], "variant");
        // all-speedup comparisons do not grow a metric column
        assert!(!c.table.header.contains(&"metric".to_string()));
    }
}
