//! Design-space exploration artifacts: the `sve dse` sweep rendered as
//! machine-readable JSON (schema [`DSE_SCHEMA`]) + long-form CSV and
//! human-readable Markdown with a cross-variant pivot, a §PPA
//! area/energy layer ([`crate::uarch::ppa`]) and a Pareto-frontier
//! ranking of design points. Like the Fig. 8 emitters, every rendering
//! is a pure function of the row data — no timestamps, no environment —
//! so the artifacts are byte-stable and golden-tested
//! (`tests/dse_compare_golden.rs`).
//!
//! The per-variant benchmark payload is exactly the Fig. 8 shape
//! ([`crate::report::fig8::benchmarks_json`]), which is what lets
//! `sve report --compare` diff `fig8.json` and `dse.json` artifacts
//! interchangeably. On top of that, v2 adds per-variant `area_proxy`
//! and `energy_pj` sections (whose perf/W and perf/mm² values are also
//! compared, under the same `--fail-on-regress` contract) and a
//! top-level `pareto` ranking.

use std::io;
use std::path::{Path, PathBuf};

use crate::coordinator::{RunRecord, VariantRows};
use crate::csvutil::{f, Table};
use crate::report::fig8;
use crate::report::json::Json;
use crate::uarch::{ppa, UarchConfig};

/// Schema tag of the `dse.json` artifact. v2 added the §PPA layer
/// (`area_proxy`, `energy_pj`, `pareto`); v1 artifacts are still
/// accepted by `sve report --compare` ([`DSE_SCHEMA_V1`]).
pub const DSE_SCHEMA: &str = "sve-repro/dse/v2";

/// The pre-PPA schema tag, kept so `--compare` can still diff
/// artifacts produced before the v2 migration.
pub const DSE_SCHEMA_V1: &str = "sve-repro/dse/v1";

/// Every [`UarchConfig`] field as a flat JSON object, in declaration
/// order — the artifact records the exact design point it was timed
/// under, so two artifacts are comparable without access to the CLI
/// invocation that produced them. Built from the single field
/// enumeration in `uarch::config` ([`crate::uarch::OVERRIDE_KEYS`] +
/// [`crate::uarch::field_value`]), so a new config field automatically
/// appears here.
pub fn uarch_json(c: &UarchConfig) -> Json {
    Json::Obj(
        crate::uarch::OVERRIDE_KEYS
            .iter()
            .map(|&key| {
                let v = crate::uarch::field_value(c, key)
                    .expect("every OVERRIDE_KEYS entry is readable");
                (key.to_string(), Json::u64(v))
            })
            .collect(),
    )
}

/// One-line human summary of a design point, used as the section
/// subtitle in `dse.md`.
pub fn uarch_summary(c: &UarchConfig) -> String {
    format!(
        "L1D {}K/{}-way · L2 {}K/{}-way · decode/retire {}/{} · ROB {} · \
         issue {}i+{}v · {} ld / {} st per cycle",
        c.l1d_bytes / 1024,
        c.l1d_assoc,
        c.l2_bytes / 1024,
        c.l2_assoc,
        c.decode_width,
        c.retire_width,
        c.rob,
        c.int_issue_per_cycle,
        c.vec_issue_per_cycle,
        c.loads_per_cycle,
        c.stores_per_cycle
    )
}

/// Total §PPA energy proxy of one run under its variant's
/// configuration (pJ) — the glue between [`RunRecord`] (which carries
/// the raw counters) and [`ppa::energy_pj`].
pub fn run_energy_pj(r: &RunRecord, cfg: &UarchConfig) -> f64 {
    ppa::energy_pj(cfg, r.isa.vl(), r.insts, r.cycles, &r.counters).total_pj
}

/// The `area_proxy` object of one variant: the VL-independent core
/// area plus the per-VL vector datapath and totals.
pub fn area_json(cfg: &UarchConfig, vls: &[usize]) -> Json {
    let core = ppa::area_um2(cfg, 128).core_um2;
    Json::Obj(vec![
        ("core_um2".into(), Json::f64(core)),
        (
            "per_vl".into(),
            Json::Arr(
                vls.iter()
                    .map(|&vl| {
                        let a = ppa::area_um2(cfg, vl);
                        Json::Obj(vec![
                            ("vl_bits".into(), Json::u64(vl as u64)),
                            ("vector_um2".into(), Json::f64(a.vector_um2)),
                            ("total_um2".into(), Json::f64(a.total_um2)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The `energy_pj` array of one variant: per benchmark, the NEON
/// baseline energy and the per-VL SVE energies with the derived
/// perf/W (runs per joule) and perf/mm² (runs per second per mm² at a
/// nominal 1 GHz) metrics `--compare` diffs.
pub fn energy_json(v: &VariantRows, vls: &[usize]) -> Json {
    Json::Arr(
        v.rows
            .iter()
            .map(|r| {
                Json::Obj(vec![
                    ("bench".into(), Json::str(r.bench)),
                    ("neon_pj".into(), Json::f64(run_energy_pj(&r.neon, &v.uarch))),
                    (
                        "sve".into(),
                        Json::Arr(
                            vls.iter()
                                .enumerate()
                                .map(|(i, &vl)| {
                                    let e = run_energy_pj(&r.sve[i], &v.uarch);
                                    let a = ppa::area_um2(&v.uarch, vl);
                                    Json::Obj(vec![
                                        ("vl_bits".into(), Json::u64(vl as u64)),
                                        ("energy_pj".into(), Json::f64(e)),
                                        (
                                            "perf_per_watt".into(),
                                            Json::f64(ppa::perf_per_watt(e)),
                                        ),
                                        (
                                            "perf_per_mm2".into(),
                                            Json::f64(ppa::perf_per_mm2(
                                                r.sve[i].cycles,
                                                a.total_um2,
                                            )),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// One (variant, VL) design point in the Pareto ranking.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    /// The variant's display name.
    pub variant: String,
    /// The SVE vector length of this point.
    pub vl_bits: usize,
    /// Across-benchmark arithmetic mean of SVE speedup over NEON.
    pub mean_speedup: f64,
    /// Total §PPA energy of the SVE runs across benchmarks (pJ).
    pub energy_pj: f64,
    /// Area proxy of the variant at this VL (µm²).
    pub area_um2: f64,
    /// On the Pareto frontier: no other point is at least as good on
    /// all three axes and strictly better on one.
    pub frontier: bool,
    /// `variant@vlN` label of a point that dominates this one.
    pub dominated_by: Option<String>,
}

/// Rank every (variant, VL) design point on the
/// (mean speedup ↑, energy ↓, area ↓) axes: mark dominated points and
/// sort frontier-first, then by mean speedup descending (matrix order
/// breaks exact ties, so the ranking is fully deterministic).
pub fn pareto(variants: &[VariantRows], vls: &[usize]) -> Vec<ParetoPoint> {
    let mut pts: Vec<ParetoPoint> = Vec::new();
    for v in variants {
        for (vi, &vl) in vls.iter().enumerate() {
            let mut sp = 0.0;
            let mut e = 0.0;
            for r in &v.rows {
                sp += r.speedup(vi);
                e += run_energy_pj(&r.sve[vi], &v.uarch);
            }
            let mean_speedup = if v.rows.is_empty() { 0.0 } else { sp / v.rows.len() as f64 };
            pts.push(ParetoPoint {
                variant: v.name.clone(),
                vl_bits: vl,
                mean_speedup,
                energy_pj: e,
                area_um2: ppa::area_um2(&v.uarch, vl).total_um2,
                frontier: true,
                dominated_by: None,
            });
        }
    }
    // mark dominated points (the first dominator in matrix order is
    // recorded; domination chains all terminate on the frontier)
    let dominated: Vec<Option<String>> = pts
        .iter()
        .map(|p| {
            pts.iter()
                .find(|q| {
                    q.mean_speedup >= p.mean_speedup
                        && q.energy_pj <= p.energy_pj
                        && q.area_um2 <= p.area_um2
                        && (q.mean_speedup > p.mean_speedup
                            || q.energy_pj < p.energy_pj
                            || q.area_um2 < p.area_um2)
                })
                .map(|q| format!("{}@vl{}", q.variant, q.vl_bits))
        })
        .collect();
    for (p, dom) in pts.iter_mut().zip(dominated) {
        if let Some(label) = dom {
            p.frontier = false;
            p.dominated_by = Some(label);
        }
    }
    let mut order: Vec<usize> = (0..pts.len()).collect();
    order.sort_by(|&a, &b| {
        pts[b]
            .frontier
            .cmp(&pts[a].frontier)
            .then(pts[b].mean_speedup.total_cmp(&pts[a].mean_speedup))
            .then(a.cmp(&b))
    });
    order.into_iter().map(|i| pts[i].clone()).collect()
}

/// The Pareto ranking as a table (for `dse.md` and the CLI).
pub fn pareto_table(pts: &[ParetoPoint]) -> Table {
    let mut t = Table::new(vec![
        "rank",
        "variant",
        "vl_bits",
        "mean_speedup",
        "energy_pj",
        "area_mm2",
        "pareto",
        "dominated_by",
    ]);
    for (i, p) in pts.iter().enumerate() {
        t.push_row(vec![
            (i + 1).to_string(),
            p.variant.clone(),
            p.vl_bits.to_string(),
            f(p.mean_speedup, 2),
            f(p.energy_pj, 1),
            f(p.area_um2 / 1.0e6, 3),
            if p.frontier { "frontier".to_string() } else { "dominated".to_string() },
            p.dominated_by.clone().unwrap_or_else(|| "-".to_string()),
        ]);
    }
    t
}

/// The `pareto` section of `dse.json`.
pub fn pareto_json(pts: &[ParetoPoint]) -> Json {
    Json::Arr(
        pts.iter()
            .map(|p| {
                Json::Obj(vec![
                    ("variant".into(), Json::str(p.variant.clone())),
                    ("vl_bits".into(), Json::u64(p.vl_bits as u64)),
                    ("mean_speedup".into(), Json::f64(p.mean_speedup)),
                    ("energy_pj".into(), Json::f64(p.energy_pj)),
                    ("area_um2".into(), Json::f64(p.area_um2)),
                    ("frontier".into(), Json::Bool(p.frontier)),
                    (
                        "dominated_by".into(),
                        match &p.dominated_by {
                            Some(l) => Json::str(l.clone()),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect(),
    )
}

/// The `sve dse --pareto-only` view: frontier design points only.
/// Returns the variants that own at least one frontier point (in the
/// original variant order) and the frontier points themselves (in
/// ranking order). Because domination is transitive and every dominator
/// chain terminates on the frontier, re-ranking the kept variants can
/// never resurrect a dominated point — filtering is stable.
pub fn frontier_only(
    variants: &[VariantRows],
    vls: &[usize],
) -> (Vec<VariantRows>, Vec<ParetoPoint>) {
    let mut pts = pareto(variants, vls);
    pts.retain(|p| p.frontier);
    let kept = variants
        .iter()
        .filter(|v| pts.iter().any(|p| p.variant == v.name))
        .cloned()
        .collect();
    (kept, pts)
}

/// The long-form CSV restricted to frontier (variant, VL) rows.
pub fn frontier_table(variants: &[VariantRows], vls: &[usize], pts: &[ParetoPoint]) -> Table {
    let mut t = table(variants, vls);
    t.rows.retain(|r| pts.iter().any(|p| p.variant == r[0] && p.vl_bits.to_string() == r[4]));
    t
}

/// The cross-variant pivot: one row per (benchmark, VL); per variant a
/// speedup column, a perf/W column (runs per joule) and a perf/mm²
/// column (runs per second per mm² at a nominal 1 GHz) — the paper's
/// PPA question ("which design point suits my targets?") on a single
/// screen.
pub fn pivot(variants: &[VariantRows], vls: &[usize]) -> Table {
    let mut header = vec!["bench".to_string(), "vl_bits".to_string()];
    for v in variants {
        header.push(v.name.clone());
    }
    for v in variants {
        header.push(format!("{} perf/W", v.name));
    }
    for v in variants {
        header.push(format!("{} perf/mm2", v.name));
    }
    let mut t = Table::new(header);
    let Some(first) = variants.first() else { return t };
    for (bi, row0) in first.rows.iter().enumerate() {
        for (vi, vl) in vls.iter().enumerate() {
            let mut cells = vec![row0.bench.to_string(), vl.to_string()];
            for v in variants {
                cells.push(f(v.rows[bi].speedup(vi), 2));
            }
            for v in variants {
                let e = run_energy_pj(&v.rows[bi].sve[vi], &v.uarch);
                cells.push(f(ppa::perf_per_watt(e), 1));
            }
            for v in variants {
                let a = ppa::area_um2(&v.uarch, *vl);
                cells.push(f(ppa::perf_per_mm2(v.rows[bi].sve[vi].cycles, a.total_um2), 1));
            }
            t.push_row(cells);
        }
    }
    t
}

/// The long-form table behind `dse.csv`: one row per
/// (variant, benchmark, VL) — the shape plotting tools want — with the
/// §PPA columns alongside the timing ones.
pub fn table(variants: &[VariantRows], vls: &[usize]) -> Table {
    let mut t = Table::new(vec![
        "variant",
        "bench",
        "group",
        "extra_vec_%",
        "vl_bits",
        "speedup",
        "neon_cycles",
        "sve_cycles",
        "energy_pj",
        "perf_per_watt",
        "perf_per_mm2",
        "area_um2",
    ]);
    for v in variants {
        for r in &v.rows {
            for (vi, vl) in vls.iter().enumerate() {
                let e = run_energy_pj(&r.sve[vi], &v.uarch);
                let a = ppa::area_um2(&v.uarch, *vl);
                t.push_row(vec![
                    v.name.clone(),
                    r.bench.to_string(),
                    r.group.short().to_string(),
                    f(100.0 * r.extra_vectorization, 1),
                    vl.to_string(),
                    f(r.speedup(vi), 2),
                    r.neon.cycles.to_string(),
                    r.sve[vi].cycles.to_string(),
                    f(e, 1),
                    f(ppa::perf_per_watt(e), 1),
                    f(ppa::perf_per_mm2(r.sve[vi].cycles, a.total_um2), 1),
                    f(a.total_um2, 0),
                ]);
            }
        }
    }
    t
}

/// The machine-readable DSE document: per variant, the exact design
/// point ([`uarch_json`]), the §PPA area/energy proxies and the
/// Fig. 8-shaped benchmark payload; at the top level, the Pareto
/// ranking of every (variant, VL) design point.
pub fn to_json(variants: &[VariantRows], vls: &[usize]) -> Json {
    to_json_with(variants, vls, &pareto(variants, vls))
}

/// [`to_json`] with an explicit `pareto` section — what `--pareto-only`
/// uses to emit a frontier-only ranking over the kept variants.
pub fn to_json_with(variants: &[VariantRows], vls: &[usize], pts: &[ParetoPoint]) -> Json {
    Json::Obj(vec![
        ("schema".into(), Json::str(DSE_SCHEMA)),
        ("figure".into(), Json::str("dse")),
        (
            "title".into(),
            Json::str("SVE speedup over Advanced SIMD across microarchitecture design points"),
        ),
        ("vls_bits".into(), Json::Arr(vls.iter().map(|&v| Json::u64(v as u64)).collect())),
        (
            "variants".into(),
            Json::Arr(
                variants
                    .iter()
                    .map(|v| {
                        Json::Obj(vec![
                            ("name".into(), Json::str(v.name.clone())),
                            ("uarch".into(), uarch_json(&v.uarch)),
                            ("area_proxy".into(), area_json(&v.uarch, vls)),
                            ("energy_pj".into(), energy_json(v, vls)),
                            ("benchmarks".into(), fig8::benchmarks_json(&v.rows)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("pareto".into(), pareto_json(pts)),
    ])
}

/// The human-readable Markdown artifact (`dse.md`).
pub fn to_markdown(variants: &[VariantRows], vls: &[usize]) -> String {
    to_markdown_with(variants, vls, &pareto(variants, vls))
}

/// [`to_markdown`] with an explicit Pareto ranking (see [`to_json_with`]).
pub fn to_markdown_with(variants: &[VariantRows], vls: &[usize], pts: &[ParetoPoint]) -> String {
    use std::fmt::Write as _;
    let vl_list = vls.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ");
    let mut out = String::new();
    let _ = write!(
        out,
        "# DSE — SVE speedup across µarch design points\n\
         \n\
         Schema: `{DSE_SCHEMA}` · SVE vector lengths: {vl_list} bits · \
         {nv} variants × {nb} benchmarks, every run validated against its \
         golden outputs.\n\
         \n\
         Each variant section is the Fig. 8 table timed under that design \
         point; the pivot puts every variant's speedup, perf/W (runs per \
         joule) and perf/mm² (runs per second per mm² at a nominal 1 GHz) \
         side by side, and the Pareto table ranks every (variant, VL) \
         design point on the (performance, energy, area) axes — the §PPA \
         proxy formulas are documented in EXPERIMENTS.md §PPA.\n\
         \n",
        nv = variants.len(),
        nb = variants.first().map_or(0, |v| v.rows.len()),
    );
    for v in variants {
        let _ = write!(
            out,
            "## {}\n\n{}\n\n{}\n",
            v.name,
            uarch_summary(&v.uarch),
            fig8::table(&v.rows, vls).to_markdown(),
        );
    }
    let _ = write!(
        out,
        "## Cross-variant pivot — speedup, perf/W, perf/mm² over NEON\n\n{}\n",
        pivot(variants, vls).to_markdown(),
    );
    let _ = write!(
        out,
        "## Pareto frontier — performance vs energy vs area\n\n\
         `mean_speedup` averages SVE speedup over NEON across benchmarks; \
         `energy_pj` sums the energy proxy over the SVE runs; `area_mm2` \
         is the area proxy at that VL. `frontier` marks non-dominated \
         points: no other design point is at least as good on all three \
         axes and strictly better on one.\n\n{}\n\
         Regenerate with `sve dse --uarch <variants> --out <dir>` (add \
         `--resume` to reuse cached jobs); machine-readable copies: \
         `dse.json`, `dse.csv`.\n",
        pareto_table(pts).to_markdown(),
    );
    out
}

/// Write `dse.json`, `dse.csv` and `dse.md` under `out_dir`, returning
/// the paths written.
pub fn write_artifacts(
    variants: &[VariantRows],
    vls: &[usize],
    out_dir: impl AsRef<Path>,
) -> io::Result<Vec<PathBuf>> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join("dse.json");
    std::fs::write(&json_path, to_json(variants, vls).render_pretty())?;
    let csv_path = dir.join("dse.csv");
    std::fs::write(&csv_path, table(variants, vls).to_csv())?;
    let md_path = dir.join("dse.md");
    std::fs::write(&md_path, to_markdown(variants, vls))?;
    Ok(vec![json_path, csv_path, md_path])
}

/// [`write_artifacts`] for `sve dse --pareto-only`: every section is
/// filtered to frontier design points — dominated variants disappear
/// from the `variants` payload, the `pareto` ranking lists frontier
/// points only, and `dse.csv` keeps only rows whose (variant, VL) pair
/// is on the frontier.
pub fn write_artifacts_pareto_only(
    variants: &[VariantRows],
    vls: &[usize],
    out_dir: impl AsRef<Path>,
) -> io::Result<Vec<PathBuf>> {
    let (kept, pts) = frontier_only(variants, vls);
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join("dse.json");
    std::fs::write(&json_path, to_json_with(&kept, vls, &pts).render_pretty())?;
    let csv_path = dir.join("dse.csv");
    std::fs::write(&csv_path, frontier_table(&kept, vls, &pts).to_csv())?;
    let md_path = dir.join("dse.md");
    std::fs::write(&md_path, to_markdown_with(&kept, vls, &pts))?;
    Ok(vec![json_path, csv_path, md_path])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Fig8Row, Isa, RunRecord};
    use crate::uarch::{base_variant, PpaCounters};
    use crate::workloads::Group;

    fn rec(bench: &'static str, isa: Isa, cycles: u64) -> RunRecord {
        let mut class_counts = [0u64; crate::isa::NUM_UOP_CLASSES];
        for (i, slot) in class_counts.iter_mut().enumerate() {
            *slot = 10 * cycles / (i as u64 + 2);
        }
        RunRecord {
            bench,
            group: Group::Right,
            isa,
            cycles,
            insts: 10 * cycles,
            vector_fraction: 0.5,
            vectorized: true,
            l1d_miss_rate: 0.125,
            ipc: 1.5,
            counters: PpaCounters {
                l1d_accesses: 2 * cycles,
                l2_accesses: cycles / 4,
                mem_accesses: cycles / 16,
                mispredicts: cycles / 100,
                cracked_elems: 0,
                pf_issued: cycles / 2,
                pf_useful: cycles / 3,
                dram_channel_cycles: cycles,
                class_counts,
            },
        }
    }

    fn variant(name: &str, base: &str, neon_cycles: u64) -> VariantRows {
        let sve = vec![
            rec("stream_triad", Isa::Sve(128), neon_cycles * 4 / 5),
            rec("stream_triad", Isa::Sve(256), neon_cycles * 2 / 5),
        ];
        VariantRows {
            name: name.into(),
            uarch: base_variant(base).unwrap(),
            rows: vec![Fig8Row {
                bench: "stream_triad",
                group: Group::Right,
                neon: rec("stream_triad", Isa::Neon, neon_cycles),
                sve,
                extra_vectorization: 0.25,
            }],
        }
    }

    fn fixture() -> Vec<VariantRows> {
        vec![variant("table2", "table2", 1000), variant("small-core", "small-core", 2000)]
    }

    #[test]
    fn json_has_schema_uarch_ppa_and_fig8_shaped_benchmarks() {
        let v = to_json(&fixture(), &[128, 256]);
        let back = Json::parse(&v.render_pretty()).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("schema").unwrap().as_str(), Some(DSE_SCHEMA));
        let variants = back.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 2);
        assert_eq!(variants[0].get("name").unwrap().as_str(), Some("table2"));
        assert_eq!(
            variants[1].get("uarch").unwrap().get("l2_bytes").unwrap().as_u64(),
            Some(128 * 1024)
        );
        let benches = variants[0].get("benchmarks").unwrap().as_arr().unwrap();
        let sve = benches[0].get("sve").unwrap().as_arr().unwrap();
        assert_eq!(sve[0].get("speedup").unwrap().as_f64(), Some(1.25));
        // v2: the PPA layer is present and internally consistent
        let area = variants[0].get("area_proxy").unwrap();
        let core = area.get("core_um2").unwrap().as_f64().unwrap();
        let per_vl = area.get("per_vl").unwrap().as_arr().unwrap();
        assert_eq!(per_vl.len(), 2);
        let total0 = per_vl[0].get("total_um2").unwrap().as_f64().unwrap();
        let vec0 = per_vl[0].get("vector_um2").unwrap().as_f64().unwrap();
        assert_eq!(total0, core + vec0);
        let energy = variants[0].get("energy_pj").unwrap().as_arr().unwrap();
        let erun = &energy[0].get("sve").unwrap().as_arr().unwrap()[0];
        let e = erun.get("energy_pj").unwrap().as_f64().unwrap();
        assert!(e > 0.0);
        assert_eq!(erun.get("perf_per_watt").unwrap().as_f64(), Some(1.0e12 / e));
        // the pareto ranking covers every (variant, VL) point
        let pareto = back.get("pareto").unwrap().as_arr().unwrap();
        assert_eq!(pareto.len(), 4);
        assert!(pareto.iter().any(|p| p.get("frontier").unwrap().as_bool() == Some(true)));
    }

    #[test]
    fn empty_variant_slice_renders_without_panicking() {
        let p = pivot(&[], &[128, 256]);
        assert_eq!(p.header, vec!["bench", "vl_bits"]);
        assert!(p.rows.is_empty());
        assert!(to_markdown(&[], &[128]).contains("0 variants"));
        assert!(pareto(&[], &[128]).is_empty());
    }

    #[test]
    fn pivot_and_csv_have_expected_shape() {
        let p = pivot(&fixture(), &[128, 256]);
        assert_eq!(
            p.header,
            vec![
                "bench",
                "vl_bits",
                "table2",
                "small-core",
                "table2 perf/W",
                "small-core perf/W",
                "table2 perf/mm2",
                "small-core perf/mm2",
            ]
        );
        assert_eq!(p.rows.len(), 2); // 1 bench x 2 VLs
        assert_eq!(p.rows[0][..4], ["stream_triad", "128", "1.25", "1.25"]);
        let csv = table(&fixture(), &[128, 256]).to_csv();
        assert_eq!(csv.lines().count(), 5); // header + 2 variants x 2 VLs
        assert!(csv.starts_with(
            "variant,bench,group,extra_vec_%,vl_bits,speedup,neon_cycles,sve_cycles,\
             energy_pj,perf_per_watt,perf_per_mm2,area_um2"
        ));
        assert!(csv.contains("small-core,stream_triad,right,25.0,256,2.50,2000,800,"));
    }

    #[test]
    fn pareto_marks_dominated_points() {
        // same benchmark timings on a small and a big core: the big
        // core burns more area and energy for identical mean speedup,
        // so every big-core point is dominated by its small-core twin
        let same = vec![
            variant("small-core", "small-core", 1000),
            variant("big-core", "big-core", 1000),
        ];
        let pts = pareto(&same, &[128, 256]);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            if p.variant == "big-core" {
                assert!(!p.frontier, "{p:?} should be dominated");
                assert!(p.dominated_by.as_deref().unwrap().starts_with("small-core"));
            } else {
                assert!(p.frontier, "{p:?} should be on the frontier");
            }
        }
        // frontier points rank first
        assert!(pts[0].frontier && pts[1].frontier);
        let t = pareto_table(&pts);
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[0][0], "1");
        assert!(t.rows[3][6] == "dominated");
    }

    #[test]
    fn frontier_only_drops_dominated_variants_everywhere() {
        // identical timings on small-core and big-core: every big-core
        // point is dominated (see pareto_marks_dominated_points), so the
        // frontier view keeps exactly the small-core variant
        let same = vec![
            variant("small-core", "small-core", 1000),
            variant("big-core", "big-core", 1000),
        ];
        let (kept, pts) = frontier_only(&same, &[128, 256]);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].name, "small-core");
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.frontier && p.variant == "small-core"));
        // the frontier json lists only frontier points and kept variants
        let doc = to_json_with(&kept, &[128, 256], &pts);
        assert_eq!(doc.get("variants").unwrap().as_arr().unwrap().len(), 1);
        let pj = doc.get("pareto").unwrap().as_arr().unwrap();
        assert_eq!(pj.len(), 2);
        assert!(pj.iter().all(|p| p.get("frontier").unwrap().as_bool() == Some(true)));
        // the frontier csv keeps only frontier (variant, VL) rows
        let t = frontier_table(&kept, &[128, 256], &pts);
        assert_eq!(t.rows.len(), 2);
        assert!(t.rows.iter().all(|r| r[0] == "small-core"));
        // an unfiltered emitter run is untouched (golden safety)
        let full = to_json(&same, &[128, 256]);
        assert_eq!(full.get("pareto").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn pareto_only_artifacts_write_filtered_files() {
        let same = vec![
            variant("small-core", "small-core", 1000),
            variant("big-core", "big-core", 1000),
        ];
        let dir = std::env::temp_dir()
            .join(format!("sve-dse-pareto-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let paths = write_artifacts_pareto_only(&same, &[128, 256], &dir).unwrap();
        assert_eq!(paths.len(), 3);
        let json = std::fs::read_to_string(&paths[0]).unwrap();
        assert!(!json.contains("big-core"), "dominated variant must be filtered");
        assert!(!json.contains("\"frontier\": false"));
        let csv = std::fs::read_to_string(&paths[1]).unwrap();
        assert!(!csv.contains("big-core"));
        let md = std::fs::read_to_string(&paths[2]).unwrap();
        assert!(!md.contains("big-core"), "md sections are frontier-only");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn markdown_sections_and_artifacts() {
        let md = to_markdown(&fixture(), &[128, 256]);
        assert!(md.contains("# DSE"));
        assert!(md.contains("## table2"));
        assert!(md.contains("## small-core"));
        assert!(md.contains("## Cross-variant pivot"));
        assert!(md.contains("## Pareto frontier"));
        assert!(md.contains(DSE_SCHEMA));
        let dir = std::env::temp_dir().join(format!("sve-dse-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let paths = write_artifacts(&fixture(), &[128, 256], &dir).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            assert!(p.exists(), "{p:?} missing");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
