//! Design-space exploration artifacts: the `sve dse` sweep rendered as
//! machine-readable JSON (schema [`DSE_SCHEMA`]) + long-form CSV and
//! human-readable Markdown with a cross-variant pivot. Like the Fig. 8
//! emitters, every rendering is a pure function of the row data — no
//! timestamps, no environment — so the artifacts are byte-stable and
//! golden-tested (`tests/dse_compare_golden.rs`).
//!
//! The per-variant benchmark payload is exactly the Fig. 8 shape
//! ([`crate::report::fig8::benchmarks_json`]), which is what lets
//! `sve report --compare` diff `fig8.json` and `dse.json` artifacts
//! interchangeably.

use std::io;
use std::path::{Path, PathBuf};

use crate::coordinator::VariantRows;
use crate::csvutil::{f, Table};
use crate::report::fig8;
use crate::report::json::Json;
use crate::uarch::UarchConfig;

/// Schema tag of the `dse.json` artifact.
pub const DSE_SCHEMA: &str = "sve-repro/dse/v1";

/// Every [`UarchConfig`] field as a flat JSON object, in declaration
/// order — the artifact records the exact design point it was timed
/// under, so two artifacts are comparable without access to the CLI
/// invocation that produced them. Built from the single field
/// enumeration in `uarch::config` ([`crate::uarch::OVERRIDE_KEYS`] +
/// [`crate::uarch::field_value`]), so a new config field automatically
/// appears here.
pub fn uarch_json(c: &UarchConfig) -> Json {
    Json::Obj(
        crate::uarch::OVERRIDE_KEYS
            .iter()
            .map(|&key| {
                let v = crate::uarch::field_value(c, key)
                    .expect("every OVERRIDE_KEYS entry is readable");
                (key.to_string(), Json::u64(v))
            })
            .collect(),
    )
}

/// One-line human summary of a design point, used as the section
/// subtitle in `dse.md`.
pub fn uarch_summary(c: &UarchConfig) -> String {
    format!(
        "L1D {}K/{}-way · L2 {}K/{}-way · decode/retire {}/{} · ROB {} · \
         issue {}i+{}v · {} ld / {} st per cycle",
        c.l1d_bytes / 1024,
        c.l1d_assoc,
        c.l2_bytes / 1024,
        c.l2_assoc,
        c.decode_width,
        c.retire_width,
        c.rob,
        c.int_issue_per_cycle,
        c.vec_issue_per_cycle,
        c.loads_per_cycle,
        c.stores_per_cycle
    )
}

/// The cross-variant pivot: one row per (benchmark, VL), one speedup
/// column per variant — the paper's PPA question ("which design point
/// suits my targets?") on a single screen.
pub fn pivot(variants: &[VariantRows], vls: &[usize]) -> Table {
    let mut header = vec!["bench".to_string(), "vl_bits".to_string()];
    for v in variants {
        header.push(v.name.clone());
    }
    let mut t = Table::new(header);
    let Some(first) = variants.first() else { return t };
    for (bi, row0) in first.rows.iter().enumerate() {
        for (vi, vl) in vls.iter().enumerate() {
            let mut cells = vec![row0.bench.to_string(), vl.to_string()];
            for v in variants {
                cells.push(f(v.rows[bi].speedup(vi), 2));
            }
            t.push_row(cells);
        }
    }
    t
}

/// The long-form table behind `dse.csv`: one row per
/// (variant, benchmark, VL) — the shape plotting tools want.
pub fn table(variants: &[VariantRows], vls: &[usize]) -> Table {
    let mut t = Table::new(vec![
        "variant",
        "bench",
        "group",
        "extra_vec_%",
        "vl_bits",
        "speedup",
        "neon_cycles",
        "sve_cycles",
    ]);
    for v in variants {
        for r in &v.rows {
            for (vi, vl) in vls.iter().enumerate() {
                t.push_row(vec![
                    v.name.clone(),
                    r.bench.to_string(),
                    r.group.short().to_string(),
                    f(100.0 * r.extra_vectorization, 1),
                    vl.to_string(),
                    f(r.speedup(vi), 2),
                    r.neon.cycles.to_string(),
                    r.sve[vi].cycles.to_string(),
                ]);
            }
        }
    }
    t
}

/// The machine-readable DSE document: per variant, the exact design
/// point ([`uarch_json`]) plus the Fig. 8-shaped benchmark payload.
pub fn to_json(variants: &[VariantRows], vls: &[usize]) -> Json {
    Json::Obj(vec![
        ("schema".into(), Json::str(DSE_SCHEMA)),
        ("figure".into(), Json::str("dse")),
        (
            "title".into(),
            Json::str("SVE speedup over Advanced SIMD across microarchitecture design points"),
        ),
        ("vls_bits".into(), Json::Arr(vls.iter().map(|&v| Json::u64(v as u64)).collect())),
        (
            "variants".into(),
            Json::Arr(
                variants
                    .iter()
                    .map(|v| {
                        Json::Obj(vec![
                            ("name".into(), Json::str(v.name.clone())),
                            ("uarch".into(), uarch_json(&v.uarch)),
                            ("benchmarks".into(), fig8::benchmarks_json(&v.rows)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The human-readable Markdown artifact (`dse.md`).
pub fn to_markdown(variants: &[VariantRows], vls: &[usize]) -> String {
    use std::fmt::Write as _;
    let vl_list = vls.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ");
    let mut out = String::new();
    let _ = write!(
        out,
        "# DSE — SVE speedup across µarch design points\n\
         \n\
         Schema: `{DSE_SCHEMA}` · SVE vector lengths: {vl_list} bits · \
         {nv} variants × {nb} benchmarks, every run validated against its \
         golden outputs.\n\
         \n\
         Each variant section is the Fig. 8 table timed under that design \
         point; the pivot at the end puts every variant's speedup-vs-VL \
         side by side (speedup is NEON cycles / SVE cycles at the same \
         design point).\n\
         \n",
        nv = variants.len(),
        nb = variants.first().map_or(0, |v| v.rows.len()),
    );
    for v in variants {
        let _ = write!(
            out,
            "## {}\n\n{}\n\n{}\n",
            v.name,
            uarch_summary(&v.uarch),
            fig8::table(&v.rows, vls).to_markdown(),
        );
    }
    let _ = write!(
        out,
        "## Cross-variant pivot — speedup over NEON\n\n{}\n\
         Regenerate with `sve dse --uarch <variants> --out <dir>` (add \
         `--resume` to reuse cached jobs); machine-readable copies: \
         `dse.json`, `dse.csv`.\n",
        pivot(variants, vls).to_markdown(),
    );
    out
}

/// Write `dse.json`, `dse.csv` and `dse.md` under `out_dir`, returning
/// the paths written.
pub fn write_artifacts(
    variants: &[VariantRows],
    vls: &[usize],
    out_dir: impl AsRef<Path>,
) -> io::Result<Vec<PathBuf>> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join("dse.json");
    std::fs::write(&json_path, to_json(variants, vls).render_pretty())?;
    let csv_path = dir.join("dse.csv");
    std::fs::write(&csv_path, table(variants, vls).to_csv())?;
    let md_path = dir.join("dse.md");
    std::fs::write(&md_path, to_markdown(variants, vls))?;
    Ok(vec![json_path, csv_path, md_path])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Fig8Row, Isa, RunRecord};
    use crate::uarch::base_variant;
    use crate::workloads::Group;

    fn rec(bench: &'static str, isa: Isa, cycles: u64) -> RunRecord {
        RunRecord {
            bench,
            group: Group::Right,
            isa,
            cycles,
            insts: 10 * cycles,
            vector_fraction: 0.5,
            vectorized: true,
            l1d_miss_rate: 0.125,
            ipc: 1.5,
        }
    }

    fn variant(name: &str, base: &str, neon_cycles: u64) -> VariantRows {
        let sve = vec![
            rec("stream_triad", Isa::Sve(128), neon_cycles * 4 / 5),
            rec("stream_triad", Isa::Sve(256), neon_cycles * 2 / 5),
        ];
        VariantRows {
            name: name.into(),
            uarch: base_variant(base).unwrap(),
            rows: vec![Fig8Row {
                bench: "stream_triad",
                group: Group::Right,
                neon: rec("stream_triad", Isa::Neon, neon_cycles),
                sve,
                extra_vectorization: 0.25,
            }],
        }
    }

    fn fixture() -> Vec<VariantRows> {
        vec![variant("table2", "table2", 1000), variant("small-core", "small-core", 2000)]
    }

    #[test]
    fn json_has_schema_uarch_and_fig8_shaped_benchmarks() {
        let v = to_json(&fixture(), &[128, 256]);
        let back = Json::parse(&v.render_pretty()).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("schema").unwrap().as_str(), Some(DSE_SCHEMA));
        let variants = back.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 2);
        assert_eq!(variants[0].get("name").unwrap().as_str(), Some("table2"));
        assert_eq!(
            variants[1].get("uarch").unwrap().get("l2_bytes").unwrap().as_u64(),
            Some(128 * 1024)
        );
        let benches = variants[0].get("benchmarks").unwrap().as_arr().unwrap();
        let sve = benches[0].get("sve").unwrap().as_arr().unwrap();
        assert_eq!(sve[0].get("speedup").unwrap().as_f64(), Some(1.25));
    }

    #[test]
    fn empty_variant_slice_renders_without_panicking() {
        let p = pivot(&[], &[128, 256]);
        assert_eq!(p.header, vec!["bench", "vl_bits"]);
        assert!(p.rows.is_empty());
        assert!(to_markdown(&[], &[128]).contains("0 variants"));
    }

    #[test]
    fn pivot_and_csv_have_expected_shape() {
        let p = pivot(&fixture(), &[128, 256]);
        assert_eq!(p.header, vec!["bench", "vl_bits", "table2", "small-core"]);
        assert_eq!(p.rows.len(), 2); // 1 bench x 2 VLs
        assert_eq!(p.rows[0], vec!["stream_triad", "128", "1.25", "1.25"]);
        let csv = table(&fixture(), &[128, 256]).to_csv();
        assert_eq!(csv.lines().count(), 5); // header + 2 variants x 2 VLs
        assert!(csv.starts_with("variant,bench,group,extra_vec_%,vl_bits,speedup"));
        assert!(csv.contains("small-core,stream_triad,right,25.0,256,2.50,2000,800"));
    }

    #[test]
    fn markdown_sections_and_artifacts() {
        let md = to_markdown(&fixture(), &[128, 256]);
        assert!(md.contains("# DSE"));
        assert!(md.contains("## table2"));
        assert!(md.contains("## small-core"));
        assert!(md.contains("## Cross-variant pivot"));
        assert!(md.contains(DSE_SCHEMA));
        let dir = std::env::temp_dir().join(format!("sve-dse-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let paths = write_artifacts(&fixture(), &[128, 256], &dir).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            assert!(p.exists(), "{p:?} missing");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
