//! Fig. 2 artifact emitters: the paper's daxpy kernel compiled three
//! ways (scalar, Advanced SIMD, SVE), with per-target code listings and
//! simulated cycle counts across vector lengths. Emits `fig2.json`
//! (schema [`FIG2_SCHEMA`]) + `fig2.csv` + `fig2.md`.

use std::io;
use std::path::{Path, PathBuf};

use crate::compiler::{compile, BinOp, Compiled, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
use crate::csvutil::{f, Table};
use crate::exec::Executor;
use crate::mem::Memory;
use crate::report::json::Json;
use crate::uarch::{run_timed, UarchConfig};

/// Schema tag of the `fig2.json` artifact.
pub const FIG2_SCHEMA: &str = "sve-repro/fig2/v1";

/// Problem size for the report's daxpy runs (small enough that the
/// whole report regenerates in well under a second).
pub const DAXPY_N: u64 = 1024;

/// The canonical Fig. 2 kernel: `y[i] = a*x[i] + y[i]` over f64.
pub fn daxpy_kernel(mem: &mut Memory, n: u64) -> Kernel {
    let xb = mem.alloc(8 * n, 64);
    let yb = mem.alloc(8 * n, 64);
    for i in 0..n {
        mem.write_f64(xb + 8 * i, i as f64).unwrap();
        mem.write_f64(yb + 8 * i, 1.0).unwrap();
    }
    let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n));
    let x = k.array("x", Ty::F64, xb);
    let y = k.array("y", Ty::F64, yb);
    k.body.push(Stmt::Store {
        arr: y,
        idx: Index::Affine { offset: 0 },
        value: Expr::bin(
            BinOp::Add,
            Expr::bin(BinOp::Mul, Expr::ConstF(3.0), Expr::load(x, Index::Affine { offset: 0 })),
            Expr::load(y, Index::Affine { offset: 0 }),
        ),
    });
    k
}

/// Assembly-style listing of a compiled program (labels + Debug insts).
pub fn listing(c: &Compiled) -> Vec<String> {
    let mut out = Vec::with_capacity(c.program.insts.len());
    for (i, inst) in c.program.insts.iter().enumerate() {
        if let Some(l) = c.program.label_at(i) {
            out.push(format!("{l}:"));
        }
        out.push(format!("  {i:>3}: {inst:?}"));
    }
    out
}

/// One simulated (target, VL) data point.
pub struct Fig2Run {
    pub label: String,
    pub target: &'static str,
    pub vl_bits: usize,
    pub cycles: u64,
    pub insts: u64,
    pub ipc: f64,
}

/// One compiled target's static view.
pub struct Fig2Target {
    pub target: &'static str,
    pub vectorized: bool,
    pub static_insts: usize,
    pub static_sve: usize,
    pub static_neon: usize,
    pub listing: Vec<String>,
}

/// The full Fig. 2 report data: three compilations + a VL sweep of the
/// SVE binary (plus scalar and NEON baselines at 128).
pub struct Fig2Report {
    pub n: u64,
    pub targets: Vec<Fig2Target>,
    pub runs: Vec<Fig2Run>,
}

fn target_name(t: Target) -> &'static str {
    match t {
        Target::Scalar => "scalar",
        Target::Neon => "neon",
        Target::Sve => "sve",
    }
}

/// Build the report by compiling and simulating the canonical kernel.
pub fn build(n: u64) -> Fig2Report {
    let mut mem = Memory::new();
    let k = daxpy_kernel(&mut mem, n);
    let mut targets = Vec::new();
    let mut runs = Vec::new();
    for (t, vls) in [
        (Target::Scalar, &[128usize][..]),
        (Target::Neon, &[128][..]),
        (Target::Sve, &[128, 256, 512, 1024, 2048][..]),
    ] {
        let c = compile(&k, t);
        let (sve, neon, _) = c.program.static_mix();
        targets.push(Fig2Target {
            target: target_name(t),
            vectorized: c.vectorized,
            static_insts: c.program.len(),
            static_sve: sve,
            static_neon: neon,
            listing: listing(&c),
        });
        for &vl in vls {
            let mut ex = Executor::new(vl, mem.clone());
            let (stats, tm) =
                run_timed(&mut ex, &c.program, UarchConfig::default(), 10_000_000)
                    .expect("daxpy must not trap");
            let label = match t {
                Target::Scalar => "scalar".to_string(),
                Target::Neon => "neon".to_string(),
                Target::Sve => format!("sve-{vl}"),
            };
            runs.push(Fig2Run {
                label,
                target: target_name(t),
                vl_bits: vl,
                cycles: tm.cycles,
                insts: stats.insts,
                ipc: tm.ipc(),
            });
        }
    }
    Fig2Report { n, targets, runs }
}

/// The per-run CSV table.
pub fn table(rep: &Fig2Report) -> Table {
    let mut t = Table::new(vec!["label", "target", "vl_bits", "cycles", "insts", "ipc"]);
    for r in &rep.runs {
        t.push_row(vec![
            r.label.clone(),
            r.target.to_string(),
            r.vl_bits.to_string(),
            r.cycles.to_string(),
            r.insts.to_string(),
            f(r.ipc, 2),
        ]);
    }
    t
}

/// The machine-readable Fig. 2 document.
pub fn to_json(rep: &Fig2Report) -> Json {
    Json::Obj(vec![
        ("schema".into(), Json::str(FIG2_SCHEMA)),
        ("figure".into(), Json::str("fig2")),
        ("title".into(), Json::str("daxpy compiled for scalar, Advanced SIMD and SVE")),
        ("n".into(), Json::u64(rep.n)),
        (
            "targets".into(),
            Json::Arr(
                rep.targets
                    .iter()
                    .map(|t| {
                        Json::Obj(vec![
                            ("target".into(), Json::str(t.target)),
                            ("vectorized".into(), Json::Bool(t.vectorized)),
                            ("static_insts".into(), Json::u64(t.static_insts as u64)),
                            ("static_sve".into(), Json::u64(t.static_sve as u64)),
                            ("static_neon".into(), Json::u64(t.static_neon as u64)),
                            (
                                "listing".into(),
                                Json::Arr(t.listing.iter().map(Json::str).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "runs".into(),
            Json::Arr(
                rep.runs
                    .iter()
                    .map(|r| {
                        Json::Obj(vec![
                            ("label".into(), Json::str(r.label.clone())),
                            ("target".into(), Json::str(r.target)),
                            ("vl_bits".into(), Json::u64(r.vl_bits as u64)),
                            ("cycles".into(), Json::u64(r.cycles)),
                            ("insts".into(), Json::u64(r.insts)),
                            ("ipc".into(), Json::f64(r.ipc)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The human-readable Markdown artifact (`fig2.md`).
pub fn to_markdown(rep: &Fig2Report) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "# Fig. 2 — daxpy compiled three ways\n");
    let _ = writeln!(
        out,
        "Schema: `{FIG2_SCHEMA}` · n = {} · one kernel, three code \
         generators; the SVE binary is vector-length agnostic and is \
         re-run unchanged at every VL (§2.2).\n",
        rep.n
    );
    let _ = writeln!(out, "{}", table(rep).to_markdown());
    for t in &rep.targets {
        let _ = writeln!(
            out,
            "## {} ({} static instructions, vectorized: {})\n",
            t.target, t.static_insts, t.vectorized
        );
        let _ = writeln!(out, "```");
        for line in &t.listing {
            let _ = writeln!(out, "{line}");
        }
        let _ = writeln!(out, "```\n");
    }
    let _ = writeln!(
        out,
        "Regenerate with `sve report --out <dir>`; machine-readable \
         copies: `fig2.json`, `fig2.csv`."
    );
    out
}

/// Write `fig2.json`, `fig2.csv` and `fig2.md` under `out_dir`.
pub fn write_artifacts(rep: &Fig2Report, out_dir: impl AsRef<Path>) -> io::Result<Vec<PathBuf>> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join("fig2.json");
    std::fs::write(&json_path, to_json(rep).render_pretty())?;
    let csv_path = dir.join("fig2.csv");
    std::fs::write(&csv_path, table(rep).to_csv())?;
    let md_path = dir.join("fig2.md");
    std::fs::write(&md_path, to_markdown(rep))?;
    Ok(vec![json_path, csv_path, md_path])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_shape_matches_the_figure() {
        let rep = build(256);
        assert_eq!(rep.targets.len(), 3);
        assert!(!rep.targets[0].vectorized, "scalar");
        assert!(rep.targets[1].vectorized, "neon");
        assert!(rep.targets[2].vectorized, "sve");
        assert!(rep.targets[2].static_sve > 0);
        assert_eq!(rep.runs.len(), 1 + 1 + 5);
        // cycles must fall (weakly) as VL grows on a streaming kernel,
        // and the endpoints must show real scaling
        let sve: Vec<u64> =
            rep.runs.iter().filter(|r| r.target == "sve").map(|r| r.cycles).collect();
        assert!(sve.windows(2).all(|w| w[1] <= w[0]), "VL scaling: {sve:?}");
        assert!(
            *sve.last().unwrap() * 2 < sve[0],
            "2048-bit must at least halve 128-bit cycles: {sve:?}"
        );
        let v = to_json(&rep);
        let back = Json::parse(&v.render_pretty()).unwrap();
        assert_eq!(back, v);
        assert!(to_markdown(&rep).contains("## sve"));
    }
}
