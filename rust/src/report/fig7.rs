//! Fig. 7 artifact emitters: the SVE encoding-budget model — how much
//! of the architecture's single 28-bit encoding region each instruction
//! group consumes, plus the §4 destructive-vs-constructive
//! counterfactual. Emits `fig7.json` (schema [`FIG7_SCHEMA`]) +
//! `fig7.csv` + `fig7.md`.

use std::io;
use std::path::{Path, PathBuf};

use crate::csvutil::Table;
use crate::isa::encoding::{self, sve_region_report};
use crate::report::json::Json;

/// Schema tag of the `fig7.json` artifact.
pub const FIG7_SCHEMA: &str = "sve-repro/fig7/v1";

/// The per-group CSV table.
pub fn table() -> Table {
    let (groups, total) = sve_region_report();
    let mut t = Table::new(vec!["group", "points", "share_of_region_%"]);
    for g in &groups {
        t.push_row(vec![
            g.group.clone(),
            g.points.to_string(),
            format!("{:.3}", 100.0 * g.share_of_region),
        ]);
    }
    t.push_row(vec![
        "total".to_string(),
        total.to_string(),
        format!("{:.3}", 100.0 * total as f64 / encoding::SVE_REGION_POINTS as f64),
    ]);
    t
}

/// The machine-readable Fig. 7 document.
pub fn to_json() -> Json {
    let (groups, total) = sve_region_report();
    let (destructive, constructive) = encoding::constructive_counterfactual();
    Json::Obj(vec![
        ("schema".into(), Json::str(FIG7_SCHEMA)),
        ("figure".into(), Json::str("fig7")),
        ("title".into(), Json::str("SVE encoding budget within one 28-bit region")),
        ("region_bits".into(), Json::u64(encoding::SVE_REGION_BITS as u64)),
        ("region_points".into(), Json::Num(encoding::SVE_REGION_POINTS.to_string())),
        (
            "groups".into(),
            Json::Arr(
                groups
                    .iter()
                    .map(|g| {
                        Json::Obj(vec![
                            ("group".into(), Json::str(g.group.clone())),
                            ("points".into(), Json::Num(g.points.to_string())),
                            ("share_of_region".into(), Json::f64(g.share_of_region)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("total_points".into(), Json::Num(total.to_string())),
        (
            "counterfactual".into(),
            Json::Obj(vec![
                ("full_dp_opcodes".into(), Json::u64(encoding::FULL_DP_OPCODES as u64)),
                ("destructive_plus_movprfx_points".into(), Json::Num(destructive.to_string())),
                ("fully_constructive_points".into(), Json::Num(constructive.to_string())),
            ]),
        ),
    ])
}

/// The human-readable Markdown artifact (`fig7.md`).
pub fn to_markdown() -> String {
    use std::fmt::Write as _;
    let (_, total) = sve_region_report();
    let (destructive, constructive) = encoding::constructive_counterfactual();
    let mut out = String::new();
    let _ = writeln!(out, "# Fig. 7 — SVE encoding budget\n");
    let _ = writeln!(
        out,
        "Schema: `{FIG7_SCHEMA}` · SVE fits one {}-bit region of the \
         AArch64 opcode space ({} encoding points).\n",
        encoding::SVE_REGION_BITS,
        encoding::SVE_REGION_POINTS
    );
    let _ = writeln!(out, "{}", table().to_markdown());
    let _ = writeln!(
        out,
        "Used: {total} of {} points ({:.2}%).\n",
        encoding::SVE_REGION_POINTS,
        100.0 * total as f64 / encoding::SVE_REGION_POINTS as f64
    );
    let _ = writeln!(
        out,
        "§4 counterfactual (full {}-opcode data-processing set): \
         destructive forms plus `movprfx` need {destructive} points; \
         fully-constructive forms would need {constructive} points — \
         {:.1}x the entire region. This is why SVE keeps destructive \
         destinations and pairs them with `movprfx`.\n",
        encoding::FULL_DP_OPCODES,
        constructive as f64 / encoding::SVE_REGION_POINTS as f64
    );
    let _ = writeln!(
        out,
        "Regenerate with `sve report --out <dir>` or `sve encoding`; \
         machine-readable copies: `fig7.json`, `fig7.csv`."
    );
    out
}

/// Write `fig7.json`, `fig7.csv` and `fig7.md` under `out_dir`.
pub fn write_artifacts(out_dir: impl AsRef<Path>) -> io::Result<Vec<PathBuf>> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join("fig7.json");
    std::fs::write(&json_path, to_json().render_pretty())?;
    let csv_path = dir.join("fig7.csv");
    std::fs::write(&csv_path, table().to_csv())?;
    let md_path = dir.join("fig7.md");
    std::fs::write(&md_path, to_markdown())?;
    Ok(vec![json_path, csv_path, md_path])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_artifacts_are_consistent() {
        let v = to_json();
        let back = Json::parse(&v.render_pretty()).unwrap();
        assert_eq!(back, v);
        let total: u128 = match back.get("total_points").unwrap() {
            Json::Num(n) => n.parse().unwrap(),
            other => panic!("total_points must be a number, got {other:?}"),
        };
        assert!(total < encoding::SVE_REGION_POINTS, "SVE fits in one region");
        let t = table();
        assert!(t.rows.len() >= 2);
        assert_eq!(t.rows.last().unwrap()[0], "total");
        assert!(to_markdown().contains("movprfx"));
    }
}
