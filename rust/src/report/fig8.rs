//! Fig. 8 artifact emitters: speedup-over-Advanced-SIMD sweep results
//! as machine-readable JSON (schema [`FIG8_SCHEMA`]) + CSV and
//! human-readable Markdown. All three renderings are pure functions of
//! the row data — no timestamps, no environment — so they are
//! byte-stable and golden-tested (`tests/report_golden.rs`).

use std::io;
use std::path::{Path, PathBuf};

use crate::coordinator::{Fig8Row, RunRecord};
use crate::csvutil::{f, Table};
use crate::report::json::Json;

/// Schema tag of the `fig8.json` artifact.
pub const FIG8_SCHEMA: &str = "sve-repro/fig8/v1";

/// Render the Fig. 8 table (speedups + extra vectorization).
pub fn table(rows: &[Fig8Row], vls: &[usize]) -> Table {
    let mut header = vec!["bench".to_string(), "group".to_string(), "extra_vec_%".to_string()];
    for vl in vls {
        header.push(format!("speedup_sve{vl}"));
    }
    header.push("neon_cycles".into());
    let mut t = Table::new(header);
    for r in rows {
        let mut row = vec![
            r.bench.to_string(),
            r.group.short().to_string(),
            f(100.0 * r.extra_vectorization, 1),
        ];
        for i in 0..vls.len() {
            row.push(f(r.speedup(i), 2));
        }
        row.push(r.neon.cycles.to_string());
        t.push_row(row);
    }
    t
}

/// ASCII rendition of Fig. 8: one row per benchmark, speedup bars per VL
/// plus the extra-vectorization percentage.
pub fn chart(rows: &[Fig8Row], vls: &[usize]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Fig. 8 — speedup over Advanced SIMD (bracket: extra vectorization %)\n"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<13} [{:>5.1}% extra vectorization]  {}",
            r.bench,
            100.0 * r.extra_vectorization,
            r.group.short()
        );
        for (i, vl) in vls.iter().enumerate() {
            let sp = r.speedup(i);
            let bar_len = (sp * 8.0).round() as usize;
            let _ = writeln!(out, "  sve-{:<4} {:>5.2}x |{}", vl, sp, "#".repeat(bar_len.min(80)));
        }
    }
    out
}

fn run_json(r: &RunRecord, speedup: Option<f64>) -> Json {
    let mut fields = vec![("vl_bits".to_string(), Json::u64(r.isa.vl() as u64))];
    if let Some(sp) = speedup {
        fields.push(("speedup".into(), Json::f64(sp)));
    }
    fields.extend([
        ("cycles".to_string(), Json::u64(r.cycles)),
        ("insts".to_string(), Json::u64(r.insts)),
        ("ipc".to_string(), Json::f64(r.ipc)),
        ("vectorized".to_string(), Json::Bool(r.vectorized)),
        ("vector_fraction".to_string(), Json::f64(r.vector_fraction)),
        ("l1d_miss_rate".to_string(), Json::f64(r.l1d_miss_rate)),
        ("pf_issued".to_string(), Json::u64(r.counters.pf_issued)),
        ("pf_useful".to_string(), Json::u64(r.counters.pf_useful)),
        ("dram_channel_cycles".to_string(), Json::u64(r.counters.dram_channel_cycles)),
    ]);
    Json::Obj(fields)
}

/// The per-benchmark array shared by the Fig. 8 and DSE documents: one
/// object per row with the NEON baseline and the per-VL SVE runs
/// (including speedups). `sve report --compare` understands exactly
/// this shape, wherever it appears.
pub fn benchmarks_json(rows: &[Fig8Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::Obj(vec![
                    ("bench".into(), Json::str(r.bench)),
                    ("group".into(), Json::str(r.group.short())),
                    ("extra_vectorization".into(), Json::f64(r.extra_vectorization)),
                    ("neon".into(), run_json(&r.neon, None)),
                    (
                        "sve".into(),
                        Json::Arr(
                            r.sve
                                .iter()
                                .enumerate()
                                .map(|(i, s)| run_json(s, Some(r.speedup(i))))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// The machine-readable Fig. 8 document.
pub fn to_json(rows: &[Fig8Row], vls: &[usize]) -> Json {
    let benchmarks = benchmarks_json(rows);
    Json::Obj(vec![
        ("schema".into(), Json::str(FIG8_SCHEMA)),
        ("figure".into(), Json::str("fig8")),
        (
            "title".into(),
            Json::str("SVE speedup over Advanced SIMD across vector lengths"),
        ),
        ("vls_bits".into(), Json::Arr(vls.iter().map(|&v| Json::u64(v as u64)).collect())),
        ("benchmarks".into(), benchmarks),
    ])
}

/// The human-readable Markdown artifact (`fig8.md`).
pub fn to_markdown(rows: &[Fig8Row], vls: &[usize]) -> String {
    let vl_list = vls.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ");
    format!(
        "# Fig. 8 — SVE speedup over Advanced SIMD\n\
         \n\
         Schema: `{FIG8_SCHEMA}` · SVE vector lengths: {vl_list} bits · \
         {nb} benchmarks, every run validated against its golden outputs.\n\
         \n\
         Speedup is NEON cycles / SVE cycles at each vector length; \
         `extra_vec_%` is the dynamic vector-instruction fraction SVE \
         gains over NEON at VL=128 (the paper's grey bars).\n\
         \n\
         {table}\n\
         ```\n\
         {chart}```\n\
         \n\
         Regenerate with `sve sweep --out <dir>` (add `--resume` to reuse \
         cached jobs); machine-readable copies: `fig8.json`, `fig8.csv`.\n",
        nb = rows.len(),
        table = table(rows, vls).to_markdown(),
        chart = chart(rows, vls),
    )
}

/// Write `fig8.json`, `fig8.csv` and `fig8.md` under `out_dir`,
/// returning the paths written.
pub fn write_artifacts(
    rows: &[Fig8Row],
    vls: &[usize],
    out_dir: impl AsRef<Path>,
) -> io::Result<Vec<PathBuf>> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join("fig8.json");
    std::fs::write(&json_path, to_json(rows, vls).render_pretty())?;
    let csv_path = dir.join("fig8.csv");
    std::fs::write(&csv_path, table(rows, vls).to_csv())?;
    let md_path = dir.join("fig8.md");
    std::fs::write(&md_path, to_markdown(rows, vls))?;
    Ok(vec![json_path, csv_path, md_path])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Isa;
    use crate::uarch::PpaCounters;
    use crate::workloads::Group;

    fn rec(bench: &'static str, isa: Isa, cycles: u64) -> RunRecord {
        RunRecord {
            bench,
            group: Group::Right,
            isa,
            cycles,
            insts: 10 * cycles,
            vector_fraction: 0.5,
            vectorized: true,
            l1d_miss_rate: 0.125,
            ipc: 1.5,
            counters: PpaCounters::default(),
        }
    }

    fn rows() -> Vec<Fig8Row> {
        let neon = rec("stream_triad", Isa::Neon, 1000);
        let sve = vec![
            rec("stream_triad", Isa::Sve(128), 800),
            rec("stream_triad", Isa::Sve(256), 400),
        ];
        vec![Fig8Row {
            bench: "stream_triad",
            group: Group::Right,
            extra_vectorization: 0.25,
            neon,
            sve,
        }]
    }

    #[test]
    fn json_is_parseable_and_complete() {
        let v = to_json(&rows(), &[128, 256]);
        let back = Json::parse(&v.render_pretty()).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("schema").unwrap().as_str(), Some(FIG8_SCHEMA));
        let benches = back.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 1);
        let sve = benches[0].get("sve").unwrap().as_arr().unwrap();
        assert_eq!(sve[0].get("speedup").unwrap().as_f64(), Some(1.25));
        assert_eq!(sve[1].get("speedup").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn csv_and_markdown_have_expected_shape() {
        let t = table(&rows(), &[128, 256]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        let header = "bench,group,extra_vec_%,speedup_sve128,speedup_sve256,neon_cycles";
        assert!(csv.starts_with(header));
        assert!(csv.contains("stream_triad,right,25.0,1.25,2.50,1000"));
        let md = to_markdown(&rows(), &[128, 256]);
        assert!(md.contains("# Fig. 8"));
        assert!(md.contains(FIG8_SCHEMA));
        assert!(md.contains("| stream_triad"));
    }

    #[test]
    fn artifacts_written_to_disk() {
        let dir = std::env::temp_dir().join(format!("sve-fig8-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let paths = write_artifacts(&rows(), &[128, 256], &dir).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            assert!(p.exists(), "{p:?} missing");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
