//! Minimal JSON value type, writer and parser (the offline image has no
//! serde). Three properties matter for the report subsystem:
//!
//! 1. **Deterministic output** — objects keep insertion order and the
//!    writers add no timestamps, so artifacts and job files are
//!    byte-stable and can be golden-tested.
//! 2. **Exact number round-trips** — numbers are carried as their
//!    literal text ([`Json::Num`]). `f64` values are written with Rust's
//!    shortest-round-trip `Display`, so `parse(render(x))` recovers the
//!    exact bits; this is what makes resumed sweeps bit-identical.
//! 3. **No dependencies** — plain `std`, like `csvutil` and `rng`.
//!
//! ```
//! use sve_repro::report::json::Json;
//! let v = Json::Obj(vec![
//!     ("name".into(), Json::str("daxpy")),
//!     ("cycles".into(), Json::u64(1234)),
//!     ("ipc".into(), Json::f64(1.5)),
//! ]);
//! let text = v.render();
//! assert_eq!(text, r#"{"name":"daxpy","cycles":1234,"ipc":1.5}"#);
//! assert_eq!(Json::parse(&text).unwrap(), v);
//! ```

/// A JSON value. Numbers are kept as literal text so integer and float
/// precision survive a write/read cycle untouched.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// The literal number token, written verbatim.
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in insertion order (deterministic rendering).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An integer number value.
    pub fn u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    /// A float number value, written with the shortest representation
    /// that round-trips exactly. Non-finite values become `null` (JSON
    /// has no NaN/inf).
    pub fn f64(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(format!("{v}"))
        } else {
            Json::Null
        }
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact rendering (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Human-friendly rendering: 2-space indent, one field per line.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = vec![];
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            fields.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        let bytes = self.b;
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| "unterminated string".to_string())?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| "unterminated escape".to_string())?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| "surrogate \\u escape unsupported".to_string())?,
                            );
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the full char in the source
                    let start = self.i - 1;
                    let text = std::str::from_utf8(&bytes[start..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.i]).unwrap().to_string();
        if tok.parse::<f64>().is_err() {
            return Err(format!("bad number '{tok}' at byte {start}"));
        }
        Ok(Json::Num(tok))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::Obj(vec![
            ("s".into(), Json::str("a\"b\\c\nd")),
            ("n".into(), Json::u64(u64::MAX)),
            ("f".into(), Json::f64(0.1)),
            ("b".into(), Json::Bool(true)),
            ("z".into(), Json::Null),
            ("arr".into(), Json::Arr(vec![Json::u64(1), Json::str("x")])),
            ("empty".into(), Json::Arr(vec![])),
            ("obj".into(), Json::Obj(vec![("k".into(), Json::Bool(false))])),
        ]);
        let compact = v.render();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.render_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn floats_roundtrip_exactly() {
        for bits in [0x3ff0000000000001u64, 0x3fb999999999999au64, 0x7fefffffffffffffu64] {
            let x = f64::from_bits(bits);
            let v = Json::f64(x);
            let back = Json::parse(&v.render()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), bits);
        }
        assert_eq!(Json::f64(f64::NAN), Json::Null);
    }

    #[test]
    fn u64_exactness_beyond_f64() {
        let v = Json::u64(9_007_199_254_740_993); // 2^53 + 1
        let back = Json::parse(&v.render()).unwrap();
        assert_eq!(back.as_u64(), Some(9_007_199_254_740_993));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"caf\\u00e9 µarch\"").unwrap();
        assert_eq!(v.as_str(), Some("café µarch"));
        let w = Json::str("tab\tnewline\n");
        assert_eq!(Json::parse(&w.render()).unwrap(), w);
    }
}
