//! Report generation: machine-readable (JSON, CSV) and human-readable
//! (Markdown) artifacts for the paper's figures, plus the persistence
//! layer that makes sweeps resumable.
//!
//! The coordinator produces data ([`crate::coordinator::Fig8Row`],
//! [`crate::coordinator::RunRecord`]); this module owns every rendering
//! of it:
//!
//! * [`json`] — dependency-free JSON value/writer/parser with exact
//!   number round-trips (the foundation of resume bit-identity).
//! * [`store`] — the content-addressed job cache under
//!   `<out>/jobs/<fnv1a-key>.json`; `sve sweep --resume` reloads
//!   completed jobs from here instead of re-simulating them.
//! * [`fig2`] — daxpy codegen listings + cycles across VLs.
//! * [`fig7`] — the encoding-budget model and §4 counterfactual.
//! * [`fig8`] — the headline speedup sweep.
//! * [`dse`] — the design-space sweep across µarch variants
//!   (`sve dse`), per-variant Fig. 8 tables + cross-variant pivot.
//! * [`compare`] — cross-commit diffing of fig8/dse artifacts
//!   (`sve report --compare`), the primitive behind CI's regression
//!   wall.
//!
//! Every emitter is a pure function of its inputs — no timestamps, no
//! host details — so artifacts are byte-stable across machines and
//! reruns, and the golden-file tests in `tests/report_golden.rs` and
//! `tests/dse_compare_golden.rs` can pin them exactly.
//!
//! Layout of a populated `reports/` directory:
//!
//! ```text
//! reports/
//! ├── fig2.{json,csv,md}     sve report
//! ├── fig7.{json,csv,md}     sve report
//! ├── fig8.{json,csv,md}     sve sweep / sve report
//! ├── dse.{json,csv,md}      sve dse
//! └── jobs/<key>.json        one cached RunRecord per sweep/dse job
//! ```

pub mod compare;
pub mod dse;
pub mod fig2;
pub mod fig7;
pub mod fig8;
pub mod json;
pub mod store;
