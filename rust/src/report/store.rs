//! Content-addressed persistence for sweep jobs.
//!
//! Every (workload, target, VL, [`UarchConfig`]) job is identified by a
//! 64-bit FNV-1a hash of its full configuration ([`job_key`]). A
//! [`JobStore`] maps that key to a small JSON file
//! (`<out>/jobs/<key>.json`, schema [`JOB_SCHEMA`]) holding the job's
//! [`RunRecord`]. A resumed sweep loads the file instead of
//! re-simulating; because floats are serialized with shortest
//! round-trip formatting (see [`super::json`]), a reloaded record is
//! bit-identical to the freshly simulated one.
//!
//! Any mismatch — missing file, parse error, schema drift, or a record
//! whose identity fields disagree with the requested job — is treated
//! as a cache miss, never an error: the job is simply re-simulated.

use std::path::{Path, PathBuf};

use crate::coordinator::{Isa, RunRecord};
use crate::report::json::Json;
use crate::uarch::{PpaCounters, UarchConfig};
use crate::workloads::{self, Group};

/// Schema tag written into every job file; bump on layout changes so
/// stale caches self-invalidate. v2 added the §PPA event counters
/// ([`crate::uarch::PpaCounters`]); v3 added the PR-9 memory-system
/// counters (`pf_issued`/`pf_useful`/`dram_channel_cycles`) and the
/// per-µop-class retire histogram the per-class energy model consumes.
/// Older files are treated as cache misses (the schema is part of
/// every [`job_key`], so old keys are simply never looked up again)
/// and re-simulated.
pub const JOB_SCHEMA: &str = "sve-repro/fig8-job/v3";

/// 64-bit FNV-1a. Tiny, dependency-free, and stable across platforms —
/// exactly what a cache key needs (this is not a security boundary).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The content hash identifying one sweep job.
///
/// Covers the schema version, workload name, ISA + vector length, and
/// every field of the microarchitecture config (via its `Debug`
/// rendering — all fields are integers, so the text is exact). Changing
/// any model parameter therefore changes every key, and a stale
/// `reports/jobs/` directory can never leak old numbers into a new
/// sweep.
pub fn job_key(bench: &str, isa: Isa, cfg: &UarchConfig) -> String {
    let ident = format!("{JOB_SCHEMA}|{bench}|{}|{}|{cfg:?}", isa.label(), isa.vl());
    format!("{:016x}", fnv1a(ident.as_bytes()))
}

/// On-disk job cache under `<out>/jobs/`.
pub struct JobStore {
    dir: PathBuf,
}

impl JobStore {
    /// Open (creating if needed) the job cache under `out_dir/jobs`.
    pub fn open(out_dir: impl AsRef<Path>) -> std::io::Result<JobStore> {
        let dir = out_dir.as_ref().join("jobs");
        std::fs::create_dir_all(&dir)?;
        Ok(JobStore { dir })
    }

    /// Path of one job file.
    pub fn job_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Persist one record under `key`.
    pub fn save(&self, key: &str, r: &RunRecord) -> std::io::Result<()> {
        std::fs::write(self.job_path(key), record_to_json(key, r).render_pretty())
    }

    /// Load the record cached under `key`, if present and valid.
    /// Returns `None` (cache miss) on any missing/corrupt/mismatched
    /// file — the caller re-simulates.
    pub fn load(&self, key: &str, bench: &str, isa: Isa) -> Option<RunRecord> {
        let text = std::fs::read_to_string(self.job_path(key)).ok()?;
        let r = record_from_json(&Json::parse(&text).ok()?)?;
        // identity check: the file must describe exactly this job
        if r.bench != bench || r.isa != isa {
            return None;
        }
        Some(r)
    }

    /// Bump `key`'s recency for the LRU eviction order by rewriting the
    /// file in place (a plain mtime update without touching bytes —
    /// `std` has no utimes). Best-effort: a missing or unreadable file
    /// is simply not touched.
    pub fn touch(&self, key: &str) {
        let path = self.job_path(key);
        if let Ok(text) = std::fs::read_to_string(&path) {
            let _ = std::fs::write(&path, text);
        }
    }

    /// Evict least-recently-used job files until the store fits in
    /// `max_bytes`. Eviction order is oldest mtime first, key as the
    /// deterministic tiebreak; a key for which `protected` returns
    /// `true` (the serve hub passes its in-flight set) is never
    /// removed, even if the store stays over budget because of it.
    /// Non-`.json` strangers in the directory are ignored entirely.
    pub fn gc(
        &self,
        max_bytes: u64,
        protected: &dyn Fn(&str) -> bool,
    ) -> std::io::Result<GcOutcome> {
        let mut entries: Vec<(String, u64, std::time::SystemTime)> = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(key) = name.to_str().and_then(|n| n.strip_suffix(".json")) else {
                continue;
            };
            let Ok(meta) = entry.metadata() else { continue };
            if !meta.is_file() {
                continue;
            }
            let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            entries.push((key.to_string(), meta.len(), mtime));
        }
        let bytes_before: u64 = entries.iter().map(|e| e.1).sum();
        let examined = entries.len();
        entries.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        let mut bytes_after = bytes_before;
        let mut evicted = 0usize;
        for (key, len, _) in &entries {
            if bytes_after <= max_bytes {
                break;
            }
            if protected(key) {
                continue;
            }
            if std::fs::remove_file(self.job_path(key)).is_ok() {
                bytes_after -= len;
                evicted += 1;
            }
        }
        Ok(GcOutcome { examined, evicted, bytes_before, bytes_after })
    }
}

/// What one [`JobStore::gc`] pass did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcOutcome {
    /// Job files found in the store.
    pub examined: usize,
    /// Files removed this pass.
    pub evicted: usize,
    /// Store size before the pass, bytes.
    pub bytes_before: u64,
    /// Store size after the pass (over `max_bytes` only if protected
    /// keys pin it there).
    pub bytes_after: u64,
}

/// Serialize one [`RunRecord`] (plus its key, for human inspection).
pub fn record_to_json(key: &str, r: &RunRecord) -> Json {
    Json::Obj(vec![
        ("schema".into(), Json::str(JOB_SCHEMA)),
        ("key".into(), Json::str(key)),
        ("bench".into(), Json::str(r.bench)),
        ("group".into(), Json::str(r.group.short())),
        ("isa".into(), Json::str(r.isa.label())),
        ("vl_bits".into(), Json::u64(r.isa.vl() as u64)),
        ("cycles".into(), Json::u64(r.cycles)),
        ("insts".into(), Json::u64(r.insts)),
        ("vector_fraction".into(), Json::f64(r.vector_fraction)),
        ("vectorized".into(), Json::Bool(r.vectorized)),
        ("l1d_miss_rate".into(), Json::f64(r.l1d_miss_rate)),
        ("ipc".into(), Json::f64(r.ipc)),
        ("l1d_accesses".into(), Json::u64(r.counters.l1d_accesses)),
        ("l2_accesses".into(), Json::u64(r.counters.l2_accesses)),
        ("mem_accesses".into(), Json::u64(r.counters.mem_accesses)),
        ("mispredicts".into(), Json::u64(r.counters.mispredicts)),
        ("cracked_elems".into(), Json::u64(r.counters.cracked_elems)),
        ("pf_issued".into(), Json::u64(r.counters.pf_issued)),
        ("pf_useful".into(), Json::u64(r.counters.pf_useful)),
        ("dram_channel_cycles".into(), Json::u64(r.counters.dram_channel_cycles)),
        (
            "class_counts".into(),
            Json::Arr(r.counters.class_counts.iter().map(|&n| Json::u64(n)).collect()),
        ),
    ])
}

/// Deserialize a job file back into a [`RunRecord`]. `None` on any
/// schema or field problem (treated as a cache miss by [`JobStore`]).
pub fn record_from_json(v: &Json) -> Option<RunRecord> {
    if v.get("schema")?.as_str()? != JOB_SCHEMA {
        return None;
    }
    let bench_name = v.get("bench")?.as_str()?;
    // intern against the static workload list: records always describe
    // known workloads, and RunRecord carries a &'static str
    let bench = *workloads::NAMES.iter().find(|n| **n == bench_name)?;
    let group = Group::from_short(v.get("group")?.as_str()?)?;
    let isa = Isa::parse_label(v.get("isa")?.as_str()?)?;
    Some(RunRecord {
        bench,
        group,
        isa,
        cycles: v.get("cycles")?.as_u64()?,
        insts: v.get("insts")?.as_u64()?,
        vector_fraction: v.get("vector_fraction")?.as_f64()?,
        vectorized: v.get("vectorized")?.as_bool()?,
        l1d_miss_rate: v.get("l1d_miss_rate")?.as_f64()?,
        ipc: v.get("ipc")?.as_f64()?,
        counters: PpaCounters {
            l1d_accesses: v.get("l1d_accesses")?.as_u64()?,
            l2_accesses: v.get("l2_accesses")?.as_u64()?,
            mem_accesses: v.get("mem_accesses")?.as_u64()?,
            mispredicts: v.get("mispredicts")?.as_u64()?,
            cracked_elems: v.get("cracked_elems")?.as_u64()?,
            pf_issued: v.get("pf_issued")?.as_u64()?,
            pf_useful: v.get("pf_useful")?.as_u64()?,
            dram_channel_cycles: v.get("dram_channel_cycles")?.as_u64()?,
            class_counts: {
                let arr = v.get("class_counts")?.as_arr()?;
                if arr.len() != crate::isa::NUM_UOP_CLASSES {
                    return None;
                }
                let mut counts = [0u64; crate::isa::NUM_UOP_CLASSES];
                for (slot, item) in counts.iter_mut().zip(arr) {
                    *slot = item.as_u64()?;
                }
                counts
            },
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunRecord {
        let mut class_counts = [0u64; crate::isa::NUM_UOP_CLASSES];
        for (i, slot) in class_counts.iter_mut().enumerate() {
            *slot = (i as u64 + 1) * 11;
        }
        RunRecord {
            bench: "stream_triad",
            group: Group::Right,
            isa: Isa::Sve(512),
            cycles: 123_456,
            insts: 98_765,
            vector_fraction: 0.9375,
            vectorized: true,
            l1d_miss_rate: f64::from_bits(0x3fb999999999999a), // ~0.1, awkward bits
            ipc: 1.75,
            counters: PpaCounters {
                l1d_accesses: 40_000,
                l2_accesses: 4_000,
                mem_accesses: 500,
                mispredicts: 123,
                cracked_elems: 7,
                pf_issued: 250,
                pf_useful: 210,
                dram_channel_cycles: 8_000,
                class_counts,
            },
        }
    }

    #[test]
    fn record_roundtrip_is_bitwise() {
        let r = sample();
        let v = record_to_json("deadbeefdeadbeef", &r);
        let back = record_from_json(&Json::parse(&v.render_pretty()).unwrap()).unwrap();
        assert_eq!(back.bench, r.bench);
        assert_eq!(back.group, r.group);
        assert_eq!(back.isa, r.isa);
        assert_eq!(back.cycles, r.cycles);
        assert_eq!(back.insts, r.insts);
        assert_eq!(back.vector_fraction.to_bits(), r.vector_fraction.to_bits());
        assert_eq!(back.vectorized, r.vectorized);
        assert_eq!(back.l1d_miss_rate.to_bits(), r.l1d_miss_rate.to_bits());
        assert_eq!(back.ipc.to_bits(), r.ipc.to_bits());
        assert_eq!(back.counters, r.counters);
    }

    #[test]
    fn v1_job_files_are_cache_misses() {
        // a pre-PPA record (old schema tag, no counters) must reload as
        // a miss, never as a record with invented counters
        let r = sample();
        let mut v = record_to_json("deadbeefdeadbeef", &r);
        if let Json::Obj(fields) = &mut v {
            fields.retain(|(k, _)| !k.ends_with("_accesses"));
            for (k, val) in fields.iter_mut() {
                if k == "schema" {
                    *val = Json::str("sve-repro/fig8-job/v1");
                }
            }
        }
        assert!(record_from_json(&v).is_none(), "v1 file must miss");
        // same layout but current schema tag with counters missing: miss
        let mut v = record_to_json("deadbeefdeadbeef", &r);
        if let Json::Obj(fields) = &mut v {
            fields.retain(|(k, _)| k != "mispredicts");
        }
        assert!(record_from_json(&v).is_none(), "missing counter must miss");
    }

    #[test]
    fn v2_job_files_are_cache_misses() {
        // a pre-PR-9 record (v2 tag, no memory-system counters) must
        // reload as a miss, never as a record with invented prefetch
        // stats or a zeroed class histogram
        let r = sample();
        let mut v = record_to_json("deadbeefdeadbeef", &r);
        if let Json::Obj(fields) = &mut v {
            fields.retain(|(k, _)| {
                !matches!(
                    k.as_str(),
                    "pf_issued" | "pf_useful" | "dram_channel_cycles" | "class_counts"
                )
            });
            for (k, val) in fields.iter_mut() {
                if k == "schema" {
                    *val = Json::str("sve-repro/fig8-job/v2");
                }
            }
        }
        assert!(record_from_json(&v).is_none(), "v2 file must miss");
        // current tag but a truncated class histogram: miss, not a
        // silently misaligned energy attribution
        let mut v = record_to_json("deadbeefdeadbeef", &r);
        if let Json::Obj(fields) = &mut v {
            for (k, val) in fields.iter_mut() {
                if k == "class_counts" {
                    if let Json::Arr(items) = val {
                        items.pop();
                    }
                }
            }
        }
        assert!(record_from_json(&v).is_none(), "short class_counts must miss");
    }

    #[test]
    fn keys_separate_every_dimension() {
        let cfg = UarchConfig::default();
        let base = job_key("stream_triad", Isa::Sve(256), &cfg);
        assert_eq!(base.len(), 16);
        assert_ne!(base, job_key("haccmk", Isa::Sve(256), &cfg));
        assert_ne!(base, job_key("stream_triad", Isa::Sve(512), &cfg));
        assert_ne!(base, job_key("stream_triad", Isa::Neon, &cfg));
        let mut slow = UarchConfig::default();
        slow.mem_lat += 1;
        assert_ne!(base, job_key("stream_triad", Isa::Sve(256), &slow));
        // every workload name (including the PR-7 oneDAL/SU(3) families)
        // hashes to its own key at a fixed (isa, cfg)
        let mut keys: Vec<String> = crate::workloads::NAMES
            .iter()
            .map(|n| job_key(n, Isa::Sve(256), &cfg))
            .collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), crate::workloads::NAMES.len(), "key collision");
    }

    /// The PR-7 workload names must intern through the job-file
    /// round-trip (a name missing from `workloads::NAMES` would silently
    /// downgrade every cached job for it to a miss).
    #[test]
    fn new_workload_names_roundtrip_through_job_files() {
        for (name, group) in [
            ("onedal_cov", Group::Right),
            ("onedal_moments", Group::Right),
            ("onedal_l2dist", Group::Right),
            ("su3_mv", Group::Middle),
            ("su3_dot", Group::Middle),
        ] {
            let bench = *crate::workloads::NAMES
                .iter()
                .find(|n| **n == name)
                .unwrap_or_else(|| panic!("{name} missing from workloads::NAMES"));
            let mut r = sample();
            r.bench = bench;
            r.group = group;
            let v = record_to_json("deadbeefdeadbeef", &r);
            let back = record_from_json(&Json::parse(&v.render_pretty()).unwrap())
                .unwrap_or_else(|| panic!("{name} failed to reload"));
            assert_eq!(back.bench, name);
            assert_eq!(back.group, group);
        }
    }

    #[test]
    fn store_save_load_and_miss_semantics() {
        let dir = std::env::temp_dir()
            .join(format!("sve-store-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let st = JobStore::open(&dir).unwrap();
        let r = sample();
        let key = job_key(r.bench, r.isa, &UarchConfig::default());
        assert!(st.load(&key, r.bench, r.isa).is_none(), "empty store misses");
        st.save(&key, &r).unwrap();
        let got = st.load(&key, r.bench, r.isa).unwrap();
        assert_eq!(got.cycles, r.cycles);
        // identity mismatch -> miss, not a wrong answer
        assert!(st.load(&key, "haccmk", r.isa).is_none());
        assert!(st.load(&key, r.bench, Isa::Sve(256)).is_none());
        // corrupt file -> miss
        std::fs::write(st.job_path(&key), "not json").unwrap();
        assert!(st.load(&key, r.bench, r.isa).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_evicts_oldest_first_and_never_in_flight_keys() {
        let dir = std::env::temp_dir().join(format!("sve-gc-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let st = JobStore::open(&dir).unwrap();
        let r = sample();
        // three records, mtimes strictly ordered a < b < c
        for key in ["aaaa", "bbbb", "cccc"] {
            st.save(key, &r).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(30));
        }
        let one = std::fs::metadata(st.job_path("aaaa")).unwrap().len();
        // budget for two files: the oldest unprotected one goes
        let out = st.gc(2 * one, &|_| false).unwrap();
        assert_eq!(out.examined, 3);
        assert_eq!(out.evicted, 1);
        assert!(!st.job_path("aaaa").exists(), "oldest must go first");
        assert!(st.job_path("bbbb").exists() && st.job_path("cccc").exists());
        assert_eq!(out.bytes_after, out.bytes_before - one);
        // a touch re-warms: after touching b, shrinking to one file
        // must evict c (now the coldest), not b
        std::thread::sleep(std::time::Duration::from_millis(30));
        st.touch("bbbb");
        let out = st.gc(one, &|_| false).unwrap();
        assert_eq!(out.evicted, 1);
        assert!(st.job_path("bbbb").exists(), "touched file survives");
        assert!(!st.job_path("cccc").exists());
        // protected (in-flight) keys are never evicted, even when the
        // store cannot meet the budget because of them
        let out = st.gc(0, &|key| key == "bbbb").unwrap();
        assert_eq!(out.evicted, 0);
        assert!(st.job_path("bbbb").exists());
        assert!(out.bytes_after > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_ignores_stranger_files_and_zero_budget_empties_the_store() {
        let dir =
            std::env::temp_dir().join(format!("sve-gc-stranger-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let st = JobStore::open(&dir).unwrap();
        let r = sample();
        st.save("aaaa", &r).unwrap();
        st.save("bbbb", &r).unwrap();
        std::fs::write(dir.join("jobs").join("README.txt"), "not a job").unwrap();
        let out = st.gc(0, &|_| false).unwrap();
        assert_eq!(out.examined, 2, "strangers are not the store's to manage");
        assert_eq!(out.evicted, 2);
        assert_eq!(out.bytes_after, 0);
        assert!(dir.join("jobs").join("README.txt").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fnv1a_known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }
}
