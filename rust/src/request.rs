//! The typed request layer: one schema, two spellings.
//!
//! Every `sve` subcommand that drives the sweep engine is described by
//! a plain struct here — [`SweepRequest`], [`DseRequest`],
//! [`ReportRequest`] — with **one** parser per flag instead of the
//! per-subcommand ad-hoc loops `main.rs` used to carry. The same
//! structs round-trip through JSON ([`SweepRequest::to_json`] /
//! [`SweepRequest::from_json`]), and that JSON form *is* the `sve
//! serve` wire format (see [`crate::serve::proto`]): CLI flags and the
//! socket API are two spellings of one schema, so a request accepted on
//! the command line is by construction expressible over the socket and
//! vice versa.
//!
//! Parsers return `Err(message)` — the CLI maps that to the exit-2
//! usage contract, the server to a structured `error` response. The
//! flag grammar, defaults, and error wording are unchanged from the
//! pre-PR-8 CLI (pinned by the integration tests).

use std::path::PathBuf;

use crate::coordinator::SweepConfig;
use crate::exec::Engine;
use crate::report::json::Json;
use crate::uarch::{parse_variants, UarchVariant, VARIANT_NAMES};
use crate::workloads;

/// Value of `name`, or `None` when the flag is absent. A flag present
/// with no trailing value is an error, never a silent default —
/// `--fail-on-regress $PCT` with `PCT` unset in a CI shell must not
/// quietly disable the regression wall.
pub fn flag(args: &[String], name: &str) -> Result<Option<String>, String> {
    let Some(i) = args.iter().position(|a| a == name) else {
        return Ok(None);
    };
    match args.get(i + 1) {
        Some(v) => Ok(Some(v.clone())),
        None => Err(format!("{name} needs a value")),
    }
}

/// Is the bare flag `name` present?
pub fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Parse the positional benchmark argument of `sve <cmd> <bench>`.
pub fn parse_bench_arg(args: &[String], cmd: &str) -> Result<&'static str, String> {
    let Some(bench) = args.get(1) else {
        return Err(format!("usage: sve {cmd} <bench>"));
    };
    intern_bench(bench)
}

/// Intern a benchmark name against [`workloads::NAMES`] (the
/// `&'static str` the coordinator carries in every record).
fn intern_bench(name: &str) -> Result<&'static str, String> {
    workloads::NAMES.iter().find(|n| **n == name).copied().ok_or_else(|| {
        format!("unknown benchmark '{name}' (try: {})", workloads::NAMES.join(", "))
    })
}

/// Parse `--vl BITS` with a default, validating §2.2 legality.
pub fn parse_vl(args: &[String], default: usize) -> Result<usize, String> {
    let Some(text) = flag(args, "--vl")? else { return Ok(default) };
    let Ok(vl) = text.parse::<usize>() else {
        return Err(format!("--vl '{text}' is not a number"));
    };
    if !crate::vl_is_legal(vl) {
        return Err(format!("--vl {vl} is illegal (§2.2: 128..2048 in steps of 128)"));
    }
    Ok(vl)
}

/// Parse `--vls A,B,C` (default `128,256,512`), validating each entry.
pub fn parse_vls(args: &[String]) -> Result<Vec<usize>, String> {
    let text = flag(args, "--vls")?.unwrap_or_else(|| "128,256,512".into());
    let mut vls = Vec::new();
    for part in text.split(',') {
        let Ok(vl) = part.trim().parse::<usize>() else {
            return Err(format!("--vls component '{part}' is not a number"));
        };
        if !crate::vl_is_legal(vl) {
            return Err(format!("--vls {vl} is illegal (§2.2: 128..2048 in steps of 128)"));
        }
        vls.push(vl);
    }
    Ok(vls)
}

/// Parse `--jobs N` (default `0` = one worker per CPU).
pub fn parse_jobs(args: &[String]) -> Result<usize, String> {
    let Some(text) = flag(args, "--jobs")? else { return Ok(0) };
    text.parse::<usize>().map_err(|_| format!("--jobs '{text}' is not a number"))
}

/// Parse `--benches a,b` (default: every benchmark).
pub fn parse_benches(args: &[String]) -> Result<Vec<&'static str>, String> {
    let Some(text) = flag(args, "--benches")? else {
        return Ok(workloads::NAMES.to_vec());
    };
    let mut names = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        match workloads::NAMES.iter().find(|n| **n == part) {
            Some(n) => names.push(*n),
            None => {
                return Err(format!(
                    "unknown benchmark '{part}' in --benches (try: {})",
                    workloads::NAMES.join(", ")
                ))
            }
        }
    }
    Ok(names)
}

/// `--no-trace` drops back to the baseline block interpreter; the
/// default is the superblock trace engine. Reported numbers are
/// bit-identical either way (pinned by `exec/trace.rs` tests) — the
/// flag exists for A/B simulator-throughput runs and for bisecting.
pub fn parse_engine(args: &[String]) -> Engine {
    if has_flag(args, "--no-trace") {
        Engine::Baseline
    } else {
        Engine::Trace
    }
}

// ---------------------------------------------------------------------
// SweepRequest
// ---------------------------------------------------------------------

/// One Fig. 8 sweep over a (benchmark × {NEON} ∪ {SVE@vl}) matrix —
/// the typed form of `sve sweep`, and (in its JSON spelling) the body
/// of a `sve-repro/serve-req/v1` sweep request.
///
/// ```
/// use sve_repro::request::SweepRequest;
/// let args: Vec<String> =
///     ["--vls", "128,256", "--benches", "haccmk", "--jobs", "2", "--resume"]
///         .iter().map(|s| s.to_string()).collect();
/// let req = SweepRequest::from_cli(&args).unwrap();
/// assert_eq!(req.vls, vec![128, 256]);
/// assert_eq!(req.benches, vec!["haccmk"]);
/// assert!(req.resume);
/// // the JSON spelling round-trips to the same request
/// let back = SweepRequest::from_json(&req.to_json()).unwrap();
/// assert_eq!(req, back);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SweepRequest {
    /// SVE vector lengths to sweep (bits), §2.2-legal, non-empty.
    pub vls: Vec<usize>,
    /// Benchmarks, interned against [`workloads::NAMES`].
    pub benches: Vec<&'static str>,
    /// Artifact/cache directory (`--out`). `None` = the CLI default
    /// `reports`; the server substitutes its own store directory.
    pub out: Option<PathBuf>,
    /// Worker threads (`--jobs`); `0` = one per CPU.
    pub jobs: usize,
    /// Reuse completed jobs cached on disk (`--resume`). The server
    /// always behaves as if this were set: the shared job store *is*
    /// the dedupe substrate.
    pub resume: bool,
    /// Run on the baseline interpreter (`--no-trace`). Results are
    /// bit-identical either way, so the server treats this as a local
    /// A/B knob and may ignore it.
    pub no_trace: bool,
}

impl SweepRequest {
    /// Parse the `sve sweep` flag set.
    pub fn from_cli(args: &[String]) -> Result<SweepRequest, String> {
        Ok(SweepRequest {
            vls: parse_vls(args)?,
            benches: parse_benches(args)?,
            out: flag(args, "--out")?.map(PathBuf::from),
            jobs: parse_jobs(args)?,
            resume: has_flag(args, "--resume"),
            no_trace: has_flag(args, "--no-trace"),
        })
    }

    /// The functional engine this request selects.
    pub fn engine(&self) -> Engine {
        if self.no_trace {
            Engine::Baseline
        } else {
            Engine::Trace
        }
    }

    /// The output directory, with the CLI default applied.
    pub fn out_dir(&self) -> PathBuf {
        self.out.clone().unwrap_or_else(|| PathBuf::from("reports"))
    }

    /// Lower into the coordinator's [`SweepConfig`] plus the artifact
    /// directory (always set: persistence is the point of the CLI).
    pub fn to_config(&self) -> (SweepConfig, PathBuf) {
        let out = self.out_dir();
        let mut cfg = SweepConfig::new(&self.vls, &self.benches);
        cfg.jobs = self.jobs;
        cfg.resume = self.resume;
        cfg.out_dir = Some(out.clone());
        cfg.engine = self.engine();
        (cfg, out)
    }

    /// The number of jobs this request's matrix expands to (per µarch
    /// variant): one NEON baseline plus one SVE point per VL, per
    /// benchmark.
    pub fn matrix_len(&self) -> usize {
        self.benches.len() * (1 + self.vls.len())
    }

    /// The JSON spelling (the serve wire body).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("vls".into(), Json::Arr(self.vls.iter().map(|&v| Json::u64(v as u64)).collect())),
            (
                "benches".into(),
                Json::Arr(self.benches.iter().map(|b| Json::str(*b)).collect()),
            ),
        ];
        if let Some(out) = &self.out {
            fields.push(("out".into(), Json::str(out.to_string_lossy())));
        }
        fields.push(("jobs".into(), Json::u64(self.jobs as u64)));
        fields.push(("resume".into(), Json::Bool(self.resume)));
        fields.push(("no_trace".into(), Json::Bool(self.no_trace)));
        Json::Obj(fields)
    }

    /// Parse the JSON spelling. Absent fields take the CLI defaults;
    /// present fields are validated with the same rules (and error
    /// wording) as the flags.
    pub fn from_json(v: &Json) -> Result<SweepRequest, String> {
        let vls = match v.get("vls") {
            None => vec![128, 256, 512],
            Some(arr) => {
                let items = arr.as_arr().ok_or("'vls' must be an array of numbers")?;
                let mut vls = Vec::with_capacity(items.len());
                for item in items {
                    let vl = item
                        .as_u64()
                        .ok_or("'vls' must be an array of numbers")?
                        as usize;
                    if !crate::vl_is_legal(vl) {
                        return Err(format!(
                            "--vls {vl} is illegal (§2.2: 128..2048 in steps of 128)"
                        ));
                    }
                    vls.push(vl);
                }
                vls
            }
        };
        let benches = match v.get("benches") {
            None => workloads::NAMES.to_vec(),
            Some(arr) => {
                let items = arr.as_arr().ok_or("'benches' must be an array of strings")?;
                let mut benches = Vec::with_capacity(items.len());
                for item in items {
                    let name =
                        item.as_str().ok_or("'benches' must be an array of strings")?;
                    benches.push(intern_bench(name)?);
                }
                benches
            }
        };
        let out = match v.get("out") {
            None | Some(Json::Null) => None,
            Some(o) => Some(PathBuf::from(o.as_str().ok_or("'out' must be a string")?)),
        };
        let jobs = match v.get("jobs") {
            None => 0,
            Some(j) => j.as_u64().ok_or("'jobs' must be a number")? as usize,
        };
        let get_bool = |key: &str| -> Result<bool, String> {
            match v.get(key) {
                None => Ok(false),
                Some(b) => b.as_bool().ok_or_else(|| format!("'{key}' must be a boolean")),
            }
        };
        Ok(SweepRequest {
            vls,
            benches,
            out,
            jobs,
            resume: get_bool("resume")?,
            no_trace: get_bool("no_trace")?,
        })
    }
}

// ---------------------------------------------------------------------
// DseRequest
// ---------------------------------------------------------------------

/// A design-space sweep across µarch variants — the typed form of
/// `sve dse`, and (in JSON) the body of a serve `dse` request.
///
/// ```
/// use sve_repro::request::DseRequest;
/// let args: Vec<String> =
///     ["--uarch", "table2,small-core", "--vls", "128", "--benches", "haccmk"]
///         .iter().map(|s| s.to_string()).collect();
/// let req = DseRequest::from_cli(&args).unwrap();
/// assert_eq!(req.variants().unwrap().len(), 2);
/// assert_eq!(DseRequest::from_json(&req.to_json()).unwrap(), req);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct DseRequest {
    /// The matrix + execution knobs shared with plain sweeps.
    pub sweep: SweepRequest,
    /// The `--uarch` variant spec (validated at parse time; expanded
    /// with [`DseRequest::variants`]).
    pub uarch: String,
    /// Restrict the report and artifacts to Pareto-frontier points.
    pub pareto_only: bool,
}

impl DseRequest {
    /// Parse the `sve dse` flag set.
    pub fn from_cli(args: &[String]) -> Result<DseRequest, String> {
        let uarch = flag(args, "--uarch")?.unwrap_or_else(|| VARIANT_NAMES.join(","));
        // validate the spec here so a typo is a parse error (exit 2 /
        // structured error response), not a mid-sweep failure
        parse_variants(&uarch)?;
        Ok(DseRequest {
            sweep: SweepRequest::from_cli(args)?,
            uarch,
            pareto_only: has_flag(args, "--pareto-only"),
        })
    }

    /// Expand the `--uarch` spec into concrete design points.
    pub fn variants(&self) -> Result<Vec<UarchVariant>, String> {
        parse_variants(&self.uarch)
    }

    /// The JSON spelling (the serve wire body): the sweep fields plus
    /// `uarch` and `pareto_only`.
    pub fn to_json(&self) -> Json {
        let mut fields = match self.sweep.to_json() {
            Json::Obj(fields) => fields,
            _ => unreachable!("SweepRequest::to_json returns an object"),
        };
        fields.push(("uarch".into(), Json::str(&self.uarch)));
        fields.push(("pareto_only".into(), Json::Bool(self.pareto_only)));
        Json::Obj(fields)
    }

    /// Parse the JSON spelling (defaults: all base variants, full
    /// report).
    pub fn from_json(v: &Json) -> Result<DseRequest, String> {
        let uarch = match v.get("uarch") {
            None => VARIANT_NAMES.join(","),
            Some(u) => u.as_str().ok_or("'uarch' must be a string")?.to_string(),
        };
        parse_variants(&uarch)?;
        let pareto_only = match v.get("pareto_only") {
            None => false,
            Some(b) => b.as_bool().ok_or("'pareto_only' must be a boolean")?,
        };
        Ok(DseRequest { sweep: SweepRequest::from_json(v)?, uarch, pareto_only })
    }
}

// ---------------------------------------------------------------------
// ReportRequest
// ---------------------------------------------------------------------

/// The figure-emission request behind `sve report` (without
/// `--compare`, which is a pure artifact diff and never runs jobs).
/// `report` is idempotent by design: it always resumes from the job
/// cache, so emitting figures twice never re-simulates.
///
/// ```
/// use sve_repro::request::ReportRequest;
/// let args: Vec<String> = ["--vls", "128"].iter().map(|s| s.to_string()).collect();
/// let req = ReportRequest::from_cli(&args).unwrap();
/// assert!(req.sweep.resume, "report always resumes");
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ReportRequest {
    /// The underlying sweep, with `resume` forced on.
    pub sweep: SweepRequest,
}

impl ReportRequest {
    /// Parse the `sve report` flag set.
    pub fn from_cli(args: &[String]) -> Result<ReportRequest, String> {
        let mut sweep = SweepRequest::from_cli(args)?;
        sweep.resume = true;
        Ok(ReportRequest { sweep })
    }
}

// ---------------------------------------------------------------------
// Serve / submit options (CLI-only: these configure the transport, not
// a job matrix, so they have no wire spelling)
// ---------------------------------------------------------------------

/// Options for `sve serve` — the long-running sweep service.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeOpts {
    /// `host:port` to listen on (default `127.0.0.1:7878`; port `0`
    /// picks an ephemeral port, printed at startup).
    pub listen: String,
    /// Job-store directory (default `reports`), shared with `sve
    /// sweep --out`/`--resume` runs.
    pub out: PathBuf,
    /// Worker threads per request; `0` = one per CPU.
    pub jobs: usize,
    /// On-disk job-cache budget in bytes (`--cache-bytes`); `None`
    /// disables GC.
    pub cache_bytes: Option<u64>,
    /// Per-request job budget (`--max-request-jobs`, default 4096):
    /// a runaway matrix gets a structured error, not a day-long sweep.
    pub max_request_jobs: usize,
    /// Run jobs on the baseline interpreter (`--no-trace`).
    pub no_trace: bool,
}

impl ServeOpts {
    /// Parse the `sve serve` flag set.
    pub fn from_cli(args: &[String]) -> Result<ServeOpts, String> {
        let cache_bytes = match flag(args, "--cache-bytes")? {
            None => None,
            Some(text) => Some(
                text.parse::<u64>()
                    .map_err(|_| format!("--cache-bytes '{text}' is not a number"))?,
            ),
        };
        let max_request_jobs = match flag(args, "--max-request-jobs")? {
            None => 4096,
            Some(text) => text
                .parse::<usize>()
                .map_err(|_| format!("--max-request-jobs '{text}' is not a number"))?,
        };
        Ok(ServeOpts {
            listen: flag(args, "--listen")?.unwrap_or_else(|| "127.0.0.1:7878".into()),
            out: flag(args, "--out")?.unwrap_or_else(|| "reports".into()).into(),
            jobs: parse_jobs(args)?,
            cache_bytes,
            max_request_jobs,
            no_trace: has_flag(args, "--no-trace"),
        })
    }
}

/// What a `sve submit` invocation asks the server to do.
#[derive(Clone, Debug, PartialEq)]
pub enum SubmitAction {
    /// Submit a sweep request and stream its results.
    Sweep(SweepRequest),
    /// Submit a design-space request and stream its results.
    Dse(DseRequest),
    /// Liveness probe (`--ping`): exit 0 iff the server answers.
    Ping,
    /// Print the server's cumulative dedupe/GC statistics (`--stats`).
    Stats,
    /// Ask the server to drain in-flight work and exit 0
    /// (`--shutdown`).
    Shutdown,
}

/// Options for `sve submit` — the scripting/CI client for a running
/// `sve serve`.
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitOpts {
    /// `host:port` of the server (default `127.0.0.1:7878`).
    pub addr: String,
    /// The request to send.
    pub action: SubmitAction,
}

impl SubmitOpts {
    /// Parse the `sve submit` flag set.
    pub fn from_cli(args: &[String]) -> Result<SubmitOpts, String> {
        let addr = flag(args, "--addr")?.unwrap_or_else(|| "127.0.0.1:7878".into());
        let action = if has_flag(args, "--ping") {
            SubmitAction::Ping
        } else if has_flag(args, "--stats") {
            SubmitAction::Stats
        } else if has_flag(args, "--shutdown") {
            SubmitAction::Shutdown
        } else if has_flag(args, "--dse") {
            SubmitAction::Dse(DseRequest::from_cli(args)?)
        } else if has_flag(args, "--uarch") {
            return Err("submit: --uarch requires --dse (plain submits run at table2)".into());
        } else {
            SubmitAction::Sweep(SweepRequest::from_cli(args)?)
        };
        Ok(SubmitOpts { addr, action })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn sweep_cli_defaults_match_the_pre_refactor_cli() {
        let req = SweepRequest::from_cli(&argv(&[])).unwrap();
        assert_eq!(req.vls, vec![128, 256, 512]);
        assert_eq!(req.benches, workloads::NAMES.to_vec());
        assert_eq!(req.out, None);
        assert_eq!(req.out_dir(), PathBuf::from("reports"));
        assert_eq!(req.jobs, 0);
        assert!(!req.resume && !req.no_trace);
        assert_eq!(req.engine(), Engine::Trace);
        let (cfg, out) = req.to_config();
        assert_eq!(cfg.vls, req.vls);
        assert_eq!(cfg.out_dir, Some(out));
    }

    #[test]
    fn sweep_cli_errors_keep_their_wording() {
        for (args, needle) in [
            (&["--vls", "128,xyz"][..], "not a number"),
            (&["--vls", "4096"][..], "illegal"),
            (&["--jobs", "many"][..], "not a number"),
            (&["--benches", "nosuchbench"][..], "unknown benchmark"),
            (&["--vls"][..], "--vls needs a value"),
            (&["--out"][..], "--out needs a value"),
        ] {
            let err = SweepRequest::from_cli(&argv(args)).unwrap_err();
            assert!(err.contains(needle), "{args:?}: {err}");
        }
    }

    #[test]
    fn sweep_json_roundtrip_is_exact() {
        let req = SweepRequest {
            vls: vec![128, 2048],
            benches: vec!["stream_triad", "su3_mv"],
            out: Some(PathBuf::from("elsewhere")),
            jobs: 7,
            resume: true,
            no_trace: true,
        };
        let text = req.to_json().render();
        let back = SweepRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(req, back);
        assert_eq!(back.engine(), Engine::Baseline);
    }

    #[test]
    fn sweep_json_defaults_and_rejections() {
        // an empty object is the default sweep
        let req = SweepRequest::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(req, SweepRequest::from_cli(&argv(&[])).unwrap());
        // bad shapes are structured errors, with the CLI's wording for
        // value-level problems
        for (text, needle) in [
            (r#"{"vls": "128"}"#, "array of numbers"),
            (r#"{"vls": [192]}"#, "illegal"),
            (r#"{"benches": ["nosuchbench"]}"#, "unknown benchmark"),
            (r#"{"benches": [128]}"#, "array of strings"),
            (r#"{"jobs": "many"}"#, "must be a number"),
            (r#"{"resume": 1}"#, "must be a boolean"),
        ] {
            let err =
                SweepRequest::from_json(&Json::parse(text).unwrap()).unwrap_err();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn dse_roundtrip_and_validation() {
        let args = argv(&["--uarch", "small-core,rob=64,128", "--vls", "128", "--pareto-only"]);
        let req = DseRequest::from_cli(&args).unwrap();
        assert!(req.pareto_only);
        assert_eq!(req.variants().unwrap().len(), 2);
        let back = DseRequest::from_json(&Json::parse(&req.to_json().render()).unwrap())
            .unwrap();
        assert_eq!(req, back);
        // a bad spec fails at parse time, both spellings
        assert!(DseRequest::from_cli(&argv(&["--uarch", "no-such-core"])).is_err());
        let err = DseRequest::from_json(&Json::parse(r#"{"uarch": "no-such-core"}"#).unwrap())
            .unwrap_err();
        assert!(err.contains("unknown variant"), "{err}");
        // defaults: every base variant
        let req = DseRequest::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(req.uarch, VARIANT_NAMES.join(","));
    }

    #[test]
    fn report_request_always_resumes() {
        let req = ReportRequest::from_cli(&argv(&[])).unwrap();
        assert!(req.sweep.resume);
        let (cfg, _) = req.sweep.to_config();
        assert!(cfg.resume);
    }

    #[test]
    fn serve_and_submit_opts_parse() {
        let opts = ServeOpts::from_cli(&argv(&[
            "--listen",
            "127.0.0.1:0",
            "--out",
            "store",
            "--cache-bytes",
            "4096",
            "--max-request-jobs",
            "12",
        ]))
        .unwrap();
        assert_eq!(opts.listen, "127.0.0.1:0");
        assert_eq!(opts.out, PathBuf::from("store"));
        assert_eq!(opts.cache_bytes, Some(4096));
        assert_eq!(opts.max_request_jobs, 12);
        assert!(ServeOpts::from_cli(&argv(&["--cache-bytes", "lots"])).is_err());

        let sub = SubmitOpts::from_cli(&argv(&["--ping"])).unwrap();
        assert_eq!(sub.action, SubmitAction::Ping);
        assert_eq!(sub.addr, "127.0.0.1:7878");
        let sub =
            SubmitOpts::from_cli(&argv(&["--dse", "--uarch", "table2", "--vls", "128"]))
                .unwrap();
        assert!(matches!(sub.action, SubmitAction::Dse(_)));
        let err = SubmitOpts::from_cli(&argv(&["--uarch", "table2"])).unwrap_err();
        assert!(err.contains("--uarch requires --dse"), "{err}");
    }

    #[test]
    fn matrix_len_counts_neon_plus_vls() {
        let req = SweepRequest::from_cli(&argv(&[
            "--vls",
            "128,256",
            "--benches",
            "haccmk,graph500",
        ]))
        .unwrap();
        assert_eq!(req.matrix_len(), 6);
    }
}
