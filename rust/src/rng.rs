//! Deterministic xoshiro256** RNG.
//!
//! The offline image ships no `rand` crate, and reproducibility of every
//! workload's memory image matters more than statistical perfection, so we
//! carry our own small, well-known generator. The same seeds are used by
//! the Python golden models where inputs must agree (those are generated on
//! the Rust side and fed to PJRT as literals, so cross-language agreement
//! is by construction).

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small seeds give well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        // Lemire-style rejection-free multiply-shift is fine here; slight
        // modulo bias at 2^64 scale is irrelevant for workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_range(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "100! chance");
    }
}
