//! PJRT golden-model runtime: loads the AOT-compiled JAX/Pallas HLO
//! artifacts (`artifacts/*.hlo.txt`, built once by `make artifacts`) and
//! executes them from Rust via the `xla` crate — Python is never on the
//! simulation path.
//!
//! The goldens cross-validate the ISA simulator: the same inputs are fed
//! to (a) the simulator running compiler-generated SVE code and (b) the
//! PJRT-executed Pallas kernels, and the results must agree. This proves
//! all three layers compose.
//!
//! The real path needs the external `xla` and `anyhow` crates, which the
//! offline image cannot fetch, so it is gated behind the `pjrt` cargo
//! feature (vendor the crates and wire them to the feature to enable
//! it). The default build compiles a dependency-free stub whose
//! [`validate_all`] returns an explanatory error; the CLI `validate`
//! subcommand reports it and the integration test self-skips because the
//! artifacts directory is absent.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    /// One validation outcome.
    #[derive(Debug)]
    pub struct Validation {
        pub name: String,
        pub max_abs_err: f64,
        pub ok: bool,
    }

    /// Stub: the build has no PJRT backend.
    pub fn validate_all(_artifacts_dir: impl AsRef<Path>) -> Result<Vec<Validation>, String> {
        Err("built without the `pjrt` feature: PJRT golden validation needs the \
             external `xla` crate (vendor it and enable the feature)"
            .into())
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;
