
use crate::rng::Rng;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A loaded golden-model executable.
pub struct Golden {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// The PJRT client + artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
            dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    /// Load and compile one artifact (HLO text — see aot.py for why text).
    pub fn load(&self, name: &str) -> Result<Golden> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parse {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        Ok(Golden { exe, name: name.to_string() })
    }
}

impl Golden {
    /// Execute with literal inputs; returns the single tuple element
    /// (aot.py lowers with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

/// One validation outcome.
#[derive(Debug)]
pub struct Validation {
    pub name: String,
    pub max_abs_err: f64,
    pub ok: bool,
}

/// Cross-validate the PJRT daxpy golden against the simulator's SVE
/// daxpy (Fig. 2c semantics through the whole stack).
pub fn validate_daxpy(rt: &Runtime) -> Result<Validation> {
    use crate::compiler::{compile, BinOp, Expr, Index, Kernel, Stmt, Target, Trip, Ty};
    use crate::exec::Executor;
    use crate::mem::Memory;

    const N: usize = 1024; // must match python/compile/model.py DAXPY_N
    let mut rng = Rng::new(2024);
    let a = 2.5f64;
    let n_active = 1000i32; // non-multiple-of-VL tail
    let xs: Vec<f64> = (0..N).map(|_| rng.f64_range(-2.0, 2.0)).collect();
    let ys: Vec<f64> = (0..N).map(|_| rng.f64_range(-2.0, 2.0)).collect();

    // PJRT side
    let g = rt.load("daxpy")?;
    let ln = xla::Literal::vec1(&[n_active]);
    let la = xla::Literal::vec1(&[a]);
    let lx = xla::Literal::vec1(&xs);
    let ly = xla::Literal::vec1(&ys);
    let out = g.run(&[ln, la, lx, ly])?;
    let golden: Vec<f64> = out.to_vec()?;

    // simulator side: compiler-generated SVE daxpy
    let mut mem = Memory::new();
    let xb = mem.alloc(8 * N as u64, 64);
    let yb = mem.alloc(8 * N as u64, 64);
    mem.write_f64_slice(xb, &xs);
    mem.write_f64_slice(yb, &ys);
    let mut k = Kernel::new("daxpy", Ty::F64, Trip::Count(n_active as u64));
    let x = k.array("x", Ty::F64, xb);
    let y = k.array("y", Ty::F64, yb);
    k.body.push(Stmt::Store {
        arr: y,
        idx: Index::Affine { offset: 0 },
        value: Expr::bin(
            BinOp::Add,
            Expr::bin(BinOp::Mul, Expr::ConstF(a), Expr::load(x, Index::Affine { offset: 0 })),
            Expr::load(y, Index::Affine { offset: 0 }),
        ),
    });
    let c = compile(&k, Target::Sve);
    let mut ex = Executor::new(512, mem);
    ex.run(&c.program, 10_000_000).map_err(|e| anyhow::anyhow!("sim trap {e:?}"))?;
    let sim = ex.mem.read_f64_slice(yb, N);

    let mut max_err = 0.0f64;
    for i in 0..N {
        max_err = max_err.max((sim[i] - golden[i]).abs());
    }
    Ok(Validation { name: "daxpy".into(), max_abs_err: max_err, ok: max_err < 1e-12 })
}

/// Cross-validate the ordered (fadda) and tree (faddv) reductions: the
/// simulator's SveFadda/FAddV against the Pallas goldens.
pub fn validate_reductions(rt: &Runtime) -> Result<Vec<Validation>> {
    use crate::arch::Esize;
    use crate::asm::Asm;
    use crate::exec::Executor;
    use crate::isa::{Inst, RedOp, SveMemOff};
    use crate::mem::Memory;

    const N: usize = 256; // must match model.py RED_N
    let mut rng = Rng::new(7777);
    let xs: Vec<f64> = (0..N).map(|_| rng.f64_range(-1e6, 1e6)).collect();
    let n_active = 200i32;

    let mut out = vec![];
    for (name, op) in [("fadda", None), ("faddv", Some(RedOp::FAddV))] {
        let g = rt.load(name)?;
        let golden: Vec<f64> =
            g.run(&[xla::Literal::vec1(&[n_active]), xla::Literal::vec1(&xs)])?.to_vec()?;

        // simulator: one whilelt-governed pass accumulating across the
        // whole array (vector loop for tree; fadda for ordered)
        let mut mem = Memory::new();
        let xb = mem.alloc(8 * N as u64, 64);
        mem.write_f64_slice(xb, &xs);
        let mut a = Asm::new();
        a.push(Inst::MovImm { xd: 0, imm: xb });
        a.push(Inst::MovImm { xd: 20, imm: 0 });
        a.push(Inst::MovImm { xd: 21, imm: n_active as u64 });
        a.push(Inst::FmovImm { dbl: true, dd: 24, bits: 0 });
        a.push(Inst::DupImm { zd: 16, esize: Esize::D, imm: 0 });
        a.push(Inst::Ptrue { pd: 6, esize: Esize::D, s: false });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 20, xm: 21, unsigned: false });
        a.label("loop");
        a.push(Inst::SveLd1 {
            zt: 0,
            pg: 0,
            esize: Esize::D,
            base: 0,
            off: SveMemOff::RegScaled(20),
            ff: false,
        });
        match op {
            None => a.push(Inst::SveFadda { vdn: 24, pg: 0, zm: 0, dbl: true }),
            Some(_) => a.push(Inst::SveFpBin {
                op: crate::isa::FpOp::Add,
                zdn: 16,
                pg: 0,
                zm: 0,
                dbl: true,
            }),
        };
        a.push(Inst::IncDec { xdn: 20, esize: Esize::D, dec: false });
        a.push(Inst::While { pd: 0, esize: Esize::D, xn: 20, xm: 21, unsigned: false });
        a.push_branch(Inst::BCond { cond: crate::arch::Cond::FIRST, target: 0 }, "loop");
        if op.is_some() {
            a.push(Inst::SveReduce { op: RedOp::FAddV, vd: 24, pg: 6, zn: 16, esize: Esize::D });
        }
        a.push(Inst::Halt);
        let p = a.finish();
        // VL = 2048 == 256 f64 lanes == the whole golden array: the tree
        // shapes then agree exactly
        let mut ex = Executor::new(2048, mem);
        ex.run(&p, 1_000_000).map_err(|e| anyhow::anyhow!("sim trap {e:?}"))?;
        let sim = ex.state.get_d(24);
        let err = (sim - golden[0]).abs();
        let tol = match name {
            "fadda" => 0.0,       // strictly ordered: must be bitwise equal
            _ => 1e-6,            // tree shapes may associate differently
        };
        out.push(Validation { name: name.into(), max_abs_err: err, ok: err <= tol });
    }
    Ok(out)
}

/// Validate the eorv golden (integer XOR is exact).
pub fn validate_eorv(rt: &Runtime) -> Result<Validation> {
    const N: usize = 256;
    let mut rng = Rng::new(31337);
    let xs: Vec<i64> = (0..N).map(|_| (rng.next_u64() >> 2) as i64).collect();
    let n_active = 170i32;
    let g = rt.load("eorv")?;
    let golden: Vec<i64> =
        g.run(&[xla::Literal::vec1(&[n_active]), xla::Literal::vec1(&xs)])?.to_vec()?;
    let want = xs[..n_active as usize].iter().fold(0i64, |a, &b| a ^ b);
    let ok = golden[0] == want;
    Ok(Validation { name: "eorv".into(), max_abs_err: if ok { 0.0 } else { 1.0 }, ok })
}

/// Run every cross-validation; returns one record per golden.
pub fn validate_all(artifacts_dir: impl AsRef<Path>) -> Result<Vec<Validation>> {
    let rt = Runtime::new(artifacts_dir)?;
    let mut v = vec![validate_daxpy(&rt)?];
    v.extend(validate_reductions(&rt)?);
    v.push(validate_eorv(&rt)?);
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("daxpy.hlo.txt").exists().then_some(p)
    }

    #[test]
    fn pjrt_goldens_match_simulator() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let vs = validate_all(dir).expect("validation harness");
        for v in &vs {
            assert!(v.ok, "{}: max_abs_err={}", v.name, v.max_abs_err);
        }
        assert_eq!(vs.len(), 4);
    }
}
