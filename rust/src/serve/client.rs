//! The client half of the serve protocol — the library behind
//! `sve submit`, and the harness `tests/serve.rs` drives concurrency
//! scenarios with.
//!
//! One [`Client`] owns one connection and speaks one request at a
//! time: send a line, then read response lines until the request's
//! terminal line (`done`, `error`, or the single-line answer).
//! Streamed job results are surfaced through a callback as they
//! arrive, so a large matrix reports progress incrementally instead of
//! buffering the whole sweep.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::request::{DseRequest, SweepRequest};
use crate::serve::hub::Stats;
use crate::serve::proto::{
    parse_response, render_request, Counts, Envelope, JobLine, Request, Response,
};

/// A connection to a running `sve serve`.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Monotonic per-connection request counter (correlation ids).
    next_id: u64,
}

impl Client {
    /// Connect to `host:port`.
    pub fn connect(addr: &str) -> Result<Client, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let writer = stream.try_clone().map_err(|e| format!("connect {addr}: {e}"))?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 0 })
    }

    fn send(&mut self, req: Request) -> Result<String, String> {
        self.next_id += 1;
        let id = format!("r{}", self.next_id);
        let line = render_request(&Envelope { id: id.clone(), req });
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .map_err(|e| format!("send request: {e}"))?;
        Ok(id)
    }

    fn recv(&mut self) -> Result<Response, String> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => Err("server closed the connection".into()),
            Ok(_) => parse_response(line.trim_end()),
            Err(e) => Err(format!("read response: {e}")),
        }
    }

    /// Liveness probe: `Ok` iff the server answered `pong`.
    pub fn ping(&mut self) -> Result<(), String> {
        self.send(Request::Ping)?;
        match self.recv()? {
            Response::Pong { .. } => Ok(()),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("expected pong, got {other:?}")),
        }
    }

    /// Fetch the server's cumulative dedupe/GC counters.
    pub fn stats(&mut self) -> Result<Stats, String> {
        self.send(Request::Stats)?;
        match self.recv()? {
            Response::Stats { stats, .. } => Ok(stats),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("expected stats, got {other:?}")),
        }
    }

    /// Ask the server to drain and exit; `Ok` once acknowledged.
    pub fn shutdown_server(&mut self) -> Result<(), String> {
        self.send(Request::Shutdown)?;
        match self.recv()? {
            Response::ShuttingDown { .. } => Ok(()),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("expected shutdown ack, got {other:?}")),
        }
    }

    /// Submit a sweep and stream its results: `on_job` fires once per
    /// retired job, in completion order. Returns the server's terminal
    /// accounting. Any `error` line — including a mid-stream job
    /// failure — ends the request as `Err`.
    pub fn submit_sweep(
        &mut self,
        req: &SweepRequest,
        on_job: &mut dyn FnMut(&JobLine),
    ) -> Result<Counts, String> {
        self.submit(Request::Sweep(req.clone()), on_job)
    }

    /// [`Client::submit_sweep`] for a design-space request.
    pub fn submit_dse(
        &mut self,
        req: &DseRequest,
        on_job: &mut dyn FnMut(&JobLine),
    ) -> Result<Counts, String> {
        self.submit(Request::Dse(req.clone()), on_job)
    }

    fn submit(
        &mut self,
        req: Request,
        on_job: &mut dyn FnMut(&JobLine),
    ) -> Result<Counts, String> {
        let id = self.send(req)?;
        loop {
            match self.recv()? {
                Response::Accepted { .. } => {}
                Response::Job { id: rid, job } => {
                    if rid == id {
                        on_job(&job);
                    }
                }
                Response::Done { id: rid, counts } if rid == id => return Ok(counts),
                Response::Error { message, .. } => return Err(message),
                other => return Err(format!("unexpected response {other:?}")),
            }
        }
    }
}
