//! The dedupe hub: one answer per job key, no matter how many clients
//! ask.
//!
//! [`Hub::obtain`] is the server's only path to a [`RunRecord`]. It
//! layers three caches over the simulator, checked in order:
//!
//! 1. **In-memory results** — jobs this server process has already
//!    retired ([`Source::Deduped`]).
//! 2. **In-flight claims** — a job some other client's worker is
//!    simulating *right now*. The caller blocks on a condvar and
//!    adopts the publisher's result (also [`Source::Deduped`] — the
//!    simulation ran once either way).
//! 3. **The on-disk job store** — the same content-addressed cache
//!    `sve sweep --resume` uses ([`Source::Reloaded`]; the file's
//!    mtime is bumped so the LRU GC sees the hit).
//!
//! Only a full miss simulates ([`Source::Simulated`]). The claim →
//! simulate → publish sequence is panic-safe: the claimant publishes a
//! `Done` slot (success *or* error) before returning, under a
//! `catch_unwind`, so waiters can never wedge on a job whose claimant
//! died — the tentpole robustness requirement.
//!
//! Workloads are built and compiled once per (benchmark, target) for
//! the lifetime of the hub, exactly like the batch coordinator's
//! prep table — the decoded µop program is VL- and µarch-independent
//! (§2.2), so every client at every design point shares it.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::compiler::{Compiled, Target};
use crate::coordinator::{run_compiled_engine_with, Isa, RunRecord};
use crate::exec::Engine;
use crate::report::store::{job_key, GcOutcome, JobStore};
use crate::uarch::UarchConfig;
use crate::workloads::{self, Workload};

/// Where an obtained record came from — the provenance streamed to the
/// client with every job line, and the basis of the smoke tests'
/// "simulated/deduped/reloaded" accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// This request ran the simulation (a full cache miss).
    Simulated,
    /// Served from hub memory: either retired earlier in this server's
    /// lifetime, or claimed by a concurrent request we waited on.
    Deduped,
    /// Reloaded from the on-disk job store (a `--resume`-style hit).
    Reloaded,
}

impl Source {
    /// The wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Source::Simulated => "simulated",
            Source::Deduped => "deduped",
            Source::Reloaded => "reloaded",
        }
    }

    /// Inverse of [`Source::as_str`].
    pub fn parse(s: &str) -> Option<Source> {
        match s {
            "simulated" => Some(Source::Simulated),
            "deduped" => Some(Source::Deduped),
            "reloaded" => Some(Source::Reloaded),
            _ => None,
        }
    }
}

/// Cumulative hub counters (whole-server lifetime), served by the
/// `stats` request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Stats {
    pub simulated: u64,
    pub deduped: u64,
    pub reloaded: u64,
    /// Job files evicted by the cache GC.
    pub evicted: u64,
}

/// One [`Hub::obtain`] outcome: the job's cache key, the record (or the
/// job's failure message), and where it came from.
pub struct Obtained {
    pub key: String,
    pub source: Source,
    pub result: Result<RunRecord, String>,
}

/// A retired or in-flight job in hub memory.
enum Slot {
    /// Claimed: some worker is simulating it; wait on the condvar.
    InFlight,
    /// Retired: adopt this result (errors dedupe too — a deterministic
    /// simulator fails identically on every retry).
    Done(Result<RunRecord, String>),
}

/// Compiled-once workload state shared across every VL, variant and
/// client (see module docs).
struct Prep {
    w: Workload,
    compiled: Compiled,
}

/// The server-side job broker: in-flight dedupe + result memory over
/// the content-addressed job store.
pub struct Hub {
    store: JobStore,
    engine: Engine,
    cache_bytes: Option<u64>,
    slots: Mutex<HashMap<String, Slot>>,
    retired: Condvar,
    preps: Mutex<HashMap<(&'static str, u8), Arc<Prep>>>,
    simulated: AtomicU64,
    deduped: AtomicU64,
    reloaded: AtomicU64,
    evicted: AtomicU64,
}

impl Hub {
    /// Open a hub over `<out_dir>/jobs/`, running jobs on `engine`.
    /// `cache_bytes` bounds the on-disk store ([`Hub::gc`]); `None`
    /// disables eviction.
    pub fn open(
        out_dir: &Path,
        engine: Engine,
        cache_bytes: Option<u64>,
    ) -> Result<Hub, String> {
        let store = JobStore::open(out_dir)
            .map_err(|e| format!("open job store in {out_dir:?}: {e}"))?;
        Ok(Hub {
            store,
            engine,
            cache_bytes,
            slots: Mutex::new(HashMap::new()),
            retired: Condvar::new(),
            preps: Mutex::new(HashMap::new()),
            simulated: AtomicU64::new(0),
            deduped: AtomicU64::new(0),
            reloaded: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        })
    }

    /// Get the record for one job, simulating at most once across all
    /// concurrent callers (see module docs for the cache order).
    ///
    /// `bench` must be interned against [`workloads::NAMES`] — the
    /// request layer guarantees this — and `cfg` must be realizable
    /// (checked by variant parsing). A panicking job is converted to a
    /// per-job `Err`, published to every waiter, and never unwinds into
    /// the caller.
    pub fn obtain(&self, bench: &'static str, isa: Isa, cfg: &UarchConfig) -> Obtained {
        let key = job_key(bench, isa, cfg);
        {
            let mut slots = self.slots.lock().unwrap();
            loop {
                match slots.get(&key) {
                    Some(Slot::Done(res)) => {
                        self.deduped.fetch_add(1, Ordering::Relaxed);
                        return Obtained { key, source: Source::Deduped, result: res.clone() };
                    }
                    Some(Slot::InFlight) => {
                        slots = self.retired.wait(slots).unwrap();
                    }
                    None => break,
                }
            }
            if let Some(r) = self.store.load(&key, bench, isa) {
                self.store.touch(&key); // an LRU hit: bump recency
                slots.insert(key.clone(), Slot::Done(Ok(r.clone())));
                self.reloaded.fetch_add(1, Ordering::Relaxed);
                return Obtained { key, source: Source::Reloaded, result: Ok(r) };
            }
            slots.insert(key.clone(), Slot::InFlight);
        }

        // full miss: we hold the claim — simulate outside the lock so
        // unrelated jobs proceed, then publish unconditionally
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let r = self.simulate(bench, isa, cfg)?;
            self.store
                .save(&key, &r)
                .map_err(|e| format!("persist {bench}/{}: {e}", isa.label()))?;
            Ok(r)
        }))
        .unwrap_or_else(|_| Err(format!("{bench}/{}: job panicked", isa.label())));
        self.simulated.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.slots.lock().unwrap();
        slots.insert(key.clone(), Slot::Done(result.clone()));
        self.retired.notify_all();
        Obtained { key, source: Source::Simulated, result }
    }

    fn simulate(
        &self,
        bench: &'static str,
        isa: Isa,
        cfg: &UarchConfig,
    ) -> Result<RunRecord, String> {
        let prep = self.prep(bench, isa.target());
        run_compiled_engine_with(&prep.w, &prep.compiled, isa, cfg, self.engine)
    }

    /// The compile-once table: build + compile on first use of a
    /// (benchmark, target), shared read-only afterwards.
    fn prep(&self, bench: &'static str, target: Target) -> Arc<Prep> {
        let tag = match target {
            Target::Scalar => 0u8,
            Target::Neon => 1,
            Target::Sve => 2,
        };
        let mut preps = self.preps.lock().unwrap();
        Arc::clone(preps.entry((bench, tag)).or_insert_with(|| {
            let w = workloads::build(bench);
            let compiled = w.compile(target);
            Arc::new(Prep { w, compiled })
        }))
    }

    /// Enforce the on-disk cache budget, never evicting a key some
    /// worker has in flight (its save would resurrect a file the GC
    /// just accounted, and a concurrent reload could read a torn view).
    /// `None` when GC is disabled or the directory scan failed.
    pub fn gc(&self) -> Option<GcOutcome> {
        let max = self.cache_bytes?;
        let in_flight: HashSet<String> = {
            let slots = self.slots.lock().unwrap();
            slots
                .iter()
                .filter(|(_, s)| matches!(s, Slot::InFlight))
                .map(|(k, _)| k.clone())
                .collect()
        };
        let out = self.store.gc(max, &|key| in_flight.contains(key)).ok()?;
        self.evicted.fetch_add(out.evicted as u64, Ordering::Relaxed);
        Some(out)
    }

    /// Cumulative counters since the hub opened.
    pub fn stats(&self) -> Stats {
        Stats {
            simulated: self.simulated.load(Ordering::Relaxed),
            deduped: self.deduped.load(Ordering::Relaxed),
            reloaded: self.reloaded.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sve-hub-{tag}-{}", std::process::id()))
    }

    #[test]
    fn concurrent_obtains_simulate_once() {
        let dir = tmp("dedupe");
        let _ = std::fs::remove_dir_all(&dir);
        let hub = Hub::open(&dir, Engine::default(), None).unwrap();
        let cfg = UarchConfig::default();
        let sources: Mutex<Vec<Source>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let got = hub.obtain("stream_triad", Isa::Sve(256), &cfg);
                    assert!(got.result.is_ok());
                    sources.lock().unwrap().push(got.source);
                });
            }
        });
        let sources = sources.into_inner().unwrap();
        let sim = sources.iter().filter(|s| **s == Source::Simulated).count();
        assert_eq!(sim, 1, "exactly one thread simulates: {sources:?}");
        assert_eq!(hub.stats().simulated, 1);
        assert_eq!(hub.stats().deduped, 3);
        // and the answers agree with a solo run
        let solo = crate::coordinator::run_one("stream_triad", Isa::Sve(256)).unwrap();
        let again = hub.obtain("stream_triad", Isa::Sve(256), &cfg);
        assert_eq!(again.source, Source::Deduped);
        assert_eq!(again.result.unwrap().cycles, solo.cycles);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_hits_count_as_reloaded_and_survive_a_new_hub() {
        let dir = tmp("reload");
        let _ = std::fs::remove_dir_all(&dir);
        let cycles = {
            let hub = Hub::open(&dir, Engine::default(), None).unwrap();
            let got = hub.obtain("haccmk", Isa::Neon, &UarchConfig::default());
            assert_eq!(got.source, Source::Simulated);
            got.result.unwrap().cycles
        };
        // a fresh hub over the same store: memory cold, disk warm
        let hub = Hub::open(&dir, Engine::default(), None).unwrap();
        let got = hub.obtain("haccmk", Isa::Neon, &UarchConfig::default());
        assert_eq!(got.source, Source::Reloaded);
        assert_eq!(got.result.unwrap().cycles, cycles);
        assert_eq!(hub.stats().reloaded, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_respects_budget() {
        let dir = tmp("gc");
        let _ = std::fs::remove_dir_all(&dir);
        // budget of one byte: everything evictable goes
        let hub = Hub::open(&dir, Engine::default(), Some(1)).unwrap();
        let cfg = UarchConfig::default();
        hub.obtain("stream_triad", Isa::Neon, &cfg).result.unwrap();
        hub.obtain("stream_triad", Isa::Sve(128), &cfg).result.unwrap();
        let out = hub.gc().unwrap();
        assert_eq!(out.examined, 2);
        assert_eq!(out.evicted, 2);
        assert!(out.bytes_after <= 1);
        assert_eq!(hub.stats().evicted, 2);
        // evicted jobs re-simulate (hub memory still has them — use a
        // fresh hub to prove the disk is really empty)
        let hub2 = Hub::open(&dir, Engine::default(), Some(1)).unwrap();
        let got = hub2.obtain("stream_triad", Isa::Neon, &cfg);
        assert_eq!(got.source, Source::Simulated);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
