//! `sve serve`: the long-running sweep service (ROADMAP item 3).
//!
//! A [`Server`] listens on a loopback TCP socket and speaks the
//! line-delimited JSON protocol of [`proto`]: clients submit
//! sweep/DSE requests (the JSON spelling of
//! [`crate::request::SweepRequest`] / [`crate::request::DseRequest`]),
//! the server expands each into the same deterministic job matrix the
//! batch coordinator uses ([`crate::coordinator::job_matrix`]), runs
//! the jobs through the dedupe [`hub::Hub`], and streams per-job
//! results back as they retire. TCP on `127.0.0.1` is the one
//! std-only transport that works identically everywhere the simulator
//! builds; the protocol itself is transport-agnostic bytes.
//!
//! The contracts, in one place:
//!
//! * **Dedupe** — a job requested by two clients simulates once; the
//!   second requester adopts the first's result (in-flight or
//!   retired). Counted per request as `simulated`/`deduped`/
//!   `reloaded` on the terminal `done` line.
//! * **Robustness** — a malformed request line gets a structured
//!   `error` response and the connection stays usable; a request
//!   expanding past the per-request job budget is refused up front; a
//!   panicking job becomes a per-job error response, never a server
//!   crash; a client disconnecting mid-stream stops its own workers
//!   (in-flight jobs still publish to the hub for everyone else).
//! * **Graceful shutdown** — on a `shutdown` request (or
//!   [`Server::request_shutdown`]): stop accepting connections,
//!   refuse new sweep/dse requests, let streams already accepted run
//!   to their `done` line, GC the cache, return from [`Server::run`]
//!   so the process can exit 0.
//! * **Cache lifecycle** — after every request the on-disk job store
//!   is garbage-collected down to `cache_bytes` (oldest mtime first;
//!   reload hits re-warm their file; in-flight keys are never
//!   evicted).

pub mod client;
pub mod hub;
pub mod proto;

pub use client::Client;

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{self, job_matrix};
use crate::exec::Engine;
use crate::request::{DseRequest, ServeOpts, SweepRequest};
use crate::uarch::{UarchConfig, UarchVariant};
use hub::{Hub, Source, Stats};
use proto::{Counts, JobLine, Request, Response};

/// How a [`Server`] runs jobs and manages its store.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    /// Job-store directory (shared with `sve sweep --out` runs).
    pub out_dir: PathBuf,
    /// Worker threads per request; `0` = one per CPU.
    pub jobs: usize,
    /// On-disk cache budget in bytes; `None` disables GC.
    pub cache_bytes: Option<u64>,
    /// Refuse requests expanding to more jobs than this.
    pub max_request_jobs: usize,
    /// Functional engine for every job (results are bit-identical on
    /// either engine, so this is a host-speed knob, not a semantic
    /// one).
    pub engine: Engine,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            out_dir: PathBuf::from("reports"),
            jobs: 0,
            cache_bytes: None,
            max_request_jobs: 4096,
            engine: Engine::default(),
        }
    }
}

impl ServerConfig {
    /// Lower the parsed `sve serve` CLI options into a config.
    pub fn from_opts(o: &ServeOpts) -> ServerConfig {
        ServerConfig {
            out_dir: o.out.clone(),
            jobs: o.jobs,
            cache_bytes: o.cache_bytes,
            max_request_jobs: o.max_request_jobs,
            engine: if o.no_trace { Engine::Baseline } else { Engine::Trace },
        }
    }
}

/// State shared by the accept loop and every connection handler.
struct Shared {
    hub: Hub,
    jobs: usize,
    max_request_jobs: usize,
    shutdown: AtomicBool,
}

/// The long-running sweep service. Bind, then [`Server::run`] until a
/// shutdown request arrives.
///
/// ```no_run
/// use sve_repro::serve::{Server, ServerConfig};
/// let server = Server::bind("127.0.0.1:7878", ServerConfig::default()).unwrap();
/// println!("listening on {}", server.local_addr().unwrap());
/// server.run().unwrap(); // returns after a shutdown request drains
/// ```
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7878`; port `0` picks a free
    /// port) and open the job store. No connection is accepted until
    /// [`Server::run`].
    pub fn bind(addr: &str, cfg: ServerConfig) -> Result<Server, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("bind {addr}: set_nonblocking: {e}"))?;
        let hub = Hub::open(&cfg.out_dir, cfg.engine, cfg.cache_bytes)?;
        let shared = Arc::new(Shared {
            hub,
            jobs: cfg.jobs,
            max_request_jobs: cfg.max_request_jobs,
            shutdown: AtomicBool::new(false),
        });
        Ok(Server { listener, shared })
    }

    /// The bound address (useful with port `0`).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Flip the shutdown flag from outside the protocol (tests, signal
    /// handlers). Equivalent to a client `shutdown` request.
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Cumulative hub counters (also served by the `stats` request).
    pub fn stats(&self) -> Stats {
        self.shared.hub.stats()
    }

    /// Accept and serve connections until shutdown, then drain: every
    /// stream already accepted runs to its terminal line before this
    /// returns. `Ok(())` is the graceful path — the caller exits 0.
    pub fn run(&self) -> Result<(), String> {
        let mut handles = Vec::new();
        loop {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let shared = Arc::clone(&self.shared);
                    handles.push(std::thread::spawn(move || handle_connection(stream, shared)));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(format!("accept: {e}")),
            }
            handles.retain(|h: &std::thread::JoinHandle<()>| !h.is_finished());
        }
        // drain: handlers see the flag, finish in-flight streams, exit
        for h in handles {
            let _ = h.join();
        }
        let _ = self.shared.hub.gc();
        Ok(())
    }
}

/// Write one response line; `false` means the client is gone.
fn send(writer: &Mutex<TcpStream>, resp: &Response) -> bool {
    let line = proto::render_response(resp);
    let mut w = writer.lock().unwrap();
    w.write_all(line.as_bytes()).and_then(|_| w.write_all(b"\n")).is_ok()
}

/// One line read from a connection.
enum ReadOutcome {
    /// A complete (or final unterminated) line is in the buffer.
    Line,
    /// The client closed (EOF or a hard socket error).
    Gone,
    /// The server is shutting down; abandon the idle connection.
    Draining,
}

/// Read one line, waking every read-timeout tick to check the shutdown
/// flag. Partial bytes survive across ticks inside `line` (the
/// protocol is ASCII JSON, so a timeout can never split a codepoint).
fn read_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    shutdown: &AtomicBool,
) -> ReadOutcome {
    loop {
        match reader.read_line(line) {
            Ok(0) => return ReadOutcome::Gone,
            Ok(_) => return ReadOutcome::Line,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    return ReadOutcome::Draining;
                }
            }
            Err(_) => return ReadOutcome::Gone,
        }
    }
}

fn handle_connection(stream: TcpStream, shared: Arc<Shared>) {
    // the read timeout is the shutdown-poll tick, not a deadline
    if stream.set_read_timeout(Some(Duration::from_millis(200))).is_err() {
        return;
    }
    let Ok(writer) = stream.try_clone() else { return };
    let writer = Mutex::new(writer);
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match read_line(&mut reader, &mut line, &shared.shutdown) {
            ReadOutcome::Line => {}
            ReadOutcome::Gone | ReadOutcome::Draining => return,
        }
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        let env = match proto::parse_request(text) {
            Ok(env) => env,
            Err(message) => {
                // a client bug costs one request, never the connection
                if !send(&writer, &Response::Error { id: String::new(), message }) {
                    return;
                }
                continue;
            }
        };
        let alive = match env.req {
            Request::Ping => send(&writer, &Response::Pong { id: env.id }),
            Request::Stats => {
                send(&writer, &Response::Stats { id: env.id, stats: shared.hub.stats() })
            }
            Request::Shutdown => {
                shared.shutdown.store(true, Ordering::SeqCst);
                send(&writer, &Response::ShuttingDown { id: env.id });
                return;
            }
            Request::Sweep(req) => serve_matrix(&writer, &shared, &env.id, &req, None),
            Request::Dse(req) => {
                serve_matrix(&writer, &shared, &env.id, &req.sweep, Some(&req))
            }
        };
        if !alive {
            return;
        }
    }
}

/// Expand, validate, and stream one sweep/dse request. Returns whether
/// the client is still connected.
fn serve_matrix(
    writer: &Mutex<TcpStream>,
    shared: &Shared,
    id: &str,
    sweep: &SweepRequest,
    dse: Option<&DseRequest>,
) -> bool {
    let refuse = |message: String| {
        send(writer, &Response::Error { id: id.to_string(), message })
    };
    if shared.shutdown.load(Ordering::SeqCst) {
        return refuse("server is shutting down; request refused".into());
    }
    let variants = match dse {
        Some(d) => match d.variants() {
            Ok(v) => v,
            Err(e) => return refuse(e),
        },
        None => vec![UarchVariant { name: "table2".into(), cfg: UarchConfig::default() }],
    };
    // same matrix validation (and wording) as the batch coordinator;
    // vl legality and benchmark names were already checked at parse
    if sweep.vls.is_empty() {
        return refuse("sweep needs at least one vector length".into());
    }
    if sweep.benches.is_empty() {
        return refuse("sweep needs at least one benchmark".into());
    }
    let jobs = job_matrix(&sweep.benches, &sweep.vls, variants.len());
    if jobs.len() > shared.max_request_jobs {
        return refuse(format!(
            "request expands to {} jobs, over the per-request budget of {}",
            jobs.len(),
            shared.max_request_jobs
        ));
    }
    if !send(writer, &Response::Accepted { id: id.to_string(), jobs: jobs.len() }) {
        return false;
    }

    // shard this request's matrix exactly like the batch coordinator:
    // self-scheduling workers over an atomic cursor. The hub dedupes
    // against every other concurrent request.
    let simulated = AtomicUsize::new(0);
    let deduped = AtomicUsize::new(0);
    let reloaded = AtomicUsize::new(0);
    let gone = AtomicBool::new(false);
    let failed = AtomicBool::new(false);
    let cursor = AtomicUsize::new(0);
    let nworkers = coordinator::worker_count(shared.jobs, jobs.len());
    std::thread::scope(|s| {
        for _ in 0..nworkers {
            s.spawn(|| loop {
                if gone.load(Ordering::SeqCst) || failed.load(Ordering::SeqCst) {
                    break;
                }
                let n = cursor.fetch_add(1, Ordering::Relaxed);
                if n >= jobs.len() {
                    break;
                }
                let job = jobs[n];
                let variant = &variants[job.variant];
                let got = shared.hub.obtain(job.bench, job.isa, &variant.cfg);
                match got.source {
                    Source::Simulated => simulated.fetch_add(1, Ordering::Relaxed),
                    Source::Deduped => deduped.fetch_add(1, Ordering::Relaxed),
                    Source::Reloaded => reloaded.fetch_add(1, Ordering::Relaxed),
                };
                let resp = match got.result {
                    Ok(record) => Response::Job {
                        id: id.to_string(),
                        job: JobLine {
                            variant: variant.name.clone(),
                            source: got.source,
                            key: got.key,
                            record,
                        },
                    },
                    Err(message) => {
                        // a failed job fails the request (like a batch
                        // sweep) but other workers' jobs still publish
                        failed.store(true, Ordering::SeqCst);
                        Response::Error { id: id.to_string(), message }
                    }
                };
                if !send(writer, &resp) {
                    // client hung up: stop pulling new jobs; jobs other
                    // requests still want stay obtainable via the hub
                    gone.store(true, Ordering::SeqCst);
                }
                if failed.load(Ordering::SeqCst) {
                    break;
                }
            });
        }
    });
    let mut alive = !gone.load(Ordering::SeqCst);
    if alive && !failed.load(Ordering::SeqCst) {
        alive = send(
            writer,
            &Response::Done {
                id: id.to_string(),
                counts: Counts {
                    jobs: jobs.len(),
                    simulated: simulated.load(Ordering::Relaxed),
                    deduped: deduped.load(Ordering::Relaxed),
                    reloaded: reloaded.load(Ordering::Relaxed),
                },
            },
        );
    }
    // cache lifecycle: enforce the budget once the burst is over
    let _ = shared.hub.gc();
    alive && !failed.load(Ordering::SeqCst)
}
