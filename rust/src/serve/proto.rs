//! The serve wire protocol: line-delimited JSON over a socket.
//!
//! Each line is one JSON document. Requests carry schema
//! [`REQ_SCHEMA`], responses [`RESP_SCHEMA`]; both are rendered with
//! the compact writer ([`crate::report::json::Json::render`]), which
//! escapes embedded newlines, so one document is always exactly one
//! line. The sweep/dse request bodies are the JSON spelling of
//! [`SweepRequest`]/[`DseRequest`] — the CLI and the socket share one
//! schema by construction (see [`crate::request`]).
//!
//! Request envelope:
//!
//! ```json
//! {"schema":"sve-repro/serve-req/v1","id":"r1","kind":"sweep",
//!  "request":{"vls":[128,256],"benches":["haccmk"]}}
//! ```
//!
//! `kind` is one of `sweep`, `dse`, `ping`, `stats`, `shutdown`;
//! `request` (sweep/dse only) may omit any field to take the CLI
//! default. A sweep/dse stream answers with one `accepted` line, one
//! `job` line per matrix cell **as each job retires** (order follows
//! completion, not the matrix), and one terminal `done` line; every
//! other kind answers with a single line. Any malformed or
//! unsupported line produces an `error` response and leaves the
//! connection usable — a client bug costs one request, never the
//! server.

use crate::report::json::Json;
use crate::report::store::{record_from_json, record_to_json};
use crate::request::{DseRequest, SweepRequest};
use crate::serve::hub::{Source, Stats};

/// Schema tag on every request line.
pub const REQ_SCHEMA: &str = "sve-repro/serve-req/v1";
/// Schema tag on every response line.
pub const RESP_SCHEMA: &str = "sve-repro/serve-resp/v1";

/// What a request line asks for.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Run a (benchmark × {NEON} ∪ {SVE@vl}) matrix at table2.
    Sweep(SweepRequest),
    /// Run the matrix across µarch variants.
    Dse(DseRequest),
    /// Liveness probe.
    Ping,
    /// Cumulative server counters.
    Stats,
    /// Drain in-flight work, refuse new work, exit 0.
    Shutdown,
}

/// A request plus its client-chosen correlation id (echoed verbatim on
/// every response line the request produces).
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub id: String,
    pub req: Request,
}

/// One streamed job result.
#[derive(Clone, Debug)]
pub struct JobLine {
    /// µarch variant display name (`table2` for plain sweeps).
    pub variant: String,
    /// Where the record came from (dedupe accounting).
    pub source: Source,
    /// The job's content-address in the store.
    pub key: String,
    /// The record itself, in the job-file schema.
    pub record: crate::coordinator::RunRecord,
}

/// The terminal accounting line of a sweep/dse stream.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub jobs: usize,
    pub simulated: usize,
    pub deduped: usize,
    pub reloaded: usize,
}

/// One response line.
#[derive(Clone, Debug)]
pub enum Response {
    /// The request parsed and fits the budget; `jobs` results follow.
    Accepted { id: String, jobs: usize },
    /// One retired job.
    Job { id: String, job: JobLine },
    /// End of a sweep/dse stream, with its dedupe accounting.
    Done { id: String, counts: Counts },
    /// The request failed (parse error, budget, drain, or a job
    /// failure); terminal for the request, not the connection.
    Error { id: String, message: String },
    /// Answer to `ping`.
    Pong { id: String },
    /// Answer to `stats`.
    Stats { id: String, stats: Stats },
    /// Answer to `shutdown`: the server is draining.
    ShuttingDown { id: String },
}

/// Render a request envelope as one wire line (no trailing newline).
pub fn render_request(env: &Envelope) -> String {
    let (kind, body) = match &env.req {
        Request::Sweep(r) => ("sweep", Some(r.to_json())),
        Request::Dse(r) => ("dse", Some(r.to_json())),
        Request::Ping => ("ping", None),
        Request::Stats => ("stats", None),
        Request::Shutdown => ("shutdown", None),
    };
    let mut fields = vec![
        ("schema".into(), Json::str(REQ_SCHEMA)),
        ("id".into(), Json::str(&env.id)),
        ("kind".into(), Json::str(kind)),
    ];
    if let Some(body) = body {
        fields.push(("request".into(), body));
    }
    Json::Obj(fields).render()
}

/// Parse one request line. Every failure is a `String` the server
/// wraps into an `error` response — parsing never panics.
pub fn parse_request(line: &str) -> Result<Envelope, String> {
    let v = Json::parse(line).map_err(|e| format!("malformed request: {e}"))?;
    let schema = v
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("malformed request: missing 'schema'")?;
    if schema != REQ_SCHEMA {
        return Err(format!("unsupported request schema '{schema}' (expected {REQ_SCHEMA})"));
    }
    let id = v.get("id").and_then(Json::as_str).unwrap_or("").to_string();
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("malformed request: missing 'kind'")?;
    let empty = Json::Obj(Vec::new());
    let body = v.get("request").unwrap_or(&empty);
    let req = match kind {
        "sweep" => Request::Sweep(SweepRequest::from_json(body)?),
        "dse" => Request::Dse(DseRequest::from_json(body)?),
        "ping" => Request::Ping,
        "stats" => Request::Stats,
        "shutdown" => Request::Shutdown,
        other => return Err(format!("unknown request kind '{other}'")),
    };
    Ok(Envelope { id, req })
}

fn head(kind: &str, id: &str) -> Vec<(String, Json)> {
    vec![
        ("schema".into(), Json::str(RESP_SCHEMA)),
        ("type".into(), Json::str(kind)),
        ("id".into(), Json::str(id)),
    ]
}

/// Render a response as one wire line (no trailing newline).
pub fn render_response(resp: &Response) -> String {
    let fields = match resp {
        Response::Accepted { id, jobs } => {
            let mut f = head("accepted", id);
            f.push(("jobs".into(), Json::u64(*jobs as u64)));
            f
        }
        Response::Job { id, job } => {
            let mut f = head("job", id);
            f.push(("variant".into(), Json::str(&job.variant)));
            f.push(("source".into(), Json::str(job.source.as_str())));
            f.push(("record".into(), record_to_json(&job.key, &job.record)));
            f
        }
        Response::Done { id, counts } => {
            let mut f = head("done", id);
            f.push(("jobs".into(), Json::u64(counts.jobs as u64)));
            f.push(("simulated".into(), Json::u64(counts.simulated as u64)));
            f.push(("deduped".into(), Json::u64(counts.deduped as u64)));
            f.push(("reloaded".into(), Json::u64(counts.reloaded as u64)));
            f
        }
        Response::Error { id, message } => {
            let mut f = head("error", id);
            f.push(("message".into(), Json::str(message)));
            f
        }
        Response::Pong { id } => head("pong", id),
        Response::Stats { id, stats } => {
            let mut f = head("stats", id);
            f.push(("simulated".into(), Json::u64(stats.simulated)));
            f.push(("deduped".into(), Json::u64(stats.deduped)));
            f.push(("reloaded".into(), Json::u64(stats.reloaded)));
            f.push(("evicted".into(), Json::u64(stats.evicted)));
            f
        }
        Response::ShuttingDown { id } => head("shutting-down", id),
    };
    Json::Obj(fields).render()
}

/// Parse one response line (the client half of the protocol).
pub fn parse_response(line: &str) -> Result<Response, String> {
    let v = Json::parse(line).map_err(|e| format!("malformed response: {e}"))?;
    let schema = v
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("malformed response: missing 'schema'")?;
    if schema != RESP_SCHEMA {
        return Err(format!("unsupported response schema '{schema}' (expected {RESP_SCHEMA})"));
    }
    let id = v.get("id").and_then(Json::as_str).unwrap_or("").to_string();
    let kind = v
        .get("type")
        .and_then(Json::as_str)
        .ok_or("malformed response: missing 'type'")?;
    let num = |key: &str| -> Result<u64, String> {
        v.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("malformed response: missing '{key}'"))
    };
    match kind {
        "accepted" => Ok(Response::Accepted { id, jobs: num("jobs")? as usize }),
        "job" => {
            let rec = v.get("record").ok_or("malformed response: missing 'record'")?;
            let record =
                record_from_json(rec).ok_or("malformed response: bad job record")?;
            let key = rec
                .get("key")
                .and_then(Json::as_str)
                .ok_or("malformed response: record missing 'key'")?
                .to_string();
            let variant = v
                .get("variant")
                .and_then(Json::as_str)
                .ok_or("malformed response: missing 'variant'")?
                .to_string();
            let source = v
                .get("source")
                .and_then(Json::as_str)
                .and_then(Source::parse)
                .ok_or("malformed response: bad 'source'")?;
            Ok(Response::Job { id, job: JobLine { variant, source, key, record } })
        }
        "done" => Ok(Response::Done {
            id,
            counts: Counts {
                jobs: num("jobs")? as usize,
                simulated: num("simulated")? as usize,
                deduped: num("deduped")? as usize,
                reloaded: num("reloaded")? as usize,
            },
        }),
        "error" => {
            let message = v
                .get("message")
                .and_then(Json::as_str)
                .ok_or("malformed response: missing 'message'")?
                .to_string();
            Ok(Response::Error { id, message })
        }
        "pong" => Ok(Response::Pong { id }),
        "stats" => Ok(Response::Stats {
            id,
            stats: Stats {
                simulated: num("simulated")?,
                deduped: num("deduped")?,
                reloaded: num("reloaded")?,
                evicted: num("evicted")?,
            },
        }),
        "shutting-down" => Ok(Response::ShuttingDown { id }),
        other => Err(format!("unknown response type '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Isa;

    #[test]
    fn request_roundtrip_all_kinds() {
        let args: Vec<String> =
            ["--vls", "128,256", "--benches", "haccmk"].iter().map(|s| s.to_string()).collect();
        let sweep = SweepRequest::from_cli(&args).unwrap();
        let dse_args: Vec<String> =
            ["--uarch", "table2,small-core"].iter().map(|s| s.to_string()).collect();
        let dse = DseRequest::from_cli(&dse_args).unwrap();
        for req in [
            Request::Sweep(sweep),
            Request::Dse(dse),
            Request::Ping,
            Request::Stats,
            Request::Shutdown,
        ] {
            let env = Envelope { id: "r7".into(), req };
            let line = render_request(&env);
            assert!(!line.contains('\n'), "one document, one line: {line}");
            assert_eq!(parse_request(&line).unwrap(), env);
        }
    }

    #[test]
    fn request_parse_rejects_garbage_without_panicking() {
        for bad in [
            "not json at all",
            "{}",
            r#"{"schema":"sve-repro/serve-req/v0","kind":"ping"}"#,
            r#"{"schema":"sve-repro/serve-req/v1","kind":"frobnicate"}"#,
            r#"{"schema":"sve-repro/serve-req/v1","kind":"sweep","request":{"vls":[192]}}"#,
            r#"{"schema":"sve-repro/serve-req/v1","kind":"sweep","request":{"benches":["x"]}}"#,
        ] {
            assert!(parse_request(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn response_roundtrip_carries_records_bit_exactly() {
        let record = crate::coordinator::run_one("haccmk", Isa::Sve(128)).unwrap();
        let resp = Response::Job {
            id: "r1".into(),
            job: JobLine {
                variant: "table2".into(),
                source: Source::Simulated,
                key: "deadbeefdeadbeef".into(),
                record: record.clone(),
            },
        };
        let line = render_response(&resp);
        assert!(!line.contains('\n'));
        match parse_response(&line).unwrap() {
            Response::Job { id, job } => {
                assert_eq!(id, "r1");
                assert_eq!(job.variant, "table2");
                assert_eq!(job.source, Source::Simulated);
                assert_eq!(job.key, "deadbeefdeadbeef");
                assert_eq!(job.record.cycles, record.cycles);
                assert_eq!(job.record.insts, record.insts);
                assert_eq!(
                    job.record.vector_fraction.to_bits(),
                    record.vector_fraction.to_bits()
                );
                assert_eq!(job.record.ipc.to_bits(), record.ipc.to_bits());
                assert_eq!(job.record.counters, record.counters);
            }
            other => panic!("expected a job response, got {other:?}"),
        }
    }

    #[test]
    fn response_roundtrip_scalar_kinds() {
        let counts = Counts { jobs: 6, simulated: 3, deduped: 2, reloaded: 1 };
        match parse_response(&render_response(&Response::Done { id: "a".into(), counts }))
            .unwrap()
        {
            Response::Done { id, counts: c } => {
                assert_eq!(id, "a");
                assert_eq!(c, counts);
            }
            other => panic!("{other:?}"),
        }
        let stats = Stats { simulated: 10, deduped: 20, reloaded: 5, evicted: 2 };
        match parse_response(&render_response(&Response::Stats { id: "s".into(), stats }))
            .unwrap()
        {
            Response::Stats { stats: s, .. } => assert_eq!(s, stats),
            other => panic!("{other:?}"),
        }
        for resp in [
            Response::Pong { id: "p".into() },
            Response::ShuttingDown { id: "q".into() },
            Response::Error { id: "e".into(), message: "nope".into() },
            Response::Accepted { id: "x".into(), jobs: 42 },
        ] {
            let line = render_response(&resp);
            assert!(parse_response(&line).is_ok(), "{line}");
        }
    }
}
