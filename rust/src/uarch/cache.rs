//! Two-level cache hierarchy: L1 (I or D) backed by a unified L2.
//! Set-associative, LRU, line granularity. Accessed in program order by
//! the timing pipeline (a standard trace-driven approximation).

/// One set-associative cache level.
pub struct Cache {
    sets: usize,
    assoc: usize,
    line_shift: u32,
    /// tags[set * assoc + way]
    tags: Vec<u64>,
    /// LRU timestamps, same layout
    lru: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(bytes: usize, assoc: usize, line_bytes: usize) -> Self {
        let lines = bytes / line_bytes;
        let sets = lines / assoc;
        assert!(sets.is_power_of_two(), "set count must be a power of two");
        Cache {
            sets,
            assoc,
            line_shift: line_bytes.trailing_zeros(),
            tags: vec![u64::MAX; lines],
            lru: vec![0; lines],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Look up (and fill on miss) the line containing `addr`.
    /// Returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let line = addr >> self.line_shift;
        let set = (line as usize) & (self.sets - 1);
        let base = set * self.assoc;
        for w in 0..self.assoc {
            if self.tags[base + w] == line {
                self.lru[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        // LRU victim
        let mut victim = 0;
        for w in 1..self.assoc {
            if self.lru[base + w] < self.lru[base + victim] {
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.lru[base + victim] = self.clock;
        false
    }
}

/// Where an access was served from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HitLevel {
    L1,
    L2,
    Mem,
}

/// L1 + unified L2.
pub struct Hierarchy {
    pub l1d: Cache,
    pub l1i: Cache,
    pub l2: Cache,
}

impl Hierarchy {
    pub fn new(cfg: &super::UarchConfig) -> Self {
        Hierarchy {
            l1d: Cache::new(cfg.l1d_bytes, cfg.l1d_assoc, cfg.line_bytes),
            l1i: Cache::new(cfg.l1i_bytes, cfg.l1i_assoc, cfg.line_bytes),
            l2: Cache::new(cfg.l2_bytes, cfg.l2_assoc, cfg.line_bytes),
        }
    }

    pub fn access_data(&mut self, addr: u64) -> HitLevel {
        if self.l1d.access(addr) {
            HitLevel::L1
        } else if self.l2.access(addr) {
            HitLevel::L2
        } else {
            HitLevel::Mem
        }
    }

    pub fn access_inst(&mut self, addr: u64) -> HitLevel {
        let level = if self.l1i.access(addr) {
            HitLevel::L1
        } else if self.l2.access(addr) {
            HitLevel::L2
        } else {
            HitLevel::Mem
        };
        // sequential next-line prefetcher: straight-line code pays the
        // cold-miss penalty once, not per line
        let next = addr + 64;
        self.l1i.access(next);
        self.l2.access(next);
        level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(64 * 1024, 4, 64);
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1004), "same line");
        assert!(!c.access(0x1040), "next line misses");
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn conflict_evicts_lru() {
        // 64KB/4way/64B: 256 sets; addresses 64KB/4 = 16KB apart collide
        let mut c = Cache::new(64 * 1024, 4, 64);
        let stride = 16 * 1024u64;
        for k in 0..4 {
            assert!(!c.access(k * stride));
        }
        for k in 0..4 {
            assert!(c.access(k * stride), "all four ways resident");
        }
        assert!(!c.access(4 * stride), "fifth way evicts");
        assert!(!c.access(0), "way 0 was LRU victim");
    }

    #[test]
    fn working_set_larger_than_l1_spills_to_l2() {
        let cfg = super::super::UarchConfig::default();
        let mut h = Hierarchy::new(&cfg);
        // stream 128KB: misses L1 (64KB) on second pass, hits L2 (256KB)
        let lines = (128 * 1024) / 64;
        for i in 0..lines {
            h.access_data(i as u64 * 64);
        }
        let (mut l1h, mut l2h, mut mem) = (0, 0, 0);
        for i in 0..lines {
            match h.access_data(i as u64 * 64) {
                HitLevel::L1 => l1h += 1,
                HitLevel::L2 => l2h += 1,
                HitLevel::Mem => mem += 1,
            }
        }
        assert!(l2h > lines / 2, "most of pass 2 should hit L2 (got {l2h})");
        assert_eq!(mem, 0, "fits L2");
        let _ = l1h;
    }
}
